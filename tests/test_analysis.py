"""Static analysis & verification (ISSUE-10).

Covers: the seeded-mutation self-test (every injected miscompilation
caught with its expected code and attributed to the mutating pass), the
verifier being a no-op on all committed benchmark SDFGs through both
backend pipelines, the repaired structural checks in core.validation
(STRUCT001 symbol collision, STRUCT002 connector shadowing), the typed
refusal-code taxonomy shared by ``grid_decisions`` and verifier
findings, strict-mode failure, verify-aware compilation-cache keys, and
the serving donation metadata.
"""
import importlib
import os
import sys

import pytest

from repro.analysis import (CODES, Diagnostic, VerificationError,
                            refusal_code, verify_sdfg)
from repro.analysis.selftest import CASES, run_case, vec_sdfg
from repro.core.validation import ValidationError, validate_sdfg
from repro.pipeline import lower
from repro.pipeline.cache import CompilationCache
from repro.pipeline.passes import PassManager
from repro.pipeline.stages import _env_verify

BENCH_DIR = os.path.join(os.path.dirname(__file__), os.pardir,
                         "benchmarks")


def _bench(name):
    if BENCH_DIR not in sys.path:
        sys.path.insert(0, BENCH_DIR)
    return importlib.import_module(name)


# ---------------------------------------------------------------------------
# Seeded-mutation self-test
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("case", CASES, ids=[c.name for c in CASES])
def test_mutation_caught_with_expected_code(case):
    """Each injected miscompilation is caught with the right code,
    attributed to the mutation pass, on a clean baseline."""
    r = run_case(case)
    assert r["baseline_clean"], \
        f"{case.name}: base program not clean: {r}"
    assert r["prior_passes_clean"], \
        f"{case.name}: a legitimate pass was blamed: {r}"
    assert r["caught"], \
        f"{case.name}: expected {case.expected_code}, got {r['codes']}"
    assert r["attribution_ok"] and case.name in r["attributed_to"]


def test_mutation_classes_are_distinct():
    """ISSUE-10 acceptance: >= 8 distinct miscompilation classes."""
    assert len({c.expected_code for c in CASES}) >= 8
    assert len(CASES) >= 8


def test_strict_mode_raises_at_offending_pass():
    case = CASES[0]  # wcr_drop
    sdfg = case.build()
    pm = PassManager(case.passes(), name="strict")
    from repro.analysis.selftest import _MutationPass
    pm.append(_MutationPass(case.mutate, case.name))
    with pytest.raises(VerificationError) as exc:
        pm.run(sdfg, report={}, verify="strict")
    assert any(d.code == case.expected_code for d in exc.value.diagnostics)
    assert all(d.pass_name and d.pass_name.startswith("Mutate[")
               for d in exc.value.diagnostics)


# ---------------------------------------------------------------------------
# Verifier is a no-op on every committed benchmark
# ---------------------------------------------------------------------------


_BENCH_BUILDERS = [
    ("axpydot", lambda: _bench("axpydot").build(256)),
    ("axpydot_two_producer",
     lambda: _bench("axpydot").build_two_producer(256)),
    ("gemver", lambda: _bench("gemver").build(64)),
    ("gemver_chain", lambda: _bench("gemver").build_chain(64)),
    ("star_stencil", lambda: _bench("stencil_bench")._star_sdfg(64, 64)),
    ("jacobi_chain", lambda: _bench("jacobi_chain")._chain_sdfg(128)),
    ("lenet_convblock", lambda: _bench("lenet")._convblock_sdfg(2)),
]


@pytest.mark.parametrize("backend", ["jnp", "pallas"])
@pytest.mark.parametrize("name,build", _BENCH_BUILDERS,
                         ids=[n for n, _ in _BENCH_BUILDERS])
def test_benchmarks_verify_clean(name, build, backend):
    cp = lower(build()).compile(backend=backend, cache=None, verify="full")
    vrec = cp.report["verify"]
    assert vrec["baseline"] == []
    assert vrec["violations"] == 0, vrec
    assert all(p["clean"] for p in vrec["passes"])
    # every executed pass got a verification record
    executed = [p["name"] for p in cp.report["passes"]
                if not p["skipped"]]
    assert [p["name"] for p in vrec["passes"]] == executed


def test_verify_off_by_default(monkeypatch):
    monkeypatch.delenv("REPRO_VERIFY", raising=False)
    cp = lower(vec_sdfg()).compile(cache=None)
    assert "verify" not in cp.report


def test_env_verify_parsing(monkeypatch):
    for raw, want in [("", None), ("0", None), ("off", None),
                      ("1", "full"), ("full", "full"),
                      ("strict", "strict"), ("TRUE", "full")]:
        monkeypatch.setenv("REPRO_VERIFY", raw)
        assert _env_verify() == want, raw


def test_verify_keys_cache_separately(monkeypatch):
    """A cached non-verified artifact must not satisfy a verifying
    compile (it has no verify record), and vice versa."""
    monkeypatch.delenv("REPRO_VERIFY", raising=False)
    cache = CompilationCache(max_entries=8)
    low = lower(vec_sdfg())
    plain = low.compile(cache=cache)
    verified = low.compile(cache=cache, verify="full")
    assert "verify" not in plain.report
    assert verified.report["verify"]["violations"] == 0
    # both are cached, under distinct keys
    assert low.compile(cache=cache) is plain
    assert low.compile(cache=cache, verify="full") is verified


# ---------------------------------------------------------------------------
# core.validation structural checks (satellite regression)
# ---------------------------------------------------------------------------


def test_container_symbol_collision_rejected():
    s = vec_sdfg()
    s.specialize(x=3)   # symbol named like the container
    with pytest.raises(ValidationError) as exc:
        validate_sdfg(s)
    assert exc.value.code == "STRUCT001"
    assert "x" in str(exc.value)


def test_connector_shadowing_rejected():
    from repro.core.sdfg import SDFG
    s = SDFG("shadow")
    s.add_array("a", (4,), "float32")
    st = s.add_state("main", is_start=True)
    t = st.add_tasklet("t", ["v", "v"], ["o"],
                       fn=lambda v: {"o": v})
    acc_in = st.add_access("a")
    acc_out = st.add_access("a")
    from repro.core.memlet import Memlet, Range, Subset
    sub = Subset([Range.make(0, 4)])
    st.add_edge(acc_in, None, t, "v", Memlet.simple("a", sub))
    st.add_edge(t, "o", acc_out, None, Memlet.simple("a", sub))
    with pytest.raises(ValidationError) as exc:
        validate_sdfg(s)
    assert exc.value.code == "STRUCT002"


def test_same_name_in_and_out_is_legal():
    """Inputs are fn kwargs, outputs are result keys — one name in both
    is the serving decode step's idiom, not shadowing."""
    from repro.core.memlet import Memlet, Range, Subset
    from repro.core.sdfg import SDFG
    s = SDFG("inout")
    s.add_array("a", (4,), "float32")
    st = s.add_state("main", is_start=True)
    t = st.add_tasklet("t", ["x"], ["x"], fn=lambda x: {"x": x})
    sub = Subset([Range.make(0, 4)])
    st.add_edge(st.add_access("a"), None, t, "x", Memlet.simple("a", sub))
    st.add_edge(t, "x", st.add_access("a"), None, Memlet.simple("a", sub))
    validate_sdfg(s)   # must not raise


def test_validation_error_surfaces_as_struct_diagnostic():
    s = vec_sdfg()
    s.specialize(x=3)
    diags = verify_sdfg(s)
    assert any(d.code == "STRUCT001" for d in diags)


# ---------------------------------------------------------------------------
# Typed refusal taxonomy (satellite)
# ---------------------------------------------------------------------------


def test_refusal_codes_classify_known_reasons():
    assert refusal_code("fusion",
                        "fusing would reorder accesses to t") == "FUS001"
    assert refusal_code("fusion", "t is pinned to HBM") == "FUS002"
    assert refusal_code("fusion", "something novel") == "FUS000"
    assert refusal_code("grid",
                        "blocks pin 99 B of VMEM > budget 1 B") == "GRD001"
    assert refusal_code("grid",
                        "grid of 1 step(s) below min_grid_steps=2; "
                        "vmap path wins") == "GRD002"
    assert refusal_code("grid_fallback", "anything") == "GRD004"
    assert refusal_code("shard",
                        "read crosses the shard boundary") == "SHR002"
    assert refusal_code("shard", "mystery") == "SHR000"


def test_all_refusal_rules_map_to_registered_codes():
    from repro.analysis.diagnostics import (_REFUSAL_FALLBACK,
                                            _REFUSAL_RULES)
    for rules in _REFUSAL_RULES.values():
        for _, code in rules:
            assert code in CODES
    for code in _REFUSAL_FALLBACK.values():
        assert code in CODES


def test_grid_decisions_carry_codes():
    """Every refusal-shaped grid decision now carries a typed code, and
    the verbatim reason strings are untouched."""
    jacobi = _bench("jacobi_chain")
    cp = lower(jacobi._chain_sdfg(128)).compile(backend="pallas",
                                                cache=None)
    refused = [d for d in cp.report["grid_decisions"]
               if d["decision"] in ("unfused", "vmap", "unsharded",
                                    "shard_refused")]
    assert refused, "expected at least one refusal in the jacobi chain"
    for d in refused:
        assert d["code"] in CODES, d
    # the unified stream mirrors them as info-severity diagnostics
    assert cp.report["refusals"]
    for r in cp.report["refusals"]:
        assert r["code"] in CODES and r["severity"] == "info"


def test_diagnostic_identity_excludes_attribution():
    a = Diagnostic(code="BND001", message="m", state="s")
    assert a.key() == a.attributed("SomePass").key()
    assert a.attributed("SomePass").to_dict()["pass"] == "SomePass"


# ---------------------------------------------------------------------------
# Donation metadata on the serving path
# ---------------------------------------------------------------------------


def test_serving_decode_step_stamps_donated_metadata():
    import dataclasses

    import jax

    from repro.configs import get_config
    from repro.models.transformer import TransformerLM
    from repro.serving.compile import DecodeStepCompiler

    cfg = dataclasses.replace(get_config("granite-3-2b").reduced(),
                              activation_dtype="float32")
    model = TransformerLM(cfg)
    params = model.init(jax.random.PRNGKey(0))
    compiler = DecodeStepCompiler(model, params, page_size=8, n_pages=16)
    low = compiler._lowered(B=2, ctx=16)
    donated = low.sdfg.metadata["donated"]
    assert donated == sorted(compiler._donate) and donated
    # every donated buffer is written by the step: the donation lint
    # stays silent (DON001 would be the PR-6/PR-8 aliasing bug)
    from repro.analysis.bounds import check_donation
    assert check_donation(low.sdfg) == []
