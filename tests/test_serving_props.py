"""Property test: scheduler churn preserves pool invariants.

Random interleavings of submit / step / pool seizure / clock advance —
whatever the order, the KVPagePool accounting must stay exact
(free + live + seized == capacity, zero reservation drift, no null or
duplicated live pages; all checked by ``Scheduler.check_invariants``)
and, once pressure lifts, every request must terminate with a typed
finish reason.

The property is stated once (:func:`churn_property`) and driven two
ways: by Hypothesis when it is installed (shrinking on failure), and by
a seeded numpy fuzzer otherwise, so the invariant check always runs even
on machines without the optional dependency.
"""
import dataclasses

import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.models.transformer import TransformerLM
from repro.pipeline.cache import CompilationCache
from repro.serving import FINISH_REASONS, Scheduler

try:
    from hypothesis import HealthCheck, given, settings
    from hypothesis import strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

CACHE = CompilationCache()


@pytest.fixture(scope="module")
def model_params():
    cfg = dataclasses.replace(get_config("starcoder2-3b").reduced(),
                              activation_dtype="float32")
    model = TransformerLM(cfg)
    return model, model.init(jax.random.PRNGKey(0))


def churn_property(model_params, ops, seed):
    """ops: list of ("submit", plen, new, deadline) | ("step",) |
    ("seize", n) | ("release",) | ("tick", dt)."""
    model, params = model_params
    rng = np.random.default_rng(seed)
    clk = [0.0]
    sched = Scheduler(model, params, max_slots=3, page_size=4, n_pages=24,
                      max_model_len=32, prefill_chunk=4,
                      cache_dtype="float32", compile_cache=CACHE,
                      queue_ttl_s=60.0, clock=lambda: clk[0])
    seized = []
    n_submitted = 0
    for op in ops:
        if op[0] == "submit":
            _, plen, new, deadline = op
            sched.submit(list(rng.integers(0, model.cfg.vocab, plen)),
                         new, deadline_s=deadline)
            n_submitted += 1
        elif op[0] == "step":
            sched.step()
        elif op[0] == "seize":
            seized.extend(sched.pool.seize(op[1]))
        elif op[0] == "release":
            if seized:
                sched.pool.release(seized)
                seized = []
        else:  # tick
            clk[0] += op[1]
        sched.check_invariants()

    # lift the pressure and drain: every request must terminate
    if seized:
        sched.pool.release(seized)
    sched.run()
    sched.check_invariants()
    assert not sched.queue
    assert all(r is None for r in sched.slots)
    assert len(sched.finished) == n_submitted
    for r in sched.finished:
        assert r.done and r.finish_reason in FINISH_REASONS


def _random_ops(rng) -> list:
    ops = []
    for _ in range(int(rng.integers(4, 20))):
        k = int(rng.integers(0, 5))
        if k == 0:
            deadline = [None, 3.0, 30.0][int(rng.integers(0, 3))]
            ops.append(("submit", int(rng.integers(1, 11)),
                        int(rng.integers(1, 9)), deadline))
        elif k == 1:
            ops.append(("step",))
        elif k == 2:
            ops.append(("seize", int(rng.integers(0, 9))))
        elif k == 3:
            ops.append(("release",))
        else:
            ops.append(("tick", float(rng.uniform(0.1, 4.0))))
    return ops


@pytest.mark.parametrize("seed", range(8))
def test_churn_preserves_invariants_fuzz(model_params, seed):
    rng = np.random.default_rng(1000 + seed)
    churn_property(model_params, _random_ops(rng), seed)


if HAVE_HYPOTHESIS:
    OPS = st.lists(
        st.one_of(
            st.tuples(st.just("submit"), st.integers(1, 10),
                      st.integers(1, 8),
                      st.sampled_from([None, 3.0, 30.0])),
            st.tuples(st.just("step")),
            st.tuples(st.just("seize"), st.integers(0, 8)),
            st.tuples(st.just("release")),
            st.tuples(st.just("tick"), st.floats(0.1, 4.0)),
        ),
        min_size=4, max_size=20)

    @settings(max_examples=10, deadline=None,
              suppress_health_check=[HealthCheck.function_scoped_fixture,
                                     HealthCheck.too_slow])
    @given(ops=OPS, seed=st.integers(0, 2**31 - 1))
    def test_churn_preserves_invariants_hypothesis(model_params, ops, seed):
        churn_property(model_params, list(ops), seed)
