"""Staged pipeline tests: Wrapped -> Lowered -> Compiled round-trip,
compilation-cache hit/miss on the SDFG content hash, PassManager
ordering/skip semantics, and jnp-vs-pallas cross-validation through the
staged path."""
import numpy as np
import pytest

import repro.kernels  # noqa: F401  (register fused kernels)
from repro.codegen.compiler import compile_sdfg
from repro.core.sdfg import SDFG
from repro.frontends import blas
from repro.frontends.api import Program, dc_program
from repro.pipeline import (CompilationCache, Compiled,
                            DeviceOffloadPass, Lowered, Pass, PassManager,
                            StreamingCompositionPass, Wrapped,
                            default_pipeline, lower)
from repro.transforms import DeviceOffload, StreamingComposition


@dc_program
def axpydot(p, n):
    a = p.scalar_input("a", "float32")
    x, y, w = (p.input(nm, (n,)) for nm in ("x", "y", "w"))
    p.output("result", blas.dot(blas.axpy(a, x, y), w))


def build_axpydot(n):
    p = Program("axpydot")
    a = p.scalar_input("a", "float32")
    x, y, w = (p.input(nm, (n,)) for nm in ("x", "y", "w"))
    p.output("result", blas.dot(blas.axpy(a, x, y), w))
    return p.finalize()


@pytest.fixture
def data():
    rng = np.random.default_rng(3)
    n = 512
    return dict(
        n=n, a=np.float32(0.9),
        x=rng.standard_normal(n).astype(np.float32),
        y=rng.standard_normal(n).astype(np.float32),
        w=rng.standard_normal(n).astype(np.float32),
    )


def result_of(compiled, d):
    out = compiled(a=d["a"], x=d["x"], y=d["y"], w=d["w"])
    return float(np.asarray(out["result"]).ravel()[0])


def expected(d):
    return float(np.dot((d["a"] * d["x"] + d["y"]).astype(np.float32),
                        d["w"]))


# -- stages ------------------------------------------------------------------

def test_dc_program_returns_wrapped_stage():
    assert isinstance(axpydot, Wrapped)
    sdfg = axpydot(64)          # calling traces to the raw SDFG
    assert isinstance(sdfg, SDFG)
    low = axpydot.lower(64)
    assert isinstance(low, Lowered)
    assert isinstance(low.compile("jnp", cache=None), Compiled)


@pytest.mark.parametrize("backend", ["jnp", "pallas"])
def test_stage_roundtrip_matches_compile_sdfg(backend, data):
    """Wrapped.lower().compile() ≡ the legacy one-shot compile_sdfg."""
    staged = axpydot.lower(data["n"]).optimize(
        [DeviceOffloadPass(), StreamingCompositionPass()])
    c_new = staged.compile(backend, cache=None)

    legacy_sdfg = build_axpydot(data["n"])
    legacy_sdfg.apply(DeviceOffload)
    legacy_sdfg.apply(StreamingComposition)
    c_old = compile_sdfg(legacy_sdfg, backend=backend)

    r_new, r_old = result_of(c_new, data), result_of(c_old, data)
    np.testing.assert_allclose(r_new, r_old, rtol=1e-6)
    np.testing.assert_allclose(r_new, expected(data), rtol=1e-4)
    assert c_new.report["fused_regions"] == c_old.report["fused_regions"]


def test_compile_does_not_mutate_lowered_sdfg(data):
    staged = axpydot.lower(data["n"])
    h = staged.sdfg.content_hash()
    staged.compile("jnp", cache=None)
    assert staged.sdfg.content_hash() == h
    assert staged.sdfg.all_library_nodes()  # still unexpanded


def test_legacy_compile_sdfg_expands_in_place(data):
    sdfg = build_axpydot(data["n"])
    compile_sdfg(sdfg, backend="jnp")
    assert not sdfg.all_library_nodes()


def test_jnp_pallas_cross_validation_staged(data):
    outs = {}
    for backend in ("jnp", "pallas"):
        staged = axpydot.lower(data["n"]).optimize(
            [DeviceOffloadPass(), StreamingCompositionPass()])
        c = staged.compile(backend, cache=None)
        if backend == "pallas":
            assert c.report["fused_regions"] == ["Axpy+Dot"]
        outs[backend] = result_of(c, data)
    np.testing.assert_allclose(outs["jnp"], outs["pallas"], rtol=1e-4)
    np.testing.assert_allclose(outs["jnp"], expected(data), rtol=1e-4)


def test_wrapped_symbol_binding():
    @dc_program
    def scaled(p):
        x = p.input("x", ("n",), "float32")
        y = p.input("y", ("n",), "float32")
        a = p.scalar_input("a", "float32")
        p.output("z", blas.axpy(a, x, y))

    low = scaled.lower(n=48)     # 'n' is not a builder arg -> symbol binding
    assert low.sdfg.symbol_values["n"] == 48
    c = low.compile("jnp", cache=None)
    rng = np.random.default_rng(0)
    x, y = (rng.standard_normal(48).astype(np.float32) for _ in range(2))
    out = c(a=np.float32(2.0), x=x, y=y)
    np.testing.assert_allclose(np.asarray(out["z"]), 2.0 * x + y, rtol=1e-5)


# -- compilation cache -------------------------------------------------------

def test_cache_hit_on_identical_sdfg(data):
    cache = CompilationCache()
    staged = axpydot.lower(data["n"])
    c1 = staged.compile("jnp", cache=cache)
    assert cache.stats == {"entries": 1, "hits": 0, "misses": 1}
    c2 = staged.compile("jnp", cache=cache)
    assert c2 is c1                       # served from the cache
    assert cache.stats["hits"] == 1

    # a separately-built but identical program also hits
    c3 = axpydot.lower(data["n"]).compile("jnp", cache=cache)
    assert c3 is c1
    assert cache.stats["hits"] == 2


def test_cache_miss_on_different_backend_pipeline_or_content(data):
    cache = CompilationCache()
    staged = axpydot.lower(data["n"])
    c1 = staged.compile("jnp", cache=cache)
    # different backend -> miss
    c2 = staged.compile("pallas", cache=cache)
    assert c2 is not c1
    # different pipeline config -> miss
    c3 = staged.compile("jnp", expansion_level="generic", cache=cache)
    assert c3 is not c1
    # different content (other symbol size) -> miss
    c4 = axpydot.lower(data["n"] // 2).compile("jnp", cache=cache)
    assert c4 is not c1
    assert cache.stats["entries"] == 4
    # transformed variant hashes differently -> miss
    c5 = axpydot.lower(data["n"]).optimize(
        [DeviceOffloadPass()]).compile("jnp", cache=cache)
    assert c5 is not c1


def test_cache_lru_bound():
    cache = CompilationCache(max_entries=2)
    for i in range(4):
        cache.store(("k", i), i)
    assert len(cache) == 2
    assert cache.lookup(("k", 3)) == 3
    assert cache.lookup(("k", 0)) is None


def test_content_hash_sensitivity(data):
    s1, s2 = build_axpydot(data["n"]), build_axpydot(data["n"])
    assert s1.content_hash() == s2.content_hash()
    s2.metadata["pin_hbm"] = ("x",)
    assert s1.content_hash() != s2.content_hash()
    s3 = build_axpydot(data["n"])
    s3.specialize(batch=4)
    assert s1.content_hash() != s3.content_hash()
    s4 = build_axpydot(data["n"])
    s4.arrays["x"].vector_width = 128
    assert s1.content_hash() != s4.content_hash()


# -- PassManager -------------------------------------------------------------

class _Recorder(Pass):
    def __init__(self, tag, log):
        self.tag = tag
        self.log = log
        self.name = tag

    def apply(self, sdfg, report):
        self.log.append(self.tag)
        return self.tag

    def options(self):
        return {"tag": self.tag}


def test_passmanager_runs_in_order_with_timing():
    log = []
    pm = PassManager([_Recorder(t, log) for t in ("a", "b", "c")],
                     name="ordered")
    report = pm.run(SDFG("empty"))
    assert log == ["a", "b", "c"]
    names = [e["name"] for e in report["passes"]]
    assert names == ["a", "b", "c"]
    assert all(e["seconds"] >= 0.0 and not e["skipped"]
               for e in report["passes"])
    assert [e["summary"] for e in report["passes"]] == ["a", "b", "c"]


def test_passmanager_skip_semantics():
    log = []
    pm = PassManager([_Recorder(t, log) for t in ("a", "b", "c")],
                     skip=("b",))
    report = pm.run(SDFG("empty"), skip=("c",))
    assert log == ["a"]  # b skipped by manager config, c by run() argument
    by_name = {e["name"]: e for e in report["passes"]}
    assert not by_name["a"]["skipped"]
    assert by_name["b"]["skipped"] and by_name["c"]["skipped"]
    # skip set is part of the cache signature
    assert PassManager([], skip=("b",)).signature() != \
        PassManager([]).signature()


def test_passmanager_accepts_transformation_classes(data):
    staged = axpydot.lower(data["n"])
    staged.optimize([DeviceOffload, StreamingComposition])
    entries = staged.reports[-1]["passes"]
    assert [e["name"] for e in entries] == ["DeviceOffload",
                                            "StreamingComposition"]
    assert entries[0]["summary"] == 1  # applied once


def test_default_pipeline_shapes():
    jnp_pm = default_pipeline("jnp")
    pal_pm = default_pipeline("pallas", interpret=True)
    assert [p.name for p in jnp_pm] == ["SetExpansionPreference",
                                        "ExpandLibraryNodes"]
    assert [p.name for p in pal_pm] == ["SetExpansionPreference",
                                        "PipelineFusion",
                                        "ExpandLibraryNodes",
                                        "MapFusion",
                                        "Vectorization",
                                        "MapTiling",
                                        "GridConversion"]
    assert jnp_pm.signature() != pal_pm.signature()


# -- frontend satellite ------------------------------------------------------

def test_output_rename_collision_raises():
    n = 16
    p = Program("collide")
    a = p.scalar_input("a", "float32")
    x, y = p.input("x", (n,)), p.input("y", (n,))
    z = blas.axpy(a, x, y)
    with pytest.raises(ValueError, match="already exists"):
        p.output("x", z)  # would silently overwrite input descriptor 'x'


def test_lower_helper_validates():
    s = build_axpydot(64)
    assert isinstance(lower(s), Lowered)
