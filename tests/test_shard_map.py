"""Elastic multi-host execution (ISSUE 9).

Three layers under test:

1. **Partition analysis** (transforms/shard_map.py): memlet
   classification — shard-local (parameter indexes the dim exactly),
   replicated (whole-read weights), collective (wcr over the partition
   -> psum) — plus *typed refusals* that leave the SDFG untouched:
   halo reads crossing the shard boundary, non-divisible extents,
   declared-replicated conflicts.
2. **Mesh-keyed compilation**: the shard count and mesh signature are
   part of the pipeline signature, so a shrunken mesh can never reuse a
   stale compiled step.
3. **Numeric equality and elastic recovery on a real multi-device
   mesh** (subprocess with ``--xla_force_host_platform_device_count``,
   since device count is fixed at jax import): the sharded compiled
   step matches the unsharded one for both training and serving; host
   death restores sharded checkpoints onto a smaller mesh with
   loss-curve-identical training and byte-identical greedy streams.

Satellite regressions ride along: HeartbeatMonitor inf-median,
FaultPlan consumed across clusters, checkpoint commit-window atomicity
and typed restore errors.
"""
import json
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

import jax.numpy as jnp
from repro.checkpoint import (CheckpointError, latest_step, manifest_for,
                              restore, save, save_sharded)
from repro.core.memlet import Memlet, Range, Subset
from repro.core.sdfg import SDFG
from repro.core.symbolic import sym
from repro.pipeline.passes import default_pipeline
from repro.runtime import FaultPlan, HeartbeatMonitor, SimulatedCluster
from repro.transforms.shard_map import partition_sdfg

SRC = os.path.join(os.path.dirname(__file__), os.pardir, "src")


# ---------------------------------------------------------------------------
# SDFG builders
# ---------------------------------------------------------------------------
def rows_sdfg(n=8, m=4, halo=False):
    """Row map: y[i] = 2 x[i] (+ optionally x[i+1]: a halo read) with a
    whole-container wcr("add") loss accumulator."""
    s = SDFG("rows")
    s.add_array("x", (n, m), "float32")
    s.add_array("y", (n, m), "float32")
    s.add_array("acc", (1,), "float32")
    st = s.add_state("main", is_start=True)
    idx = Range.index(sym("i") + 1) if halo else Range.index(sym("i"))
    st.add_mapped_tasklet(
        "rows", {"i": (0, n)},
        inputs={"xr": Memlet.simple(
            "x", Subset([idx, Range.make(0, m)]))},
        outputs={"yr": Memlet.simple(
            "y", Subset([Range.index(sym("i")), Range.make(0, m)])),
            "a": Memlet.simple("acc", wcr="add")},
        fn=lambda xr: {"yr": xr * 2.0, "a": xr.sum().reshape(1)})
    return s


def _shape0(s, name):
    return int(s.arrays[name].shape[0].evaluate({}))


# ---------------------------------------------------------------------------
# Classification
# ---------------------------------------------------------------------------
class TestClassification:
    def test_shard_local_replicated_psum(self):
        s = rows_sdfg(n=8)
        res = partition_sdfg(s, 2)
        assert res["sharded"]
        assert res["specs"]["x"] == 0 and res["specs"]["y"] == 0
        assert res["specs"]["acc"] is None
        assert "acc" in res["psum"]
        # container shapes and the map range divided in place
        assert _shape0(s, "x") == 4 and _shape0(s, "y") == 4
        assert s.metadata["shard_map"]["n_shards"] == 2
        hows = {d["container"]: d for d in res["decisions"]
                if d.get("decision") == "shard"}
        assert "indexed" in hows["x"]["how"]

    def test_weights_stay_replicated(self):
        s = rows_sdfg(n=8)
        s.add_array("w", (4, 4), "float32")  # never indexed by the map
        res = partition_sdfg(s, 2)
        assert res["sharded"]
        assert res["specs"].get("w") is None  # absent/None = replicated
        reps = [d for d in res["decisions"]
                if d.get("container") == "w"]
        assert reps and reps[0]["decision"] == "replicated"

    def test_n_shards_one_is_identity(self):
        s = rows_sdfg()
        res = partition_sdfg(s, 1)
        assert not res["sharded"] and res["decisions"] == []
        assert _shape0(s, "x") == 8

    def test_halo_read_is_typed_refusal_sdfg_untouched(self):
        s = rows_sdfg(n=8, halo=True)
        # pin y so the halo read on x is the hot parameter's violation
        s.metadata["shard_declared"] = {"y": 0}
        res = partition_sdfg(s, 2)
        assert not res["sharded"]
        refusals = [d for d in res["decisions"]
                    if d["decision"] == "shard_refused"]
        assert refusals, res["decisions"]
        assert "crosses the shard boundary" in refusals[0]["reason"]
        # validate-before-mutate: nothing divided, nothing stamped
        assert _shape0(s, "x") == 8 and _shape0(s, "y") == 8
        assert "shard_map" not in s.metadata

    def test_non_divisible_extent_refuses(self):
        s = rows_sdfg(n=6)
        res = partition_sdfg(s, 4)
        assert not res["sharded"]
        reasons = " ".join(str(d.get("reason")) for d in res["decisions"])
        assert "not divisible" in reasons
        assert _shape0(s, "x") == 6

    def test_declared_replicated_conflict_refuses(self):
        s = rows_sdfg(n=8)
        s.metadata["shard_declared"] = {"x": None, "y": 0}
        res = partition_sdfg(s, 2)
        assert not res["sharded"]
        refusals = [d for d in res["decisions"]
                    if d["decision"] == "shard_refused"]
        assert "must stay replicated" in refusals[0]["reason"]
        assert _shape0(s, "x") == 8


# ---------------------------------------------------------------------------
# Mesh-keyed compilation
# ---------------------------------------------------------------------------
class TestCacheKeys:
    def test_pipeline_signature_distinct_per_mesh(self):
        """A mesh shrink must be a cache miss: n_shards and the mesh
        signature are pipeline-signature relevant, per backend."""
        for backend in ("jnp", "pallas"):
            p0 = default_pipeline(backend)
            p2a = default_pipeline(backend, n_shards=2, mesh_sig="meshA")
            p2b = default_pipeline(backend, n_shards=2, mesh_sig="meshB")
            p4a = default_pipeline(backend, n_shards=4, mesh_sig="meshA")
            sigs = {p0.signature(), p2a.signature(), p2b.signature(),
                    p4a.signature()}
            assert len(sigs) == 4, f"{backend}: colliding signatures"

    def test_sharded_pipeline_is_named(self):
        assert default_pipeline("jnp").name == "jnp_default"
        assert default_pipeline(
            "jnp", n_shards=2, mesh_sig="m").name == "jnp_sharded"
        assert default_pipeline(
            "pallas", n_shards=2, mesh_sig="m").name == "pallas_sharded"


# ---------------------------------------------------------------------------
# Sharded checkpoints (satellite: commit window + typed restore errors)
# ---------------------------------------------------------------------------
class TestCheckpoints:
    STATE = {"params": {"w": jnp.arange(8.0).reshape(2, 4),
                        "b": jnp.ones((3,))},
             "step": jnp.asarray(5, jnp.int32)}

    def test_interrupted_save_never_shadows_a_good_checkpoint(self, tmp_path):
        """Regression: the commit used to delete the live step dir before
        moving the tmp dir in — a crash in that window left NO valid
        checkpoint. Now stale .tmp/.old dirs are invisible to
        latest_step and the committed step restores intact."""
        save(str(tmp_path), 5, self.STATE)
        (tmp_path / "step_00000009.tmp").mkdir()   # crashed mid-save
        (tmp_path / "step_00000005.old").mkdir()   # crashed mid-commit
        assert latest_step(str(tmp_path)) == 5
        got = restore(str(tmp_path), 5, self.STATE)
        np.testing.assert_array_equal(np.asarray(got["params"]["w"]),
                                      np.asarray(self.STATE["params"]["w"]))

    def test_resave_replaces_atomically(self, tmp_path):
        save(str(tmp_path), 5, self.STATE)
        newer = {"params": {"w": jnp.zeros((2, 4)), "b": jnp.ones((3,))},
                 "step": jnp.asarray(5, jnp.int32)}
        save(str(tmp_path), 5, newer)
        got = restore(str(tmp_path), 5, newer)
        assert float(np.abs(np.asarray(got["params"]["w"])).max()) == 0.0
        assert not (tmp_path / "step_00000005.old").exists()
        assert not (tmp_path / "step_00000005.tmp").exists()

    def test_restore_missing_leaf_is_typed_and_named(self, tmp_path):
        save(str(tmp_path), 5, self.STATE)
        like = {"params": {"w": self.STATE["params"]["w"],
                           "b": self.STATE["params"]["b"],
                           "extra": jnp.zeros((2,))},
                "step": self.STATE["step"]}
        with pytest.raises(CheckpointError, match="extra"):
            restore(str(tmp_path), 5, like)

    def test_sharded_manifest_records_mesh_signature(self, tmp_path):
        save_sharded(str(tmp_path), 7, self.STATE, mesh_sig="MESHSIG")
        man = manifest_for(str(tmp_path), 7)
        assert man["sharded"] is True
        assert "MESHSIG" in man["mesh_signature"]
        got = restore(str(tmp_path), 7, self.STATE)
        for a, b in zip(np.asarray(got["params"]["w"]).ravel(),
                        np.asarray(self.STATE["params"]["w"]).ravel()):
            assert a == b

    def test_restore_missing_shard_file_is_typed(self, tmp_path):
        save_sharded(str(tmp_path), 7, self.STATE)
        d = tmp_path / "step_00000007"
        victim = sorted(d.glob("leaf_*.npy"))[0]
        victim.unlink()
        with pytest.raises(CheckpointError):
            restore(str(tmp_path), 7, self.STATE)


# ---------------------------------------------------------------------------
# Satellite regressions: monitor median, fault-plan reuse
# ---------------------------------------------------------------------------
def test_heartbeat_median_survives_dead_host_inf():
    """Regression: a dead host records inf durations; those used to enter
    the straggler median, inflating the threshold to inf forever so no
    straggler was ever flagged again."""
    m = HeartbeatMonitor(deadline_s=1e9, straggler_factor=2.0)
    for _ in range(8):
        m.record(0, 1.0)
    for _ in range(16):
        assert m.record(2, float("inf")) != "straggler"
    assert m.record(0, 1.0) == "ok"
    assert m.record(1, 5.0) == "straggler"  # finite median stayed ~1.0


def test_fault_plan_reusable_across_clusters():
    """Regression: SimulatedCluster.run clears die_at_step after firing;
    sharing one plan across clusters silently dropped the fault from the
    second run. The cluster now copies the plan in __init__."""
    plan = FaultPlan(die_at_step=3, die_host=1)
    for trial in range(2):
        sim = SimulatedCluster(4, plan=plan)
        out = sim.run(6, lambda s: None, lambda s: None, lambda: 0)
        assert out["restarts"], f"trial {trial}: fault never fired"
    assert plan.die_at_step == 3


# ---------------------------------------------------------------------------
# Multi-device execution (subprocess: device count is fixed at jax import)
# ---------------------------------------------------------------------------
def _run_sub(script: str, timeout=900) -> dict:
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    proc = subprocess.run([sys.executable, "-c", script],
                          capture_output=True, text=True, timeout=timeout,
                          env=env)
    assert proc.returncode == 0, (
        f"subprocess failed:\n{proc.stdout[-4000:]}\n{proc.stderr[-4000:]}")
    return json.loads(proc.stdout.splitlines()[-1])


TRAIN_SCRIPT = textwrap.dedent("""
    import dataclasses, json, shutil, tempfile
    import numpy as np
    from repro.configs import get_config
    from repro.pipeline.cache import CompilationCache
    from repro.runtime import (ElasticTrainer, ElasticTrainerConfig,
                               FaultPlan, run_elastic_training)

    cfg = dataclasses.replace(get_config("starcoder2-3b").reduced(),
                              activation_dtype="float32")
    out = {}

    # sharded step == unsharded step
    t1 = ElasticTrainer(cfg, n_shards=1, seq_len=8, global_batch=4,
                        cache=CompilationCache(max_entries=8))
    t2 = ElasticTrainer(cfg, n_shards=2, seq_len=8, global_batch=4,
                        cache=CompilationCache(max_entries=8))
    s1, s2 = t1.init_state(), t2.init_state()
    diffs = []
    for step in range(2):
        s1, m1 = t1.run_step(s1, step)
        s2, m2 = t2.run_step(s2, step)
        diffs.append(abs(m1["loss"] - m2["loss"]))
    out["step_loss_maxdiff"] = max(diffs)
    rep = t2.report
    out["shard_map"] = rep.get("shard_map")
    out["n_psum"] = len(rep["shard_map"]["psum"])
    out["n_decisions"] = len([d for d in rep.get("grid_decisions", ())
                              if "shard" in str(d.get("decision"))])

    # mesh-keyed cache: k=1 and k=2 must not share an entry
    shared = CompilationCache(max_entries=8)
    ElasticTrainer(cfg, n_shards=1, seq_len=8, global_batch=4,
                   cache=shared).compiled_step()
    ElasticTrainer(cfg, n_shards=2, seq_len=8, global_batch=4,
                   cache=shared).compiled_step()
    out["cache_entries"] = shared.stats["entries"]

    # elastic: host death at step 3 -> restore sharded ckpt on smaller mesh
    d_base, d_el = tempfile.mkdtemp(), tempfile.mkdtemp()
    base = run_elastic_training(cfg, n_hosts=2, n_steps=5, ckpt_dir=d_base,
                                seq_len=8, global_batch=4,
                                checkpoint_every=2,
                                cache=CompilationCache(max_entries=8))
    el = run_elastic_training(cfg, n_hosts=2, n_steps=5, ckpt_dir=d_el,
                              plan=FaultPlan(die_at_step=3, die_host=1),
                              seq_len=8, global_batch=4, checkpoint_every=2,
                              cache=CompilationCache(max_entries=8))
    out["loss_curve_maxdiff"] = max(
        abs(base["losses"][s] - el["losses"][s]) for s in base["losses"])
    out["n_restarts"] = len(el["sim"]["restarts"])
    out["wasted_steps"] = el["sim"]["wasted_steps"]
    out["reshards"] = [(r["n_hosts"], r["n_shards"]) for r in el["reshards"]]

    # restore N -> N+1 and N -> N-1: same ckpt, different mesh, same loss
    tk2 = ElasticTrainer(cfg, n_shards=2, seq_len=8, global_batch=4,
                         tcfg=ElasticTrainerConfig(ckpt_dir=d_base),
                         cache=CompilationCache(max_entries=8))
    tk4 = ElasticTrainer(cfg, n_shards=4, seq_len=8, global_batch=4,
                         tcfg=ElasticTrainerConfig(ckpt_dir=d_base),
                         cache=CompilationCache(max_entries=8))
    tk1 = ElasticTrainer(cfg, n_shards=1, seq_len=8, global_batch=4,
                         tcfg=ElasticTrainerConfig(ckpt_dir=d_base),
                         cache=CompilationCache(max_entries=8))
    resumed = []
    for t in (tk2, tk4, tk1):
        st = t.restore_or_init()
        step = int(st["step"])
        _, m = t.run_step(st, step)
        resumed.append(m["loss"])
    out["resume_step"] = step
    out["regrow_maxdiff"] = max(abs(l - resumed[0]) for l in resumed)
    shutil.rmtree(d_base, ignore_errors=True)
    shutil.rmtree(d_el, ignore_errors=True)
    print(json.dumps(out))
""")

SERVE_SCRIPT = textwrap.dedent("""
    import dataclasses, json, os, shutil, tempfile
    import jax
    from repro.configs import get_config
    from repro.models.transformer import TransformerLM
    from repro.serving import Scheduler

    cfg = dataclasses.replace(get_config("starcoder2-3b").reduced(),
                              activation_dtype="float32")
    model = TransformerLM(cfg)
    params = model.init(jax.random.PRNGKey(0))
    PROMPTS = [[3, 1, 4], [1, 5, 9, 2], [6, 5, 3, 5, 8], [9, 7]]
    KW = dict(max_slots=4, page_size=4, n_pages=16, max_model_len=16,
              prefill_chunk=4, cache_dtype="float32", donate=False)

    def streams(sched, n_new=5):
        for pr in PROMPTS:
            sched.submit(pr, n_new)
        return {r.rid: list(r.tokens_out) for r in sched.run()}

    out = {}
    base = streams(Scheduler(model, params, **KW))
    sh = Scheduler(model, params, n_shards=2, **KW)
    got = streams(sh)
    sh.check_invariants()
    out["sharded_eq"] = got == base
    out["n_shards"] = sh.stats()["n_shards"]
    step = sh.compiler._steps[max(sh.compiler._steps)]
    sm = step.report.get("shard_map")
    out["report_sharded"] = bool(sm and sm.get("sharded"))
    out["n_decisions"] = len(step.report.get("grid_decisions", ()))
    out["rung"] = step.rung

    # snapshot -> lose host 1's shard file -> restore -> recompute
    s1 = Scheduler(model, params, n_shards=2, **KW)
    for pr in PROMPTS:
        s1.submit(pr, 5)
    for _ in range(3):
        s1.step()
    d = tempfile.mkdtemp()
    s1.snapshot_to_dir(d)
    os.remove(os.path.join(d, "host001.npz"))
    s2 = Scheduler(model, params, n_shards=2, **KW).restore_from_dir(d)
    ev = [e for e in s2.events if e["kind"] == "restore_recompute"]
    out["recompute_events"] = len(ev)
    out["recompute_kept_tokens"] = min(e["kept_tokens"] for e in ev)
    out["hostloss_eq"] = {r.rid: list(r.tokens_out)
                          for r in s2.run()} == base
    s2.check_invariants()
    out["watchdog_shard_lost"] = bool(
        s2.watchdog.faults_of("restore_shard_lost"))
    shutil.rmtree(d, ignore_errors=True)

    # live shrink 2 -> 1 mid-run: preempt-to-fit + recompiled step
    s3 = Scheduler(model, params, n_shards=2, **KW)
    for pr in PROMPTS:
        s3.submit(pr, 5)
    for _ in range(3):
        s3.step()
    sig_before = s3.stats()["mesh_signature"]
    s3.shrink(1)
    out["shrink_events"] = [e["kind"] for e in s3.events
                            if e["kind"] in ("mesh_shrink",
                                             "shrink_preempt")]
    out["mesh_sig_changed"] = s3.stats()["mesh_signature"] != sig_before
    out["shrink_eq"] = {r.rid: list(r.tokens_out)
                        for r in s3.run()} == base
    s3.check_invariants()
    print(json.dumps(out))
""")


@pytest.fixture(scope="module")
def train_sub():
    return _run_sub(TRAIN_SCRIPT)


@pytest.fixture(scope="module")
def serve_sub():
    return _run_sub(SERVE_SCRIPT)


class TestShardedTraining:
    def test_sharded_step_matches_unsharded(self, train_sub):
        assert train_sub["step_loss_maxdiff"] < 1e-4
        assert train_sub["shard_map"]["n_shards"] == 2
        assert train_sub["n_psum"] >= 1, "wcr grads produced no psum"
        assert train_sub["n_decisions"] >= 1, \
            "no partition decisions in report['grid_decisions']"

    def test_mesh_shrink_is_cache_miss(self, train_sub):
        assert train_sub["cache_entries"] == 2

    def test_host_death_loss_curve_identical(self, train_sub):
        assert train_sub["n_restarts"] == 1
        assert train_sub["loss_curve_maxdiff"] < 1e-4
        # resharded onto fewer hosts after the death
        reshards = train_sub["reshards"]
        assert len(reshards) == 2 and reshards[1][1] < reshards[0][1]
        assert train_sub["wasted_steps"] >= 0

    def test_restore_onto_larger_and_smaller_mesh(self, train_sub):
        """One sharded checkpoint, restored N -> N-1 and N -> N+1: the
        next step's loss is identical on every mesh size."""
        assert train_sub["regrow_maxdiff"] < 1e-4
        assert train_sub["resume_step"] >= 1


class TestShardedServing:
    def test_sharded_streams_byte_identical(self, serve_sub):
        assert serve_sub["sharded_eq"]
        assert serve_sub["n_shards"] == 2
        assert serve_sub["report_sharded"]
        assert serve_sub["n_decisions"] >= 1
        assert serve_sub["rung"] in ("grid", "jit")

    def test_host_shard_loss_recomputes_token_exact(self, serve_sub):
        assert serve_sub["recompute_events"] >= 1
        assert serve_sub["recompute_kept_tokens"] > 0
        assert serve_sub["watchdog_shard_lost"]
        assert serve_sub["hostloss_eq"]

    def test_live_shrink_preempts_and_stays_exact(self, serve_sub):
        assert "mesh_shrink" in serve_sub["shrink_events"]
        assert "shrink_preempt" in serve_sub["shrink_events"]
        assert serve_sub["mesh_sig_changed"]
        assert serve_sub["shrink_eq"]
