"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps + property tests.
All kernels run in interpret mode (CPU) per the assignment."""
import numpy as np
import pytest

hypothesis = pytest.importorskip(
    "hypothesis", reason="property tests need the optional 'hypothesis' "
    "package (pip install repro[test])")
from hypothesis import given, settings, strategies as st  # noqa: E402

import jax.numpy as jnp  # noqa: E402
from repro.kernels import axpydot, dot, gemm, stencil  # noqa: E402

RNG = np.random.default_rng(42)


# -- axpydot ---------------------------------------------------------------
@pytest.mark.parametrize("n", [1024, 4096, 5000, 16384])
@pytest.mark.parametrize("dtype", [np.float32, "bfloat16"])
def test_axpydot_sweep(n, dtype):
    if dtype == "bfloat16":
        import ml_dtypes
        dtype = ml_dtypes.bfloat16
    a = np.float32(1.3)
    x, y, w = (RNG.standard_normal(n).astype(dtype) for _ in range(3))
    out = axpydot.axpydot(a, x, y, w, interpret=True)
    ref = axpydot.axpydot_ref(a, x, y, w)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=3e-3 if dtype != np.float32 else 3e-5)


# -- dot ---------------------------------------------------------------------
@pytest.mark.parametrize("n", [1024, 2048, 9973])
def test_dot_sweep(n):
    x, w = (RNG.standard_normal(n).astype(np.float32) for _ in range(2))
    np.testing.assert_allclose(np.asarray(dot.dot(x, w, interpret=True)),
                               np.asarray(dot.dot_ref(x, w)), rtol=3e-5)


# -- gemm ---------------------------------------------------------------------
@pytest.mark.parametrize("shape", [(128, 128, 128), (256, 512, 128),
                                   (300, 200, 150), (64, 1000, 32)])
@pytest.mark.parametrize("act", [None, "relu", "gelu"])
def test_gemm_sweep(shape, act):
    M, K, N = shape
    A = RNG.standard_normal((M, K)).astype(np.float32)
    B = RNG.standard_normal((K, N)).astype(np.float32)
    bias = RNG.standard_normal(N).astype(np.float32)
    out = gemm.matmul(A, B, bias, activation=act, bm=128, bk=128, bn=128,
                      interpret=True)
    ref = gemm.matmul_ref(A, B, bias, activation=act)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=2e-4,
                               atol=2e-4)


def test_gemm_bf16():
    import ml_dtypes
    A = RNG.standard_normal((128, 256)).astype(ml_dtypes.bfloat16)
    B = RNG.standard_normal((256, 128)).astype(ml_dtypes.bfloat16)
    out = gemm.matmul(A, B, interpret=True)
    ref = gemm.matmul_ref(A, B)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), rtol=3e-2,
                               atol=3e-2)


@given(m=st.integers(8, 160), k=st.integers(8, 160), n=st.integers(8, 160))
@settings(max_examples=12, deadline=None)
def test_gemm_property_shapes(m, k, n):
    A = RNG.standard_normal((m, k)).astype(np.float32)
    B = RNG.standard_normal((k, n)).astype(np.float32)
    out = gemm.matmul(A, B, bm=64, bk=64, bn=64, interpret=True)
    np.testing.assert_allclose(np.asarray(out),
                               np.asarray(gemm.matmul_ref(A, B)),
                               rtol=2e-4, atol=2e-4)


# -- stencils ------------------------------------------------------------------
@pytest.mark.parametrize("hw", [(64, 48), (128, 128), (65, 33)])
def test_diffusion2d(hw):
    a = RNG.standard_normal(hw).astype(np.float32)
    co = np.array([0.2, 0.1, 0.15, 0.25, 0.3], np.float32)
    np.testing.assert_allclose(
        np.asarray(stencil.diffusion2d(a, co, bh=16, interpret=True)),
        np.asarray(stencil.diffusion2d_ref(a, co)), rtol=1e-5, atol=1e-6)


def test_jacobi3d_and_diffusion3d():
    a = RNG.standard_normal((16, 12, 10)).astype(np.float32)
    np.testing.assert_allclose(
        np.asarray(stencil.jacobi3d(a, bd=4, interpret=True)),
        np.asarray(stencil.jacobi3d_ref(a)), rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(
        np.asarray(stencil.diffusion3d(a, 0.1, bd=4, interpret=True)),
        np.asarray(stencil.diffusion3d_ref(a, 0.1)), rtol=1e-5, atol=1e-5)


@given(di=st.integers(-2, 2), dj=st.integers(-2, 2))
@settings(max_examples=10, deadline=None)
def test_stencil2d_arbitrary_offsets(di, dj):
    offsets = ((0, 0), (di, dj))
    a = RNG.standard_normal((32, 24)).astype(np.float32)
    co = np.array([0.5, 0.25], np.float32)
    np.testing.assert_allclose(
        np.asarray(stencil.stencil2d(a, co, offsets, bh=8, interpret=True)),
        np.asarray(stencil.stencil2d_ref(a, co, offsets)),
        rtol=1e-5, atol=1e-6)


def test_stencil_chain_matches_sequential():
    offs = ((0, 0), (-1, 0), (1, 0), (0, -1), (0, 1))
    a = RNG.standard_normal((48, 40)).astype(np.float32)
    c1 = np.array([0.2, 0.1, 0.15, 0.25, 0.3], np.float32)
    c2 = np.array([0.1, 0.2, 0.3, 0.2, 0.2], np.float32)
    fused = stencil.stencil2d_chain(a, [c1, c2], (offs, offs), bh=16,
                                    interpret=True)
    seq = stencil.stencil2d_ref(stencil.stencil2d_ref(a, c1, offs), c2, offs)
    np.testing.assert_allclose(np.asarray(fused), np.asarray(seq),
                               rtol=1e-4, atol=1e-5)
