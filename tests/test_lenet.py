"""LeNet-5 case study (paper §5): ladder correctness + volume ordering."""
import numpy as np
import pytest

import repro.kernels  # noqa: F401
from repro.frontends.ml import build_lenet, init_lenet_params, lenet_reference
from repro.transforms import (DeviceOffload, InputToConstant,
                              StreamingComposition)


@pytest.fixture(scope="module")
def setup():
    params = init_lenet_params()
    rng = np.random.default_rng(0)
    x = rng.standard_normal((16, 1, 28, 28)).astype(np.float32)
    return params, x, np.asarray(lenet_reference(params, x))


def test_naive_matches_reference(setup):
    params, x, exp = setup
    sdfg = build_lenet(16)
    sdfg.apply(DeviceOffload)
    out = sdfg.compile("jnp")(x=x, **params)
    np.testing.assert_allclose(np.asarray(out["probs"]), exp, rtol=1e-3,
                               atol=1e-5)


def test_ladder_volumes_and_fused_pallas(setup):
    params, x, exp = setup
    s1 = build_lenet(16)
    s1.apply(DeviceOffload)
    v_naive = s1.off_chip_volume()

    s2 = build_lenet(16)
    assert s2.apply(InputToConstant, parameters=params) == len(params)
    s2.apply(DeviceOffload)
    v_const = s2.off_chip_volume()
    s2.apply(StreamingComposition)
    v_stream = s2.off_chip_volume()
    assert v_naive > v_const > v_stream  # paper Table-3 ordering

    c = s2.compile("pallas")
    # conv+pool stages fuse (paper Fig. 16 streaming between operators)
    assert c.report["fused_regions"].count("Conv2d+MaxPool2d") == 2
    out = c(x=x)
    np.testing.assert_allclose(np.asarray(out["probs"]), exp, rtol=1e-3,
                               atol=1e-5)


def test_input_to_constant_ratio_matches_paper(setup):
    """Paper Table 3: InputToConstant gives a ~1.2x volume reduction.

    Our memlet accounting reads each weight once per execution (i.e. the
    naive baseline is already weight-cached on-chip), so the paper's ratio
    appears at small batch where weights are a comparable fraction of
    traffic; at batch 1000 the FPGA naive re-streams weights per tile,
    which we don't model (EXPERIMENTS §Paper)."""
    params, _, _ = setup
    s1 = build_lenet(32)
    s1.apply(DeviceOffload)
    s2 = build_lenet(32)
    s2.apply(InputToConstant, parameters=params)
    s2.apply(DeviceOffload)
    ratio = s1.off_chip_volume() / s2.off_chip_volume()
    assert 1.1 < ratio < 1.35  # paper: 0.28/0.22 GiB = 1.27x
