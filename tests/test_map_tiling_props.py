"""Property tests (optional hypothesis dependency) for multi-parameter
MapTiling: random shapes x random tile sizes — including non-divisible
remainders with masked partial final blocks — compared against numpy
through the Pallas grid path, for elementwise maps and wcr-add
reductions."""
import numpy as np
import pytest

hypothesis = pytest.importorskip(
    "hypothesis", reason="property tests need the optional 'hypothesis' "
                         "dependency (pip install -e .[test])")
from hypothesis import given, settings, strategies as hst  # noqa: E402

from repro.pipeline import lower  # noqa: E402

from test_map_tiling_multidim import (_ew2d_sdfg, _rowsum_sdfg,  # noqa: E402
                                      _tile_pipeline)


@settings(max_examples=20, deadline=None)
@given(n=hst.integers(min_value=2, max_value=40),
       m=hst.integers(min_value=2, max_value=40),
       ti=hst.integers(min_value=1, max_value=12),
       tj=hst.integers(min_value=1, max_value=14),
       seed=hst.integers(min_value=0, max_value=2 ** 31 - 1))
def test_property_random_shapes_and_tiles(n, m, ti, tj, seed):
    rng = np.random.default_rng(seed)
    x = rng.standard_normal((n, m)).astype(np.float32)
    y = rng.standard_normal(m).astype(np.float32)
    pm = _tile_pipeline({"i": ti, "j": tj})
    cp = lower(_ew2d_sdfg(n, m)).compile("pallas", pipeline=pm, cache=None)
    op = np.asarray(cp(x=x, y=y)["out"])
    np.testing.assert_allclose(op, 2 * x + y, rtol=1e-5, atol=1e-6)


@settings(max_examples=15, deadline=None)
@given(n=hst.integers(min_value=2, max_value=30),
       m=hst.integers(min_value=2, max_value=30),
       ti=hst.integers(min_value=1, max_value=9),
       tj=hst.integers(min_value=1, max_value=9),
       seed=hst.integers(min_value=0, max_value=2 ** 31 - 1))
def test_property_random_reductions(n, m, ti, tj, seed):
    rng = np.random.default_rng(seed)
    x = rng.standard_normal((n, m)).astype(np.float32)
    pm = _tile_pipeline({"i": ti, "j": tj})
    cp = lower(_rowsum_sdfg(n, m)).compile("pallas", pipeline=pm, cache=None)
    op = np.asarray(cp(x=x)["out"])
    np.testing.assert_allclose(op, x.sum(axis=1), rtol=1e-4, atol=1e-5)
