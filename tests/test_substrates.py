"""Substrate tests: optimizer, checkpoint, data, runtime fault tolerance,
gradient compression."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp
from repro.checkpoint import latest_step, restore, save
from repro.data import DataConfig, TokenStream
from repro.optim import adafactor, adamw, get_optimizer, warmup_cosine
from repro.runtime import FaultPlan, SimulatedCluster, Trainer, TrainerConfig
from repro.runtime.compression import dequantize_int8, quantize_int8


# -- optimizers ---------------------------------------------------------------
@pytest.mark.parametrize("name", ["adamw", "adafactor"])
def test_optimizer_descends_quadratic(name):
    opt = get_optimizer(name, lr=0.1, warmup=1, total=200)
    params = {"w": jnp.ones((8, 4)) * 3.0, "b": jnp.ones((4,)) * -2.0}
    state = opt.init(params)

    def loss(p):
        return jnp.sum(p["w"] ** 2) + jnp.sum(p["b"] ** 2)

    l0 = float(loss(params))
    for step in range(60):
        g = jax.grad(loss)(params)
        params, state = opt.update(g, state, params,
                                   jnp.asarray(step, jnp.int32))
    assert float(loss(params)) < 0.05 * l0


def test_adafactor_state_is_factored():
    opt = adafactor(warmup_cosine(1e-3, 10, 100))
    params = {"w": jnp.ones((64, 32))}
    st = opt.init(params)
    assert st["w"]["vr"].shape == (64,)
    assert st["w"]["vc"].shape == (32,)


# -- data ------------------------------------------------------------------
def test_data_deterministic_and_sharded():
    cfg = DataConfig(vocab=100, seq_len=16, global_batch=8)
    s0 = TokenStream(cfg, shard=0, num_shards=4)
    s1 = TokenStream(cfg, shard=1, num_shards=4)
    b0a, b0b = s0.batch_at(3), s0.batch_at(3)
    np.testing.assert_array_equal(b0a, b0b)        # recomputable
    assert not np.array_equal(s0.batch_at(3), s1.batch_at(3))
    assert s0.batch_at(3).shape == (2, 16)
    assert s0.batch_at(3).dtype == np.int32


# -- checkpoint -----------------------------------------------------------
def test_checkpoint_roundtrip(tmp_path):
    state = {"params": {"w": jnp.arange(12.0).reshape(3, 4),
                        "layers": [{"a": jnp.ones((2,))},
                                   {"a": jnp.zeros((2,))}]},
             "step": jnp.asarray(7, jnp.int32)}
    save(str(tmp_path), 7, state)
    assert latest_step(str(tmp_path)) == 7
    restored = restore(str(tmp_path), 7, state)
    for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# -- gradient compression ----------------------------------------------------
def test_int8_quantization_error_bounded():
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal(10_240).astype(np.float32))
    q, scale = quantize_int8(x, jax.random.PRNGKey(0))
    deq = dequantize_int8(q, scale, x.shape)
    err = np.abs(np.asarray(deq - x))
    blk_max = np.abs(np.asarray(x)).reshape(-1, 256).max(axis=1)
    assert np.all(err.reshape(-1, 256) <= (blk_max[:, None] / 127) + 1e-6)


def test_int8_rounding_unbiased():
    x = jnp.full((4096,), 0.31337, jnp.float32)
    deqs = []
    for seed in range(8):
        q, s = quantize_int8(x, jax.random.PRNGKey(seed))
        deqs.append(np.asarray(dequantize_int8(q, s, x.shape)).mean())
    assert abs(np.mean(deqs) - 0.31337) < 2e-4


# -- trainer + simulated cluster fault tolerance -------------------------------
def _tiny_trainer(tmp_path, steps=8):
    from repro.configs import get_config
    from repro.launch.mesh import make_smoke_mesh
    cfg = get_config("granite-3-2b").reduced()
    mesh = make_smoke_mesh()
    tcfg = TrainerConfig(steps=steps, checkpoint_every=4,
                         ckpt_dir=str(tmp_path))
    return Trainer(cfg, mesh, tcfg, seq_len=32, global_batch=4)


def test_trainer_runs_and_checkpoints(tmp_path):
    tr = _tiny_trainer(tmp_path)
    out = tr.run()
    assert len(out["log"]) == 8
    assert all(np.isfinite(m["loss"]) for m in out["log"])
    assert latest_step(str(tmp_path)) == 8


def test_trainer_restart_resumes(tmp_path):
    tr = _tiny_trainer(tmp_path, steps=4)
    tr.run()
    # simulate crash + restart with more steps: resumes from step 4
    tr2 = _tiny_trainer(tmp_path, steps=6)
    out = tr2.run()
    assert out["log"][0]["step"] == 4
    assert out["log"][-1]["step"] == 5


def test_simulated_cluster_failure_recovery(tmp_path):
    """Host dies at step 7 -> detection -> restore from checkpoint(5) ->
    elastic continue on fewer hosts -> completes all steps."""
    saved = {}
    work = []

    def do_step(step):
        work.append(step)

    def save_ckpt(step):
        saved["latest"] = step

    def restore_ckpt():
        return saved.get("latest", 0)

    plan = FaultPlan(die_at_step=7, die_host=2)
    sim = SimulatedCluster(n_hosts=4, plan=plan)
    out = sim.run(12, do_step, save_ckpt, restore_ckpt, checkpoint_every=5)
    assert out["restarts"] and out["restarts"][0]["resumed_from"] == 5
    assert out["restarts"][0]["new_n_hosts"] == 3
    assert out["steps_run"] >= 12  # replayed 5..7 after restart


def test_simulated_cluster_straggler_detection():
    plan = FaultPlan(straggle_host=1, straggle_factor=5.0)
    sim = SimulatedCluster(n_hosts=4, plan=plan, straggler_factor=2.0)
    sim.run(10, lambda s: None, lambda s: None, lambda: 0)
    assert any(e[1] == 1 for e in sim.monitor.events if e[0] == "straggler")


def test_simulated_cluster_wasted_steps_and_host_status():
    """The summary separates replayed work (checkpoint..failure) from
    total executed steps and surfaces per-host monitor statuses."""
    saved = {}

    def save_ckpt(step):
        saved["latest"] = step

    plan = FaultPlan(die_at_step=7, die_host=2)
    sim = SimulatedCluster(n_hosts=4, plan=plan)
    out = sim.run(12, lambda s: None, save_ckpt,
                  lambda: saved.get("latest", 0), checkpoint_every=5)
    # died at 7, restored from 5 -> steps 5 and 6 ran twice
    assert out["wasted_steps"] == 2
    assert out["steps_run"] == 12 + out["wasted_steps"]
    assert out["host_status"][2] == "dead"
    assert all(out["host_status"][h] == "ok" for h in (0, 1, 3))


def test_simulated_cluster_fault_free_has_no_waste():
    sim = SimulatedCluster(n_hosts=2)
    out = sim.run(6, lambda s: None, lambda s: None, lambda: 0)
    assert out["wasted_steps"] == 0
    assert set(out["host_status"].values()) == {"ok"}
