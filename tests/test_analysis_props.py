"""Property tests for the static verifier (ISSUE-10 satellite).

Randomly generated *legal* map scopes must verify clean, and a random
single-edit mutation of a legal program (subset shift, wcr drop, range
resize) must be detected. Skipped unless the optional ``hypothesis``
dependency is installed.
"""
import pytest

hypothesis = pytest.importorskip(
    "hypothesis", reason="property tests need the optional 'hypothesis' "
                         "dependency (pip install -e .[test])")
from hypothesis import given, settings, strategies as hst  # noqa: E402

from repro.analysis import verify_sdfg  # noqa: E402
from repro.core.memlet import Memlet, Range, Subset  # noqa: E402
from repro.core.sdfg import MapEntry, SDFG, Tasklet  # noqa: E402
from repro.core.symbolic import sym  # noqa: E402


def _legal_sdfg(n, m, wcr, two_d):
    """An always-legal program: per-iteration disjoint writes (or a
    wcr-protected accumulation) over static unit-step ranges."""
    s = SDFG("prop")
    shape = (n, m) if two_d else (n,)
    s.add_array("x", shape, "float32")
    s.add_array("y", shape, "float32")
    st = s.add_state("main", is_start=True)
    if two_d:
        params = {"i": (0, n), "j": (0, m)}
        sub = lambda: Subset([Range.index(sym("i")),
                              Range.index(sym("j"))])
    else:
        params = {"i": (0, n)}
        sub = lambda: Subset([Range.index(sym("i"))])
    outputs = {"yv": Memlet.simple("y", sub())}
    if wcr:
        s.add_array("acc", (1,), "float32")
        outputs["a"] = Memlet.simple("acc", wcr="add")
        fn = lambda xv: {"yv": xv * 2.0, "a": xv.reshape(-1)[:1]}
    else:
        fn = lambda xv: {"yv": xv * 2.0}
    st.add_mapped_tasklet(
        "body", params,
        inputs={"xv": Memlet.simple("x", sub())},
        outputs=outputs, fn=fn)
    return s


@settings(max_examples=40, deadline=None)
@given(n=hst.integers(min_value=1, max_value=128),
       m=hst.integers(min_value=1, max_value=16),
       wcr=hst.booleans(), two_d=hst.booleans())
def test_random_legal_scopes_verify_clean(n, m, wcr, two_d):
    assert verify_sdfg(_legal_sdfg(n, m, wcr, two_d)) == []


def _edges_of(sdfg, data, reads):
    out = []
    for st in sdfg.states:
        for e in st.edges:
            if e.memlet is None or e.memlet.data != data:
                continue
            if reads == isinstance(e.dst, Tasklet):
                out.append(e)
    return out


def _shift_read(sdfg, k):
    """x[i] -> x[i+k]: k >= 2 provably escapes the container on an
    (0, n) map; also an in-place RACE002 when y aliases x."""
    for e in _edges_of(sdfg, "x", reads=True):
        e.memlet.subset = Subset([Range.index(sym("i") + k)])


def _drop_wcr(sdfg):
    for st in sdfg.states:
        for e in st.edges:
            if e.memlet is not None and e.memlet.wcr is not None:
                e.memlet.wcr = None


def _widen_write(sdfg, k):
    """Per-iteration write of one element becomes a k-element slab
    starting at i: iterations overlap (RACE001) and the subset escapes
    the container near the end (BND001)."""
    for e in _edges_of(sdfg, "y", reads=False):
        e.memlet.subset = Subset([Range.make(sym("i"), sym("i") + k)])


@settings(max_examples=40, deadline=None)
@given(n=hst.integers(min_value=4, max_value=128),
       kind=hst.sampled_from(["shift_read", "drop_wcr", "widen_write"]),
       k=hst.integers(min_value=2, max_value=5))
def test_random_single_edit_mutations_detected(n, kind, k):
    s = _legal_sdfg(n, 1, wcr=(kind == "drop_wcr"), two_d=False)
    assert verify_sdfg(s) == []
    if kind == "shift_read":
        _shift_read(s, k)
        expected = {"BND001"}
    elif kind == "drop_wcr":
        _drop_wcr(s)
        expected = {"RACE001"}
    else:
        _widen_write(s, k)
        expected = {"RACE001", "BND001"}
    codes = {d.code for d in verify_sdfg(s)}
    assert codes & expected, (kind, n, k, codes)


@settings(max_examples=20, deadline=None)
@given(n=hst.integers(min_value=2, max_value=64),
       k=hst.integers(min_value=1, max_value=4))
def test_range_resize_past_extent_detected(n, k):
    """Resizing the map range past the container extent makes the
    (previously in-bounds) per-iteration access provably escape."""
    s = _legal_sdfg(n, 1, wcr=False, two_d=False)
    for st in s.states:
        for node in st.nodes:
            if isinstance(node, MapEntry):
                node.map.ranges = [Range.make(0, n + k)]
    codes = {d.code for d in verify_sdfg(s)}
    assert "BND001" in codes, (n, k, codes)
