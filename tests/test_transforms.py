"""Mid-level transformation tests (paper §3.2)."""
import numpy as np
import pytest

import repro.kernels  # noqa: F401  (register fusions)
from repro.core.dtypes import ScheduleType
from repro.frontends import blas
from repro.frontends.api import Program
from repro.transforms import (DeviceOffload, InputToConstant, MapTiling,
                              StreamingComposition, StreamingMemory,
                              Vectorization)


def build_axpydot(n):
    p = Program("axpydot")
    a = p.scalar_input("a", "float32")
    x, y, w = (p.input(nm, (n,)) for nm in ("x", "y", "w"))
    r = blas.dot(blas.axpy(a, x, y), w)
    p.output("result", r)
    return p.finalize()


@pytest.fixture
def axpydot_inputs():
    rng = np.random.default_rng(0)
    n = 512
    return dict(
        n=n, a=np.float32(0.7),
        x=rng.standard_normal(n).astype(np.float32),
        y=rng.standard_normal(n).astype(np.float32),
        w=rng.standard_normal(n).astype(np.float32),
    )


def expected_axpydot(d):
    return np.dot((d["a"] * d["x"] + d["y"]).astype(np.float32), d["w"])


def test_ladder_preserves_semantics(axpydot_inputs):
    d = axpydot_inputs
    exp = expected_axpydot(d)
    for transforms in ([DeviceOffload],
                       [DeviceOffload, StreamingComposition],
                       [DeviceOffload, StreamingComposition,
                        StreamingMemory]):
        sdfg = build_axpydot(d["n"])
        for t in transforms:
            sdfg.apply(t)
        out = sdfg.compile("jnp")(a=d["a"], x=d["x"], y=d["y"], w=d["w"])
        np.testing.assert_allclose(np.asarray(out["result"]).ravel()[0], exp,
                                   rtol=1e-4)


def test_composition_requires_matching_orders():
    # an array read twice (out-degree 2) must NOT compose
    n = 64
    p = Program("no_compose")
    a = p.scalar_input("a", "float32")
    x, y = p.input("x", (n,)), p.input("y", (n,))
    z = blas.axpy(a, x, y)
    r1 = blas.dot(z, x)
    # second consumer of z
    st = p.state
    from repro.library.blas import Dot
    from repro.core import Memlet
    d2 = st.add_node(Dot("dot_b"))
    st.add_edge(z.node, None, d2, "x", Memlet.simple(z.name))
    st.add_edge(st.add_access("y"), None, d2, "w", Memlet.simple("y"))
    r2h = p.temp((1,), "float32", name="r2")
    st.add_edge(d2, "result", r2h.fresh_write_node(), None,
                Memlet.simple("r2"))
    p.output("result", r1)
    p.output("r2", r2h)
    sdfg = p.finalize()
    sdfg.apply(DeviceOffload)
    assert sdfg.apply(StreamingComposition) == 0  # z has two consumers


def test_input_to_constant(axpydot_inputs):
    d = axpydot_inputs
    sdfg = build_axpydot(d["n"])
    n_applied = sdfg.apply(InputToConstant, parameters={"w": d["w"]})
    assert n_applied == 1
    sdfg.apply(DeviceOffload)
    # w no longer an argument, not counted in off-chip volume
    assert "w" not in sdfg.argument_names()
    out = sdfg.compile("jnp")(a=d["a"], x=d["x"], y=d["y"])
    np.testing.assert_allclose(np.asarray(out["result"]).ravel()[0],
                               expected_axpydot(d), rtol=1e-4)


def test_input_to_constant_refuses_written_arrays():
    n = 32
    p = Program("w_written")
    a = p.scalar_input("a", "float32")
    x, y = p.input("x", (n,)), p.input("y", (n,))
    z = blas.axpy(a, x, y)
    p.output("z", z)
    sdfg = p.finalize()
    # z is written -> cannot become constant
    assert sdfg.apply(InputToConstant,
                      parameters={"z": np.zeros(n, np.float32)}) == 0


def test_vectorization_sets_width():
    sdfg = build_axpydot(512)
    sdfg.apply(Vectorization, width=128)
    assert sdfg.metadata["vector_width"] == 128
    assert sdfg.arrays["x"].vector_width == 128


def test_map_tiling(axpydot_inputs):
    d = axpydot_inputs
    sdfg = build_axpydot(d["n"])
    sdfg.apply(DeviceOffload)
    sdfg.expand_library_nodes(level="generic")
    n_tiled = sdfg.apply(MapTiling, tile_size=64)
    assert n_tiled >= 1
    out = sdfg.compile("jnp")(a=d["a"], x=d["x"], y=d["y"], w=d["w"])
    np.testing.assert_allclose(np.asarray(out["result"]).ravel()[0],
                               expected_axpydot(d), rtol=1e-4)
