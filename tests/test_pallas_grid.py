"""Native Pallas grid codegen: memlet->BlockSpec factorization property
tests, jnp-vs-pallas cross-validation through the grid path, the
trip-limit acceptance case, strided memlet reads, and the vmap
slice-write fallback."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

import repro.kernels  # noqa: F401  (registers fusions)
from repro.codegen import pallas_backend
from repro.codegen.common import read_memlet
from repro.core.memlet import (BlockFactorError, Memlet, Range, Subset,
                               factor_subset)
from repro.core.sdfg import SDFG
from repro.core.symbolic import Expr, sym
from repro.frontends import blas
from repro.frontends.api import Program
from repro.pipeline import lower


# ---------------------------------------------------------------------------
# factor_subset: blocks reassemble to the plain memlet reads
# ---------------------------------------------------------------------------

def _reassemble(value, memlet, fact, grid_params, block_params):
    """Gather every block per (block_shape, index_map) and check it equals
    the elements read_memlet returns for the corresponding parameter
    bindings; also rebuild the union of blocks."""
    names = list(grid_params)
    imap = fact.index_map(names)
    got = np.full(value.shape, np.nan, np.float32)
    for ids in np.ndindex(*[grid_params[p][1] for p in names]):
        coords = imap(*[int(i) for i in ids])
        sl = tuple(slice(c * b, c * b + b)
                   for c, b in zip(coords, fact.block_shape))
        block = np.asarray(value[sl])
        got[sl] = block
        # element-wise parity with the interpreter's read_memlet
        env = {p: grid_params[p][0] + int(i) for p, i in zip(names, ids)}
        if not block_params:
            ref = np.asarray(read_memlet(jnp.asarray(value), memlet, env))
            assert np.array_equal(block.squeeze(), np.asarray(ref).squeeze())
        else:
            for bids in np.ndindex(*[block_params[q] for q in block_params]):
                benv = dict(env)
                benv.update({q: int(b) for q, b
                             in zip(block_params, bids)})
                ref = np.asarray(read_memlet(jnp.asarray(value), memlet,
                                             benv))
                pd = dict(fact.param_dims)
                idx = [0] * len(fact.block_shape)
                for q, b in zip(block_params, bids):
                    idx[pd[q]] = int(b)
                assert np.allclose(block[tuple(idx)].squeeze(),
                                   ref.squeeze())
    return got


@pytest.mark.parametrize("case", ["index", "tiled", "row_slice", "affine2d"])
def test_factor_subset_blocks_reassemble(case):
    rng = np.random.default_rng(7)
    if case == "index":          # x[i] over i in [0, 12)
        value = rng.standard_normal(12).astype(np.float32)
        memlet = Memlet.simple("x", Subset.indices([sym("i")]))
        grid, block = {"i": (0, 12)}, {}
        shape = (12,)
    elif case == "tiled":        # x[4*it + q], tile extent 4
        value = rng.standard_normal(16).astype(np.float32)
        memlet = Memlet.simple(
            "x", Subset.indices([sym("it") * 4 + sym("q")]))
        grid, block = {"it": (0, 4)}, {"q": 4}
        shape = (16,)
    elif case == "row_slice":    # A[i, 0:6] over rows
        value = rng.standard_normal((5, 6)).astype(np.float32)
        memlet = Memlet.simple(
            "A", Subset([Range.index(sym("i")), Range.make(0, 6)]))
        grid, block = {"i": (0, 5)}, {}
        shape = (5, 6)
    else:                        # A[2*i, j] with rebased j in [1, 4)
        value = rng.standard_normal((8, 4)).astype(np.float32)
        memlet = Memlet.simple(
            "A", Subset.indices([sym("i") * 2, sym("j")]))
        grid, block = {"i": (0, 4), "j": (1, 3)}, {}
        shape = (8, 4)
    fact = factor_subset(memlet.subset, [Expr.const(s) for s in shape],
                         grid, block, {})
    got = _reassemble(value, memlet, fact, grid, block)
    covered = ~np.isnan(got)
    assert covered.any()
    assert np.array_equal(got[covered], np.asarray(value)[covered])


def test_factor_subset_rejects_non_affine_and_misaligned():
    shape = [Expr.const(16)]
    with pytest.raises(BlockFactorError):  # quadratic index
        factor_subset(Subset.indices([sym("i") * sym("i")]), shape,
                      {"i": (0, 4)}, {}, {})
    with pytest.raises(BlockFactorError):  # unbound (dynamic) symbol
        factor_subset(Subset.indices([sym("i") + sym("t")]), shape,
                      {"i": (0, 16)}, {}, {})
    with pytest.raises(BlockFactorError):  # tile offset not block-aligned
        factor_subset(Subset.indices([sym("it") * 3 + sym("q")]), shape,
                      {"it": (0, 4)}, {"q": 4}, {})
    with pytest.raises(BlockFactorError):  # strided range
        factor_subset(Subset([Range.make(0, 16, 2)]), shape,
                      {"i": (0, 8)}, {}, {})


# ---------------------------------------------------------------------------
# grid-path acceptance: tiled map beyond the trip limit -> one pallas_call
# ---------------------------------------------------------------------------

def _big_rows_sdfg(n=8192, m=4):
    s = SDFG("bigrows")
    s.add_array("x", (n, m), "float32")
    s.add_array("out", (n, m), "float32")
    st = s.add_state("main", is_start=True)
    st.add_mapped_tasklet(
        "rows", {"i": (0, n)},
        inputs={"xr": Memlet.simple("x", Subset([Range.index(sym("i")),
                                                 Range.make(0, m)]))},
        outputs={"o": Memlet.simple("out", Subset([Range.index(sym("i")),
                                                   Range.make(0, m)]))},
        fn=lambda xr: xr * 2.0 + 1.0)
    return s


def test_tiled_map_beyond_trip_limit_single_grid_kernel(monkeypatch):
    """A tiled map with total trip count > SEQUENTIAL_TRIP_LIMIT compiles
    through default_pipeline('pallas') as ONE pl.pallas_call grid kernel;
    the jnp interpreter still refuses (trace-time loop guard)."""
    x = np.random.default_rng(0).standard_normal((8192, 4)).astype(np.float32)

    calls = []
    orig = pallas_backend.pl.pallas_call

    def counting(*a, **kw):
        calls.append(kw.get("grid"))
        return orig(*a, **kw)

    monkeypatch.setattr(pallas_backend.pl, "pallas_call", counting)
    c = lower(_big_rows_sdfg()).compile("pallas", jit=False, cache=None)
    assert c.report["grid_kernels"] == ["rows_tiled"]
    out = np.asarray(c(x=x)["out"])
    np.testing.assert_allclose(out, x * 2 + 1, rtol=1e-6)
    # 8192 rows / 64 tile (the CPU-interpret calibrated minor width)
    assert len(calls) == 1 and calls[0] == (128,)

    with pytest.raises(NotImplementedError, match="sequential iterations"):
        lower(_big_rows_sdfg()).compile("jnp", cache=None)(x=x)


# ---------------------------------------------------------------------------
# jnp-vs-pallas cross-validation through the grid path
# ---------------------------------------------------------------------------

def test_gemm_wcr_grid_cross_validation():
    """The hand-written kernels/gemm pattern — K innermost, scratch
    accumulator with @pl.when init/flush — generated from a wcr-add map."""
    M, N, K = 32, 24, 16
    s = SDFG("gemm3")
    s.add_array("A", (M, K), "float32")
    s.add_array("B", (K, N), "float32")
    s.add_array("C", (M, N), "float32")
    st = s.add_state("main", is_start=True)
    i, j, k = sym("i"), sym("j"), sym("k")
    st.add_mapped_tasklet(
        "gemm", {"i": (0, M), "j": (0, N), "k": (0, K)},
        inputs={"a": Memlet.simple("A", Subset.indices([i, k])),
                "b": Memlet.simple("B", Subset.indices([k, j]))},
        outputs={"c": Memlet.simple("C", Subset.indices([i, j]), wcr="add")},
        fn=lambda a, b: a * b)
    rng = np.random.default_rng(1)
    A = rng.standard_normal((M, K)).astype(np.float32)
    B = rng.standard_normal((K, N)).astype(np.float32)
    c = lower(s).compile("pallas")
    assert c.report["grid_kernels"] == ["gemm_tiled"]
    np.testing.assert_allclose(np.asarray(c(A=A, B=B)["C"]), A @ B,
                               rtol=1e-4, atol=1e-5)


def test_stencil_grid_cross_validation():
    """5-point star over interior points via per-offset index memlets; the
    untouched boundary verifies box stitching of partial grid writes."""
    n, m = 20, 24
    s = SDFG("star5")
    s.add_array("a", (n, m), "float32")
    s.add_array("b", (n, m), "float32")
    st = s.add_state("main", is_start=True)
    i, j = sym("i"), sym("j")
    offs = {"c": (0, 0), "nn": (-1, 0), "ss": (1, 0),
            "ww": (0, -1), "ee": (0, 1)}
    st.add_mapped_tasklet(
        "star", {"i": (1, n - 1), "j": (1, m - 1)},
        inputs={kk: Memlet.simple("a", Subset.indices([i + di, j + dj]))
                for kk, (di, dj) in offs.items()},
        outputs={"o": Memlet.simple("b", Subset.indices([i, j]))},
        fn=lambda c, nn, ss, ww, ee: 0.5 * c + 0.125 * (nn + ss + ww + ee))
    a = np.random.default_rng(3).standard_normal((n, m)).astype(np.float32)
    cp = lower(s).compile("pallas")
    assert cp.report["grid_kernels"] == ["star_tiled"]
    out_p = np.asarray(cp(a=a)["b"])
    out_j = np.asarray(lower(s).compile("jnp")(a=a)["b"])
    assert np.isfinite(out_p).all()
    np.testing.assert_allclose(out_p, out_j, rtol=1e-5, atol=1e-6)
    assert np.all(out_p[0] == 0) and np.all(out_p[:, -1] == 0)


def test_axpy_tiled_grid_cross_validation():
    n = 2048
    rng = np.random.default_rng(2)
    a = np.float32(0.7)
    x, y = (rng.standard_normal(n).astype(np.float32) for _ in range(2))
    p = Program("axpy")
    ah = p.scalar_input("a", "float32")
    xh, yh = p.input("x", (n,)), p.input("y", (n,))
    p.output("z", blas.axpy(ah, xh, yh))
    s = p.finalize()
    c = lower(s).compile("pallas", expansion_level="generic")
    assert c.report["grid_kernels"] == ["axpy0_map_tiled"]
    out = np.asarray(c(a=a, x=x, y=y)["z"])
    np.testing.assert_allclose(out, a * x + y, rtol=1e-5, atol=1e-6)


def _build_axpydot(n):
    p = Program("axpydot")
    a = p.scalar_input("a", "float32")
    x, y, w = (p.input(nm, (n,)) for nm in ("x", "y", "w"))
    p.output("result", blas.dot(blas.axpy(a, x, y), w))
    return p.finalize()


def test_axpydot_grid_cross_validation():
    """Acceptance: axpydot jnp-vs-pallas within 1e-4 through the grid path
    (generic expansions -> the axpy fuses into the dot's partial-product
    stream stage, one grid kernel)."""
    n = 2048
    rng = np.random.default_rng(5)
    a = np.float32(-0.3)
    x, y, w = (rng.standard_normal(n).astype(np.float32) for _ in range(3))
    outs = {}
    for backend in ("jnp", "pallas"):
        c = lower(_build_axpydot(n)).compile(backend,
                                             expansion_level="generic")
        if backend == "pallas":
            assert any(k.startswith("axpy0_map+dot0_stream")
                       for k in c.report["grid_kernels"])
        outs[backend] = np.asarray(c(a=a, x=x, y=y, w=w)["result"]).ravel()[0]
    np.testing.assert_allclose(outs["pallas"], outs["jnp"], rtol=1e-4)


def _build_gemver(n):
    p = Program("gemver")
    A = p.input("A", (n, n))
    u1, v1 = p.input("u1", (n,)), p.input("v1", (n,))
    u2, v2 = p.input("u2", (n,)), p.input("v2", (n,))
    yv, zv = p.input("y", (n,)), p.input("z", (n,))
    B1 = blas.ger(A, u1, v1)
    B2 = blas.ger(B1, u2, v2)
    x = blas.gemv(B2, yv, y0=zv, trans=True, alpha=0.9, beta=1.0)
    w = blas.gemv(B2, x, alpha=1.1)
    p.output("x_out", x)
    p.output("w_out", w)
    return p.finalize()


def test_gemver_grid_cross_validation():
    """Acceptance: gemver jnp-vs-pallas within 1e-4. The two rank-1
    updates fuse into ONE grid kernel (B1 never leaves the kernel); the
    two gemv row maps lower to grid kernels of their own."""
    n = 64
    rng = np.random.default_rng(6)
    d = {k: rng.standard_normal((n, n) if k == "A" else n).astype(np.float32)
         for k in ("A", "u1", "v1", "u2", "v2", "y", "z")}
    cj = lower(_build_gemver(n)).compile("jnp")
    cp = lower(_build_gemver(n)).compile("pallas", expansion_level="generic")
    assert cp.report["grid_kernels"] == ["ger0_map+ger1_map_tiled",
                                         "gemv0_rows", "gemv1_rows"]
    assert cp.report["grid_fallbacks"] == []
    # the row-sliced gemv reads of B2 refuse halo fusion with a typed
    # reason instead of silently staying unfused
    assert sorted(cp.report["grid_skipped"]) == [
        ("gemv0_rows", "fusion refused: consumer reads a windowed slice "
                       "of the intermediate"),
        ("gemv1_rows", "fusion refused: consumer reads a windowed slice "
                       "of the intermediate")]
    fused = next(c for c in cp.report["grid_converted"]
                 if c["map"] == "ger0_map+ger1_map_tiled")
    assert fused["tasklets"] == 2
    # multi-dim tiling: the fused rank-1 pair runs on sublane x lane blocks
    assert len(fused["block_shape"]) == 2 and fused["block_shape"][-1] >= 8
    oj, op = cj(**d), cp(**d)
    for kk in ("x_out", "w_out"):
        np.testing.assert_allclose(np.asarray(op[kk]), np.asarray(oj[kk]),
                                   rtol=1e-4, atol=1e-5)


def test_grid_fallback_on_unrolled_schedule():
    """Non-eligible scopes (e.g. UNROLLED reduce phases) stay on the
    interpreter path and the program still runs correctly."""
    n = 256
    rng = np.random.default_rng(8)
    x, w = (rng.standard_normal(n).astype(np.float32) for _ in range(2))
    p = Program("dot")
    xh, wh = p.input("x", (n,)), p.input("w", (n,))
    p.output("result", blas.dot(xh, wh))
    c = lower(p.finalize()).compile("pallas", expansion_level="partial_sums")
    assert any("dot0_reduce" in lbl for lbl, _ in c.report["grid_fallbacks"])
    out = np.asarray(c(x=x, w=w)["result"]).ravel()[0]
    np.testing.assert_allclose(out, np.dot(x, w), rtol=1e-4)


def test_two_outputs_same_container_stitch():
    """Two output edges targeting disjoint halves of one container must
    both survive the grid-path stitch (regression: stale pre-kernel
    values dropped all but the last)."""
    n = 8
    s = SDFG("twoout")
    s.add_array("x", (n,), "float32")
    s.add_array("out", (2 * n,), "float32")
    st = s.add_state("main", is_start=True)
    i = sym("i")
    st.add_mapped_tasklet(
        "halves", {"i": (0, n)},
        inputs={"v": Memlet.simple("x", Subset.indices([i]))},
        outputs={"lo": Memlet.simple("out", Subset.indices([i])),
                 "hi": Memlet.simple("out", Subset.indices([i + n]))},
        fn=lambda v: {"lo": v * 2.0, "hi": v * 3.0})
    x = np.random.default_rng(10).standard_normal(n).astype(np.float32)
    op = np.asarray(lower(s).compile("pallas")(x=x)["out"])
    oj = np.asarray(lower(s).compile("jnp")(x=x)["out"])
    np.testing.assert_allclose(op, oj, rtol=1e-6)
    np.testing.assert_allclose(op, np.concatenate([x * 2, x * 3]), rtol=1e-6)


# ---------------------------------------------------------------------------
# satellite: strided memlet reads
# ---------------------------------------------------------------------------

def test_read_memlet_static_strides():
    x = jnp.arange(16, dtype=jnp.float32)
    m = Memlet.simple("x", Subset([Range.make(1, 13, 2)]))  # x[1:13:2]
    out = np.asarray(read_memlet(x, m, {}))
    np.testing.assert_array_equal(out, np.arange(16, dtype=np.float32)[1:13:2])

    A = jnp.arange(24, dtype=jnp.float32).reshape(4, 6)
    m2 = Memlet.simple("A", Subset([Range.index(2), Range.make(0, 6, 3)]))
    out2 = np.asarray(read_memlet(A, m2, {}))
    np.testing.assert_array_equal(out2, np.asarray(A)[2, 0:6:3])

    # span not a multiple of step sizes like numpy (ceil)
    m3 = Memlet.simple("x", Subset([Range.make(0, 15, 2)]))
    out3 = np.asarray(read_memlet(x, m3, {}))
    np.testing.assert_array_equal(out3, np.arange(16, dtype=np.float32)[0:15:2])


def test_read_memlet_interleaved_partial_sums():
    """x[l::K] — the interleaved partial-sum subset — with both a static
    and a traced lane index."""
    K, n = 4, 32
    x = jnp.arange(n, dtype=jnp.float32)
    lanes = Subset([Range(sym("l"), sym("l") + K * (n // K), Expr.const(K))])
    m = Memlet.simple("x", lanes)
    for l in range(K):
        out = np.asarray(read_memlet(x, m, {"l": l}))
        np.testing.assert_array_equal(out, np.asarray(x)[l::K])

    @jax.jit
    def traced(l):
        return read_memlet(x, m, {"l": l})

    np.testing.assert_array_equal(np.asarray(traced(jnp.int32(2))),
                                  np.asarray(x)[2::K])


def test_write_memlet_static_strides():
    """Strided *writes* with static starts mirror the strided reads: the
    values land on exactly the strided positions (set / wcr add/max/min);
    only traced starts with strides keep the loud failure."""
    from repro.codegen.common import write_memlet
    x = jnp.zeros(16, jnp.float32)
    m = Memlet.simple("x", Subset([Range.make(1, 13, 2)]))
    out = np.asarray(write_memlet(x, m, jnp.ones(6, jnp.float32), {}))
    ref = np.zeros(16, np.float32)
    ref[1:13:2] = 1.0
    np.testing.assert_array_equal(out, ref)

    # wcr add accumulates on the strided positions only
    m_add = Memlet.simple("x", Subset([Range.make(0, 15, 2)]), wcr="add")
    base = jnp.arange(16, dtype=jnp.float32)
    out2 = np.asarray(write_memlet(base, m_add,
                                   10 * jnp.ones(8, jnp.float32), {}))
    ref2 = np.arange(16, dtype=np.float32)
    ref2[0:15:2] += 10
    np.testing.assert_array_equal(out2, ref2)

    # wcr min on a strided 2-d subset
    A = jnp.full((4, 6), 5.0, jnp.float32)
    m2 = Memlet.simple("A", Subset([Range.index(2), Range.make(0, 6, 3)]),
                       wcr="min")
    out3 = np.asarray(write_memlet(A, m2, jnp.zeros(2, jnp.float32), {}))
    ref3 = np.full((4, 6), 5.0, np.float32)
    ref3[2, 0:6:3] = 0.0
    np.testing.assert_array_equal(out3, ref3)

    # traced start + stride would need a scatter: still loud
    with pytest.raises(NotImplementedError, match="strided memlet writes"):
        jax.jit(lambda s: write_memlet(
            x, Memlet.simple("x", Subset([Range(sym("s"), sym("s") + 12,
                                                Expr.const(2))])),
            jnp.ones(6, jnp.float32), {"s": s}))(jnp.int32(1))


# ---------------------------------------------------------------------------
# multi-tasklet grid kernels (fused scopes)
# ---------------------------------------------------------------------------

def _chain_sdfg(n=256):
    """Hand-built fused-style scope: two tasklets threaded by a
    per-iteration transient on a direct tasklet->tasklet edge."""
    from repro.core.dtypes import StorageType
    s = SDFG("chain")
    s.add_array("x", (n,), "float32")
    s.add_array("out", (n,), "float32")
    s.add_transient("t", (n,), "float32", storage=StorageType.REG)
    st = s.add_state("main", is_start=True)
    entry, exit_ = st.add_map("chain", {"i": (0, n)})
    t1 = st.add_tasklet("t1", ["v"], ["w"], lambda v: v * 2.0)
    t2 = st.add_tasklet("t2", ["w"], ["o"], lambda w: w + 1.0)
    i = sym("i")
    st.add_edge(st.add_access("x"), None, entry, "IN_x", Memlet.simple("x"))
    st.add_edge(entry, "OUT_x", t1, "v",
                Memlet.simple("x", Subset.indices([i])))
    st.add_edge(t1, "w", t2, "w", Memlet.simple("t", Subset.indices([i])))
    st.add_edge(t2, "o", exit_, "IN_out",
                Memlet.simple("out", Subset.indices([i])))
    st.add_edge(exit_, "OUT_out", st.add_access("out"), None,
                Memlet.simple("out"))
    return s


def test_multi_tasklet_scope_single_grid_kernel(monkeypatch):
    """A two-tasklet chain compiles to ONE pallas_call; the intermediate
    never materializes as an operand (only x in, out out)."""
    calls = []
    orig = pallas_backend.pl.pallas_call

    def counting(*a, **kw):
        calls.append((kw.get("grid"), len(kw.get("in_specs", []))))
        return orig(*a, **kw)

    monkeypatch.setattr(pallas_backend.pl, "pallas_call", counting)
    x = np.random.default_rng(11).standard_normal(256).astype(np.float32)
    c = lower(_chain_sdfg()).compile("pallas", jit=False, cache=None)
    assert c.report["grid_kernels"] == ["chain_tiled"]
    out = np.asarray(c(x=x)["out"])
    np.testing.assert_allclose(out, x * 2 + 1, rtol=1e-6)
    assert calls == [((4,), 1)]  # one kernel (256 / 64 tile), one operand

    oj = np.asarray(lower(_chain_sdfg()).compile("jnp", cache=None)(x=x)["out"])
    np.testing.assert_allclose(out, oj, rtol=1e-6)


def test_multi_tasklet_chain_with_reduction():
    """Fused chain feeding a wcr-add scalar reduction: axpy -> mul chained
    in-kernel, scratch-accumulated dot result."""
    n = 512
    s = SDFG("axpydot_fused")
    for nm in ("x", "y", "w"):
        s.add_array(nm, (n,), "float32")
    s.add_scalar("r", "float32")
    s.add_transient("z", (n,), "float32")
    st = s.add_state("main", is_start=True)
    entry, exit_ = st.add_map("fdot", {"i": (0, n)})
    i = sym("i")
    t1 = st.add_tasklet("axpy", ["x", "y"], ["z"], lambda x, y: 0.5 * x + y)
    t2 = st.add_tasklet("mul", ["z", "w"], ["p"], lambda z, w: z * w)
    for nm, conn, t in (("x", "x", t1), ("y", "y", t1), ("w", "w", t2)):
        st.add_edge(st.add_access(nm), None, entry, f"IN_{nm}",
                    Memlet.simple(nm))
        st.add_edge(entry, f"OUT_{nm}", t, conn,
                    Memlet.simple(nm, Subset.indices([i])))
    st.add_edge(t1, "z", t2, "z", Memlet.simple("z", Subset.indices([i])))
    st.add_edge(t2, "p", exit_, "IN_r", Memlet.simple("r", wcr="add"))
    st.add_edge(exit_, "OUT_r", st.add_access("r"), None,
                Memlet.simple("r", wcr="add"))
    rng = np.random.default_rng(12)
    x, y, w = (rng.standard_normal(n).astype(np.float32) for _ in range(3))
    cp = lower(s).compile("pallas", cache=None)
    assert cp.report["grid_kernels"] == ["fdot_tiled"]
    out = float(np.asarray(cp(x=x, y=y, w=w)["r"]))
    np.testing.assert_allclose(out, np.dot(0.5 * x + y, w), rtol=1e-4)


# ---------------------------------------------------------------------------
# satellite: wcr max / min through the grid path
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("wcr", ["max", "min"])
def test_wcr_extrema_grid_cross_validation(wcr):
    """Row-extrema via wcr max/min: the reduction dimension lowers to a
    VMEM scratch running-extremum with @pl.when init/flush."""
    M, N = 16, 24
    s = SDFG(f"row{wcr}")
    s.add_array("A", (M, N), "float32")
    s.add_array("out", (M,), "float32")
    st = s.add_state("main", is_start=True)
    i, j = sym("i"), sym("j")
    st.add_mapped_tasklet(
        f"row{wcr}", {"i": (0, M), "j": (0, N)},
        inputs={"a": Memlet.simple("A", Subset.indices([i, j]))},
        outputs={"o": Memlet.simple("out", Subset.indices([i]), wcr=wcr)},
        fn=lambda a: a)
    A = np.random.default_rng(13).standard_normal((M, N)).astype(np.float32)
    cp = lower(s).compile("pallas", cache=None)
    assert cp.report["grid_kernels"] == [f"row{wcr}_tiled"]
    op = np.asarray(cp(A=A)["out"])
    oj = np.asarray(lower(s).compile("jnp", cache=None)(A=A)["out"])
    np.testing.assert_allclose(op, oj, rtol=1e-6)
    # both backends combine with the container's prior (zero) contents
    red = A.max(axis=1) if wcr == "max" else A.min(axis=1)
    comb = np.maximum if wcr == "max" else np.minimum
    np.testing.assert_allclose(op, comb(red, 0.0), rtol=1e-6)


@pytest.mark.parametrize("wcr", ["max", "min"])
def test_wcr_extrema_scalar_tiled(wcr):
    """Whole-array extremum into a scalar through a *tiled* map: the
    intra-tile axis reduces in-block, the grid axis through scratch."""
    n = 512
    s = SDFG(f"all{wcr}")
    s.add_array("x", (n,), "float32")
    s.add_scalar("out", "float32")
    st = s.add_state("main", is_start=True)
    st.add_mapped_tasklet(
        f"all{wcr}", {"i": (0, n)},
        inputs={"v": Memlet.simple("x", Subset.indices([sym("i")]))},
        outputs={"o": Memlet.simple("out", wcr=wcr)},
        fn=lambda v: v)
    x = np.random.default_rng(14).standard_normal(n).astype(np.float32)
    cp = lower(s).compile("pallas", cache=None)
    assert cp.report["grid_kernels"] == [f"all{wcr}_tiled"]
    op = float(np.asarray(cp(x=x)["out"]))
    oj = float(np.asarray(lower(s).compile("jnp", cache=None)(x=x)["out"]))
    np.testing.assert_allclose(op, oj, rtol=1e-6)
    red = x.max() if wcr == "max" else x.min()
    comb = max if wcr == "max" else min
    np.testing.assert_allclose(op, comb(float(red), 0.0), rtol=1e-6)


# ---------------------------------------------------------------------------
# cost model: tiny maps stay on the vmap path
# ---------------------------------------------------------------------------

def _rows_sdfg(n, m, label="rows"):
    s = SDFG(label)
    s.add_array("x", (n, m), "float32")
    s.add_array("out", (n, m), "float32")
    st = s.add_state("main", is_start=True)
    st.add_mapped_tasklet(
        label, {"i": (0, n)},
        inputs={"xr": Memlet.simple("x", Subset([Range.index(sym("i")),
                                                 Range.make(0, m)]))},
        outputs={"o": Memlet.simple("out", Subset([Range.index(sym("i")),
                                                   Range.make(0, m)]))},
        fn=lambda xr: xr * 3.0)
    return s


def test_cost_model_skips_single_step_grid():
    """A one-step grid is a whole-array copy: the default cost model keeps
    it on the vmap path and records the decision."""
    s = _rows_sdfg(1, 8, label="one")
    x = np.random.default_rng(15).standard_normal((1, 8)).astype(np.float32)
    c = lower(s).compile("pallas", cache=None)
    assert c.report["grid_kernels"] == []
    assert [lbl for lbl, _ in c.report["grid_skipped"]] == ["one"]
    assert "min_grid_steps" in c.report["grid_skipped"][0][1]
    np.testing.assert_allclose(np.asarray(c(x=x)["out"]), x * 3, rtol=1e-6)


def test_cost_model_min_grid_steps_knob():
    """The same map converts by default and skips under a raised
    trip threshold — while still computing the right answer."""
    from repro.pipeline import GridConversionPass, PassManager
    x = np.random.default_rng(16).standard_normal((64, 4)).astype(np.float32)
    c_on = lower(_rows_sdfg(64, 4)).compile("pallas", cache=None)
    assert c_on.report["grid_kernels"] == ["rows"]
    pm = PassManager([GridConversionPass(min_grid_steps=1000)], name="tiny")
    c_off = lower(_rows_sdfg(64, 4)).compile("pallas", pipeline=pm,
                                             cache=None)
    assert c_off.report["grid_kernels"] == []
    assert [lbl for lbl, _ in c_off.report["grid_skipped"]] == ["rows"]
    np.testing.assert_allclose(np.asarray(c_on(x=x)["out"]),
                               np.asarray(c_off(x=x)["out"]), rtol=1e-6)


def test_cost_model_vmem_budget():
    """Blocks that exceed the VMEM budget keep the scope on the vmap
    path, with the overflow recorded in the skip reason."""
    from repro.pipeline import GridConversionPass, PassManager
    pm = PassManager([GridConversionPass(vmem_budget_bytes=64)], name="vmem")
    c = lower(_rows_sdfg(64, 128)).compile("pallas", pipeline=pm, cache=None)
    assert c.report["grid_kernels"] == []
    (lbl, reason), = c.report["grid_skipped"]
    assert lbl == "rows" and "VMEM" in reason
    x = np.random.default_rng(17).standard_normal((64, 128)).astype(np.float32)
    np.testing.assert_allclose(np.asarray(c(x=x)["out"]), x * 3, rtol=1e-6)


# ---------------------------------------------------------------------------
# satellite: vmap slice-write fallback
# ---------------------------------------------------------------------------

def test_vmap_slice_write_falls_back_to_sequential():
    """A mapped tasklet writing a per-iteration slice used to raise
    NotImplementedError in the vectorized lowering; it now falls back to
    the sequential schedule."""
    n, m = 8, 5
    s = SDFG("sliced")
    s.add_array("x", (n, m), "float32")
    s.add_array("out", (n, m), "float32")
    st = s.add_state("main", is_start=True)
    st.add_mapped_tasklet(
        "rows", {"i": (0, n)},
        inputs={"xr": Memlet.simple("x", Subset([Range.index(sym("i")),
                                                 Range.make(0, m)]))},
        outputs={"o": Memlet.simple("out", Subset([Range.index(sym("i")),
                                                   Range.make(0, m)]))},
        fn=lambda xr: jnp.cumsum(xr))
    x = np.random.default_rng(9).standard_normal((n, m)).astype(np.float32)
    out = np.asarray(lower(s).compile("jnp")(x=x)["out"])
    np.testing.assert_allclose(out, np.cumsum(x, axis=1), rtol=1e-5)
