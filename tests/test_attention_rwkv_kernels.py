"""Flash-attention and chunked-WKV Pallas kernels vs jnp oracles."""
import numpy as np
import pytest

import jax.numpy as jnp
from repro.kernels.attention import attention_ref, flash_attention
from repro.kernels.rwkv import wkv_chunked, wkv_ref

RNG = np.random.default_rng(11)


def _qkv(b, sq, sk, hq, hkv, dh, dtype=np.float32):
    q = RNG.standard_normal((b, sq, hq, dh)).astype(dtype)
    k = RNG.standard_normal((b, sk, hkv, dh)).astype(dtype)
    v = RNG.standard_normal((b, sk, hkv, dh)).astype(dtype)
    return q, k, v


@pytest.mark.parametrize("cfg", [
    dict(b=1, s=256, hq=4, hkv=4, dh=64),            # MHA
    dict(b=2, s=128, hq=8, hkv=2, dh=32),            # GQA 4:1
    dict(b=1, s=512, hq=2, hkv=1, dh=64),            # MQA
])
@pytest.mark.parametrize("causal", [True, False])
def test_flash_attention_matches_ref(cfg, causal):
    q, k, v = _qkv(cfg["b"], cfg["s"], cfg["s"], cfg["hq"], cfg["hkv"],
                   cfg["dh"])
    out = flash_attention(q, k, v, causal=causal, bq=64, bk=64,
                          interpret=True)
    ref = attention_ref(q, k, v, causal=causal)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-4, atol=2e-4)


def test_flash_attention_sliding_window():
    q, k, v = _qkv(1, 256, 256, 4, 4, 32)
    out = flash_attention(q, k, v, causal=True, window=64, bq=64, bk=64,
                          interpret=True)
    ref = attention_ref(q, k, v, causal=True, window=64)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-4, atol=2e-4)


def test_flash_attention_bf16():
    import ml_dtypes
    q, k, v = _qkv(1, 128, 128, 4, 4, 64, dtype=ml_dtypes.bfloat16)
    out = flash_attention(q, k, v, causal=True, bq=64, bk=64, interpret=True)
    ref = attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               rtol=3e-2, atol=3e-2)


# -- WKV ------------------------------------------------------------------
@pytest.mark.parametrize("S", [16, 64, 160])
@pytest.mark.parametrize("hd", [8, 32])
def test_wkv_kernel_matches_sequential(S, hd):
    B, H = 2, 3
    r, k, v = (jnp.asarray(RNG.standard_normal((B, S, H, hd)) * 0.5,
                           jnp.float32) for _ in range(3))
    w = jnp.asarray(np.exp(-0.5 - 3.0 * RNG.uniform(0, 1, (B, S, H, hd))),
                    jnp.float32)
    u = jnp.asarray(RNG.standard_normal((H, hd)) * 0.3, jnp.float32)
    s0 = jnp.zeros((B, H, hd, hd), jnp.float32)
    out_k, st_k = wkv_chunked(r, k, v, w, u, interpret=True)
    out_r, st_r = wkv_ref(r, k, v, w, u, s0)
    np.testing.assert_allclose(np.asarray(out_k), np.asarray(out_r),
                               rtol=3e-4, atol=3e-4)
    np.testing.assert_allclose(np.asarray(st_k), np.asarray(st_r),
                               rtol=3e-4, atol=3e-4)
