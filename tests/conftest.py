import os
import sys
from pathlib import Path

# src layout import path (tests run with PYTHONPATH=src, but be robust)
SRC = Path(__file__).resolve().parents[1] / "src"
if str(SRC) not in sys.path:
    sys.path.insert(0, str(SRC))

# NOTE: deliberately no --xla_force_host_platform_device_count here;
# smoke tests and benches must see 1 device (dry-run sets 512 itself).
