"""Per-architecture smoke tests: reduced same-family configs run one
forward/train step on CPU, asserting shapes + no NaNs; decode paths are
validated against the full-sequence forward (cache consistency)."""
import dataclasses

import numpy as np
import pytest

import jax
import jax.numpy as jnp
from repro.configs import ARCHS, get_config
from repro.models import build_model, example_batch

ALL_ARCHS = sorted(ARCHS)


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_smoke_train_step(arch):
    cfg = get_config(arch).reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    batch_np = example_batch(cfg, "train", batch=2, seq=32)
    batch = {k: jnp.asarray(v) for k, v in batch_np.items()}
    if cfg.family == "vlm":
        batch["stub_embeds"] = batch["stub_embeds"][:, :cfg.n_stub_tokens]

    logits, aux = model.forward(params, batch)
    assert logits.shape[:2] == (2, 32)
    assert bool(jnp.all(jnp.isfinite(logits)))

    loss, grads = jax.value_and_grad(model.loss)(params, batch)
    assert np.isfinite(float(loss))
    gnorms = [float(jnp.max(jnp.abs(g))) for g in jax.tree.leaves(grads)]
    assert all(np.isfinite(g) for g in gnorms)


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_smoke_decode_step(arch):
    cfg = get_config(arch).reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    cache = model.init_cache(2, 64)
    tok = jnp.zeros((2, 1), jnp.int32)
    logits, cache = model.decode_step(params, cache, tok)
    assert logits.shape[0] == 2 and logits.shape[1] == 1
    assert bool(jnp.all(jnp.isfinite(logits)))
    logits2, cache = model.decode_step(params, cache, tok)
    assert int(cache["pos"]) == 2


@pytest.mark.parametrize("arch", [
    "granite-3-2b", "rwkv6-7b", "jamba-1.5-large-398b", "gemma3-4b"])
def test_decode_matches_forward(arch):
    """Token-by-token decode with cache == full-sequence forward.

    MoE archs (jamba) only agree because the default eval-mode forward
    disables capacity dropping (capacity = n_tokens): the training drop
    decision depends on whole-batch whole-sequence token counts that
    token-by-token decode cannot (and at inference should not) see."""
    cfg = dataclasses.replace(get_config(arch).reduced(),
                              activation_dtype="float32")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(1))
    T = 12
    tokens = jnp.asarray(
        np.random.default_rng(0).integers(0, cfg.vocab, (2, T), np.int32))
    full_logits, _ = model.forward(params, {"tokens": tokens})

    cache = model.init_cache(2, 32, dtype=jnp.float32)
    outs = []
    for t in range(T):
        lg, cache = model.decode_step(params, cache, tokens[:, t:t + 1])
        outs.append(lg)
    dec_logits = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(dec_logits),
                               np.asarray(full_logits), rtol=2e-3, atol=2e-3)


def test_moe_router_balance_loss_positive():
    cfg = get_config("kimi-k2-1t-a32b").reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    batch = {k: jnp.asarray(v) for k, v in
             example_batch(cfg, "train", 2, 32).items()}
    _, aux = model.forward(params, batch)
    assert float(aux) > 0.0


def test_param_counts_match_configs():
    # full-config parameter counts should be in the family ballpark
    expect = {
        "kimi-k2-1t-a32b": (0.9e12, 1.2e12),
        "yi-34b": (30e9, 38e9),
        "granite-3-2b": (2.2e9, 2.9e9),
        "rwkv6-7b": (6e9, 8e9),
        "jamba-1.5-large-398b": (350e9, 440e9),
    }
    for arch, (lo, hi) in expect.items():
        n = get_config(arch).n_params()
        assert lo <= n <= hi, (arch, n)
