"""IR construction, validation, data-movement accounting."""
import numpy as np
import pytest

from repro.core import (Memlet, SDFG, StorageType, Subset, ValidationError,
                        sym)
from repro.frontends import blas
from repro.frontends.api import Program
from repro.transforms import DeviceOffload, StreamingComposition


def build_axpydot(n=256):
    p = Program("axpydot")
    a = p.scalar_input("a", "float32")
    x, y, w = (p.input(nm, (n,)) for nm in ("x", "y", "w"))
    z = blas.axpy(a, x, y)
    r = blas.dot(z, w)
    p.output("result", r)
    return p.finalize()


def test_validation_passes():
    build_axpydot().validate()


def test_unknown_container_rejected():
    sdfg = SDFG("bad")
    st_ = sdfg.add_state("s", is_start=True)
    t = st_.add_tasklet("t", [], ["o"], lambda: {"o": 0.0})
    acc = st_.add_access("ghost_not_added")  # container never declared
    st_.add_edge(t, "o", acc, None, Memlet.simple("ghost_not_added"))
    with pytest.raises(ValidationError):
        sdfg.validate()


def test_stream_volume_check():
    sdfg = SDFG("vol")
    sdfg.add_array("x", (8,), "float32")
    sdfg.add_array("y", (8,), "float32")
    sdfg.add_stream("s", "float32", element_shape=(8,))
    st_ = sdfg.add_state("s0", is_start=True)
    xin = st_.add_access("x")
    t1 = st_.add_tasklet("prod", ["i"], ["o"], lambda i: i)
    t2 = st_.add_tasklet("cons", ["i"], ["o"], lambda i: i)
    sin = st_.add_access("s")
    sout = st_.add_access("s")
    yout = st_.add_access("y")
    st_.add_edge(xin, None, t1, "i", Memlet.simple("x"))
    st_.add_edge(t1, "o", sin, None, Memlet.simple("s", volume=8))
    st_.add_edge(sout, None, t2, "i", Memlet.simple("s", volume=4))  # != 8
    st_.add_edge(t2, "o", yout, None, Memlet.simple("y"))
    with pytest.raises(ValidationError, match="Fig.-7"):
        sdfg.validate()


def test_off_chip_volume_accounting():
    n = 128
    sdfg = build_axpydot(n)
    sdfg.apply(DeviceOffload)
    naive = sdfg.off_chip_volume()
    # pre-copies 3n*4, kernel: x,y,w reads + z write + z read + result, post 4
    assert naive == 3 * n * 4 + (5 * n * 4 + 4) + 4
    sdfg2 = build_axpydot(n)
    sdfg2.apply(DeviceOffload)
    assert sdfg2.apply(StreamingComposition) == 1
    assert naive - sdfg2.off_chip_volume() == 2 * n * 4  # z round-trip gone


def test_processing_elements_detected():
    from repro.transforms import StreamingMemory
    sdfg = build_axpydot(64)
    sdfg.apply(DeviceOffload)
    sdfg.apply(StreamingComposition)
    sdfg.apply(StreamingMemory)
    main = [s for s in sdfg.states if s.label == "main"][0]
    # readers(x,y,w) + axpy + dot + writer(result) = 6 concurrent PEs
    assert len(main.processing_elements()) == 6


def test_symbolic_volume():
    n = sym("n")
    p = Program("sym")
    x = p.input("x", (n,))
    y = p.input("y", (n,))
    a = p.scalar_input("a")
    z = blas.axpy(a, x, y)
    p.output("z", z)
    sdfg = p.finalize()
    sdfg.apply(DeviceOffload)
    vol = sdfg.off_chip_volume(symbolic=True)
    assert vol.evaluate({"n": 100}) == sdfg.off_chip_volume(env={"n": 100})
