"""Property tests (optional hypothesis dependency): random elementwise
chains fuse completely and match plain composition; fusion legality is
exactly range-match + element-read + no-wcr; strided memlet writes land
on exactly the strided positions for every wcr mode."""
import numpy as np
import pytest

hypothesis = pytest.importorskip(
    "hypothesis", reason="property tests need the optional 'hypothesis' "
                         "dependency (pip install -e .[test])")
from hypothesis import given, settings, strategies as hst  # noqa: E402

import jax.numpy as jnp  # noqa: E402

from repro.codegen.common import write_memlet  # noqa: E402
from repro.core.memlet import Memlet, Range, Subset  # noqa: E402
from repro.core.sdfg import SDFG  # noqa: E402
from repro.core.symbolic import sym  # noqa: E402, F401  (chain builder)
from repro.pipeline import lower  # noqa: E402
from repro.transforms import MapFusion  # noqa: E402

from test_map_fusion import _pair_sdfg  # noqa: E402

_OPS = [lambda v, c=c: v * c for c in (2.0, -0.5)] + \
       [lambda v, c=c: v + c for c in (1.0, -3.0)]


@settings(max_examples=25, deadline=None)
@given(n=hst.sampled_from([4, 16, 33]),
       ops=hst.lists(hst.sampled_from(list(range(len(_OPS)))),
                     min_size=2, max_size=4),
       data=hst.integers(min_value=0, max_value=2 ** 31 - 1))
def test_fused_chain_matches_composition(n, ops, data):
    """Any elementwise producer->consumer chain fuses completely and both
    backends agree with the plain composed function."""
    s = SDFG("prop")
    s.add_array("x", (n,), "float32")
    s.add_array("out", (n,), "float32")
    st = s.add_state("main", is_start=True)
    i = sym("i")
    prev_name, prev_node = "x", None
    for k, op in enumerate(ops):
        last = k == len(ops) - 1
        dst = "out" if last else f"t{k}"
        if not last:
            s.add_transient(dst, (n,), "float32")
        kw = {} if prev_node is None else {"input_nodes":
                                           {prev_name: prev_node}}
        _, _, ex = st.add_mapped_tasklet(
            f"m{k}", {"i": (0, n)},
            inputs={"v": Memlet.simple(prev_name, Subset.indices([i]))},
            outputs={"w": Memlet.simple(dst, Subset.indices([i]))},
            fn=_OPS[op], **kw)
        prev_name = dst
        prev_node = next(e.dst for e in st.out_edges(ex)
                         if e.memlet.data == dst)
    assert s.apply(MapFusion) == len(ops) - 1
    x = np.random.default_rng(data).standard_normal(n).astype(np.float32)
    ref = x
    for op in ops:
        ref = _OPS[op](ref)
    oj = np.asarray(lower(s).compile("jnp", cache=None)(x=x)["out"])
    op_ = np.asarray(lower(s).compile("pallas", cache=None)(x=x)["out"])
    np.testing.assert_allclose(oj, ref, rtol=1e-5)
    np.testing.assert_allclose(op_, ref, rtol=1e-5)


@settings(max_examples=20, deadline=None)
@given(n=hst.sampled_from([8, 24]),
       pn=hst.sampled_from([8, 12, 24]),
       off=hst.sampled_from([0, 1]),
       wcr=hst.sampled_from([None, "add"]))
def test_fusion_legality_property(n, pn, off, wcr):
    """Fusion applies exactly when ranges match, the read is the written
    element, and no wcr touches the intermediate."""
    legal = (pn == n) and (off == 0) and (wcr is None)
    s = _pair_sdfg(n=n, cons_params={"j": (0, pn)}, offset=off, wcr=wcr)
    assert (s.apply(MapFusion) == 1) is legal


@settings(max_examples=40, deadline=None)
@given(n=hst.integers(min_value=4, max_value=40),
       start=hst.integers(min_value=0, max_value=6),
       step=hst.integers(min_value=1, max_value=4),
       wcr=hst.sampled_from([None, "add", "max", "min"]),
       seed=hst.integers(min_value=0, max_value=2 ** 31 - 1))
def test_strided_write_matches_numpy(n, start, step, wcr, seed):
    """write_memlet with a static strided subset behaves exactly like the
    equivalent numpy strided assignment / combine."""
    stop = min(n, start + 3 * step + 1)
    count = -(-(stop - start) // step)
    if count <= 0:
        return
    rng = np.random.default_rng(seed)
    base = rng.standard_normal(n).astype(np.float32)
    vals = rng.standard_normal(count).astype(np.float32)
    m = Memlet.simple("x", Subset([Range.make(start, stop, step)]), wcr=wcr)
    out = np.asarray(write_memlet(jnp.asarray(base), m,
                                  jnp.asarray(vals), {}))
    ref = base.copy()
    sl = slice(start, stop, step)
    if wcr == "add":
        ref[sl] += vals
    elif wcr == "max":
        ref[sl] = np.maximum(ref[sl], vals)
    elif wcr == "min":
        ref[sl] = np.minimum(ref[sl], vals)
    else:
        ref[sl] = vals
    np.testing.assert_allclose(out, ref, rtol=1e-6)


@settings(max_examples=20, deadline=None)
@given(n=hst.sampled_from([8, 33, 64]),
       prods=hst.lists(hst.tuples(
           hst.sampled_from(list(range(len(_OPS)))),   # producer body
           hst.sampled_from(list(range(len(_OPS))))),  # second stage
           min_size=1, max_size=3),
       seed=hst.integers(min_value=0, max_value=2 ** 31 - 1))
def test_random_multi_producer_dags_match_numpy(n, prods, seed):
    """Random multi-producer DAGs: k independent producer chains (k in
    1..3, each 1-2 maps deep) feeding ONE consumer that sums them. Every
    scope must fuse into a single map, and both backends must match the
    plain numpy composition."""
    from repro.core.sdfg import MapEntry  # noqa: E402
    k = len(prods)
    s = SDFG("dagprop")
    s.add_array("out", (n,), "float32")
    st = s.add_state("main", is_start=True)
    i = sym("i")
    feed_nodes, feed_names, total_maps = {}, [], 0
    rng = np.random.default_rng(seed)
    data = {}
    for pi, (op1, op2) in enumerate(prods):
        src = f"x{pi}"
        s.add_array(src, (n,), "float32")
        data[src] = rng.standard_normal(n).astype(np.float32)
        t1 = f"t{pi}_0"
        s.add_transient(t1, (n,), "float32")
        _, _, ex = st.add_mapped_tasklet(
            f"p{pi}a", {"i": (0, n)},
            inputs={"v": Memlet.simple(src, Subset.indices([i]))},
            outputs={"w": Memlet.simple(t1, Subset.indices([i]))},
            fn=_OPS[op1])
        node = next(e.dst for e in st.out_edges(ex) if e.memlet.data == t1)
        total_maps += 1
        t2 = f"t{pi}_1"
        s.add_transient(t2, (n,), "float32")
        _, _, ex2 = st.add_mapped_tasklet(
            f"p{pi}b", {"i": (0, n)},
            inputs={"v": Memlet.simple(t1, Subset.indices([i]))},
            outputs={"w": Memlet.simple(t2, Subset.indices([i]))},
            fn=_OPS[op2], input_nodes={t1: node})
        node = next(e.dst for e in st.out_edges(ex2) if e.memlet.data == t2)
        total_maps += 1
        feed_nodes[t2] = node
        feed_names.append(t2)
    st.add_mapped_tasklet(
        "consume", {"i": (0, n)},
        inputs={f"u{pi}": Memlet.simple(nm, Subset.indices([i]))
                for pi, nm in enumerate(feed_names)},
        outputs={"o": Memlet.simple("out", Subset.indices([i]))},
        fn=lambda **kw: sum(kw.values()),
        input_nodes=feed_nodes)
    total_maps += 1
    assert s.apply(MapFusion) == total_maps - 1   # everything collapses
    entries = [nd for st2 in s.states for nd in st2.nodes
               if isinstance(nd, MapEntry)]
    assert len(entries) == 1
    ref = np.zeros(n, dtype=np.float32)
    for pi, (op1, op2) in enumerate(prods):
        ref = ref + _OPS[op2](_OPS[op1](data[f"x{pi}"]))
    oj = np.asarray(lower(s).compile("jnp", cache=None)(**data)["out"])
    np.testing.assert_allclose(oj, ref, rtol=1e-4, atol=1e-5)
    op_ = np.asarray(lower(s).compile("pallas", cache=None)(**data)["out"])
    np.testing.assert_allclose(op_, ref, rtol=1e-4, atol=1e-5)
