"""Multi-dimensional MapTiling: property tests over random shapes and
tile sizes (including non-divisible remainders with masked partial
blocks), alignment-aware defaults from Vectorization's vector width, the
annotation-based idempotence contract, and grid acceptance checks that
gemver/stencil kernels compile with multi-dim lane/sublane blocks."""
import math

import numpy as np
import pytest

import repro.kernels  # noqa: F401
from repro.core.memlet import Memlet, Range, Subset
from repro.core.sdfg import SDFG
from repro.core.symbolic import sym
from repro.pipeline import (GridConversionPass, MapTilingPass, PassManager,
                            lower)
from repro.transforms import MapTiling, Vectorization
from repro.transforms.map_tiling import _choose_tile, normalize_tiling


def _ew2d_sdfg(n, m):
    """out[i, j] = 2*x[i, j] + y[j] — elementwise 2-D map with a
    broadcast second operand."""
    s = SDFG("ew2d")
    s.add_array("x", (n, m), "float32")
    s.add_array("y", (m,), "float32")
    s.add_array("out", (n, m), "float32")
    st = s.add_state("main", is_start=True)
    i, j = sym("i"), sym("j")
    st.add_mapped_tasklet(
        "ew", {"i": (0, n), "j": (0, m)},
        inputs={"a": Memlet.simple("x", Subset.indices([i, j])),
                "b": Memlet.simple("y", Subset.indices([j]))},
        outputs={"o": Memlet.simple("out", Subset.indices([i, j]))},
        fn=lambda a, b: 2.0 * a + b)
    return s


def _rowsum_sdfg(n, m):
    """out[i] += x[i, j] — wcr-add reduction over the minor dimension."""
    s = SDFG("rowsum")
    s.add_array("x", (n, m), "float32")
    s.add_array("out", (n,), "float32")
    st = s.add_state("main", is_start=True)
    i, j = sym("i"), sym("j")
    st.add_mapped_tasklet(
        "rowsum", {"i": (0, n), "j": (0, m)},
        inputs={"a": Memlet.simple("x", Subset.indices([i, j]))},
        outputs={"o": Memlet.simple("out", Subset.indices([i]), wcr="add")},
        fn=lambda a: a)
    return s


def _tile_pipeline(tile_sizes):
    return PassManager([MapTilingPass(tile_sizes=tile_sizes),
                        GridConversionPass(min_grid_steps=1)],
                       name="explicit_tiles")


# ---------------------------------------------------------------------------
# explicit multi-dim tiling: both backends match numpy for every
# (shape, tile) combination, divisible or not
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n,m,ti,tj", [
    (16, 32, 8, 16),     # exact tiles both dims
    (18, 22, 8, 16),     # partial final tiles both dims
    (7, 5, 3, 2),        # small with remainders
    (13, 17, 4, 17),     # prime extents, whole-dim minor tile
    (12, 9, 5, 4),       # remainder i, remainder j
])
def test_multidim_tiling_matches_numpy(n, m, ti, tj):
    rng = np.random.default_rng(n * 100 + m)
    x = rng.standard_normal((n, m)).astype(np.float32)
    y = rng.standard_normal(m).astype(np.float32)
    pm = _tile_pipeline({"i": ti, "j": tj})
    cp = lower(_ew2d_sdfg(n, m)).compile("pallas", pipeline=pm, cache=None)
    assert cp.report["grid_kernels"] == ["ew_tiled"]
    op = np.asarray(cp(x=x, y=y)["out"])
    np.testing.assert_allclose(op, 2 * x + y, rtol=1e-6)
    # jnp mirrors the generalized (masked) tiling on the same tiled graph
    s = _ew2d_sdfg(n, m)
    s.apply(MapTiling, tile_sizes={"i": ti, "j": tj})
    oj = np.asarray(lower(s).compile("jnp", cache=None)(x=x, y=y)["out"])
    np.testing.assert_allclose(oj, 2 * x + y, rtol=1e-6)


@pytest.mark.parametrize("n,m,ti,tj", [
    (16, 24, 8, 8),      # exact
    (10, 23, 4, 8),      # partial minor tile: masked reduce lanes
    (9, 7, 4, 3),        # partial both
])
def test_multidim_tiling_wcr_reduction_matches_numpy(n, m, ti, tj):
    """Partial minor tiles must mask padding lanes to the wcr identity
    before the intra-block reduction — a garbage lane would corrupt the
    row sums."""
    rng = np.random.default_rng(n * 7 + m)
    x = rng.standard_normal((n, m)).astype(np.float32)
    pm = _tile_pipeline({"i": ti, "j": tj})
    cp = lower(_rowsum_sdfg(n, m)).compile("pallas", pipeline=pm, cache=None)
    assert cp.report["grid_kernels"] == ["rowsum_tiled"]
    op = np.asarray(cp(x=x)["out"])
    np.testing.assert_allclose(op, x.sum(axis=1), rtol=1e-4, atol=1e-5)
    s = _rowsum_sdfg(n, m)
    s.apply(MapTiling, tile_sizes={"i": ti, "j": tj})
    oj = np.asarray(lower(s).compile("jnp", cache=None)(x=x)["out"])
    np.testing.assert_allclose(oj, x.sum(axis=1), rtol=1e-4, atol=1e-5)


# ---------------------------------------------------------------------------
# alignment-aware defaults + annotation contract
# ---------------------------------------------------------------------------

def test_choose_tile_prefers_divisors_then_masks():
    assert _choose_tile(256, 128) == 128          # lane-aligned
    assert _choose_tile(96, 128) == 96            # whole dim in one block
    assert _choose_tile(192, 128) == 96           # largest divisor in range
    assert _choose_tile(131, 128) == 128          # prime: ceil + mask
    assert _choose_tile(1, 128) is None


def test_default_tiles_follow_vector_width():
    """vector_width recorded by Vectorization flows into MapTiling's
    minor-dim default; the second dim tiles to sublanes."""
    n, m = 64, 512
    s = _ew2d_sdfg(n, m)
    s.apply(Vectorization, width=128)
    assert s.metadata["vector_width"] == 128
    assert s.apply(MapTiling) == 1
    entry = next(nd for st in s.states for nd in st.nodes
                 if hasattr(nd, "map") and nd.map.label == "ew_tiled")
    tiling = normalize_tiling(entry.map.annotations["tiling"])
    assert tiling["j_in"]["tile"] == 128          # minor -> lanes
    assert tiling["i_in"]["tile"] == 8            # second -> sublanes
    assert entry.map.params == ["i_tile", "i_in", "j_tile", "j_in"]
    assert tiling["j_in"]["blocks"] == math.ceil(m / 128)


def test_annotation_idempotence_not_label():
    """Re-applying MapTiling must be a no-op because of the *annotations*,
    even when the label suffix is stripped — the `_tiled` label hack is
    gone."""
    s = _ew2d_sdfg(64, 256)
    assert s.apply(MapTiling) == 1
    entry = next(nd for st in s.states for nd in st.nodes
                 if hasattr(nd, "map") and "ew" in nd.map.label)
    entry.map.label = "ew"                        # strip the cosmetic suffix
    assert s.apply(MapTiling) == 0                # annotations block re-tiling


def test_per_dimension_retiling_composes():
    """Tiling one dimension explicitly, then letting a second MapTiling
    pick up the remaining dimension, must compose (and stay correct)."""
    n, m = 24, 256
    s = _ew2d_sdfg(n, m)
    assert s.apply(MapTiling, tile_sizes={"j": 128}) == 1
    assert s.apply(MapTiling, tile_sizes={"i": 8}) == 1
    entry = next(nd for st in s.states for nd in st.nodes
                 if hasattr(nd, "map") and "ew" in nd.map.label)
    tiling = normalize_tiling(entry.map.annotations["tiling"])
    assert {q: t["tile"] for q, t in tiling.items()} == {"j_in": 128,
                                                         "i_in": 8}
    rng = np.random.default_rng(3)
    x = rng.standard_normal((n, m)).astype(np.float32)
    y = rng.standard_normal(m).astype(np.float32)
    cp = lower(s).compile("pallas", cache=None)
    np.testing.assert_allclose(np.asarray(cp(x=x, y=y)["out"]), 2 * x + y,
                               rtol=1e-6)


def test_partial_tile_plain_output_falls_back():
    """A partial tile whose intra param is ABSENT from a plain (non-wcr)
    output cannot pick a deterministic last write from the padding lanes:
    the scope must be left to the structural interpreter."""
    n = 10
    s = SDFG("lastwrite")
    s.add_array("x", (n,), "float32")
    s.add_array("out", (1,), "float32")
    st = s.add_state("main", is_start=True)
    st.add_mapped_tasklet(
        "lw", {"i": (0, n)},
        inputs={"v": Memlet.simple("x", Subset.indices([sym("i")]))},
        outputs={"o": Memlet.simple("out", Subset.indices([0]))},
        fn=lambda v: v)
    s.apply(MapTiling, tile_sizes={"i": 4})      # 10 = 2*4 + 2: partial
    cp = lower(s).compile("pallas", cache=None)
    assert cp.report["grid_kernels"] == []
    assert any("partial tile" in reason
               for _, reason in cp.report["grid_fallbacks"])


def test_whole_block_probe_rejects_reduction_shaped_bodies():
    """A tasklet like ``lambda a: jnp.sum(a)`` is the identity under
    per-element semantics but a reduction on whole blocks — and its
    scalar result still broadcasts to the tile shape, so a shape trace
    alone cannot reject it. The concrete probe must route it to the
    per-element vmap path and keep results correct."""
    import jax.numpy as jnp
    n, m = 16, 256
    s = SDFG("sneaky")
    s.add_array("x", (n, m), "float32")
    s.add_array("out", (n, m), "float32")
    st = s.add_state("main", is_start=True)
    i, j = sym("i"), sym("j")
    st.add_mapped_tasklet(
        "sneaky", {"i": (0, n), "j": (0, m)},
        inputs={"a": Memlet.simple("x", Subset.indices([i, j]))},
        outputs={"o": Memlet.simple("out", Subset.indices([i, j]))},
        fn=lambda a: jnp.sum(a))
    x = np.random.default_rng(5).standard_normal((n, m)).astype(np.float32)
    cp = lower(s).compile("pallas", cache=None)
    assert cp.report["grid_kernels"] == ["sneaky_tiled"]
    np.testing.assert_allclose(np.asarray(cp(x=x)["out"]), x, rtol=1e-6)


def test_default_policy_plans_each_map_once():
    """The apply_everywhere fixpoint must not whole-tile params the
    default policy deliberately left untiled (outer/batch dims, second
    dims <= sublanes) in a later round."""
    n, b = 64, 32
    s = SDFG("batch3d")
    s.add_array("x", (b, n, 512), "float32")
    s.add_array("out", (b, n, 512), "float32")
    st = s.add_state("main", is_start=True)
    bb, i, j = sym("b"), sym("i"), sym("j")
    st.add_mapped_tasklet(
        "b3", {"b": (0, b), "i": (0, n), "j": (0, 512)},
        inputs={"a": Memlet.simple("x", Subset.indices([bb, i, j]))},
        outputs={"o": Memlet.simple("out", Subset.indices([bb, i, j]))},
        fn=lambda a: a + 1.0)
    assert s.apply(MapTiling) == 1                # one planning round only
    entry = next(nd for st2 in s.states for nd in st2.nodes
                 if hasattr(nd, "map") and "b3" in nd.map.label)
    tiling = normalize_tiling(entry.map.annotations["tiling"])
    assert set(tiling) == {"i_in", "j_in"}        # b stays a grid dim
    assert "b" in entry.map.params and "b_in" not in entry.map.params


# ---------------------------------------------------------------------------
# acceptance: paper benchmarks get lane/sublane blocks
# ---------------------------------------------------------------------------

def test_gemver_grid_blocks_are_multidim():
    from test_pallas_grid import _build_gemver
    cp = lower(_build_gemver(128)).compile("pallas",
                                           expansion_level="generic")
    fused = next(c for c in cp.report["grid_converted"]
                 if c["map"].startswith("ger0_map+ger1_map"))
    # CPU-interpret calibrated defaults: 32-sublane x 64-lane blocks
    assert fused["block_shape"] == [32, 64]
    assert fused["block_shape"][-1] >= 8
    assert fused["bytes_per_step"] > 0


def test_stencil_grid_blocks_are_multidim():
    from benchmarks.stencil_bench import _star_sdfg
    cp = lower(_star_sdfg(130, 130)).compile("pallas")
    assert cp.report["grid_kernels"] == ["star_tiled"]
    (conv,) = cp.report["grid_converted"]
    assert conv["block_shape"] == [32, 64]        # calibrated defaults
    assert conv["block_shape"][-1] >= 8


def test_grid_decisions_recorded():
    """The vmap-vs-grid decision inputs land in Compiled.report for
    calibration: every analyzed scope gets a decision entry with the
    cost-model inputs."""
    cp = lower(_ew2d_sdfg(64, 256)).compile("pallas", cache=None)
    (dec,) = cp.report["grid_decisions"]
    assert dec["decision"] == "grid" and dec["reason"] is None
    assert dec["block_shape"] == [32, 64]         # calibrated defaults
    assert dec["grid_steps"] == 8   # (64/32) x (256/64)
    assert dec["vmem_bytes"] > 0 and dec["bytes_per_step"] > 0


def test_sublane_default_is_dtype_aware():
    """The second-dim tile default follows the container dtype's packing:
    fp32 -> 8 sublanes, bf16 -> 16, int8 -> 32 (pallas guide tiling
    table). The narrowest container accessed by the scope decides."""
    from repro.core.dtypes import sublanes_for
    assert sublanes_for("float32") == 8
    assert sublanes_for("bfloat16") == 16
    assert sublanes_for("float16") == 16
    assert sublanes_for("int8") == 32
    assert sublanes_for("float64") == 8

    def ew(dtype):
        n, m = 64, 512
        s = SDFG("ewdt")
        s.add_array("x", (n, m), dtype)
        s.add_array("out", (n, m), dtype)
        st = s.add_state("main", is_start=True)
        i, j = sym("i"), sym("j")
        st.add_mapped_tasklet(
            "ew", {"i": (0, n), "j": (0, m)},
            inputs={"a": Memlet.simple("x", Subset.indices([i, j]))},
            outputs={"o": Memlet.simple("out", Subset.indices([i, j]))},
            fn=lambda a: a + a)
        return s

    for dtype, sub in (("float32", 8), ("bfloat16", 16), ("int8", 32)):
        s = ew(dtype)
        assert s.apply(MapTiling) == 1
        entry = next(nd for st in s.states for nd in st.nodes
                     if hasattr(nd, "map") and "ew" in nd.map.label)
        tiling = normalize_tiling(entry.map.annotations["tiling"])
        assert tiling["i_in"]["tile"] == sub, (dtype, tiling)
        assert tiling["j_in"]["tile"] == 128


def test_vectorization_records_sublane_width():
    """Vectorization records the dtype-aware sublane width alongside the
    lane width, for scopes whose own containers can't pin one."""
    from repro.core.dtypes import DType
    s = _ew2d_sdfg(64, 256)
    s.arrays["x"].dtype = DType("bfloat16")
    s.apply(Vectorization, width=128)
    assert s.metadata["vector_width"] == 128
    assert s.metadata["sublane_width"] == 16   # narrowest container: bf16


def test_calibrated_tile_table_feeds_default_pipeline():
    """The committed-calibration tile table is consulted by the default
    pallas pipeline (interpret mode); real hardware keeps the static
    alignment defaults."""
    assert GridConversionPass.default_tiles("pallas", True) == {
        "minor": 64, "second": 32}
    assert GridConversionPass.default_tiles("pallas", False) == {}
    from repro.pipeline.passes import default_pipeline
    pm = default_pipeline("pallas", interpret=True)
    tiling_pass = next(p for p in pm if p.name == "MapTiling")
    assert tiling_pass.kwargs["tile_size"] == 64
    assert tiling_pass.kwargs["second_size"] == 32
    pm2 = default_pipeline("pallas", interpret=False)
    tiling_pass2 = next(p for p in pm2 if p.name == "MapTiling")
    assert tiling_pass2.kwargs["tile_size"] is None
