"""Symbolic engine unit + property tests."""
import pytest

hypothesis = pytest.importorskip(
    "hypothesis", reason="property tests need the optional 'hypothesis' "
    "package (pip install repro[test])")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core.symbolic import Expr, evaluate, prod, sym  # noqa: E402


def test_basic_algebra():
    n, m = sym("n"), sym("m")
    e = (n + 1) * m - m
    assert e == n * m
    assert (n * m / m) == n
    assert evaluate(n * m + 2, {"n": 3, "m": 4}) == 14


def test_division_exact():
    n = sym("n")
    assert (n * 4) / 2 == n * 2
    # rational monomials (paper Fig. 7: K*M*N/P) evaluate exactly
    assert (sym("n") / sym("m")).evaluate({"n": 12, "m": 4}) == 3


def test_subs():
    n, p = sym("n"), sym("p")
    e = n * n / p
    assert e.subs({"n": 6, "p": 4}).as_const() == 9


small_ints = st.integers(min_value=-20, max_value=20)


@given(a=small_ints, b=small_ints, c=small_ints)
@settings(max_examples=100, deadline=None)
def test_poly_eval_matches_python(a, b, c):
    n, m = sym("n"), sym("m")
    e = a * n * n + b * n * m + c
    for nv in (0, 1, 3):
        for mv in (1, 2):
            assert e.evaluate({"n": nv, "m": mv}) == a * nv * nv + b * nv * mv + c


@given(xs=st.lists(small_ints.filter(lambda v: v != 0), min_size=1,
                   max_size=5))
@settings(max_examples=50, deadline=None)
def test_prod_matches(xs):
    import math
    assert prod(xs).as_int() == math.prod(xs)


def test_canonical_equality_for_access_orders():
    i, j = sym("i"), sym("j")
    assert (i * 4 + j) == (j + i * 4)
    assert hash(i * 4 + j) == hash(j + 4 * i)
