"""MapFusion: legality (refusals), semantics (fused == unfused), the
off-chip-volume payoff, and the acceptance path — a producer->consumer
map pair compiling to ONE Pallas grid kernel with the intermediate held
in-kernel."""
import numpy as np
import pytest

import repro.kernels  # noqa: F401  (registers fusions)
from repro.core.dtypes import StorageType
from repro.core.memlet import Memlet, Subset
from repro.core.sdfg import SDFG, MapEntry
from repro.core.symbolic import sym
from repro.frontends import blas
from repro.frontends.api import Program
from repro.pipeline import (ExpandLibraryNodesPass, GridConversionPass,
                            MapFusionPass, MapTilingPass, PassManager,
                            SetExpansionPreferencePass, lower)
from repro.transforms import DeviceOffload, MapFusion


def _pair_sdfg(n=64, cons_params=None, wcr=None, offset=0,
               extra_reader=False):
    """producer map writing transient t elementwise; consumer map reading
    it back. Knobs inject each illegality the transform must refuse."""
    s = SDFG("pair")
    s.add_array("x", (n,), "float32")
    s.add_array("out", (n,), "float32")
    s.add_transient("t", (n,), "float32")
    st = s.add_state("main", is_start=True)
    i = sym("i")
    _, _, ex1 = st.add_mapped_tasklet(
        "prod", {"i": (0, n)},
        inputs={"v": Memlet.simple("x", Subset.indices([i]))},
        outputs={"w": Memlet.simple("t", Subset.indices([i]), wcr=wcr)},
        fn=lambda v: v + 1.0)
    t_node = next(e.dst for e in st.out_edges(ex1) if e.memlet.data == "t")
    params = cons_params or {"i": (0, n)}
    cp = sym(next(iter(params)))
    st.add_mapped_tasklet(
        "cons", params,
        inputs={"u": Memlet.simple("t", Subset.indices([cp + offset]))},
        outputs={"o": Memlet.simple("out", Subset.indices([cp]))},
        fn=lambda u: u * 2.0,
        input_nodes={"t": t_node})
    if extra_reader:
        s.add_array("out2", (n,), "float32")
        st.add_mapped_tasklet(
            "cons2", {"k": (0, n)},
            inputs={"u": Memlet.simple("t", Subset.indices([sym("k")]))},
            outputs={"o": Memlet.simple("out2", Subset.indices([sym("k")]))},
            fn=lambda u: u - 1.0,
            input_nodes={"t": t_node})
    return s


# ---------------------------------------------------------------------------
# legality: each violation refuses to fuse
# ---------------------------------------------------------------------------

def test_fusion_applies_on_matching_pair():
    s = _pair_sdfg()
    assert s.apply(MapFusion) == 1
    labels = [n.map.label for st in s.states for n in st.nodes
              if isinstance(n, MapEntry)]
    assert labels == ["prod+cons"]
    assert s.arrays["t"].storage is StorageType.REG


def test_fusion_refuses_non_matching_ranges():
    assert _pair_sdfg(cons_params={"j": (0, 32)}).apply(MapFusion) == 0
    assert _pair_sdfg(cons_params={"j": (1, 64)}).apply(MapFusion) == 0


def test_fusion_refuses_multi_reader_intermediate():
    assert _pair_sdfg(extra_reader=True).apply(MapFusion) == 0


def test_fusion_refuses_wcr_intermediate():
    assert _pair_sdfg(wcr="add").apply(MapFusion) == 0


def test_fusion_refuses_offset_reads():
    # stencil-style halo read: consumer wants t[i+1], producer wrote t[i]
    assert _pair_sdfg(n=8, offset=1).apply(MapFusion) == 0


def test_fusion_refuses_broadcast_intermediate_write():
    """A write subset that ignores a map parameter is a revisited
    location (last write wins); fusing would hand the consumer the
    per-iteration value instead of the final one."""
    n = 8
    s = SDFG("bcast")
    s.add_array("x", (n, n), "float32")
    s.add_array("out", (n, n), "float32")
    s.add_transient("t", (n,), "float32")
    st = s.add_state("main", is_start=True)
    i, j = sym("i"), sym("j")
    _, _, ex1 = st.add_mapped_tasklet(
        "prod", {"i": (0, n), "j": (0, n)},
        inputs={"v": Memlet.simple("x", Subset.indices([i, j]))},
        outputs={"w": Memlet.simple("t", Subset.indices([i]))},  # no j!
        fn=lambda v: v + 1.0)
    t_node = next(e.dst for e in st.out_edges(ex1) if e.memlet.data == "t")
    st.add_mapped_tasklet(
        "cons", {"i": (0, n), "j": (0, n)},
        inputs={"u": Memlet.simple("t", Subset.indices([i]))},
        outputs={"o": Memlet.simple("out", Subset.indices([i, j]))},
        fn=lambda u: u * 2.0, input_nodes={"t": t_node})
    assert s.apply(MapFusion) == 0


def test_fusion_refuses_non_injective_index_writes():
    """t[i+j] collides across iterations (iterations (0,1) and (1,0) hit
    the same element): last write wins sequentially, so fusing would
    change the values the consumer sees. Must refuse."""
    n = 4
    s = SDFG("collide")
    s.add_array("x", (n, n), "float32")
    s.add_array("out", (n, n), "float32")
    s.add_transient("t", (2 * n,), "float32")
    st = s.add_state("main", is_start=True)
    i, j = sym("i"), sym("j")
    _, _, ex1 = st.add_mapped_tasklet(
        "prod", {"i": (0, n), "j": (0, n)},
        inputs={"v": Memlet.simple("x", Subset.indices([i, j]))},
        outputs={"w": Memlet.simple("t", Subset.indices([i + j]))},
        fn=lambda v: v * 2.0)
    t_node = next(e.dst for e in st.out_edges(ex1) if e.memlet.data == "t")
    st.add_mapped_tasklet(
        "cons", {"i": (0, n), "j": (0, n)},
        inputs={"u": Memlet.simple("t", Subset.indices([i + j]))},
        outputs={"o": Memlet.simple("out", Subset.indices([i, j]))},
        fn=lambda u: u + 1.0, input_nodes={"t": t_node})
    assert s.apply(MapFusion) == 0


def test_fusion_refuses_overlapping_slice_writes():
    """A param-dependent slice write (t[i:i+2]) overlaps its neighbor
    iterations: sequentially, iteration i+1 overwrites t[i+1] before the
    consumer reads it, so fusing would hand the consumer iteration i's
    private value. Must refuse — and the unfused program must keep the
    last-write-wins answer."""
    import jax.numpy as jnp
    from repro.core.memlet import Range
    n = 6
    s = SDFG("overlap")
    s.add_array("x", (n,), "float32")
    s.add_array("out", (n - 1, 2), "float32")
    s.add_transient("t", (n,), "float32")
    st = s.add_state("main", is_start=True)
    i = sym("i")
    _, _, ex1 = st.add_mapped_tasklet(
        "prod", {"i": (0, n - 1)},
        inputs={"v": Memlet.simple("x", Subset.indices([i]))},
        outputs={"w": Memlet.simple("t", Subset([Range.make(i, i + 2)]))},
        fn=lambda v: jnp.stack([v, -v]))
    t_node = next(e.dst for e in st.out_edges(ex1) if e.memlet.data == "t")
    st.add_mapped_tasklet(
        "cons", {"i": (0, n - 1)},
        inputs={"u": Memlet.simple("t", Subset([Range.make(i, i + 2)]))},
        outputs={"o": Memlet.simple("out",
                                    Subset([Range.index(i),
                                            Range.make(0, 2)]))},
        fn=lambda u: u, input_nodes={"t": t_node})
    assert s.apply(MapFusion) == 0
    x = np.arange(1, n + 1, dtype=np.float32)
    out = np.asarray(lower(s).compile("jnp", cache=None)(x=x)["out"])
    # sequential semantics: row i = (x[i], x[i+1]) except the last row,
    # whose second element keeps the final iteration's -x write
    ref = np.stack([x[:-1], np.concatenate([x[1:-1], [-x[-2]]])], axis=1)
    np.testing.assert_allclose(out, ref, rtol=1e-6)


def test_fusion_refuses_transitive_dependency():
    """Consumer input reachable from the producer through a THIRD map:
    fusing would wire that input into the fused entry and create a
    cycle (prod -> middle -> fused -> prod)."""
    n = 8
    s = SDFG("transitive")
    s.add_array("x", (n,), "float32")
    s.add_array("out", (n,), "float32")
    for nm in ("t", "X", "Y"):
        s.add_transient(nm, (n,), "float32")
    st = s.add_state("main", is_start=True)
    i = sym("i")
    # producer: writes both t and X
    _, _, px = st.add_mapped_tasklet(
        "prod", {"i": (0, n)},
        inputs={"v": Memlet.simple("x", Subset.indices([i]))},
        outputs={"t": Memlet.simple("t", Subset.indices([i])),
                 "X": Memlet.simple("X", Subset.indices([i]))},
        fn=lambda v: {"t": v + 1.0, "X": v * 2.0})
    t_node = next(e.dst for e in st.out_edges(px) if e.memlet.data == "t")
    x_node = next(e.dst for e in st.out_edges(px) if e.memlet.data == "X")
    # middle: X -> Y
    _, _, mx = st.add_mapped_tasklet(
        "middle", {"i": (0, n)},
        inputs={"v": Memlet.simple("X", Subset.indices([i]))},
        outputs={"w": Memlet.simple("Y", Subset.indices([i]))},
        fn=lambda v: v - 3.0, input_nodes={"X": x_node})
    y_node = next(e.dst for e in st.out_edges(mx) if e.memlet.data == "Y")
    # consumer: reads t AND Y
    st.add_mapped_tasklet(
        "cons", {"i": (0, n)},
        inputs={"u": Memlet.simple("t", Subset.indices([i])),
                "y": Memlet.simple("Y", Subset.indices([i]))},
        outputs={"o": Memlet.simple("out", Subset.indices([i]))},
        fn=lambda u, y: u + y, input_nodes={"t": t_node, "Y": y_node})
    # fusing prod+cons through t must refuse: cons also depends on prod
    # via X -> middle -> Y, and rerouting Y into the fused entry cycles
    mf = MapFusion()
    match_t = next(m for m in mf.find_matches(s) if m["node"].data == "t")
    assert not mf.can_apply(s, match_t)
    # whatever legal fusions remain (prod+middle through X is fine) must
    # leave an acyclic graph that still computes the right answer
    s.apply(MapFusion)
    s.validate()
    x = np.random.default_rng(4).standard_normal(n).astype(np.float32)
    out = np.asarray(lower(s).compile("jnp", cache=None)(x=x)["out"])
    np.testing.assert_allclose(out, (x + 1) + (x * 2 - 3), rtol=1e-5)


def test_fusion_renames_consumer_params():
    s = _pair_sdfg(cons_params={"j": (0, 64)})
    assert s.apply(MapFusion) == 1
    x = np.random.default_rng(0).standard_normal(64).astype(np.float32)
    out = np.asarray(lower(s).compile("jnp", cache=None)(x=x)["out"])
    np.testing.assert_allclose(out, (x + 1) * 2, rtol=1e-6)


# ---------------------------------------------------------------------------
# semantics + the paper metric
# ---------------------------------------------------------------------------

def test_fusion_preserves_semantics_and_drops_volume():
    rng = np.random.default_rng(1)
    x = rng.standard_normal(64).astype(np.float32)
    plain, fused = _pair_sdfg(), _pair_sdfg()
    plain.apply(DeviceOffload)
    fused.apply(DeviceOffload)
    v_before = fused.off_chip_volume()
    assert fused.apply(MapFusion) == 1
    v_after = fused.off_chip_volume()
    # the t round-trip (write + read, 2n elements) leaves the metric
    assert v_before - v_after == 2 * 64 * 4
    o_plain = np.asarray(lower(plain).compile("jnp", cache=None)(x=x)["out"])
    o_fused = np.asarray(lower(fused).compile("jnp", cache=None)(x=x)["out"])
    np.testing.assert_allclose(o_fused, o_plain, rtol=1e-6)
    o_grid = np.asarray(lower(fused).compile("pallas", cache=None)(x=x)["out"])
    np.testing.assert_allclose(o_grid, o_plain, rtol=1e-6)


def _accumulate_pipeline(fused=True, tile=128):
    passes = [SetExpansionPreferencePass(("accumulate", "generic")),
              ExpandLibraryNodesPass()]
    if fused:
        passes.append(MapFusionPass())
    passes += [MapTilingPass(tile_size=tile), GridConversionPass()]
    return PassManager(passes, name="acc_fused" if fused else "acc_unfused")


def _build_axpydot(n):
    p = Program("axpydot")
    a = p.scalar_input("a", "float32")
    x, y, w = (p.input(nm, (n,)) for nm in ("x", "y", "w"))
    p.output("result", blas.dot(blas.axpy(a, x, y), w))
    return p.finalize()


def test_axpydot_chain_fuses_to_one_grid_kernel():
    """Acceptance: the axpy->dot chain compiles to ONE grid kernel with
    the axpy intermediate held in-kernel; jnp-vs-pallas within 1e-4."""
    n = 2048
    rng = np.random.default_rng(2)
    a = np.float32(0.7)
    x, y, w = (rng.standard_normal(n).astype(np.float32) for _ in range(3))
    cp = lower(_build_axpydot(n)).compile(
        "pallas", pipeline=_accumulate_pipeline(fused=True), cache=None)
    assert cp.report["grid_kernels"] == ["axpy0_map+dot0_acc_tiled"]
    assert len(cp.report["grid_kernels"]) == 1
    assert cp.report["grid_converted"][0]["tasklets"] == 2
    cu = lower(_build_axpydot(n)).compile(
        "pallas", pipeline=_accumulate_pipeline(fused=False), cache=None)
    assert len(cu.report["grid_kernels"]) == 2  # the unfused pair
    cj = lower(_build_axpydot(n)).compile("jnp", cache=None)
    rp = float(np.asarray(cp(a=a, x=x, y=y, w=w)["result"]).ravel()[0])
    ru = float(np.asarray(cu(a=a, x=x, y=y, w=w)["result"]).ravel()[0])
    rj = float(np.asarray(cj(a=a, x=x, y=y, w=w)["result"]).ravel()[0])
    np.testing.assert_allclose(rp, rj, rtol=1e-4)
    np.testing.assert_allclose(ru, rj, rtol=1e-4)


def test_fusion_cascades_over_elementwise_chain():
    """Three elementwise maps collapse into one scope (fixpoint), and the
    fused scope grid-compiles."""
    n = 256
    s = SDFG("chain3")
    s.add_array("x", (n,), "float32")
    s.add_array("out", (n,), "float32")
    s.add_transient("t1", (n,), "float32")
    s.add_transient("t2", (n,), "float32")
    st = s.add_state("main", is_start=True)
    i = sym("i")
    _, _, e1 = st.add_mapped_tasklet(
        "m1", {"i": (0, n)},
        inputs={"v": Memlet.simple("x", Subset.indices([i]))},
        outputs={"w": Memlet.simple("t1", Subset.indices([i]))},
        fn=lambda v: v * 2.0)
    t1n = next(e.dst for e in st.out_edges(e1) if e.memlet.data == "t1")
    _, _, e2 = st.add_mapped_tasklet(
        "m2", {"i": (0, n)},
        inputs={"v": Memlet.simple("t1", Subset.indices([i]))},
        outputs={"w": Memlet.simple("t2", Subset.indices([i]))},
        fn=lambda v: v + 3.0, input_nodes={"t1": t1n})
    t2n = next(e.dst for e in st.out_edges(e2) if e.memlet.data == "t2")
    st.add_mapped_tasklet(
        "m3", {"i": (0, n)},
        inputs={"v": Memlet.simple("t2", Subset.indices([i]))},
        outputs={"w": Memlet.simple("out", Subset.indices([i]))},
        fn=lambda v: v * v, input_nodes={"t2": t2n})
    assert s.apply(MapFusion) == 2
    entries = [nd for nd in s.states[0].nodes if isinstance(nd, MapEntry)]
    assert len(entries) == 1
    x = np.random.default_rng(3).standard_normal(n).astype(np.float32)
    c = lower(s).compile("pallas", cache=None)
    assert len(c.report["grid_kernels"]) == 1
    np.testing.assert_allclose(np.asarray(c(x=x)["out"]),
                               (x * 2 + 3) ** 2, rtol=1e-5)
