"""MapFusion: legality (refusals), semantics (fused == unfused), the
off-chip-volume payoff, and the acceptance paths — producer DAGs
(multi-producer, multi-intermediate, scalar intermediates,
fuse-across-tiling) compiling to ONE Pallas grid kernel with every
intermediate held in-kernel."""
import numpy as np
import pytest

import repro.kernels  # noqa: F401  (registers fusions)
from repro.core.dtypes import StorageType
from repro.core.memlet import Memlet, Subset
from repro.core.sdfg import SDFG, MapEntry
from repro.core.symbolic import sym
from repro.frontends import blas
from repro.frontends.api import Program
from repro.pipeline import (ExpandLibraryNodesPass, GridConversionPass,
                            MapFusionPass, MapTilingPass, PassManager,
                            SetExpansionPreferencePass, lower)
from repro.transforms import DeviceOffload, MapFusion, MapTiling


def _pair_sdfg(n=64, cons_params=None, wcr=None, offset=0,
               extra_reader=False):
    """producer map writing transient t elementwise; consumer map reading
    it back. Knobs inject each illegality the transform must refuse."""
    s = SDFG("pair")
    s.add_array("x", (n,), "float32")
    s.add_array("out", (n,), "float32")
    s.add_transient("t", (n,), "float32")
    st = s.add_state("main", is_start=True)
    i = sym("i")
    _, _, ex1 = st.add_mapped_tasklet(
        "prod", {"i": (0, n)},
        inputs={"v": Memlet.simple("x", Subset.indices([i]))},
        outputs={"w": Memlet.simple("t", Subset.indices([i]), wcr=wcr)},
        fn=lambda v: v + 1.0)
    t_node = next(e.dst for e in st.out_edges(ex1) if e.memlet.data == "t")
    params = cons_params or {"i": (0, n)}
    cp = sym(next(iter(params)))
    st.add_mapped_tasklet(
        "cons", params,
        inputs={"u": Memlet.simple("t", Subset.indices([cp + offset]))},
        outputs={"o": Memlet.simple("out", Subset.indices([cp]))},
        fn=lambda u: u * 2.0,
        input_nodes={"t": t_node})
    if extra_reader:
        s.add_array("out2", (n,), "float32")
        st.add_mapped_tasklet(
            "cons2", {"k": (0, n)},
            inputs={"u": Memlet.simple("t", Subset.indices([sym("k")]))},
            outputs={"o": Memlet.simple("out2", Subset.indices([sym("k")]))},
            fn=lambda u: u - 1.0,
            input_nodes={"t": t_node})
    return s


# ---------------------------------------------------------------------------
# legality: each violation refuses to fuse
# ---------------------------------------------------------------------------

def test_fusion_applies_on_matching_pair():
    s = _pair_sdfg()
    assert s.apply(MapFusion) == 1
    labels = [n.map.label for st in s.states for n in st.nodes
              if isinstance(n, MapEntry)]
    assert labels == ["prod+cons"]
    assert s.arrays["t"].storage is StorageType.REG


def test_fusion_subset_ranges_fuse_via_sigma():
    """A consumer iterating a SUBSET of the producer's box fuses through
    the write-order = read-order rule: sigma maps the consumer's box into
    the producer's, and producer iterations outside the image are dead
    once the intermediate loses its last reader."""
    for params in ({"j": (0, 32)}, {"j": (1, 64)}):
        s = _pair_sdfg(cons_params=dict(params))
        assert s.apply(MapFusion) == 1
        x = np.random.default_rng(21).standard_normal(64).astype(np.float32)
        out = np.asarray(lower(s).compile("jnp", cache=None)(x=x)["out"])
        (start, stop), = params.values()
        ref = np.zeros(64, np.float32)
        ref[start:stop] = (x[start:stop] + 1) * 2
        np.testing.assert_allclose(out, ref, rtol=1e-6)


def test_fusion_multi_reader_intermediate_replicates():
    """TWO consumers of one intermediate: the first fuses by replicating
    the producer (kept alive for the other reader), the second then owns
    the intermediate exclusively and fuses exactly."""
    s = _pair_sdfg(extra_reader=True)
    assert s.apply(MapFusion) == 2
    entries = [nd for nd in s.states[0].nodes if isinstance(nd, MapEntry)]
    assert len(entries) == 2
    x = np.random.default_rng(22).standard_normal(64).astype(np.float32)
    for backend in ("jnp", "pallas"):
        out = lower(s).compile(backend, cache=None)(x=x)
        np.testing.assert_allclose(np.asarray(out["out"]), (x + 1) * 2,
                                   rtol=1e-5)
        np.testing.assert_allclose(np.asarray(out["out2"]), x, rtol=1e-5,
                                   atol=1e-6)


def test_fusion_refuses_wcr_intermediate():
    """wcr write revisiting nothing (every param indexes the output) is
    not a reduction at all — refused with a typed reason."""
    s = _pair_sdfg(wcr="add")
    assert s.apply(MapFusion) == 0
    reasons = dict(MapFusion().explain(s))
    assert "no reduction parameters" in reasons["cons"]


def test_fusion_refuses_uncovered_offset_reads():
    # halo read past the producer's box: consumer wants t[i+1] up to
    # t[n], producer only wrote t[0..n-1] — sigma's image is not covered
    s = _pair_sdfg(n=8, offset=1)
    assert s.apply(MapFusion) == 0
    reasons = dict(MapFusion().explain(s))
    assert "outside the producer's iteration box" in reasons["cons"]


def test_fusion_halo_offset_reads_fuse():
    """The standing refusal lifted: a shifted consumer read t[j+1] whose
    image stays inside the producer's box fuses, with the producer
    replicated at the shifted index."""
    n = 64
    s = _pair_sdfg(n=n, cons_params={"j": (0, n - 1)}, offset=1)
    assert s.apply(MapFusion) == 1
    labels = [nd.map.label for st in s.states for nd in st.nodes
              if isinstance(nd, MapEntry)]
    assert labels == ["prod+cons"]
    assert s.arrays["t"].storage is StorageType.REG
    x = np.random.default_rng(23).standard_normal(n).astype(np.float32)
    ref = np.zeros(n, np.float32)
    ref[:-1] = (x[1:] + 1) * 2
    for backend in ("jnp", "pallas"):
        out = np.asarray(lower(s).compile(backend, cache=None)(x=x)["out"])
        np.testing.assert_allclose(out, ref, rtol=1e-5)


def test_fusion_stencil_chain_single_scope():
    """A 3-stage radius-1 stencil chain collapses into ONE scope whose
    replica count grows linearly (1+3+5, content-deduplicated), matching
    numpy on both backends."""
    n = 256
    s = SDFG("stchain")
    s.add_array("x", (n,), "float32")
    s.add_array("out", (n,), "float32")
    s.add_transient("t1", (n,), "float32")
    s.add_transient("t2", (n,), "float32")
    st = s.add_state("main", is_start=True)
    i = sym("i")

    def stage(name, src, dst, lo, hi, node=None):
        _, _, ex = st.add_mapped_tasklet(
            name, {"i": (lo, hi)},
            inputs={"a": Memlet.simple(src, Subset.indices([i - 1])),
                    "b": Memlet.simple(src, Subset.indices([i])),
                    "c": Memlet.simple(src, Subset.indices([i + 1]))},
            outputs={"w": Memlet.simple(dst, Subset.indices([i]))},
            fn=lambda a, b, c: (a + b + c) / 3.0,
            input_nodes={src: node} if node is not None else None)
        return next(e.dst for e in st.out_edges(ex) if e.memlet.data == dst)

    t1n = stage("s1", "x", "t1", 1, n - 1)
    t2n = stage("s2", "t1", "t2", 2, n - 2, t1n)
    stage("s3", "t2", "out", 3, n - 3, t2n)
    assert s.apply(MapFusion) == 2
    entries = [nd for nd in s.states[0].nodes if isinstance(nd, MapEntry)]
    assert len(entries) == 1
    from repro.core.sdfg import Tasklet
    tasklets = [nd for nd in s.states[0].nodes if isinstance(nd, Tasklet)]
    assert len(tasklets) == 1 + 3 + 5
    x = np.random.default_rng(24).standard_normal(n).astype(np.float32)
    ref = np.zeros(n, np.float64)
    a = np.zeros(n, np.float64)
    a[1:n - 1] = (x[:n - 2] + x[1:n - 1] + x[2:]) / 3.0
    b = np.zeros(n, np.float64)
    b[2:n - 2] = (a[1:n - 3] + a[2:n - 2] + a[3:n - 1]) / 3.0
    ref[3:n - 3] = (b[2:n - 4] + b[3:n - 3] + b[4:n - 2]) / 3.0
    for backend in ("jnp", "pallas"):
        out = np.asarray(lower(s).compile(backend, cache=None)(x=x)["out"])
        np.testing.assert_allclose(out, ref.astype(np.float32), rtol=1e-4,
                                   atol=1e-5)


def test_fusion_refuses_broadcast_intermediate_write():
    """A write subset that ignores a map parameter is a revisited
    location (last write wins); fusing would hand the consumer the
    per-iteration value instead of the final one."""
    n = 8
    s = SDFG("bcast")
    s.add_array("x", (n, n), "float32")
    s.add_array("out", (n, n), "float32")
    s.add_transient("t", (n,), "float32")
    st = s.add_state("main", is_start=True)
    i, j = sym("i"), sym("j")
    _, _, ex1 = st.add_mapped_tasklet(
        "prod", {"i": (0, n), "j": (0, n)},
        inputs={"v": Memlet.simple("x", Subset.indices([i, j]))},
        outputs={"w": Memlet.simple("t", Subset.indices([i]))},  # no j!
        fn=lambda v: v + 1.0)
    t_node = next(e.dst for e in st.out_edges(ex1) if e.memlet.data == "t")
    st.add_mapped_tasklet(
        "cons", {"i": (0, n), "j": (0, n)},
        inputs={"u": Memlet.simple("t", Subset.indices([i]))},
        outputs={"o": Memlet.simple("out", Subset.indices([i, j]))},
        fn=lambda u: u * 2.0, input_nodes={"t": t_node})
    assert s.apply(MapFusion) == 0


def test_fusion_refuses_non_injective_index_writes():
    """t[i+j] collides across iterations (iterations (0,1) and (1,0) hit
    the same element): last write wins sequentially, so fusing would
    change the values the consumer sees. Must refuse."""
    n = 4
    s = SDFG("collide")
    s.add_array("x", (n, n), "float32")
    s.add_array("out", (n, n), "float32")
    s.add_transient("t", (2 * n,), "float32")
    st = s.add_state("main", is_start=True)
    i, j = sym("i"), sym("j")
    _, _, ex1 = st.add_mapped_tasklet(
        "prod", {"i": (0, n), "j": (0, n)},
        inputs={"v": Memlet.simple("x", Subset.indices([i, j]))},
        outputs={"w": Memlet.simple("t", Subset.indices([i + j]))},
        fn=lambda v: v * 2.0)
    t_node = next(e.dst for e in st.out_edges(ex1) if e.memlet.data == "t")
    st.add_mapped_tasklet(
        "cons", {"i": (0, n), "j": (0, n)},
        inputs={"u": Memlet.simple("t", Subset.indices([i + j]))},
        outputs={"o": Memlet.simple("out", Subset.indices([i, j]))},
        fn=lambda u: u + 1.0, input_nodes={"t": t_node})
    assert s.apply(MapFusion) == 0


def test_fusion_refuses_overlapping_slice_writes():
    """A param-dependent slice write (t[i:i+2]) overlaps its neighbor
    iterations: sequentially, iteration i+1 overwrites t[i+1] before the
    consumer reads it, so fusing would hand the consumer iteration i's
    private value. Must refuse — and the unfused program must keep the
    last-write-wins answer."""
    import jax.numpy as jnp
    from repro.core.memlet import Range
    n = 6
    s = SDFG("overlap")
    s.add_array("x", (n,), "float32")
    s.add_array("out", (n - 1, 2), "float32")
    s.add_transient("t", (n,), "float32")
    st = s.add_state("main", is_start=True)
    i = sym("i")
    _, _, ex1 = st.add_mapped_tasklet(
        "prod", {"i": (0, n - 1)},
        inputs={"v": Memlet.simple("x", Subset.indices([i]))},
        outputs={"w": Memlet.simple("t", Subset([Range.make(i, i + 2)]))},
        fn=lambda v: jnp.stack([v, -v]))
    t_node = next(e.dst for e in st.out_edges(ex1) if e.memlet.data == "t")
    st.add_mapped_tasklet(
        "cons", {"i": (0, n - 1)},
        inputs={"u": Memlet.simple("t", Subset([Range.make(i, i + 2)]))},
        outputs={"o": Memlet.simple("out",
                                    Subset([Range.index(i),
                                            Range.make(0, 2)]))},
        fn=lambda u: u, input_nodes={"t": t_node})
    assert s.apply(MapFusion) == 0
    x = np.arange(1, n + 1, dtype=np.float32)
    out = np.asarray(lower(s).compile("jnp", cache=None)(x=x)["out"])
    # sequential semantics: row i = (x[i], x[i+1]) except the last row,
    # whose second element keeps the final iteration's -x write
    ref = np.stack([x[:-1], np.concatenate([x[1:-1], [-x[-2]]])], axis=1)
    np.testing.assert_allclose(out, ref, rtol=1e-6)


def test_fusion_refuses_transitive_dependency():
    """Consumer input reachable from the producer through a THIRD map:
    fusing would wire that input into the fused entry and create a
    cycle (prod -> middle -> fused -> prod)."""
    n = 8
    s = SDFG("transitive")
    s.add_array("x", (n,), "float32")
    s.add_array("out", (n,), "float32")
    for nm in ("t", "X", "Y"):
        s.add_transient(nm, (n,), "float32")
    st = s.add_state("main", is_start=True)
    i = sym("i")
    # producer: writes both t and X
    _, _, px = st.add_mapped_tasklet(
        "prod", {"i": (0, n)},
        inputs={"v": Memlet.simple("x", Subset.indices([i]))},
        outputs={"t": Memlet.simple("t", Subset.indices([i])),
                 "X": Memlet.simple("X", Subset.indices([i]))},
        fn=lambda v: {"t": v + 1.0, "X": v * 2.0})
    t_node = next(e.dst for e in st.out_edges(px) if e.memlet.data == "t")
    x_node = next(e.dst for e in st.out_edges(px) if e.memlet.data == "X")
    # middle: X -> Y
    _, _, mx = st.add_mapped_tasklet(
        "middle", {"i": (0, n)},
        inputs={"v": Memlet.simple("X", Subset.indices([i]))},
        outputs={"w": Memlet.simple("Y", Subset.indices([i]))},
        fn=lambda v: v - 3.0, input_nodes={"X": x_node})
    y_node = next(e.dst for e in st.out_edges(mx) if e.memlet.data == "Y")
    # consumer: reads t AND Y
    st.add_mapped_tasklet(
        "cons", {"i": (0, n)},
        inputs={"u": Memlet.simple("t", Subset.indices([i])),
                "y": Memlet.simple("Y", Subset.indices([i]))},
        outputs={"o": Memlet.simple("out", Subset.indices([i]))},
        fn=lambda u, y: u + y, input_nodes={"t": t_node, "Y": y_node})
    # fusing prod+cons through t must refuse: cons also depends on prod
    # via X -> middle -> Y, and rerouting Y into the fused entry cycles
    mf = MapFusion()
    match_t = next(m for m in mf.find_matches(s) if m["node"].data == "t")
    assert not mf.can_apply(s, match_t)
    # whatever legal fusions remain (prod+middle through X is fine) must
    # leave an acyclic graph that still computes the right answer
    s.apply(MapFusion)
    s.validate()
    x = np.random.default_rng(4).standard_normal(n).astype(np.float32)
    out = np.asarray(lower(s).compile("jnp", cache=None)(x=x)["out"])
    np.testing.assert_allclose(out, (x + 1) + (x * 2 - 3), rtol=1e-5)


def test_fusion_renames_consumer_params():
    s = _pair_sdfg(cons_params={"j": (0, 64)})
    assert s.apply(MapFusion) == 1
    x = np.random.default_rng(0).standard_normal(64).astype(np.float32)
    out = np.asarray(lower(s).compile("jnp", cache=None)(x=x)["out"])
    np.testing.assert_allclose(out, (x + 1) * 2, rtol=1e-6)


# ---------------------------------------------------------------------------
# semantics + the paper metric
# ---------------------------------------------------------------------------

def test_fusion_preserves_semantics_and_drops_volume():
    rng = np.random.default_rng(1)
    x = rng.standard_normal(64).astype(np.float32)
    plain, fused = _pair_sdfg(), _pair_sdfg()
    plain.apply(DeviceOffload)
    fused.apply(DeviceOffload)
    v_before = fused.off_chip_volume()
    assert fused.apply(MapFusion) == 1
    v_after = fused.off_chip_volume()
    # the t round-trip (write + read, 2n elements) leaves the metric
    assert v_before - v_after == 2 * 64 * 4
    o_plain = np.asarray(lower(plain).compile("jnp", cache=None)(x=x)["out"])
    o_fused = np.asarray(lower(fused).compile("jnp", cache=None)(x=x)["out"])
    np.testing.assert_allclose(o_fused, o_plain, rtol=1e-6)
    o_grid = np.asarray(lower(fused).compile("pallas", cache=None)(x=x)["out"])
    np.testing.assert_allclose(o_grid, o_plain, rtol=1e-6)


def _accumulate_pipeline(fused=True, tile=128):
    passes = [SetExpansionPreferencePass(("accumulate", "generic")),
              ExpandLibraryNodesPass()]
    if fused:
        passes.append(MapFusionPass())
    passes += [MapTilingPass(tile_size=tile), GridConversionPass()]
    return PassManager(passes, name="acc_fused" if fused else "acc_unfused")


def _build_axpydot(n):
    p = Program("axpydot")
    a = p.scalar_input("a", "float32")
    x, y, w = (p.input(nm, (n,)) for nm in ("x", "y", "w"))
    p.output("result", blas.dot(blas.axpy(a, x, y), w))
    return p.finalize()


def test_axpydot_chain_fuses_to_one_grid_kernel():
    """Acceptance: the axpy->dot chain compiles to ONE grid kernel with
    the axpy intermediate held in-kernel; jnp-vs-pallas within 1e-4."""
    n = 2048
    rng = np.random.default_rng(2)
    a = np.float32(0.7)
    x, y, w = (rng.standard_normal(n).astype(np.float32) for _ in range(3))
    cp = lower(_build_axpydot(n)).compile(
        "pallas", pipeline=_accumulate_pipeline(fused=True), cache=None)
    assert cp.report["grid_kernels"] == ["axpy0_map+dot0_acc_tiled"]
    assert len(cp.report["grid_kernels"]) == 1
    assert cp.report["grid_converted"][0]["tasklets"] == 2
    cu = lower(_build_axpydot(n)).compile(
        "pallas", pipeline=_accumulate_pipeline(fused=False), cache=None)
    assert len(cu.report["grid_kernels"]) == 2  # the unfused pair
    cj = lower(_build_axpydot(n)).compile("jnp", cache=None)
    rp = float(np.asarray(cp(a=a, x=x, y=y, w=w)["result"]).ravel()[0])
    ru = float(np.asarray(cu(a=a, x=x, y=y, w=w)["result"]).ravel()[0])
    rj = float(np.asarray(cj(a=a, x=x, y=y, w=w)["result"]).ravel()[0])
    np.testing.assert_allclose(rp, rj, rtol=1e-4)
    np.testing.assert_allclose(ru, rj, rtol=1e-4)


def test_fusion_cascades_over_elementwise_chain():
    """Three elementwise maps collapse into one scope (fixpoint), and the
    fused scope grid-compiles."""
    n = 256
    s = SDFG("chain3")
    s.add_array("x", (n,), "float32")
    s.add_array("out", (n,), "float32")
    s.add_transient("t1", (n,), "float32")
    s.add_transient("t2", (n,), "float32")
    st = s.add_state("main", is_start=True)
    i = sym("i")
    _, _, e1 = st.add_mapped_tasklet(
        "m1", {"i": (0, n)},
        inputs={"v": Memlet.simple("x", Subset.indices([i]))},
        outputs={"w": Memlet.simple("t1", Subset.indices([i]))},
        fn=lambda v: v * 2.0)
    t1n = next(e.dst for e in st.out_edges(e1) if e.memlet.data == "t1")
    _, _, e2 = st.add_mapped_tasklet(
        "m2", {"i": (0, n)},
        inputs={"v": Memlet.simple("t1", Subset.indices([i]))},
        outputs={"w": Memlet.simple("t2", Subset.indices([i]))},
        fn=lambda v: v + 3.0, input_nodes={"t1": t1n})
    t2n = next(e.dst for e in st.out_edges(e2) if e.memlet.data == "t2")
    st.add_mapped_tasklet(
        "m3", {"i": (0, n)},
        inputs={"v": Memlet.simple("t2", Subset.indices([i]))},
        outputs={"w": Memlet.simple("out", Subset.indices([i]))},
        fn=lambda v: v * v, input_nodes={"t2": t2n})
    assert s.apply(MapFusion) == 2
    entries = [nd for nd in s.states[0].nodes if isinstance(nd, MapEntry)]
    assert len(entries) == 1
    x = np.random.default_rng(3).standard_normal(n).astype(np.float32)
    c = lower(s).compile("pallas", cache=None)
    assert len(c.report["grid_kernels"]) == 1
    np.testing.assert_allclose(np.asarray(c(x=x)["out"]),
                               (x * 2 + 3) ** 2, rtol=1e-5)


# ---------------------------------------------------------------------------
# multi-producer DAGs, multi-intermediate groups, scalar intermediates
# ---------------------------------------------------------------------------

def _two_producer_sdfg(n=128):
    """t1 = x+1 and t2 = y*2 from independent producers; out = t1+t2."""
    s = SDFG("twoprod")
    for nm in ("x", "y", "out"):
        s.add_array(nm, (n,), "float32")
    s.add_transient("t1", (n,), "float32")
    s.add_transient("t2", (n,), "float32")
    st = s.add_state("main", is_start=True)
    i = sym("i")
    _, _, e1 = st.add_mapped_tasklet(
        "p1", {"i": (0, n)},
        inputs={"v": Memlet.simple("x", Subset.indices([i]))},
        outputs={"w": Memlet.simple("t1", Subset.indices([i]))},
        fn=lambda v: v + 1.0)
    t1n = next(e.dst for e in st.out_edges(e1) if e.memlet.data == "t1")
    _, _, e2 = st.add_mapped_tasklet(
        "p2", {"j": (0, n)},
        inputs={"v": Memlet.simple("y", Subset.indices([sym("j")]))},
        outputs={"w": Memlet.simple("t2", Subset.indices([sym("j")]))},
        fn=lambda v: v * 2.0)
    t2n = next(e.dst for e in st.out_edges(e2) if e.memlet.data == "t2")
    st.add_mapped_tasklet(
        "c", {"k": (0, n)},
        inputs={"u1": Memlet.simple("t1", Subset.indices([sym("k")])),
                "u2": Memlet.simple("t2", Subset.indices([sym("k")]))},
        outputs={"o": Memlet.simple("out", Subset.indices([sym("k")]))},
        fn=lambda u1, u2: u1 + u2, input_nodes={"t1": t1n, "t2": t2n})
    return s


def test_fusion_multi_producer_dag_single_kernel():
    """A consumer fed by TWO independent producer exits fuses with both
    (fixpoint), and the fused DAG compiles to ONE grid kernel on pallas
    and one vmapped body on jnp."""
    n = 128
    s = _two_producer_sdfg(n)
    assert s.apply(MapFusion) == 2
    entries = [nd for nd in s.states[0].nodes if isinstance(nd, MapEntry)]
    assert len(entries) == 1
    rng = np.random.default_rng(7)
    x = rng.standard_normal(n).astype(np.float32)
    y = rng.standard_normal(n).astype(np.float32)
    ref = (x + 1) + (y * 2)
    cp = lower(s).compile("pallas", cache=None)
    assert len(cp.report["grid_kernels"]) == 1
    conv = cp.report["grid_converted"][0]
    assert conv["tasklets"] == 3 and conv["in_kernel_values"] == 2
    np.testing.assert_allclose(np.asarray(cp(x=x, y=y)["out"]), ref,
                               rtol=1e-5)
    s2 = _two_producer_sdfg(n)
    s2.apply(MapFusion)
    oj = np.asarray(lower(s2).compile("jnp", cache=None)(x=x, y=y)["out"])
    np.testing.assert_allclose(oj, ref, rtol=1e-5)


def _two_intermediate_sdfg(n=64, wcr_on_X=None):
    """ONE producer writing TWO intermediates, both read by one consumer:
    both must fuse in a single application (fusing only one would leave a
    container path into the fused scope — a cycle)."""
    s = SDFG("twoint")
    s.add_array("x", (n,), "float32")
    s.add_array("out", (n,), "float32")
    s.add_transient("t", (n,), "float32")
    s.add_transient("X", (n,), "float32")
    st = s.add_state("main", is_start=True)
    i = sym("i")
    _, _, px = st.add_mapped_tasklet(
        "prod", {"i": (0, n)},
        inputs={"v": Memlet.simple("x", Subset.indices([i]))},
        outputs={"t": Memlet.simple("t", Subset.indices([i])),
                 "X": Memlet.simple("X", Subset.indices([i]), wcr=wcr_on_X)},
        fn=lambda v: {"t": v + 1.0, "X": v * 2.0})
    tn = next(e.dst for e in st.out_edges(px) if e.memlet.data == "t")
    xn = next(e.dst for e in st.out_edges(px) if e.memlet.data == "X")
    st.add_mapped_tasklet(
        "cons", {"i": (0, n)},
        inputs={"u": Memlet.simple("t", Subset.indices([i])),
                "w2": Memlet.simple("X", Subset.indices([i]))},
        outputs={"o": Memlet.simple("out", Subset.indices([i]))},
        fn=lambda u, w2: u + w2, input_nodes={"t": tn, "X": xn})
    return s


def test_fusion_multi_intermediate_one_application():
    n = 64
    s = _two_intermediate_sdfg(n)
    assert s.apply(MapFusion) == 1        # both intermediates, one apply
    assert s.arrays["t"].storage is StorageType.REG
    assert s.arrays["X"].storage is StorageType.REG
    x = np.random.default_rng(8).standard_normal(n).astype(np.float32)
    ref = (x + 1) + (x * 2)
    for backend in ("jnp", "pallas"):
        out = np.asarray(lower(s).compile(backend, cache=None)(x=x)["out"])
        np.testing.assert_allclose(out, ref, rtol=1e-5)


def test_fusion_multi_intermediate_poisoned_by_wcr():
    """When ANY intermediate between the pair is ineligible (here: wcr on
    one of two), the whole pair refuses — fusing a subset would put a
    cycle through the leftover container."""
    assert _two_intermediate_sdfg(wcr_on_X="add").apply(MapFusion) == 0


def _scalar_pair_sdfg(trips):
    s = SDFG("scalpair")
    s.add_array("x", (4,), "float32")
    s.add_array("out", (4,), "float32")
    s.add_scalar("sc", "float32", transient=True)
    st = s.add_state("main", is_start=True)
    i = sym("i")
    _, _, p = st.add_mapped_tasklet(
        "p", {"i": (0, trips)},
        inputs={"v": Memlet.simple("x", Subset.indices([i]))},
        outputs={"w": Memlet.simple("sc")},
        fn=lambda v: v + 1.0)
    scn = next(e.dst for e in st.out_edges(p) if e.memlet.data == "sc")
    st.add_mapped_tasklet(
        "c", {"i": (0, trips)},
        inputs={"u": Memlet.simple("sc")},
        outputs={"o": Memlet.simple("out", Subset.indices([i]))},
        fn=lambda u: u * 2.0, input_nodes={"sc": scn})
    return s


def test_fusion_scalar_intermediate():
    """A Scalar-descriptor intermediate fuses under the same disjointness
    rule as arrays: with no index dimensions, it is legal exactly when no
    parameter revisits it (single-trip maps) — and refused otherwise
    (the sequential schedule delivers the LAST write to every consumer
    iteration, not the per-iteration value)."""
    s = _scalar_pair_sdfg(trips=1)
    assert s.apply(MapFusion) == 1
    assert s.arrays["sc"].storage is StorageType.REG
    x = np.arange(1, 5, dtype=np.float32)
    out = np.asarray(lower(s).compile("jnp", cache=None)(x=x)["out"])
    exp = np.zeros(4, np.float32)
    exp[0] = (x[0] + 1) * 2
    np.testing.assert_allclose(out, exp, rtol=1e-6)
    assert _scalar_pair_sdfg(trips=4).apply(MapFusion) == 0


# ---------------------------------------------------------------------------
# fuse-across-tiling: range equivalence up to MapTiling splits
# ---------------------------------------------------------------------------

def _tileable_pair(n=512):
    return _pair_sdfg(n=n, cons_params={"j": (0, n)})


@pytest.mark.parametrize("tile_prod,tile_cons,fuses", [
    (None, None, True),              # classic untiled pair
    ({"i": 64}, None, True),         # tiled producer, untiled consumer
    (None, {"j": 64}, True),         # untiled producer, tiled consumer
    ({"i": 64}, {"j": 64}, True),    # both tiled, same tile
    ({"i": 64}, {"j": 128}, False),  # tile mismatch refuses
])
def test_fusion_across_tiling_matrix(tile_prod, tile_cons, fuses):
    """Range matching consults Map.annotations['tiling']: a tiled
    producer and untiled consumer (or two maps tiled alike) over the same
    underlying extent fuse; mismatched tiles refuse."""
    n = 512
    s = _tileable_pair(n)
    if tile_prod:
        s.apply(MapTiling, map_label="prod", tile_sizes=tile_prod)
    if tile_cons:
        s.apply(MapTiling, map_label="cons", tile_sizes=tile_cons)
    assert (s.apply(MapFusion) == 1) is fuses
    x = np.random.default_rng(9).standard_normal(n).astype(np.float32)
    ref = (x + 1) * 2
    for backend in ("jnp", "pallas"):
        out = np.asarray(lower(s).compile(backend, cache=None)(x=x)["out"])
        np.testing.assert_allclose(out, ref, rtol=1e-5)


def test_fusion_tiling_orders_commute():
    """MapFusion -> MapTiling and MapTiling -> MapFusion must produce the
    same fused kernel set (same labels, same single grid kernel)."""
    def compile_order(order):
        passes = [SetExpansionPreferencePass(("generic",)),
                  ExpandLibraryNodesPass()]
        if order == "fuse_first":
            passes += [MapFusionPass(), MapTilingPass()]
        else:
            passes += [MapTilingPass(), MapFusionPass()]
        passes.append(GridConversionPass())
        return lower(_tileable_pair(512)).compile(
            "pallas", pipeline=PassManager(passes, name=order), cache=None)

    ft, tf = compile_order("fuse_first"), compile_order("tile_first")
    assert ft.report["grid_kernels"] == tf.report["grid_kernels"]
    assert len(ft.report["grid_kernels"]) == 1
    x = np.random.default_rng(10).standard_normal(512).astype(np.float32)
    np.testing.assert_allclose(np.asarray(ft(x=x)["out"]),
                               np.asarray(tf(x=x)["out"]), rtol=1e-6)


def _three_scope_sdfg(n=32, s_transient=True):
    """m1 writes t1 AND a second container S; m2 consumes t1; m3 reads
    t2 (from m2) and S."""
    s = SDFG("threescope")
    s.add_array("x", (n,), "float32")
    s.add_array("out", (n,), "float32")
    for nm in ("t1", "t2"):
        s.add_transient(nm, (n,), "float32")
    if s_transient:
        s.add_transient("S", (n,), "float32")
    else:
        s.add_array("S", (n,), "float32")     # program output: not fusible
    st = s.add_state("main", is_start=True)
    i = sym("i")
    _, _, e1 = st.add_mapped_tasklet(
        "m1", {"i": (0, n)},
        inputs={"v": Memlet.simple("x", Subset.indices([i]))},
        outputs={"t1": Memlet.simple("t1", Subset.indices([i])),
                 "S": Memlet.simple("S", Subset.indices([i]))},
        fn=lambda v: {"t1": v + 1.0, "S": v * 3.0})
    t1n = next(e.dst for e in st.out_edges(e1) if e.memlet.data == "t1")
    sn = next(e.dst for e in st.out_edges(e1) if e.memlet.data == "S")
    _, _, e2 = st.add_mapped_tasklet(
        "m2", {"i": (0, n)},
        inputs={"v": Memlet.simple("t1", Subset.indices([i]))},
        outputs={"w": Memlet.simple("t2", Subset.indices([i]))},
        fn=lambda v: v - 2.0, input_nodes={"t1": t1n})
    t2n = next(e.dst for e in st.out_edges(e2) if e.memlet.data == "t2")
    st.add_mapped_tasklet(
        "m3", {"i": (0, n)},
        inputs={"v": Memlet.simple("t2", Subset.indices([i])),
                "s2": Memlet.simple("S", Subset.indices([i]))},
        outputs={"o": Memlet.simple("out", Subset.indices([i]))},
        fn=lambda v, s2: v + s2, input_nodes={"t2": t2n, "S": sn})
    return s


def test_fusion_shared_container_across_three_scopes():
    """With S a non-transient output, m1+m2 fuse but m3 must stay out:
    the fused scope writes the shared container m3 reads, and fusing m3
    would put a container path (a cycle) through the fused scope. With S
    transient and element-exact, all three scopes legally collapse — S
    just joins the intermediate group."""
    n = 32
    x = np.random.default_rng(11).standard_normal(n).astype(np.float32)
    ref = ((x + 1) - 2) + x * 3

    s = _three_scope_sdfg(n, s_transient=False)
    assert s.apply(MapFusion) == 1        # m1+m2 only; m3 stays out
    entries = [nd for nd in s.states[0].nodes if isinstance(nd, MapEntry)]
    assert len(entries) == 2
    for backend in ("jnp", "pallas"):
        out = lower(s).compile(backend, cache=None)(x=x)
        np.testing.assert_allclose(np.asarray(out["out"]), ref, rtol=1e-5)
        np.testing.assert_allclose(np.asarray(out["S"]), x * 3, rtol=1e-5)

    s = _three_scope_sdfg(n, s_transient=True)
    assert s.apply(MapFusion) == 2        # S rides the t2 group
    entries = [nd for nd in s.states[0].nodes if isinstance(nd, MapEntry)]
    assert len(entries) == 1
    for backend in ("jnp", "pallas"):
        out = np.asarray(lower(s).compile(backend, cache=None)(x=x)["out"])
        np.testing.assert_allclose(out, ref, rtol=1e-5)


# ---------------------------------------------------------------------------
# acceptance: the paper DAGs as single grid kernels
# ---------------------------------------------------------------------------

def _chain_pipeline(order="fuse_first"):
    passes = [SetExpansionPreferencePass(("accumulate", "generic")),
              ExpandLibraryNodesPass()]
    if order == "fuse_first":
        passes += [MapFusionPass(), MapTilingPass()]
    else:
        passes += [MapTilingPass(), MapFusionPass()]
    passes.append(GridConversionPass())
    return PassManager(passes, name=f"chain_{order}")


def test_gemver_chain_fuses_to_one_grid_kernel():
    """Acceptance: gemver's ger->ger->gemv chain (accumulate gemv
    expansion) lowers to a single pallas_call — with B1 and B2 held
    in-kernel — in BOTH pipeline orders."""
    from benchmarks.gemver import build_chain
    n = 96
    rng = np.random.default_rng(12)
    d = {k: rng.standard_normal((n, n) if k == "A" else n).astype(np.float32)
         for k in ("A", "u1", "v1", "u2", "v2", "xw")}
    B = d["A"] + np.outer(d["u1"], d["v1"]) + np.outer(d["u2"], d["v2"])
    ref = 1.1 * B @ d["xw"]
    kernels = {}
    for order in ("fuse_first", "tile_first"):
        cp = lower(build_chain(n)).compile(
            "pallas", pipeline=_chain_pipeline(order), cache=None)
        kernels[order] = cp.report["grid_kernels"]
        assert len(cp.report["grid_kernels"]) == 1
        conv = cp.report["grid_converted"][0]
        assert conv["tasklets"] == 3 and conv["in_kernel_values"] == 2
        np.testing.assert_allclose(np.asarray(cp(**d)["w_out"]), ref,
                                   rtol=1e-3, atol=1e-4)
    assert kernels["fuse_first"] == kernels["tile_first"]
    cj = lower(build_chain(n)).compile("jnp", cache=None)
    np.testing.assert_allclose(np.asarray(cj(**d)["w_out"]), ref,
                               rtol=1e-3, atol=1e-4)


def test_axpydot_two_producer_dot_single_kernel():
    """Acceptance: a dot over TWO produced operands — both axpys fold
    into the dot's grid kernel."""
    from benchmarks.axpydot import build_two_producer
    n = 2048
    rng = np.random.default_rng(13)
    a, b = np.float32(0.7), np.float32(-0.4)
    x, y, u, v = (rng.standard_normal(n).astype(np.float32)
                  for _ in range(4))
    ref = np.dot((a * x + y).astype(np.float32),
                 (b * u + v).astype(np.float32))
    cp = lower(build_two_producer(n)).compile(
        "pallas", pipeline=_accumulate_pipeline(fused=True), cache=None)
    assert len(cp.report["grid_kernels"]) == 1
    conv = cp.report["grid_converted"][0]
    assert conv["tasklets"] == 3 and conv["in_kernel_values"] == 2
    got = float(np.asarray(
        cp(a=a, b=b, x=x, y=y, u=u, v=v)["result"]).ravel()[0])
    np.testing.assert_allclose(got, ref, rtol=1e-4)


def test_gemver_b2_multi_consumer_fuses_into_both_gemvs():
    """gemver's B2 -> two-gemv shape: one produced matrix feeds TWO
    reductions (x = B2^T @ y reads it transposed, w = B2 @ x straight).
    The transposed reader fuses by replicating the producer (kept for the
    other), the straight reader then fuses exactly — B2 never round-trips
    through HBM."""
    n = 48
    s = SDFG("b2gemvs")
    s.add_array("A", (n, n), "float32")
    for nm in ("u2", "v2", "xw", "yv"):
        s.add_array(nm, (n,), "float32")
    s.add_array("x_out", (n,), "float32")
    s.add_array("w_out", (n,), "float32")
    s.add_transient("B2", (n, n), "float32")
    st = s.add_state("main", is_start=True)
    i, j = sym("i"), sym("j")
    _, _, px = st.add_mapped_tasklet(
        "ger", {"i": (0, n), "j": (0, n)},
        inputs={"a": Memlet.simple("A", Subset.indices([i, j])),
                "u": Memlet.simple("u2", Subset.indices([i])),
                "v": Memlet.simple("v2", Subset.indices([j]))},
        outputs={"w": Memlet.simple("B2", Subset.indices([i, j]))},
        fn=lambda a, u, v: a + u * v)
    b2n = next(e.dst for e in st.out_edges(px) if e.memlet.data == "B2")
    st.add_mapped_tasklet(
        "gemv_t", {"i": (0, n), "j": (0, n)},
        inputs={"m": Memlet.simple("B2", Subset.indices([j, i])),
                "z": Memlet.simple("yv", Subset.indices([j]))},
        outputs={"o": Memlet.simple("x_out", Subset.indices([i]),
                                    wcr="add")},
        fn=lambda m, z: m * z, input_nodes={"B2": b2n})
    st.add_mapped_tasklet(
        "gemv", {"i": (0, n), "j": (0, n)},
        inputs={"m": Memlet.simple("B2", Subset.indices([i, j])),
                "z": Memlet.simple("xw", Subset.indices([j]))},
        outputs={"o": Memlet.simple("w_out", Subset.indices([i]),
                                    wcr="add")},
        fn=lambda m, z: m * z, input_nodes={"B2": b2n})
    assert s.apply(MapFusion) == 2
    entries = [nd for nd in s.states[0].nodes if isinstance(nd, MapEntry)]
    assert len(entries) == 2
    b2_nodes = [nd for stt in s.states for nd in stt.data_nodes()
                if nd.data == "B2"]
    assert not b2_nodes                   # fully consumed in-kernel
    rng = np.random.default_rng(25)
    d = {"A": rng.standard_normal((n, n)).astype(np.float32)}
    for nm in ("u2", "v2", "xw", "yv"):
        d[nm] = rng.standard_normal(n).astype(np.float32)
    B2 = d["A"] + np.outer(d["u2"], d["v2"])
    for backend in ("jnp", "pallas"):
        out = lower(s).compile(backend, cache=None)(**d)
        np.testing.assert_allclose(np.asarray(out["x_out"]), B2.T @ d["yv"],
                                   rtol=1e-3, atol=1e-4)
        np.testing.assert_allclose(np.asarray(out["w_out"]), B2 @ d["xw"],
                                   rtol=1e-3, atol=1e-4)


# ---------------------------------------------------------------------------
# wcr-producing scope feeding a consumer: two-phase accumulate+consume
# ---------------------------------------------------------------------------
def _wcr_chain_sdfg(n, m):
    """Row-sum reduction (wcr=add over j) feeding an elementwise consumer
    through the transient ``t`` — the fused scope carries an internal wcr
    edge and must lower as accumulate-then-consume."""
    s = SDFG("wcr_chain")
    s.add_array("A", (n, m), "float32")
    s.add_array("y", (n,), "float32")
    s.add_array("out", (n,), "float32")
    s.add_transient("t", (n,), "float32")
    st = s.add_state("main", is_start=True)
    i, j, k = sym("i"), sym("j"), sym("k")
    _, _, ex = st.add_mapped_tasklet(
        "rowsum", {"i": (0, n), "j": (0, m)},
        inputs={"a": Memlet.simple("A", Subset.indices([i, j]))},
        outputs={"o": Memlet.simple("t", Subset.indices([i]), wcr="add")},
        fn=lambda a: a * 2.0)
    t_node = next(e.dst for e in st.out_edges(ex) if e.memlet.data == "t")
    st.add_mapped_tasklet(
        "shift", {"k": (0, n)},
        inputs={"v": Memlet.simple("t", Subset.indices([k])),
                "z": Memlet.simple("y", Subset.indices([k]))},
        outputs={"o": Memlet.simple("out", Subset.indices([k]))},
        fn=lambda v, z: v + z, input_nodes={"t": t_node})
    return s


@pytest.mark.parametrize("tiled", [False, True])
def test_fusion_wcr_reduction_into_consumer_two_phase(tiled):
    """The standing wcr refusal lifted: a reduction-producing scope fuses
    with its consumer; both backends lower the internal wcr edge as a
    two-phase accumulate+consume (tiled: scratch accumulators per kept
    tile param, phase flip on the reduction grid dim)."""
    n, m = (128, 96) if tiled else (48, 32)
    s = _wcr_chain_sdfg(n, m)
    assert s.apply(MapFusion) == 1
    entries = [nd for nd in s.states[0].nodes if isinstance(nd, MapEntry)]
    assert len(entries) == 1
    t_nodes = [nd for stt in s.states for nd in stt.data_nodes()
               if nd.data == "t"]
    assert not t_nodes                    # reduction held in-kernel
    rng = np.random.default_rng(31)
    A = rng.standard_normal((n, m)).astype(np.float32)
    y = rng.standard_normal(n).astype(np.float32)
    ref = 2.0 * A.sum(axis=1) + y
    oj = np.asarray(lower(s).compile("jnp", cache=None)(A=A, y=y)["out"])
    np.testing.assert_allclose(oj, ref, rtol=1e-4, atol=1e-4)
    if tiled:
        cp = lower(s).compile("pallas", cache=None)  # default tiled pipeline
    else:
        cp = lower(s).compile(
            "pallas", cache=None,
            pipeline=PassManager([GridConversionPass()], name="wcr_untiled"))
    assert len(cp.report["grid_kernels"]) == 1, cp.report
    og = np.asarray(cp(A=A, y=y)["out"])
    np.testing.assert_allclose(og, ref, rtol=1e-4, atol=1e-4)


def test_refused_fusion_reports_typed_reason_through_pipeline():
    """A refused fusion (consumer read leaving the producer's box) must
    surface its typed reason in ``grid_skipped``/``grid_decisions``
    through the default pallas pipeline, not silently fall back."""
    n = 64
    s = SDFG("refused")
    s.add_array("x", (n,), "float32")
    s.add_transient("t", (n,), "float32")
    s.add_array("out", (n,), "float32")
    st = s.add_state("main", is_start=True)
    i = sym("i")
    _, _, ex = st.add_mapped_tasklet(
        "prod", {"i": (8, n - 8)},
        inputs={"v": Memlet.simple("x", Subset.indices([i]))},
        outputs={"o": Memlet.simple("t", Subset.indices([i]))},
        fn=lambda v: v * 2.0)
    t_node = next(e.dst for e in st.out_edges(ex) if e.memlet.data == "t")
    st.add_mapped_tasklet(
        "cons", {"i": (8, n - 8)},
        inputs={"a": Memlet.simple("t", Subset.indices([i - 8])),
                "b": Memlet.simple("t", Subset.indices([i]))},
        outputs={"o": Memlet.simple("out", Subset.indices([i]))},
        fn=lambda a, b: a + b, input_nodes={"t": t_node})
    cp = lower(s).compile("pallas", cache=None)
    assert len(cp.report["grid_kernels"]) == 2    # per-stage fallback
    refusals = [r for r in cp.report.get("grid_skipped", [])
                if r[1].startswith("fusion refused:")]
    assert refusals, cp.report.get("grid_skipped")
    assert any("outside the producer" in r[1] for r in refusals)
    unfused = [d for d in cp.report.get("grid_decisions", [])
               if d.get("decision") == "unfused"]
    assert any("outside the producer" in (d.get("reason") or "")
               for d in unfused)
