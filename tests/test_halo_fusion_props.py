"""Property tests for halo-aware MapFusion (optional hypothesis
dependency): random stencil-chain depths x offset sets x tile shapes all
fuse into ONE scope whose grid kernel matches the numpy reference on both
backends. (The deterministic refusal-reporting counterpart lives in
``test_map_fusion.py`` so it runs without hypothesis.)"""
import numpy as np
import pytest

hypothesis = pytest.importorskip(
    "hypothesis", reason="property tests need the optional 'hypothesis' "
                         "dependency (pip install -e .[test])")
from hypothesis import given, settings, strategies as hst  # noqa: E402

from repro.core.memlet import Memlet, Subset  # noqa: E402
from repro.core.sdfg import SDFG, MapEntry  # noqa: E402
from repro.core.symbolic import sym  # noqa: E402
from repro.pipeline import (GridConversionPass, MapTilingPass,  # noqa: E402
                            PassManager, lower)
from repro.transforms import MapFusion  # noqa: E402

MARGIN = 4  # stage k computes [MARGIN*(k+1), n - MARGIN*(k+1))


def _coef(o):
    return 0.25 * (o + 2)


def _stage_fn(offs):
    """Weighted sum over the sampled offsets; connector ``v{o+1}`` reads
    the predecessor at ``i + o`` with a per-offset coefficient so any
    offset mix-up changes the result."""
    def fn(**kw):
        return sum(_coef(int(k[1:]) - 1) * v for k, v in kw.items())
    return fn


def _chain_sdfg(n, stage_offsets):
    s = SDFG("halo_prop")
    s.add_array("x", (n,), "float32")
    s.add_array("out", (n,), "float32")
    st = s.add_state("main", is_start=True)
    i = sym("i")
    prev_name, prev_node = "x", None
    for k, offs in enumerate(stage_offsets):
        last = k == len(stage_offsets) - 1
        dst = "out" if last else f"t{k}"
        if not last:
            s.add_transient(dst, (n,), "float32")
        lo, hi = MARGIN * (k + 1), n - MARGIN * (k + 1)
        kw = {} if prev_node is None else {"input_nodes":
                                           {prev_name: prev_node}}
        _, _, ex = st.add_mapped_tasklet(
            f"stage{k}", {"i": (lo, hi)},
            inputs={f"v{o + 1}": Memlet.simple(
                        prev_name, Subset.indices([i + o])) for o in offs},
            outputs={"o": Memlet.simple(dst, Subset.indices([i]))},
            fn=_stage_fn(offs), **kw)
        prev_name = dst
        prev_node = next(e.dst for e in st.out_edges(ex)
                         if e.memlet.data == dst)
    return s


def _reference(x, stage_offsets):
    n = x.shape[0]
    cur = x
    for k, offs in enumerate(stage_offsets):
        lo, hi = MARGIN * (k + 1), n - MARGIN * (k + 1)
        nxt = np.zeros_like(cur)
        nxt[lo:hi] = sum(_coef(o) * cur[lo + o:hi + o] for o in offs)
        cur = nxt
    return cur


@settings(max_examples=20, deadline=None)
@given(n=hst.sampled_from([48, 96, 160]),
       stage_offsets=hst.lists(
           hst.lists(hst.sampled_from([-1, 0, 1]),
                     min_size=1, max_size=3, unique=True),
           min_size=2, max_size=3),
       tile=hst.sampled_from([None, 8, 32]),
       seed=hst.integers(min_value=0, max_value=2 ** 31 - 1))
def test_random_stencil_chains_fuse_and_match(n, stage_offsets, tile, seed):
    """Any chain of 2-3 radius-1 stencil stages fuses to a single scope
    (producers replicated per shifted read) and both backends match the
    numpy reference. When the fused extent divides the tile the scope
    must convert to ONE grid kernel; when it does not, windowed operands
    cannot ride a masked partial tile, so the analysis must record a
    typed fallback (never silently emit a wrong kernel) — and the vmap
    path it falls back to must still match."""
    s = _chain_sdfg(n, stage_offsets)
    assert s.apply(MapFusion) == len(stage_offsets) - 1
    entries = [nd for st in s.states for nd in st.nodes
               if isinstance(nd, MapEntry)]
    assert len(entries) == 1

    x = np.random.default_rng(seed).standard_normal(n).astype(np.float32)
    ref = _reference(x, stage_offsets)

    oj = np.asarray(lower(s).compile("jnp", cache=None)(x=x)["out"])
    np.testing.assert_allclose(oj, ref, rtol=1e-4, atol=1e-5)

    extent = n - 2 * MARGIN * len(stage_offsets)
    if tile is None:
        cp = lower(s).compile("pallas", cache=None)
        # the default 1-D tiling always picks a divisor (or leaves the
        # map whole), so conversion is guaranteed for these extents
        guaranteed = True
    else:
        pm = PassManager([MapTilingPass(tile_sizes={"i": tile}),
                          GridConversionPass()], name=f"halo_tile{tile}")
        cp = lower(s).compile("pallas", cache=None, pipeline=pm)
        guaranteed = extent % tile == 0 and extent // tile >= 2
    kernels = cp.report["grid_kernels"]
    assert len(kernels) <= 1, f"chain split into {kernels}"
    if guaranteed:
        assert len(kernels) == 1, \
            f"expected one grid kernel, report={cp.report}"
    elif not kernels:
        # a refused conversion must be loud: either the cost model's
        # typed skip or the analysis's typed fallback, never silence
        assert (cp.report.get("grid_skipped")
                or cp.report.get("grid_fallbacks")), cp.report
    og = np.asarray(cp(x=x)["out"])
    np.testing.assert_allclose(og, ref, rtol=1e-4, atol=1e-5)
