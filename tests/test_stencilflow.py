"""StencilFlow case study (paper §6): JSON frontend, dependency mapping,
chain fusion."""
import numpy as np
import pytest

import repro.kernels  # noqa: F401
from repro.frontends.stencil import build_stencil_program, parse_computation
from repro.kernels.stencil import stencil2d_ref
from repro.transforms import DeviceOffload, StreamingComposition

SPEC = {
    "name": "diff2", "dimensions": [48, 40], "outputs": ["d"],
    "inputs": {"a": {"data_type": "float32", "input_dims": ["j", "k"]}},
    "program": {
        "b": {"computation": "b = c0*a[j,k] + c1*a[j-1,k] + c2*a[j+1,k] + "
                             "c3*a[j,k-1] + c4*a[j,k+1]"},
        "d": {"computation": "d = c0*b[j,k] + c1*b[j-1,k] + c2*b[j+1,k] + "
                             "c3*b[j,k-1] + c4*b[j,k+1]"},
    }}
OFFS = [(0, 0), (-1, 0), (1, 0), (0, -1), (0, 1)]


def test_parse_computation():
    out, arr, offsets, coeffs = parse_computation(
        SPEC["program"]["b"]["computation"])
    assert out == "b" and arr == "a"
    assert offsets == OFFS
    assert coeffs == ["c0", "c1", "c2", "c3", "c4"]


def test_dependency_order_detected():
    spec = dict(SPEC)
    # swap insertion order; builder must still schedule b before d
    spec["program"] = {"d": SPEC["program"]["d"], "b": SPEC["program"]["b"]}
    sdfg = build_stencil_program(spec)
    sdfg.validate()


@pytest.mark.parametrize("backend", ["jnp", "pallas"])
def test_two_iteration_program(backend):
    rng = np.random.default_rng(0)
    a = rng.standard_normal((48, 40)).astype(np.float32)
    co = np.array([0.2, 0.1, 0.15, 0.25, 0.3], np.float32)
    sdfg = build_stencil_program(SPEC)
    sdfg.apply(DeviceOffload)
    v0 = sdfg.off_chip_volume()
    n = sdfg.apply(StreamingComposition)
    assert n == 1  # intermediate field b -> stream
    assert v0 - sdfg.off_chip_volume() == 2 * 48 * 40 * 4
    c = sdfg.compile(backend)
    if backend == "pallas":
        assert c.report["fused_regions"] == ["Stencil+Stencil"]
    out = c(a=a, b_coeffs=co, d_coeffs=co)
    exp = stencil2d_ref(stencil2d_ref(a, co, OFFS), co, OFFS)
    np.testing.assert_allclose(np.asarray(out["d"]), np.asarray(exp),
                               rtol=1e-4, atol=1e-5)


def test_cyclic_program_rejected():
    spec = dict(SPEC)
    spec["program"] = {
        "b": {"computation": "b = c0*d[j,k]"},
        "d": {"computation": "d = c0*b[j,k]"},
    }
    with pytest.raises(ValueError, match="cyclic"):
        build_stencil_program(spec)
