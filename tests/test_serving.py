"""Serving subsystem: paged KV pool, continuous batching, compiled step.

Covers the ISSUE-6 acceptance invariants: no page leaks and consistent
block tables across admit/evict churn, chunked prefill == whole-prompt
prefill bit-for-bit, the compiled (B, ctx)-bucketed decode step matching
the uncompiled ``decode_step`` token for token (two attention configs +
RWKV), grid conversion of the in-step attention, compilation-cache hits
across repeated shape buckets, and the env-configurable cache capacity.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models.transformer import TransformerLM
from repro.pipeline.cache import (CACHE_SIZE_ENV, CompilationCache,
                                  _default_max_entries)
from repro.serving import KVPagePool, PageError, Scheduler


def _f32(cfg):
    """Serving math must match decode_step bit-for-bit; fp32 activations
    make argmax ties impossible to hit by rounding."""
    return dataclasses.replace(cfg, activation_dtype="float32")


def _model(arch: str, f32=True):
    cfg = get_config(arch).reduced()
    if f32:
        cfg = _f32(cfg)
    model = TransformerLM(cfg)
    return model, model.init(jax.random.PRNGKey(0))


def _reference_decode(model, params, prompts, new_tokens, max_model_len):
    """Greedy decode through jax.jit(decode_step) on a dense cache."""
    B = prompts.shape[0]
    cache = model.init_cache(B, max_model_len)
    step = jax.jit(model.decode_step)
    logits, cache = step(params, cache, jnp.asarray(prompts, jnp.int32))
    tokens = [[int(jnp.argmax(logits[b, -1]))] for b in range(B)]
    for _ in range(new_tokens - 1):
        toks = jnp.asarray([[t[-1]] for t in tokens], jnp.int32)
        logits, cache = step(params, cache, toks)
        for b in range(B):
            tokens[b].append(int(jnp.argmax(logits[b, 0])))
    return tokens, logits


# ---------------------------------------------------------------------------
# KVPagePool
# ---------------------------------------------------------------------------
class TestPagePool:
    def _pool(self, n_pages=8, page_size=4):
        return KVPagePool({0: (2, 8)}, n_pages, page_size)

    def test_null_page_never_allocated(self):
        pool = self._pool()
        pages = pool.alloc(pool.num_free, reserved=False)
        assert 0 not in pages
        assert len(pages) == pool.n_pages - 1

    def test_reserve_alloc_free_roundtrip(self):
        pool = self._pool()
        pool.reserve(3)
        assert pool.available == 7 - 3
        pages = pool.alloc(2)
        assert pool._reserved == 1
        pool.free(pages)
        pool.unreserve(1)
        assert pool.num_free == 7 and pool.available == 7

    def test_overcommit_rejected(self):
        pool = self._pool()
        pool.reserve(5)
        with pytest.raises(PageError):
            pool.reserve(3)
        with pytest.raises(PageError):
            pool.alloc(3, reserved=False)

    def test_double_free_and_null_free_rejected(self):
        pool = self._pool()
        (pg,) = pool.alloc(1, reserved=False)
        pool.free([pg])
        with pytest.raises(PageError):
            pool.free([pg])
        with pytest.raises(PageError):
            pool.free([0])

    def test_write_prefill_pads_to_page(self):
        pool = self._pool()
        pages = pool.alloc(2, reserved=False)
        k = jnp.ones((6, 2, 8), jnp.float32)  # 6 tokens over 2x4-slot pages
        pool.write_prefill(0, pages, k, 2 * k)
        got = pool.k_pages[0][jnp.asarray(pages)].reshape(8, 2, 8)
        assert np.all(np.asarray(got[:6]) == 1.0)
        assert np.all(np.asarray(got[6:]) == 0.0)


# ---------------------------------------------------------------------------
# Scheduler invariants under churn
# ---------------------------------------------------------------------------
class TestSchedulerInvariants:
    def test_admit_evict_no_leaks(self):
        model, params = _model("starcoder2-3b", f32=False)
        sched = Scheduler(model, params, max_slots=3, page_size=8,
                          n_pages=24, max_model_len=64, prefill_chunk=4,
                          compile_cache=CompilationCache())
        rng = np.random.RandomState(0)
        for i in range(7):
            L = int(rng.randint(2, 14))
            sched.submit(list(rng.randint(0, model.cfg.vocab, size=L)),
                         int(rng.randint(2, 9)))
            if i % 2 == 0:
                sched.step()
                sched.check_invariants()
        reqs = sched.run()
        sched.check_invariants()
        assert len(reqs) == 7
        assert all(r.done for r in reqs)
        # every page returned, every reservation released
        assert sched.pool.num_free == sched.pool.n_pages - 1
        assert sched.pool._reserved == 0
        assert not np.any(sched.block_table)

    def test_queue_waits_for_pages(self):
        model, params = _model("starcoder2-3b", f32=False)
        # room for exactly one request's worst case at a time
        sched = Scheduler(model, params, max_slots=2, page_size=8,
                          n_pages=4, max_model_len=32, prefill_chunk=8,
                          compile_cache=CompilationCache())
        for _ in range(2):
            sched.submit(list(range(1, 9)), 8)  # 8+8 tokens -> 2 pages
        sched.step()
        assert sum(r is not None for r in sched.slots) == 1
        assert len(sched.queue) == 1
        reqs = sched.run()
        assert len(reqs) == 2
        sched.check_invariants()


# ---------------------------------------------------------------------------
# Chunked prefill == whole-prompt prefill
# ---------------------------------------------------------------------------
class TestChunkedPrefill:
    @pytest.mark.parametrize("chunk", [1, 3, 8])
    def test_chunked_matches_whole(self, chunk):
        model, params = _model("gemma3-4b")
        prompt = np.arange(1, 12) % model.cfg.vocab
        L = len(prompt)
        step = jax.jit(model.decode_step)

        whole_cache = model.init_cache(1, L)
        whole_logits, whole_cache = step(
            params, whole_cache, jnp.asarray(prompt[None], jnp.int32))

        cache = model.init_cache(1, L)
        logits = None
        i = 0
        while i < L:
            logits, cache = step(
                params, cache,
                jnp.asarray(prompt[None, i:i + chunk], jnp.int32))
            i += chunk

        # XLA CPU selects different matmul kernels for (s=L) vs (s=chunk)
        # activations, so equality across chunkings is to rounding, not
        # bit-for-bit; the sampled token must still be identical.
        wl, cl = np.asarray(whole_logits[0, -1]), np.asarray(logits[0, -1])
        np.testing.assert_allclose(wl, cl, rtol=2e-6, atol=2e-6)
        assert int(wl.argmax()) == int(cl.argmax())
        for leaf_w, leaf_c in zip(jax.tree.leaves(whole_cache),
                                  jax.tree.leaves(cache)):
            np.testing.assert_allclose(
                np.asarray(leaf_w, np.float32),
                np.asarray(leaf_c, np.float32), rtol=2e-6, atol=2e-6)


# ---------------------------------------------------------------------------
# Compiled step == uncompiled decode_step
# ---------------------------------------------------------------------------
class TestCompiledStep:
    @pytest.mark.parametrize("arch", ["starcoder2-3b", "gemma3-4b",
                                      "rwkv6-7b"])
    def test_tokens_match_reference(self, arch):
        model, params = _model(arch)
        B, L, new = 4, 6, 5
        prompts = np.asarray(jax.random.randint(
            jax.random.PRNGKey(1), (B, L), 0, model.cfg.vocab))
        ref_tokens, _ = _reference_decode(model, params, prompts, new, 64)

        sched = Scheduler(model, params, max_slots=4, page_size=8,
                          n_pages=32, max_model_len=64, prefill_chunk=4,
                          compile_cache=CompilationCache())
        for b in range(B):
            sched.submit(list(map(int, prompts[b])), new)
        reqs = sched.run()
        sched.check_invariants()
        for b, r in enumerate(reqs):
            assert r.tokens_out == ref_tokens[b], (
                f"slot {b}: {r.tokens_out} != reference {ref_tokens[b]}")

    def test_grid_kernel_in_compiled_step(self):
        """At a grid-converting bucket the per-layer attention maps become
        Pallas grid kernels inside the compiled step (dtype-aware tiling:
        fp32 -> 8-row sublane blocks, so B=16 yields >= 2 grid steps)."""
        model, params = _model("starcoder2-3b")
        B, L, new = 16, 6, 4
        prompts = np.asarray(jax.random.randint(
            jax.random.PRNGKey(1), (B, L), 0, model.cfg.vocab))
        ref_tokens, _ = _reference_decode(model, params, prompts, new, 64)

        sched = Scheduler(model, params, max_slots=B, page_size=8,
                          n_pages=64, max_model_len=64, prefill_chunk=8,
                          dtype_aware_sublanes=True,
                          compile_cache=CompilationCache())
        for b in range(B):
            sched.submit(list(map(int, prompts[b])), new)
        reqs = sched.run()
        for b, r in enumerate(reqs):
            assert r.tokens_out == ref_tokens[b]

        report = sched.compiler._steps[max(sched.compiler._steps)].report
        kernels = report.get("grid_kernels", [])
        assert len(kernels) == model.cfg.n_layers
        assert all("attn" in k for k in kernels)
        blocks = report["grid_converted"][0]["block_shape"]
        assert blocks[0] == 8  # fp32 sublane rows

    def test_padding_lanes_do_not_disturb_active(self):
        """A batch of 3 in 4 slots runs with one padding lane (null-page
        writes + masked gathers); results must equal the dense 3-lane
        reference."""
        model, params = _model("starcoder2-3b")
        B, L, new = 3, 5, 4
        prompts = np.asarray(jax.random.randint(
            jax.random.PRNGKey(2), (B, L), 0, model.cfg.vocab))
        ref_tokens, _ = _reference_decode(model, params, prompts, new, 64)
        sched = Scheduler(model, params, max_slots=4, page_size=8,
                          n_pages=32, max_model_len=64, prefill_chunk=4,
                          compile_cache=CompilationCache())
        for b in range(B):
            sched.submit(list(map(int, prompts[b])), new)
        reqs = sched.run()
        for b, r in enumerate(reqs):
            assert r.tokens_out == ref_tokens[b]


# ---------------------------------------------------------------------------
# Sampling beyond greedy argmax
# ---------------------------------------------------------------------------
class TestSampling:
    def test_invalid_sampling_args_rejected(self):
        model, params = _model("starcoder2-3b", f32=False)
        with pytest.raises(ValueError):
            Scheduler(model, params, temperature=-0.1)
        with pytest.raises(ValueError):
            Scheduler(model, params, top_k=0)

    def test_sample_respects_temperature_and_top_k(self):
        model, params = _model("starcoder2-3b", f32=False)
        row = np.asarray([0.0, 3.0, 2.5, -1.0, 2.9], np.float32)
        greedy = Scheduler(model, params, compile_cache=CompilationCache())
        assert greedy._sample(row) == 1  # temperature 0 == argmax
        sched = Scheduler(model, params, temperature=1.0, top_k=2, seed=11,
                          compile_cache=CompilationCache())
        draws = {sched._sample(row) for _ in range(200)}
        assert draws <= {1, 4}  # support truncated to the top-2 logits
        assert draws == {1, 4}  # both survivors actually drawn

    def test_seeded_sampling_deterministic(self):
        """Same seed -> identical token streams through the full
        scheduler (prefill sample + batched decode samples); greedy
        remains the temperature=0 default."""
        model, params = _model("starcoder2-3b", f32=False)
        rng = np.random.RandomState(5)
        prompts = [list(rng.randint(1, model.cfg.vocab, size=6))
                   for _ in range(3)]

        def decode(seed):
            sched = Scheduler(model, params, max_slots=3, page_size=8,
                              n_pages=24, max_model_len=64, prefill_chunk=4,
                              compile_cache=CompilationCache(),
                              temperature=0.8, top_k=8, seed=seed)
            for p in prompts:
                sched.submit(p, 6)
            return [r.tokens_out for r in sched.run()]

        first = decode(seed=3)
        assert first == decode(seed=3)
        assert any(len(set(t)) > 1 for t in first)  # it did sample tokens


# ---------------------------------------------------------------------------
# Compilation-cache behavior
# ---------------------------------------------------------------------------
class TestServingCompileCache:
    def test_bucket_reuse_hits_cache(self):
        model, params = _model("starcoder2-3b", f32=False)
        cc = CompilationCache()

        def run_once():
            sched = Scheduler(model, params, max_slots=3, page_size=8,
                              n_pages=24, max_model_len=64,
                              prefill_chunk=4, compile_cache=cc)
            for _ in range(3):
                sched.submit(list(range(1, 6)), 4)
            sched.run()

        run_once()
        first = dict(cc.stats)
        assert first["misses"] >= 1
        run_once()  # identical workload -> identical (B, ctx) buckets
        second = cc.stats
        assert second["misses"] == first["misses"]
        assert second["hits"] == first["hits"] + first["misses"]

    def test_env_var_configures_capacity(self, monkeypatch):
        monkeypatch.setenv(CACHE_SIZE_ENV, "2")
        assert _default_max_entries() == 2
        cc = CompilationCache()
        assert cc.max_entries == 2
        for i in range(4):
            cc.store(i, i)
        assert len(cc) == 2
        # explicit argument wins over the env var
        assert CompilationCache(max_entries=7).max_entries == 7

    def test_env_var_rejects_garbage(self, monkeypatch):
        monkeypatch.setenv(CACHE_SIZE_ENV, "zero")
        with pytest.raises(ValueError):
            CompilationCache()
        monkeypatch.setenv(CACHE_SIZE_ENV, "0")
        with pytest.raises(ValueError):
            CompilationCache()
