"""Two-backend equivalence (paper: Xilinx/Intel -> pallas/jnp) and
multi-level Dot expansions (§3.3.1)."""
import numpy as np
import pytest

import repro.kernels  # noqa: F401
from repro.frontends import blas
from repro.frontends.api import Program
from repro.transforms import DeviceOffload, StreamingComposition


def build_axpydot(n):
    p = Program("axpydot")
    a = p.scalar_input("a", "float32")
    x, y, w = (p.input(nm, (n,)) for nm in ("x", "y", "w"))
    p.output("result", blas.dot(blas.axpy(a, x, y), w))
    return p.finalize()


@pytest.mark.parametrize("backend", ["jnp", "pallas"])
def test_backend_equivalence(backend):
    rng = np.random.default_rng(1)
    n = 2048
    a = np.float32(-0.3)
    x, y, w = (rng.standard_normal(n).astype(np.float32) for _ in range(3))
    sdfg = build_axpydot(n)
    sdfg.apply(DeviceOffload)
    sdfg.apply(StreamingComposition)
    c = sdfg.compile(backend)
    if backend == "pallas":
        assert c.report["fused_regions"] == ["Axpy+Dot"]
    out = c(a=a, x=x, y=y, w=w)
    exp = np.dot((a * x + y).astype(np.float32), w)
    np.testing.assert_allclose(np.asarray(out["result"]).ravel()[0], exp,
                               rtol=1e-4)


@pytest.mark.parametrize("level", ["xla", "accumulate", "partial_sums"])
def test_dot_expansion_levels(level):
    """§3.3.1: Intel native accumulation vs Xilinx partial sums — same
    semantics, different subgraphs."""
    rng = np.random.default_rng(2)
    n = 256
    x, w = (rng.standard_normal(n).astype(np.float32) for _ in range(2))
    p = Program("dot")
    xh, wh = p.input("x", (n,)), p.input("w", (n,))
    p.output("result", blas.dot(xh, wh))
    sdfg = p.finalize()
    c = sdfg.compile("jnp", expansion_level=level)
    out = c(x=x, w=w)
    np.testing.assert_allclose(np.asarray(out["result"]).ravel()[0],
                               np.dot(x, w), rtol=1e-4)


def test_systolic_gemm_expansion():
    """Paper Fig. 6: unrolled map over P PEs chained by pipes."""
    rng = np.random.default_rng(3)
    N, K, M = 16, 12, 8
    A = rng.standard_normal((N, K)).astype(np.float32)
    B = rng.standard_normal((K, M)).astype(np.float32)
    p = Program("mm")
    Ah, Bh = p.input("A", (N, K)), p.input("B", (K, M))
    p.output("C", blas.gemm(Ah, Bh))
    sdfg = p.finalize()
    sdfg.metadata["systolic_pes"] = 4
    c = sdfg.compile("jnp", expansion_level="systolic")
    out = c(A=A, B=B)
    np.testing.assert_allclose(np.asarray(out["C"]), A @ B, rtol=1e-4,
                               atol=1e-5)
    # P PEs plus two readers materialized in the graph
    labels = [n.label for st in sdfg.states for n in st.nodes]
    assert any("read_A" in l for l in labels)
    assert any("read_B" in l for l in labels)


def test_gemv_ger_expansions():
    rng = np.random.default_rng(4)
    n, m = 24, 16
    A = rng.standard_normal((n, m)).astype(np.float32)
    x = rng.standard_normal(m).astype(np.float32)
    u = rng.standard_normal(n).astype(np.float32)
    v = rng.standard_normal(m).astype(np.float32)
    p = Program("gemver_bits")
    Ah = p.input("A", (n, m))
    xh, uh, vh = p.input("x", (m,)), p.input("u", (n,)), p.input("v", (m,))
    A2 = blas.ger(Ah, uh, vh, alpha=0.5)
    y = blas.gemv(A2, xh)
    yt = blas.gemv(A2, uh, trans=True)
    p.output("y", y)
    p.output("yt", yt)
    sdfg = p.finalize()
    for level in ("xla", "generic"):
        c = sdfg.compile("jnp", expansion_level=level) if level == "xla" \
            else build_and_compile_generic(n, m)
        out = c(A=A, x=x, u=u, v=v)
        A2_np = A + 0.5 * np.outer(u, v)
        np.testing.assert_allclose(np.asarray(out["y"]), A2_np @ x,
                                   rtol=1e-3, atol=1e-4)
        np.testing.assert_allclose(np.asarray(out["yt"]), A2_np.T @ u,
                                   rtol=1e-3, atol=1e-4)


def build_and_compile_generic(n, m):
    p = Program("gemver_bits")
    Ah = p.input("A", (n, m))
    xh, uh, vh = p.input("x", (m,)), p.input("u", (n,)), p.input("v", (m,))
    A2 = blas.ger(Ah, uh, vh, alpha=0.5)
    p.output("y", blas.gemv(A2, xh))
    p.output("yt", blas.gemv(A2, uh, trans=True))
    return p.finalize().compile("jnp", expansion_level="generic")


def test_dynamic_stride_memlets_fall_back_to_sequential():
    """A subset whose STEP rides a map parameter used to crash the whole
    compile with NotImplementedError out of read_memlet; it must degrade
    to the sequential structural interpreter on both backends, and the
    pallas pipeline must record the scope in grid_fallbacks."""
    import jax.numpy as jnp

    from repro.core.memlet import Memlet, Range, Subset
    from repro.core.sdfg import SDFG
    from repro.core.symbolic import sym
    from repro.pipeline import lower

    n = 8
    s = SDFG("dynstride")
    s.add_array("x", (2 * n,), "float32")
    s.add_array("out", (n,), "float32")
    st = s.add_state("main", is_start=True)
    i = sym("i")
    # read x[0 : 2n : i+1] — a per-iteration stride; sum it into out[i]
    st.add_mapped_tasklet(
        "dyn", {"i": (0, n)},
        inputs={"v": Memlet.simple(
            "x", Subset([Range.make(0, 2 * n, i + 1)]))},
        outputs={"o": Memlet.simple("out", Subset.indices([i]))},
        fn=lambda v: jnp.sum(v))
    x = np.random.default_rng(20).standard_normal(2 * n).astype(np.float32)
    ref = np.array([x[0:2 * n:k + 1].sum() for k in range(n)],
                   dtype=np.float32)
    oj = np.asarray(lower(s).compile("jnp", cache=None)(x=x)["out"])
    np.testing.assert_allclose(oj, ref, rtol=1e-5)
    cp = lower(s).compile("pallas", cache=None)
    assert cp.report["grid_kernels"] == []
    assert any("strided" in reason or "stride" in reason
               for _, reason in cp.report["grid_fallbacks"])
    np.testing.assert_allclose(np.asarray(cp(x=x)["out"]), ref, rtol=1e-5)
