"""Fault-tolerant serving (ISSUE 8): preemption, deadlines, degradation
ladder, fault injection, and snapshot-exact recovery.

The acceptance bar is *token-exactness under faults*: for every recovery
path — preemption + re-prefill, fallback re-run after an injected step
exception or NaN logits, recompute recovery under buffer donation,
snapshot/restore mid-decode — the greedy token streams of non-faulted
requests must be byte-identical to a fault-free run, and every request
must terminate with a typed ``finish_reason``.
"""
import dataclasses

import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.models.transformer import TransformerLM
from repro.pipeline.cache import CompilationCache
from repro.runtime.cluster_sim import FaultPlan, SimulatedCluster
from repro.serving import (FINISH_REASONS, FaultInjector, Scheduler,
                           ServeFaultPlan, StepWatchdog)

# one cache for the whole module: every test uses the same scheduler
# geometry, so each (B, ctx) bucket lowers exactly once
CACHE = CompilationCache()

PROMPTS = [[1, 2, 3], [4, 5], [6, 7, 8, 9], [2, 2]]


@pytest.fixture(scope="module")
def model_params():
    cfg = dataclasses.replace(get_config("starcoder2-3b").reduced(),
                              activation_dtype="float32")
    model = TransformerLM(cfg)
    return model, model.init(jax.random.PRNGKey(0))


def mk(model_params, max_slots=4, **kw):
    model, params = model_params
    return Scheduler(model, params, max_slots=max_slots, page_size=4,
                     n_pages=32, max_model_len=32, prefill_chunk=4,
                     cache_dtype="float32", compile_cache=CACHE, **kw)


def streams(reqs):
    return {r.rid: list(r.tokens_out) for r in reqs}


@pytest.fixture(scope="module")
def baseline(model_params):
    """Fault-free greedy streams for PROMPTS."""
    s = mk(model_params)
    for p in PROMPTS:
        s.submit(p, 8)
    out = streams(s.run())
    s.check_invariants()
    return out


def run_plan(model_params, plan, **kw):
    s = mk(model_params, injector=FaultInjector(plan), **kw)
    for p in PROMPTS:
        s.submit(p, 8)
    out = streams(s.run())
    s.check_invariants()
    return s, out


# ---------------------------------------------------------------------------
# Preemption (and the page-boundary crash regression)
# ---------------------------------------------------------------------------
class TestPreemption:
    def test_page_pressure_preempts_instead_of_crashing(self, model_params,
                                                        baseline):
        """Regression: Scheduler.step() used an unguarded pool.alloc(1)
        at page-boundary crossings — pool pressure killed the server.
        Now it preempts the youngest request and the run completes."""
        plan = ServeFaultPlan(page_pressure_at=1,
                              page_pressure_release_at=8)
        s, out = run_plan(model_params, plan)
        assert s.n_preemptions >= 1
        assert out == baseline  # preempted streams resume token-exact
        assert all(r.finish_reason in FINISH_REASONS for r in s.finished)

    def test_direct_seize_mid_run(self, model_params, baseline):
        """Same regression without the injector: seize the pool by hand
        between steps."""
        s = mk(model_params)
        for p in PROMPTS:
            s.submit(p, 8)
        s.step()
        seized = s.pool.seize()
        for _ in range(4):
            s.step()  # crossings preempt, never raise
            s.check_invariants()
        s.pool.release(seized)
        out = streams(s.run())
        s.check_invariants()
        assert out == baseline

    def test_preempted_request_keeps_tokens(self, model_params):
        plan = ServeFaultPlan(page_pressure_at=1,
                              page_pressure_release_at=10)
        s, _ = run_plan(model_params, plan)
        evs = [e for e in s.events if e["kind"] == "preempt"]
        assert evs and all(e["kept_tokens"] > 0 for e in evs)

    def test_preemption_limit_finishes_typed(self, model_params):
        """A request evicted more than max_preemptions times stops
        thrashing and finishes ``preempted_limit``."""
        plan = ServeFaultPlan(page_pressure_at=1,
                              page_pressure_release_at=200)
        s = mk(model_params, max_slots=1, max_preemptions=0,
               injector=FaultInjector(plan))
        s.submit([1, 2, 3, 4, 5, 6, 7], 12)  # crosses a page boundary
        s.run()
        s.check_invariants()
        assert [r.finish_reason for r in s.finished] == ["preempted_limit"]


# ---------------------------------------------------------------------------
# Deadlines and TTLs
# ---------------------------------------------------------------------------
class TestDeadlines:
    def test_queue_ttl_and_active_deadline(self, model_params):
        clk = [0.0]
        s = mk(model_params, max_slots=1, clock=lambda: clk[0],
               queue_ttl_s=5.0)
        s.submit(PROMPTS[0], 20, deadline_s=2.0)   # active, tight deadline
        s.submit(PROMPTS[1], 8)                    # queued, TTL 5
        s.submit(PROMPTS[2], 8)                    # queued, TTL 5
        for _ in range(3):
            s.step()
            clk[0] += 1.5
        clk[0] += 10.0  # everything still waiting is now past its limit
        s.run()
        s.check_invariants()
        reasons = {r.rid: r.finish_reason for r in s.finished}
        assert reasons[0] == "timeout"          # active past deadline
        assert "timeout" in (reasons[1], reasons[2])  # queue TTL
        assert all(v in FINISH_REASONS for v in reasons.values())

    def test_no_deadline_never_times_out(self, model_params, baseline):
        clk = [0.0]
        s = mk(model_params, clock=lambda: clk[0])
        for p in PROMPTS:
            s.submit(p, 8)
        clk[0] += 1e9
        out = streams(s.run())
        assert out == baseline


# ---------------------------------------------------------------------------
# Degradation ladder
# ---------------------------------------------------------------------------
class TestDegradationLadder:
    def test_injected_exception_falls_back_token_exact(self, model_params,
                                                       baseline):
        plan = ServeFaultPlan(step_exception_at=1)
        s, out = run_plan(model_params, plan)
        assert s.n_fallback_steps >= 1
        assert s.watchdog.faults_of("step_exception")
        assert out == baseline

    def test_nan_logits_rerun_token_exact(self, model_params, baseline):
        plan = ServeFaultPlan(nan_logits_at=2)
        s, out = run_plan(model_params, plan)
        assert s.watchdog.faults_of("nan_logits")
        assert out == baseline

    def test_persistent_nan_lane_fails_only_that_request(self, model_params,
                                                         baseline):
        """One lane's logits stay NaN: that request finishes ``failed``
        after max_failures; the other lanes stream on untouched."""
        plan = ServeFaultPlan(nan_logits_at=1, nan_slots=(0,),
                              nan_persistent=True)
        s, out = run_plan(model_params, plan, max_failures=2)
        reasons = {r.rid: r.finish_reason for r in s.finished}
        assert reasons[0] == "failed"
        for rid in (1, 2, 3):
            assert out[rid] == baseline[rid]

    def test_persistent_exception_fails_everyone_typed(self, model_params):
        plan = ServeFaultPlan(step_exception_at=0,
                              exception_persistent=True)
        s, _ = run_plan(model_params, plan, max_failures=2)
        assert {r.finish_reason for r in s.finished} == {"failed"}
        assert len(s.finished) == len(PROMPTS)

    def test_recompute_recovery_under_donation(self, model_params,
                                               baseline):
        """With buffer donation on, a failed step's inputs are consumed —
        recovery must recompute from tokens (preempt-all + re-prefill)
        and still produce byte-identical streams."""
        plan = ServeFaultPlan(step_exception_at=1)
        s, out = run_plan(model_params, plan, donate=True)
        assert s.n_recomputes >= 1
        assert s.n_fallback_steps == 0  # rung 2 impossible when donating
        assert out == baseline

    def test_compile_failure_degrades_then_recovers(self, model_params,
                                                    baseline):
        """A failing grid compile serves the jnp-jit rung and retries
        with capped backoff until the compile succeeds again."""
        plan = ServeFaultPlan(compile_fail_buckets="all",
                              compile_fail_times=2)
        s, out = run_plan(model_params, plan)
        kinds = [e["kind"] for e in s.compiler.events]
        assert "compile_fallback" in kinds
        assert "compile_retry_failed" in kinds
        assert "compile_recovered" in kinds
        assert out == baseline

    def test_slow_step_trips_watchdog(self, model_params):
        plan = ServeFaultPlan(slow_step_at=6, slow_factor=1e6)
        wd = StepWatchdog(deadline_s=3600.0, straggler_factor=4.0)
        s = mk(model_params, injector=FaultInjector(plan), watchdog=wd)
        for p in PROMPTS:
            s.submit(p, 8)
        s.run()
        assert any(e["kind"] in ("straggler", "dead")
                   for e in wd.events)


# ---------------------------------------------------------------------------
# Combined acceptance plan
# ---------------------------------------------------------------------------
def test_combined_fault_plan_token_exact(model_params, baseline):
    """ISSUE-8 acceptance: one step failure + forced page pressure
    (>= 1 preemption) + one NaN-logits step in a single run — every
    request finishes with a typed reason and the greedy streams are
    byte-identical to the fault-free run."""
    plan = ServeFaultPlan(step_exception_at=1, page_pressure_at=2,
                          page_pressure_release_at=8, nan_logits_at=5)
    s, out = run_plan(model_params, plan)
    st = s.stats()
    assert st["preemptions"] >= 1
    assert st["fallback_steps"] >= 2  # exception + NaN re-runs
    assert all(r.finish_reason in FINISH_REASONS for r in s.finished)
    assert out == baseline
    # the whole timeline is observable
    kinds = [e["kind"] for e in st["watchdog_events"]]
    assert "step_exception" in kinds and "nan_logits" in kinds


# ---------------------------------------------------------------------------
# Snapshot / restore
# ---------------------------------------------------------------------------
class TestSnapshot:
    def test_mid_decode_restore_token_exact(self, model_params, baseline):
        s = mk(model_params)
        for p in PROMPTS:
            s.submit(p, 8)
        for _ in range(3):
            s.step()
        snap = s.snapshot()
        restored = mk(model_params).restore(snap)
        out_orig = streams(s.run())
        out_rest = streams(restored.run())
        restored.check_invariants()
        assert out_orig == baseline
        assert out_rest == baseline

    def test_snapshot_is_deep_copy(self, model_params):
        s = mk(model_params)
        for p in PROMPTS:
            s.submit(p, 8)
        s.step()
        snap = s.snapshot()
        live = {r.rid: list(r.tokens_out)
                for r in s.slots if r is not None}
        s.run()  # keep generating: must not disturb the snapshot
        for d in snap["slots"]:
            if d is not None:
                assert d["tokens_out"] == live[d["rid"]]

    def test_restore_preserves_sampling_rng(self, model_params):
        """Non-greedy sampling resumes identically because the numpy
        generator state rides in the snapshot."""
        def build():
            return mk(model_params, temperature=0.8, top_k=8, seed=7)

        s = build()
        for p in PROMPTS:
            s.submit(p, 8)
        for _ in range(3):
            s.step()
        snap = s.snapshot()
        out_orig = streams(s.run())
        out_rest = streams(build().restore(snap).run())
        assert out_orig == out_rest

    def test_restore_rejects_config_mismatch(self, model_params):
        s = mk(model_params)
        s.submit(PROMPTS[0], 4)
        s.step()
        snap = s.snapshot()
        other = mk(model_params, max_slots=2)
        with pytest.raises(ValueError, match="config"):
            other.restore(snap)

    def test_snapshot_under_simulated_cluster_faults(self, model_params,
                                                     baseline):
        """Drive the scheduler as a SimulatedCluster workload: host death
        restores the latest scheduler snapshot and the decode replays
        token-exact (the serving analogue of trainer restart-resume)."""
        s = mk(model_params)
        for p in PROMPTS:
            s.submit(p, 8)
        saved = {}

        def save_ckpt(step):
            saved["snap"] = s.snapshot()
            saved["step"] = step

        def restore_ckpt():
            s.restore(saved["snap"])
            return saved["step"]

        save_ckpt(0)
        sim = SimulatedCluster(n_hosts=2,
                               plan=FaultPlan(die_at_step=5, die_host=1))
        out = sim.run(14, lambda step: s.step(), save_ckpt, restore_ckpt,
                      checkpoint_every=3)
        assert out["restarts"] and out["wasted_steps"] >= 1
        assert out["host_status"][1] == "dead"
        final = streams(s.run())
        s.check_invariants()
        assert final == baseline


def test_stats_shape(model_params):
    s = mk(model_params)
    s.submit(PROMPTS[0], 4)
    s.run()
    st = s.stats()
    for key in ("n_steps", "n_decode_steps", "finish_reasons",
                "preemptions", "fallback_steps", "recomputes",
                "watchdog_events", "compiler_events", "pool"):
        assert key in st
    assert st["finish_reasons"] == {"max_tokens": 1}
