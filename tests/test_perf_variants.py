"""Optimized perf variants must be numerically equivalent to baselines."""
import dataclasses

import numpy as np
import pytest

import jax
import jax.numpy as jnp
from repro.configs import get_config
from repro.models import build_model, example_batch
from repro.models.layers import (attention_chunked, attention_xla, moe_block)

RNG = np.random.default_rng(5)


@pytest.mark.parametrize("window", [None, 16])
@pytest.mark.parametrize("gqa", [(8, 8), (8, 2)])
def test_chunked_attention_matches_naive(window, gqa):
    hq, hkv = gqa
    b, s, dh = 2, 128, 32
    q = jnp.asarray(RNG.standard_normal((b, s, hq, dh)), jnp.float32)
    k = jnp.asarray(RNG.standard_normal((b, s, hkv, dh)), jnp.float32)
    v = jnp.asarray(RNG.standard_normal((b, s, hkv, dh)), jnp.float32)
    naive = attention_xla(q, k, v, causal=True, window=window)
    chunked = attention_chunked(q, k, v, causal=True, window=window, bk=32)
    np.testing.assert_allclose(np.asarray(chunked), np.asarray(naive),
                               rtol=2e-4, atol=2e-4)


def test_sort_moe_matches_onehot():
    b, s, d, e, f, k = 2, 16, 8, 4, 16, 2
    x = jnp.asarray(RNG.standard_normal((b, s, d)), jnp.float32)
    router = jnp.asarray(RNG.standard_normal((d, e)), jnp.float32)
    wg = jnp.asarray(RNG.standard_normal((e, d, f)) * 0.3, jnp.float32)
    wu = jnp.asarray(RNG.standard_normal((e, d, f)) * 0.3, jnp.float32)
    wd = jnp.asarray(RNG.standard_normal((e, f, d)) * 0.3, jnp.float32)
    out1, aux1 = moe_block(x, router, wg, wu, wd, top_k=k,
                           capacity_factor=8.0, dispatch="onehot")
    out2, aux2 = moe_block(x, router, wg, wu, wd, top_k=k,
                           capacity_factor=8.0, dispatch="sort")
    np.testing.assert_allclose(np.asarray(out1), np.asarray(out2),
                               rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(float(aux1), float(aux2), rtol=1e-5)


@pytest.mark.parametrize("arch", ["yi-34b", "llama4-scout-17b-a16e"])
def test_optimized_model_matches_baseline(arch):
    base_cfg = dataclasses.replace(get_config(arch).reduced(),
                                   activation_dtype="float32")
    opt_cfg = dataclasses.replace(base_cfg, attention_impl="chunked",
                                  moe_dispatch="sort")
    m1, m2 = build_model(base_cfg), build_model(opt_cfg)
    params = m1.init(jax.random.PRNGKey(0))
    batch = {kk: jnp.asarray(v) for kk, v in
             example_batch(base_cfg, "train", 2, 32).items()}
    l1, _ = m1.forward(params, batch)
    l2, _ = m2.forward(params, batch)
    np.testing.assert_allclose(np.asarray(l2), np.asarray(l1),
                               rtol=3e-3, atol=3e-3)
