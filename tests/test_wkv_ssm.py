"""Chunked WKV6 / chunked selective-scan vs sequential references."""
import numpy as np
import pytest

hypothesis = pytest.importorskip(
    "hypothesis", reason="property tests need the optional 'hypothesis' "
    "package (pip install repro[test])")
from hypothesis import given, settings, strategies as st  # noqa: E402

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
from repro.models import blocks  # noqa: E402

RNG = np.random.default_rng(7)


def _wkv_inputs(B, S, H, hd):
    r, k, v = (RNG.standard_normal((B, S, H, hd)).astype(np.float32) * 0.5
               for _ in range(3))
    # decays in the same range the model produces: exp(-0.5 - 3*sigmoid)
    w = np.exp(-0.5 - 3.0 * RNG.uniform(0, 1, (B, S, H, hd))
               ).astype(np.float32)
    u = (RNG.standard_normal((H, hd)) * 0.3).astype(np.float32)
    s0 = RNG.standard_normal((B, H, hd, hd)).astype(np.float32) * 0.1
    return map(jnp.asarray, (r, k, v, w, u, s0))


@pytest.mark.parametrize("S", [16, 64, 128])
def test_wkv_chunked_matches_sequential(S):
    r, k, v, w, u, s0 = _wkv_inputs(2, S, 3, 8)
    out_ref, st_ref = blocks._wkv_scan(r, k, v, w, u, s0)
    out_chk, st_chk = blocks._wkv_chunked(r, k, v, w, u, s0)
    np.testing.assert_allclose(np.asarray(out_chk), np.asarray(out_ref),
                               rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(st_chk), np.asarray(st_ref),
                               rtol=2e-4, atol=2e-4)


@given(b=st.integers(1, 3), h=st.integers(1, 4), hd=st.sampled_from([4, 8]))
@settings(max_examples=8, deadline=None)
def test_wkv_property(b, h, hd):
    r, k, v, w, u, s0 = _wkv_inputs(b, 32, h, hd)
    out_ref, _ = blocks._wkv_scan(r, k, v, w, u, s0)
    out_chk, _ = blocks._wkv_chunked(r, k, v, w, u, s0)
    np.testing.assert_allclose(np.asarray(out_chk), np.asarray(out_ref),
                               rtol=3e-4, atol=3e-4)


def test_wkv_decode_consistency():
    """Chunked prefill then per-token sequential steps == full sequential."""
    r, k, v, w, u, s0 = _wkv_inputs(1, 48, 2, 8)
    out_full, st_full = blocks._wkv_scan(r, k, v, w, u, s0)
    out_pre, st_pre = blocks._wkv_chunked(r[:, :32], k[:, :32], v[:, :32],
                                          w[:, :32], u, s0)
    st = st_pre
    outs = [out_pre]
    for t in range(32, 48):
        o, st = blocks._wkv_scan(r[:, t:t+1], k[:, t:t+1], v[:, t:t+1],
                                 w[:, t:t+1], u, st)
        outs.append(o)
    np.testing.assert_allclose(np.asarray(jnp.concatenate(outs, axis=1)),
                               np.asarray(out_full), rtol=3e-4, atol=3e-4)


# -- mamba selective scan ---------------------------------------------------
def _ssm_inputs(B, S, Din, N):
    u = RNG.standard_normal((B, S, Din)).astype(np.float32)
    ldA = -np.abs(RNG.uniform(0.01, 2.0, (B, S, Din, N))).astype(np.float32)
    dBu = (RNG.standard_normal((B, S, Din, N)) * 0.2).astype(np.float32)
    C = RNG.standard_normal((B, S, N)).astype(np.float32)
    s0 = (RNG.standard_normal((B, Din, N)) * 0.1).astype(np.float32)
    return map(jnp.asarray, (u, ldA, dBu, C, s0))


@pytest.mark.parametrize("S", [16, 64])
def test_ssm_chunked_matches_ref(S):
    u, ldA, dBu, C, s0 = _ssm_inputs(2, S, 6, 4)
    y_ref, st_ref = blocks._ssm_scan_ref(u, ldA, dBu, C, s0)
    y_chk, st_chk = blocks._ssm_scan_chunked(u, ldA, dBu, C, s0)
    np.testing.assert_allclose(np.asarray(y_chk), np.asarray(y_ref),
                               rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(st_chk), np.asarray(st_ref),
                               rtol=2e-4, atol=2e-4)
