from . import kernel as _kernel
from . import ref as _ref

diffusion2d = _kernel.diffusion2d
jacobi3d = _kernel.jacobi3d
diffusion3d = _kernel.diffusion3d
stencil2d = _kernel.stencil2d
stencil2d_chain = _kernel.stencil2d_chain
diffusion2d_ref = _ref.diffusion2d
jacobi3d_ref = _ref.jacobi3d
diffusion3d_ref = _ref.diffusion3d
stencil2d_ref = _ref.stencil2d
