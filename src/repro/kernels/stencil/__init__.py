from . import ops  # noqa: F401
from .ops import (diffusion2d, diffusion2d_ref, diffusion3d, diffusion3d_ref,
                  jacobi3d, jacobi3d_ref, stencil2d, stencil2d_chain,
                  stencil2d_ref)

__all__ = ["diffusion2d", "diffusion2d_ref", "diffusion3d",
           "diffusion3d_ref", "jacobi3d", "jacobi3d_ref", "stencil2d",
           "stencil2d_chain", "stencil2d_ref", "ops"]
