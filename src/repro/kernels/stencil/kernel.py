"""Sliding-window stencil Pallas kernels — the paper's §6.2 Xilinx
shift-register emulation, adapted to the TPU memory hierarchy.

Intel OpenCL gives StencilFlow a shift register holding the stencil
wavefront; Vivado HLS does not, so the paper derives explicit cyclic
buffers per access offset. The TPU has neither construct: the adaptation
(DESIGN.md §2) keeps a **halo'd row slab resident in VMEM** per grid step.
Each grid step owns one row-tile of the output and reads an overlapping
(tile + 2*halo) slab of the pre-padded input, expressed with an
element-indexed BlockSpec (``pl.Element``) — the buffers between access
points become VMEM rows, and the wavefront advances tile-by-tile down the
grid, double-buffered by the Pallas pipeline exactly like the FPGA reader
PEs feed the shift register.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _pick_tile(n: int, target: int) -> int:
    t = min(target, n)
    while n % t != 0:
        t -= 1
    return t


def _element_block_spec(shape, index_map) -> pl.BlockSpec:
    """Element-indexed BlockSpec across jax versions: newer jax spells it
    ``pl.Element`` per dimension; older releases use the ``Unblocked``
    indexing mode. Both make ``index_map`` return element offsets, which
    the overlapping halo'd slabs need (slab height is not a multiple of
    the tile stride)."""
    if hasattr(pl, "Element"):
        return pl.BlockSpec(tuple(pl.Element(s) for s in shape), index_map)
    return pl.BlockSpec(shape, index_map, indexing_mode=pl.Unblocked())


# ---------------------------------------------------------------------------
# Generic 2D stencil: static offsets, runtime coeffs (SMEM)
# ---------------------------------------------------------------------------
def _stencil2d_kernel(c_ref, a_ref, o_ref, *, offsets, radius):
    slab = a_ref[...].astype(jnp.float32)
    bh = o_ref.shape[0]
    W = o_ref.shape[1]
    out = jnp.zeros((bh, W), jnp.float32)
    r = radius
    for k, (di, dj) in enumerate(offsets):
        out += c_ref[k] * jax.lax.slice(
            slab, (r + di, r + dj), (r + di + bh, r + dj + W))
    o_ref[...] = out.astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("offsets", "bh", "interpret"))
def stencil2d(a, coeffs, offsets, bh: int = 256, interpret: bool = True):
    """out[p] = sum_k c_k * a[p + offsets_k], constant-0 boundary."""
    H, W = a.shape
    bh = _pick_tile(H, bh)
    r = max(max(abs(di), abs(dj)) for di, dj in offsets)
    p = jnp.pad(a, r)
    coeffs = jnp.asarray(coeffs, jnp.float32)
    return pl.pallas_call(
        functools.partial(_stencil2d_kernel, offsets=tuple(offsets), radius=r),
        grid=(H // bh,),
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),
            _element_block_spec((bh + 2 * r, W + 2 * r),
                                lambda i: (i * bh, 0)),
        ],
        out_specs=pl.BlockSpec((bh, W), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((H, W), a.dtype),
        interpret=interpret,
    )(coeffs, p)


# ---------------------------------------------------------------------------
# Fused multi-stage 2D stencil chain (paper §6: fully pipelined multi-stencil
# architectures). All stages execute on one VMEM-resident slab per grid step;
# intermediates never touch HBM — the delay buffers of StencilFlow become
# shrinking VMEM halos. Inter-stage boundary conditions are enforced by
# masking positions outside the global domain to the constant-0 boundary.
# ---------------------------------------------------------------------------
def _stencil2d_chain_kernel(c_ref, a_ref, o_ref, *, stages, radii, H, W, bh):
    R = sum(radii)
    i = pl.program_id(0)
    cur = a_ref[...].astype(jnp.float32)  # halo R slab of padded input
    h = R
    coeff_base = 0
    for s, (offsets, n_coeff) in enumerate(stages):
        r = radii[s]
        h_new = h - r
        size_u = bh + 2 * h_new
        size_v = W + 2 * h_new
        out = jnp.zeros((size_u, size_v), jnp.float32)
        for k, (di, dj) in enumerate(offsets):
            out += c_ref[coeff_base + k] * jax.lax.slice(
                cur, (r + di, r + dj), (r + di + size_u, r + dj + size_v))
        coeff_base += n_coeff
        if s < len(stages) - 1:
            # constant-0 boundary for the *next* stage's input: zero
            # positions outside the global domain
            row0 = i * bh - h_new
            rows = row0 + jax.lax.broadcasted_iota(jnp.int32,
                                                   (size_u, size_v), 0)
            cols = -h_new + jax.lax.broadcasted_iota(jnp.int32,
                                                     (size_u, size_v), 1)
            inside = ((rows >= 0) & (rows < H) & (cols >= 0) & (cols < W))
            out = jnp.where(inside, out, 0.0)
        cur = out
        h = h_new
    o_ref[...] = cur.astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("offsets_per_stage", "bh",
                                             "interpret"))
def stencil2d_chain(a, coeffs_per_stage, offsets_per_stage, bh: int = 256,
                    interpret: bool = True):
    """Apply consecutive stencil stages in one fused kernel.

    offsets_per_stage: tuple of tuples of (di, dj); coeffs_per_stage: list of
    coefficient arrays, concatenated into one SMEM vector.
    """
    H, W = a.shape
    bh = _pick_tile(H, bh)
    radii = tuple(max(max(abs(di), abs(dj)) for di, dj in offs)
                  for offs in offsets_per_stage)
    R = sum(radii)
    p = jnp.pad(a, R)
    coeffs = jnp.concatenate([jnp.asarray(c, jnp.float32).reshape(-1)
                              for c in coeffs_per_stage])
    stages = tuple((tuple(offs), len(offs)) for offs in offsets_per_stage)
    return pl.pallas_call(
        functools.partial(_stencil2d_chain_kernel, stages=stages,
                          radii=radii, H=H, W=W, bh=bh),
        grid=(H // bh,),
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),
            _element_block_spec((bh + 2 * R, W + 2 * R),
                                lambda i: (i * bh, 0)),
        ],
        out_specs=pl.BlockSpec((bh, W), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((H, W), a.dtype),
        interpret=interpret,
    )(coeffs, p)


# ---------------------------------------------------------------------------
# diffusion 2D (paper Fig. 17): 5-point stencil, constant-0 boundary
# ---------------------------------------------------------------------------
def _diffusion2d_kernel(c_ref, a_ref, o_ref):
    c0, c1, c2, c3, c4 = (c_ref[k] for k in range(5))
    slab = a_ref[...].astype(jnp.float32)
    out = (c0 * slab[1:-1, 1:-1] + c1 * slab[:-2, 1:-1]
           + c2 * slab[2:, 1:-1] + c3 * slab[1:-1, :-2]
           + c4 * slab[1:-1, 2:])
    o_ref[...] = out.astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bh", "interpret"))
def diffusion2d(a, coeffs, bh: int = 256, interpret: bool = True):
    H, W = a.shape
    bh = _pick_tile(H, bh)
    p = jnp.pad(a, 1)  # constant-0 boundary
    coeffs = jnp.asarray(coeffs, jnp.float32)
    return pl.pallas_call(
        _diffusion2d_kernel,
        grid=(H // bh,),
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),
            _element_block_spec((bh + 2, W + 2),
                                lambda i: (i * bh, 0)),
        ],
        out_specs=pl.BlockSpec((bh, W), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((H, W), a.dtype),
        interpret=interpret,
    )(coeffs, p)


# ---------------------------------------------------------------------------
# Jacobi 3D: 7-point stencil over (D, H, W); tiles over the slowest axis
# ---------------------------------------------------------------------------
def _jacobi3d_kernel(a_ref, o_ref):
    slab = a_ref[...].astype(jnp.float32)
    c = jnp.float32(1.0 / 7.0)
    out = c * (slab[1:-1, 1:-1, 1:-1]
               + slab[:-2, 1:-1, 1:-1] + slab[2:, 1:-1, 1:-1]
               + slab[1:-1, :-2, 1:-1] + slab[1:-1, 2:, 1:-1]
               + slab[1:-1, 1:-1, :-2] + slab[1:-1, 1:-1, 2:])
    o_ref[...] = out.astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bd", "interpret"))
def jacobi3d(a, bd: int = 16, interpret: bool = True):
    D, H, W = a.shape
    bd = _pick_tile(D, bd)
    p = jnp.pad(a, 1)
    return pl.pallas_call(
        _jacobi3d_kernel,
        grid=(D // bd,),
        in_specs=[_element_block_spec(
            (bd + 2, H + 2, W + 2),
            lambda i: (i * bd, 0, 0))],
        out_specs=pl.BlockSpec((bd, H, W), lambda i: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((D, H, W), a.dtype),
        interpret=interpret,
    )(p)


# ---------------------------------------------------------------------------
# diffusion 3D: explicit laplacian step
# ---------------------------------------------------------------------------
def _diffusion3d_kernel(alpha_ref, a_ref, o_ref):
    alpha = alpha_ref[0]
    slab = a_ref[...].astype(jnp.float32)
    center = slab[1:-1, 1:-1, 1:-1]
    lap = (slab[:-2, 1:-1, 1:-1] + slab[2:, 1:-1, 1:-1]
           + slab[1:-1, :-2, 1:-1] + slab[1:-1, 2:, 1:-1]
           + slab[1:-1, 1:-1, :-2] + slab[1:-1, 1:-1, 2:]
           - 6.0 * center)
    o_ref[...] = (center + alpha * lap).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bd", "interpret"))
def diffusion3d(a, alpha: float = 0.1, bd: int = 16, interpret: bool = True):
    D, H, W = a.shape
    bd = _pick_tile(D, bd)
    p = jnp.pad(a, 1)
    alpha_arr = jnp.asarray([alpha], jnp.float32)
    return pl.pallas_call(
        _diffusion3d_kernel,
        grid=(D // bd,),
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),
            _element_block_spec(
                (bd + 2, H + 2, W + 2),
                lambda i: (i * bd, 0, 0)),
        ],
        out_specs=pl.BlockSpec((bd, H, W), lambda i: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((D, H, W), a.dtype),
        interpret=interpret,
    )(alpha_arr, p)
