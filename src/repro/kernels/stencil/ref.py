"""Pure-jnp oracles for the stencil kernels (paper §6, StencilFlow).

Constant-0 boundary conditions, matching the paper's Fig.-17 JSON programs.
"""
import jax.numpy as jnp


def diffusion2d(a, coeffs):
    """b = c0*a[j,k] + c1*a[j-1,k] + c2*a[j+1,k] + c3*a[j,k-1] + c4*a[j,k+1]."""
    c0, c1, c2, c3, c4 = [jnp.float32(c) for c in coeffs]
    p = jnp.pad(a, 1)
    return (c0 * p[1:-1, 1:-1] + c1 * p[:-2, 1:-1] + c2 * p[2:, 1:-1]
            + c3 * p[1:-1, :-2] + c4 * p[1:-1, 2:]).astype(a.dtype)


def jacobi3d(a):
    """7-point Jacobi: average of the 6 neighbors and the center / 7."""
    p = jnp.pad(a, 1)
    c = jnp.float32(1.0 / 7.0)
    out = c * (p[1:-1, 1:-1, 1:-1] + p[:-2, 1:-1, 1:-1] + p[2:, 1:-1, 1:-1]
               + p[1:-1, :-2, 1:-1] + p[1:-1, 2:, 1:-1]
               + p[1:-1, 1:-1, :-2] + p[1:-1, 1:-1, 2:])
    return out.astype(a.dtype)


def stencil2d(a, coeffs, offsets):
    """Generic 2D stencil, constant-0 boundary."""
    r = max(max(abs(di), abs(dj)) for di, dj in offsets)
    p = jnp.pad(a, r)
    H, W = a.shape
    out = jnp.zeros((H, W), jnp.float32)
    for c, (di, dj) in zip(coeffs, offsets):
        out = out + jnp.float32(c) * p[r + di:r + di + H, r + dj:r + dj + W]
    return out.astype(a.dtype)


def diffusion3d(a, alpha=0.1):
    """Explicit diffusion step: a + alpha * 3D laplacian(a)."""
    p = jnp.pad(a, 1)
    lap = (p[:-2, 1:-1, 1:-1] + p[2:, 1:-1, 1:-1]
           + p[1:-1, :-2, 1:-1] + p[1:-1, 2:, 1:-1]
           + p[1:-1, 1:-1, :-2] + p[1:-1, 1:-1, 2:]
           - 6.0 * p[1:-1, 1:-1, 1:-1])
    return (a + jnp.float32(alpha) * lap).astype(a.dtype)
