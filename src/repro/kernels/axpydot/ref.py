"""Pure-jnp oracle for the fused AXPYDOT pipeline (paper §4.1)."""
import jax.numpy as jnp


def axpydot(a, x, y, w):
    z = a * x + y
    return jnp.dot(z.astype(jnp.float32), w.astype(jnp.float32))[None]
