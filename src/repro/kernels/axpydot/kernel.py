"""Fused AXPYDOT Pallas kernel — the paper's streaming-composition pipeline
realized as a single TPU kernel.

On FPGA, StreamingComposition turns  z = a*x+y ; r = z.w  into five PEs
chained by FIFOs so z never touches off-chip memory. On TPU, the same
fusion is one Pallas kernel: the grid streams (x, y, w) block-by-block from
HBM into VMEM (the Pallas pipeline double-buffers = the reader PEs), the
AXPY stage feeds the DOT stage through VMEM values (= the z FIFO), and the
accumulator uses **partial-sum interleaving** (paper §3.3.1, the Xilinx
specialization): an (8, 128) fp32 VREG-shaped tile of partial sums breaks
the loop-carried add dependency; a final reduction collapses it.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

SUBLANES, LANES = 8, 128
TILE = SUBLANES * LANES  # 1024-element accumulation tile


def _axpydot_kernel(a_ref, x_ref, y_ref, w_ref, o_ref, acc_ref):
    step = pl.program_id(0)

    @pl.when(step == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    a = a_ref[0]
    # AXPY stage (z never leaves VMEM) -> DOT stage
    z = a * x_ref[...].astype(jnp.float32) + y_ref[...].astype(jnp.float32)
    prod = z * w_ref[...].astype(jnp.float32)
    # partial-sum interleaving across an (8,128) accumulator tile
    acc_ref[...] += jnp.sum(prod.reshape(-1, SUBLANES, LANES), axis=0)

    @pl.when(step == pl.num_programs(0) - 1)
    def _reduce():
        o_ref[...] = jnp.sum(acc_ref[...])[None]


@functools.partial(jax.jit, static_argnames=("block_n", "interpret"))
def axpydot(a, x, y, w, block_n: int = 8 * TILE, interpret: bool = True):
    n = x.shape[0]
    block_n = min(block_n, n)
    if block_n % TILE != 0 or n % block_n != 0:
        # pad to tile multiple; zeros are exact under +
        import numpy as np
        padded = int(np.ceil(n / TILE) * TILE)
        block_n = min(block_n - block_n % TILE or TILE, padded)
        while padded % block_n != 0:
            block_n -= TILE
        pad = padded - n
        x = jnp.pad(x, (0, pad))
        y = jnp.pad(y, (0, pad))
        w = jnp.pad(w, (0, pad))
        n = padded
    grid = (n // block_n,)
    a_arr = jnp.asarray(a, jnp.float32).reshape(1)
    return pl.pallas_call(
        _axpydot_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1,), lambda i: (0,)),
            pl.BlockSpec((block_n,), lambda i: (i,)),
            pl.BlockSpec((block_n,), lambda i: (i,)),
            pl.BlockSpec((block_n,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((1,), lambda i: (0,)),
        out_shape=jax.ShapeDtypeStruct((1,), jnp.float32),
        scratch_shapes=[pltpu.VMEM((SUBLANES, LANES), jnp.float32)],
        interpret=interpret,
    )(a_arr, x, y, w)
