"""Jitted wrapper + fusion registration for the AXPYDOT kernel."""
from __future__ import annotations

from ...codegen.pipeline_fusion import register_fusion
from . import kernel as _kernel
from . import ref as _ref

axpydot = _kernel.axpydot
axpydot_ref = _ref.axpydot


@register_fusion(("Axpy", "Dot"))
def _fuse_axpy_dot(chain, sdfg, state, interpret, in_map, out_map):
    """StreamingComposition(axpy -> z -> dot) => one fused Pallas kernel."""
    axpy_n, dot_n = chain
    a_c = in_map[(axpy_n.label, "a")]
    x_c = in_map[(axpy_n.label, "x")]
    y_c = in_map[(axpy_n.label, "y")]
    w_c = in_map[(dot_n.label, "w")]
    r_c = out_map[(dot_n.label, "result")]

    def fn(**kw):
        return {r_c: axpydot(kw[a_c], kw[x_c], kw[y_c], kw[w_c],
                             interpret=interpret)}

    return fn
