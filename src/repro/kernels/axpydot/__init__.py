from . import ops  # noqa: F401  (registers the Axpy+Dot fusion)
from .ops import axpydot, axpydot_ref

__all__ = ["axpydot", "axpydot_ref", "ops"]
