"""Decode-attention Pallas kernel over a gathered paged-KV context.

Serving decodes one token per sequence per step: q is (B, H, Dh) and the
context K/V — gathered from the paged KV pool through the block table —
is (B, C, H, Dh) where C is the *context bucket* (a small multiple of the
page size), not the model's max sequence length. The kernel fuses
score -> mask -> softmax -> PV per (batch, head) grid cell so the (C,)
score vector never leaves VMEM; per-sequence lengths arrive as a
scalar-prefetch operand (``pltpu.PrefetchScalarGridSpec``) and mask the
context tail, so one compiled kernel serves every occupancy of the
bucket.

This is the hand-written "flash" expansion level of the
``PagedAttnDecode`` library node; the "pallas" level generates the
equivalent grid kernel from the SDFG (memlets -> BlockSpecs) and is the
serving default.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _decode_kernel(pos_ref, q_ref, k_ref, v_ref, o_ref, *, scale, window,
                   ctx):
    b = pl.program_id(0)
    pos = pos_ref[b]
    q = q_ref[0, 0].astype(jnp.float32)            # (Dh,)
    k = k_ref[0, :, 0].astype(jnp.float32)         # (C, Dh)
    v = v_ref[0, :, 0].astype(jnp.float32)
    s = jnp.sum(k * q[None, :], axis=-1) * scale   # (C,)
    k_pos = jax.lax.broadcasted_iota(jnp.int32, (ctx, 1), 0)[:, 0]
    mask = k_pos <= pos
    if window is not None:
        mask &= k_pos > pos - window
    s = jnp.where(mask, s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o_ref[0, 0] = (p @ v).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("window", "interpret"))
def decode_attention(q, k, v, pos, *, window: int = None,
                     interpret: bool = True):
    """q: (B, H, Dh); k/v: (B, C, H, Dh) gathered context; pos: (B,) int32
    absolute position of the current token -> (B, H, Dh).

    Causal over absolute context positions: key j attends iff
    ``j <= pos[b]`` (and ``j > pos[b] - window`` for sliding-window
    layers). Entries past ``pos`` — unwritten pages, the null page of
    evicted slots — are masked structurally, so pool garbage never
    reaches the softmax.
    """
    b, h, dh = q.shape
    _, c, _, _ = k.shape
    scale = 1.0 / np.sqrt(dh)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(b, h),
        in_specs=[
            pl.BlockSpec((1, 1, dh), lambda i, j, pos: (i, j, 0)),
            pl.BlockSpec((1, c, 1, dh), lambda i, j, pos: (i, 0, j, 0)),
            pl.BlockSpec((1, c, 1, dh), lambda i, j, pos: (i, 0, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, dh), lambda i, j, pos: (i, j, 0)),
    )
    return pl.pallas_call(
        functools.partial(_decode_kernel, scale=scale, window=window,
                          ctx=c),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, h, dh), q.dtype),
        interpret=interpret,
    )(pos.astype(jnp.int32), q, k, v)
