from . import ops  # noqa: F401
from .ops import attention_ref, flash_attention

__all__ = ["attention_ref", "flash_attention", "ops"]
