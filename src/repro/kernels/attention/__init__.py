from . import ops  # noqa: F401
from .decode import decode_attention
from .ops import attention_ref, flash_attention

__all__ = ["attention_ref", "decode_attention", "flash_attention", "ops"]
