"""Pure-jnp oracle for flash attention (causal / windowed / GQA)."""
import jax
import jax.numpy as jnp
import numpy as np


def attention(q, k, v, causal=True, window=None):
    """q: (B, Sq, Hq, Dh); k/v: (B, Sk, Hkv, Dh)."""
    b, sq, hq, dh = q.shape
    _, sk, hkv, _ = k.shape
    rep = hq // hkv
    if rep > 1:
        k = jnp.repeat(k, rep, axis=2)
        v = jnp.repeat(v, rep, axis=2)
    logits = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32),
                        k.astype(jnp.float32)) / np.sqrt(dh)
    qp = jnp.arange(sq)[:, None]
    kp = jnp.arange(sk)[None, :]
    mask = jnp.ones((sq, sk), bool)
    if causal:
        mask &= kp <= qp
    if window is not None:
        mask &= kp > qp - window
    logits = jnp.where(mask[None, None], logits, -jnp.inf)
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhqk,bkhd->bqhd", probs, v.astype(jnp.float32))
    return out.astype(q.dtype)
