"""Flash attention (fwd) Pallas kernel: causal, sliding-window, GQA.

This is the paper's StreamingComposition insight applied to attention
(DESIGN.md §4): QK^T -> softmax -> PV fused into one kernel so the (Sq,Sk)
score matrix never reaches HBM. Online-softmax running (max, sum) registers
play the role of the paper's §3.3.1 accumulation specialization; the KV
sequence streams block-by-block through VMEM like the FPGA reader PEs.

Grid: (batch*heads, Sq/bq, Sk/bk) with the KV dimension innermost; the
fp32 VMEM scratch carries (acc, m, l) across KV steps. Causal/window
blocks that are fully masked are skipped via jnp.where on block indices
(structural zero-work; on TPU Mosaic hoists the branch).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
                  scale, causal, window, bq, bk, k_steps):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0].astype(jnp.float32)          # (bq, dh)
    k = k_ref[0].astype(jnp.float32)          # (bk, dh)
    v = v_ref[0].astype(jnp.float32)
    s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale

    q_pos = qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
    k_pos = ki * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    mask = jnp.ones((bq, bk), jnp.bool_)
    if causal:
        mask &= k_pos <= q_pos
    if window is not None:
        mask &= k_pos > q_pos - window
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new[:, None])
    l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=1)
    acc_ref[...] = acc_ref[...] * alpha[:, None] + jnp.dot(
        p, v, preferred_element_type=jnp.float32)
    m_ref[...] = m_new

    @pl.when(ki == k_steps - 1)
    def _finalize():
        denom = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0] = (acc_ref[...] / denom[:, None]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=(
    "causal", "window", "bq", "bk", "interpret"))
def flash_attention(q, k, v, *, causal: bool = True, window: int = None,
                    bq: int = 128, bk: int = 128, interpret: bool = True):
    """q: (B, Sq, Hq, Dh); k/v: (B, Sk, Hkv, Dh) -> (B, Sq, Hq, Dh)."""
    b, sq, hq, dh = q.shape
    _, sk, hkv, _ = k.shape
    rep = hq // hkv
    scale = 1.0 / np.sqrt(dh)
    bq = min(bq, sq)
    bk = min(bk, sk)
    while sq % bq:
        bq -= 1
    while sk % bk:
        bk -= 1
    # layout: fold heads into the grid's leading dim; GQA indexes the
    # shared KV head via integer division in the index_map
    qh = q.transpose(0, 2, 1, 3).reshape(b * hq, sq, dh)
    kh = k.transpose(0, 2, 1, 3).reshape(b * hkv, sk, dh)
    vh = v.transpose(0, 2, 1, 3).reshape(b * hkv, sk, dh)
    k_steps = sk // bk
    grid = (b * hq, sq // bq, k_steps)

    def kv_index(h, qi, ki):
        return (h // rep, ki, 0)

    out = pl.pallas_call(
        functools.partial(_flash_kernel, scale=scale, causal=causal,
                          window=window, bq=bq, bk=bk, k_steps=k_steps),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq, dh), lambda h, qi, ki: (h, qi, 0)),
            pl.BlockSpec((1, bk, dh), kv_index),
            pl.BlockSpec((1, bk, dh), kv_index),
        ],
        out_specs=pl.BlockSpec((1, bq, dh), lambda h, qi, ki: (h, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((b * hq, sq, dh), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, dh), jnp.float32),
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq,), jnp.float32),
        ],
        interpret=interpret,
    )(qh, kh, vh)
    return out.reshape(b, hq, sq, dh).transpose(0, 2, 1, 3)
