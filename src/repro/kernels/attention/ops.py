from . import kernel as _kernel
from . import ref as _ref

flash_attention = _kernel.flash_attention
attention_ref = _ref.attention
