from . import ops  # noqa: F401
from .ops import wkv_chunked, wkv_ref

__all__ = ["wkv_chunked", "wkv_ref", "ops"]
