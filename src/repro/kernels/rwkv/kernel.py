"""Chunked WKV6 Pallas kernel.

TPU adaptation of RWKV6's data-dependent-decay linear recurrence
(DESIGN.md §4): the GLA-style chunkwise form turns the per-token recurrence
into MXU matmuls. The grid walks (batch*heads) x sequence-chunks; the
(hd, hd) fp32 state lives in VMEM scratch and carries across chunk steps —
a literal shift register of the recurrence state, with the intra-chunk
causal matmul playing the paper's 'unrolled circuit' role.

Chunk length 16 bounds exp(cumsum log w) within fp32 (|log w| <= 3.5).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

CHUNK = 16


def _wkv_kernel(r_ref, k_ref, v_ref, w_ref, u_ref, o_ref, s_final_ref,
                state_ref, *, n_chunks):
    ci = pl.program_id(1)

    @pl.when(ci == 0)
    def _init():
        state_ref[...] = jnp.zeros_like(state_ref)

    r = r_ref[0].astype(jnp.float32)       # (C, hd)
    k = k_ref[0].astype(jnp.float32)
    v = v_ref[0].astype(jnp.float32)
    w = w_ref[0].astype(jnp.float32)
    u = u_ref[0].astype(jnp.float32)       # (hd,)
    C = r.shape[0]

    lw = jnp.log(jnp.maximum(w, 1e-8))
    la = jnp.cumsum(lw, axis=0)            # inclusive per-key log decay
    a_prev = jnp.exp(la - lw)              # A_{t-1}
    a_last = jnp.exp(la[-1])               # (hd,)
    r_t = r * a_prev
    k_t = k * jnp.exp(-la)
    k_rev = k * jnp.exp(la[-1:] - la)

    # intra-chunk: strictly-causal scores + diagonal bonus
    scores = jnp.dot(r_t, k_t.T, preferred_element_type=jnp.float32)
    t_pos = jax.lax.broadcasted_iota(jnp.int32, (C, C), 0)
    j_pos = jax.lax.broadcasted_iota(jnp.int32, (C, C), 1)
    scores = jnp.where(j_pos < t_pos, scores, 0.0)
    out = jnp.dot(scores, v, preferred_element_type=jnp.float32)
    diag = jnp.sum(r * u[None, :] * k, axis=1)
    out = out + diag[:, None] * v

    # inter-chunk: apply carried state, then update it
    out = out + jnp.dot(r_t, state_ref[...],
                        preferred_element_type=jnp.float32)
    state_ref[...] = a_last[:, None] * state_ref[...] + jnp.dot(
        k_rev.T, v, preferred_element_type=jnp.float32)
    o_ref[0] = out.astype(o_ref.dtype)

    @pl.when(ci == n_chunks - 1)
    def _emit_state():
        s_final_ref[0] = state_ref[...]


@functools.partial(jax.jit, static_argnames=("interpret",))
def wkv_chunked(r, k, v, w, u, interpret: bool = True):
    """r,k,v,w: (B,S,H,hd); u: (H,hd) -> (out (B,S,H,hd), state (B,H,hd,hd)).
    Zero initial state (prefill); S must be a multiple of CHUNK."""
    B, S, H, hd = r.shape
    assert S % CHUNK == 0, (S, CHUNK)
    n_chunks = S // CHUNK

    def fold(x):
        return x.transpose(0, 2, 1, 3).reshape(B * H, S, hd)

    rf, kf, vf, wf = map(fold, (r, k, v, w))
    uf = jnp.broadcast_to(u[None], (B, H, hd)).reshape(B * H, hd)

    out, state = pl.pallas_call(
        functools.partial(_wkv_kernel, n_chunks=n_chunks),
        grid=(B * H, n_chunks),
        in_specs=[
            pl.BlockSpec((1, CHUNK, hd), lambda h, c: (h, c, 0)),
            pl.BlockSpec((1, CHUNK, hd), lambda h, c: (h, c, 0)),
            pl.BlockSpec((1, CHUNK, hd), lambda h, c: (h, c, 0)),
            pl.BlockSpec((1, CHUNK, hd), lambda h, c: (h, c, 0)),
            pl.BlockSpec((1, hd), lambda h, c: (h, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, CHUNK, hd), lambda h, c: (h, c, 0)),
            pl.BlockSpec((1, hd, hd), lambda h, c: (h, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B * H, S, hd), r.dtype),
            jax.ShapeDtypeStruct((B * H, hd, hd), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((hd, hd), jnp.float32)],
        interpret=interpret,
    )(rf, kf, vf, wf, uf)
    out = out.reshape(B, H, S, hd).transpose(0, 2, 1, 3)
    state = state.reshape(B, H, hd, hd)
    return out, state
