from . import kernel as _kernel
from . import ref as _ref

wkv_chunked = _kernel.wkv_chunked
wkv_ref = _ref.wkv
