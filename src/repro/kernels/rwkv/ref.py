"""Pure-jnp oracle for the WKV6 recurrence: the sequential scan."""
import jax
import jax.numpy as jnp


def wkv(r, k, v, w, u, state0):
    """r,k,v,w: (B,S,H,hd); u: (H,hd); state0: (B,H,hd,hd).
    out_t = r_t . (S_{t-1} + u*k_t v_t^T); S_t = diag(w_t) S_{t-1} + k_t v_t^T
    """
    def step(state, xs):
        rt, kt, vt, wt = xs
        kv = kt[..., :, None] * vt[..., None, :]
        out = jnp.einsum("bhkv,bhk->bhv", state + u[..., :, None] * kv, rt)
        return wt[..., :, None] * state + kv, out

    xs = tuple(jnp.moveaxis(t, 1, 0) for t in (r, k, v, w))
    state, outs = jax.lax.scan(step, state0, xs)
    return jnp.moveaxis(outs, 0, 1), state
