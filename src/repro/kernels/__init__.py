"""Pallas TPU kernels (pl.pallas_call + BlockSpec) for compute hot-spots.

Each kernel package ships kernel.py (the pallas_call), ops.py (jit'd
wrapper + fusion/library registration), and ref.py (pure-jnp oracle).
Importing this package registers all pipeline-fusion patterns.
"""
from . import attention  # noqa: F401
from . import axpydot  # noqa: F401
from . import dot  # noqa: F401
from . import gemm  # noqa: F401
from . import rwkv  # noqa: F401
from . import stencil  # noqa: F401

__all__ = ["attention", "axpydot", "dot", "gemm", "rwkv", "stencil"]
