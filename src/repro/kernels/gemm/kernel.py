"""Tiled GEMM Pallas kernel — the MXU realization of the paper's Fig.-6
systolic array (DESIGN.md §2).

The paper instantiates P processing elements, each buffering part of A and
streaming B through a FIFO chain. On TPU, the 128x128 MXU *is* the systolic
array; the kernel's job is the paper's 'memory reader PE' role: tile
(bm, bk, bn) blocks through VMEM with the K grid dimension innermost so the
fp32 VMEM scratch accumulator carries partial C tiles across K steps
(= the PE-chain accumulation), and the Pallas pipeline double-buffers the
HBM->VMEM streams (= the FIFOs). An optional fused epilogue (bias +
activation) plays the role of a downstream streaming-composed PE.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

MXU = 128


def _act(name, x):
    if name is None:
        return x
    if name == "relu":
        return jnp.maximum(x, 0.0)
    if name == "silu":
        return x / (1.0 + jnp.exp(-x))
    if name == "gelu":
        return 0.5 * x * (1.0 + jnp.tanh(
            0.7978845608028654 * (x + 0.044715 * x ** 3)))
    raise ValueError(name)


def _matmul_kernel(a_ref, b_ref, o_ref, acc_ref, *, activation, k_steps):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(a_ref[...], b_ref[...],
                            preferred_element_type=jnp.float32)

    @pl.when(k == k_steps - 1)
    def _epilogue():
        o_ref[...] = _act(activation, acc_ref[...]).astype(o_ref.dtype)


def _matmul_bias_kernel(a_ref, b_ref, bias_ref, o_ref, acc_ref, *,
                        activation, k_steps):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(a_ref[...], b_ref[...],
                            preferred_element_type=jnp.float32)

    @pl.when(k == k_steps - 1)
    def _epilogue():
        out = acc_ref[...] + bias_ref[...].astype(jnp.float32)
        o_ref[...] = _act(activation, out).astype(o_ref.dtype)


def _pad_to(x, m0, m1):
    p0 = (-x.shape[0]) % m0
    p1 = (-x.shape[1]) % m1
    if p0 or p1:
        x = jnp.pad(x, ((0, p0), (0, p1)))
    return x


@functools.partial(jax.jit, static_argnames=(
    "bm", "bk", "bn", "activation", "interpret", "out_dtype"))
def matmul(a, b, bias=None, *, bm: int = 2 * MXU, bk: int = 4 * MXU,
           bn: int = 2 * MXU, activation: str = None,
           interpret: bool = True, out_dtype=None):
    """C = act(A @ B + bias), A:(M,K) B:(K,N), fp32 accumulation."""
    M, K = a.shape
    K2, N = b.shape
    assert K == K2, (a.shape, b.shape)
    bm_, bk_, bn_ = min(bm, M), min(bk, K), min(bn, N)
    # clamp to hw-aligned sizes when the problem allows it
    a_p = _pad_to(a, bm_, bk_)
    b_p = _pad_to(b, bk_, bn_)
    Mp, Kp = a_p.shape
    _, Np = b_p.shape
    k_steps = Kp // bk_
    grid = (Mp // bm_, Np // bn_, k_steps)
    out_dtype = out_dtype or a.dtype

    if bias is not None:
        bias_p = jnp.pad(bias, (0, Np - bias.shape[0])).reshape(1, Np)
        out = pl.pallas_call(
            functools.partial(_matmul_bias_kernel, activation=activation,
                              k_steps=k_steps),
            grid=grid,
            in_specs=[
                pl.BlockSpec((bm_, bk_), lambda i, j, k: (i, k)),
                pl.BlockSpec((bk_, bn_), lambda i, j, k: (k, j)),
                pl.BlockSpec((1, bn_), lambda i, j, k: (0, j)),
            ],
            out_specs=pl.BlockSpec((bm_, bn_), lambda i, j, k: (i, j)),
            out_shape=jax.ShapeDtypeStruct((Mp, Np), out_dtype),
            scratch_shapes=[pltpu.VMEM((bm_, bn_), jnp.float32)],
            interpret=interpret,
        )(a_p, b_p, bias_p)
    else:
        out = pl.pallas_call(
            functools.partial(_matmul_kernel, activation=activation,
                              k_steps=k_steps),
            grid=grid,
            in_specs=[
                pl.BlockSpec((bm_, bk_), lambda i, j, k: (i, k)),
                pl.BlockSpec((bk_, bn_), lambda i, j, k: (k, j)),
            ],
            out_specs=pl.BlockSpec((bm_, bn_), lambda i, j, k: (i, j)),
            out_shape=jax.ShapeDtypeStruct((Mp, Np), out_dtype),
            scratch_shapes=[pltpu.VMEM((bm_, bn_), jnp.float32)],
            interpret=interpret,
        )(a_p, b_p)
    if (Mp, Np) != (M, N):
        out = out[:M, :N]
    return out
