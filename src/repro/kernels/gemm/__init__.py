from . import ops  # noqa: F401
from .ops import matmul, matmul_ref

__all__ = ["matmul", "matmul_ref", "ops"]
