from . import kernel as _kernel
from . import ref as _ref

matmul = _kernel.matmul
matmul_ref = _ref.matmul
