"""Pure-jnp oracle for the tiled GEMM kernel."""
import jax.numpy as jnp


def _act(name, x):
    if name is None:
        return x
    if name == "relu":
        return jnp.maximum(x, 0.0)
    if name == "silu":
        return x / (1.0 + jnp.exp(-x))
    if name == "gelu":
        return 0.5 * x * (1.0 + jnp.tanh(
            0.7978845608028654 * (x + 0.044715 * x ** 3)))
    raise ValueError(name)


def matmul(a, b, bias=None, activation=None, out_dtype=None):
    out = jnp.matmul(a.astype(jnp.float32), b.astype(jnp.float32),
                     preferred_element_type=jnp.float32)
    if bias is not None:
        out = out + bias.astype(jnp.float32)
    out = _act(activation, out)
    return out.astype(out_dtype or a.dtype)
