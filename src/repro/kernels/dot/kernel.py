"""DOT Pallas kernel with partial-sum interleaving (paper §3.3.1).

The streaming phase accumulates into an (8,128) fp32 tile (the TPU reshaping
of the paper's 'buffer larger than the add latency'); the reduce phase
collapses the tile. Used by the Dot Library Node's ``pallas`` expansion.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

SUBLANES, LANES = 8, 128
TILE = SUBLANES * LANES


def _dot_kernel(x_ref, w_ref, o_ref, acc_ref):
    step = pl.program_id(0)

    @pl.when(step == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    prod = x_ref[...].astype(jnp.float32) * w_ref[...].astype(jnp.float32)
    acc_ref[...] += jnp.sum(prod.reshape(-1, SUBLANES, LANES), axis=0)

    @pl.when(step == pl.num_programs(0) - 1)
    def _reduce():
        o_ref[...] = jnp.sum(acc_ref[...])[None]


@functools.partial(jax.jit, static_argnames=("block_n", "interpret"))
def dot(x, w, block_n: int = 8 * TILE, interpret: bool = True):
    n = x.shape[0]
    block_n = min(block_n, max(n, TILE))
    if block_n % TILE != 0 or n % block_n != 0:
        import numpy as np
        padded = int(np.ceil(n / TILE) * TILE)
        block_n = min(block_n - block_n % TILE or TILE, padded)
        while padded % block_n != 0:
            block_n -= TILE
        pad = padded - n
        x = jnp.pad(x, (0, pad))
        w = jnp.pad(w, (0, pad))
        n = padded
    return pl.pallas_call(
        _dot_kernel,
        grid=(n // block_n,),
        in_specs=[pl.BlockSpec((block_n,), lambda i: (i,)),
                  pl.BlockSpec((block_n,), lambda i: (i,))],
        out_specs=pl.BlockSpec((1,), lambda i: (0,)),
        out_shape=jax.ShapeDtypeStruct((1,), jnp.float32),
        scratch_shapes=[pltpu.VMEM((SUBLANES, LANES), jnp.float32)],
        interpret=interpret,
    )(x, w)
