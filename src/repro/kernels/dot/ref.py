"""Pure-jnp oracle for DOT."""
import jax.numpy as jnp


def dot(x, w):
    return jnp.dot(x.astype(jnp.float32), w.astype(jnp.float32))[None]
