from . import kernel as _kernel
from . import ref as _ref

dot = _kernel.dot
dot_ref = _ref.dot
