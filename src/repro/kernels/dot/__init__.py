from . import ops  # noqa: F401
from .ops import dot, dot_ref

__all__ = ["dot", "dot_ref", "ops"]
