"""Storage, schedule, and container metadata for the TPU-adapted SDFG.

The paper's FPGA storage lattice (DDR/HBM off-chip, BRAM/M20K/LUTRAM/URAM
on-chip, registers, shift registers) maps onto the TPU memory hierarchy:

    HOST   -- host DRAM, outside the device                (paper: host)
    HBM    -- device off-chip memory                        (paper: global)
    VMEM   -- on-chip vector memory, ~128 MiB/core on v5e   (paper: local/BRAM)
    REG    -- vector registers, fully parallel access       (paper: registers)

Shift registers (paper §3.3.2) have no TPU primitive; the stencil Library
Node expands to explicit sliding-window VMEM buffers instead (DESIGN.md §2).
"""
from __future__ import annotations

import enum

import numpy as np


class StorageType(enum.Enum):
    DEFAULT = "default"   # resolved by context (transient inside kernels -> VMEM)
    HOST = "host"
    HBM = "hbm"
    VMEM = "vmem"
    REG = "reg"

    @property
    def on_device(self) -> bool:
        return self in (StorageType.HBM, StorageType.VMEM, StorageType.REG)

    @property
    def off_chip(self) -> bool:
        """Counts toward the paper's 'off-chip volume' metric."""
        return self is StorageType.HBM


class ScheduleType(enum.Enum):
    """Map schedules (paper §2.2)."""
    PIPELINED = "pipelined"   # sequential grid, pipeline parallelism (default)
    DEVICE = "device"         # explicit device grid (Pallas pallas_call grid)
    UNROLLED = "unrolled"     # parametric hardware replication (systolic / SIMD)
    MXU = "mxu"               # unrolled onto the 128x128 systolic MXU
    MESH = "mesh"             # unrolled across chips (shard_map axis)


class DType:
    """Thin dtype wrapper with byte size, bridging numpy and jax."""

    __slots__ = ("np_dtype",)

    _CANON = {
        "float32": np.float32, "float64": np.float64, "float16": np.float16,
        "bfloat16": None,  # filled lazily to avoid importing jax here
        "int32": np.int32, "int64": np.int64, "int8": np.int8,
        "uint8": np.uint8, "bool": np.bool_,
    }

    def __init__(self, name_or_dtype):
        if isinstance(name_or_dtype, DType):
            self.np_dtype = name_or_dtype.np_dtype
            return
        if isinstance(name_or_dtype, str):
            if name_or_dtype == "bfloat16":
                import ml_dtypes  # shipped with jax
                self.np_dtype = np.dtype(ml_dtypes.bfloat16)
            else:
                self.np_dtype = np.dtype(self._CANON[name_or_dtype])
        else:
            self.np_dtype = np.dtype(name_or_dtype)

    @property
    def bytes(self) -> int:
        return self.np_dtype.itemsize

    @property
    def name(self) -> str:
        return self.np_dtype.name

    def __eq__(self, other):
        if isinstance(other, (str, np.dtype, type)):
            try:
                other = DType(other)
            except Exception:
                return NotImplemented
        if isinstance(other, DType):
            return self.np_dtype == other.np_dtype
        return NotImplemented

    def __hash__(self):
        return hash(self.np_dtype)

    def __repr__(self):
        return f"DType({self.name})"


float32 = DType("float32")
float64 = DType("float64")
bfloat16 = DType("bfloat16")
int32 = DType("int32")

# TPU v5e hardware constants used for vector-width legality checks
# (Vectorization transform) and roofline math.
TPU_LANES = 128          # minor-dim vector width
TPU_SUBLANES = 8         # second-minor width for fp32
MXU_DIM = 128            # systolic array edge


def sublanes_for_bytes(nbytes: int) -> int:
    """Sublane count for an element width in bytes — the single source
    of the packing rule (see :func:`sublanes_for`)."""
    return TPU_SUBLANES * 4 // min(4, int(nbytes))


def sublanes_for(dtype) -> int:
    """Dtype-aware second-minor (sublane) tile width.

    The native TPU tile is (sublane x 128 lanes) with the sublane count
    set by element width: a register row packs 32 bits per lane, so
    narrower dtypes pack more rows per tile — fp32 -> 8, bf16/fp16 -> 16,
    int8/fp8 -> 32. Wider-than-32-bit dtypes keep the fp32 count.
    """
    return sublanes_for_bytes(DType(dtype).bytes)
