"""Stateful DataFlow multiGraph (SDFG) IR, adapted for TPU code generation.

Faithful to the paper's Fig.-2 glossary:

  * ``SDFG``        -- control-flow graph of states (+ containers, symbols)
  * ``State``       -- pure-dataflow multigraph
  * ``AccessNode``  -- data container access (Array solid / Stream dashed)
  * ``Tasklet``     -- fine-grained computation; may only touch data that is
                       explicitly passed via dataflow edges
  * ``MapEntry/MapExit`` -- parametric parallelism scope (pipelined/unrolled)
  * ``LibraryNode`` -- abstract behavior ("what"), expanded into parametric
                       subgraphs ("how") at multiple levels (paper §3)
  * ``NestedSDFG``  -- control flow embedded in dataflow
  * edges carry ``Memlet`` annotations capturing *all* data movement

Weakly connected components of a state are independently-schedulable
*processing elements* (paper §2.4); on TPU these become fused-kernel stages
pipelined over grid steps (DESIGN.md §2).

The IR also implements the paper's headline analysis: **off-chip data
volume**, computed by summing memlet volumes incident to HBM containers.
"""
from __future__ import annotations

import hashlib
import itertools
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, Iterable, List, Optional, Sequence, Tuple

import networkx as nx
import numpy as np

from .dtypes import DType, ScheduleType, StorageType
from .memlet import Memlet, Range, Subset
from .symbolic import Expr, ExprLike, prod

# ---------------------------------------------------------------------------
# Data descriptors
# ---------------------------------------------------------------------------


@dataclass
class Data:
    dtype: DType
    storage: StorageType = StorageType.DEFAULT
    transient: bool = False

    @property
    def is_stream(self) -> bool:
        return isinstance(self, Stream)


@dataclass
class Array(Data):
    shape: Tuple[Expr, ...] = ()
    vector_width: int = 1  # set by the Vectorization transformation

    @property
    def num_elements(self) -> Expr:
        return prod(self.shape) if self.shape else Expr.const(1)

    def bytes(self, env: Dict[str, int]) -> int:
        return self.num_elements.evaluate(env) * self.dtype.bytes


@dataclass
class Scalar(Data):
    @property
    def shape(self):
        return ()

    @property
    def num_elements(self) -> Expr:
        return Expr.const(1)


@dataclass
class Stream(Data):
    """Bounded FIFO (paper §2.5): single-producer, single-consumer on FPGA;
    on TPU, a VMEM-resident block exchanged between fused pipeline stages.
    ``shape`` models arrays-of-streams (e.g. systolic pipes A_pipe[P+1])."""
    buffer_size: int = 1
    shape: Tuple[Expr, ...] = ()          # array-of-streams dims
    element_shape: Tuple[Expr, ...] = ()  # logical stream payload per push
    total_volume: Optional[Expr] = None   # total elements pushed (for codegen)

    @property
    def num_elements(self) -> Expr:
        return prod(self.shape) if self.shape else Expr.const(1)


# ---------------------------------------------------------------------------
# Graph nodes
# ---------------------------------------------------------------------------

_node_counter = itertools.count()


class Node:
    def __init__(self, label: str = ""):
        self.uid = next(_node_counter)
        self.label = label or f"{type(self).__name__.lower()}_{self.uid}"

    def __repr__(self):
        return f"{type(self).__name__}({self.label})"

    def __hash__(self):
        return self.uid

    def __eq__(self, other):
        return self is other


class AccessNode(Node):
    def __init__(self, data: str):
        super().__init__(data)
        self.data = data


class Tasklet(Node):
    """Computation node. ``fn`` is a jax-traceable callable mapping the
    input-connector values (kwargs) to a dict/tuple of output-connector
    values. This is the TPU analogue of the paper's C++ tasklet body."""

    def __init__(self, name: str, inputs: Sequence[str], outputs: Sequence[str],
                 fn: Callable, side_effect_free: bool = True):
        super().__init__(name)
        self.inputs = list(inputs)
        self.outputs = list(outputs)
        self.fn = fn
        self.side_effect_free = side_effect_free


@dataclass
class Map:
    params: List[str]
    ranges: List[Range]
    schedule: ScheduleType = ScheduleType.PIPELINED
    label: str = "map"
    # Unroll/vector hints set by Vectorization / expansions:
    vector_width: int = 1
    #: pass-to-codegen metadata (MapTiling tile structure, derived Pallas
    #: grid specs, storage hints). Content-hash relevant.
    annotations: Dict[str, Any] = field(default_factory=dict)


class MapEntry(Node):
    def __init__(self, map_: Map):
        super().__init__(map_.label + "_entry")
        self.map = map_


class MapExit(Node):
    def __init__(self, map_: Map, entry: MapEntry):
        super().__init__(map_.label + "_exit")
        self.map = map_
        self.entry = entry


class NestedSDFG(Node):
    def __init__(self, label: str, sdfg: "SDFG", inputs: Sequence[str],
                 outputs: Sequence[str], symbol_mapping: Dict[str, ExprLike] = None):
        super().__init__(label)
        self.sdfg = sdfg
        self.inputs = list(inputs)
        self.outputs = list(outputs)
        self.symbol_mapping = {k: Expr.wrap(v) for k, v in (symbol_mapping or {}).items()}


class LibraryNode(Node):
    """Abstract-behavior node (paper §3). Subclasses register named
    expansions at decreasing abstraction levels; ``expand`` rewrites the
    node in-place into the chosen implementation subgraph."""

    #: name -> callable(node, sdfg, state) -> None (mutates graph)
    expansions: Dict[str, Callable] = {}
    default_expansion: str = "xla"

    def __init__(self, name: str, inputs: Sequence[str], outputs: Sequence[str]):
        super().__init__(name)
        self.inputs = list(inputs)
        self.outputs = list(outputs)

    # -- context inspection helpers (paper: "Library Nodes can inspect
    #    their context using the surrounding memlets and nodes") ----------
    def in_edges(self, state: "State"):
        return state.in_edges(self)

    def out_edges(self, state: "State"):
        return state.out_edges(self)

    def input_desc(self, state: "State", conn: str) -> Data:
        for e in state.in_edges(self):
            if e.dst_conn == conn:
                return state.sdfg.arrays[e.memlet.data]
        raise KeyError(conn)

    def output_desc(self, state: "State", conn: str) -> Data:
        for e in state.out_edges(self):
            if e.src_conn == conn:
                return state.sdfg.arrays[e.memlet.data]
        raise KeyError(conn)

    def expand(self, sdfg: "SDFG", state: "State", level: Optional[str] = None) -> str:
        level = level or self.pick_expansion(sdfg, state)
        impl = self.expansions[level]
        impl(self, sdfg, state)
        return level

    def pick_expansion(self, sdfg: "SDFG", state: "State") -> str:
        pref = sdfg.expansion_preference
        for name in pref:
            if name in self.expansions:
                return name
        return self.default_expansion


# ---------------------------------------------------------------------------
# Edges
# ---------------------------------------------------------------------------


@dataclass
class DataflowEdge:
    src: Node
    src_conn: Optional[str]
    dst: Node
    dst_conn: Optional[str]
    memlet: Memlet
    key: int = 0  # multigraph key


@dataclass
class InterstateEdge:
    condition: Optional[Callable[[Dict[str, int]], bool]] = None
    assignments: Dict[str, Callable[[Dict[str, int]], int]] = field(default_factory=dict)


# ---------------------------------------------------------------------------
# State: pure dataflow multigraph
# ---------------------------------------------------------------------------


class State:
    def __init__(self, label: str, sdfg: "SDFG"):
        self.label = label
        self.sdfg = sdfg
        self.graph = nx.MultiDiGraph()

    # -- construction ---------------------------------------------------
    def add_node(self, node: Node) -> Node:
        self.graph.add_node(node)
        return node

    def add_access(self, data: str) -> AccessNode:
        return self.add_node(AccessNode(data))

    def add_tasklet(self, name: str, inputs: Sequence[str], outputs: Sequence[str],
                    fn: Callable) -> Tasklet:
        return self.add_node(Tasklet(name, inputs, outputs, fn))

    def add_map(self, label: str, params: Dict[str, Tuple[ExprLike, ExprLike]],
                schedule: ScheduleType = ScheduleType.PIPELINED) -> Tuple[MapEntry, MapExit]:
        m = Map(
            params=list(params.keys()),
            ranges=[Range.make(lo, hi) for lo, hi in params.values()],
            schedule=schedule, label=label,
        )
        entry = MapEntry(m)
        exit_ = MapExit(m, entry)
        self.add_node(entry)
        self.add_node(exit_)
        return entry, exit_

    def add_edge(self, src: Node, src_conn: Optional[str], dst: Node,
                 dst_conn: Optional[str], memlet: Memlet) -> DataflowEdge:
        key = self.graph.add_edge(src, dst)
        e = DataflowEdge(src, src_conn, dst, dst_conn, memlet, key)
        self.graph.edges[src, dst, key]["edge"] = e
        return e

    def add_nested_sdfg(self, sdfg: "SDFG", inputs, outputs, symbol_mapping=None,
                        label: str = "nested") -> NestedSDFG:
        n = NestedSDFG(label, sdfg, inputs, outputs, symbol_mapping)
        sdfg.parent = self.sdfg
        return self.add_node(n)

    def add_mapped_tasklet(self, name: str, params: Dict[str, Tuple[ExprLike, ExprLike]],
                           inputs: Dict[str, Memlet], outputs: Dict[str, Memlet],
                           fn: Callable,
                           schedule: ScheduleType = ScheduleType.PIPELINED,
                           input_nodes: Dict[str, AccessNode] = None,
                           output_nodes: Dict[str, AccessNode] = None):
        """Convenience: access -> map entry -> tasklet -> map exit -> access."""
        entry, exit_ = self.add_map(name, params, schedule)
        t = self.add_tasklet(name, list(inputs.keys()), list(outputs.keys()), fn)
        input_nodes = input_nodes or {}
        output_nodes = output_nodes or {}
        if not inputs:
            self.add_edge(entry, None, t, None, Memlet(data=None))
        for conn, memlet in inputs.items():
            an = input_nodes.get(memlet.data) or self.add_access(memlet.data)
            self.add_edge(an, None, entry, f"IN_{memlet.data}",
                          Memlet.simple(memlet.data))
            self.add_edge(entry, f"OUT_{memlet.data}", t, conn, memlet)
        for conn, memlet in outputs.items():
            an = output_nodes.get(memlet.data) or self.add_access(memlet.data)
            self.add_edge(t, conn, exit_, f"IN_{memlet.data}", memlet)
            self.add_edge(exit_, f"OUT_{memlet.data}", an, None,
                          Memlet.simple(memlet.data, wcr=memlet.wcr))
        return t, entry, exit_

    def remove_node(self, node: Node):
        self.graph.remove_node(node)

    def remove_edge(self, e: DataflowEdge):
        self.graph.remove_edge(e.src, e.dst, e.key)

    # -- queries ----------------------------------------------------------
    @property
    def nodes(self) -> List[Node]:
        return list(self.graph.nodes)

    @property
    def edges(self) -> List[DataflowEdge]:
        return [d["edge"] for _, _, d in self.graph.edges(data=True)]

    def in_edges(self, node: Node) -> List[DataflowEdge]:
        return [d["edge"] for _, _, d in self.graph.in_edges(node, data=True)]

    def out_edges(self, node: Node) -> List[DataflowEdge]:
        return [d["edge"] for _, _, d in self.graph.out_edges(node, data=True)]

    def in_degree(self, node: Node) -> int:
        return self.graph.in_degree(node)

    def out_degree(self, node: Node) -> int:
        return self.graph.out_degree(node)

    def topological_nodes(self) -> List[Node]:
        return list(nx.topological_sort(self.graph))

    def data_nodes(self) -> List[AccessNode]:
        return [n for n in self.graph.nodes if isinstance(n, AccessNode)]

    def library_nodes(self) -> List[LibraryNode]:
        out = [n for n in self.graph.nodes if isinstance(n, LibraryNode)]
        for n in self.graph.nodes:
            if isinstance(n, NestedSDFG):
                for st in n.sdfg.states:
                    out.extend(st.library_nodes())
        return out

    # -- scopes -------------------------------------------------------------
    def scope_children(self) -> Dict[Optional[MapEntry], List[Node]]:
        """Map from scope (None = top level) to directly-contained nodes."""
        result: Dict[Optional[MapEntry], List[Node]] = {None: []}
        scope_of: Dict[Node, Optional[MapEntry]] = {}
        for node in self.topological_nodes():
            preds = [e.src for e in self.in_edges(node)]
            entry_preds = [p for p in preds if isinstance(p, MapEntry)]
            if not preds:
                scope = None
            elif entry_preds:
                scope = entry_preds[0]
            else:
                p = preds[0]
                if isinstance(p, MapExit):
                    scope = scope_of.get(p.entry, None)
                else:
                    scope = scope_of.get(p, None)
            # MapExit closes its own scope:
            if isinstance(node, MapExit):
                scope = scope_of.get(node.entry, None)
            scope_of[node] = scope
            result.setdefault(scope, []).append(node)
            if isinstance(node, MapEntry):
                result.setdefault(node, [])
        return result

    # -- processing elements (paper §2.4) ------------------------------------
    def processing_elements(self) -> List[List[Node]]:
        """Weakly connected components = independently scheduled PEs.
        Components that only synchronize through a shared stream container
        still count as separate PEs (paper: they synchronize by push/pop)."""
        comps = list(nx.weakly_connected_components(self.graph))
        return [list(c) for c in comps]

    # -- the paper's headline metric ------------------------------------------
    def off_chip_volume(self, env: Optional[Dict[str, int]] = None,
                        symbolic: bool = False):
        """Total bytes moved to/from HBM in this state, from memlet
        annotations (paper Tables 1-3 'Off-Chip Volume' column)."""
        env = env or {}
        total = Expr.const(0)
        for e in self.edges:
            for node in (e.src, e.dst):
                if isinstance(node, AccessNode):
                    if node.data in self.sdfg.constants:
                        continue  # InputToConstant: folded into the program
                    desc = self.sdfg.arrays[node.data]
                    if desc.storage.off_chip and not isinstance(desc, Stream):
                        vol = e.memlet.volume_or_subset()
                        if vol is None:
                            vol = desc.num_elements
                        total = total + vol * desc.dtype.bytes
                        break  # count each edge once even if both ends are HBM
        if symbolic:
            return total
        full_env = dict(self.sdfg.symbol_values)
        full_env.update(env)
        return total.evaluate(full_env)

    def __repr__(self):
        return f"State({self.label}, {len(self.nodes)} nodes)"


# ---------------------------------------------------------------------------
# SDFG
# ---------------------------------------------------------------------------


class SDFG:
    def __init__(self, name: str):
        self.name = name
        self.arrays: Dict[str, Data] = {}
        self.symbols: Dict[str, DType] = {}
        self.symbol_values: Dict[str, int] = {}   # defaults / specialization
        self.constants: Dict[str, np.ndarray] = {}  # InputToConstant results
        self.states: List[State] = []
        self.cfg = nx.DiGraph()
        self.start_state: Optional[State] = None
        self.parent: Optional[SDFG] = None
        #: ordered expansion preference used by LibraryNode.pick_expansion,
        #: e.g. ("pallas", "xla", "generic") for the explicit backend.
        self.expansion_preference: Tuple[str, ...] = ("xla", "generic")
        #: free-form annotations (transformation history, vector width, ...)
        self.metadata: Dict[str, Any] = {"transformation_history": []}

    # -- containers -----------------------------------------------------
    def _add(self, name: str, desc: Data, allow_exists=False) -> str:
        if name in self.arrays and not allow_exists:
            raise ValueError(f"container {name!r} already exists")
        self.arrays[name] = desc
        return name

    def add_array(self, name: str, shape: Sequence[ExprLike], dtype,
                  storage: StorageType = StorageType.DEFAULT,
                  transient: bool = False) -> str:
        shp = tuple(Expr.wrap(s) for s in shape)
        for s in shp:
            for sname in s.free_symbols:
                self.symbols.setdefault(sname, DType("int64"))
        return self._add(name, Array(dtype=DType(dtype), storage=storage,
                                     transient=transient, shape=shp))

    def add_transient(self, name: str, shape, dtype,
                      storage: StorageType = StorageType.DEFAULT) -> str:
        return self.add_array(name, shape, dtype, storage, transient=True)

    def add_scalar(self, name: str, dtype, storage=StorageType.DEFAULT,
                   transient=False) -> str:
        return self._add(name, Scalar(dtype=DType(dtype), storage=storage,
                                      transient=transient))

    def add_stream(self, name: str, dtype, buffer_size: int = 4,
                   shape: Sequence[ExprLike] = (),
                   element_shape: Sequence[ExprLike] = (),
                   total_volume: ExprLike = None,
                   storage: StorageType = StorageType.VMEM) -> str:
        return self._add(name, Stream(
            dtype=DType(dtype), storage=storage, transient=True,
            buffer_size=buffer_size,
            shape=tuple(Expr.wrap(s) for s in shape),
            element_shape=tuple(Expr.wrap(s) for s in element_shape),
            total_volume=Expr.wrap(total_volume) if total_volume is not None else None))

    # -- states ----------------------------------------------------------
    def add_state(self, label: str, is_start: bool = False) -> State:
        st = State(label, self)
        self.states.append(st)
        self.cfg.add_node(st)
        if is_start or self.start_state is None:
            self.start_state = st
        return st

    def add_state_after(self, prev: State, label: str) -> State:
        st = self.add_state(label)
        self.add_interstate_edge(prev, st)
        return st

    def add_state_before(self, nxt: State, label: str) -> State:
        st = self.add_state(label)
        # redirect incoming edges of nxt
        for pred in list(self.cfg.predecessors(nxt)):
            data = self.cfg.edges[pred, nxt]["edge"]
            self.cfg.remove_edge(pred, nxt)
            self.cfg.add_edge(pred, st, edge=data)
        self.add_interstate_edge(st, nxt)
        if self.start_state is nxt:
            self.start_state = st
        return st

    def add_interstate_edge(self, src: State, dst: State,
                            edge: InterstateEdge = None):
        self.cfg.add_edge(src, dst, edge=edge or InterstateEdge())

    def state_order(self) -> List[State]:
        if not self.states:
            return []
        return list(nx.topological_sort(self.cfg))

    # -- whole-graph queries ------------------------------------------------
    def all_library_nodes(self) -> List[Tuple[State, LibraryNode]]:
        out = []
        for st in self.states:
            for n in st.library_nodes():
                # find owning state (could be nested)
                out.append((st, n))
        return out

    def off_chip_volume(self, env=None, symbolic=False):
        if symbolic:
            total = Expr.const(0)
            for st in self.states:
                total = total + st.off_chip_volume(env, symbolic=True)
            return total
        return sum(st.off_chip_volume(env) for st in self.states)

    def free_symbols(self) -> set:
        out = set()
        for desc in self.arrays.values():
            shape = getattr(desc, "shape", ())
            for s in shape:
                out |= s.free_symbols
        return out

    # -- content hash (pipeline cache key) ----------------------------------
    def content_hash(self) -> str:
        """Structural hash over topology, descriptors, and symbols.

        Two SDFGs built identically (same frontend calls, same transforms)
        hash equal, so the compilation cache can serve repeated
        ``compile()`` calls — including across separately-built but
        identical programs. Mutating the graph, a descriptor, a symbol
        binding, a constant, or compile-relevant metadata changes the hash.
        """
        h = hashlib.sha256()

        def put(*parts):
            for p in parts:
                h.update(repr(p).encode())
                h.update(b"\x00")

        put("sdfg", self.name, self.expansion_preference)
        for name, dt in sorted(self.symbols.items()):
            put("sym", name, dt.name)
        for name, v in sorted(self.symbol_values.items()):
            put("symval", name, v)
        for name, arr in sorted(self.constants.items()):
            a = np.ascontiguousarray(arr)
            put("const", name, a.dtype.str, a.shape,
                hashlib.sha1(a.tobytes()).hexdigest())
        for key in sorted(self.metadata):
            if key == "transformation_history":
                continue  # provenance, not content
            put("meta", key, _stable_repr(self.metadata[key]))
        for name, desc in sorted(self.arrays.items()):
            put("container", name, _descriptor_signature(desc))

        states = {st: i for i, st in enumerate(self.states)}
        for st in self.states:
            put("state", st.label)
            index = {}
            for i, node in enumerate(st.graph.nodes):
                index[node] = i
                put("node", i, _node_signature(node))
            for u, v, k, d in st.graph.edges(keys=True, data=True):
                e = d["edge"]
                put("edge", index[e.src], e.src_conn, index[e.dst],
                    e.dst_conn, k, e.memlet)
        for src, dst, d in self.cfg.edges(data=True):
            e = d.get("edge")
            put("cfedge", states[src], states[dst],
                _callable_fingerprint(getattr(e, "condition", None)),
                sorted((k, _callable_fingerprint(v)) for k, v in
                       (getattr(e, "assignments", None) or {}).items()))
        put("start", states.get(self.start_state))
        return h.hexdigest()

    # -- library-node expansion (paper §3: multi-level lowering) -----------
    def expand_library_nodes(self, level: Optional[str] = None,
                             recursive: bool = True) -> List[str]:
        """Expand until no library nodes remain; returns expansion log."""
        log = []
        progress = True
        while progress:
            progress = False
            for st in list(self.states):
                for node in list(st.graph.nodes):
                    if isinstance(node, LibraryNode):
                        used = node.expand(self, st, level)
                        log.append(f"{node.label}->{used}")
                        progress = True
                    elif isinstance(node, NestedSDFG) and recursive:
                        log.extend(node.sdfg.expand_library_nodes(level))
        return log

    # -- transformations ----------------------------------------------------
    def apply(self, transformation, **kwargs) -> int:
        """Apply a transformation class/instance everywhere it matches.
        Returns number of applications (paper §3.2)."""
        from ..transforms.base import Transformation
        t = transformation() if isinstance(transformation, type) else transformation
        n = t.apply_everywhere(self, **kwargs)
        self.metadata["transformation_history"].append(
            (type(t).__name__, n, kwargs))
        return n

    # -- validation / compilation -------------------------------------------
    def validate(self):
        from .validation import validate_sdfg
        validate_sdfg(self)

    def specialize(self, **symbol_values: int):
        self.symbol_values.update(symbol_values)
        return self

    def compile(self, backend: str = "jnp", jit: bool = True, **kwargs):
        """Legacy one-shot compile; delegates to the staged pipeline
        (pipeline.Lowered) with in-place lowering. Prefer
        ``pipeline.lower(sdfg).compile(...)`` in new code."""
        from ..codegen.compiler import compile_sdfg
        return compile_sdfg(self, backend=backend, jit=jit, **kwargs)

    def argument_names(self) -> List[str]:
        """Non-transient containers = program arguments, in insertion order."""
        return [k for k, v in self.arrays.items()
                if not v.transient and k not in self.constants]

    def __repr__(self):
        return (f"SDFG({self.name}: {len(self.states)} states, "
                f"{len(self.arrays)} containers)")


# ---------------------------------------------------------------------------
# Content-hash helpers
# ---------------------------------------------------------------------------


def _stable_repr(value) -> str:
    if isinstance(value, np.ndarray):
        a = np.ascontiguousarray(value)
        return f"ndarray({a.dtype},{a.shape}," \
               f"{hashlib.sha1(a.tobytes()).hexdigest()})"
    if isinstance(value, dict):
        return "{" + ",".join(f"{k}:{_stable_repr(v)}"
                              for k, v in sorted(value.items())) + "}"
    if isinstance(value, (set, frozenset)):
        return "{" + ",".join(sorted(_stable_repr(v) for v in value)) + "}"
    if isinstance(value, (list, tuple)):
        return "(" + ",".join(_stable_repr(v) for v in value) + ")"
    return repr(value)


def _callable_fingerprint(fn) -> str:
    """Stable-enough identity for a tasklet body / interstate condition:
    qualname + bytecode digest + primitive constants and closure values.
    Distinct-but-equal callables may fingerprint apart (a cache miss, never
    a false hit within one build style)."""
    if fn is None:
        return "none"
    parts = [getattr(fn, "__qualname__", None) or repr(type(fn))]
    code = getattr(fn, "__code__", None)
    if code is not None:
        parts.append(hashlib.sha1(code.co_code).hexdigest())
        # co_names: bytecode only stores name *indices*, so two bodies
        # calling different globals (sin vs cos) share co_code
        parts.append(_stable_repr(code.co_names))
        parts.append(_stable_repr(tuple(
            c for c in code.co_consts
            if isinstance(c, (int, float, str, bytes, bool, type(None))))))
    for d in (getattr(fn, "__defaults__", None) or ()):
        parts.append(_callable_fingerprint(d) if callable(d)
                     else _stable_repr(d))
    for cell in (getattr(fn, "__closure__", None) or ()):
        try:
            v = cell.cell_contents
        except ValueError:
            continue
        parts.append(_closure_value_fingerprint(v))
    return "|".join(parts)


def _closure_value_fingerprint(v) -> str:
    if isinstance(v, (int, float, str, bytes, bool, tuple, list, dict, set,
                      frozenset, type(None), np.ndarray)):
        return _stable_repr(v)
    if callable(v):
        return _callable_fingerprint(v)
    if hasattr(v, "__array__"):  # jax arrays etc.; repr would truncate
        return _stable_repr(np.asarray(v))
    # arbitrary object: repr may embed an address — at worst a cache
    # miss across rebuilds, never a false hit
    return f"{type(v).__name__}:{v!r}"


def _descriptor_signature(desc: Data) -> tuple:
    sig = (type(desc).__name__, desc.dtype.name, desc.storage.value,
           desc.transient)
    if isinstance(desc, Stream):
        sig += (desc.buffer_size, desc.shape, desc.element_shape,
                desc.total_volume)
    elif isinstance(desc, Array):
        sig += (desc.shape, desc.vector_width)
    return sig


def _map_signature(m: Map) -> tuple:
    return (m.label, tuple(m.params), tuple(m.ranges), m.schedule.value,
            m.vector_width, _stable_repr(m.annotations))


def _node_signature(node: Node) -> tuple:
    if isinstance(node, AccessNode):
        return ("access", node.data)
    if isinstance(node, Tasklet):
        return ("tasklet", node.label, tuple(node.inputs),
                tuple(node.outputs), _callable_fingerprint(node.fn))
    if isinstance(node, MapEntry):
        return ("map_entry", _map_signature(node.map))
    if isinstance(node, MapExit):
        return ("map_exit", node.map.label)
    if isinstance(node, NestedSDFG):
        return ("nested", node.label, tuple(node.inputs),
                tuple(node.outputs),
                tuple(sorted((k, repr(v))
                             for k, v in node.symbol_mapping.items())),
                node.sdfg.content_hash())
    if isinstance(node, LibraryNode):
        # every instance attribute is potentially computation-defining
        # (Ger.alpha, Gemv.trans, Conv2d.activation, Stencil.offsets, ...)
        attrs = tuple(sorted(
            (k, _callable_fingerprint(v) if callable(v) else _stable_repr(v))
            for k, v in vars(node).items() if k != "uid"))
        return ("library", type(node).__name__, attrs)
    return (type(node).__name__, node.label)
