"""Memlets: data-movement annotations on dataflow edges (paper Fig. 2/7).

A memlet names the data container being moved, the subset being accessed
(symbolic ranges, possibly referencing map parameters), the total data
volume moved over the lifetime of the scope (e.g. ``K*M*N/P`` in Fig. 7),
and an optional write-conflict resolution (``wcr``) for accumulation.

The *access order* of a memlet — its index expressions with map parameters
canonicalized to positional indices — is what StreamingComposition compares
to decide whether a producer and consumer can be fused through a stream.

``factor_subset`` is the grid-codegen analysis (paper: memlets become the
platform kernel's address generators): it factors an affine subset into a
``block_shape`` plus per-dimension block-coordinate expressions over the
map parameters — exactly the ``(block_shape, index_map)`` pair a Pallas
``BlockSpec`` needs.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from fractions import Fraction
from typing import Dict, Mapping, Optional, Sequence, Tuple

from .symbolic import Expr, ExprLike, prod


@dataclass(frozen=True)
class Range:
    """Half-open symbolic range [start, stop) with step."""
    start: Expr
    stop: Expr
    step: Expr

    @staticmethod
    def make(start: ExprLike, stop: ExprLike, step: ExprLike = 1) -> "Range":
        return Range(Expr.wrap(start), Expr.wrap(stop), Expr.wrap(step))

    @staticmethod
    def index(i: ExprLike) -> "Range":
        e = Expr.wrap(i)
        return Range(e, e + 1, Expr.const(1))

    @property
    def size(self) -> Expr:
        return (self.stop - self.start) / self.step

    def is_index(self) -> bool:
        # size == 1 without forming (stop-start)/step: symbolic division
        # by a multi-term step (a per-iteration stride like i+1) raises
        return self.stop - self.start == self.step

    def subs(self, env) -> "Range":
        return Range(self.start.subs(env), self.stop.subs(env), self.step.subs(env))

    def __repr__(self):
        if self.is_index():
            return f"[{self.start}]"
        s = f"[{self.start}:{self.stop}"
        if self.step != Expr.const(1):
            s += f":{self.step}"
        return s + "]"


class Subset(tuple):
    """Tuple of Ranges, one per container dimension."""

    def __new__(cls, ranges: Sequence[Range]):
        return super().__new__(cls, tuple(ranges))

    @staticmethod
    def full(shape: Sequence[ExprLike]) -> "Subset":
        return Subset([Range.make(0, s) for s in shape])

    @staticmethod
    def indices(idx: Sequence[ExprLike]) -> "Subset":
        return Subset([Range.index(i) for i in idx])

    @property
    def num_elements(self) -> Expr:
        return prod(r.size for r in self)

    def subs(self, env) -> "Subset":
        return Subset([r.subs(env) for r in self])

    def __repr__(self):
        return "".join(repr(r) for r in self)


@dataclass
class Memlet:
    """Data movement annotation: container + subset + volume (+ wcr)."""
    data: str
    subset: Optional[Subset] = None       # None = whole container
    volume: Optional[Expr] = None         # None = subset.num_elements (once)
    wcr: Optional[str] = None             # e.g. "add" for accumulation writes
    dynamic: bool = False                 # data-dependent volume

    @staticmethod
    def simple(data: str, subset: Optional[Subset] = None,
               volume: ExprLike = None, wcr: str = None) -> "Memlet":
        v = Expr.wrap(volume) if volume is not None else None
        return Memlet(data=data, subset=subset, volume=v, wcr=wcr)

    def volume_or_subset(self) -> Optional[Expr]:
        if self.volume is not None:
            return self.volume
        if self.subset is not None:
            return self.subset.num_elements
        return None

    def access_order(self, param_names: Sequence[str]) -> Tuple:
        """Canonical access-order key: index expressions with map params
        remapped to positional placeholders (paper §3.2.3). Two memlets with
        equal keys iterate their containers in the same order."""
        if self.subset is None:
            return ("FULL", self.data and None)
        env = {p: Expr.sym(f"__i{k}") for k, p in enumerate(param_names)}
        return tuple(
            (r.start.subs(env), r.stop.subs(env), r.step.subs(env))
            for r in self.subset
        )

    def __repr__(self):
        s = f"Memlet({self.data}{self.subset if self.subset is not None else ''}"
        if self.volume is not None:
            s += f", vol={self.volume}"
        if self.wcr:
            s += f", wcr={self.wcr}"
        return s + ")"


# ---------------------------------------------------------------------------
# Subset -> (block_shape, index_map) factorization for grid codegen
# ---------------------------------------------------------------------------


class BlockFactorError(ValueError):
    """Raised when a subset cannot be factored into blocked form (non-affine
    indices, unaligned offsets, dynamic symbols, ...). Callers fall back to
    the structural-interpreter lowering, mirroring the paper's fallback to
    generic expansions."""


def _int_coeff(c, context) -> int:
    if isinstance(c, Fraction):
        if c.denominator != 1:
            raise BlockFactorError(f"non-integer coefficient {c} in {context}")
        return c.numerator
    return int(c)


def _affine_coeffs(e: Expr, context) -> Tuple[int, Dict[str, int]]:
    """Decompose ``e`` as ``c0 + sum(c_s * s)``; reject higher degrees."""
    c0, coeffs = 0, {}
    for mono, c in e.terms.items():
        if mono == ():
            c0 = _int_coeff(c, context)
        elif len(mono) == 1 and mono[0][1] == 1:
            coeffs[mono[0][0]] = _int_coeff(c, context)
        else:
            raise BlockFactorError(f"non-affine index {e} in {context}")
    return c0, coeffs


def eval_affine(e: Expr, env: Mapping[str, object]):
    """Evaluate an integer-affine Expr where symbols may be bound to traced
    scalars (used by BlockSpec index maps at kernel-trace time)."""
    const, out = 0, None
    for mono, c in e.terms.items():
        ci = _int_coeff(c, e)
        if mono == ():
            const += ci
        else:
            (name, _), = mono
            term = env[name] if ci == 1 else ci * env[name]
            out = term if out is None else out + term
    if out is None:
        return const
    return out + const if const else out


@dataclass(frozen=True)
class SubsetFactorization:
    """A subset factored into per-dimension blocks.

    ``block_shape[d]`` elements are moved per grid step along dim ``d``;
    ``index_exprs[d]`` gives the *block* coordinate as an integer-affine
    expression over 0-based grid parameters; ``squeeze_dims`` are the
    size-1 index dimensions ``read_memlet`` squeezes; ``param_dims`` maps
    each intra-block (tile) parameter to the container dimension it spans.

    ``windows`` handles block-*misaligned* affine accesses (stencil halo
    offsets): a windowed dimension moves the whole container extent per
    grid step (``block_shape[d]`` = container dim, block coordinate 0) and
    the kernel body slices an element-addressed window out of it in-VMEM —
    each entry is ``(dim, element-start Expr over grid params, length)``.
    """
    block_shape: Tuple[int, ...]
    index_exprs: Tuple[Expr, ...]
    squeeze_dims: Tuple[int, ...]
    param_dims: Tuple[Tuple[str, int], ...] = ()
    windows: Tuple[Tuple[int, Expr, int], ...] = ()

    def index_map(self, param_order: Sequence[str]):
        """Build ``f(*grid_ids) -> block coords`` for a Pallas BlockSpec."""
        exprs = self.index_exprs
        names = tuple(param_order)

        def f(*ids):
            env = dict(zip(names, ids))
            return tuple(eval_affine(e, env) for e in exprs)

        return f

    def effective_shape(self) -> Tuple[int, ...]:
        """Shape of the value the kernel body sees: the block shape with
        windowed dimensions narrowed to their window length."""
        shp = list(self.block_shape)
        for d, _, ln in self.windows:
            shp[d] = ln
        return tuple(shp)


def factor_subset(subset: Optional[Subset], shape: Sequence[ExprLike],
                  grid_params: Mapping[str, Tuple[int, int]],
                  block_params: Mapping[str, int],
                  env: Mapping[str, int],
                  allow_windows: bool = False) -> SubsetFactorization:
    """Factor ``subset`` into ``(block_shape, index_map)`` form.

    ``grid_params`` maps each grid parameter to its ``(range_start, size)``
    — index expressions are rebased so parameters are 0-based grid
    coordinates. ``block_params`` map intra-block (tile) parameters to
    their extents; a dimension indexed by a tile parameter widens into a
    block of that extent. ``env`` binds the remaining *static* symbols.
    Raises :class:`BlockFactorError` when the subset is non-affine, refers
    to unknown (dynamic) symbols, or its offsets don't align to the block.

    With ``allow_windows``, a block-misaligned dimension (a stencil halo
    offset, a non-block-multiple grid stride) degrades to a *window*
    instead of raising: the BlockSpec moves the whole container dimension
    and the factorization records an element-addressed window the kernel
    body slices per grid step.
    """
    env = dict(env)
    shape_sizes = []
    for s in shape:
        try:
            shape_sizes.append(Expr.wrap(s).evaluate(env))
        except Exception as exc:
            raise BlockFactorError(f"dynamic container shape {s}") from exc
    if subset is None:
        return SubsetFactorization(
            tuple(shape_sizes),
            tuple(Expr.const(0) for _ in shape_sizes), ())
    if len(subset) != len(shape_sizes):
        raise BlockFactorError(
            f"subset rank {len(subset)} != container rank {len(shape_sizes)}")
    rebase = {p: Expr.sym(p) + st for p, (st, _) in grid_params.items()
              if st != 0}
    block_shape, exprs, squeeze = [], [], []
    param_dims: Dict[str, int] = {}
    windows = []
    for d, r in enumerate(subset):
        ctx = f"dim {d} of {subset}"
        step = r.step.subs(env)
        if not step.is_const() or step.as_int() != 1:
            raise BlockFactorError(f"strided range (step {step}) in {ctx}")
        size = r.size.subs(env)
        if not size.is_const():
            raise BlockFactorError(f"dynamic range size {size} in {ctx}")
        sz = size.as_int()
        start = r.start.subs(env)
        if rebase:
            start = start.subs(rebase)
        c0, coeffs = _affine_coeffs(start, ctx)
        unknown = set(coeffs) - set(grid_params) - set(block_params)
        if unknown:
            raise BlockFactorError(f"unbound symbols {sorted(unknown)} in {ctx}")
        bsyms = sorted(s for s in coeffs if s in block_params)
        q = None
        if bsyms:
            if len(bsyms) > 1:
                raise BlockFactorError(
                    f"multiple tile params {bsyms} in one dimension ({ctx})")
            q = bsyms[0]
            if sz != 1 or coeffs[q] != 1:
                raise BlockFactorError(
                    f"tile param {q} must index with unit stride a size-1 "
                    f"range ({ctx})")
            if q in param_dims:
                raise BlockFactorError(
                    f"tile param {q} indexes two dimensions ({ctx})")
            bs = block_params[q]
            param_dims[q] = d
        else:
            bs = sz
        if bs <= 0:
            raise BlockFactorError(f"empty block in {ctx}")
        misaligned = bool(c0 % bs) or any(
            cg % bs for g, cg in coeffs.items() if g not in block_params)
        if misaligned and allow_windows and bs > 1:
            # whole container dimension per step; element-addressed window
            start_expr = Expr.const(c0)
            for g, cg in coeffs.items():
                if g not in block_params:
                    start_expr = start_expr + Expr.sym(g) * cg
            block_shape.append(shape_sizes[d])
            exprs.append(Expr.const(0))
            windows.append((d, start_expr, bs))
            continue
        if c0 % bs:
            raise BlockFactorError(
                f"offset {c0} not aligned to block {bs} ({ctx})")
        iexpr = Expr.const(c0 // bs)
        for g, cg in coeffs.items():
            if g in block_params:
                continue
            if cg % bs:
                raise BlockFactorError(
                    f"grid coefficient {cg} of {g} not divisible by block "
                    f"{bs} ({ctx})")
            iexpr = iexpr + Expr.sym(g) * (cg // bs)
        block_shape.append(bs)
        exprs.append(iexpr)
        if r.is_index() and bs == 1:
            squeeze.append(d)
    return SubsetFactorization(tuple(block_shape), tuple(exprs),
                               tuple(squeeze),
                               tuple(sorted(param_dims.items())),
                               tuple(windows))
