"""Memlets: data-movement annotations on dataflow edges (paper Fig. 2/7).

A memlet names the data container being moved, the subset being accessed
(symbolic ranges, possibly referencing map parameters), the total data
volume moved over the lifetime of the scope (e.g. ``K*M*N/P`` in Fig. 7),
and an optional write-conflict resolution (``wcr``) for accumulation.

The *access order* of a memlet — its index expressions with map parameters
canonicalized to positional indices — is what StreamingComposition compares
to decide whether a producer and consumer can be fused through a stream.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Optional, Sequence, Tuple

from .symbolic import Expr, ExprLike, prod


@dataclass(frozen=True)
class Range:
    """Half-open symbolic range [start, stop) with step."""
    start: Expr
    stop: Expr
    step: Expr

    @staticmethod
    def make(start: ExprLike, stop: ExprLike, step: ExprLike = 1) -> "Range":
        return Range(Expr.wrap(start), Expr.wrap(stop), Expr.wrap(step))

    @staticmethod
    def index(i: ExprLike) -> "Range":
        e = Expr.wrap(i)
        return Range(e, e + 1, Expr.const(1))

    @property
    def size(self) -> Expr:
        return (self.stop - self.start) / self.step

    def is_index(self) -> bool:
        return self.size == Expr.const(1)

    def subs(self, env) -> "Range":
        return Range(self.start.subs(env), self.stop.subs(env), self.step.subs(env))

    def __repr__(self):
        if self.is_index():
            return f"[{self.start}]"
        s = f"[{self.start}:{self.stop}"
        if self.step != Expr.const(1):
            s += f":{self.step}"
        return s + "]"


class Subset(tuple):
    """Tuple of Ranges, one per container dimension."""

    def __new__(cls, ranges: Sequence[Range]):
        return super().__new__(cls, tuple(ranges))

    @staticmethod
    def full(shape: Sequence[ExprLike]) -> "Subset":
        return Subset([Range.make(0, s) for s in shape])

    @staticmethod
    def indices(idx: Sequence[ExprLike]) -> "Subset":
        return Subset([Range.index(i) for i in idx])

    @property
    def num_elements(self) -> Expr:
        return prod(r.size for r in self)

    def subs(self, env) -> "Subset":
        return Subset([r.subs(env) for r in self])

    def __repr__(self):
        return "".join(repr(r) for r in self)


@dataclass
class Memlet:
    """Data movement annotation: container + subset + volume (+ wcr)."""
    data: str
    subset: Optional[Subset] = None       # None = whole container
    volume: Optional[Expr] = None         # None = subset.num_elements (once)
    wcr: Optional[str] = None             # e.g. "add" for accumulation writes
    dynamic: bool = False                 # data-dependent volume

    @staticmethod
    def simple(data: str, subset: Optional[Subset] = None,
               volume: ExprLike = None, wcr: str = None) -> "Memlet":
        v = Expr.wrap(volume) if volume is not None else None
        return Memlet(data=data, subset=subset, volume=v, wcr=wcr)

    def volume_or_subset(self) -> Optional[Expr]:
        if self.volume is not None:
            return self.volume
        if self.subset is not None:
            return self.subset.num_elements
        return None

    def access_order(self, param_names: Sequence[str]) -> Tuple:
        """Canonical access-order key: index expressions with map params
        remapped to positional placeholders (paper §3.2.3). Two memlets with
        equal keys iterate their containers in the same order."""
        if self.subset is None:
            return ("FULL", self.data and None)
        env = {p: Expr.sym(f"__i{k}") for k, p in enumerate(param_names)}
        return tuple(
            (r.start.subs(env), r.stop.subs(env), r.step.subs(env))
            for r in self.subset
        )

    def __repr__(self):
        s = f"Memlet({self.data}{self.subset if self.subset is not None else ''}"
        if self.volume is not None:
            s += f", vol={self.volume}"
        if self.wcr:
            s += f", wcr={self.wcr}"
        return s + ")"
