"""Tiny symbolic-expression engine for memlet volumes and shapes.

The paper annotates every dataflow edge with a (possibly symbolic) data
volume, e.g. ``K*M*N/P`` for the systolic-array B reader in Fig. 7.  DaCe
uses sympy; we implement the minimal subset needed: integer-coefficient
sums of products of named symbols, with substitution and exact division.

Expressions are immutable and hashable.  ``simplify`` is canonical enough
for equality testing of the access-order expressions compared by the
StreamingComposition transformation.
"""
from __future__ import annotations

import math
from fractions import Fraction
from typing import Iterable, Mapping, Union

Number = Union[int, Fraction]


def _as_frac(x) -> Fraction:
    if isinstance(x, Fraction):
        return x
    if isinstance(x, int):
        return Fraction(x)
    raise TypeError(f"non-integer coefficient {x!r}")


class Expr:
    """Canonical polynomial: {monomial(tuple of sorted symbol names w/ powers): coeff}."""

    __slots__ = ("terms",)

    def __init__(self, terms: Mapping[tuple, Number] | None = None):
        t = {}
        for mono, c in (terms or {}).items():
            c = _as_frac(c)
            if c != 0:
                t[mono] = t.get(mono, Fraction(0)) + c
        self.terms = {m: c for m, c in t.items() if c != 0}

    # -- constructors -------------------------------------------------
    @staticmethod
    def const(v) -> "Expr":
        return Expr({(): _as_frac(v)})

    @staticmethod
    def sym(name: str) -> "Expr":
        return Expr({((name, 1),): Fraction(1)})

    @staticmethod
    def wrap(v: "ExprLike") -> "Expr":
        if isinstance(v, Expr):
            return v
        if isinstance(v, str):
            return Expr.sym(v)
        return Expr.const(v)

    # -- algebra -------------------------------------------------------
    def __add__(self, other):
        other = Expr.wrap(other)
        t = dict(self.terms)
        for m, c in other.terms.items():
            t[m] = t.get(m, Fraction(0)) + c
        return Expr(t)

    __radd__ = __add__

    def __neg__(self):
        return Expr({m: -c for m, c in self.terms.items()})

    def __sub__(self, other):
        return self + (-Expr.wrap(other))

    def __rsub__(self, other):
        return Expr.wrap(other) - self

    def __mul__(self, other):
        other = Expr.wrap(other)
        t: dict = {}
        for m1, c1 in self.terms.items():
            for m2, c2 in other.terms.items():
                powers: dict = {}
                for n, p in m1 + m2:
                    powers[n] = powers.get(n, 0) + p
                mono = tuple(sorted(powers.items()))
                t[mono] = t.get(mono, Fraction(0)) + c1 * c2
        return Expr(t)

    __rmul__ = __mul__

    def __truediv__(self, other):
        other = Expr.wrap(other)
        if other.is_const():
            c = other.as_const()
            if c == 0:
                raise ZeroDivisionError
            return Expr({m: v / c for m, v in self.terms.items()})
        # symbolic divisor: divide every monomial (negative powers allowed —
        # rational monomials like K*M*N/P, paper Fig. 7)
        if len(other.terms) == 1:
            (dm, dc), = other.terms.items()
            t = {}
            for m, c in self.terms.items():
                powers = dict(m)
                for n, p in dm:
                    powers[n] = powers.get(n, 0) - p
                mono = tuple(sorted((n, p) for n, p in powers.items() if p != 0))
                t[mono] = t.get(mono, Fraction(0)) + c / dc
            return Expr(t)
        raise ValueError(f"cannot divide by {other}")

    def __floordiv__(self, other):
        return self / other

    # -- queries -------------------------------------------------------
    def is_const(self) -> bool:
        return all(m == () for m in self.terms)

    def as_const(self) -> Fraction:
        if not self.terms:
            return Fraction(0)
        if not self.is_const():
            raise ValueError(f"{self} is not constant")
        return self.terms[()]

    def as_int(self) -> int:
        c = self.as_const()
        if c.denominator != 1:
            raise ValueError(f"{self} is not an integer")
        return c.numerator

    @property
    def free_symbols(self) -> set:
        out = set()
        for m in self.terms:
            for n, _ in m:
                out.add(n)
        return out

    def subs(self, env: Mapping[str, "ExprLike"]) -> "Expr":
        out = Expr.const(0)
        for m, c in self.terms.items():
            term = Expr.const(c)
            for n, p in m:
                rep = Expr.wrap(env[n]) if n in env else Expr.sym(n)
                if p >= 0:
                    for _ in range(p):
                        term = term * rep
                else:
                    for _ in range(-p):
                        term = term / rep
            out = out + term
        return out

    def evaluate(self, env: Mapping[str, int]) -> int:
        v = self.subs(env)
        return v.as_int()

    # -- identity ------------------------------------------------------
    def _key(self):
        return tuple(sorted(self.terms.items()))

    def __eq__(self, other):
        if isinstance(other, (int, Fraction)):
            other = Expr.const(other)
        if not isinstance(other, Expr):
            return NotImplemented
        return self._key() == other._key()

    def __hash__(self):
        return hash(self._key())

    def __repr__(self):
        if not self.terms:
            return "0"
        parts = []
        for m, c in sorted(self.terms.items()):
            syms = "*".join(n if p == 1 else f"{n}**{p}" for n, p in m)
            if m == ():
                parts.append(str(c))
            elif c == 1:
                parts.append(syms)
            else:
                parts.append(f"{c}*{syms}")
        return " + ".join(parts)


ExprLike = Union[Expr, int, str, Fraction]


def sym(name: str) -> Expr:
    return Expr.sym(name)


def simplify(e: ExprLike) -> Expr:
    return Expr.wrap(e)


def evaluate(e: ExprLike, env: Mapping[str, int]) -> int:
    return Expr.wrap(e).evaluate(env)


def prod(xs: Iterable[ExprLike]) -> Expr:
    out = Expr.const(1)
    for x in xs:
        out = out * Expr.wrap(x)
    return out
