"""repro.core — the paper's primary contribution, adapted to TPU/JAX.

Data-centric IR (SDFG): states of pure dataflow, memlet-annotated edges,
maps for parametric parallelism, streams for pipeline composition, and
multi-level Library Nodes (paper §3) expanded toward platform-specialized
implementations (XLA-auto vs Pallas-explicit backends).
"""
from .dtypes import (DType, ScheduleType, StorageType, TPU_LANES, TPU_SUBLANES,
                     MXU_DIM, bfloat16, float32, float64, int32)
from .memlet import Memlet, Range, Subset
from .sdfg import (AccessNode, Array, Data, DataflowEdge, InterstateEdge,
                   LibraryNode, Map, MapEntry, MapExit, NestedSDFG, Node,
                   Scalar, SDFG, State, Stream, Tasklet)
from .symbolic import Expr, evaluate, prod, simplify, sym
from .validation import ValidationError, validate_sdfg

__all__ = [
    "DType", "ScheduleType", "StorageType", "TPU_LANES", "TPU_SUBLANES",
    "MXU_DIM", "bfloat16", "float32", "float64", "int32",
    "Memlet", "Range", "Subset",
    "AccessNode", "Array", "Data", "DataflowEdge", "InterstateEdge",
    "LibraryNode", "Map", "MapEntry", "MapExit", "NestedSDFG", "Node",
    "Scalar", "SDFG", "State", "Stream", "Tasklet",
    "Expr", "evaluate", "prod", "simplify", "sym",
    "ValidationError", "validate_sdfg",
]
