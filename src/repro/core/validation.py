"""SDFG validation (paper §2.5 / Fig. 7).

Enforces the constraints the paper relies on:

  * streams are bounded and single-producer / single-consumer (FPGA
    hardware constraint; on TPU it is what makes stream->VMEM-block fusion
    legal),
  * producer/consumer *volume* matching on streams -- the paper's Fig.-7
    check that the data volume pushed equals the volume popped (a mismatch
    means deadlock on FPGA, and an illegal fusion on TPU),
  * structural sanity: memlets name existing containers, map scopes are
    well formed, tasklet connectors match their edges.
"""
from __future__ import annotations

from typing import Dict

from .memlet import Memlet
from .sdfg import (AccessNode, LibraryNode, MapEntry, MapExit, NestedSDFG,
                   SDFG, State, Stream, Tasklet)
from .symbolic import Expr


class ValidationError(Exception):
    """Structural validation failure. ``code`` ties the failure into the
    typed diagnostic taxonomy (``analysis.diagnostics.CODES``); checks
    predating the taxonomy leave it None (reported as STRUCT000)."""

    def __init__(self, message: str, code: str = None):
        super().__init__(message)
        self.code = code


def validate_state(state: State, sdfg: SDFG):
    g = state.graph
    # structural checks -----------------------------------------------------
    for e in state.edges:
        if e.memlet.data is not None and e.memlet.data not in sdfg.arrays:
            raise ValidationError(
                f"{state.label}: memlet references unknown container "
                f"{e.memlet.data!r}")
    for node in state.nodes:
        if isinstance(node, Tasklet):
            # connector shadowing: a duplicate within either list makes
            # the tasklet namespace ambiguous — two edges feed one fn
            # kwarg / one output key names two edges (STRUCT002). The
            # same name appearing as both an input and an output is
            # legal: inputs are fn kwargs, outputs are result-dict keys,
            # two separate namespaces.
            dup_in = [c for c in set(node.inputs)
                      if node.inputs.count(c) > 1]
            dup_out = [c for c in set(node.outputs)
                       if node.outputs.count(c) > 1]
            if dup_in or dup_out:
                detail = []
                if dup_in:
                    detail.append(f"duplicate inputs {sorted(dup_in)}")
                if dup_out:
                    detail.append(f"duplicate outputs {sorted(dup_out)}")
                raise ValidationError(
                    f"{state.label}/{node.label}: connector shadowing — "
                    f"{'; '.join(detail)}", code="STRUCT002")
            in_conns = {e.dst_conn for e in state.in_edges(node) if e.dst_conn}
            out_conns = {e.src_conn for e in state.out_edges(node) if e.src_conn}
            missing_in = set(node.inputs) - in_conns
            missing_out = set(node.outputs) - out_conns
            if missing_in:
                raise ValidationError(
                    f"{state.label}/{node.label}: unconnected input "
                    f"connectors {sorted(missing_in)}")
            if missing_out:
                raise ValidationError(
                    f"{state.label}/{node.label}: unconnected output "
                    f"connectors {sorted(missing_out)}")
        if isinstance(node, MapEntry):
            exits = [n for n in state.nodes
                     if isinstance(n, MapExit) and n.entry is node]
            if len(exits) != 1:
                raise ValidationError(
                    f"{state.label}/{node.label}: map entry must have exactly "
                    f"one exit (found {len(exits)})")

    # stream constraints ------------------------------------------------------
    producers: Dict[str, int] = {}
    consumers: Dict[str, int] = {}
    pushed: Dict[str, Expr] = {}
    popped: Dict[str, Expr] = {}
    for node in state.nodes:
        if not isinstance(node, AccessNode):
            continue
        desc = sdfg.arrays[node.data]
        if not isinstance(desc, Stream):
            continue
        if desc.buffer_size <= 0:
            raise ValidationError(
                f"stream {node.data!r} must be bounded (buffer_size > 0)")
        for e in state.in_edges(node):
            producers[node.data] = producers.get(node.data, 0) + 1
            vol = e.memlet.volume_or_subset()
            if vol is not None:
                pushed[node.data] = pushed.get(node.data, Expr.const(0)) + vol
        for e in state.out_edges(node):
            consumers[node.data] = consumers.get(node.data, 0) + 1
            vol = e.memlet.volume_or_subset()
            if vol is not None:
                popped[node.data] = popped.get(node.data, Expr.const(0)) + vol

    for name in set(producers) | set(consumers):
        desc = sdfg.arrays[name]
        # arrays-of-streams (systolic pipes) may have one producer/consumer
        # per array index; allow up to the array size.
        limit = 1
        if desc.shape:
            try:
                limit = desc.num_elements.evaluate(sdfg.symbol_values)
            except Exception:
                limit = None  # symbolic pipe count: skip cardinality check
        if limit is not None and producers.get(name, 0) > limit:
            raise ValidationError(
                f"stream {name!r}: {producers[name]} producers "
                f"(single-producer constraint, limit {limit})")
        if limit is not None and consumers.get(name, 0) > limit:
            raise ValidationError(
                f"stream {name!r}: {consumers[name]} consumers "
                f"(single-consumer constraint, limit {limit})")

    # producer/consumer volume check (Fig. 7) -----------------------------
    for name in set(pushed) & set(popped):
        desc = sdfg.arrays[name]
        if desc.shape:
            # arrays-of-streams (systolic pipes): the Fig.-7 annotation is
            # per pipe index; graph-level totals intentionally differ.
            continue
        if pushed[name] != popped[name]:
            # exact symbolic equality required; mismatch => deadlock/illegal fusion
            raise ValidationError(
                f"stream {name!r}: produced volume {pushed[name]} != "
                f"consumed volume {popped[name]} (Fig.-7 check)")


def validate_sdfg(sdfg: SDFG):
    # container names and symbol names share the argument/closure
    # namespace at codegen time — a collision silently shadows one with
    # the other (STRUCT001). (The historical duplicate-container check
    # iterated dict keys, which cannot repeat, so it never fired.)
    collisions = sorted(set(sdfg.arrays) & set(sdfg.symbol_values))
    if collisions:
        raise ValidationError(
            f"container name(s) {collisions} collide with symbol names",
            code="STRUCT001")
    for st in sdfg.states:
        validate_state(st, sdfg)
        for node in st.nodes:
            if isinstance(node, NestedSDFG):
                validate_sdfg(node.sdfg)
