"""Elastic multi-host training over a ShardMapPass-partitioned step.

The training step here is not hand-sharded: it is an ordinary
data-parallel SDFG — a map over the batch dimension whose tasklet
computes one example's loss gradient, accumulated with wcr("add") —
and ``ShardMapPass`` (transforms/shard_map.py) partitions it across the
host mesh entirely from memlet analysis: ``tokens`` indexes the mapped
dim exactly (shard-local), the weights are whole-read (replicated), and
the wcr gradient accumulators reduce over the partitioned dim
(collective -> ``lax.psum``). No ``shard_declared`` hints needed.

Elasticity: the shard count is a pass option and the mesh signature is
part of the pipeline signature, so a restart on fewer hosts is a
compilation-cache miss that recompiles the step for the smaller mesh.
Checkpoints are written with :func:`repro.checkpoint.save_sharded`
(per-host shard files + mesh signature in the manifest); restore
reassembles the global arrays, so restoring onto any mesh size just
works — ``run_elastic_training`` wires this into
:class:`~repro.runtime.cluster_sim.SimulatedCluster` so a simulated
host death restores the latest sharded checkpoint onto the shrunken
mesh and continues with the recompiled step.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp

from .. import checkpoint as ckpt_lib
from ..configs.base import ModelConfig
from ..core.memlet import Memlet, Range, Subset
from ..core.sdfg import SDFG
from ..core.symbolic import sym
from ..data import DataConfig, make_global_batch
from ..models.registry import build_model
from ..optim import clip_by_global_norm, get_optimizer
from ..pipeline import lower
from ..pipeline.cache import COMPILATION_CACHE
from ..pipeline.passes import default_pipeline


def _stored_shape(shape) -> tuple:
    """0-d leaves ride in (1,) containers (SDFG arrays are >= 1-D)."""
    return tuple(int(d) for d in shape) if len(shape) else (1,)


def data_parallel_grad_sdfg(model, a_params, B: int, seq_len: int) -> SDFG:
    """The data-parallel gradient SDFG: ``loss``/``g{i}`` = mean over the
    batch of per-example loss/grads, built as a wcr("add") map over the
    batch dim so ShardMapPass can partition it by analysis alone."""
    leaves, treedef = jax.tree_util.tree_flatten(a_params)
    n = len(leaves)
    shapes = [tuple(int(d) for d in leaf.shape) for leaf in leaves]
    inv_b = float(1.0 / B)

    s = SDFG(f"dp_grad_b{B}_s{seq_len}")
    s.add_array("tokens", (B, seq_len), "int32")
    for i, leaf in enumerate(leaves):
        s.add_array(f"w{i}", _stored_shape(leaf.shape), str(leaf.dtype))
        s.add_array(f"g{i}", _stored_shape(leaf.shape), str(leaf.dtype))
    s.add_array("loss", (1,), "float32")

    def body(tok, **w):
        vals = [w[f"w{i}"].reshape(shapes[i]) for i in range(n)]
        params = jax.tree_util.tree_unflatten(treedef, vals)
        loss, grads = jax.value_and_grad(model.loss)(
            params, {"tokens": tok[None]})
        gl = jax.tree_util.tree_leaves(grads)
        out = {f"g{i}": (gl[i] * inv_b).reshape(_stored_shape(shapes[i]))
               .astype(leaves[i].dtype) for i in range(n)}
        out["loss_o"] = (loss * inv_b).reshape(1).astype(jnp.float32)
        return out

    st = s.add_state("main", is_start=True)
    ins = {"tok": Memlet.simple("tokens", Subset([
        Range.index(sym("b")), Range.make(0, seq_len)]))}
    ins.update({f"w{i}": Memlet.simple(f"w{i}") for i in range(n)})
    outs = {f"g{i}": Memlet.simple(f"g{i}", wcr="add") for i in range(n)}
    outs["loss_o"] = Memlet.simple("loss", wcr="add")
    st.add_mapped_tasklet("dp_grad", {"b": (0, B)}, inputs=ins,
                          outputs=outs, fn=body)
    return s


@dataclasses.dataclass
class ElasticTrainerConfig:
    steps: int = 8
    checkpoint_every: int = 2
    ckpt_dir: Optional[str] = None
    clip_norm: float = 1.0


class ElasticTrainer:
    """Data-parallel trainer whose step is a sharded compiled SDFG.

    ``n_shards > 1`` requires that many visible devices (set
    ``XLA_FLAGS=--xla_force_host_platform_device_count=N`` before
    importing jax to simulate hosts on CPU) and a divisible global
    batch. The compiled step's cache key includes the shard count and
    mesh signature, so two trainers over the same mesh share one
    compile and a shrink never reuses a stale step.
    """

    def __init__(self, cfg: ModelConfig, n_shards: int = 1,
                 tcfg: Optional[ElasticTrainerConfig] = None,
                 seq_len: int = 32, global_batch: int = 8,
                 shard_axis: str = "shard", cache=None):
        if n_shards > 1 and global_batch % n_shards:
            raise ValueError(f"global_batch {global_batch} not divisible "
                             f"by n_shards {n_shards}")
        self.cfg = cfg
        self.n_shards = int(n_shards)
        self.shard_axis = shard_axis
        self.tcfg = tcfg or ElasticTrainerConfig()
        self.seq_len = seq_len
        self.global_batch = global_batch
        self.model = build_model(cfg)
        self.opt = get_optimizer(cfg.optimizer)
        self.data_cfg = DataConfig(vocab=cfg.vocab, seq_len=seq_len,
                                   global_batch=global_batch)
        self.cache = COMPILATION_CACHE if cache is None else cache
        self.mesh_sig = None
        if self.n_shards > 1:
            from ..codegen.shard import make_shard_mesh
            from ..launch.steps import mesh_signature
            self.mesh_sig = repr(mesh_signature(
                make_shard_mesh(self.n_shards, shard_axis)))
        self._a_params = jax.eval_shape(lambda k: self.model.init(k),
                                        jax.random.PRNGKey(0))
        self._leaves, self._treedef = jax.tree_util.tree_flatten(
            self._a_params)
        self._compiled = None

    # -- compiled step ----------------------------------------------------
    def compiled_step(self):
        if self._compiled is None:
            sdfg = data_parallel_grad_sdfg(
                self.model, self._a_params, self.global_batch, self.seq_len)
            self._compiled = lower(sdfg).compile(
                backend="jnp", cache=self.cache,
                pipeline=default_pipeline(
                    "jnp", n_shards=self.n_shards,
                    shard_axis=self.shard_axis, mesh_sig=self.mesh_sig))
        return self._compiled

    @property
    def report(self) -> Optional[dict]:
        return self._compiled.report if self._compiled else None

    # -- state ------------------------------------------------------------
    def init_state(self, seed: int = 0) -> Dict:
        params = self.model.init(jax.random.PRNGKey(seed))
        return {"params": params, "opt": self.opt.init(params),
                "step": jnp.zeros((), jnp.int32)}

    def restore_or_init(self, seed: int = 0) -> Dict:
        if self.tcfg.ckpt_dir:
            last = ckpt_lib.latest_step(self.tcfg.ckpt_dir)
            if last is not None:
                a_state = {"params": self._a_params,
                           "opt": jax.eval_shape(self.opt.init,
                                                 self._a_params),
                           "step": jax.ShapeDtypeStruct((), jnp.int32)}
                return ckpt_lib.restore(self.tcfg.ckpt_dir, last, a_state)
        return self.init_state(seed)

    def save(self, step: int, state: Dict):
        if self.tcfg.ckpt_dir:
            ckpt_lib.save_sharded(self.tcfg.ckpt_dir, step, state,
                                  mesh_sig=self.mesh_sig)

    # -- stepping ---------------------------------------------------------
    def train_step(self, state: Dict, tokens) -> tuple:
        fn = self.compiled_step()
        kw = {"tokens": jnp.asarray(tokens, jnp.int32)}
        for i, leaf in enumerate(jax.tree_util.tree_leaves(
                state["params"])):
            kw[f"w{i}"] = jnp.asarray(leaf).reshape(
                _stored_shape(self._leaves[i].shape))
        out = fn(**kw)
        gl = [out[f"g{i}"].reshape(self._leaves[i].shape)
              for i in range(len(self._leaves))]
        grads = jax.tree_util.tree_unflatten(self._treedef, gl)
        grads, gnorm = clip_by_global_norm(grads, self.tcfg.clip_norm)
        new_params, new_opt = self.opt.update(
            grads, state["opt"], state["params"], state["step"])
        new_state = {"params": new_params, "opt": new_opt,
                     "step": state["step"] + 1}
        return new_state, {"loss": float(out["loss"][0]),
                           "grad_norm": float(gnorm)}

    def run_step(self, state: Dict, step: int) -> tuple:
        batch = make_global_batch(self.data_cfg, step, self.cfg)
        return self.train_step(state, batch["tokens"])

    def run(self) -> Dict:
        state = self.restore_or_init()
        log: List[dict] = []
        for step in range(int(state["step"]), self.tcfg.steps):
            state, metrics = self.run_step(state, step)
            log.append({"step": step, **metrics})
            if (step + 1) % self.tcfg.checkpoint_every == 0:
                self.save(step + 1, state)
        return {"state": state, "log": log}


def usable_shards(global_batch: int, n_hosts: int) -> int:
    """Largest shard count <= n_hosts dividing the global batch."""
    for k in range(max(1, n_hosts), 0, -1):
        if global_batch % k == 0:
            return k
    return 1


def run_elastic_training(cfg: ModelConfig, n_hosts: int, n_steps: int,
                         ckpt_dir: str, plan=None, seq_len: int = 16,
                         global_batch: int = 8, seed: int = 0,
                         checkpoint_every: int = 2, cache=None) -> Dict:
    """Drive the REAL sharded compiled step through SimulatedCluster.

    A simulated host death restores the latest sharded checkpoint onto
    the shrunken mesh — a new trainer with fewer shards, whose step is
    a compilation-cache miss recompile — and training continues. The
    returned ``losses`` maps step -> the last loss computed at that
    step, so callers can assert loss-curve-identical continuation
    against an uninterrupted run.
    """
    from .cluster_sim import SimulatedCluster

    box = {"trainer": None, "state": None, "hosts": n_hosts}
    losses: Dict[int, float] = {}
    reshard_log: List[dict] = []

    def make_trainer():
        k = usable_shards(global_batch, box["hosts"])
        t = ElasticTrainer(
            cfg, n_shards=k,
            tcfg=ElasticTrainerConfig(steps=n_steps,
                                      checkpoint_every=checkpoint_every,
                                      ckpt_dir=ckpt_dir),
            seq_len=seq_len, global_batch=global_batch, cache=cache)
        reshard_log.append({"n_hosts": box["hosts"], "n_shards": k,
                            "mesh_sig": t.mesh_sig})
        return t

    box["trainer"] = make_trainer()
    box["state"] = box["trainer"].restore_or_init(seed)

    def do_step(step):
        box["state"], metrics = box["trainer"].run_step(box["state"], step)
        losses[step] = metrics["loss"]

    def save_ckpt(step):
        box["trainer"].save(step, box["state"])

    def restore_ckpt():
        # the sim has detected the death; rebuild on the surviving hosts
        box["hosts"] -= 1
        box["trainer"] = make_trainer()
        box["state"] = box["trainer"].restore_or_init(seed)
        return int(box["state"]["step"])

    sim = SimulatedCluster(n_hosts, plan=plan)
    result = sim.run(n_steps, do_step, save_ckpt, restore_ckpt,
                     checkpoint_every=checkpoint_every)
    return {"losses": losses, "sim": result, "reshards": reshard_log,
            "final_state": box["state"], "trainer": box["trainer"]}
