from .cluster_sim import FaultPlan, SimulatedCluster
from .elastic import (ElasticTrainer, ElasticTrainerConfig,
                      data_parallel_grad_sdfg, run_elastic_training,
                      usable_shards)
from .trainer import HeartbeatMonitor, Trainer, TrainerConfig

__all__ = ["FaultPlan", "SimulatedCluster", "HeartbeatMonitor", "Trainer",
           "TrainerConfig", "ElasticTrainer", "ElasticTrainerConfig",
           "data_parallel_grad_sdfg", "run_elastic_training",
           "usable_shards"]
