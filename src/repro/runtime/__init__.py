from .cluster_sim import FaultPlan, SimulatedCluster
from .trainer import HeartbeatMonitor, Trainer, TrainerConfig

__all__ = ["FaultPlan", "SimulatedCluster", "HeartbeatMonitor", "Trainer",
           "TrainerConfig"]
