"""Simulated cluster for fault-tolerance tests.

A SimulatedCluster drives N logical hosts through training steps, injecting
failures (host death at step k) and stragglers (slow host with factor f).
It validates the control-plane behavior the real deployment relies on:
detection -> checkpoint restore -> (optionally) elastic mesh shrink ->
bit-exact continuation thanks to the counter-based data pipeline.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional

import numpy as np

from .trainer import HeartbeatMonitor


@dataclasses.dataclass
class FaultPlan:
    die_at_step: Optional[int] = None
    die_host: int = 0
    straggle_host: Optional[int] = None
    straggle_factor: float = 3.0


class SimulatedCluster:
    def __init__(self, n_hosts: int, step_time_s: float = 0.01,
                 plan: FaultPlan = None,
                 deadline_s: float = 1.0, straggler_factor: float = 2.0):
        self.n_hosts = n_hosts
        self.step_time_s = step_time_s
        # run() clears die_at_step once the fault fires; copy so reusing
        # one plan across clusters does not silently drop the fault
        self.plan = dataclasses.replace(plan) if plan else FaultPlan()
        self.monitor = HeartbeatMonitor(deadline_s, straggler_factor)
        self.restarts: List[Dict] = []
        self.step_log: List[Dict] = []

    def host_step_duration(self, host: int, step: int) -> float:
        if (self.plan.die_at_step is not None
                and step >= self.plan.die_at_step
                and host == self.plan.die_host):
            return float("inf")  # never heartbeats
        base = self.step_time_s
        if self.plan.straggle_host == host:
            base *= self.plan.straggle_factor
        return base * (1.0 + 0.01 * ((host * 2654435761 + step) % 7))

    def run(self, n_steps: int, do_step: Callable[[int], None],
            save_ckpt: Callable[[int], None],
            restore_ckpt: Callable[[], int],
            checkpoint_every: int = 5) -> Dict:
        """do_step(step) performs real training work; the simulation layers
        cluster behavior around it."""
        step = 0
        alive = set(range(self.n_hosts))
        done = set()  # step indices already executed once
        wasted = 0    # replayed (post-restore) step executions
        while step < n_steps:
            durations = {h: self.host_step_duration(h, step) for h in alive}
            slowest = max(durations.values())
            if slowest == float("inf"):
                # failure detected via missed heartbeat -> restart cycle
                dead = [h for h, d in durations.items() if d == float("inf")]
                for h in dead:
                    self.monitor.record(h, durations[h])
                restart_from = restore_ckpt()
                self.restarts.append({"step": step, "dead_hosts": dead,
                                      "resumed_from": restart_from,
                                      "new_n_hosts": self.n_hosts - len(dead)})
                alive -= set(dead)  # elastic: continue on fewer hosts
                self.plan.die_at_step = None
                step = restart_from
                continue
            for h, d in durations.items():
                self.monitor.record(h, d)
            if step in done:
                wasted += 1  # work between the checkpoint and the failure
            done.add(step)
            do_step(step)
            self.step_log.append({"step": step, "t": slowest})
            step += 1
            if step % checkpoint_every == 0:
                save_ckpt(step)
        return {"restarts": self.restarts,
                "straggler_events": [e for e in self.monitor.events
                                     if e[0] == "straggler"],
                "steps_run": len(self.step_log),
                "wasted_steps": wasted,
                "host_status": dict(self.monitor.host_status)}
