"""Fault-tolerant training runtime.

On a real pod this process runs per host under a cluster scheduler; here
the same control loop runs single-process with the production mesh logic,
and the fault-tolerance machinery (heartbeats, straggler detection,
checkpoint/restart, elastic resharding) is exercised through a simulated
cluster in tests. Design points for 1000+ nodes:

  * checkpoint/restart: atomic step-directory checkpoints (checkpoint/),
    deterministic counter-based data (data/) so restarts replay exactly;
  * failure detection: per-step heartbeat deadline; a missing heartbeat
    triggers restore-from-latest + (optionally) a smaller mesh (elastic);
  * straggler mitigation: per-step duration EWMA; hosts slower than
    ``straggler_factor`` x median are reported for replacement — with
    synchronous SPMD the collective itself is the barrier, so mitigation
    is replace-or-shrink, not async;
  * gradient compression: optional int8 quantization of the DP all-reduce
    (runtime/compression.py) for interconnect-constrained clusters.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Dict, Optional

import jax
import numpy as np

from .. import checkpoint as ckpt_lib
from ..configs.base import ModelConfig
from ..data import DataConfig, make_global_batch
from ..launch import sharding as shd
from ..launch.steps import make_train_step, mesh_signature
from ..models.registry import build_model
from ..pipeline.cache import COMPILATION_CACHE


@dataclasses.dataclass
class TrainerConfig:
    steps: int = 20
    checkpoint_every: int = 10
    ckpt_dir: Optional[str] = None
    heartbeat_deadline_s: float = 300.0
    straggler_factor: float = 2.0
    log_every: int = 1


class HeartbeatMonitor:
    """Tracks per-step durations; flags stragglers and missed deadlines."""

    def __init__(self, deadline_s: float, straggler_factor: float):
        self.deadline_s = deadline_s
        self.straggler_factor = straggler_factor
        self.durations = []
        self.events = []
        self.host_status = {}  # host -> last status ("ok|straggler|dead")

    def record(self, host: int, duration: float):
        self.durations.append(duration)
        if duration > self.deadline_s:
            self.events.append(("dead", host, duration))
            self.host_status[host] = "dead"
            return "dead"
        # dead hosts record inf/NaN durations; those must not enter the
        # straggler median or one death inflates the threshold forever
        finite = [d for d in self.durations[-32:] if np.isfinite(d)]
        med = float(np.median(finite)) if finite else duration
        if len(finite) >= 4 and duration > self.straggler_factor * med:
            self.events.append(("straggler", host, duration))
            self.host_status[host] = "straggler"
            return "straggler"
        self.host_status[host] = "ok"
        return "ok"


class Trainer:
    def __init__(self, cfg: ModelConfig, mesh, tcfg: TrainerConfig = None,
                 seq_len: int = 512, global_batch: int = 8):
        self.cfg = cfg
        self.mesh = mesh
        self.tcfg = tcfg or TrainerConfig()
        self.seq_len = seq_len
        self.global_batch = global_batch
        (self.step_fn, self.state_shardings, self.a_state, self.model,
         self.opt) = make_train_step(cfg, mesh, remat=False)
        self.data_cfg = DataConfig(vocab=cfg.vocab, seq_len=seq_len,
                                   global_batch=global_batch)
        b_specs = {"tokens": None}
        self.monitor = HeartbeatMonitor(self.tcfg.heartbeat_deadline_s,
                                        self.tcfg.straggler_factor)
        # staged-pipeline cache: trainers over the same (config x mesh)
        # cell share one jitted train step (and its XLA trace) — a
        # checkpoint/restart or elastic-reshard restart recompiles nothing
        # that an identical predecessor already compiled.
        key = ("trainer_step", repr(cfg), mesh_signature(mesh), False)
        self._jitted = COMPILATION_CACHE.get_or_build(
            key, lambda: jax.jit(self.step_fn, donate_argnums=(0,)))

    # -- state ------------------------------------------------------------
    def init_state(self, seed: int = 0) -> Dict:
        params = self.model.init(jax.random.PRNGKey(seed))
        opt_state = self.opt.init(params)
        import jax.numpy as jnp
        return {"params": params, "opt": opt_state,
                "step": jnp.zeros((), jnp.int32)}

    def restore_or_init(self) -> Dict:
        if self.tcfg.ckpt_dir:
            last = ckpt_lib.latest_step(self.tcfg.ckpt_dir)
            if last is not None:
                state = ckpt_lib.restore(self.tcfg.ckpt_dir, last,
                                         self.a_state, self.state_shardings)
                return state
        return self.init_state()

    # -- loop ---------------------------------------------------------------
    def run(self, on_step: Callable = None) -> Dict:
        state = self.restore_or_init()
        start = int(state["step"])
        metrics_log = []
        for step in range(start, self.tcfg.steps):
            t0 = time.time()
            batch_np = make_global_batch(self.data_cfg, step, self.cfg)
            batch = {k: jax.numpy.asarray(v) for k, v in batch_np.items()}
            state, metrics = self._jitted(state, batch)
            metrics = jax.device_get(metrics)
            dt = time.time() - t0
            self.monitor.record(0, dt)
            metrics_log.append({"step": step, "loss": float(metrics["loss"]),
                                "s": dt})
            if on_step:
                on_step(step, metrics)
            if (self.tcfg.ckpt_dir
                    and (step + 1) % self.tcfg.checkpoint_every == 0):
                ckpt_lib.save(self.tcfg.ckpt_dir, step + 1, state)
        return {"state": state, "log": metrics_log,
                "events": self.monitor.events}
