"""Gradient compression for the data-parallel all-reduce.

int8 block-quantized all-reduce via shard_map over the DP axes: each DP
rank quantizes its local gradient shard (per-block absmax scales),
all-reduces the int8 payload as int32 partial sums plus fp32 scales, and
dequantizes — an 8x interconnect-volume reduction with unbiased stochastic
rounding. Opt-in (``grad_compression='int8'``) for interconnect-bound
clusters; the dry-run's collective term quantifies the win.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

BLOCK = 256


def quantize_int8(x, key) -> Tuple[jnp.ndarray, jnp.ndarray]:
    flat = x.reshape(-1)
    pad = (-flat.shape[0]) % BLOCK
    flat = jnp.pad(flat, (0, pad))
    blocks = flat.reshape(-1, BLOCK).astype(jnp.float32)
    scale = jnp.max(jnp.abs(blocks), axis=1, keepdims=True) / 127.0 + 1e-12
    scaled = blocks / scale
    # unbiased stochastic rounding
    noise = jax.random.uniform(key, scaled.shape) - 0.5
    q = jnp.clip(jnp.round(scaled + noise), -127, 127).astype(jnp.int8)
    return q, scale[:, 0]


def dequantize_int8(q, scale, shape) -> jnp.ndarray:
    blocks = q.astype(jnp.float32) * scale[:, None]
    flat = blocks.reshape(-1)
    n = int(np.prod(shape))
    return flat[:n].reshape(shape)


def compressed_psum(grads, axis_names, key):
    """int8-compressed psum over ``axis_names`` (inside shard_map)."""
    leaves, treedef = jax.tree.flatten(grads)
    out = []
    for i, g in enumerate(leaves):
        k = jax.random.fold_in(key, i)
        q, scale = quantize_int8(g, k)
        # int8 payload summed as int32 (prevents overflow across ranks),
        # scales summed to reconstruct the mean of per-rank dequants
        qsum = jax.lax.psum(q.astype(jnp.int32), axis_names)
        ssum = jax.lax.psum(scale, axis_names)
        n_ranks = jax.lax.psum(jnp.ones((), jnp.float32), axis_names)
        # average dequantization error stays unbiased: use mean scale
        deq = dequantize_int8(qsum.astype(jnp.float32) / n_ranks,
                              ssum / n_ranks, g.shape)
        out.append(deq.astype(g.dtype))
    return jax.tree.unflatten(treedef, out)
