"""Stencil Library Node (paper §6, StencilFlow).

One abstract node per stencil operator; expansions:

  * ``xla``    -- padded-shift jnp composite (XLA auto-fuses; the 'Intel
                  shift register' analogue where the compiler manages
                  buffering),
  * ``pallas`` -- the explicit sliding-window VMEM kernel (the 'Xilinx
                  explicit buffers' analogue, §6.2).

Chains of Stencil nodes composed through streams fuse into a single
multi-stage Pallas kernel (registered below) — StencilFlow's fully
pipelined multi-stencil architecture with delay buffers as VMEM halos.
"""
from __future__ import annotations

from typing import Sequence, Tuple

import jax.numpy as jnp

from ..codegen.pipeline_fusion import FUSION_REGISTRY
from ..core.sdfg import LibraryNode, SDFG, State
from .util import replace_with_tasklet


class Stencil(LibraryNode):
    """2D stencil with static offsets and runtime scalar coefficients."""
    default_expansion = "xla"

    def __init__(self, name: str, offsets: Sequence[Tuple[int, int]],
                 coeff_names: Sequence[str]):
        super().__init__(name, inputs=["a", "c"], outputs=["b"])
        self.offsets = tuple(tuple(o) for o in offsets)
        self.coeff_names = list(coeff_names)

    @property
    def radius(self) -> int:
        return max(max(abs(di), abs(dj)) for di, dj in self.offsets)


def _stencil_xla(node: Stencil, sdfg: SDFG, state: State):
    offsets = node.offsets

    def fn(a, c):
        from ..kernels.stencil import stencil2d_ref
        return stencil2d_ref(a, [c[k] for k in range(len(offsets))], offsets)

    replace_with_tasklet(node, sdfg, state, fn, "xla")


def _stencil_pallas(node: Stencil, sdfg: SDFG, state: State):
    offsets = node.offsets
    interpret = sdfg.metadata.get("pallas_interpret", True)

    def fn(a, c):
        from ..kernels.stencil import stencil2d
        return stencil2d(a, c, offsets, interpret=interpret)

    replace_with_tasklet(node, sdfg, state, fn, "pallas")


Stencil.expansions = {"xla": _stencil_xla, "generic": _stencil_xla,
                      "pallas": _stencil_pallas}


def _fuse_stencil_chain(chain, sdfg, state, interpret, in_map, out_map):
    """N consecutive stencils -> one fused multi-stage kernel."""
    offsets_per_stage = tuple(n.offsets for n in chain)
    a_c = in_map[(chain[0].label, "a")]
    c_cs = [in_map[(n.label, "c")] for n in chain]
    out_c = out_map[(chain[-1].label, "b")]

    def fn(**kw):
        from ..kernels.stencil import stencil2d_chain
        coeffs = [kw[c] for c in c_cs]
        return {out_c: stencil2d_chain(kw[a_c], coeffs, offsets_per_stage,
                                       interpret=interpret)}

    return fn


# register chains of length 2..6
for _k in range(2, 7):
    FUSION_REGISTRY[tuple(["Stencil"] * _k)] = _fuse_stencil_chain
