"""Neural-network Library Nodes (paper §5, DaCeML/ONNX analogue).

Operators used by the LeNet-5 case study, each with multi-level expansions:
``xla`` composites, and for the compute hot-spots (Conv2d, Linear) a
``pallas`` expansion lowering to the im2col + systolic-GEMM kernel — the
paper's §5.2 'convolutions are implemented using the im2col approach,
relying heavily on the systolic matrix multiplication of §2.6'.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ..codegen.pipeline_fusion import register_fusion
from ..core.sdfg import LibraryNode, SDFG, State
from .util import replace_with_tasklet


# ---------------------------------------------------------------------------
def _im2col(x, R, S):
    """x: (N, C, H, W) -> patches (N*OH*OW, C*R*S) for VALID conv."""
    N, C, H, W = x.shape
    OH, OW = H - R + 1, W - S + 1
    idx_h = jnp.arange(OH)[:, None] + jnp.arange(R)[None, :]
    idx_w = jnp.arange(OW)[:, None] + jnp.arange(S)[None, :]
    # (N, C, OH, R, W)
    g = x[:, :, idx_h, :]
    # (N, C, OH, R, OW, S)
    g = g[:, :, :, :, idx_w]
    # -> (N, OH, OW, C, R, S)
    g = g.transpose(0, 2, 4, 1, 3, 5)
    return g.reshape(N * OH * OW, C * R * S), (N, OH, OW)


class Conv2d(LibraryNode):
    """VALID 2D convolution, NCHW, weights (K, C, R, S) + bias (K,)."""
    default_expansion = "xla"

    def __init__(self, name="conv", activation: str = None):
        super().__init__(name, inputs=["x", "W", "b"], outputs=["y"])
        self.activation = activation


def _conv_xla(node: Conv2d, sdfg: SDFG, state: State):
    act = node.activation

    def fn(x, W, b):
        y = jax.lax.conv_general_dilated(
            x.astype(jnp.float32), W.astype(jnp.float32),
            window_strides=(1, 1), padding="VALID",
            dimension_numbers=("NCHW", "OIHW", "NCHW"))
        y = y + b.astype(jnp.float32)[None, :, None, None]
        if act == "relu":
            y = jnp.maximum(y, 0.0)
        return y.astype(x.dtype)

    replace_with_tasklet(node, sdfg, state, fn, "xla")


def _conv_pallas(node: Conv2d, sdfg: SDFG, state: State):
    """im2col + systolic GEMM with fused bias(+activation) epilogue."""
    act = node.activation
    interpret = sdfg.metadata.get("pallas_interpret", True)

    def fn(x, W, b):
        from ..kernels.gemm import matmul
        K, C, R, S = W.shape
        cols, (N, OH, OW) = _im2col(x, R, S)
        w2 = W.reshape(K, C * R * S).T
        y = matmul(cols, w2, b, activation=act, interpret=interpret)
        return y.reshape(N, OH, OW, K).transpose(0, 3, 1, 2)

    replace_with_tasklet(node, sdfg, state, fn, "pallas")


Conv2d.expansions = {"xla": _conv_xla, "generic": _conv_xla,
                     "pallas": _conv_pallas}


# ---------------------------------------------------------------------------
class Relu(LibraryNode):
    default_expansion = "xla"

    def __init__(self, name="relu"):
        super().__init__(name, inputs=["x"], outputs=["y"])


def _relu_xla(node: Relu, sdfg: SDFG, state: State):
    replace_with_tasklet(node, sdfg, state,
                         lambda x: jnp.maximum(x, 0), "xla")


Relu.expansions = {"xla": _relu_xla, "generic": _relu_xla,
                   "pallas": _relu_xla}


# ---------------------------------------------------------------------------
class MaxPool2d(LibraryNode):
    """Window=stride pooling via sliding window (paper §5.2: implemented
    with shift registers on Intel; reduce_window on TPU)."""
    default_expansion = "xla"

    def __init__(self, name="maxpool", window: int = 2):
        super().__init__(name, inputs=["x"], outputs=["y"])
        self.window = window

    def out_shape(self, in_shape):
        n, c, h, w = in_shape
        return (n, c, h // self.window, w // self.window)


def _maxpool_xla(node: MaxPool2d, sdfg: SDFG, state: State):
    wdw = node.window

    def fn(x):
        return jax.lax.reduce_window(
            x, -jnp.inf if x.dtype.kind == "f" else x.dtype.type(-2**31),
            jax.lax.max, (1, 1, wdw, wdw), (1, 1, wdw, wdw), "VALID")

    replace_with_tasklet(node, sdfg, state, fn, "xla")


MaxPool2d.expansions = {"xla": _maxpool_xla, "generic": _maxpool_xla,
                        "pallas": _maxpool_xla}


# ---------------------------------------------------------------------------
class Linear(LibraryNode):
    """y = act(x @ W^T + b); W: (out, in)."""
    default_expansion = "xla"

    def __init__(self, name="linear", activation: str = None):
        super().__init__(name, inputs=["x", "W", "b"], outputs=["y"])
        self.activation = activation


def _linear_xla(node: Linear, sdfg: SDFG, state: State):
    act = node.activation

    def fn(x, W, b):
        y = x.astype(jnp.float32) @ W.astype(jnp.float32).T \
            + b.astype(jnp.float32)
        if act == "relu":
            y = jnp.maximum(y, 0.0)
        return y.astype(x.dtype)

    replace_with_tasklet(node, sdfg, state, fn, "xla")


def _linear_pallas(node: Linear, sdfg: SDFG, state: State):
    act = node.activation
    interpret = sdfg.metadata.get("pallas_interpret", True)

    def fn(x, W, b):
        from ..kernels.gemm import matmul
        return matmul(x, W.T, b, activation=act, interpret=interpret)

    replace_with_tasklet(node, sdfg, state, fn, "pallas")


Linear.expansions = {"xla": _linear_xla, "generic": _linear_xla,
                     "pallas": _linear_pallas}


# ---------------------------------------------------------------------------
class Softmax(LibraryNode):
    default_expansion = "xla"

    def __init__(self, name="softmax", axis: int = -1):
        super().__init__(name, inputs=["x"], outputs=["y"])
        self.axis = axis


def _softmax_xla(node: Softmax, sdfg: SDFG, state: State):
    axis = node.axis
    replace_with_tasklet(node, sdfg, state,
                         lambda x: jax.nn.softmax(x, axis=axis), "xla")


Softmax.expansions = {"xla": _softmax_xla, "generic": _softmax_xla,
                      "pallas": _softmax_xla}


# ---------------------------------------------------------------------------
class Flatten(LibraryNode):
    default_expansion = "xla"

    def __init__(self, name="flatten"):
        super().__init__(name, inputs=["x"], outputs=["y"])


def _flatten_xla(node: Flatten, sdfg: SDFG, state: State):
    replace_with_tasklet(node, sdfg, state,
                         lambda x: x.reshape(x.shape[0], -1), "xla")


Flatten.expansions = {"xla": _flatten_xla, "generic": _flatten_xla,
                      "pallas": _flatten_xla}


# ---------------------------------------------------------------------------
# Fused pipelines (paper Fig. 16: streaming between Conv/ReLU/MaxPool).
# Conv2d carries its own activation; a streamed Conv2d->MaxPool2d chain
# fuses into im2col-GEMM + pooling without materializing the conv output.
# ---------------------------------------------------------------------------
@register_fusion(("Conv2d", "MaxPool2d"))
def _fuse_conv_pool(chain, sdfg, state, interpret, in_map, out_map):
    conv_n, pool_n = chain
    act = conv_n.activation
    wdw = pool_n.window
    x_c = in_map[(conv_n.label, "x")]
    W_c = in_map[(conv_n.label, "W")]
    b_c = in_map[(conv_n.label, "b")]
    y_c = out_map[(pool_n.label, "y")]

    def fn(**kw):
        from ..kernels.gemm import matmul
        x, W, b = kw[x_c], kw[W_c], kw[b_c]
        K, C, R, S = W.shape
        cols, (N, OH, OW) = _im2col(x, R, S)
        y = matmul(cols, W.reshape(K, C * R * S).T, b, activation=act,
                   interpret=interpret)
        y = y.reshape(N, OH, OW, K).transpose(0, 3, 1, 2)
        y = jax.lax.reduce_window(y, -jnp.inf, jax.lax.max,
                                  (1, 1, wdw, wdw), (1, 1, wdw, wdw), "VALID")
        return {y_c: y}

    return fn
