"""BLAS Library Nodes with multi-level expansions (paper §3.1, §3.3, §4).

Levels per node (selected via ``sdfg.expansion_preference`` or explicitly):

  * ``generic``       -- pure-dataflow subgraph (maps + tasklets), the level
                         mid-level transformations operate on;
  * ``xla``           -- delegate to a jnp composite (the MKL/cuBLAS analogue);
  * ``pallas``        -- platform-specialized Pallas kernel;
  * Dot additionally exposes the paper's two §3.3.1 accumulation strategies:
      ``partial_sums`` (Xilinx analogue: interleaved partial-sum buffer that
      breaks the loop-carried add dependency; on TPU, an 8x128 VREG-shaped
      accumulator tile) and ``accumulate`` (Intel analogue: native single
      accumulator — the MXU/VPU fp32 accumulate path).
  * Gemm additionally exposes ``systolic`` — the paper's Fig.-6
    one-dimensional systolic array as an UNROLLED map over P processing
    elements chained by pipe streams.
"""
from __future__ import annotations

import jax.numpy as jnp

from ..core.dtypes import ScheduleType, TPU_SUBLANES
from ..core.memlet import Memlet, Range, Subset
from ..core.sdfg import LibraryNode, SDFG, State
from ..core.symbolic import Expr, sym
from .util import in_edge, operand_nodes, out_edge, replace_with_tasklet, unique_name

# Partial-sum interleaving factor (paper: "buffer of a size larger than the
# latency of the addition"; on TPU we shape it as sublanes*lanes-friendly).
PARTIAL_SUM_LANES = 16


# ---------------------------------------------------------------------------
# AXPY: z = a*x + y
# ---------------------------------------------------------------------------
class Axpy(LibraryNode):
    default_expansion = "xla"

    def __init__(self, name="axpy"):
        super().__init__(name, inputs=["a", "x", "y"], outputs=["z"])


def _axpy_xla(node: Axpy, sdfg: SDFG, state: State):
    replace_with_tasklet(node, sdfg, state,
                         lambda a, x, y: a * x + y, "xla")


def _axpy_generic(node: Axpy, sdfg: SDFG, state: State):
    ops = operand_nodes(state, node)
    x_desc = sdfg.arrays[ops["x"].data]
    n = x_desc.shape[0]
    xe, ye, ae = (in_edge(state, node, c) for c in ("x", "y", "a"))
    ze = out_edge(state, node, "z")
    state.remove_node(node)
    state.add_mapped_tasklet(
        f"{node.label}_map", {"i": (0, n)},
        inputs={
            "a": Memlet.simple(ae.memlet.data),
            "x": Memlet.simple(xe.memlet.data, Subset.indices([sym("i")])),
            "y": Memlet.simple(ye.memlet.data, Subset.indices([sym("i")])),
        },
        outputs={"z": Memlet.simple(ze.memlet.data,
                                    Subset.indices([sym("i")]))},
        fn=lambda a, x, y: a * x + y,
        input_nodes={ae.memlet.data: ae.src, xe.memlet.data: xe.src,
                     ye.memlet.data: ye.src},
        output_nodes={ze.memlet.data: ze.dst},
    )


Axpy.expansions = {"xla": _axpy_xla, "generic": _axpy_generic}


# ---------------------------------------------------------------------------
# DOT: result = x . w
# ---------------------------------------------------------------------------
class Dot(LibraryNode):
    default_expansion = "xla"

    def __init__(self, name="dot"):
        super().__init__(name, inputs=["x", "w"], outputs=["result"])


def _dot_xla(node: Dot, sdfg: SDFG, state: State):
    replace_with_tasklet(
        node, sdfg, state,
        lambda x, w: jnp.dot(x.astype(jnp.float32), w.astype(jnp.float32)),
        "xla")


def _dot_accumulate(node: Dot, sdfg: SDFG, state: State):
    """Intel analogue (§3.3.1): stream into a single native accumulator.
    On TPU the fp32 accumulate is native (MXU/VPU), so the subgraph is a
    mapped tasklet with a scalar wcr-add target."""
    ops = operand_nodes(state, node)
    n = sdfg.arrays[ops["x"].data].shape[0]
    xe, we = in_edge(state, node, "x"), in_edge(state, node, "w")
    re = out_edge(state, node, "result")
    state.remove_node(node)
    state.add_mapped_tasklet(
        f"{node.label}_acc", {"i": (0, n)},
        inputs={
            "x": Memlet.simple(xe.memlet.data, Subset.indices([sym("i")])),
            "w": Memlet.simple(we.memlet.data, Subset.indices([sym("i")])),
        },
        outputs={"r": Memlet.simple(re.memlet.data, wcr="add")},
        fn=lambda x, w: x * w,
        input_nodes={xe.memlet.data: xe.src, we.memlet.data: we.src},
        output_nodes={re.memlet.data: re.dst},
    )


def _dot_partial_sums(node: Dot, sdfg: SDFG, state: State):
    """Xilinx analogue (§3.3.1): partial-sum interleaving. The streaming
    phase accumulates into K=PARTIAL_SUM_LANES interleaved partial sums
    (breaking the loop-carried dependency), and an unrolled 'reduce' phase
    collapses them — exactly the paper's two-map structure."""
    K = PARTIAL_SUM_LANES
    ops = operand_nodes(state, node)
    n = sdfg.arrays[ops["x"].data].shape[0]
    dtype = sdfg.arrays[ops["x"].data].dtype
    xe, we = in_edge(state, node, "x"), in_edge(state, node, "w")
    re = out_edge(state, node, "result")
    acc_name = unique_name(sdfg, f"{node.label}_partial")
    from ..core.dtypes import StorageType
    sdfg.add_transient(acc_name, (K,), dtype, storage=StorageType.REG)
    state.remove_node(node)
    # streaming phase: acc[l] += x[c*K+l] * w[c*K+l]
    _, _, ex1 = state.add_mapped_tasklet(
        f"{node.label}_stream", {"c": (0, n / K), "l": (0, K)},
        inputs={
            "x": Memlet.simple(xe.memlet.data,
                               Subset.indices([sym("c") * K + sym("l")])),
            "w": Memlet.simple(we.memlet.data,
                               Subset.indices([sym("c") * K + sym("l")])),
        },
        outputs={"p": Memlet.simple(acc_name, Subset.indices([sym("l")]),
                                    wcr="add")},
        fn=lambda x, w: x * w,
        input_nodes={xe.memlet.data: xe.src, we.memlet.data: we.src},
    )
    acc_node = out_edge(state, ex1, f"OUT_{acc_name}").dst
    # reduce phase: unrolled over the K partials (W-1 adders in the paper)
    state.add_mapped_tasklet(
        f"{node.label}_reduce", {"l": (0, K)},
        inputs={"p": Memlet.simple(acc_name, Subset.indices([sym("l")]))},
        outputs={"r": Memlet.simple(re.memlet.data, wcr="add")},
        fn=lambda p: p,
        schedule=ScheduleType.UNROLLED,
        input_nodes={acc_name: acc_node},
        output_nodes={re.memlet.data: re.dst},
    )


def _dot_pallas(node: Dot, sdfg: SDFG, state: State):
    from ..kernels.dot import ops as dot_ops
    interpret = sdfg.metadata.get("pallas_interpret", True)
    replace_with_tasklet(
        node, sdfg, state,
        lambda x, w: dot_ops.dot(x, w, interpret=interpret), "pallas")


Dot.expansions = {
    "xla": _dot_xla,
    "generic": _dot_partial_sums,   # generic == the portable partial-sum graph
    "partial_sums": _dot_partial_sums,
    "accumulate": _dot_accumulate,
    "pallas": _dot_pallas,
}


# ---------------------------------------------------------------------------
# GEMV: y = alpha * op(A) x (+ beta*y0)
# ---------------------------------------------------------------------------
class Gemv(LibraryNode):
    default_expansion = "xla"

    def __init__(self, name="gemv", trans: bool = False, alpha: float = 1.0,
                 beta: float = 0.0):
        ins = ["A", "x"] + (["y0"] if beta != 0.0 else [])
        super().__init__(name, inputs=ins, outputs=["y"])
        self.trans = trans
        self.alpha = alpha
        self.beta = beta


def _gemv_xla(node: Gemv, sdfg: SDFG, state: State):
    trans, alpha, beta = node.trans, node.alpha, node.beta

    def fn(A, x, y0=None):
        Au = A.T if trans else A
        y = alpha * (Au @ x)
        if beta != 0.0 and y0 is not None:
            y = y + beta * y0
        return y

    replace_with_tasklet(node, sdfg, state, fn, "xla")


def _gemv_generic(node: Gemv, sdfg: SDFG, state: State):
    """Row-streaming generic expansion: map over output rows, each a Dot-like
    reduction (tiles-by-rows scheme; for trans, tiles-by-columns — paper §4.2
    access-pattern matching)."""
    ops = operand_nodes(state, node)
    A_desc = sdfg.arrays[ops["A"].data]
    n, m = A_desc.shape
    rows = m if node.trans else n
    trans, alpha, beta = node.trans, node.alpha, node.beta
    Ae, xe = in_edge(state, node, "A"), in_edge(state, node, "x")
    ye = out_edge(state, node, "y")
    y0e = in_edge(state, node, "y0") if beta != 0.0 else None
    state.remove_node(node)
    if trans:
        a_sub = Subset([Range.make(0, n), Range.index(sym("i"))])
    else:
        a_sub = Subset([Range.index(sym("i")), Range.make(0, m)])
    inputs = {
        "Arow": Memlet.simple(Ae.memlet.data, a_sub),
        "x": Memlet.simple(xe.memlet.data),
    }
    input_nodes = {Ae.memlet.data: Ae.src, xe.memlet.data: xe.src}
    if y0e is not None:
        inputs["y0"] = Memlet.simple(y0e.memlet.data,
                                     Subset.indices([sym("i")]))
        input_nodes[y0e.memlet.data] = y0e.src

    def fn(Arow, x, y0=None):
        v = alpha * jnp.dot(jnp.ravel(Arow).astype(jnp.float32),
                            x.astype(jnp.float32))
        if y0 is not None:
            v = v + beta * y0
        return v

    state.add_mapped_tasklet(
        f"{node.label}_rows", {"i": (0, rows)},
        inputs=inputs,
        outputs={"y": Memlet.simple(ye.memlet.data,
                                    Subset.indices([sym("i")]))},
        fn=fn, input_nodes=input_nodes,
        output_nodes={ye.memlet.data: ye.dst},
    )


def _gemv_accumulate(node: Gemv, sdfg: SDFG, state: State):
    """Elementwise-exact accumulate expansion: one (i, j) map whose
    tasklet contributes ``alpha * A[i, j] * x[j]`` to ``y[i]`` under
    wcr-add (``y[j] += A[i, j] * x[i]`` for trans). Unlike the
    row-streaming expansion, every A read is a single element over the
    full (i, j) space — exactly the shape MapFusion fuses with an
    upstream producer of A over the same space (ger -> gemv chains become
    ONE grid kernel with the updated matrix held in-kernel).

    ``beta * y0`` seeds through a separate elementwise wcr-add map:
    addition commutes, so the seed and the accumulation maps need no
    ordering edge between their writes."""
    ops = operand_nodes(state, node)
    n, m = sdfg.arrays[ops["A"].data].shape
    trans, alpha, beta = node.trans, node.alpha, node.beta
    Ae, xe = in_edge(state, node, "A"), in_edge(state, node, "x")
    ye = out_edge(state, node, "y")
    y0e = in_edge(state, node, "y0") if beta != 0.0 else None
    state.remove_node(node)
    i, j = sym("i"), sym("j")
    out_idx, x_idx = (j, i) if trans else (i, j)
    state.add_mapped_tasklet(
        f"{node.label}_acc", {"i": (0, n), "j": (0, m)},
        inputs={
            "A": Memlet.simple(Ae.memlet.data, Subset.indices([i, j])),
            "x": Memlet.simple(xe.memlet.data, Subset.indices([x_idx])),
        },
        outputs={"y": Memlet.simple(ye.memlet.data,
                                    Subset.indices([out_idx]), wcr="add")},
        fn=lambda A, x: alpha * A * x,
        input_nodes={Ae.memlet.data: Ae.src, xe.memlet.data: xe.src},
        output_nodes={ye.memlet.data: ye.dst},
    )
    if y0e is not None:
        rows = m if trans else n
        k = sym("k")
        state.add_mapped_tasklet(
            f"{node.label}_seed", {"k": (0, rows)},
            inputs={"y0": Memlet.simple(y0e.memlet.data,
                                        Subset.indices([k]))},
            outputs={"y": Memlet.simple(ye.memlet.data,
                                        Subset.indices([k]), wcr="add")},
            fn=lambda y0: beta * y0,
            input_nodes={y0e.memlet.data: y0e.src},
            output_nodes={ye.memlet.data: ye.dst},
        )


Gemv.expansions = {"xla": _gemv_xla, "generic": _gemv_generic,
                   "accumulate": _gemv_accumulate}


# ---------------------------------------------------------------------------
# GER: A' = A + alpha * outer(x, y)
# ---------------------------------------------------------------------------
class Ger(LibraryNode):
    default_expansion = "xla"

    def __init__(self, name="ger", alpha: float = 1.0):
        super().__init__(name, inputs=["A", "x", "y"], outputs=["Aout"])
        self.alpha = alpha


def _ger_xla(node: Ger, sdfg: SDFG, state: State):
    alpha = node.alpha
    replace_with_tasklet(node, sdfg, state,
                         lambda A, x, y: A + alpha * jnp.outer(x, y), "xla")


def _ger_generic(node: Ger, sdfg: SDFG, state: State):
    ops = operand_nodes(state, node)
    n, m = sdfg.arrays[ops["A"].data].shape
    alpha = node.alpha
    Ae, xe, ye = (in_edge(state, node, c) for c in ("A", "x", "y"))
    oe = out_edge(state, node, "Aout")
    state.remove_node(node)
    state.add_mapped_tasklet(
        f"{node.label}_map", {"i": (0, n), "j": (0, m)},
        inputs={
            "A": Memlet.simple(Ae.memlet.data,
                               Subset.indices([sym("i"), sym("j")])),
            "x": Memlet.simple(xe.memlet.data, Subset.indices([sym("i")])),
            "y": Memlet.simple(ye.memlet.data, Subset.indices([sym("j")])),
        },
        outputs={"out": Memlet.simple(oe.memlet.data,
                                      Subset.indices([sym("i"), sym("j")]))},
        fn=lambda A, x, y: A + alpha * x * y,
        input_nodes={Ae.memlet.data: Ae.src, xe.memlet.data: xe.src,
                     ye.memlet.data: ye.src},
        output_nodes={oe.memlet.data: oe.dst},
    )


Ger.expansions = {"xla": _ger_xla, "generic": _ger_generic}


# ---------------------------------------------------------------------------
# GEMM: C = A @ B
# ---------------------------------------------------------------------------
class Gemm(LibraryNode):
    default_expansion = "xla"

    def __init__(self, name="gemm"):
        super().__init__(name, inputs=["A", "B"], outputs=["C"])


def _gemm_xla(node: Gemm, sdfg: SDFG, state: State):
    replace_with_tasklet(
        node, sdfg, state,
        lambda A, B: jnp.matmul(A, B, preferred_element_type=jnp.float32
                                ).astype(A.dtype), "xla")


def _gemm_pallas(node: Gemm, sdfg: SDFG, state: State):
    from ..kernels.gemm import ops as gemm_ops
    interpret = sdfg.metadata.get("pallas_interpret", True)
    replace_with_tasklet(
        node, sdfg, state,
        lambda A, B: gemm_ops.matmul(A, B, interpret=interpret), "pallas")


def _gemm_systolic(node: Gemm, sdfg: SDFG, state: State):
    """Paper Fig. 6: one-dimensional systolic array as an UNROLLED map over
    P processing elements connected by pipe streams. PE p computes a block
    of C rows while forwarding the streamed B matrix down the chain
    (B enters the head of the chain once per row-tile: volume K*M*N/(P*Tn),
    matching the Fig.-7 annotation with tile height P*Tn)."""
    P = int(sdfg.metadata.get("systolic_pes", 4))
    ops = operand_nodes(state, node)
    N, K = sdfg.arrays[ops["A"].data].shape
    K2, M = sdfg.arrays[ops["B"].data].shape
    dtype = sdfg.arrays[ops["A"].data].dtype
    Ae, Be = in_edge(state, node, "A"), in_edge(state, node, "B")
    Ce = out_edge(state, node, "C")
    A_name, B_name, C_name = Ae.memlet.data, Be.memlet.data, Ce.memlet.data
    state.remove_node(node)

    b_pipe = unique_name(sdfg, f"{node.label}_B_pipe")
    sdfg.add_stream(b_pipe, dtype, buffer_size=1, shape=(P + 1,),
                    element_shape=(K, M), total_volume=K * M)
    a_pipe = unique_name(sdfg, f"{node.label}_A_pipe")
    sdfg.add_stream(a_pipe, dtype, buffer_size=1, shape=(P + 1,),
                    element_shape=(N, K), total_volume=N * K)

    pipe_in = state.add_access(b_pipe)
    apipe_in = state.add_access(a_pipe)
    # read_B: memory reader PE (paper red box) pushes B into the pipe head
    read_b = state.add_tasklet(f"{node.label}_read_B", ["mem"], ["pipe"],
                               lambda mem: mem)
    state.add_edge(Be.src, None, read_b, "mem",
                   Memlet.simple(B_name, volume=Expr.wrap(K * M)))
    state.add_edge(read_b, "pipe", pipe_in, None,
                   Memlet.simple(b_pipe,
                                 Subset([Range.index(0), Range.make(0, K),
                                         Range.make(0, M)]),
                                 volume=Expr.wrap(K * M)))
    read_a = state.add_tasklet(f"{node.label}_read_A", ["mem"], ["pipe"],
                               lambda mem: mem)
    state.add_edge(Ae.src, None, read_a, "mem",
                   Memlet.simple(A_name, volume=Expr.wrap(N * K)))
    state.add_edge(read_a, "pipe", apipe_in, None,
                   Memlet.simple(a_pipe,
                                 Subset([Range.index(0), Range.make(0, N),
                                         Range.make(0, K)]),
                                 volume=Expr.wrap(N * K)))

    # the systolic chain: unrolled map over P PEs (paper: each instance is a
    # weakly-connected component => an independently scheduled PE)
    entry, exit_ = state.add_map(f"{node.label}_pes", {"p": (0, P)},
                                 schedule=ScheduleType.UNROLLED)
    rows = N // P

    def pe_fn(a_in, a_mine, b_in):
        # PE p: forward the A and B streams down the chain unchanged, keep
        # my row block, contribute my C tile (paper Fig. 6 buffering scheme).
        c_blk = jnp.matmul(a_mine, b_in, preferred_element_type=jnp.float32
                           ).astype(a_mine.dtype)
        return {"a_out": a_in, "b_out": b_in, "c_blk": c_blk}

    pe = state.add_tasklet(f"{node.label}_pe", ["a_in", "a_mine", "b_in"],
                           ["a_out", "b_out", "c_blk"], pe_fn)

    p = sym("p")
    state.add_edge(apipe_in, None, entry, f"IN_{a_pipe}",
                   Memlet.simple(a_pipe))
    state.add_edge(pipe_in, None, entry, f"IN_{b_pipe}", Memlet.simple(b_pipe))
    state.add_edge(entry, f"OUT_{a_pipe}", pe, "a_in",
                   Memlet.simple(a_pipe,
                                 Subset([Range.index(p), Range.make(0, N),
                                         Range.make(0, K)]),
                                 volume=Expr.wrap(N * K)))
    state.add_edge(entry, f"OUT_{a_pipe}", pe, "a_mine",
                   Memlet.simple(a_pipe,
                                 Subset([Range.index(p),
                                         Range.make(p * rows, (p + 1) * rows),
                                         Range.make(0, K)]),
                                 volume=Expr.wrap(N * K) / P))
    state.add_edge(entry, f"OUT_{b_pipe}", pe, "b_in",
                   Memlet.simple(b_pipe,
                                 Subset([Range.index(p), Range.make(0, K),
                                         Range.make(0, M)]),
                                 volume=Expr.wrap(K * M) * P))
    # forward to next pipe slot
    state.add_edge(pe, "a_out", exit_, f"IN_{a_pipe}",
                   Memlet.simple(a_pipe,
                                 Subset([Range.index(p + 1), Range.make(0, N),
                                         Range.make(0, K)]),
                                 volume=Expr.wrap(N * K)))
    state.add_edge(pe, "b_out", exit_, f"IN_{b_pipe}",
                   Memlet.simple(b_pipe,
                                 Subset([Range.index(p + 1), Range.make(0, K),
                                         Range.make(0, M)]),
                                 volume=Expr.wrap(K * M) * P))
    state.add_edge(pe, "c_blk", exit_, f"IN_{C_name}",
                   Memlet.simple(C_name,
                                 Subset([Range.make(p * rows, (p + 1) * rows),
                                         Range.make(0, M)]),
                                 volume=Expr.wrap(N * M)))
    apipe_out = state.add_access(a_pipe)
    bpipe_out = state.add_access(b_pipe)
    state.add_edge(exit_, f"OUT_{a_pipe}", apipe_out, None,
                   Memlet.simple(a_pipe, volume=Expr.wrap(N * K)))
    state.add_edge(exit_, f"OUT_{b_pipe}", bpipe_out, None,
                   Memlet.simple(b_pipe, volume=Expr.wrap(K * M) * P))
    state.add_edge(exit_, f"OUT_{C_name}", Ce.dst, None,
                   Memlet.simple(C_name, volume=Expr.wrap(N * M)))


Gemm.expansions = {
    "xla": _gemm_xla,
    "pallas": _gemm_pallas,
    "systolic": _gemm_systolic,
    "generic": _gemm_xla,
}
