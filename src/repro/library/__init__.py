"""Library Nodes: abstract behavior, multi-level expansions (paper §3)."""
from .attention import PagedAttnDecode
from .blas import Axpy, Dot, Gemm, Gemv, Ger

__all__ = ["Axpy", "Dot", "Gemm", "Gemv", "Ger", "PagedAttnDecode"]
