"""Helpers for Library-Node expansions (paper §3)."""
from __future__ import annotations

from typing import Callable, Dict

from ..core.memlet import Memlet
from ..core.sdfg import AccessNode, LibraryNode, SDFG, State, Tasklet


def in_edge(state: State, node, conn: str):
    for e in state.in_edges(node):
        if e.dst_conn == conn:
            return e
    raise KeyError(f"{node.label}: no in-edge on connector {conn!r}")


def out_edge(state: State, node, conn: str):
    for e in state.out_edges(node):
        if e.src_conn == conn:
            return e
    raise KeyError(f"{node.label}: no out-edge on connector {conn!r}")


def replace_with_tasklet(node: LibraryNode, sdfg: SDFG, state: State,
                         fn: Callable, name_suffix: str = "impl") -> Tasklet:
    """Swap a library node for a single tasklet with identical connectors —
    the 'delegate to a high-performance implementation' expansion level
    (paper §3.3: cuBLAS/MKL analogue; here a jnp or Pallas composite)."""
    t = state.add_tasklet(f"{node.label}_{name_suffix}", node.inputs,
                          node.outputs, fn)
    for e in state.in_edges(node):
        state.add_edge(e.src, e.src_conn, t, e.dst_conn, e.memlet)
    for e in state.out_edges(node):
        state.add_edge(t, e.src_conn, e.dst, e.dst_conn, e.memlet)
    state.remove_node(node)
    return t


def operand_nodes(state: State, node: LibraryNode) -> Dict[str, AccessNode]:
    """Connector name -> neighboring access node."""
    out: Dict[str, AccessNode] = {}
    for e in state.in_edges(node):
        if isinstance(e.src, AccessNode) and e.dst_conn:
            out[e.dst_conn] = e.src
    for e in state.out_edges(node):
        if isinstance(e.dst, AccessNode) and e.src_conn:
            out[e.src_conn] = e.dst
    return out


def unique_name(sdfg: SDFG, base: str) -> str:
    name = base
    i = 0
    while name in sdfg.arrays:
        i += 1
        name = f"{base}_{i}"
    return name
