"""Attention Library Nodes (paper §3): decode attention over paged KV.

``PagedAttnDecode`` abstracts one serving decode step of attention for a
whole batch: q is (B, H, Dh), the context K/V — gathered from the paged
KV pool via the block table — is (B, C, H, Dh) with C the context
bucket, and ``pos`` (B,) carries each sequence's absolute position for
causal/window masking. Expansion levels, most specialized first:

  * ``flash``   -- delegate to the hand-written Pallas kernel
                   (``kernels.attention.decode_attention``), the paper's
                   'vendor library' level;
  * ``pallas``  -- a generic (b, h) mapped tasklet whose affine memlets
                   let MapTiling + GridConversion derive a batched grid
                   kernel (the serving default: the attention step shows
                   up in ``report['grid_kernels']``);
  * ``xla``     -- one jnp tasklet, the shardable reference.

All three share one masking contract: key j participates iff
``j <= pos[b]`` (and ``j > pos[b] - window`` when sliding-window), so
unwritten pages and the null page of inactive slots never reach the
softmax regardless of what garbage they hold.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..core.memlet import Memlet, Range, Subset
from ..core.sdfg import SDFG, LibraryNode, State
from ..core.symbolic import sym
from .util import in_edge, out_edge, replace_with_tasklet

NEG_INF = -1e30


def _operand_shape(sdfg: SDFG, state: State, node, conn: str):
    e = in_edge(state, node, conn)
    desc = sdfg.arrays[e.memlet.data]
    return tuple(int(s.evaluate(sdfg.symbol_values)) for s in desc.shape)


def _expand_xla(node: "PagedAttnDecode", sdfg: SDFG, state: State):
    _, ctx, _, dh = _operand_shape(sdfg, state, node, "k")
    scale = 1.0 / np.sqrt(dh)
    window = node.window

    def attn(q, k, v, pos):
        qf = q.astype(jnp.float32)
        kf = k.astype(jnp.float32)
        vf = v.astype(jnp.float32)
        s = jnp.einsum("bhd,bchd->bhc", qf, kf) * scale
        j = jnp.arange(ctx)[None, None, :]
        mask = j <= pos[:, None, None]
        if window is not None:
            mask &= j > pos[:, None, None] - window
        s = jnp.where(mask, s, NEG_INF)
        p = jax.nn.softmax(s, axis=-1)
        out = jnp.einsum("bhc,bchd->bhd", p, vf)
        return {"out": out.astype(q.dtype)}

    replace_with_tasklet(node, sdfg, state, attn, "xla")


def _expand_flash(node: "PagedAttnDecode", sdfg: SDFG, state: State):
    window = node.window
    interpret = bool(sdfg.metadata.get("pallas_interpret", True))

    def attn(q, k, v, pos):
        from ..kernels.attention import decode_attention
        return {"out": decode_attention(q, k, v, pos, window=window,
                                        interpret=interpret)}

    replace_with_tasklet(node, sdfg, state, attn, "flash")


def _expand_grid(node: "PagedAttnDecode", sdfg: SDFG, state: State):
    """Generic (b, h) map over per-head attention rows.

    Every memlet is affine in the map parameters (the context/head-dim
    extents move as whole dims), so GridConversion can factor them into
    BlockSpecs; the per-iteration operands are rows/matrices, which takes
    the nested-vmap kernel-body path. MapTiling tiles b into sublane
    blocks (dtype-aware when the pipeline leaves second_size unset), so
    the derived grid streams (b_tile, C, Dh) context slabs through VMEM.
    """
    eq = in_edge(state, node, "q")
    ek = in_edge(state, node, "k")
    ev = in_edge(state, node, "v")
    ep = in_edge(state, node, "pos")
    eo = out_edge(state, node, "out")
    b_n, h_n, dh = _operand_shape(sdfg, state, node, "q")
    _, ctx, _, _ = _operand_shape(sdfg, state, node, "k")
    scale = 1.0 / np.sqrt(dh)
    window = node.window

    def attn_row(q, k, v, pos):
        qf = q.astype(jnp.float32)
        kf = k.astype(jnp.float32)
        s = kf @ qf * scale                        # (C,)
        j = jnp.arange(ctx)
        mask = j <= pos
        if window is not None:
            mask &= j > pos - window
        s = jnp.where(mask, s, NEG_INF)
        p = jax.nn.softmax(s, axis=-1)
        out = p @ v.astype(jnp.float32)
        return {"out": out.astype(q.dtype)}

    b, h = sym("b"), sym("h")
    qd, kd, vd = eq.memlet.data, ek.memlet.data, ev.memlet.data
    pd, od = ep.memlet.data, eo.memlet.data
    state.remove_node(node)
    state.add_mapped_tasklet(
        f"{node.label}_grid", {"b": (0, b_n), "h": (0, h_n)},
        inputs={
            "q": Memlet.simple(qd, Subset([Range.index(b), Range.index(h),
                                           Range.make(0, dh)])),
            "k": Memlet.simple(kd, Subset([Range.index(b),
                                           Range.make(0, ctx),
                                           Range.index(h),
                                           Range.make(0, dh)])),
            "v": Memlet.simple(vd, Subset([Range.index(b),
                                           Range.make(0, ctx),
                                           Range.index(h),
                                           Range.make(0, dh)])),
            "pos": Memlet.simple(pd, Subset([Range.index(b)])),
        },
        outputs={
            "out": Memlet.simple(od, Subset([Range.index(b), Range.index(h),
                                             Range.make(0, dh)])),
        },
        fn=attn_row,
        input_nodes={qd: eq.src, kd: ek.src, vd: ev.src, pd: ep.src},
        output_nodes={od: eo.dst},
    )


class PagedAttnDecode(LibraryNode):
    """Batched single-token decode attention over a gathered context.

    Connectors: q (B, H, Dh), k/v (B, C, H, Dh) — already GQA-repeated to
    H heads by the page gather — pos (B,) int32 -> out (B, H, Dh).
    """

    expansions = {
        "flash": _expand_flash,
        "pallas": _expand_grid,
        "xla": _expand_xla,
        "generic": _expand_grid,
    }
    default_expansion = "xla"

    def __init__(self, name: str, window: Optional[int] = None):
        super().__init__(name, inputs=["q", "k", "v", "pos"],
                         outputs=["out"])
        self.window = window
