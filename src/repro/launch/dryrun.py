import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count=512")
# NOTE: the two lines above MUST run before any other import (jax locks the
# device count on first init). Docstring and __future__ imports follow.
DOC = """Multi-pod dry-run (deliverable e): prove the distribution config is
coherent without hardware.

For every (architecture x input-shape) cell, lower + compile train_step /
serve_step on the single-pod (16,16)=(data,model) mesh and the multi-pod
(2,16,16)=(pod,data,model) mesh, print memory_analysis() and
cost_analysis(), extract the roofline terms (launch/analysis.py), and dump
everything to JSON for EXPERIMENTS.md.

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun --arch yi-34b --shape train_4k
    PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod]
"""

import argparse  # noqa: E402
import dataclasses  # noqa: E402
import json  # noqa: E402
import time  # noqa: E402
import traceback  # noqa: E402
from pathlib import Path  # noqa: E402

import jax

from ..configs import ARCHS, SHAPES, get_config
from ..models import _flags
from ..models.transformer import build_schedule
from . import analysis
from .mesh import make_production_mesh
from .steps import lower_cell


def probe_costs(cfg, shape, mesh, remat: bool = True) -> dict:
    """Exact per-step flops/bytes by depth extrapolation.

    XLA's cost_analysis counts while-loop bodies ONCE, so the full model's
    numbers undercount the layer stack. We *lower* (no compile — seconds,
    not minutes) two shallow variants (period and 2*period layers) with
    every scan unrolled, take the per-period slope, and extrapolate:

        total(L) = shallow(P) + slope * (L - P) / P

    lowered.cost_analysis() reports whole-program (unpartitioned) numbers;
    we divide by the chip count (valid for evenly-sharded programs — the
    sharding rules shard every large tensor). Collective bytes come from
    the full compiled HLO with trip-count weighting (analysis.py).
    """
    n_chips = mesh.devices.size
    period, _, _ = (build_schedule(cfg) if cfg.family != "encdec"
                    else ([None], None, []))
    P = len(period) if cfg.family != "encdec" else 1

    def measure(n_layers):
        changes = {"n_layers": n_layers}
        if cfg.n_encoder_layers:
            changes["n_encoder_layers"] = n_layers
        c = dataclasses.replace(cfg, **changes)
        _flags.UNROLL_SCANS = True
        try:
            lowered = lower_cell(c, shape, mesh, remat=remat)
        finally:
            _flags.UNROLL_SCANS = False
        cost = lowered.cost_analysis() or {}
        return {
            "flops": float(cost.get("flops", 0.0)) / n_chips,
            "bytes": float(cost.get("bytes accessed", 0.0)) / n_chips,
        }

    m1 = measure(P)
    m2 = measure(2 * P)
    L = cfg.n_layers
    out = {}
    for k in ("flops", "bytes"):
        slope = (m2[k] - m1[k])
        out[k] = m1[k] + slope * (L - P) / P
    out["per_period"] = {k: (m2[k] - m1[k]) for k in m1}
    out["intercept"] = {k: 2 * m1[k] - m2[k] for k in m1}
    return out

RESULTS_DIR = Path(__file__).resolve().parents[3] / "results"


def run_cell(arch: str, shape_name: str, multi_pod: bool = False,
             remat: bool = True, verbose: bool = True,
             probe: bool = True, optimized: bool = False) -> dict:
    cfg = get_config(arch)
    if optimized:
        # beyond-paper hillclimbed variant (EXPERIMENTS §Perf): chunked
        # online-softmax attention + scatter/gather MoE dispatch
        cfg = dataclasses.replace(cfg, attention_impl="chunked",
                                  moe_dispatch="sort")
    shape = SHAPES[shape_name]
    rec = {"arch": arch, "shape": shape_name,
           "mesh": "2x16x16" if multi_pod else "16x16",
           "kind": shape.kind, "status": "ok",
           "variant": "optimized" if optimized else "baseline"}
    if shape_name in cfg.skip_shapes:
        rec["status"] = "skipped"
        rec["reason"] = ("pure full-attention arch: long_500k skipped per "
                         "assignment (DESIGN.md §4)")
        return rec
    mesh = make_production_mesh(multi_pod=multi_pod)
    n_chips = mesh.devices.size
    t0 = time.time()
    try:
        lowered = lower_cell(cfg, shape, mesh, remat=remat)
        rec["lower_s"] = round(time.time() - t0, 1)
        t1 = time.time()
        compiled = lowered.compile()
        rec["compile_s"] = round(time.time() - t1, 1)
        mem = analysis.memory_per_device(compiled)
        if shape.kind == "train":
            tokens = shape.global_batch * shape.seq_len
            mf = analysis.train_model_flops(cfg.n_active_params(), tokens)
        elif shape.kind == "prefill":
            tokens = shape.global_batch * shape.seq_len
            mf = 2.0 * cfg.n_active_params() * tokens
        else:
            mf = analysis.decode_model_flops(cfg.n_active_params(),
                                             shape.global_batch)
        terms = analysis.roofline_terms(compiled, n_chips, model_flops=mf)
        rec["memory"] = mem
        rec["roofline_raw"] = {k: v for k, v in terms.items()
                               if k != "collective_ops"}
        rec["collectives"] = terms["collective_ops"]
        # exact costs via depth extrapolation (see probe_costs docstring);
        # collective bytes already trip-count-weighted from the full compile
        if probe:
            pr = probe_costs(cfg, shape, mesh, remat=remat)
            rec["probe"] = pr
            rec["roofline"] = analysis.terms_from_counts(
                pr["flops"], pr["bytes"],
                terms["collective_bytes_per_dev"], n_chips, model_flops=mf)
            terms = dict(rec["roofline"])
        else:
            rec["roofline"] = rec["roofline_raw"]
        if verbose:
            print(f"--- {arch} x {shape_name} on {rec['mesh']} ---")
            print("memory_analysis:", json.dumps(mem))
            print("cost(/dev, depth-extrapolated): flops=%.3e bytes=%.3e "
                  "coll=%.3e" % (terms["hlo_flops_per_dev"],
                                 terms["hlo_bytes_per_dev"],
                                 terms["collective_bytes_per_dev"]))
            print("terms: compute=%.4fs memory=%.4fs collective=%.4fs "
                  "dominant=%s roofline=%.3f" % (
                      terms["compute_s"], terms["memory_s"],
                      terms["collective_s"], terms["dominant"],
                      terms.get("roofline_fraction", 0.0)))
    except Exception as e:  # noqa: BLE001 — record failures, keep sweeping
        rec["status"] = "error"
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-2000:]
        if verbose:
            print(f"--- {arch} x {shape_name} on {rec['mesh']}: FAILED ---")
            print(rec["error"])
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--no-remat", action="store_true")
    ap.add_argument("--no-probe", action="store_true",
                    help="skip cost extrapolation (multi-pod pass: the "
                         "roofline table is single-pod only)")
    ap.add_argument("--optimized", action="store_true",
                    help="hillclimbed variant (chunked attention + sort MoE "
                         "dispatch) instead of the paper-faithful baseline")
    ap.add_argument("--out", default=None)
    args = ap.parse_args()

    cells = []
    archs = list(ARCHS) if (args.all or not args.arch) else [args.arch]
    shapes = list(SHAPES) if (args.all or not args.shape) else [args.shape]
    meshes = [False, True] if args.both_meshes else [args.multi_pod]

    results = []
    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                results.append(run_cell(arch, shape, multi_pod=mp,
                                        remat=not args.no_remat,
                                        probe=not args.no_probe,
                                        optimized=args.optimized))

    RESULTS_DIR.mkdir(exist_ok=True)
    out = Path(args.out) if args.out else RESULTS_DIR / "dryrun.json"
    existing = []
    if out.exists():
        existing = json.loads(out.read_text())
        keys = {(r["arch"], r["shape"], r["mesh"]) for r in results}
        existing = [r for r in existing
                    if (r["arch"], r["shape"], r["mesh"]) not in keys]
    out.write_text(json.dumps(existing + results, indent=1))
    ok = sum(r["status"] == "ok" for r in results)
    sk = sum(r["status"] == "skipped" for r in results)
    err = sum(r["status"] == "error" for r in results)
    print(f"\n== dry-run: {ok} ok, {sk} skipped, {err} failed -> {out}")
    return 1 if err else 0


if __name__ == "__main__":
    raise SystemExit(main())
