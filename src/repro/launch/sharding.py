"""Sharding rules: parameter / optimizer / activation / cache PartitionSpecs.

2D (fsdp x tensor) sharding: weight matrices shard their input-ish dim over
the data axes (ZeRO/FSDP — pods included, so 1T-param states fit per chip)
and their parallel dim over the model axis (Megatron TP). MoE expert stacks
shard experts over the model axis (EP). KV caches shard heads over model
when divisible, otherwise sequence (long-context decode: sequence-sharded
KV, softmax combine inserted by GSPMD). Every rule checks divisibility and
falls back to replication per-dimension.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from ..configs.base import ModelConfig
from .mesh import dp_axes, mesh_axis_sizes

# rule tables: leaf-name -> per-dim roles, trailing dims of the unstacked
# leaf. roles: 'fsdp' (shard over data axes), 'tp' (model axis), None.
_PARAM_RULES = {
    # embeddings / heads
    "embed": ("tp", "fsdp"),          # (V, D): vocab-parallel
    "lm_head": ("fsdp", "tp"),        # (D, V)
    "stub_proj": ("fsdp", "tp"),
    "frame_proj": ("fsdp", "tp"),
    # attention
    "wq": ("fsdp", "tp"), "wk": ("fsdp", "tp"), "wv": ("fsdp", "tp"),
    "wg": ("fsdp", "tp"),
    "wo": ("tp", "fsdp"),
    # dense mlp
    "w_gate": ("fsdp", "tp"), "w_up": ("fsdp", "tp"),
    "w_down": ("tp", "fsdp"),
    "w_in": ("fsdp", "tp"), "w_out": ("tp", "fsdp"),
    "b_in": ("tp",), "b_out": (None,),
    "cm_wk": ("fsdp", "tp"), "cm_wv": ("tp", "fsdp"),
    # moe (leading E dim = expert parallel over model axis)
    "router": ("fsdp", "tp"),
    "moe_gate": ("tp", "fsdp", None), "moe_up": ("tp", "fsdp", None),
    "moe_down": ("tp", None, "fsdp"),
    "sh_gate": ("fsdp", "tp"), "sh_up": ("fsdp", "tp"),
    "sh_down": ("tp", "fsdp"),
    # mamba
    "conv_w": (None, "tp"), "w_bcdt": ("tp", None),
    "A_log": ("tp", None), "dt_bias": ("tp",), "D": ("tp",),
    # rwkv
    "wr": ("fsdp", "tp"), "w_decay": (None,), "u_bonus": ("tp", None),
    "mix_rkvwg": (None, None), "cm_mix": (None,),
}

_STACKED_CONTAINERS = ("body", "encoder", "decoder")


def _role_to_axis(role, dim_size: int, sizes: Dict[str, int],
                  fsdp_axes: Tuple[str, ...]):
    if role == "tp" and "model" in sizes:
        if dim_size % sizes["model"] == 0:
            return "model"
        return None
    if role == "fsdp" and fsdp_axes:
        # use as many dp axes as divide the dim (pod outermost)
        usable = []
        prod = 1
        for a in fsdp_axes:
            if dim_size % (prod * sizes[a]) == 0:
                usable.append(a)
                prod *= sizes[a]
        if usable:
            return tuple(usable) if len(usable) > 1 else usable[0]
        return None
    return None


def param_pspecs(cfg: ModelConfig, params, mesh) -> Dict:
    """PartitionSpec pytree matching ``params`` (works for opt states too
    via tree-prefix broadcasting by the caller)."""
    sizes = mesh_axis_sizes(mesh)
    fsdp = dp_axes(mesh)

    def spec_for_path(path, leaf):
        names = [p.key for p in path if hasattr(p, "key")]
        name = names[-1] if names else ""
        stacked = any(n in _STACKED_CONTAINERS for n in names)
        rule = _PARAM_RULES.get(name)
        shape = leaf.shape
        core_shape = shape[1:] if stacked else shape
        if rule is None or len(rule) != len(core_shape):
            return P()  # replicate unknowns (norm scales etc.)
        spec = []
        if stacked:
            spec.append(None)
        for role, d in zip(rule, core_shape):
            spec.append(_role_to_axis(role, d, sizes, fsdp))
        return P(*spec)

    return jax.tree_util.tree_map_with_path(spec_for_path, params)


def opt_pspecs(optimizer_name: str, params, params_specs) -> Dict:
    """Optimizer-state specs mirror parameter specs (ZeRO); adafactor's
    factored moments drop the reduced dimension's axis."""
    if optimizer_name == "adamw":
        return {"m": params_specs, "v": params_specs}
    if optimizer_name == "adafactor":
        def leaf(p, s):
            if not isinstance(s, P) or len(s) != p.ndim:
                s = P(*([None] * p.ndim))
            if p.ndim >= 2:
                return {"vr": P(*s[:-1]),
                        "vc": P(*(list(s[:-2]) + [s[-1]]))}
            return {"v": s}
        return jax.tree.map(leaf, params, params_specs,
                            is_leaf=lambda x: isinstance(x, P))
    raise ValueError(optimizer_name)


def batch_axes(batch_size: int, mesh) -> Optional[Tuple[str, ...]]:
    sizes = mesh_axis_sizes(mesh)
    usable, prod = [], 1
    for a in dp_axes(mesh):
        if batch_size % (prod * sizes[a]) == 0:
            usable.append(a)
            prod *= sizes[a]
    if not usable:
        return None
    return tuple(usable) if len(usable) > 1 else usable[0]


def batch_pspecs(cfg: ModelConfig, specs: Dict, mesh) -> Dict:
    out = {}
    for k, v in specs.items():
        b = batch_axes(v.shape[0], mesh)
        out[k] = P(b, *([None] * (len(v.shape) - 1)))
    return out


def cache_pspecs(cfg: ModelConfig, cache, mesh) -> Dict:
    """KV cache: batch over dp axes; heads over model if divisible, else
    sequence over model (sequence-parallel long-context decode)."""
    sizes = mesh_axis_sizes(mesh)

    def leaf_spec(path, leaf):
        names = [getattr(p, "key", None) for p in path]
        name = [n for n in names if isinstance(n, str)][-1] \
            if any(isinstance(n, str) for n in names) else ""
        stacked = "body" in names or "layers" in names
        shape = leaf.shape
        core = shape[1:] if stacked else shape
        prefix = [None] if stacked else []
        if name in ("k", "v", "ck", "cv") and len(core) == 4:
            b, s, h, hd = core
            ba = batch_axes(b, mesh)
            if "model" in sizes and h % sizes["model"] == 0:
                return P(*prefix, ba, None, "model", None)
            if "model" in sizes and s % sizes["model"] == 0:
                return P(*prefix, ba, "model", None, None)
            return P(*prefix, ba, None, None, None)
        if name == "conv" and len(core) == 3:
            b, k, din = core
            ba = batch_axes(b, mesh)
            tp = "model" if din % sizes.get("model", 1) == 0 else None
            return P(*prefix, ba, None, tp)
        if name == "ssm" and len(core) == 3:
            b, din, n = core
            ba = batch_axes(b, mesh)
            tp = "model" if din % sizes.get("model", 1) == 0 else None
            return P(*prefix, ba, tp, None)
        if name == "wkv" and len(core) == 4:
            b, h, hd, hd2 = core
            ba = batch_axes(b, mesh)
            tp = "model" if h % sizes.get("model", 1) == 0 else None
            return P(*prefix, ba, tp, None, None)
        if name in ("shift1", "shift2") and len(core) == 3:
            ba = batch_axes(core[0], mesh)
            tp = "model" if core[2] % sizes.get("model", 1) == 0 else None
            return P(*prefix, ba, None, tp)
        return P()

    return jax.tree_util.tree_map_with_path(leaf_spec, cache)


def to_shardings(pspec_tree, mesh):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), pspec_tree,
                        is_leaf=lambda x: isinstance(x, P))
