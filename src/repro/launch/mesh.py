"""Production meshes.

Defined as FUNCTIONS (not module-level constants) so importing this module
never touches jax device state. The dry-run sets
XLA_FLAGS=--xla_force_host_platform_device_count=512 before any jax import;
smoke tests and benches see 1 device.
"""
from __future__ import annotations

import jax


def _make_mesh(shape, axes):
    # jax.sharding.AxisType landed after 0.4.x; older releases have only
    # Auto semantics, so the kwarg is simply omitted there.
    if hasattr(jax.sharding, "AxisType"):
        auto = (jax.sharding.AxisType.Auto,) * len(shape)
        return jax.make_mesh(shape, axes, axis_types=auto)
    return jax.make_mesh(shape, axes)


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _make_mesh(shape, axes)


def make_smoke_mesh():
    """1-device mesh with the production axis names, for CPU tests."""
    return _make_mesh((1, 1), ("data", "model"))


def mesh_axis_sizes(mesh) -> dict:
    return dict(zip(mesh.axis_names, mesh.devices.shape))


def dp_axes(mesh) -> tuple:
    """Axes used for data parallelism (pod outermost when present)."""
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)
