"""Roofline-term extraction from compiled dry-run artifacts.

    compute term    = HLO_FLOPs / (chips * peak_FLOP/s)
    memory term     = HLO_bytes / (chips * HBM_bw)
    collective term = collective_bytes / (chips * link_bw)

HLO_FLOPs / HLO_bytes come from compiled.cost_analysis(); collective bytes
are NOT in cost_analysis, so we parse the optimized HLO text and sum the
operand sizes of every all-gather / all-reduce / reduce-scatter /
all-to-all / collective-permute. Hardware: TPU v5e-class constants.
"""
from __future__ import annotations

import re
from typing import Dict

# TPU v5e-class hardware constants (per assignment)
PEAK_FLOPS = 197e12        # bf16 FLOP/s per chip
HBM_BW = 819e9             # B/s per chip
LINK_BW = 50e9             # B/s per ICI link

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_RE = re.compile(r"\b(f64|f32|f16|bf16|f8e4m3fn|f8e5m2|s64|u64|s32|"
                       r"u32|s16|u16|s8|u8|pred|c64|c128)\[([0-9,]*)\]")


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES[dtype]


_COMP_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w\.\-]+)\s*\([^)]*\)\s*->")
_WHILE_RE = re.compile(r"while\(.*?body=%?([\w\.\-]+)")
_TRIP_RE = re.compile(r'known_trip_count..:\{.n.:.(\d+).\}')
_CALL_RE = re.compile(r"\b(?:call|fusion)\(.*?to_apply=%?([\w\.\-]+)")


def collective_stats(hlo_text: str) -> Dict:
    """Sum operand sizes of collective ops in optimized HLO text,
    **weighted by loop trip counts**: XLA emits a while-loop body once, so a
    collective inside the layer scan must count n_periods times. We read
    the ``known_trip_count`` backend config off each while op and propagate
    multipliers through nested loops/calls."""
    # 1. split into computations; collect per-computation collectives + edges
    comp = None
    per_comp: Dict[str, Dict] = {}
    edges: Dict[str, list] = {}
    for line in hlo_text.splitlines():
        stripped = line.strip()
        if not stripped:
            continue
        if not line.startswith(" ") and (stripped.startswith("%")
                                         or stripped.startswith("ENTRY")):
            m = _COMP_RE.match(stripped)
            if m:
                comp = m.group(1)
                per_comp.setdefault(
                    comp, {k: {"count": 0, "bytes": 0} for k in _COLLECTIVES})
                edges.setdefault(comp, [])
                continue
        if comp is None:
            continue
        wm = _WHILE_RE.search(stripped)
        if wm:
            tm = _TRIP_RE.search(stripped)
            trips = int(tm.group(1)) if tm else 1
            edges[comp].append((wm.group(1), trips))
        cm = _CALL_RE.search(stripped)
        if cm:
            edges[comp].append((cm.group(1), 1))
        m = re.search(r"=\s*[^=]*?\b(" + "|".join(_COLLECTIVES)
                      + r")(?:-start|-done)?\(", stripped)
        if not m or "-done(" in stripped:
            continue
        kind = m.group(1)
        paren = stripped[stripped.index("(", m.start()):]
        nbytes = sum(_shape_bytes(d, s) for d, s in _SHAPE_RE.findall(paren))
        per_comp[comp][kind]["count"] += 1
        per_comp[comp][kind]["bytes"] += nbytes

    # 2. propagate multipliers from every root (computations nobody calls)
    called = {child for es in edges.values() for child, _ in es}
    mult: Dict[str, float] = {}
    roots = [c for c in per_comp if c not in called]
    stack = [(r, 1.0) for r in roots]
    while stack:
        c, m = stack.pop()
        if mult.get(c, 0) >= m:
            continue
        mult[c] = max(mult.get(c, 0.0), m)
        for child, trips in edges.get(c, []):
            stack.append((child, m * trips))

    out = {k: {"count": 0, "bytes": 0} for k in _COLLECTIVES}
    for c, stats in per_comp.items():
        f = mult.get(c, 1.0)
        for k in _COLLECTIVES:
            out[k]["count"] += int(stats[k]["count"] * f)
            out[k]["bytes"] += int(stats[k]["bytes"] * f)
    out["total_bytes"] = sum(out[k]["bytes"] for k in _COLLECTIVES)
    out["total_count"] = sum(out[k]["count"] for k in _COLLECTIVES)
    return out


def roofline_terms(compiled, n_chips: int, model_flops: float = None) -> Dict:
    """cost_analysis() of the SPMD partitioned program reports PER-DEVICE
    flops/bytes (verified against 6*N*D/chips for the dense archs); the
    optimized HLO text likewise shows per-device shard shapes. Terms are
    therefore per-chip work over per-chip capability:

        compute_s    = flops_per_dev / peak
        memory_s     = bytes_per_dev / HBM_bw
        collective_s = collective_bytes_per_dev / link_bw
    """
    cost = compiled.cost_analysis() or {}
    flops = float(cost.get("flops", 0.0))
    nbytes = float(cost.get("bytes accessed", 0.0))
    try:
        hlo = compiled.as_text()
    except Exception:
        hlo = ""
    coll = collective_stats(hlo)
    terms = {
        "hlo_flops_per_dev": flops,
        "hlo_bytes_per_dev": nbytes,
        "collective_bytes_per_dev": coll["total_bytes"],
        "collective_ops": {k: coll[k] for k in _COLLECTIVES},
        "compute_s": flops / PEAK_FLOPS,
        "memory_s": nbytes / HBM_BW,
        "collective_s": coll["total_bytes"] / LINK_BW,
    }
    dom = max(("compute_s", "memory_s", "collective_s"),
              key=lambda k: terms[k])
    terms["dominant"] = dom.replace("_s", "")
    if model_flops:
        mf_dev = model_flops / n_chips
        terms["model_flops"] = model_flops
        terms["useful_fraction"] = mf_dev / flops if flops else 0.0
        # roofline fraction: useful model FLOPs over the time implied by the
        # dominant term (what fraction of peak the step achieves)
        t_bound = max(terms["compute_s"], terms["memory_s"],
                      terms["collective_s"])
        if t_bound > 0:
            terms["roofline_fraction"] = mf_dev / (t_bound * PEAK_FLOPS)
    return terms


def terms_from_counts(flops: float, nbytes: float, coll_bytes: float,
                      n_chips: int, model_flops: float = None) -> Dict:
    """Roofline terms from (per-device) op counts — used with the
    depth-extrapolated exact costs (dryrun.probe_costs)."""
    terms = {
        "hlo_flops_per_dev": flops,
        "hlo_bytes_per_dev": nbytes,
        "collective_bytes_per_dev": coll_bytes,
        "compute_s": flops / PEAK_FLOPS,
        "memory_s": nbytes / HBM_BW,
        "collective_s": coll_bytes / LINK_BW,
    }
    dom = max(("compute_s", "memory_s", "collective_s"),
              key=lambda k: terms[k])
    terms["dominant"] = dom.replace("_s", "")
    if model_flops:
        mf_dev = model_flops / n_chips
        terms["model_flops"] = model_flops
        terms["useful_fraction"] = mf_dev / flops if flops else 0.0
        t_bound = max(terms["compute_s"], terms["memory_s"],
                      terms["collective_s"])
        if t_bound > 0:
            terms["roofline_fraction"] = mf_dev / (t_bound * PEAK_FLOPS)
    return terms


def memory_per_device(compiled) -> Dict:
    try:
        ma = compiled.memory_analysis()
    except Exception:
        ma = None
    if ma is None:
        return {}
    out = {}
    for attr in ("argument_size_in_bytes", "output_size_in_bytes",
                 "temp_size_in_bytes", "generated_code_size_in_bytes",
                 "alias_size_in_bytes"):
        if hasattr(ma, attr):
            out[attr] = getattr(ma, attr)
    out["total_hbm_bytes"] = (out.get("argument_size_in_bytes", 0)
                              + out.get("output_size_in_bytes", 0)
                              + out.get("temp_size_in_bytes", 0)
                              - out.get("alias_size_in_bytes", 0))
    return out


def train_model_flops(n_active_params: float, tokens: float) -> float:
    return 6.0 * n_active_params * tokens


def decode_model_flops(n_active_params: float, tokens: float,
                       kv_read_flops: float = 0.0) -> float:
    return 2.0 * n_active_params * tokens + kv_read_flops
