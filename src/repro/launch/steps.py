"""Jitted train_step / serve_step builders with explicit shardings.

``train_step``: loss -> grad -> clip -> optimizer update, donated state.
``serve_step``: one decode step against a KV cache (donated).
Both are what the multi-pod dry-run lowers and compiles per (arch x shape
x mesh) cell.
"""
from __future__ import annotations

import functools
from typing import Dict, Optional

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from ..configs.base import ModelConfig, ShapeSpec
from ..models import _flags
from ..models.registry import build_model, input_specs
from ..optim import clip_by_global_norm, get_optimizer
from ..pipeline.cache import COMPILATION_CACHE
from . import sharding as shd


def mesh_signature(mesh) -> tuple:
    """Structural mesh identity for compilation-cache keys: two meshes
    over the same devices/axes produce interchangeable lowerings."""
    # device ids restart at 0 per platform, so the platform is part of
    # the identity (cpu:0 != tpu:0)
    return (tuple(mesh.axis_names), tuple(mesh.devices.shape),
            tuple((getattr(d, "platform", ""), int(d.id))
                  for d in mesh.devices.flat))


def abstract_params(model, cfg: ModelConfig):
    return jax.eval_shape(lambda k: model.init(k), jax.random.PRNGKey(0))


def make_train_step(cfg: ModelConfig, mesh, *, optimizer_name: str = None,
                    clip_norm: float = 1.0, remat: bool = True):
    """Returns (step_fn, state_shardings, batch_shardings, abstract_state).

    step(state, batch) -> (state, metrics); jit with shardings + donation.
    """
    model = build_model(cfg)
    model.remat = remat
    opt = get_optimizer(optimizer_name or cfg.optimizer)
    a_params = abstract_params(model, cfg)
    a_opt = jax.eval_shape(opt.init, a_params)
    a_state = {"params": a_params, "opt": a_opt,
               "step": jax.ShapeDtypeStruct((), jnp.int32)}

    p_specs = shd.param_pspecs(cfg, a_params, mesh)
    o_specs = shd.opt_pspecs(opt.name, a_params, p_specs)
    state_specs = {"params": p_specs, "opt": o_specs, "step": P()}
    state_shardings = shd.to_shardings(state_specs, mesh)

    def step(state, batch):
        loss, grads = jax.value_and_grad(model.loss)(state["params"], batch)
        grads, gnorm = clip_by_global_norm(grads, clip_norm)
        new_params, new_opt = opt.update(grads, state["opt"],
                                         state["params"], state["step"])
        new_state = {"params": new_params, "opt": new_opt,
                     "step": state["step"] + 1}
        return new_state, {"loss": loss, "grad_norm": gnorm}

    return step, state_shardings, a_state, model, opt


def make_serve_step(cfg: ModelConfig, mesh, batch: int, max_seq: int):
    """Returns (step_fn, cache_shardings, abstract_cache, model).

    serve_step(params, cache, tokens) -> (logits, cache): one new token
    against a KV cache of max_seq (the decode_* / long_* shapes)."""
    model = build_model(cfg)
    a_params = abstract_params(model, cfg)
    p_specs = shd.param_pspecs(cfg, a_params, mesh)
    a_cache = jax.eval_shape(
        functools.partial(model.init_cache, batch, max_seq))
    c_specs = shd.cache_pspecs(cfg, a_cache, mesh)
    param_shardings = shd.to_shardings(p_specs, mesh)
    cache_shardings = shd.to_shardings(c_specs, mesh)

    def serve_step(params, cache, tokens):
        return model.decode_step(params, cache, tokens)

    return serve_step, param_shardings, cache_shardings, a_params, a_cache, \
        model


def _maybe_axis(n: int, axis: str, mesh):
    sizes = shd.mesh_axis_sizes(mesh)
    return axis if axis in sizes and n % sizes[axis] == 0 else None


def lower_cell(cfg: ModelConfig, shape: ShapeSpec, mesh, remat: bool = True):
    """Lower (not compile) one (arch x shape) cell on a mesh. Returns the
    jax ``Lowered`` plus metadata. Used by dryrun.py and the roofline.

    Served from the process-wide compilation cache when the same
    (config x shape x mesh x flags) cell was lowered before — repeated
    sweep cells (dry-run re-runs, probe variants) become free."""
    key = ("lower_cell", repr(cfg), repr(shape), mesh_signature(mesh),
           bool(remat), bool(_flags.UNROLL_SCANS))
    cached = COMPILATION_CACHE.lookup(key)
    if cached is not None:
        return cached
    with jax.sharding.set_mesh(mesh):
        lowered = _lower_cell_inner(cfg, shape, mesh, remat)
    return COMPILATION_CACHE.store(key, lowered)


def _lower_cell_inner(cfg: ModelConfig, shape: ShapeSpec, mesh,
                      remat: bool = True):
    specs = input_specs(cfg, shape)
    if shape.kind in ("train", "prefill"):
        step, state_shardings, a_state, model, _ = make_train_step(
            cfg, mesh, remat=remat)
        b_specs = shd.batch_pspecs(cfg, specs, mesh)
        b_shardings = shd.to_shardings(b_specs, mesh)
        if shape.kind == "prefill":
            # inference prefill: forward only (logits), no optimizer
            def fwd(params, batch):
                logits, _ = model.forward(params, batch)
                return logits
            vocab_p = -(-cfg.vocab // 256) * 256
            fn = jax.jit(
                fwd,
                in_shardings=(state_shardings["params"], b_shardings),
                out_shardings=NamedSharding(mesh, P(
                    shd.batch_axes(shape.global_batch, mesh), None,
                    _maybe_axis(vocab_p, "model", mesh))))
            lowered = fn.lower(a_state["params"], specs)
        else:
            fn = jax.jit(step,
                         in_shardings=(state_shardings, b_shardings),
                         out_shardings=(state_shardings,
                                        NamedSharding(mesh, P())),
                         donate_argnums=(0,))
            lowered = fn.lower(a_state, specs)
        return lowered
    # decode shapes
    serve_step, param_sh, cache_sh, a_params, a_cache, model = \
        make_serve_step(cfg, mesh, shape.global_batch, shape.seq_len)
    vocab_p = -(-cfg.vocab // 256) * 256
    tok_sh = NamedSharding(mesh, P(
        shd.batch_axes(shape.global_batch, mesh), None))
    logits_sh = NamedSharding(mesh, P(
        shd.batch_axes(shape.global_batch, mesh), None,
        _maybe_axis(vocab_p, "model", mesh)))
    fn = jax.jit(serve_step,
                 in_shardings=(param_sh, cache_sh, tok_sh),
                 out_shardings=(logits_sh, cache_sh),
                 donate_argnums=(1,))
    tokens = jax.ShapeDtypeStruct((shape.global_batch, 1), jnp.int32)
    return fn.lower(a_params, a_cache, tokens)
