"""Launcher: meshes, sharding rules, train/serve steps, multi-pod dry-run."""
from .mesh import make_production_mesh, make_smoke_mesh

__all__ = ["make_production_mesh", "make_smoke_mesh"]
