"""BLAS frontend ops (paper §3.1): emit BLAS Library Nodes.

Mirrors the paper's Fig.-9 usage: ``blas.axpy(a,x,y)``, ``blas.dot(z,w)``,
plus the GEMVER constituents (Ger, Gemv) and Gemm."""
from __future__ import annotations

from ..library.blas import Axpy, Dot, Gemm, Gemv, Ger
from .api import Program, TensorHandle


def axpy(a: TensorHandle, x: TensorHandle, y: TensorHandle) -> TensorHandle:
    p = x.program
    return p.add_op(Axpy(p.fresh_label("axpy")), {"a": a, "x": x, "y": y},
                    {"z": x.shape})


def dot(x: TensorHandle, w: TensorHandle) -> TensorHandle:
    p = x.program
    return p.add_op(Dot(p.fresh_label("dot")), {"x": x, "w": w},
                    {"result": (1,)})


def ger(A: TensorHandle, x: TensorHandle, y: TensorHandle,
        alpha: float = 1.0) -> TensorHandle:
    p = A.program
    return p.add_op(Ger(p.fresh_label("ger"), alpha=alpha),
                    {"A": A, "x": x, "y": y}, {"Aout": A.shape})


def gemv(A: TensorHandle, x: TensorHandle, y0: TensorHandle = None,
         trans: bool = False, alpha: float = 1.0, beta: float = 0.0
         ) -> TensorHandle:
    p = A.program
    n, m = A.shape
    out_shape = (m,) if trans else (n,)
    ins = {"A": A, "x": x}
    if beta != 0.0 and y0 is not None:
        ins["y0"] = y0
    return p.add_op(Gemv(p.fresh_label("gemv"), trans=trans, alpha=alpha,
                         beta=beta), ins, {"y": out_shape})


def gemm(A: TensorHandle, B: TensorHandle) -> TensorHandle:
    p = A.program
    n, k = A.shape
    k2, m = B.shape
    return p.add_op(Gemm(p.fresh_label("gemm")), {"A": A, "B": B},
                    {"C": (n, m)})
