"""BLAS frontend ops (paper §3.1): emit BLAS Library Nodes.

Mirrors the paper's Fig.-9 usage: ``blas.axpy(a,x,y)``, ``blas.dot(z,w)``,
plus the GEMVER constituents (Ger, Gemv) and Gemm."""
from __future__ import annotations

import itertools

from ..library.blas import Axpy, Dot, Gemm, Gemv, Ger
from .api import Program, TensorHandle

_count = itertools.count()


def _n(base):
    return f"{base}{next(_count)}"


def axpy(a: TensorHandle, x: TensorHandle, y: TensorHandle) -> TensorHandle:
    p = x.program
    return p.add_op(Axpy(_n("axpy")), {"a": a, "x": x, "y": y},
                    {"z": x.shape})


def dot(x: TensorHandle, w: TensorHandle) -> TensorHandle:
    p = x.program
    return p.add_op(Dot(_n("dot")), {"x": x, "w": w}, {"result": (1,)})


def ger(A: TensorHandle, x: TensorHandle, y: TensorHandle,
        alpha: float = 1.0) -> TensorHandle:
    p = A.program
    return p.add_op(Ger(_n("ger"), alpha=alpha), {"A": A, "x": x, "y": y},
                    {"Aout": A.shape})


def gemv(A: TensorHandle, x: TensorHandle, y0: TensorHandle = None,
         trans: bool = False, alpha: float = 1.0, beta: float = 0.0
         ) -> TensorHandle:
    p = A.program
    n, m = A.shape
    out_shape = (m,) if trans else (n,)
    ins = {"A": A, "x": x}
    if beta != 0.0 and y0 is not None:
        ins["y0"] = y0
    return p.add_op(Gemv(_n("gemv"), trans=trans, alpha=alpha, beta=beta),
                    ins, {"y": out_shape})


def gemm(A: TensorHandle, B: TensorHandle) -> TensorHandle:
    p = A.program
    n, k = A.shape
    k2, m = B.shape
    return p.add_op(Gemm(_n("gemm")), {"A": A, "B": B}, {"C": (n, m)})
