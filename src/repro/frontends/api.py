"""Python frontend (paper §3.1): build SDFGs from numpy-like programs.

The paper's frontend parses Python/NumPy with BLAS extensions; here we
provide the equivalent builder API plus a ``@dc_program`` decorator:

    @dc_program
    def axpydot(p, n=dc_symbol("n")):
        x = p.input("x", (n,), "float32")
        y = p.input("y", (n,), "float32")
        w = p.input("w", (n,), "float32")
        a = p.scalar_input("a", "float32")
        z = blas.axpy(a, x, y)
        r = blas.dot(z, w)
        p.output("result", r)

    axpydot.lower(n=1024).optimize([...]).compile(backend="pallas")

``@dc_program`` returns a ``pipeline.Wrapped`` stage: calling it builds
the raw SDFG; ``.lower()`` enters the staged Wrapped -> Lowered ->
Compiled flow (ARCHITECTURE.md). Handles track access nodes; each op
appends Library Nodes to the current state, exchanging data through
(initially off-chip) transient arrays — the 'unoptimized SDFG' the
mid-level transformations then rewrite.
"""
from __future__ import annotations

import itertools
from typing import Optional, Sequence, Tuple, Union

from ..core.dtypes import StorageType
from ..core.memlet import Memlet
from ..core.sdfg import AccessNode, LibraryNode, SDFG, State
from ..core.symbolic import Expr, ExprLike, sym


class TensorHandle:
    def __init__(self, program: "Program", name: str, shape: Tuple[Expr, ...],
                 dtype: str, node: Optional[AccessNode] = None):
        self.program = program
        self.name = name
        self.shape = shape
        self.dtype = dtype
        self._node = node

    @property
    def node(self) -> AccessNode:
        if self._node is None:
            self._node = self.program.state.add_access(self.name)
        return self._node

    def read_node(self) -> AccessNode:
        return self.node

    def fresh_write_node(self) -> AccessNode:
        self._node = self.program.state.add_access(self.name)
        return self._node

    def __repr__(self):
        return f"TensorHandle({self.name}{list(self.shape)}:{self.dtype})"


class Program:
    """SDFG builder with a single (extendable) dataflow state."""

    def __init__(self, name: str):
        self.sdfg = SDFG(name)
        self.state = self.sdfg.add_state("main", is_start=True)
        self._tmp = itertools.count()
        self._label_counts: dict = {}

    def fresh_label(self, base: str) -> str:
        """Program-local deterministic labels (``axpy0``, ``axpy1``, ...):
        two identical builds produce identical labels, so their SDFGs
        content-hash equal and share one compilation-cache entry."""
        k = self._label_counts.get(base, 0)
        self._label_counts[base] = k + 1
        return f"{base}{k}"

    # -- containers ------------------------------------------------------
    def input(self, name: str, shape: Sequence[ExprLike], dtype="float32"
              ) -> TensorHandle:
        self.sdfg.add_array(name, shape, dtype)
        return TensorHandle(self, name,
                            tuple(Expr.wrap(s) for s in shape), dtype)

    def scalar_input(self, name: str, dtype="float32") -> TensorHandle:
        self.sdfg.add_scalar(name, dtype)
        return TensorHandle(self, name, (), dtype)

    def temp(self, shape: Sequence[ExprLike], dtype="float32",
             name: str = None) -> TensorHandle:
        name = name or f"tmp{next(self._tmp)}"
        self.sdfg.add_transient(name, shape, dtype)
        return TensorHandle(self, name,
                            tuple(Expr.wrap(s) for s in shape), dtype)

    def output(self, name: str, value: TensorHandle) -> TensorHandle:
        """Promote a temp to a named program output."""
        if value.name in self.sdfg.arrays and value.name == name:
            self.sdfg.arrays[name].transient = False
            return value
        desc = self.sdfg.arrays[value.name]
        desc.transient = False
        # rename container to the requested name
        if name != value.name:
            if name in self.sdfg.arrays:
                raise ValueError(
                    f"cannot rename {value.name!r} to output {name!r}: a "
                    f"container named {name!r} already exists in the "
                    "program; pick a fresh output name or write into the "
                    "existing container explicitly")
            self.sdfg.arrays[name] = self.sdfg.arrays.pop(value.name)
            for st in self.sdfg.states:
                for n in st.data_nodes():
                    if n.data == value.name:
                        n.data = name
                        n.label = name
                for e in st.edges:
                    if e.memlet.data == value.name:
                        e.memlet.data = name
            value.name = name
        return value

    # -- op plumbing -------------------------------------------------------
    def add_op(self, node: LibraryNode,
               inputs: dict, out_shapes: dict, out_dtypes: dict = None
               ) -> Union[TensorHandle, Tuple[TensorHandle, ...]]:
        """Wire a library node: inputs are TensorHandles keyed by connector;
        outputs become fresh transients."""
        st = self.state
        st.add_node(node)
        for conn, h in inputs.items():
            st.add_edge(h.read_node(), None, node, conn,
                        Memlet.simple(h.name))
        outs = []
        for conn in node.outputs:
            shape = out_shapes[conn]
            dtype = (out_dtypes or {}).get(conn) or \
                next(iter(inputs.values())).dtype
            h = self.temp(shape, dtype, name=f"{node.label}_{conn}")
            st.add_edge(node, conn, h.fresh_write_node(), None,
                        Memlet.simple(h.name))
            outs.append(h)
        return outs[0] if len(outs) == 1 else tuple(outs)

    # -- finalize ---------------------------------------------------------
    def finalize(self) -> SDFG:
        self.sdfg.validate()
        return self.sdfg


def dc_program(fn):
    """Decorator: fn(program, ...) builds; returns a traceable
    ``pipeline.Wrapped`` stage. Calling the result traces the builder and
    returns the raw SDFG; ``.lower(**symbol_bindings)`` returns a
    ``Lowered`` stage for ``.optimize(...)`` / ``.compile(backend=...)``."""
    from ..pipeline.stages import Wrapped

    def factory(*args, **kwargs) -> SDFG:
        p = Program(fn.__name__)
        fn(p, *args, **kwargs)
        return p.finalize()
    factory.__name__ = fn.__name__
    # symbol-binding split inspects the builder's own signature, not the
    # factory wrapper's (*args/**kwargs would swallow everything)
    factory.__signature__ = _builder_signature(fn)
    return Wrapped(factory, name=fn.__name__)


def _builder_signature(fn):
    """Signature of ``fn`` minus its leading Program parameter."""
    import inspect
    sig = inspect.signature(fn)
    params = list(sig.parameters.values())[1:]
    return sig.replace(parameters=params)
