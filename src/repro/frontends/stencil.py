"""StencilFlow-like JSON frontend (paper §6.1, Fig. 17).

Parses the paper's JSON program description — dimensions, inputs, outputs,
and per-operator ``computation`` strings like

    "b = c0*a[j,k] + c1*a[j-1,k] + c2*a[j+1,k] + c3*a[j,k-1] + c4*a[j,k+1]"

— maps the dependencies between operators, and emits an SDFG of Stencil
Library Nodes chained through (initially off-chip) transient arrays. The
mid-level transformations then stream the chain; the Pallas backend fuses
it into one multi-stage kernel (the deadlock-free fully-pipelined
architecture; delay buffers become VMEM halos, DESIGN.md §2).
"""
from __future__ import annotations

import re
from typing import Dict, List, Tuple

from ..core.memlet import Memlet
from ..core.sdfg import SDFG
from ..core.dtypes import StorageType
from ..library.stencil import Stencil
from .api import Program

_TERM = re.compile(
    r"(?P<coeff>[A-Za-z_]\w*|[-+]?\d*\.?\d+)\s*\*\s*"
    r"(?P<arr>[A-Za-z_]\w*)\s*\[\s*j\s*(?P<dj>[-+]\s*\d+)?\s*,"
    r"\s*k\s*(?P<dk>[-+]\s*\d+)?\s*\]")


def parse_computation(expr: str) -> Tuple[str, str, List[Tuple[int, int]],
                                          List[str]]:
    """'b = c0*a[j,k] + c1*a[j-1,k] ...' -> (out, in_array, offsets, coeffs)."""
    lhs, rhs = expr.split("=", 1)
    out = lhs.strip()
    offsets, coeffs, arrays = [], [], set()
    for m in _TERM.finditer(rhs):
        dj = int((m.group("dj") or "0").replace(" ", ""))
        dk = int((m.group("dk") or "0").replace(" ", ""))
        offsets.append((dj, dk))
        coeffs.append(m.group("coeff"))
        arrays.add(m.group("arr"))
    if len(arrays) != 1:
        raise ValueError(f"stencil must read exactly one array: {expr!r}")
    return out, arrays.pop(), offsets, coeffs


def build_stencil_program(spec: Dict) -> SDFG:
    """Build an SDFG from a (paper Fig.-17 style) program description."""
    H, W = spec["dimensions"]
    dtype = "float32"
    p = Program(spec.get("name", "stencilflow"))

    handles = {}
    coeff_handles = {}
    for name, meta in spec.get("inputs", {}).items():
        if meta.get("input_dims"):
            handles[name] = p.input(name, (H, W), meta.get("data_type",
                                                           dtype))
        else:
            coeff_handles[name] = None  # scalar coefficient

    # operator dependency order: an op is ready when its input exists
    ops = dict(spec["program"])
    order = []
    produced = set(handles)
    while ops:
        progress = False
        for out_name, op in list(ops.items()):
            _, in_arr, _, _ = parse_computation(op["computation"])
            if in_arr in produced:
                order.append((out_name, op))
                produced.add(out_name)
                del ops[out_name]
                progress = True
        if not progress:
            raise ValueError("cyclic or unsatisfiable stencil dependencies")

    outputs = set(spec.get("outputs", []))
    for out_name, op in order:
        target, in_arr, offsets, coeff_names = parse_computation(
            op["computation"])
        # coefficient vector input (one per stencil op)
        from .api import TensorHandle
        cvec = f"{out_name}_coeffs"
        p.sdfg.add_array(cvec, (len(coeff_names),), dtype)
        c_h = TensorHandle(p, cvec, (len(coeff_names),), dtype)
        node = Stencil(f"stencil_{out_name}", offsets, coeff_names)
        res = p.add_op(node, {"a": handles[in_arr], "c": c_h},
                       {"b": (H, W)})
        handles[out_name] = res
        if out_name in outputs:
            p.output(out_name, res)
    return p.finalize()
