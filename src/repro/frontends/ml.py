"""ML frontend (paper §5): build the LeNet-5 inference SDFG.

The paper imports a PyTorch module through ONNX; we define the identical
network natively (paper Fig. 15 architecture: conv(1->6,5) - relu - pool -
conv(6->16,5) - relu - pool - flatten - fc(256->120) - relu - fc(120->84) -
relu - fc(84->10) - softmax) as a chain of Library Nodes. Parameters are
inputs until ``InputToConstant`` bakes them into the program.
"""
from __future__ import annotations

from typing import Dict

import numpy as np

from ..core.sdfg import SDFG
from ..library.nn import Conv2d, Flatten, Linear, MaxPool2d, Relu, Softmax
from .api import Program, TensorHandle

LENET_SHAPES = {
    "conv1_W": (6, 1, 5, 5), "conv1_b": (6,),
    "conv2_W": (16, 6, 5, 5), "conv2_b": (16,),
    "fc1_W": (120, 256), "fc1_b": (120,),
    "fc2_W": (84, 120), "fc2_b": (84,),
    "fc3_W": (10, 84), "fc3_b": (10,),
}


def init_lenet_params(seed: int = 0) -> Dict[str, np.ndarray]:
    rng = np.random.default_rng(seed)
    params = {}
    for name, shape in LENET_SHAPES.items():
        fan_in = int(np.prod(shape[1:])) if len(shape) > 1 else shape[0]
        scale = 1.0 / np.sqrt(max(fan_in, 1))
        params[name] = (rng.standard_normal(shape) * scale).astype(np.float32)
    return params


def build_lenet(batch: int = 1000, fuse_activation: bool = True) -> SDFG:
    """LeNet-5 inference SDFG for 28x28 single-channel inputs."""
    p = Program("lenet5")
    x = p.input("x", (batch, 1, 28, 28))
    params = {name: p.input(name, shape)
              for name, shape in LENET_SHAPES.items()}

    def conv(x, w, b, act):
        n, c, h, ww = x.shape
        k, _, r, s = w.shape
        oh = int((h - r).as_int() + 1) if hasattr(h, "as_int") else h - r + 1
        # shapes here are Expr; evaluate statically
        from ..core.symbolic import Expr
        h_i = Expr.wrap(h).as_int()
        w_i = Expr.wrap(ww).as_int()
        r_i = Expr.wrap(r).as_int()
        s_i = Expr.wrap(s).as_int()
        node = Conv2d(f"conv_{w.name}", activation="relu" if act and
                      fuse_activation else None)
        y = p.add_op(node, {"x": x, "W": w, "b": b},
                     {"y": (batch, Expr.wrap(k).as_int(),
                            h_i - r_i + 1, w_i - s_i + 1)})
        if act and not fuse_activation:
            y = p.add_op(Relu(f"relu_{w.name}"), {"x": y}, {"y": y.shape})
        return y

    def pool(x, window=2):
        n, c, h, w = [s if isinstance(s, int) else s.as_int()
                      for s in x.shape]
        return p.add_op(MaxPool2d(f"pool_{x.name}", window), {"x": x},
                        {"y": (n, c, h // window, w // window)})

    def linear(x, w, b, act, name):
        out = w.shape[0].as_int() if hasattr(w.shape[0], "as_int") \
            else w.shape[0]
        node = Linear(f"fc_{name}", activation="relu" if act and
                      fuse_activation else None)
        y = p.add_op(node, {"x": x, "W": w, "b": b}, {"y": (batch, out)})
        if act and not fuse_activation:
            y = p.add_op(Relu(f"relu_{name}"), {"x": y}, {"y": y.shape})
        return y

    h = conv(x, params["conv1_W"], params["conv1_b"], act=True)   # 6x24x24
    h = pool(h)                                                   # 6x12x12
    h = conv(h, params["conv2_W"], params["conv2_b"], act=True)   # 16x8x8
    h = pool(h)                                                   # 16x4x4
    h = p.add_op(Flatten("flatten"), {"x": h}, {"y": (batch, 256)})
    h = linear(h, params["fc1_W"], params["fc1_b"], act=True, name="fc1")
    h = linear(h, params["fc2_W"], params["fc2_b"], act=True, name="fc2")
    h = linear(h, params["fc3_W"], params["fc3_b"], act=False, name="fc3")
    out = p.add_op(Softmax("softmax"), {"x": h}, {"y": (batch, 10)})
    p.output("probs", out)
    return p.finalize()


def lenet_reference(params: Dict[str, np.ndarray], x: np.ndarray):
    """Independent jnp oracle for LeNet-5 inference."""
    import jax
    import jax.numpy as jnp

    def conv(x, W, b):
        y = jax.lax.conv_general_dilated(
            x, W, (1, 1), "VALID", dimension_numbers=("NCHW", "OIHW", "NCHW"))
        return y + b[None, :, None, None]

    def pool(x):
        return jax.lax.reduce_window(x, -jnp.inf, jax.lax.max,
                                     (1, 1, 2, 2), (1, 1, 2, 2), "VALID")

    h = pool(jnp.maximum(conv(x, params["conv1_W"], params["conv1_b"]), 0))
    h = pool(jnp.maximum(conv(h, params["conv2_W"], params["conv2_b"]), 0))
    h = h.reshape(h.shape[0], -1)
    h = jnp.maximum(h @ params["fc1_W"].T + params["fc1_b"], 0)
    h = jnp.maximum(h @ params["fc2_W"].T + params["fc2_b"], 0)
    h = h @ params["fc3_W"].T + params["fc3_b"]
    return jax.nn.softmax(h, axis=-1)
