"""Pipeline fusion for the Pallas backend (DESIGN.md §3.3).

After StreamingComposition converts intermediate HBM arrays into streams,
this codegen pass finds maximal chains of Library Nodes connected through
stream containers and — when the chain matches a registered fused-kernel
pattern — replaces the whole chain with a single tasklet calling a fused
Pallas kernel. The stream's data then lives in VMEM for its entire
lifetime: the TPU realization of the paper's 'PEs chained by FIFOs'.

Unmatched chains still compile (each node expands on its own and the stream
materializes), mirroring the paper's fallback to generic expansions.
"""
from __future__ import annotations

from typing import Callable, Dict, List, Tuple

from ..core.sdfg import AccessNode, LibraryNode, SDFG, State, Stream, Tasklet

#: (tuple of LibraryNode type names) -> factory(nodes, sdfg, state, interpret)
#: returning (fn, input_conns, output_conns). Registered by repro.kernels.
FUSION_REGISTRY: Dict[Tuple[str, ...], Callable] = {}


def register_fusion(pattern: Tuple[str, ...]):
    def deco(factory):
        FUSION_REGISTRY[pattern] = factory
        return factory
    return deco


def _stream_chains(state: State, sdfg: SDFG) -> List[List[LibraryNode]]:
    """Maximal linear chains L0 -stream-> L1 -stream-> ... of library nodes."""
    def nodes_of(container: str):
        return [n for n in state.nodes
                if isinstance(n, AccessNode) and n.data == container]

    def stream_successor(node):
        """Producer -> (its stream nodes) -> consumer library node, possibly
        through a consumer-side access node of the same container."""
        for e in state.out_edges(node):
            if isinstance(e.dst, AccessNode) and isinstance(
                    sdfg.arrays[e.dst.data], Stream):
                for an in nodes_of(e.dst.data):
                    for oe in state.out_edges(an):
                        if isinstance(oe.dst, LibraryNode):
                            return e.dst.data, oe.dst
        return None, None

    def stream_predecessor(node):
        for e in state.in_edges(node):
            if isinstance(e.src, AccessNode) and isinstance(
                    sdfg.arrays[e.src.data], Stream):
                for an in nodes_of(e.src.data):
                    for ie in state.in_edges(an):
                        if isinstance(ie.src, LibraryNode):
                            return ie.src
        return None

    chains = []
    seen = set()
    for node in state.nodes:
        if not isinstance(node, LibraryNode) or node in seen:
            continue
        if stream_predecessor(node) is not None:
            continue  # not a chain head
        chain = [node]
        cur = node
        while True:
            _, nxt = stream_successor(cur)
            if nxt is None or nxt in seen:
                break
            chain.append(nxt)
            cur = nxt
        for n in chain:
            seen.add(n)
        if len(chain) > 1:
            chains.append(chain)
    return chains


def fuse_stream_pipelines(sdfg: SDFG, interpret: bool = True) -> List[str]:
    fused = []
    for state in sdfg.states:
        for full_chain in _stream_chains(state, sdfg):
            # greedy longest-sub-chain matching: a long streamed pipeline
            # may contain several registered fusable segments
            segments = []
            i = 0
            names = [type(n).__name__ for n in full_chain]
            while i < len(full_chain):
                best = None
                for j in range(len(full_chain), i + 1, -1):
                    if tuple(names[i:j]) in FUSION_REGISTRY:
                        best = j
                        break
                if best is None:
                    i += 1
                else:
                    segments.append(full_chain[i:best])
                    i = best
            for chain in segments:
                fused.extend(_fuse_one(sdfg, state, chain, interpret))
    return fused


def _fuse_one(sdfg: SDFG, state: State, chain, interpret) -> List[str]:
    key = tuple(type(n).__name__ for n in chain)
    factory = FUSION_REGISTRY.get(key)
    if factory is None:
        return []
    chain_set = set(chain)
    intermediates = set()
    for i, node in enumerate(chain[:-1]):
        for e in state.out_edges(node):
            if isinstance(e.dst, AccessNode) and isinstance(
                    sdfg.arrays[e.dst.data], Stream):
                # both producer- and consumer-side nodes
                for an in state.nodes:
                    if isinstance(an, AccessNode) and an.data == e.dst.data:
                        intermediates.add(an)
    # external edges and their fused-tasklet connector names
    in_map, out_map = {}, {}
    ext_in, ext_out = [], []
    for node in chain:
        for e in state.in_edges(node):
            if e.src in intermediates or e.src in chain_set:
                continue
            conn = f"{node.label}__{e.dst_conn}"
            in_map[(node.label, e.dst_conn)] = conn
            ext_in.append((e, conn))
        for e in state.out_edges(node):
            if e.dst in intermediates or e.dst in chain_set:
                continue
            conn = f"{node.label}__{e.src_conn}"
            out_map[(node.label, e.src_conn)] = conn
            ext_out.append((e, conn))
    fn = factory(chain, sdfg, state, interpret, in_map, out_map)
    t = state.add_tasklet("fused_" + "_".join(key).lower(),
                          [c for _, c in ext_in],
                          [c for _, c in ext_out], fn)
    for e, conn in ext_in:
        state.add_edge(e.src, e.src_conn, t, conn, e.memlet)
    for e, conn in ext_out:
        state.add_edge(t, conn, e.dst, e.dst_conn, e.memlet)
    for node in chain:
        state.remove_node(node)
    for an in intermediates:
        if an in state.graph and state.in_degree(an) == 0 \
                and state.out_degree(an) == 0:
            state.remove_node(an)
    return ["+".join(key)]
