"""Compile SDFGs to executable JAX callables.

Mirrors the paper's backend split (§2.1): one generic traversal
(jnp_backend's structural interpretation), with the two 'vendors':

  * ``backend='jnp'``    -- XLA-auto: expansion preference (xla, generic);
                            XLA fuses/pipelines (the Intel-OpenCL analogue).
  * ``backend='pallas'`` -- explicit: pipeline-fusion pass first replaces
                            stream-connected Library-Node chains with fused
                            Pallas kernels, then prefers (pallas, xla,
                            generic) expansions (the Vivado-HLS analogue).

Both produce the same function semantics; tests cross-validate them.
"""
from __future__ import annotations

from typing import Optional

import jax

from ..core.sdfg import SDFG
from . import jnp_backend

BACKENDS = ("jnp", "pallas")


class CompiledSDFG:
    def __init__(self, sdfg: SDFG, fn, jitted, backend: str, report: dict):
        self.sdfg = sdfg
        self.fn = fn
        self.jitted = jitted
        self.backend = backend
        self.report = report

    def __call__(self, **kwargs):
        return self.jitted(**kwargs) if self.jitted is not None else self.fn(**kwargs)

    def lower(self, **kwargs):
        return jax.jit(self.fn).lower(**kwargs)


def compile_sdfg(sdfg: SDFG, backend: str = "jnp", jit: bool = True,
                 interpret: bool = True,
                 expansion_level: Optional[str] = None) -> CompiledSDFG:
    if backend not in BACKENDS:
        raise ValueError(f"unknown backend {backend!r}; choose from {BACKENDS}")
    report = {"backend": backend, "fused_regions": [], "expansions": []}

    sdfg.validate()
    if backend == "pallas":
        sdfg.expansion_preference = ("pallas", "xla", "generic")
        sdfg.metadata["pallas_interpret"] = interpret
        from .pipeline_fusion import fuse_stream_pipelines
        report["fused_regions"] = fuse_stream_pipelines(sdfg, interpret=interpret)
    else:
        sdfg.expansion_preference = ("xla", "generic")

    report["expansions"] = sdfg.expand_library_nodes(level=expansion_level)
    sdfg.validate()

    fn = jnp_backend.build_callable(sdfg)
    jitted = jax.jit(fn) if jit else None
    return CompiledSDFG(sdfg, fn, jitted, backend, report)
