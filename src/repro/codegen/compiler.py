"""Legacy one-shot compile entry point, now a shim over the staged
pipeline (repro.pipeline): ``compile_sdfg(s, ...)`` is exactly
``pipeline.lower(s).compile(..., in_place=True)``.

The backend split (paper §2.1) lives in ``pipeline.passes
.default_pipeline``: ``jnp`` prefers (xla, generic) expansions and lets
XLA fuse (the Intel-OpenCL analogue); ``pallas`` runs pipeline-fusion
first and prefers (pallas, xla, generic) (the Vivado-HLS analogue). Both
produce the same function semantics; tests cross-validate them.

``in_place=True`` preserves the historical contract that the caller's
SDFG is expanded by compilation (callers inspect the lowered graph);
staged callers get a pristine ``Lowered`` plus a private compiled copy.
In-place compiles deliberately bypass ``pipeline.COMPILATION_CACHE`` —
the produced callable would alias the caller's live graph and a hit
would skip the in-place expansion — so only the staged path
(``Lowered.compile``) is served from the cache.
"""
from __future__ import annotations

from typing import Optional

from ..core.sdfg import SDFG
from ..pipeline.stages import BACKENDS, Compiled, Lowered

#: compat alias: the executable stage used to be defined here.
CompiledSDFG = Compiled


def compile_sdfg(sdfg: SDFG, backend: str = "jnp", jit: bool = True,
                 interpret: bool = True,
                 expansion_level: Optional[str] = None) -> Compiled:
    return Lowered(sdfg).compile(
        backend=backend, jit=jit, interpret=interpret,
        expansion_level=expansion_level, in_place=True)
