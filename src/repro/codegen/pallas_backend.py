"""The explicit Pallas backend: native grid codegen for SDFG map scopes.

Where the XLA-auto backend (jnp_backend) structurally *interprets* map
scopes — vmap for mapped tasklets, trace-time Python loops otherwise,
capped at ``SEQUENTIAL_TRIP_LIMIT`` — this backend lowers eligible
DEVICE/PIPELINED map scopes directly to a single ``pl.pallas_call`` grid
kernel, the way the paper's code generator emits complete platform
kernels from the dataflow IR:

  * the ``grid`` comes from the map ranges (tile-counter parameters after
    MapTiling; every parameter of an untiled map);
  * each memlet's affine subset is factored by
    :func:`core.memlet.factor_subset` into ``block_shape`` + an
    ``index_map`` over grid coordinates — exactly a Pallas ``BlockSpec``.
    Intra-tile parameters (MapTiling annotations) widen index dimensions
    into VMEM-resident blocks — multi-dimensional after multi-parameter
    tiling, e.g. an (8, 128) sublane×lane tile. Block-misaligned affine
    accesses (stencil halo offsets) degrade to element-addressed
    *windows*: the whole container dimension rides in VMEM and the kernel
    body slices the window per grid step. Operands whose blocks coincide
    are deduplicated into one VMEM buffer;
  * write-conflict-resolution ``add``/``max``/``min`` memlets whose index
    map ignores some grid dimensions become VMEM scratch accumulators
    (zeros / running extrema) with ``@pl.when(k == 0)`` init and a flush
    on the last reduction step — the pattern hand-written in
    ``kernels/gemm/kernel.py``. Reduction dimensions are ordered
    innermost so the output block stays resident across the accumulation;
  * scopes may hold a *chain* of tasklets (the result of MapFusion):
    tasklet->tasklet edges carry per-iteration transients that never
    materialize — they thread through the kernel body as local values,
    so a fused producer->consumer map pair is one launch with zero HBM
    intermediates;
  * tasklet bodies whose operands are all scalar-per-iteration apply
    **once to the whole block** (array-level ops on the (8, 128) tile) —
    an abstract-shape trace (``jax.eval_shape``) verifies the body is
    elementwise (results broadcast to the tile shape) before the fast
    path is taken; genuinely scalar-indexed or slice-consuming bodies
    keep the nested per-element ``vmap`` over the intra-tile parameters;
  * partial final tiles (ceil-division MapTiling of non-divisible
    extents) are masked: Pallas itself drops the out-of-bounds region of
    boundary blocks, and reduced lanes are masked to the wcr identity
    in-kernel before accumulation.

Maps whose memlets are non-affine, dynamic, strided, or misaligned beyond
what windows express are left un-annotated by ``GridConversionPass`` and
fall back to the shared structural-interpreter lowering — mirroring the
paper's fallback to generic expansions.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from ..core.dtypes import ScheduleType
from ..core.memlet import (BlockFactorError, SubsetFactorization,
                           eval_affine, factor_subset)
from ..core.sdfg import (MapEntry, MapExit, Scalar, SDFG, State, Stream,
                         Tasklet)
from ..transforms.map_tiling import normalize_tiling
from .common import (WCR_MODES, _apply_wcr, wcr_combine, wcr_identity,
                     wcr_reduce)
from .jnp_backend import StateLowering, build_callable as _build_callable

#: annotation key GridConversionPass writes and this backend consumes.
GRID_ANNOTATION = "pallas_grid"


@dataclass(frozen=True)
class EdgeSpec:
    """One tasklet edge lowered to a Pallas operand."""
    conn: str
    data: str
    fact: SubsetFactorization
    scalar: bool = False                       # 0-d container, carried as (1,)
    wcr: Optional[str] = None                  # outputs only
    reduction: Tuple[str, ...] = ()            # grid params absent from index
    box: Tuple[Tuple[int, int], ...] = ()      # written element range per dim
    node: int = 0                              # owning tasklet (chain index)


@dataclass(frozen=True)
class WcrValueSpec:
    """One in-kernel reduction value (MapFusion's wcr mode): a
    tasklet->tasklet edge carrying ``wcr`` accumulates into a VMEM scratch
    across the ``reduction`` grid steps; the consumer side of the chain
    runs once, on the last step, with the finished value."""
    key: Tuple[int, str]            # (producer chain index, src connector)
    wcr: str
    dtype: str                      # numpy dtype name for the scratch
    reduction: Tuple[str, ...]      # grid params accumulated across steps
    kept_intra: Tuple[str, ...]     # intra-tile params addressing the value


@dataclass(frozen=True)
class GridSpec:
    """Complete derived grid-kernel description for one map scope."""
    kernel_name: str
    grid: Tuple[Tuple[str, int], ...]          # (param, size) in grid order
    block_params: Tuple[Tuple[str, int], ...]  # intra-tile params + extents
    inputs: Tuple[EdgeSpec, ...]
    outputs: Tuple[EdgeSpec, ...]
    tasklet_labels: Tuple[str, ...] = ()       # topo-ordered chain labels
    #: (intra param, counter param, tile, extent) for non-divisible tiles
    partial_tiles: Tuple[Tuple[str, str, int, int], ...] = ()
    #: tasklet->tasklet edges inside the scope (fused-DAG intermediates
    #: threaded as in-kernel values; the cost model charges VMEM for them)
    internal_edges: int = 0
    #: in-kernel wcr edges (two-phase accumulate+consume kernels)
    internal_wcr: Tuple[WcrValueSpec, ...] = ()
    #: chain indices of the consumer phase (run on the last reduction step)
    phase2_nodes: Tuple[int, ...] = ()


def _scalar_fact() -> SubsetFactorization:
    from ..core.symbolic import Expr
    return SubsetFactorization((1,), (Expr.const(0),), (0,))


def operand_key(es: EdgeSpec) -> Tuple:
    """Dedup key for input operands: everything BlockSpec-relevant.
    Windows are per-edge (sliced in-kernel) and deliberately excluded, so
    a stencil's five halo reads of one container share one VMEM buffer
    when their blocks coincide."""
    return (es.data, es.scalar, es.fact.block_shape,
            tuple(repr(e) for e in es.fact.index_exprs),
            es.fact.squeeze_dims, es.fact.param_dims)


def unique_operands(spec: GridSpec) -> List[EdgeSpec]:
    """Representative EdgeSpec per deduplicated input operand."""
    seen, reps = {}, []
    for es in spec.inputs:
        k = operand_key(es)
        if k not in seen:
            seen[k] = len(reps)
            reps.append(es)
    return reps


def _tasklet_chain(state: State, entry: MapEntry, scopes) -> List[Tasklet]:
    """Topologically-ordered tasklets of the scope; raises when the scope
    holds anything else (nested maps, access nodes, ...)."""
    inner = [n for n in scopes.get(entry, []) if not isinstance(n, MapExit)]
    if not inner or not all(isinstance(n, Tasklet) for n in inner):
        raise BlockFactorError(
            f"map {entry.map.label!r}: grid codegen requires a tasklet-only "
            f"scope, got {[type(n).__name__ for n in inner]}")
    inner_set = set(inner)
    return [n for n in state.topological_nodes() if n in inner_set]


def _output_box(fact: SubsetFactorization, grid: Dict[str, Tuple[int, int]],
                label: str, dim_sizes: Tuple[int, ...],
                valid_extents: Dict[str, int]) -> Tuple[Tuple[int, int], ...]:
    """Element-range box written by an output across the whole grid,
    clamped to the container and to the *valid* extent of partial tiles;
    also verifies full coverage inside the box (each dim's block index
    must be a constant or ``param + const`` with a param used by no other
    dim; a window must step by exactly its length)."""
    box = []
    seen_params = set()
    win = {d: (e, ln) for d, e, ln in fact.windows}
    pd_inv = {d: q for q, d in fact.param_dims}
    for d, bs in enumerate(fact.block_shape):
        dim_sz = dim_sizes[d] if d < len(dim_sizes) else bs
        if d in win:
            e, ln = win[d]
            c0, syms = 0, {}
            for mono, c in e.terms.items():
                if mono == ():
                    c0 = int(c)
                else:
                    syms[mono[0][0]] = int(c)
            if not syms:
                box.append((c0, min(c0 + ln, dim_sz)))
                continue
            if len(syms) > 1 or set(syms) & seen_params:
                raise BlockFactorError(
                    f"output of {label!r}: window dim {d} start {e} not "
                    f"contiguously covered across the grid")
            (g, cg), = syms.items()
            if cg != ln:
                raise BlockFactorError(
                    f"output of {label!r}: window dim {d} steps by {cg} "
                    f"but spans {ln} elements")
            seen_params.add(g)
            n = grid[g][1]
            hi = c0 + (n - 1) * ln + ln
            if pd_inv.get(d) in valid_extents:
                hi = min(hi, c0 + valid_extents[pd_inv[d]])
            box.append((c0, min(hi, dim_sz)))
            continue
        e = fact.index_exprs[d]
        c0 = 0
        syms = {}
        for mono, c in e.terms.items():
            if mono == ():
                c0 = int(c)
            else:
                syms[mono[0][0]] = c
        if not syms:
            span = valid_extents.get(pd_inv.get(d), bs)
            box.append((c0 * bs, min(c0 * bs + span, dim_sz)))
            continue
        if len(syms) > 1 or set(syms) & seen_params:
            raise BlockFactorError(
                f"output of {label!r}: dim {d} index {e} not contiguously "
                f"covered across the grid")
        (g, cg), = syms.items()
        if cg != 1:
            raise BlockFactorError(
                f"output of {label!r}: dim {d} strides blocks by {cg}")
        seen_params.add(g)
        n = grid[g][1]
        hi = (c0 + n - 1) * bs + bs
        if pd_inv.get(d) in valid_extents:
            hi = min(hi, c0 * bs + valid_extents[pd_inv[d]])
        box.append((c0 * bs, min(hi, dim_sz)))
    return tuple(box)


def analyze_map_scope(sdfg: SDFG, state: State, entry: MapEntry,
                      scopes=None, env: Optional[Dict[str, int]] = None
                      ) -> GridSpec:
    """Derive a :class:`GridSpec` for a map scope, or raise
    :class:`BlockFactorError` when the scope must fall back to the
    structural interpreter."""
    m = entry.map
    if m.schedule not in (ScheduleType.PIPELINED, ScheduleType.DEVICE):
        raise BlockFactorError(
            f"map {m.label!r}: schedule {m.schedule.value} is not a grid")
    scopes = scopes if scopes is not None else state.scope_children()
    chain = _tasklet_chain(state, entry, scopes)
    chain_index = {t: i for i, t in enumerate(chain)}
    env = dict(sdfg.symbol_values) if env is None else dict(env)

    tiling = normalize_tiling(m.annotations.get("tiling", {}))
    grid_params: Dict[str, Tuple[int, int]] = {}
    block_params: Dict[str, int] = {}
    partials: List[Tuple[str, str, int, int]] = []
    valid_extents: Dict[str, int] = {}
    for p, r in zip(m.params, m.ranges):
        try:
            start, size = r.start.subs(env).as_int(), r.size.subs(env).as_int()
        except Exception as exc:
            raise BlockFactorError(
                f"map {m.label!r}: dynamic range for {p}") from exc
        if size < 1:
            raise BlockFactorError(f"map {m.label!r}: empty range for {p}")
        if p in tiling and size > 1:
            info = tiling[p]
            if start != 0 or size != int(info["tile"]):
                raise BlockFactorError(
                    f"map {m.label!r}: tile param {p} range [{start}, "
                    f"+{size}) disagrees with tiling annotation "
                    f"{info['tile']}")
            block_params[p] = size
            ext = info.get("extent")
            if ext is not None:
                valid_extents[p] = int(ext)
                if int(ext) % size:
                    ctr = info.get("counter")
                    if ctr is None or ctr not in m.params:
                        raise BlockFactorError(
                            f"map {m.label!r}: partial tile {p} has no "
                            f"counter to mask against")
                    partials.append((p, ctr, size, int(ext)))
        else:
            grid_params[p] = (start, size)
    if not grid_params:
        raise BlockFactorError(f"map {m.label!r}: no grid parameters")
    partial_qs = {q for q, _, _, _ in partials}
    partial_counters = {c for _, c, _, _ in partials}

    def _factor(memlet):
        if memlet.dynamic:
            raise BlockFactorError(f"dynamic memlet {memlet}")
        if memlet.data not in sdfg.arrays:
            raise BlockFactorError(f"no descriptor for {memlet.data!r}")
        desc = sdfg.arrays[memlet.data]
        if isinstance(desc, Stream):
            raise BlockFactorError(f"stream operand {memlet.data!r}")
        if isinstance(desc, Scalar) or not getattr(desc, "shape", ()):
            return _scalar_fact(), True, (1,)
        fact = factor_subset(memlet.subset, desc.shape, grid_params,
                             block_params, env, allow_windows=True)
        from ..core.symbolic import Expr
        dim_sizes = tuple(int(Expr.wrap(s).evaluate(env))
                          for s in desc.shape)
        # a window whose start depends on a partial tile's counter would
        # clamp-shift at the boundary block: fall back instead
        for d, expr, ln in fact.windows:
            if expr.free_symbols & partial_counters:
                raise BlockFactorError(
                    f"window on {memlet.data!r} dim {d} rides the partial "
                    f"tile counter {sorted(expr.free_symbols & partial_counters)}")
            pdq = {dd: q for q, dd in fact.param_dims}.get(d)
            if pdq in partial_qs:
                raise BlockFactorError(
                    f"window on {memlet.data!r} dim {d} spans partial "
                    f"tile param {pdq}")
        return fact, False, tuple(dim_sizes)

    inputs = []
    out_edge_list = []  # (chain index, edge)
    internal_vals = set()  # distinct in-kernel values: a fan-out producer
    wcr_edge_list = []  # (producer chain index, edge) for in-kernel wcr
    for ti, t in enumerate(chain):    # value is stored once, not per reader
        for e in state.in_edges(t):
            if e.dst_conn is None or e.memlet.data is None:
                continue
            if e.src in chain_index:
                # per-iteration intermediate, threaded as a local value;
                # wcr edges additionally accumulate across the reduction
                # steps (two-phase kernel, analyzed below)
                if e.memlet.wcr is not None:
                    wcr_edge_list.append((chain_index[e.src], e))
                internal_vals.add((chain_index[e.src], e.src_conn))
                continue
            fact, scalar, _ = _factor(e.memlet)
            inputs.append(EdgeSpec(e.dst_conn, e.memlet.data, fact, scalar,
                                   node=ti))
        for e in state.out_edges(t):
            if e.dst in chain_index:
                continue
            if e.memlet.data is None:
                continue
            out_edge_list.append((ti, e))

    if not out_edge_list:
        raise BlockFactorError(f"map {m.label!r}: no kernel outputs")
    used_any: List[str] = []
    outs_raw = []
    for ti, e in out_edge_list:
        if e.memlet.wcr is not None and e.memlet.wcr not in WCR_MODES:
            raise BlockFactorError(
                f"map {m.label!r}: wcr {e.memlet.wcr!r} unsupported")
        fact, scalar, dim_sizes = _factor(e.memlet)
        box = _output_box(fact, grid_params, m.label, dim_sizes,
                          valid_extents)
        used = set()
        for ex in fact.index_exprs:
            used |= ex.free_symbols
        for _, wexpr, _ in fact.windows:
            used |= wexpr.free_symbols
        if e.memlet.wcr is None:
            # a partial tile lane absent from a plain output would make the
            # garbage lane the "last write": fall back
            pd = dict(fact.param_dims)
            for q in partial_qs:
                if q not in pd:
                    raise BlockFactorError(
                        f"map {m.label!r}: partial tile param {q} absent "
                        f"from plain output {e.memlet.data!r}")
        for p in m.params:
            if p in used and p in grid_params and p not in used_any:
                used_any.append(p)
        outs_raw.append((ti, e, fact, scalar, box, used))

    # grid order: output-indexing params first (original order), reduction
    # params innermost so scratch accumulators stay block-resident.
    order = [p for p in m.params if p in grid_params and p in used_any]
    order += [p for p in m.params if p in grid_params and p not in used_any]
    outputs = []
    for ti, e, fact, scalar, box, used in outs_raw:
        reduction = tuple(p for p in order if p not in used)
        if reduction and fact.windows:
            raise BlockFactorError(
                f"map {m.label!r}: windowed output {e.memlet.data!r} "
                f"cannot host a scratch reduction")
        # every reduction dim must iterate inside every used dim
        max_used = max((order.index(p) for p in order if p in used),
                       default=-1)
        if any(order.index(p) < max_used for p in reduction):
            raise BlockFactorError(
                f"map {m.label!r}: reduction params {reduction} cannot be "
                f"ordered innermost for output {e.memlet.data!r}")
        if e.memlet.wcr is None and reduction and not getattr(
                chain[ti], "side_effect_free", True):
            raise BlockFactorError(f"map {m.label!r}: side-effecting tasklet")
        outputs.append(EdgeSpec(e.src_conn, e.memlet.data, fact, scalar,
                                e.memlet.wcr, reduction, box, node=ti))

    internal_wcr: Tuple[WcrValueSpec, ...] = ()
    phase2_nodes: Tuple[int, ...] = ()
    if wcr_edge_list:
        internal_wcr, phase2_nodes = _analyze_internal_wcr(
            sdfg, state, m, chain, chain_index, wcr_edge_list, grid_params,
            block_params, order, used_any, inputs, outputs, out_edge_list)

    return GridSpec(
        kernel_name=m.label,
        grid=tuple((p, grid_params[p][1]) for p in order),
        block_params=tuple((p, block_params[p]) for p in m.params
                           if p in block_params),
        inputs=tuple(inputs), outputs=tuple(outputs),
        tasklet_labels=tuple(t.label for t in chain),
        partial_tiles=tuple(partials),
        internal_edges=len(internal_vals),
        internal_wcr=internal_wcr, phase2_nodes=phase2_nodes)


def _analyze_internal_wcr(sdfg, state, m, chain, chain_index, wcr_edge_list,
                          grid_params, block_params, order, used_any,
                          inputs, outputs, out_edge_list
                          ) -> Tuple[Tuple[WcrValueSpec, ...],
                                     Tuple[int, ...]]:
    """Legality analysis for in-kernel wcr edges (MapFusion's reduction
    mode) and derivation of the two-phase kernel structure; raises
    :class:`BlockFactorError` when the shape cannot be expressed, falling
    back to the structural interpreter (whose sequential/phased-vmap
    lowerings are always correct for these scopes)."""
    pset = set(m.params)
    used_sets = []
    for src_ti, e in wcr_edge_list:
        if e.memlet.wcr not in WCR_MODES:
            raise BlockFactorError(
                f"map {m.label!r}: in-kernel wcr {e.memlet.wcr!r} "
                f"unsupported")
        if e.memlet.subset is None:
            raise BlockFactorError(
                f"map {m.label!r}: in-kernel wcr edge without a subset")
        used = set()
        for r in e.memlet.subset:
            used |= ((r.start.free_symbols | r.stop.free_symbols) & pset)
        used_sets.append(used)
    kept = used_sets[0]
    if any(u != kept for u in used_sets):
        raise BlockFactorError(
            f"map {m.label!r}: in-kernel wcr edges disagree on reduction "
            f"parameters")
    kept_grid = kept & set(grid_params)
    kept_intra = kept & set(block_params)
    reduction = tuple(p for p in order if p not in kept)
    red_intra = {q for q in block_params if q not in kept_intra}
    if not reduction:
        raise BlockFactorError(
            f"map {m.label!r}: in-kernel wcr with no grid reduction step")
    if kept_grid - set(used_any):
        raise BlockFactorError(
            f"map {m.label!r}: reduction-addressing params "
            f"{sorted(kept_grid - set(used_any))} absent from every output")

    # consumer phase: everything downstream of a wcr edge
    phase2 = set()
    work = [chain_index[e.dst] for _, e in wcr_edge_list]
    while work:
        ti = work.pop()
        if ti in phase2:
            continue
        phase2.add(ti)
        for e in state.out_edges(chain[ti]):
            if e.dst in chain_index:
                work.append(chain_index[e.dst])
    for ti, t in enumerate(chain):
        if ti in phase2:
            continue
        for e in state.out_edges(t):
            if (e.dst in chain_index and chain_index[e.dst] in phase2
                    and e.memlet.wcr is None):
                raise BlockFactorError(
                    f"map {m.label!r}: plain producer->consumer edge "
                    f"alongside an in-kernel wcr edge")
    for ti, e in out_edge_list:
        if ti not in phase2:
            raise BlockFactorError(
                f"map {m.label!r}: reduction producer also writes through "
                f"the exit")
    red_syms = set(reduction) | red_intra
    for es in outputs:
        if es.wcr is not None:
            raise BlockFactorError(
                f"map {m.label!r}: wcr output downstream of an in-kernel "
                f"reduction")
        _check_phase_free(m, es, red_syms, red_intra, "output")
    for es in inputs:
        if es.node in phase2:
            _check_phase_free(m, es, red_syms, red_intra, "consumer input")

    specs, seen = [], set()
    for src_ti, e in wcr_edge_list:
        key = (src_ti, e.src_conn)
        if key in seen:
            continue
        seen.add(key)
        desc = sdfg.arrays.get(e.memlet.data)
        if desc is None:
            raise BlockFactorError(
                f"map {m.label!r}: no descriptor for in-kernel wcr "
                f"intermediate {e.memlet.data!r}")
        specs.append(WcrValueSpec(
            key=key, wcr=e.memlet.wcr,
            dtype=str(desc.dtype.np_dtype.__name__
                      if hasattr(desc.dtype.np_dtype, "__name__")
                      else desc.dtype.np_dtype),
            reduction=reduction,
            kept_intra=tuple(q for q in block_params if q in kept_intra)))
    return tuple(specs), tuple(sorted(phase2))


def _check_phase_free(m, es: EdgeSpec, red_syms, red_intra, what: str):
    """A consumer-phase memlet must not address a reduction parameter —
    the consumer runs only on the last reduction step."""
    syms = set()
    for ex in es.fact.index_exprs:
        syms |= ex.free_symbols
    for _, wexpr, _ in es.fact.windows:
        syms |= wexpr.free_symbols
    if syms & red_syms or {q for q, _ in es.fact.param_dims} & red_intra:
        raise BlockFactorError(
            f"map {m.label!r}: {what} {es.data!r} addresses a reduction "
            f"parameter")


# ---------------------------------------------------------------------------
# Kernel emission
# ---------------------------------------------------------------------------


def _squeeze_adjusted_axis(fact: SubsetFactorization, dim: int) -> int:
    """Axis of ``dim`` in the loaded value after squeezing."""
    return dim - sum(1 for s in fact.squeeze_dims if s < dim)


def _conds(ids, positions, sizes, at_end: bool):
    conds = [ids[k] == (sizes[k] - 1 if at_end else 0) for k in positions]
    return functools.reduce(jnp.logical_and, conds)


class PallasStateLowering(StateLowering):
    """State lowering that emits ``pl.pallas_call`` grid kernels for map
    scopes annotated by ``GridConversionPass`` and shares the structural
    interpreter for everything else."""

    def _lower_map_custom(self, entry: MapEntry, exit_: MapExit,
                          inner: List) -> bool:
        spec: Optional[GridSpec] = entry.map.annotations.get(GRID_ANNOTATION)
        if spec is None:
            return False
        if not inner or not all(isinstance(n, Tasklet) for n in inner):
            return False
        inner_set = set(inner)
        chain = [n for n in self.state.topological_nodes() if n in inner_set]
        labels = tuple(t.label for t in chain)
        if spec.tasklet_labels and labels != spec.tasklet_labels:
            return False  # stale annotation: graph changed under the spec
        if spec.internal_wcr:
            self._emit_two_phase(entry, chain, spec)
        else:
            self._emit_grid_kernel(entry, chain, spec)
        return True

    # ------------------------------------------------------------------
    def _chain_runner(self, chain: List[Tasklet], spec: GridSpec):
        """Build ``chain_call(opvals) -> results`` running the topo-ordered
        tasklet chain with container operands from ``opvals`` (keyed by
        input-edge index) and tasklet->tasklet values as locals."""
        chain_index = {t: i for i, t in enumerate(chain)}
        int_in: List[List[Tuple[str, Tuple[int, str]]]] = []
        out_binds: List[List[Tuple[str, str, object]]] = []
        for ti, t in enumerate(chain):
            ints = []
            for e in self.state.in_edges(t):
                if e.src in chain_index:
                    ints.append((e.dst_conn,
                                 (chain_index[e.src], e.src_conn)))
            int_in.append(ints)
            out_binds.append([])
        for oi, es in enumerate(spec.outputs):
            out_binds[es.node].append((es.conn, "result", oi))
        for ti, t in enumerate(chain):
            for e in self.state.out_edges(t):
                if e.dst in chain_index:
                    out_binds[ti].append((e.src_conn, "local",
                                          (ti, e.src_conn)))
        fns = [t.fn for t in chain]
        decl_outputs = [list(getattr(t, "outputs", ())) for t in chain]
        n_out = len(spec.outputs)

        def chain_call(opvals):
            local = {}
            results = [None] * n_out
            for ti in range(len(chain)):
                kwargs = {}
                for i, es in enumerate(spec.inputs):
                    if es.node == ti:
                        kwargs[es.conn] = opvals[i]
                for conn, key in int_in[ti]:
                    kwargs[conn] = local[key]
                r = fns[ti](**kwargs)
                conns = [c for c, _, _ in out_binds[ti]]
                if not isinstance(r, dict):
                    if isinstance(r, tuple):
                        r = dict(zip(decl_outputs[ti] or conns, r))
                    else:
                        r = {conns[0]: r}
                for conn, kind, ref in out_binds[ti]:
                    if kind == "local":
                        local[ref] = r[conn]
                    else:
                        results[ref] = r[conn]
            return tuple(results)

        return chain_call

    def _whole_block_eligible(self, spec: GridSpec, chain_call,
                              chain: List[Tasklet]) -> bool:
        """True when every operand is scalar-per-iteration (all non-tile
        effective dims are size 1) AND the chain is verifiably
        elementwise: an abstract-shape trace confirms every result
        broadcasts to the tile shape, and a concrete probe on random
        block data checks the whole-block application against the
        per-element (nested vmap) semantics — a shape trace alone cannot
        reject bodies like ``lambda a: jnp.sum(a)`` whose scalar result
        still broadcasts. Slice-consuming, shape-changing, or
        value-diverging bodies keep the per-element nested vmap."""
        import numpy as np
        if not spec.block_params:
            return False
        if not all(getattr(t, "side_effect_free", True) for t in chain):
            return False
        block_order = [q for q, _ in spec.block_params]
        bp = dict(spec.block_params)
        tile_shape = tuple(n for _, n in spec.block_params)
        for es in list(spec.inputs) + list(spec.outputs):
            pdims = set(dict(es.fact.param_dims).values())
            for d, n in enumerate(es.fact.effective_shape()):
                if n != 1 and d not in pdims:
                    return False
        rng = np.random.default_rng(2025)
        padded, unpadded = {}, {}
        for i, es in enumerate(spec.inputs):
            pd = dict(es.fact.param_dims)
            present = tuple(bp[q] for q in block_order if q in pd)
            desc = self.sdfg.arrays.get(es.data)
            dt = np.dtype(desc.dtype.np_dtype if desc is not None
                          else np.float32)
            if np.issubdtype(dt, np.inexact):
                base = rng.standard_normal(present).astype(dt)
            elif dt == np.bool_:
                base = rng.integers(0, 2, present).astype(dt)
            else:
                base = rng.integers(1, 8, present).astype(dt)
            unpadded[i] = jnp.asarray(base)
            padded[i] = jnp.reshape(
                unpadded[i],
                tuple(bp[q] if q in pd else 1 for q in block_order))
        try:
            results = jax.eval_shape(chain_call, padded)
            for r in results:
                if jnp.broadcast_shapes(tuple(r.shape),
                                        tile_shape) != tile_shape:
                    return False
            # the emit may be running under an outer jit trace, where ops
            # on concrete arrays are staged as tracers; the probe needs
            # real values at trace time
            with jax.ensure_compile_time_eval():
                whole = [jnp.broadcast_to(jnp.asarray(r), tile_shape)
                         for r in chain_call(padded)]
                f = chain_call
                for q in reversed(block_order):
                    axes = {i: (0 if q in dict(es.fact.param_dims)
                                else None)
                            for i, es in enumerate(spec.inputs)}
                    f = jax.vmap(f, in_axes=(axes,), out_axes=0)
                ref = [jnp.broadcast_to(jnp.asarray(r), tile_shape)
                       for r in f(unpadded)]
                return all(
                    np.allclose(np.asarray(w), np.asarray(r), rtol=1e-5,
                                atol=1e-6, equal_nan=True)
                    for w, r in zip(whole, ref))
        except Exception:
            return False

    # ------------------------------------------------------------------
    def _emit_grid_kernel(self, entry: MapEntry, chain: List[Tasklet],
                          spec: GridSpec):
        interpret = self.sdfg.metadata.get("pallas_interpret", True)
        grid_names = [p for p, _ in spec.grid]
        grid_sizes = tuple(n for _, n in spec.grid)
        block_order = [q for q, _ in spec.block_params]
        bp = dict(spec.block_params)
        tile_shape = tuple(n for _, n in spec.block_params)

        op_reps = unique_operands(spec)
        op_index = {operand_key(es): i for i, es in enumerate(op_reps)}
        op_of_edge = [op_index[operand_key(es)] for es in spec.inputs]

        in_vals = []
        for es in op_reps:
            v = jnp.asarray(self.ensure_value(es.data))
            if es.scalar:
                v = jnp.reshape(v, (1,))
            in_vals.append(v)
        in_specs = [pl.BlockSpec(es.fact.block_shape,
                                 es.fact.index_map(grid_names))
                    for es in op_reps]

        prev_vals, out_specs, out_shapes = [], [], []
        scratch_shapes, scratch_index = [], {}
        for oi, es in enumerate(spec.outputs):
            pv = jnp.asarray(self.ensure_value(es.data))
            if es.scalar:
                pv = jnp.reshape(pv, (1,))
            prev_vals.append(pv)
            out_specs.append(pl.BlockSpec(es.fact.block_shape,
                                          es.fact.index_map(grid_names)))
            out_shapes.append(jax.ShapeDtypeStruct(pv.shape, pv.dtype))
            if es.wcr in WCR_MODES and es.reduction:
                scratch_index[oi] = len(scratch_shapes)
                scratch_shapes.append(
                    pltpu.VMEM(es.fact.block_shape, pv.dtype))

        chain_call = self._chain_runner(chain, spec)
        whole_block = self._whole_block_eligible(spec, chain_call, chain)
        n_ops, n_out = len(op_reps), len(spec.outputs)

        def kernel(*refs):
            ins = refs[:n_ops]
            outs = refs[n_ops:n_ops + n_out]
            scratch = refs[n_ops + n_out:]
            ids = [pl.program_id(k) for k in range(len(grid_names))]
            id_env = dict(zip(grid_names, ids))
            opvals = self._load_operands(spec, ins, op_of_edge, block_order,
                                         id_env)

            if whole_block:
                # one array-level application over the whole tile: pad
                # every operand to rank len(block_order) (size-1 axes for
                # absent tile params) and let broadcasting do the rest
                bvals = {}
                for i, es in enumerate(spec.inputs):
                    pd = dict(es.fact.param_dims)
                    shape = tuple(bp[q] if q in pd else 1
                                  for q in block_order)
                    bvals[i] = jnp.reshape(opvals[i], shape)
                results = chain_call(bvals)
            elif block_order:
                f = chain_call
                for q in reversed(block_order):
                    axes = {i: (0 if q in dict(es.fact.param_dims) else None)
                            for i, es in enumerate(spec.inputs)}
                    f = jax.vmap(f, in_axes=(axes,), out_axes=0)
                results = f(opvals)
            else:
                results = chain_call(opvals)

            for oi, (es, oref) in enumerate(zip(spec.outputs, outs)):
                val = jnp.asarray(results[oi])
                if whole_block:
                    val = jnp.broadcast_to(val, tile_shape)
                if es.wcr in WCR_MODES and spec.partial_tiles:
                    # mask reduced padding lanes to the identity; lanes
                    # present in the output land in the block's OOB region
                    # and are dropped by Pallas itself
                    pd = dict(es.fact.param_dims)
                    for q, counter, ts, ext in spec.partial_tiles:
                        if q in pd:
                            continue
                        ax = block_order.index(q)
                        lane = jax.lax.broadcasted_iota(
                            jnp.int32, jnp.shape(val), ax)
                        gidx = ids[grid_names.index(counter)] * ts + lane
                        val = jnp.where(
                            gidx < ext, val,
                            wcr_identity(es.wcr, jnp.asarray(val).dtype))
                val = self._assemble_block(val, es, block_order)
                if es.fact.windows:
                    idx = [slice(None)] * len(es.fact.block_shape)
                    for d, expr, ln in es.fact.windows:
                        idx[d] = pl.ds(eval_affine(expr, id_env), ln)
                    oref[tuple(idx)] = val.astype(oref.dtype)
                elif es.wcr in WCR_MODES and es.reduction:
                    acc = scratch[scratch_index[oi]]
                    red_pos = [grid_names.index(p) for p in es.reduction]
                    first = _conds(ids, red_pos, grid_sizes, at_end=False)
                    last = _conds(ids, red_pos, grid_sizes, at_end=True)

                    @pl.when(first)
                    def _init(acc=acc, es=es):
                        acc[...] = jnp.full(
                            acc.shape, wcr_identity(es.wcr, acc.dtype))

                    acc[...] = wcr_combine(es.wcr, acc[...],
                                           val.astype(acc.dtype))

                    @pl.when(last)
                    def _flush(acc=acc, oref=oref):
                        oref[...] = acc[...].astype(oref.dtype)
                else:
                    oref[...] = val.astype(oref.dtype)

        results = pl.pallas_call(
            kernel, grid=grid_sizes, in_specs=in_specs, out_specs=out_specs,
            out_shape=out_shapes, scratch_shapes=scratch_shapes,
            interpret=interpret)(*in_vals)
        if not isinstance(results, (list, tuple)):
            results = (results,)
        self._stitch_results(spec, results)

    @staticmethod
    def _load_operands(spec: GridSpec, ins, op_of_edge, block_order, id_env):
        """Per-input-edge kernel values: dedup'd VMEM block, window slice,
        squeeze, tile axes moved to the front in block-param order."""
        raw = [ref[...] for ref in ins]
        opvals = {}
        for i, es in enumerate(spec.inputs):
            v = raw[op_of_edge[i]]
            for d, expr, ln in es.fact.windows:
                v = jax.lax.dynamic_slice_in_dim(
                    v, eval_affine(expr, id_env), ln, axis=d)
            if es.fact.squeeze_dims:
                v = jnp.squeeze(v, axis=es.fact.squeeze_dims)
            pd = dict(es.fact.param_dims)
            present = [q for q in block_order if q in pd]
            if present:  # tile axes to the front, in block-param order
                src = [_squeeze_adjusted_axis(es.fact, pd[q])
                       for q in present]
                v = jnp.moveaxis(v, src, list(range(len(src))))
            opvals[i] = v
        return opvals

    # ------------------------------------------------------------------
    def _phased_runners(self, chain: List[Tasklet], spec: GridSpec):
        """Split :meth:`_chain_runner` for two-phase kernels: phase 1
        (producer side) returns the per-iteration wcr contributions keyed
        by ``spec.internal_wcr`` order; phase 2 (consumer side) takes the
        finished accumulator values and returns the kernel outputs."""
        chain_index = {t: i for i, t in enumerate(chain)}
        p2 = set(spec.phase2_nodes)
        wcr_keys = [w.key for w in spec.internal_wcr]
        int_in: List[List[Tuple[str, Tuple[int, str]]]] = []
        int_out: List[List[Tuple[str, Tuple[int, str]]]] = []
        for ti, t in enumerate(chain):
            int_in.append([(e.dst_conn, (chain_index[e.src], e.src_conn))
                           for e in self.state.in_edges(t)
                           if e.src in chain_index])
            int_out.append([(e.src_conn, (ti, e.src_conn))
                            for e in self.state.out_edges(t)
                            if e.dst in chain_index])
        res_of = {}
        for oi, es in enumerate(spec.outputs):
            res_of.setdefault(es.node, []).append((es.conn, oi))
        fns = [t.fn for t in chain]
        decl_outputs = [list(getattr(t, "outputs", ())) for t in chain]
        n_out = len(spec.outputs)

        def _normalize(ti, r):
            if isinstance(r, dict):
                return r
            conns = [c for c, _ in int_out[ti]]
            conns += [c for c, _ in res_of.get(ti, ())]
            if isinstance(r, tuple):
                return dict(zip(decl_outputs[ti] or conns, r))
            return {conns[0]: r}

        def _run_phase(tis, opvals, local):
            results = [None] * n_out
            for ti in tis:
                kwargs = {}
                for i, es in enumerate(spec.inputs):
                    if es.node == ti:
                        kwargs[es.conn] = opvals[i]
                for conn, key in int_in[ti]:
                    kwargs[conn] = local[key]
                r = _normalize(ti, fns[ti](**kwargs))
                for conn, key in int_out[ti]:
                    if key not in local:  # an acc value stays accumulated
                        local[key] = r[conn]
                for conn, oi in res_of.get(ti, ()):
                    results[oi] = r[conn]
            return results

        p1_tis = [ti for ti in range(len(chain)) if ti not in p2]
        p2_tis = [ti for ti in range(len(chain)) if ti in p2]

        def chain1_call(opvals):
            local = {}
            _run_phase(p1_tis, opvals, local)
            return tuple(local[k] for k in wcr_keys)

        def chain2_call(opvals, accs):
            local = dict(zip(wcr_keys, accs))
            return tuple(_run_phase(p2_tis, opvals, local))

        return chain1_call, chain2_call

    def _emit_two_phase(self, entry: MapEntry, chain: List[Tasklet],
                        spec: GridSpec):
        """Two-phase grid kernel for scopes with in-kernel wcr edges: each
        grid step runs the producer phase over the whole tile, reduces the
        contribution over the intra-tile reduction axes, and accumulates it
        in a VMEM scratch; on the last reduction step the consumer phase
        runs once over the kept lattice with the finished values (the
        ``@pl.when`` phase flip of the hand-written reduction kernels)."""
        import numpy as np
        interpret = self.sdfg.metadata.get("pallas_interpret", True)
        grid_names = [p for p, _ in spec.grid]
        grid_sizes = tuple(n for _, n in spec.grid)
        block_order = [q for q, _ in spec.block_params]
        bp = dict(spec.block_params)
        tile_shape = tuple(n for _, n in spec.block_params)

        op_reps = unique_operands(spec)
        op_index = {operand_key(es): i for i, es in enumerate(op_reps)}
        op_of_edge = [op_index[operand_key(es)] for es in spec.inputs]

        in_vals, in_specs = [], []
        for es in op_reps:
            v = jnp.asarray(self.ensure_value(es.data))
            if es.scalar:
                v = jnp.reshape(v, (1,))
            in_vals.append(v)
            in_specs.append(pl.BlockSpec(es.fact.block_shape,
                                         es.fact.index_map(grid_names)))

        out_specs, out_shapes = [], []
        for es in spec.outputs:
            pv = jnp.asarray(self.ensure_value(es.data))
            if es.scalar:
                pv = jnp.reshape(pv, (1,))
            out_specs.append(pl.BlockSpec(es.fact.block_shape,
                                          es.fact.index_map(grid_names)))
            out_shapes.append(jax.ShapeDtypeStruct(pv.shape, pv.dtype))

        kept_intra = set(spec.internal_wcr[0].kept_intra)
        kept_order = [q for q in block_order if q in kept_intra]
        kept_shape = tuple(bp[q] for q in kept_order)
        red_axes = tuple(i for i, q in enumerate(block_order)
                         if q not in kept_intra)
        reduction = spec.internal_wcr[0].reduction
        scratch_shapes = [pltpu.VMEM(kept_shape or (1,), np.dtype(w.dtype))
                          for w in spec.internal_wcr]

        chain1_call, chain2_call = self._phased_runners(chain, spec)
        n_ops, n_out = len(op_reps), len(spec.outputs)

        def kernel(*refs):
            ins = refs[:n_ops]
            outs = refs[n_ops:n_ops + n_out]
            accs = refs[n_ops + n_out:]
            ids = [pl.program_id(k) for k in range(len(grid_names))]
            id_env = dict(zip(grid_names, ids))
            opvals = self._load_operands(spec, ins, op_of_edge, block_order,
                                         id_env)

            if block_order:
                f1 = chain1_call
                for q in reversed(block_order):
                    axes = {i: (0 if q in dict(es.fact.param_dims) else None)
                            for i, es in enumerate(spec.inputs)}
                    f1 = jax.vmap(f1, in_axes=(axes,), out_axes=0)
                vals1 = f1(opvals)
            else:
                vals1 = chain1_call(opvals)

            red_pos = [grid_names.index(p) for p in reduction]
            first = _conds(ids, red_pos, grid_sizes, at_end=False)
            last = _conds(ids, red_pos, grid_sizes, at_end=True)
            for w, acc, v in zip(spec.internal_wcr, accs, vals1):
                part = wcr_reduce(w.wcr, v, red_axes) if red_axes else v
                part = jnp.reshape(part, acc.shape)

                @pl.when(first)
                def _init(acc=acc, w=w):
                    acc[...] = jnp.full(acc.shape,
                                        wcr_identity(w.wcr, acc.dtype))

                acc[...] = wcr_combine(w.wcr, acc[...],
                                       part.astype(acc.dtype))

            @pl.when(last)
            def _consume():
                acc_vals = tuple(jnp.reshape(acc[...], kept_shape)
                                 for acc in accs)
                if kept_order:
                    f2 = chain2_call
                    for q in reversed(kept_order):
                        axes = {i: (0 if q in dict(es.fact.param_dims)
                                    else None)
                                for i, es in enumerate(spec.inputs)}
                        f2 = jax.vmap(f2, in_axes=(axes, 0), out_axes=0)
                    results = f2(opvals, acc_vals)
                else:
                    results = chain2_call(opvals, acc_vals)
                for oi, (es, oref) in enumerate(zip(spec.outputs, outs)):
                    val = jnp.asarray(results[oi])
                    if block_order:
                        # kept-lattice result -> full tile lattice (the
                        # broadcast lanes collapse again in assembly)
                        trail = val.shape[len(kept_order):]
                        val = jnp.reshape(
                            val, tuple(bp[q] if q in kept_intra else 1
                                       for q in block_order) + trail)
                        val = jnp.broadcast_to(val, tile_shape + trail)
                    val = self._assemble_block(val, es, block_order)
                    if es.fact.windows:
                        idx = [slice(None)] * len(es.fact.block_shape)
                        for d, expr, ln in es.fact.windows:
                            idx[d] = pl.ds(eval_affine(expr, id_env), ln)
                        oref[tuple(idx)] = val.astype(oref.dtype)
                    else:
                        oref[...] = val.astype(oref.dtype)

        results = pl.pallas_call(
            kernel, grid=grid_sizes, in_specs=in_specs, out_specs=out_specs,
            out_shape=out_shapes, scratch_shapes=scratch_shapes,
            interpret=interpret)(*in_vals)
        if not isinstance(results, (list, tuple)):
            results = (results,)
        self._stitch_results(spec, results)

    def _stitch_results(self, spec: GridSpec, results):
        """Stitch each written box into the prior container contents:
        grid kernels only define the blocks their index maps touch.
        Re-fetch per output: two edges may target the same container."""
        for es, new in zip(spec.outputs, results):
            prev = jnp.asarray(self.ensure_value(es.data))
            if es.scalar:
                prev = jnp.reshape(prev, (1,))
            sl = tuple(slice(lo, hi) for lo, hi in es.box)
            if es.wcr in WCR_MODES:
                cur = _apply_wcr(prev.at[sl], es.wcr, new[sl])
            elif all((lo, hi) == (0, s) for (lo, hi), s
                     in zip(es.box, prev.shape)):
                cur = new
            else:
                cur = prev.at[sl].set(new[sl])
            if es.scalar:
                cur = jnp.reshape(cur, ())
            self.env[es.data] = cur

    @staticmethod
    def _assemble_block(val, es: EdgeSpec, block_order: List[str]):
        """Rearrange a whole-block or (vmapped) tasklet result — leading
        axes one per intra-tile param, trailing axes the tasklet's own
        result dims — into the output's effective block shape."""
        pd = dict(es.fact.param_dims)
        eff = es.fact.effective_shape()
        absent = tuple(i for i, q in enumerate(block_order) if q not in pd)
        if absent:
            if es.wcr in WCR_MODES:  # intra-block reduction
                val = wcr_reduce(es.wcr, val, absent)
            else:  # revisited location: last write wins, as sequentially
                idx = tuple(-1 if i in absent else slice(None)
                            for i in range(len(block_order)))
                val = val[idx]
        present = [q for q in block_order if q in pd]
        nlead = len(present)
        trailing = list(range(nlead, jnp.ndim(val)))
        slice_dims = [d for d in range(len(eff))
                      if d not in pd.values() and eff[d] > 1]
        if len(trailing) == len(slice_dims) and (present or trailing):
            src_of = {pd[q]: i for i, q in enumerate(present)}
            src_of.update({d: t for d, t in zip(slice_dims, trailing)})
            perm = [src_of[d] for d in sorted(src_of)]
            val = jnp.transpose(val, perm)
        return jnp.reshape(val, eff)


def build_callable(sdfg: SDFG):
    """Build fn(**arrays) using the Pallas grid lowering strategy."""
    return _build_callable(sdfg, lowering=PallasStateLowering)
