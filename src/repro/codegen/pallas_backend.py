"""The explicit Pallas backend: native grid codegen for SDFG map scopes.

Where the XLA-auto backend (jnp_backend) structurally *interprets* map
scopes — vmap for mapped tasklets, trace-time Python loops otherwise,
capped at ``SEQUENTIAL_TRIP_LIMIT`` — this backend lowers eligible
DEVICE/PIPELINED map scopes directly to a single ``pl.pallas_call`` grid
kernel, the way the paper's code generator emits complete platform
kernels from the dataflow IR:

  * the ``grid`` comes from the map ranges (tile-counter parameters after
    MapTiling; every parameter of an untiled map);
  * each memlet's affine subset is factored by
    :func:`core.memlet.factor_subset` into ``block_shape`` + an
    ``index_map`` over grid coordinates — exactly a Pallas ``BlockSpec``.
    Intra-tile parameters (MapTiling annotations) widen index dimensions
    into VMEM-resident blocks;
  * write-conflict-resolution ``add``/``max``/``min`` memlets whose index
    map ignores some grid dimensions become VMEM scratch accumulators
    (zeros / running extrema) with ``@pl.when(k == 0)`` init and a flush
    on the last reduction step — the pattern hand-written in
    ``kernels/gemm/kernel.py``. Reduction dimensions are ordered
    innermost so the output block stays resident across the accumulation;
  * scopes may hold a *chain* of tasklets (the result of MapFusion):
    tasklet->tasklet edges carry per-iteration transients that never
    materialize — they thread through the kernel body as local values,
    so a fused producer->consumer map pair is one launch with zero HBM
    intermediates;
  * tasklet bodies are applied per-element via nested ``vmap`` over the
    intra-tile parameters, so scalar tasklets stay scalar semantics-wise
    while executing on whole blocks.

Maps whose memlets are non-affine, dynamic, strided, or misaligned are
left un-annotated by ``GridConversionPass`` and fall back to the shared
structural-interpreter lowering — mirroring the paper's fallback to
generic expansions.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from ..core.dtypes import ScheduleType
from ..core.memlet import (BlockFactorError, SubsetFactorization,
                           factor_subset)
from ..core.sdfg import (MapEntry, MapExit, Scalar, SDFG, State, Stream,
                         Tasklet)
from .common import (WCR_MODES, _apply_wcr, wcr_combine, wcr_identity,
                     wcr_reduce)
from .jnp_backend import StateLowering, build_callable as _build_callable

#: annotation key GridConversionPass writes and this backend consumes.
GRID_ANNOTATION = "pallas_grid"


@dataclass(frozen=True)
class EdgeSpec:
    """One tasklet edge lowered to a Pallas operand."""
    conn: str
    data: str
    fact: SubsetFactorization
    scalar: bool = False                       # 0-d container, carried as (1,)
    wcr: Optional[str] = None                  # outputs only
    reduction: Tuple[str, ...] = ()            # grid params absent from index
    box: Tuple[Tuple[int, int], ...] = ()      # written element range per dim
    node: int = 0                              # owning tasklet (chain index)


@dataclass(frozen=True)
class GridSpec:
    """Complete derived grid-kernel description for one map scope."""
    kernel_name: str
    grid: Tuple[Tuple[str, int], ...]          # (param, size) in grid order
    block_params: Tuple[Tuple[str, int], ...]  # intra-tile params + extents
    inputs: Tuple[EdgeSpec, ...]
    outputs: Tuple[EdgeSpec, ...]
    tasklet_labels: Tuple[str, ...] = ()       # topo-ordered chain labels


def _scalar_fact() -> SubsetFactorization:
    from ..core.symbolic import Expr
    return SubsetFactorization((1,), (Expr.const(0),), (0,))


def _tasklet_chain(state: State, entry: MapEntry, scopes) -> List[Tasklet]:
    """Topologically-ordered tasklets of the scope; raises when the scope
    holds anything else (nested maps, access nodes, ...)."""
    inner = [n for n in scopes.get(entry, []) if not isinstance(n, MapExit)]
    if not inner or not all(isinstance(n, Tasklet) for n in inner):
        raise BlockFactorError(
            f"map {entry.map.label!r}: grid codegen requires a tasklet-only "
            f"scope, got {[type(n).__name__ for n in inner]}")
    inner_set = set(inner)
    return [n for n in state.topological_nodes() if n in inner_set]


def _output_box(fact: SubsetFactorization, grid: Dict[str, Tuple[int, int]],
                label: str) -> Tuple[Tuple[int, int], ...]:
    """Element-range box written by an output across the whole grid; also
    verifies full coverage inside the box (each dim's block index must be a
    constant or ``param + const`` with a param used by no other dim)."""
    box = []
    seen_params = set()
    for d, (e, bs) in enumerate(zip(fact.index_exprs, fact.block_shape)):
        c0 = 0
        syms = {}
        for mono, c in e.terms.items():
            if mono == ():
                c0 = int(c)
            else:
                syms[mono[0][0]] = c
        if not syms:
            box.append((c0 * bs, c0 * bs + bs))
            continue
        if len(syms) > 1 or set(syms) & seen_params:
            raise BlockFactorError(
                f"output of {label!r}: dim {d} index {e} not contiguously "
                f"covered across the grid")
        (g, cg), = syms.items()
        if cg != 1:
            raise BlockFactorError(
                f"output of {label!r}: dim {d} strides blocks by {cg}")
        seen_params.add(g)
        n = grid[g][1]
        box.append((c0 * bs, (c0 + n - 1) * bs + bs))
    return tuple(box)


def analyze_map_scope(sdfg: SDFG, state: State, entry: MapEntry,
                      scopes=None, env: Optional[Dict[str, int]] = None
                      ) -> GridSpec:
    """Derive a :class:`GridSpec` for a map scope, or raise
    :class:`BlockFactorError` when the scope must fall back to the
    structural interpreter."""
    m = entry.map
    if m.schedule not in (ScheduleType.PIPELINED, ScheduleType.DEVICE):
        raise BlockFactorError(
            f"map {m.label!r}: schedule {m.schedule.value} is not a grid")
    scopes = scopes if scopes is not None else state.scope_children()
    chain = _tasklet_chain(state, entry, scopes)
    chain_index = {t: i for i, t in enumerate(chain)}
    env = dict(sdfg.symbol_values) if env is None else dict(env)

    tiling = dict(m.annotations.get("tiling", {}))
    grid_params: Dict[str, Tuple[int, int]] = {}
    block_params: Dict[str, int] = {}
    for p, r in zip(m.params, m.ranges):
        try:
            start, size = r.start.subs(env).as_int(), r.size.subs(env).as_int()
        except Exception as exc:
            raise BlockFactorError(
                f"map {m.label!r}: dynamic range for {p}") from exc
        if size < 1:
            raise BlockFactorError(f"map {m.label!r}: empty range for {p}")
        if p in tiling and size > 1:
            if start != 0 or size != int(tiling[p]):
                raise BlockFactorError(
                    f"map {m.label!r}: tile param {p} range [{start}, "
                    f"+{size}) disagrees with tiling annotation {tiling[p]}")
            block_params[p] = size
        else:
            grid_params[p] = (start, size)
    if not grid_params:
        raise BlockFactorError(f"map {m.label!r}: no grid parameters")

    def _factor(memlet):
        if memlet.dynamic:
            raise BlockFactorError(f"dynamic memlet {memlet}")
        if memlet.data not in sdfg.arrays:
            raise BlockFactorError(f"no descriptor for {memlet.data!r}")
        desc = sdfg.arrays[memlet.data]
        if isinstance(desc, Stream):
            raise BlockFactorError(f"stream operand {memlet.data!r}")
        if isinstance(desc, Scalar) or not getattr(desc, "shape", ()):
            return _scalar_fact(), True
        return factor_subset(memlet.subset, desc.shape, grid_params,
                             block_params, env), False

    inputs = []
    out_edge_list = []  # (chain index, edge)
    for ti, t in enumerate(chain):
        for e in state.in_edges(t):
            if e.dst_conn is None or e.memlet.data is None:
                continue
            if e.src in chain_index:
                # per-iteration intermediate, threaded as a local value
                if e.memlet.wcr is not None:
                    raise BlockFactorError(
                        f"map {m.label!r}: wcr on in-kernel intermediate "
                        f"{e.memlet.data!r}")
                continue
            fact, scalar = _factor(e.memlet)
            inputs.append(EdgeSpec(e.dst_conn, e.memlet.data, fact, scalar,
                                   node=ti))
        for e in state.out_edges(t):
            if e.dst in chain_index:
                if e.memlet.wcr is not None:
                    raise BlockFactorError(
                        f"map {m.label!r}: wcr on in-kernel intermediate "
                        f"{e.memlet.data!r}")
                continue
            if e.memlet.data is None:
                continue
            out_edge_list.append((ti, e))

    if not out_edge_list:
        raise BlockFactorError(f"map {m.label!r}: no kernel outputs")
    used_any: List[str] = []
    outs_raw = []
    for ti, e in out_edge_list:
        if e.memlet.wcr is not None and e.memlet.wcr not in WCR_MODES:
            raise BlockFactorError(
                f"map {m.label!r}: wcr {e.memlet.wcr!r} unsupported")
        fact, scalar = _factor(e.memlet)
        box = _output_box(fact, grid_params, m.label)
        used = set()
        for ex in fact.index_exprs:
            used |= ex.free_symbols
        for p in m.params:
            if p in used and p in grid_params and p not in used_any:
                used_any.append(p)
        outs_raw.append((ti, e, fact, scalar, box, used))

    # grid order: output-indexing params first (original order), reduction
    # params innermost so scratch accumulators stay block-resident.
    order = [p for p in m.params if p in grid_params and p in used_any]
    order += [p for p in m.params if p in grid_params and p not in used_any]
    outputs = []
    for ti, e, fact, scalar, box, used in outs_raw:
        reduction = tuple(p for p in order if p not in used)
        # every reduction dim must iterate inside every used dim
        max_used = max((order.index(p) for p in order if p in used),
                       default=-1)
        if any(order.index(p) < max_used for p in reduction):
            raise BlockFactorError(
                f"map {m.label!r}: reduction params {reduction} cannot be "
                f"ordered innermost for output {e.memlet.data!r}")
        if e.memlet.wcr is None and reduction and not getattr(
                chain[ti], "side_effect_free", True):
            raise BlockFactorError(f"map {m.label!r}: side-effecting tasklet")
        outputs.append(EdgeSpec(e.src_conn, e.memlet.data, fact, scalar,
                                e.memlet.wcr, reduction, box, node=ti))

    return GridSpec(
        kernel_name=m.label,
        grid=tuple((p, grid_params[p][1]) for p in order),
        block_params=tuple(sorted(block_params.items())),
        inputs=tuple(inputs), outputs=tuple(outputs),
        tasklet_labels=tuple(t.label for t in chain))


# ---------------------------------------------------------------------------
# Kernel emission
# ---------------------------------------------------------------------------


def _squeeze_adjusted_axis(fact: SubsetFactorization, dim: int) -> int:
    """Axis of ``dim`` in the loaded value after squeezing."""
    return dim - sum(1 for s in fact.squeeze_dims if s < dim)


def _conds(ids, positions, sizes, at_end: bool):
    conds = [ids[k] == (sizes[k] - 1 if at_end else 0) for k in positions]
    return functools.reduce(jnp.logical_and, conds)


class PallasStateLowering(StateLowering):
    """State lowering that emits ``pl.pallas_call`` grid kernels for map
    scopes annotated by ``GridConversionPass`` and shares the structural
    interpreter for everything else."""

    def _lower_map_custom(self, entry: MapEntry, exit_: MapExit,
                          inner: List) -> bool:
        spec: Optional[GridSpec] = entry.map.annotations.get(GRID_ANNOTATION)
        if spec is None:
            return False
        if not inner or not all(isinstance(n, Tasklet) for n in inner):
            return False
        inner_set = set(inner)
        chain = [n for n in self.state.topological_nodes() if n in inner_set]
        labels = tuple(t.label for t in chain)
        if spec.tasklet_labels and labels != spec.tasklet_labels:
            return False  # stale annotation: graph changed under the spec
        self._emit_grid_kernel(entry, chain, spec)
        return True

    # ------------------------------------------------------------------
    def _emit_grid_kernel(self, entry: MapEntry, chain: List[Tasklet],
                          spec: GridSpec):
        interpret = self.sdfg.metadata.get("pallas_interpret", True)
        grid_names = [p for p, _ in spec.grid]
        grid_sizes = tuple(n for _, n in spec.grid)
        block_order = [q for q, _ in spec.block_params]
        chain_index = {t: i for i, t in enumerate(chain)}

        in_vals = []
        for es in spec.inputs:
            v = jnp.asarray(self.ensure_value(es.data))
            if es.scalar:
                v = jnp.reshape(v, (1,))
            in_vals.append(v)
        in_specs = [pl.BlockSpec(es.fact.block_shape,
                                 es.fact.index_map(grid_names))
                    for es in spec.inputs]

        prev_vals, out_specs, out_shapes = [], [], []
        scratch_shapes, scratch_index = [], {}
        for oi, es in enumerate(spec.outputs):
            pv = jnp.asarray(self.ensure_value(es.data))
            if es.scalar:
                pv = jnp.reshape(pv, (1,))
            prev_vals.append(pv)
            out_specs.append(pl.BlockSpec(es.fact.block_shape,
                                          es.fact.index_map(grid_names)))
            out_shapes.append(jax.ShapeDtypeStruct(pv.shape, pv.dtype))
            if es.wcr in WCR_MODES and es.reduction:
                scratch_index[oi] = len(scratch_shapes)
                scratch_shapes.append(
                    pltpu.VMEM(es.fact.block_shape, pv.dtype))

        # per-tasklet wiring: container operands (spec), in-kernel locals
        # (tasklet->tasklet edges), and result slots (spec outputs)
        int_in: List[List[Tuple[str, Tuple[int, str]]]] = []
        out_binds: List[List[Tuple[str, str, object]]] = []
        for ti, t in enumerate(chain):
            ints = []
            for e in self.state.in_edges(t):
                if e.src in chain_index:
                    ints.append((e.dst_conn,
                                 (chain_index[e.src], e.src_conn)))
            int_in.append(ints)
            out_binds.append([])
        for oi, es in enumerate(spec.outputs):
            out_binds[es.node].append((es.conn, "result", oi))
        for ti, t in enumerate(chain):
            for e in self.state.out_edges(t):
                if e.dst in chain_index:
                    out_binds[ti].append((e.src_conn, "local",
                                          (ti, e.src_conn)))

        fns = [t.fn for t in chain]
        decl_outputs = [list(getattr(t, "outputs", ())) for t in chain]
        n_in, n_out = len(spec.inputs), len(spec.outputs)

        def chain_call(opvals):
            local = {}
            results = [None] * n_out
            for ti in range(len(chain)):
                kwargs = {}
                for i, es in enumerate(spec.inputs):
                    if es.node == ti:
                        kwargs[es.conn] = opvals[i]
                for conn, key in int_in[ti]:
                    kwargs[conn] = local[key]
                r = fns[ti](**kwargs)
                conns = [c for c, _, _ in out_binds[ti]]
                if not isinstance(r, dict):
                    if isinstance(r, tuple):
                        r = dict(zip(decl_outputs[ti] or conns, r))
                    else:
                        r = {conns[0]: r}
                for conn, kind, ref in out_binds[ti]:
                    if kind == "local":
                        local[ref] = r[conn]
                    else:
                        results[ref] = r[conn]
            return tuple(results)

        def kernel(*refs):
            ins = refs[:n_in]
            outs = refs[n_in:n_in + n_out]
            scratch = refs[n_in + n_out:]
            ids = [pl.program_id(k) for k in range(len(grid_names))]

            opvals = {}
            for i, (es, ref) in enumerate(zip(spec.inputs, ins)):
                v = ref[...]
                if es.fact.squeeze_dims:
                    v = jnp.squeeze(v, axis=es.fact.squeeze_dims)
                pd = dict(es.fact.param_dims)
                present = [q for q in block_order if q in pd]
                if present:  # tile axes to the front, in block-param order
                    src = [_squeeze_adjusted_axis(es.fact, pd[q])
                           for q in present]
                    v = jnp.moveaxis(v, src, list(range(len(src))))
                opvals[i] = v

            if block_order:
                f = chain_call
                for q in reversed(block_order):
                    axes = {i: (0 if q in dict(es.fact.param_dims) else None)
                            for i, es in enumerate(spec.inputs)}
                    f = jax.vmap(f, in_axes=(axes,), out_axes=0)
                results = f(opvals)
            else:
                results = chain_call(opvals)

            for oi, (es, oref) in enumerate(zip(spec.outputs, outs)):
                val = jnp.asarray(results[oi])
                val = self._assemble_block(val, es, block_order)
                if es.wcr in WCR_MODES and es.reduction:
                    acc = scratch[scratch_index[oi]]
                    red_pos = [grid_names.index(p) for p in es.reduction]
                    first = _conds(ids, red_pos, grid_sizes, at_end=False)
                    last = _conds(ids, red_pos, grid_sizes, at_end=True)

                    @pl.when(first)
                    def _init(acc=acc, es=es):
                        acc[...] = jnp.full(
                            acc.shape, wcr_identity(es.wcr, acc.dtype))

                    acc[...] = wcr_combine(es.wcr, acc[...],
                                           val.astype(acc.dtype))

                    @pl.when(last)
                    def _flush(acc=acc, oref=oref):
                        oref[...] = acc[...].astype(oref.dtype)
                else:
                    oref[...] = val.astype(oref.dtype)

        results = pl.pallas_call(
            kernel, grid=grid_sizes, in_specs=in_specs, out_specs=out_specs,
            out_shape=out_shapes, scratch_shapes=scratch_shapes,
            interpret=interpret)(*in_vals)
        if not isinstance(results, (list, tuple)):
            results = (results,)

        for es, new in zip(spec.outputs, results):
            # Stitch the written box into the prior container contents:
            # grid kernels only define the blocks their index maps touch.
            # Re-fetch per output: two edges may target the same container.
            prev = jnp.asarray(self.ensure_value(es.data))
            if es.scalar:
                prev = jnp.reshape(prev, (1,))
            sl = tuple(slice(lo, hi) for lo, hi in es.box)
            if es.wcr in WCR_MODES:
                cur = _apply_wcr(prev.at[sl], es.wcr, new[sl])
            elif all((lo, hi) == (0, s) for (lo, hi), s
                     in zip(es.box, prev.shape)):
                cur = new
            else:
                cur = prev.at[sl].set(new[sl])
            if es.scalar:
                cur = jnp.reshape(cur, ())
            self.env[es.data] = cur

    @staticmethod
    def _assemble_block(val, es: EdgeSpec, block_order: List[str]):
        """Rearrange a (vmapped) tasklet result — leading axes one per
        intra-tile param, trailing axes the tasklet's own result dims —
        into the output's block shape."""
        pd = dict(es.fact.param_dims)
        absent = tuple(i for i, q in enumerate(block_order) if q not in pd)
        if absent:
            if es.wcr in WCR_MODES:  # intra-block reduction
                val = wcr_reduce(es.wcr, val, absent)
            else:  # revisited location: last write wins, as sequentially
                idx = tuple(-1 if i in absent else slice(None)
                            for i in range(len(block_order)))
                val = val[idx]
        present = [q for q in block_order if q in pd]
        nlead = len(present)
        trailing = list(range(nlead, jnp.ndim(val)))
        slice_dims = [d for d in range(len(es.fact.block_shape))
                      if d not in pd.values() and es.fact.block_shape[d] > 1]
        if len(trailing) == len(slice_dims) and (present or trailing):
            src_of = {pd[q]: i for i, q in enumerate(present)}
            src_of.update({d: t for d, t in zip(slice_dims, trailing)})
            perm = [src_of[d] for d in sorted(src_of)]
            val = jnp.transpose(val, perm)
        return jnp.reshape(val, es.fact.block_shape)


def build_callable(sdfg: SDFG):
    """Build fn(**arrays) using the Pallas grid lowering strategy."""
    return _build_callable(sdfg, lowering=PallasStateLowering)
