from .compiler import BACKENDS, CompiledSDFG, compile_sdfg

__all__ = ["BACKENDS", "CompiledSDFG", "compile_sdfg"]
