from .compiler import BACKENDS, CompiledSDFG, compile_sdfg


def get_backend(name: str):
    """Backend codegen module (must expose ``build_callable``)."""
    from . import jnp_backend, pallas_backend
    modules = {"jnp": jnp_backend, "pallas": pallas_backend}
    try:
        return modules[name]
    except KeyError:
        raise ValueError(
            f"unknown backend {name!r}; choose from {sorted(modules)}")


__all__ = ["BACKENDS", "CompiledSDFG", "compile_sdfg", "get_backend"]
