"""Shared codegen utilities: symbolic-expression evaluation against traced
JAX values, and memlet-driven container reads/writes.

The paper's code generator translates memlets into array indexing / stream
push-pop; here they become (dynamic-)slice reads and functional ``.at[]``
writes. Write-conflict resolution (``wcr='add'``) lowers to scatter-add,
which — unlike the FPGA case — natively tolerates duplicate indices.
"""
from __future__ import annotations

from fractions import Fraction
from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from ..core.memlet import Memlet, Range, Subset
from ..core.symbolic import Expr


def eval_expr(expr: Expr, env: Dict[str, object]):
    """Evaluate an Expr where symbols may be bound to python ints or traced
    jax scalars. Returns int when fully static."""
    if isinstance(expr, (int, float)):
        return expr
    result = None
    for mono, coeff in expr.terms.items():
        term = None
        for name, power in mono:
            if name not in env:
                raise KeyError(f"unbound symbol {name!r} in {expr}")
            v = env[name]
            for _ in range(power):
                term = v if term is None else term * v
        if isinstance(coeff, Fraction) and coeff.denominator == 1:
            coeff = coeff.numerator
        if term is None:
            term = coeff
        elif coeff != 1:
            if isinstance(coeff, Fraction):
                # exact rational scaling of a traced/int value
                term = term * coeff.numerator
                term = term // coeff.denominator
            else:
                term = coeff * term
        result = term if result is None else result + term
    return 0 if result is None else result


class DynamicStrideError(NotImplementedError):
    """A memlet's stride (or a strided start) is only known at trace time,
    which the vectorized lowering cannot address. Map lowerings catch this
    and degrade to the sequential structural interpreter, where parameter
    bindings are trace-time constants."""


def _static_int(v) -> bool:
    return isinstance(v, int)


def subset_static_sizes(subset: Subset, env: Dict[str, object]) -> Tuple[int, ...]:
    """Range sizes must be static (trace-time constants). Sizes are the
    *element counts*: ceil((stop-start)/step), so strided half-open ranges
    whose span is not a step multiple (x[0:15:2]) size like numpy."""
    static = {k: v for k, v in env.items() if _static_int(v)}
    sizes = []
    for r in subset:
        span = eval_expr(r.stop - r.start, static)
        step = eval_expr(r.step, static)
        if not _static_int(span) or not _static_int(step):
            raise ValueError(
                f"memlet range size must be static, got {r.size}")
        sizes.append(-(-span // step))
    return tuple(sizes)


def read_memlet(value, memlet: Memlet, env: Dict[str, object]):
    """Read the memlet's subset out of a container value. Index (size-1)
    dimensions are squeezed, DaCe-style. Strides must be static: static
    starts lower to strided slices, traced starts to per-dimension gathers
    (needed e.g. for interleaved partial-sum subsets like ``x[l::K]``)."""
    if memlet.subset is None:
        return value
    subset = memlet.subset
    sizes = subset_static_sizes(subset, env)
    starts = [eval_expr(r.start, env) for r in subset]
    steps = [eval_expr(r.step, env) for r in subset]
    if any(not _static_int(s) for s in steps):
        raise DynamicStrideError("dynamic memlet strides not supported")
    squeeze = tuple(i for i, r in enumerate(subset) if r.is_index())
    if len(squeeze) == len(subset):
        return value[tuple(starts)]  # all-index: scalar (gather if traced)
    if all(_static_int(s) for s in starts):
        slc = tuple(slice(st, st + sz * sp, sp)
                    for st, sz, sp in zip(starts, sizes, steps))
        out = value[slc]
    elif all(sp == 1 for sp in steps):
        out = jax.lax.dynamic_slice(value, starts, sizes)
    else:
        # traced start with a static stride: gather along each dimension
        out = value
        for d, (st, sz, sp) in enumerate(zip(starts, sizes, steps)):
            if sz == out.shape[d] and _static_int(st) and st == 0 and sp == 1:
                continue
            out = jnp.take(out, st + sp * jnp.arange(sz), axis=d)
    if squeeze:
        out = jnp.squeeze(out, axis=squeeze)
    return out


def write_memlet(container_value, memlet: Memlet, new_value,
                 env: Dict[str, object]):
    """Functionally write ``new_value`` into the container per the memlet.
    Returns the updated container value. Static starts support static
    strides (mirroring ``read_memlet``); traced starts require unit steps
    — a strided dynamic write would need a scatter and fails loudly."""
    wcr = memlet.wcr
    if memlet.subset is None:
        if wcr == "add":
            return container_value + new_value
        if wcr == "max":
            return jnp.maximum(container_value, new_value)
        if wcr == "min":
            return jnp.minimum(container_value, new_value)
        return jnp.broadcast_to(new_value, jnp.shape(container_value)) \
            if jnp.shape(new_value) != jnp.shape(container_value) else new_value
    subset = memlet.subset
    sizes = subset_static_sizes(subset, env)
    starts = [eval_expr(r.start, env) for r in subset]
    steps = [eval_expr(r.step, env) for r in subset]
    if any(not _static_int(s) for s in steps):
        raise DynamicStrideError("dynamic memlet strides not supported")
    all_index = all(r.is_index() for r in subset)
    if all_index:
        ref = container_value.at[tuple(starts)]
        scalar = new_value
        if hasattr(scalar, "shape") and scalar.shape != ():
            scalar = jnp.reshape(scalar, ())
        return _apply_wcr(ref, wcr, scalar)
    new_value = jnp.reshape(new_value, sizes)
    if all(_static_int(s) for s in starts):
        slc = tuple(slice(st, st + sz * sp, sp)
                    for st, sz, sp in zip(starts, sizes, steps))
        return _apply_wcr(container_value.at[slc], wcr, new_value)
    if any(sp != 1 for sp in steps):
        # a traced start with a stride would need a scatter; landing the
        # values on contiguous positions would be silently wrong
        raise DynamicStrideError(
            "strided memlet writes with traced starts not supported")
    if wcr == "add":
        cur = jax.lax.dynamic_slice(container_value, starts, sizes)
        return jax.lax.dynamic_update_slice(container_value, cur + new_value, starts)
    if wcr == "max":
        cur = jax.lax.dynamic_slice(container_value, starts, sizes)
        return jax.lax.dynamic_update_slice(container_value,
                                            jnp.maximum(cur, new_value), starts)
    if wcr == "min":
        cur = jax.lax.dynamic_slice(container_value, starts, sizes)
        return jax.lax.dynamic_update_slice(container_value,
                                            jnp.minimum(cur, new_value), starts)
    return jax.lax.dynamic_update_slice(container_value, new_value, starts)


def _apply_wcr(ref, wcr, value):
    if wcr == "add":
        return ref.add(value)
    if wcr == "max":
        return ref.max(value)
    if wcr == "min":
        return ref.min(value)
    return ref.set(value)


# ---------------------------------------------------------------------------
# The single wcr dispatch table shared by both backends: elementwise
# combine, axis reduce, and identity element per mode. Adding a mode here
# (plus _apply_wcr above) is the complete recipe — WCR_MODES derives from
# these keys, so a mode can never be half-supported.
# ---------------------------------------------------------------------------

_WCR_TABLE = {
    "add": (lambda a, b: a + b, jnp.sum),
    "max": (jnp.maximum, jnp.max),
    "min": (jnp.minimum, jnp.min),
}

#: wcr modes with accumulate semantics (scratch reduction / combining
#: stitches); anything else is a plain overwrite.
WCR_MODES = tuple(_WCR_TABLE)


def wcr_combine(wcr: str, a, b):
    return _WCR_TABLE[wcr][0](a, b)


def wcr_reduce(wcr: str, value, axis):
    return _WCR_TABLE[wcr][1](value, axis=axis)


def wcr_identity(wcr: str, dtype):
    """The mode's identity element: accumulator init value."""
    if wcr == "add":
        return jnp.zeros((), dtype)
    info = jnp.finfo(dtype) if jnp.issubdtype(dtype, jnp.inexact) \
        else jnp.iinfo(dtype)
    return jnp.asarray(info.min if wcr == "max" else info.max, dtype)
