"""shard_map wrapping for partitioned SDFG callables.

``ShardMapPass`` (transforms/shard_map.py) divides the SDFG's container
shapes and map ranges by ``n_shards`` and stamps the partition under
``sdfg.metadata["shard_map"]``; the backend's built callable therefore
computes ONE shard. This module wraps it in
``jax.experimental.shard_map.shard_map`` over a 1-D device mesh so the
global-shaped call runs every shard in parallel: shard-local containers
get ``PartitionSpec(axis)`` on their partition dim, replicated ones
``PartitionSpec()``, and collective outputs (wcr reduced over the
partition) a ``lax.psum`` inside the mapped function.

The mesh is built lazily at first call from the first ``n_shards``
devices — under ``XLA_FLAGS=--xla_force_host_platform_device_count=N``
these are the simulated hosts; on a real pod, the processes' local
devices. A mesh *shrink* never reuses this wrapper: a different
``n_shards`` is a different pass configuration, hence a different
pipeline signature and content hash — a compilation-cache miss and a
fresh compile, never a stale kernel.
"""
from __future__ import annotations

from typing import Dict, Set

import numpy as np


class ShardMeshError(RuntimeError):
    """Not enough devices to build the requested shard mesh."""


def make_shard_mesh(n_shards: int, axis: str):
    """1-D mesh over the first ``n_shards`` visible devices."""
    import jax
    devs = jax.devices()
    if len(devs) < n_shards:
        raise ShardMeshError(
            f"shard mesh needs {n_shards} devices but only {len(devs)} "
            f"are visible; set XLA_FLAGS=--xla_force_host_platform_"
            f"device_count={n_shards} (before importing jax) or run on "
            f"a pod slice")
    return jax.sharding.Mesh(np.array(devs[:n_shards]), (axis,))


def _pspec(axis: str, dim):
    from jax.sharding import PartitionSpec as P
    if dim is None:
        return P()
    return P(*([None] * int(dim) + [axis]))


def wrap_shard_map(fn, spec: Dict, written):
    """Wrap a kwargs->dict SDFG callable in shard_map per ``spec``.

    ``spec`` is the ``sdfg.metadata["shard_map"]`` stamp; ``written`` the
    output container names (the dict keys ``fn`` returns).
    """
    from jax.experimental.shard_map import shard_map
    import jax

    axis = spec["axis"]
    k = int(spec["n_shards"])
    specs = spec.get("specs", {})
    psums: Set[str] = set(spec.get("psum", ()))
    out_specs = {n: _pspec(axis, None if n in psums else specs.get(n))
                 for n in sorted(written)}
    mesh_box = []

    def sharded(**kwargs):
        if not mesh_box:
            mesh_box.append(make_shard_mesh(k, axis))
        mesh = mesh_box[0]
        names = sorted(kwargs)
        in_specs = ([_pspec(axis, specs.get(n)) for n in names],)

        def inner(vals):
            out = fn(**dict(zip(names, vals)))
            for n in psums:
                if n in out:
                    out[n] = jax.lax.psum(out[n], axis)
            return {n: out[n] for n in sorted(out)}

        return shard_map(inner, mesh=mesh, in_specs=in_specs,
                         out_specs=out_specs, check_rep=False)(
            [kwargs[n] for n in names])

    sharded.__name__ = getattr(fn, "__name__", "sdfg") + f"_shard{k}"
    return sharded
