"""The XLA-auto backend (Intel-OpenCL analogue, DESIGN.md §2).

Lowers a fully-expanded SDFG into a jittable JAX callable by structural
interpretation: states execute in control-flow order; within a state, the
dataflow graph is traversed topologically; tasklets call their jax-traceable
bodies; map scopes lower to vectorized (vmap) code when the scope holds only
tasklets (single mapped tasklets, and MapFusion chains whose per-iteration
intermediates thread through the vmapped body as local values), to unrolled
trace-time loops for UNROLLED/MESH schedules, and to sequential trace-time
loops otherwise. XLA then fuses and pipelines — the 'compiler does the
scheduling' vendor.

Write-conflict-resolution memlets lower to scatter-add; streams materialize
as arrays shaped by their logical element volume (SPSC + matching access
order — enforced by validation — make this semantics-preserving).
"""
from __future__ import annotations

from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..core.memlet import Memlet
from ..core.sdfg import (AccessNode, Array, LibraryNode, MapEntry, MapExit,
                         NestedSDFG, Scalar, SDFG, State, Stream, Tasklet)
from ..core.symbolic import Expr
from .common import (DynamicStrideError, WCR_MODES, _apply_wcr, eval_expr,
                     read_memlet, wcr_combine, wcr_reduce, write_memlet)

# Maps whose scope is not a single tasklet fall back to a trace-time python
# loop; cap the unrolled trip count so mistakes fail loudly instead of
# hanging the tracer.
SEQUENTIAL_TRIP_LIMIT = 4096


def container_shape(desc, env: Dict[str, int]):
    if isinstance(desc, Scalar):
        return ()
    if isinstance(desc, Stream):
        shape = desc.element_shape or ()
        if desc.shape:  # array-of-streams: outer dims first
            shape = tuple(desc.shape) + tuple(shape)
        return tuple(int(eval_expr(s, env)) for s in shape)
    return tuple(int(eval_expr(s, env)) for s in desc.shape)


class StateLowering:
    """Structural interpreter over one state's dataflow graph.

    Node dispatch, memlet reads/writes, and the generic map lowerings
    (sequential / vmap) are shared backend infrastructure; subclasses plug
    in platform map-lowering strategies by overriding
    :meth:`_lower_map_custom` (e.g. the Pallas backend's grid codegen).
    """

    def __init__(self, sdfg: SDFG, state: State, env: Dict[str, object],
                 symenv: Dict[str, object]):
        self.sdfg = sdfg
        self.state = state
        self.env = env          # container name -> jax value
        self.symenv = symenv    # symbol name -> int (or traced scalar in maps)
        self.scopes = state.scope_children()

    # ------------------------------------------------------------------
    def ensure_value(self, name: str):
        if name in self.env:
            return self.env[name]
        if name in self.sdfg.constants:
            self.env[name] = jnp.asarray(self.sdfg.constants[name])
            return self.env[name]
        desc = self.sdfg.arrays[name]
        shape = container_shape(desc, self._static_syms())
        self.env[name] = jnp.zeros(shape, dtype=desc.dtype.np_dtype)
        return self.env[name]

    def _static_syms(self):
        return {k: v for k, v in self.symenv.items() if isinstance(v, int)}

    # ------------------------------------------------------------------
    def run(self):
        """Schedule processing elements (weakly connected components,
        paper §2.4) in producer->consumer order over shared containers; on
        FPGA they run concurrently synchronized by FIFOs, here the stream
        contents materialize between pipeline stages."""
        import networkx as nx
        comps = [frozenset(c) for c in
                 nx.weakly_connected_components(self.state.graph)]
        if len(comps) <= 1:
            order = [n for n in self.state.topological_nodes()
                     if n in self.scopes.get(None, [])]
            self._run_nodes(order)
            return
        writers: Dict[str, set] = {}
        readers: Dict[str, set] = {}
        for i, comp in enumerate(comps):
            for n in comp:
                if isinstance(n, AccessNode):
                    if self.state.in_degree(n) > 0:
                        writers.setdefault(n.data, set()).add(i)
                    if self.state.out_degree(n) > 0:
                        readers.setdefault(n.data, set()).add(i)
        meta = nx.DiGraph()
        meta.add_nodes_from(range(len(comps)))
        for name, ws in writers.items():
            for w in ws:
                for r in readers.get(name, ()):  # producer before consumer
                    if r != w:
                        meta.add_edge(w, r)
        try:
            comp_order = list(nx.topological_sort(meta))
        except nx.NetworkXUnfeasible as exc:
            raise NotImplementedError(
                "feedback between processing elements requires bounded-FIFO "
                "simulation, unsupported in the materializing backend"
            ) from exc
        top = set(self.scopes.get(None, []))
        topo = self.state.topological_nodes()
        for ci in comp_order:
            comp = comps[ci]
            self._run_nodes([n for n in topo if n in comp and n in top])

    def _run_nodes(self, nodes: List):
        for node in nodes:
            if isinstance(node, AccessNode):
                self._run_access(node)
            elif isinstance(node, Tasklet):
                self._run_tasklet(node)
            elif isinstance(node, MapEntry):
                self._run_map(node)
            elif isinstance(node, MapExit):
                pass  # handled with its entry
            elif isinstance(node, NestedSDFG):
                self._run_nested(node)
            elif isinstance(node, LibraryNode):
                raise RuntimeError(
                    f"unexpanded library node {node.label!r} at codegen; call "
                    f"sdfg.expand_library_nodes() first")
            else:
                raise NotImplementedError(type(node).__name__)

    # ------------------------------------------------------------------
    def _run_access(self, node: AccessNode):
        # direct data->data edges = copies (paper §2.3 host/device copies)
        self.ensure_value(node.data)
        for e in self.state.out_edges(node):
            if isinstance(e.dst, AccessNode):
                src_val = read_memlet(self.env[node.data], e.memlet, self.symenv)
                dst_desc = self.sdfg.arrays[e.dst.data]
                self.ensure_value(e.dst.data)
                out_memlet = Memlet(data=e.dst.data, subset=None)
                self.env[e.dst.data] = write_memlet(
                    self.env[e.dst.data], out_memlet, src_val, self.symenv)

    def _gather_inputs(self, node) -> Dict[str, object]:
        kwargs = {}
        for e in self.state.in_edges(node):
            if e.dst_conn is None or e.memlet.data is None:
                continue
            src_name = e.memlet.data
            self.ensure_value(src_name)
            kwargs[e.dst_conn] = read_memlet(self.env[src_name], e.memlet,
                                             self.symenv)
        return kwargs

    def _scatter_outputs(self, node, result):
        out_edges = [e for e in self.state.out_edges(node)
                     if e.src_conn is not None and e.memlet.data is not None]
        if not isinstance(result, dict):
            conns = sorted({e.src_conn for e in out_edges})
            if isinstance(result, tuple):
                result = dict(zip(getattr(node, "outputs", conns), result))
            elif len(conns) == 1:
                # single output connector (possibly forked to several
                # access nodes — manual replication, paper §4.2)
                result = {conns[0]: result}
        for e in out_edges:
            val = result[e.src_conn]
            name = e.memlet.data
            self.ensure_value(name)
            self.env[name] = write_memlet(self.env[name], e.memlet, val,
                                          self.symenv)

    def _run_tasklet(self, node: Tasklet):
        kwargs = self._gather_inputs(node)
        result = node.fn(**kwargs)
        self._scatter_outputs(node, result)

    def _run_nested(self, node: NestedSDFG):
        inner = node.sdfg
        inner_env: Dict[str, object] = {}
        conn_to_container = {}
        for e in self.state.in_edges(node):
            if e.dst_conn is None:
                continue
            self.ensure_value(e.memlet.data)
            inner_env[e.dst_conn] = read_memlet(
                self.env[e.memlet.data], e.memlet, self.symenv)
        inner_syms = dict(inner.symbol_values)
        for k, v in node.symbol_mapping.items():
            inner_syms[k] = eval_expr(v, self.symenv)
        lower_sdfg_body(inner, inner_env, inner_syms, lowering=type(self))
        for e in self.state.out_edges(node):
            if e.src_conn is None:
                continue
            self.ensure_value(e.memlet.data)
            self.env[e.memlet.data] = write_memlet(
                self.env[e.memlet.data], e.memlet, inner_env[e.src_conn],
                self.symenv)

    # ------------------------------------------------------------------
    # Map lowering
    # ------------------------------------------------------------------
    def _map_scope_edges(self, entry: MapEntry):
        exit_ = next(n for n in self.state.nodes
                     if isinstance(n, MapExit) and n.entry is entry)
        return exit_

    def _run_map(self, entry: MapEntry):
        from ..core.dtypes import ScheduleType
        exit_ = self._map_scope_edges(entry)
        children = self.scopes.get(entry, [])
        inner = [n for n in children if not isinstance(n, MapExit)]
        if self._lower_map_custom(entry, exit_, inner):
            return
        m = entry.map
        static = self._static_syms()
        sizes = [int(eval_expr(r.size, static)) for r in m.ranges]
        starts = [eval_expr(r.start, static) for r in m.ranges]

        # tasklet-only scopes (single mapped tasklets and MapFusion chains
        # threading per-iteration transients) vectorize with one vmap
        tasklet_chain = (all(isinstance(n, Tasklet) for n in inner)
                         and len(inner) >= 1)

        def sequential():
            total = int(np.prod(sizes)) if sizes else 1
            if total > SEQUENTIAL_TRIP_LIMIT:
                raise NotImplementedError(
                    f"map {m.label!r}: {total} sequential iterations exceeds "
                    f"trace-time limit; restructure as mapped tasklet or "
                    f"compile with the pallas backend's grid codegen")
            self._run_map_sequential(entry, exit_, inner, sizes, starts)

        if m.schedule in (ScheduleType.UNROLLED, ScheduleType.MESH,
                          ScheduleType.MXU):
            self._run_map_sequential(entry, exit_, inner, sizes, starts)
        elif (tasklet_chain
              and not any(self._has_param_slice_writes(t, m) for t in inner)
              and not self._has_dynamic_strides(entry, inner, exit_)):
            snapshot = dict(self.env)
            try:
                self._run_map_vmap(entry, exit_, inner, sizes, starts)
            except DynamicStrideError:
                # a stride only the traced parameter bindings reveal:
                # restore the env and take the sequential trace-time loop
                self.env.clear()
                self.env.update(snapshot)
                sequential()
        else:
            sequential()

    def _lower_map_custom(self, entry: MapEntry, exit_: MapExit,
                          inner: List) -> bool:
        """Platform map-lowering hook; return True when the map was handled.
        The base (XLA-auto) backend has no platform strategy."""
        return False

    def _has_dynamic_strides(self, entry: MapEntry, inner: List,
                             exit_: MapExit) -> bool:
        """A subset whose *step* references a map parameter is only known
        once the parameter is bound — the vectorized lowering would trace
        it and ``read_memlet``/``write_memlet`` would refuse; route such
        scopes to the sequential loop, where bindings are ints."""
        params = set(entry.map.params)
        nodes = {entry, exit_} | set(inner)
        for e in self.state.edges:
            if e.src not in nodes and e.dst not in nodes:
                continue
            if e.memlet.subset is None:
                continue
            for r in e.memlet.subset:
                if r.step.free_symbols & params:
                    return True
        return False

    def _has_param_slice_writes(self, tasklet: Tasklet, m) -> bool:
        """Vectorized lowering cannot scatter a per-iteration *slice*; such
        maps fall back to the sequential schedule instead of hard-failing.
        Only exit-bound writes count: tasklet->tasklet edges inside a fused
        scope carry per-iteration values, not container writes."""
        params = set(m.params)
        for e in self.state.out_edges(tasklet):
            if isinstance(e.dst, Tasklet):
                continue
            subset = e.memlet.subset
            if subset is None:
                continue
            used = set()
            for r in subset:
                used |= (r.start.free_symbols & params)
            if used and any(not r.is_index() for r in subset):
                return True
        return False

    @staticmethod
    def _partial_tile_pairs(m):
        """(counter, intra, tile, extent) for MapTiling'd parameter pairs
        whose extent is not a tile multiple — the lattice points where
        ``counter*tile + intra >= extent`` are padding and must be
        skipped by the structural lowerings."""
        from ..transforms.map_tiling import normalize_tiling
        pairs = []
        pset = set(m.params)
        for q, info in normalize_tiling(m.annotations.get("tiling", {})).items():
            ext, ts, ctr = info.get("extent"), info.get("tile"), \
                info.get("counter")
            if (q in pset and ctr in pset and ext is not None
                    and int(ext) % int(ts)):
                pairs.append((ctr, q, int(ts), int(ext)))
        return pairs

    def _run_map_sequential(self, entry, exit_, inner, sizes, starts):
        """Trace-time loop (paper: unrolled map = replicated hardware)."""
        m = entry.map
        partial = self._partial_tile_pairs(m)

        def rec(d):
            if d == len(sizes):
                for ctr, q, ts, ext in partial:
                    if self.symenv[ctr] * ts + self.symenv[q] >= ext:
                        return  # padding lane of a partial final tile
                self._exec_scope_once(entry, exit_, inner)
                return
            for i in range(sizes[d]):
                self.symenv[m.params[d]] = starts[d] + i
                rec(d + 1)
            del self.symenv[m.params[d]]

        rec(0)

    def _exec_scope_once(self, entry, exit_, inner):
        """Execute scope contents with params bound in symenv. Edges through
        entry/exit apply their memlets against the enclosing env."""
        order = [n for n in self.state.topological_nodes() if n in inner]
        for node in order:
            if isinstance(node, Tasklet):
                kwargs = {}
                for e in self.state.in_edges(node):
                    if e.dst_conn is None or e.memlet.data is None:
                        continue
                    self.ensure_value(e.memlet.data)
                    kwargs[e.dst_conn] = read_memlet(
                        self.env[e.memlet.data], e.memlet, self.symenv)
                result = node.fn(**kwargs)
                out_edges = [e for e in self.state.out_edges(node)
                             if e.memlet.data is not None]
                if len(out_edges) == 1 and not isinstance(result, dict):
                    result = {out_edges[0].src_conn: result}
                for e in out_edges:
                    name = e.memlet.data
                    self.ensure_value(name)
                    self.env[name] = write_memlet(
                        self.env[name], e.memlet, result[e.src_conn],
                        self.symenv)
            elif isinstance(node, MapEntry):
                self._run_map(node)
            elif isinstance(node, MapExit):
                pass
            elif isinstance(node, AccessNode):
                self._run_access(node)
            elif isinstance(node, NestedSDFG):
                self._run_nested(node)
            else:
                raise NotImplementedError(type(node).__name__)

    def _run_map_vmap(self, entry, exit_, inner, sizes, starts):
        """Vectorized lowering of tasklet-only scopes: the canonical mapped
        tasklet, and MapFusion chains whose tasklet->tasklet edges thread
        per-iteration transients as local values through one vmapped body.

        Chains carrying *wcr* tasklet->tasklet edges (MapFusion's reduction
        mode) cannot thread per-iteration values — the consumer needs the
        fully accumulated reduction — so they lower through the two-phase
        path: a full-lattice vmap of the producer side, a ``wcr_reduce``
        over the reduction axes, then a kept-lattice vmap of the consumer
        side fed with the reduced values."""
        m = entry.map
        chain_set = set(inner)
        chain = [n for n in self.state.topological_nodes() if n in chain_set]
        ext_in = {}    # tasklet -> container-reading in-edges
        int_in = {}    # tasklet -> in-kernel intermediate in-edges
        out_edges = []  # exit-bound writes, in chain order
        for t in chain:
            ext_in[t] = [e for e in self.state.in_edges(t)
                         if e.memlet.data is not None
                         and e.src not in chain_set]
            int_in[t] = [e for e in self.state.in_edges(t)
                         if e.src in chain_set]
            out_edges.extend(e for e in self.state.out_edges(t)
                             if e.memlet.data is not None
                             and e.dst not in chain_set)
        for t in chain:
            for e in ext_in[t]:
                self.ensure_value(e.memlet.data)

        captured = {id(e): self.env[e.memlet.data]
                    for t in chain for e in ext_in[t]}
        base_env = dict(self.symenv)
        groups, gsizes = self._vmap_groups(m, sizes, starts)

        wcr_edges = [e for t in chain for e in int_in[t]
                     if e.memlet.wcr is not None]
        if wcr_edges:
            self._run_map_vmap_phased(m, chain, chain_set, ext_in, int_in,
                                      out_edges, captured, base_env,
                                      groups, gsizes, wcr_edges)
            return

        def body(*param_vals):
            local = dict(base_env)
            local.update(dict(zip(m.params, param_vals)))
            vals = {}   # (producer tasklet, connector) -> iteration value
            outs = {}   # id(exit edge) -> value
            for t in chain:
                kwargs = {}
                for e in ext_in[t]:
                    kwargs[e.dst_conn] = read_memlet(captured[id(e)],
                                                     e.memlet, local)
                for e in int_in[t]:
                    kwargs[e.dst_conn] = vals[(e.src, e.src_conn)]
                result = self._normalize_result(t, result_of=t.fn(**kwargs))
                for e in self.state.out_edges(t):
                    if e.dst not in chain_set and e.memlet.data is None:
                        continue
                    v = result[e.src_conn]
                    if e.dst in chain_set:
                        vals[(t, e.src_conn)] = v
                    else:
                        outs[id(e)] = v
            return tuple(outs[id(e)] for e in out_edges)

        if sizes:
            pvals = self._lattice_param_values(groups, gsizes)
            outs = jax.vmap(body)(*[pvals[p] for p in m.params])
            stacked = tuple(o.reshape(tuple(gsizes) + o.shape[1:])
                            for o in outs)
        else:
            stacked = body()
        self._scatter_map_outputs(m, groups, gsizes, out_edges, stacked)

    def _normalize_result(self, t, result_of):
        """Coerce a tasklet return value into a connector->value dict."""
        result = result_of
        if isinstance(result, dict):
            return result
        t_out = [e for e in self.state.out_edges(t)
                 if isinstance(e.dst, Tasklet) or e.memlet.data is not None]
        conns = [e.src_conn for e in t_out]
        if isinstance(result, tuple):
            return dict(zip(t.outputs or conns, result))
        return {conns[0]: result}

    def _vmap_groups(self, m, sizes, starts):
        """The vmap lattice is built over *groups*: normally one group per
        parameter (the classic meshgrid), but a MapTiling'd pair whose
        extent is not a tile multiple collapses into one flat group that
        enumerates only the valid (counter, intra) points — the padding
        lanes of the partial final tile never execute, mirroring the
        Pallas backend's in-kernel masking."""
        partial = self._partial_tile_pairs(m)
        pos = {p: i for i, p in enumerate(m.params)}
        in_pair = {}
        for ctr, q, ts, ext in partial:
            in_pair[ctr] = in_pair[q] = (ctr, q, ts, ext)
        groups = []  # (member params, 1-D member value arrays, size)
        done = set()
        for p in m.params:
            if p in done:
                continue
            if p in in_pair and all(x in pos for x in in_pair[p][:2]):
                ctr, q, ts, ext = in_pair[p]
                flat = jnp.arange(ext)
                groups.append(((ctr, q),
                               (starts[pos[ctr]] + flat // ts,
                                starts[pos[q]] + flat % ts), ext))
                done |= {ctr, q}
            else:
                i = pos[p]
                groups.append(((p,), (jnp.arange(sizes[i]) + starts[i],),
                               sizes[i]))
                done.add(p)
        gsizes = [g[2] for g in groups]
        return groups, gsizes

    @staticmethod
    def _lattice_param_values(groups, gsizes):
        """Flat per-parameter coordinate arrays over the full group mesh."""
        mesh = jnp.meshgrid(*[jnp.arange(s) for s in gsizes], indexing="ij")
        flat_idx = [g.reshape(-1) for g in mesh]
        pvals = {}
        for gi, (params, vals, _) in enumerate(groups):
            for p, v in zip(params, vals):
                pvals[p] = v[flat_idx[gi]]
        return pvals

    def _run_map_vmap_phased(self, m, chain, chain_set, ext_in, int_in,
                             out_edges, captured, base_env, groups, gsizes,
                             wcr_edges):
        """Two-phase vectorized lowering for MapFusion's reduction mode.

        Phase 1 (producer side) runs over the full iteration lattice and
        yields the per-iteration reduction contributions; they are combined
        with :func:`wcr_reduce` over the *reduction axes* — lattice groups
        whose parameters do not address the reduction subset. Phase 2
        (consumer side) then runs once per kept lattice point with the
        reduced value bound to the wcr connector. Shapes the phased path
        cannot express raise :class:`DynamicStrideError`, routing the scope
        to the (already correct) sequential trace-time loop."""
        pset = set(m.params)
        phase2 = set()
        work = [e.dst for e in wcr_edges]
        while work:
            t = work.pop()
            if t in phase2:
                continue
            phase2.add(t)
            work.extend(e.dst for e in self.state.out_edges(t)
                        if e.dst in chain_set)
        phase1 = [t for t in chain if t not in phase2]
        p2chain = [t for t in chain if t in phase2]

        for t in phase1:
            for e in self.state.out_edges(t):
                if (e.dst in phase2 and e.memlet.wcr is None):
                    raise DynamicStrideError(
                        "plain producer->consumer edge alongside a wcr edge")
                if e.dst not in chain_set and e.memlet.data is not None:
                    raise DynamicStrideError(
                        "reduction producer also writes through the exit")
        used_sets = []
        for e in wcr_edges:
            if e.memlet.wcr not in WCR_MODES or e.memlet.subset is None:
                raise DynamicStrideError("unsupported in-chain wcr edge")
            used = set()
            for r in e.memlet.subset:
                used |= (r.start.free_symbols & pset)
            used_sets.append(used)
        kept_params = used_sets[0]
        if any(u != kept_params for u in used_sets):
            raise DynamicStrideError(
                "in-chain wcr edges disagree on reduction parameters")
        red_params = pset - kept_params
        for t in p2chain:
            p2_memlets = [e.memlet for e in ext_in[t]]
            p2_memlets += [e.memlet for e in self.state.out_edges(t)
                           if e.dst not in chain_set
                           and e.memlet.data is not None]
            for ml in p2_memlets:
                if ml.subset is None:
                    continue
                for r in ml.subset:
                    syms = (r.start.free_symbols | r.stop.free_symbols
                            | r.step.free_symbols)
                    if syms & red_params:
                        raise DynamicStrideError(
                            "consumer memlet uses a reduction parameter")
        kept = [gi for gi, (params, _, _) in enumerate(groups)
                if set(params) & kept_params]
        for gi in kept:
            if not set(groups[gi][0]) <= kept_params:
                raise DynamicStrideError(
                    "partial-tile group straddles the reduction boundary")
        red_axes = tuple(gi for gi in range(len(groups)) if gi not in kept)
        if not red_axes:
            raise DynamicStrideError("wcr chain reduces over no lattice axis")

        wcr_keys, key_mode = [], {}
        for e in wcr_edges:
            k = (e.src, e.src_conn)
            if k not in key_mode:
                wcr_keys.append(k)
                key_mode[k] = e.memlet.wcr
            elif key_mode[k] != e.memlet.wcr:
                raise DynamicStrideError(
                    "one reduction value consumed under two wcr modes")

        def body1(*param_vals):
            local = dict(base_env)
            local.update(dict(zip(m.params, param_vals)))
            vals = {}
            for t in phase1:
                kwargs = {}
                for e in ext_in[t]:
                    kwargs[e.dst_conn] = read_memlet(captured[id(e)],
                                                     e.memlet, local)
                for e in int_in[t]:
                    kwargs[e.dst_conn] = vals[(e.src, e.src_conn)]
                result = self._normalize_result(t, result_of=t.fn(**kwargs))
                for e in self.state.out_edges(t):
                    if e.dst in chain_set:
                        vals[(t, e.src_conn)] = result[e.src_conn]
            return tuple(vals[k] for k in wcr_keys)

        pvals = self._lattice_param_values(groups, gsizes)
        outs1 = jax.vmap(body1)(*[pvals[p] for p in m.params])
        stacked1 = tuple(o.reshape(tuple(gsizes) + o.shape[1:])
                         for o in outs1)
        reduced = tuple(wcr_reduce(key_mode[k], v, red_axes)
                        for k, v in zip(wcr_keys, stacked1))

        kept_groups = [groups[gi] for gi in kept]
        kept_gsizes = [gsizes[gi] for gi in kept]
        kept_plist = [p for g in kept_groups for p in g[0]]

        def body2(red_vals, *param_vals):
            local = dict(base_env)
            local.update(dict(zip(kept_plist, param_vals)))
            vals = dict(zip(wcr_keys, red_vals))
            outs = {}
            for t in p2chain:
                kwargs = {}
                for e in ext_in[t]:
                    kwargs[e.dst_conn] = read_memlet(captured[id(e)],
                                                     e.memlet, local)
                for e in int_in[t]:
                    kwargs[e.dst_conn] = vals[(e.src, e.src_conn)]
                result = self._normalize_result(t, result_of=t.fn(**kwargs))
                for e in self.state.out_edges(t):
                    if e.dst not in chain_set and e.memlet.data is None:
                        continue
                    v = result[e.src_conn]
                    if e.dst in chain_set:
                        vals[(t, e.src_conn)] = v
                    else:
                        outs[id(e)] = v
            return tuple(outs[id(e)] for e in out_edges)

        if kept_gsizes:
            pvals2 = self._lattice_param_values(kept_groups, kept_gsizes)
            red_flat = tuple(r.reshape((-1,) + r.shape[len(kept):])
                             for r in reduced)
            outs2 = jax.vmap(body2)(red_flat,
                                    *[pvals2[p] for p in kept_plist])
            stacked2 = tuple(o.reshape(tuple(kept_gsizes) + o.shape[1:])
                             for o in outs2)
        else:
            stacked2 = body2(reduced)
        self._scatter_map_outputs(m, kept_groups, kept_gsizes,
                                  out_edges, stacked2)

    def _scatter_map_outputs(self, m, groups, gsizes, out_edges,
                             stacked):
        """Write the stacked per-lattice-point results of a vmapped scope
        through their exit memlets (index scatter, wcr reduce/combine,
        scalar targets)."""
        static = self._static_syms()
        group_params = [set(g[0]) for g in groups]
        for e, val in zip(out_edges, stacked):
            name = e.memlet.data
            self.ensure_value(name)
            subset = e.memlet.subset
            if subset is None:
                # whole-container write from a mapped tasklet => reduction
                axes = tuple(range(len(groups)))
                if e.memlet.wcr in WCR_MODES:
                    self.env[name] = wcr_combine(
                        e.memlet.wcr, self.env[name],
                        wcr_reduce(e.memlet.wcr, val, axes))
                else:
                    self.env[name] = val
                continue
            # which params appear in each subset dim?
            used_params = set()
            for r in subset:
                used_params |= (r.start.free_symbols & set(m.params))
            unused_axes = tuple(gi for gi, ps in enumerate(group_params)
                                if not (ps & used_params))
            if e.memlet.wcr in WCR_MODES and unused_axes:
                val = wcr_reduce(e.memlet.wcr, val, unused_axes)
                kept = [gi for gi in range(len(groups))
                        if gi not in unused_axes]
            else:
                kept = list(range(len(groups)))
            if not used_params:
                # scalar target
                out_memlet = e.memlet
                self.env[name] = write_memlet(self.env[name], out_memlet, val,
                                              static)
                continue
            # build index arrays per dim over the kept group grid
            kept_grids = jnp.meshgrid(
                *[jnp.arange(gsizes[gi]) for gi in kept], indexing="ij")
            kept_env = dict(static)
            for ax, gi in enumerate(kept):
                params, vals, _ = groups[gi]
                for p, v in zip(params, vals):
                    kept_env[p] = v[kept_grids[ax]]
            idx_arrays = []
            is_slice = False
            for r in subset:
                if not r.is_index():
                    is_slice = True
                    break
                idx_arrays.append(eval_expr(r.start, kept_env))
            if is_slice:
                # slice writes: fall back to sequential semantics
                raise NotImplementedError(
                    f"vectorized slice-write for map {m.label!r}; use "
                    f"sequential schedule")
            idx_arrays = [jnp.asarray(ia) if not hasattr(ia, "shape")
                          else ia for ia in idx_arrays]
            idx_arrays = jnp.broadcast_arrays(*idx_arrays) \
                if len(idx_arrays) > 1 else idx_arrays
            self.env[name] = _apply_wcr(self.env[name].at[tuple(idx_arrays)],
                                        e.memlet.wcr, val)


# ---------------------------------------------------------------------------
def lower_sdfg_body(sdfg: SDFG, env: Dict[str, object],
                    symenv: Dict[str, object], lowering=None):
    """Execute states in control-flow order against ``env`` in place.
    ``lowering`` selects the per-backend :class:`StateLowering` strategy."""
    lowering = lowering or StateLowering
    order = sdfg.state_order()
    visited_guard = 0
    current = sdfg.start_state if sdfg.start_state is not None else (
        order[0] if order else None)
    done = set()
    while current is not None:
        lowering(sdfg, current, env, symenv).run()
        done.add(current)
        succs = list(sdfg.cfg.successors(current))
        nxt = None
        for s in succs:
            edge = sdfg.cfg.edges[current, s]["edge"]
            if edge.condition is None or edge.condition(symenv):
                for k, fn in edge.assignments.items():
                    symenv[k] = fn(symenv)
                nxt = s
                break
        visited_guard += 1
        if visited_guard > 10_000:
            raise RuntimeError("control-flow did not terminate")
        current = nxt


def classify_arguments(sdfg: SDFG):
    """inputs = non-transients read before first write (in program order);
    outputs = non-transients written anywhere. A container can be both
    (in/out parameters, DaCe-style)."""
    written, read_first = set(), set()
    for st in sdfg.state_order() or sdfg.states:
        for node in st.topological_nodes():
            if not isinstance(node, AccessNode):
                continue
            desc = sdfg.arrays[node.data]
            if desc.transient:
                continue
            # a node that both writes and reads (in-out) produces before
            # consuming: count the write first
            if st.in_degree(node) > 0:
                written.add(node.data)
            if st.out_degree(node) > 0 and node.data not in written:
                read_first.add(node.data)
    inputs = [n for n in sdfg.argument_names() if n in read_first]
    outputs = sorted(written)
    return inputs, outputs


def build_callable(sdfg: SDFG, lowering=None):
    """Build fn(**arrays) -> dict of written non-transient containers.
    ``lowering`` selects the per-backend :class:`StateLowering` strategy."""
    inputs, written = classify_arguments(sdfg)

    def fn(**kwargs):
        env: Dict[str, object] = {}
        for name in inputs:
            if name not in kwargs:
                raise TypeError(f"missing SDFG argument {name!r}")
        for name, v in kwargs.items():
            env[name] = jnp.asarray(v)
        for name, v in sdfg.constants.items():
            env[name] = jnp.asarray(v)
        symenv = dict(sdfg.symbol_values)
        lower_sdfg_body(sdfg, env, symenv, lowering=lowering)
        return {k: env[k] for k in sorted(written)}

    fn.__name__ = f"sdfg_{sdfg.name}"
    shard_spec = sdfg.metadata.get("shard_map")
    if shard_spec and int(shard_spec.get("n_shards", 1)) > 1:
        # ShardMapPass divided the shapes; the per-shard body runs under
        # shard_map over the mesh axis (codegen/shard.py)
        from .shard import wrap_shard_map
        return wrap_shard_map(fn, shard_spec, written)
    return fn
