from .pipeline import DataConfig, TokenStream, make_global_batch

__all__ = ["DataConfig", "TokenStream", "make_global_batch"]
