"""Deterministic sharded data pipeline.

Synthetic token streams are generated counter-based (threefry on
(step, shard, position)), so every data-parallel shard produces its batch
independently with no host I/O, any shard can be recomputed after a
restart (fault tolerance without data-loader checkpoints), and elastic
re-sharding is a pure re-indexing. A memory-mapped binary-token file
source with the same interface covers real corpora.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, Optional

import jax
import numpy as np


@dataclasses.dataclass
class DataConfig:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0
    path: Optional[str] = None     # None = synthetic


class TokenStream:
    """data_shard i of n: yields (local_batch, seq) int32 token batches."""

    def __init__(self, cfg: DataConfig, shard: int = 0, num_shards: int = 1):
        assert cfg.global_batch % num_shards == 0
        self.cfg = cfg
        self.shard = shard
        self.num_shards = num_shards
        self.local_batch = cfg.global_batch // num_shards
        self._mm = None
        if cfg.path:
            self._mm = np.memmap(cfg.path, dtype=np.int32, mode="r")

    def batch_at(self, step: int) -> np.ndarray:
        cfg = self.cfg
        if self._mm is None:
            # counter-based: deterministic, recomputable, shard-independent
            key = jax.random.fold_in(
                jax.random.fold_in(jax.random.PRNGKey(cfg.seed), step),
                self.shard)
            toks = jax.random.randint(
                key, (self.local_batch, cfg.seq_len), 0, cfg.vocab,
                dtype=np.int32)
            return np.asarray(toks)
        # file-backed: strided window per (step, shard, row)
        n_tokens = self._mm.shape[0]
        rows = []
        for b in range(self.local_batch):
            idx = (step * cfg.global_batch
                   + self.shard * self.local_batch + b)
            start = (idx * cfg.seq_len) % max(n_tokens - cfg.seq_len, 1)
            rows.append(np.asarray(self._mm[start:start + cfg.seq_len]))
        return np.stack(rows).astype(np.int32) % self.cfg.vocab

    def __iter__(self) -> Iterator[np.ndarray]:
        step = 0
        while True:
            yield self.batch_at(step)
            step += 1


def make_global_batch(cfg: DataConfig, step: int, model_cfg=None
                      ) -> Dict[str, np.ndarray]:
    """Whole global batch (single-host testing path)."""
    stream = TokenStream(cfg, shard=0, num_shards=1)
    batch = {"tokens": stream.batch_at(step)}
    if model_cfg is not None and getattr(model_cfg, "n_stub_tokens", 0):
        rng = np.random.default_rng(cfg.seed + step)
        key = "stub_embeds" if model_cfg.family == "vlm" else "frames"
        if model_cfg.family in ("vlm", "encdec"):
            batch[key] = rng.standard_normal(
                (cfg.global_batch, model_cfg.n_stub_tokens,
                 model_cfg.d_model)).astype(np.float32)
    return batch
