"""Fault injection + step watchdog for the serving path.

The serving analogue of :mod:`repro.runtime.cluster_sim`: a
:class:`ServeFaultPlan` declares *what* goes wrong (a step exception, NaN
logits, a slow step, forced page pressure, a failing bucket compile) and
*when* (scheduler step index / shape bucket), and a :class:`FaultInjector`
fires those faults into a live :class:`~repro.serving.Scheduler`. The
scheduler does not special-case injected faults — they enter the same
detection + recovery ladder real failures do (fallback re-run →
recompute-from-tokens → typed ``failed`` finishes), so the tests that
drive a plan through the scheduler exercise exactly the production
recovery code.

Detection is centralized in :class:`StepWatchdog`, which wraps the
trainer's :class:`~repro.runtime.trainer.HeartbeatMonitor` — the same
duration-EWMA straggler/deadline machinery that guards training steps
guards serving steps, and every detected fault lands as a typed event in
``watchdog.events`` (mirroring ``Compiled.report``'s typed entries).

Slow steps are *simulated*: the injector hands the scheduler a duration
multiplier instead of sleeping, so the watchdog sees a straggling step
without the test suite paying wall-clock time.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Tuple

import numpy as np

from ..runtime.trainer import HeartbeatMonitor


class StepFault(RuntimeError):
    """A typed serving-step fault (injected or detected)."""

    def __init__(self, kind: str, detail: str = ""):
        super().__init__(f"{kind}: {detail}" if detail else kind)
        self.kind = kind
        self.detail = detail


class StepWatchdog:
    """HeartbeatMonitor-backed detection for compiled decode steps.

    ``record`` feeds per-step durations to the shared monitor (host 0 —
    the serving process) and keeps a typed event log; ``fault`` logs
    detected step faults (exceptions, NaN logits, recoveries) in the
    same stream so ``Scheduler.stats()`` can report one timeline.
    """

    def __init__(self, deadline_s: float = 60.0,
                 straggler_factor: float = 4.0,
                 monitor: Optional[HeartbeatMonitor] = None):
        self.monitor = monitor or HeartbeatMonitor(deadline_s,
                                                   straggler_factor)
        self.events: List[dict] = []

    def record(self, step: int, duration: float) -> str:
        """Feed one step duration; returns ``ok | straggler | dead``."""
        status = self.monitor.record(0, duration)
        if status != "ok":
            self.events.append({"kind": status, "step": step,
                                "duration": duration})
        return status

    def fault(self, step: int, kind: str, detail: str = ""):
        self.events.append({"kind": kind, "step": step, "detail": detail})

    def faults_of(self, kind: str) -> List[dict]:
        return [e for e in self.events if e["kind"] == kind]


@dataclasses.dataclass
class ServeFaultPlan:
    """Declarative serving fault plan (cluster_sim.FaultPlan analogue).

    All ``*_at`` fields are scheduler step indices (``Scheduler.n_steps``
    at fire time). One-shot faults fire exactly once even if the step is
    re-run through the fallback path; ``*_persistent`` re-arms them on
    every attempt from the trigger step onward (exercising the
    repeatedly-failing → ``failed`` path).
    """
    #: raise a StepFault out of the compiled step call
    step_exception_at: Optional[int] = None
    exception_persistent: bool = False
    #: overwrite (a slice of) the step's logits with NaN after it runs
    nan_logits_at: Optional[int] = None
    nan_slots: Optional[Tuple[int, ...]] = None  # None -> every lane
    nan_persistent: bool = False
    #: report the step's duration multiplied (watchdog sees a straggler)
    slow_step_at: Optional[int] = None
    slow_factor: float = 20.0
    #: seize free pages (no reservation accounting) to force preemption
    page_pressure_at: Optional[int] = None
    page_pressure_pages: int = 0  # 0 -> every free page
    page_pressure_release_at: Optional[int] = None
    #: fail the grid compile of these (B, ctx) buckets ("all" = any)
    compile_fail_buckets: Tuple = ()
    compile_fail_times: int = 1


class FaultInjector:
    """Fires a :class:`ServeFaultPlan` into a running scheduler.

    The scheduler calls the three hooks itself (`on_step_begin`,
    `on_execute`, `corrupt_logits`/`slow_factor_for`); `attach` wires the
    compile-failure hook into the scheduler's DecodeStepCompiler. Every
    fired fault is logged in ``events``.
    """

    def __init__(self, plan: ServeFaultPlan):
        self.plan = plan
        self.events: List[dict] = []
        self._fired: set = set()
        self._seized: List[int] = []
        self._compile_fails = 0
        self._pool = None

    def attach(self, scheduler):
        scheduler.compiler.compile_fault = self.compile_fault
        self._pool = scheduler.pool

    def _fire_once(self, name: str) -> bool:
        if name in self._fired:
            return False
        self._fired.add(name)
        return True

    # -- hooks ----------------------------------------------------------
    def on_step_begin(self, step: int, scheduler):
        """Pre-admission faults: seize/release pool pages. While the
        pressure window is open the pool is re-drained every step (pages
        freed by finishing requests would otherwise refill it), so any
        page-boundary crossing inside the window is guaranteed to hit an
        empty pool and take the preemption path."""
        plan = self.plan
        if (plan.page_pressure_release_at is not None
                and step >= plan.page_pressure_release_at and self._seized):
            scheduler.pool.release(self._seized)
            self.events.append({"kind": "page_pressure_release",
                                "step": step,
                                "released": len(self._seized)})
            self._seized = []
            self._fired.add("page_pressure_window")
        elif (plan.page_pressure_at is not None
                and step >= plan.page_pressure_at
                and "page_pressure_window" not in self._fired):
            want = plan.page_pressure_pages
            if want > 0 and self._seized:
                return  # fixed-count pressure: seize once only
            taken = scheduler.pool.seize(want)
            if taken:
                self._seized.extend(taken)
                self.events.append({"kind": "page_pressure", "step": step,
                                    "seized": len(taken)})
            if plan.page_pressure_release_at is None:
                # no release scheduled: one-shot seize, don't re-drain
                self._fired.add("page_pressure_window")

    def on_execute(self, step: int, retry: bool = False):
        """Called immediately before each step execution attempt."""
        plan = self.plan
        if plan.step_exception_at is None:
            return
        if plan.exception_persistent:
            if step >= plan.step_exception_at:
                self.events.append({"kind": "step_exception", "step": step,
                                    "retry": retry})
                raise StepFault("injected_step_exception",
                                f"persistent from step "
                                f"{plan.step_exception_at}")
        elif (step == plan.step_exception_at and not retry
              and self._fire_once("step_exception")):
            self.events.append({"kind": "step_exception", "step": step,
                                "retry": retry})
            raise StepFault("injected_step_exception", f"at step {step}")

    def corrupt_logits(self, step: int, rows: np.ndarray) -> np.ndarray:
        """Post-execution logits corruption (NaN injection)."""
        plan = self.plan
        if plan.nan_logits_at is None:
            return rows
        fire = (step >= plan.nan_logits_at if plan.nan_persistent
                else step == plan.nan_logits_at
                and self._fire_once("nan_logits"))
        if not fire:
            return rows
        rows = rows.copy()
        if plan.nan_slots is None:
            rows[:] = np.nan
        else:
            for s in plan.nan_slots:
                if s < rows.shape[0]:
                    rows[s] = np.nan
        self.events.append({"kind": "nan_logits", "step": step,
                            "slots": plan.nan_slots})
        return rows

    def slow_factor_for(self, step: int) -> float:
        plan = self.plan
        if (plan.slow_step_at is not None and step == plan.slow_step_at
                and self._fire_once("slow_step")):
            self.events.append({"kind": "slow_step", "step": step,
                                "factor": plan.slow_factor})
            return plan.slow_factor
        return 1.0

    def compile_fault(self, B: int, ctx: int):
        """Installed as DecodeStepCompiler.compile_fault by ``attach``."""
        plan = self.plan
        if not plan.compile_fail_buckets:
            return
        hit = (plan.compile_fail_buckets == "all"
               or (B, ctx) in plan.compile_fail_buckets)
        if hit and self._compile_fails < plan.compile_fail_times:
            self._compile_fails += 1
            self.events.append({"kind": "compile_failure",
                                "bucket": (B, ctx)})
            raise StepFault("injected_compile_failure",
                            f"bucket ({B}, {ctx})")
