"""Paged KV cache (KVPagePool): fixed-size pages + per-slot block tables.

The pool owns, per attention layer, a pair of page arrays
``(n_pages, page_size, Hkv, Dh)``; sequences own *pages*, not a
contiguous cache slab, so evicting a request frees its pages for the
next admission without reshaping any live batch array. Page 0 is a
reserved **null page**: block-table rows of inactive/evicted slots are
zero, so the compiled decode step's KV write for padding lanes lands on
the null page and the gather for those lanes reads it — both are masked
out downstream (the attention mask covers positions > pos, and padding
lanes are dropped before sampling), so the null page may hold garbage.

Allocation is two-phase so admission can never strand a running request:
``reserve`` claims worst-case page counts at admit time (a counter, no
page identities), and ``alloc`` later binds concrete pages as the
sequence actually crosses page boundaries. ``available`` is
free-minus-reserved; the scheduler admits against it.
"""
from __future__ import annotations

from typing import Dict, List, Tuple

import jax.numpy as jnp

NULL_PAGE = 0


class PageError(RuntimeError):
    """Pool invariant violation (double free, over-allocation...)."""


class KVPagePool:
    """Page accounting + per-attention-layer page storage.

    ``layers`` maps flat layer index -> (n_kv_heads, head_dim) for every
    attention layer of the model (non-attention layers hold no pages).
    """

    def __init__(self, layers: Dict[int, Tuple[int, int]], n_pages: int,
                 page_size: int, dtype=jnp.bfloat16):
        if n_pages < 2:
            raise ValueError(f"need >= 2 pages (1 null + data), "
                             f"got {n_pages}")
        self.n_pages = n_pages
        self.page_size = page_size
        self.dtype = jnp.dtype(dtype)
        # page 0 is the null page and is never handed out
        self._free: List[int] = list(range(n_pages - 1, 0, -1))
        self._reserved = 0
        self.k_pages: Dict[int, jnp.ndarray] = {}
        self.v_pages: Dict[int, jnp.ndarray] = {}
        for li, (hkv, dh) in layers.items():
            shape = (n_pages, page_size, hkv, dh)
            self.k_pages[li] = jnp.zeros(shape, self.dtype)
            self.v_pages[li] = jnp.zeros(shape, self.dtype)

    # -- accounting -----------------------------------------------------
    @property
    def num_free(self) -> int:
        return len(self._free)

    @property
    def available(self) -> int:
        """Pages that can still be *reserved* by a new admission."""
        return len(self._free) - self._reserved

    def pages_for(self, n_tokens: int) -> int:
        return -(-n_tokens // self.page_size) if n_tokens > 0 else 0

    def reserve(self, n: int):
        if n > self.available:
            raise PageError(f"cannot reserve {n} pages: only "
                            f"{self.available} available")
        self._reserved += n

    def unreserve(self, n: int):
        if n > self._reserved:
            raise PageError(f"unreserve({n}) exceeds reservation "
                            f"{self._reserved}")
        self._reserved -= n

    def alloc(self, n: int = 1, reserved: bool = True) -> List[int]:
        """Bind ``n`` concrete pages. With ``reserved`` (the scheduler
        path) the pages come out of this request's prior reservation."""
        if n > len(self._free):
            raise PageError(f"out of pages: want {n}, free "
                            f"{len(self._free)}")
        if reserved:
            self.unreserve(n)
        elif n > self.available:
            raise PageError(f"alloc({n}) would eat into reservations: "
                            f"available {self.available}")
        return [self._free.pop() for _ in range(n)]

    def free(self, pages: List[int]):
        for p in pages:
            if p == NULL_PAGE:
                raise PageError("freeing the null page")
            if not (0 < p < self.n_pages):
                raise PageError(f"freeing unknown page {p}")
            if p in self._free:
                raise PageError(f"double free of page {p}")
            self._free.append(p)

    def stats(self) -> dict:
        return {"n_pages": self.n_pages, "free": len(self._free),
                "reserved": self._reserved, "available": self.available,
                "page_size": self.page_size}

    # -- storage --------------------------------------------------------
    def write_prefill(self, li: int, pages: List[int], k, v):
        """Scatter a prefilled (S, Hkv, Dh) K/V slab into ``pages``.
        S is padded up to a whole number of pages (pad rows are past the
        sequence position, hence masked at attention time)."""
        ps = self.page_size
        s = k.shape[0]
        pad = len(pages) * ps - s
        if pad < 0:
            raise PageError(f"{len(pages)} pages cannot hold {s} tokens")
        idx = jnp.asarray(pages, jnp.int32)
        kp = jnp.pad(k, ((0, pad), (0, 0), (0, 0))).reshape(
            len(pages), ps, *k.shape[1:]).astype(self.dtype)
        vp = jnp.pad(v, ((0, pad), (0, 0), (0, 0))).reshape(
            len(pages), ps, *v.shape[1:]).astype(self.dtype)
        self.k_pages[li] = self.k_pages[li].at[idx].set(kp)
        self.v_pages[li] = self.v_pages[li].at[idx].set(vp)
