"""Paged KV cache (KVPagePool): fixed-size pages + per-slot block tables.

The pool owns, per attention layer, a pair of page arrays
``(n_pages, page_size, Hkv, Dh)``; sequences own *pages*, not a
contiguous cache slab, so evicting a request frees its pages for the
next admission without reshaping any live batch array. Page 0 is a
reserved **null page**: block-table rows of inactive/evicted slots are
zero, so the compiled decode step's KV write for padding lanes lands on
the null page and the gather for those lanes reads it — both are masked
out downstream (the attention mask covers positions > pos, and padding
lanes are dropped before sampling), so the null page may hold garbage.

Allocation is two-phase so admission can never strand a running request:
``reserve`` claims worst-case page counts at admit time (a counter, no
page identities), and ``alloc`` later binds concrete pages as the
sequence actually crosses page boundaries. ``available`` is
free-minus-reserved; the scheduler admits against it.
"""
from __future__ import annotations

from typing import Dict, List, Tuple

import jax.numpy as jnp
import numpy as np

NULL_PAGE = 0


class PageError(RuntimeError):
    """Pool invariant violation (double free, over-allocation...)."""


class KVPagePool:
    """Page accounting + per-attention-layer page storage.

    ``layers`` maps flat layer index -> (n_kv_heads, head_dim) for every
    attention layer of the model (non-attention layers hold no pages).
    """

    def __init__(self, layers: Dict[int, Tuple[int, int]], n_pages: int,
                 page_size: int, dtype=jnp.bfloat16):
        if n_pages < 2:
            raise ValueError(f"need >= 2 pages (1 null + data), "
                             f"got {n_pages}")
        self.n_pages = n_pages
        self.page_size = page_size
        self.dtype = jnp.dtype(dtype)
        self._layers = dict(layers)
        # page 0 is the null page and is never handed out
        self._free: List[int] = list(range(n_pages - 1, 0, -1))
        self._reserved = 0
        self._seized = 0
        self.k_pages: Dict[int, jnp.ndarray] = {}
        self.v_pages: Dict[int, jnp.ndarray] = {}
        self.reset_storage()

    # -- accounting -----------------------------------------------------
    @property
    def num_free(self) -> int:
        return len(self._free)

    @property
    def available(self) -> int:
        """Pages that can still be *reserved* by a new admission."""
        return len(self._free) - self._reserved

    def pages_for(self, n_tokens: int) -> int:
        return -(-n_tokens // self.page_size) if n_tokens > 0 else 0

    def reserve(self, n: int):
        if n > self.available:
            raise PageError(f"cannot reserve {n} pages: only "
                            f"{self.available} available")
        self._reserved += n

    def unreserve(self, n: int):
        if n > self._reserved:
            raise PageError(f"unreserve({n}) exceeds reservation "
                            f"{self._reserved}")
        self._reserved -= n

    def alloc(self, n: int = 1, reserved: bool = True) -> List[int]:
        """Bind ``n`` concrete pages. With ``reserved`` (the scheduler
        path) the pages come out of this request's prior reservation."""
        if n > len(self._free):
            raise PageError(f"out of pages: want {n}, free "
                            f"{len(self._free)}")
        if reserved:
            self.unreserve(n)
        elif n > self.available:
            raise PageError(f"alloc({n}) would eat into reservations: "
                            f"available {self.available}")
        return [self._free.pop() for _ in range(n)]

    def free(self, pages: List[int]):
        for p in pages:
            if p == NULL_PAGE:
                raise PageError("freeing the null page")
            if not (0 < p < self.n_pages):
                raise PageError(f"freeing unknown page {p}")
            if p in self._free:
                raise PageError(f"double free of page {p}")
            self._free.append(p)

    def stats(self) -> dict:
        return {"n_pages": self.n_pages, "free": len(self._free),
                "reserved": self._reserved, "available": self.available,
                "seized": self._seized, "page_size": self.page_size}

    # -- fault injection / recovery -------------------------------------
    def seize(self, n: int = 0) -> List[int]:
        """Remove up to ``n`` free pages (all of them for ``n <= 0``)
        from circulation WITHOUT reservation accounting — the
        fault-injection hook for forced page pressure. Seized pages may
        leave ``available`` negative; the scheduler's preemption path is
        what absorbs that hazard. Return them with :meth:`release`."""
        if n <= 0 or n > len(self._free):
            n = len(self._free)
        self._seized += n
        return [self._free.pop() for _ in range(n)]

    def release(self, pages: List[int]):
        """Return pages taken by :meth:`seize` to the free list."""
        if len(pages) > self._seized:
            raise PageError(f"releasing {len(pages)} pages but only "
                            f"{self._seized} are seized")
        for p in pages:
            if not (0 < p < self.n_pages) or p in self._free:
                raise PageError(f"releasing bad/free page {p}")
        self._seized -= len(pages)
        self._free.extend(pages)

    def reset_storage(self):
        """(Re)allocate zeroed page arrays. Used at construction and by
        recompute recovery, where a failed donating step has consumed
        the live arrays and every sequence will be re-prefilled."""
        for li, (hkv, dh) in self._layers.items():
            shape = (self.n_pages, self.page_size, hkv, dh)
            self.k_pages[li] = jnp.zeros(shape, self.dtype)
            self.v_pages[li] = jnp.zeros(shape, self.dtype)

    # -- snapshot --------------------------------------------------------
    def snapshot(self) -> dict:
        """Host-side copy of accounting + page storage (numpy-backed)."""
        return {"free": list(self._free), "reserved": self._reserved,
                "seized": self._seized,
                "k_pages": {li: np.asarray(a)
                            for li, a in self.k_pages.items()},
                "v_pages": {li: np.asarray(a)
                            for li, a in self.v_pages.items()}}

    def restore(self, snap: dict):
        if set(snap["k_pages"]) != set(self.k_pages):
            raise PageError("snapshot layer set does not match this pool")
        self._free = list(snap["free"])
        self._reserved = int(snap["reserved"])
        self._seized = int(snap.get("seized", 0))
        for li in self.k_pages:
            self.k_pages[li] = jnp.asarray(snap["k_pages"][li], self.dtype)
            self.v_pages[li] = jnp.asarray(snap["v_pages"][li], self.dtype)

    # -- storage --------------------------------------------------------
    def write_prefill(self, li: int, pages: List[int], k, v):
        """Scatter a prefilled (S, Hkv, Dh) K/V slab into ``pages``.
        S is padded up to a whole number of pages (pad rows are past the
        sequence position, hence masked at attention time)."""
        ps = self.page_size
        s = k.shape[0]
        pad = len(pages) * ps - s
        if pad < 0:
            raise PageError(f"{len(pages)} pages cannot hold {s} tokens")
        idx = jnp.asarray(pages, jnp.int32)
        kp = jnp.pad(k, ((0, pad), (0, 0), (0, 0))).reshape(
            len(pages), ps, *k.shape[1:]).astype(self.dtype)
        vp = jnp.pad(v, ((0, pad), (0, 0), (0, 0))).reshape(
            len(pages), ps, *v.shape[1:]).astype(self.dtype)
        self.k_pages[li] = self.k_pages[li].at[idx].set(kp)
        self.v_pages[li] = self.v_pages[li].at[idx].set(vp)
