"""Paged KV cache (KVPagePool): fixed-size pages + per-slot block tables.

The pool owns, per attention layer, a pair of page arrays
``(n_pages, page_size, Hkv, Dh)``; sequences own *pages*, not a
contiguous cache slab, so evicting a request frees its pages for the
next admission without reshaping any live batch array. Page 0 is a
reserved **null page**: block-table rows of inactive/evicted slots are
zero, so the compiled decode step's KV write for padding lanes lands on
the null page and the gather for those lanes reads it — both are masked
out downstream (the attention mask covers positions > pos, and padding
lanes are dropped before sampling), so the null page may hold garbage.

Allocation is two-phase so admission can never strand a running request:
``reserve`` claims worst-case page counts at admit time (a counter, no
page identities), and ``alloc`` later binds concrete pages as the
sequence actually crosses page boundaries. ``available`` is
free-minus-reserved; the scheduler admits against it.

Multi-host sharding (``n_shards > 1``): the page id space splits into
``n_shards`` contiguous blocks of ``pages_per_shard`` pages — block
``h`` lives on host ``h``'s device shard of the page arrays, and its
first page (global id ``h * pages_per_shard``) is that shard's null
page. Accounting (free lists, reservations) is per shard, because a
slot hosted on shard ``h`` can only ever reference shard-``h`` pages:
inside the compiled ``shard_map`` step each host sees only its own page
block, addressed by local ids. ``shrink`` drops the trailing shards —
host loss — once the scheduler has preempted every request living on
them; capacity reshrinks and the surviving shards keep their pages.
"""
from __future__ import annotations

from typing import Dict, List, Tuple

import jax.numpy as jnp
import numpy as np

NULL_PAGE = 0


class PageError(RuntimeError):
    """Pool invariant violation (double free, over-allocation...)."""


class KVPagePool:
    """Page accounting + per-attention-layer page storage.

    ``layers`` maps flat layer index -> (n_kv_heads, head_dim) for every
    attention layer of the model (non-attention layers hold no pages).
    """

    def __init__(self, layers: Dict[int, Tuple[int, int]], n_pages: int,
                 page_size: int, dtype=jnp.bfloat16, n_shards: int = 1):
        if n_shards < 1:
            raise ValueError(f"n_shards must be >= 1, got {n_shards}")
        if n_pages % n_shards:
            raise ValueError(f"n_pages {n_pages} not divisible by "
                             f"n_shards {n_shards}")
        if n_pages // n_shards < 2:
            raise ValueError(f"need >= 2 pages per shard (1 null + data), "
                             f"got {n_pages} over {n_shards} shards")
        self.n_pages = n_pages
        self.n_shards = n_shards
        self.pages_per_shard = n_pages // n_shards
        self.page_size = page_size
        self.dtype = jnp.dtype(dtype)
        self._layers = dict(layers)
        # the first page of each shard block is that shard's null page
        # and is never handed out (shard 0's is the global NULL_PAGE)
        pps = self.pages_per_shard
        self._shard_free: List[List[int]] = [
            list(range((h + 1) * pps - 1, h * pps, -1))
            for h in range(n_shards)]
        self._shard_reserved: List[int] = [0] * n_shards
        self._seized = 0
        self.k_pages: Dict[int, jnp.ndarray] = {}
        self.v_pages: Dict[int, jnp.ndarray] = {}
        self.reset_storage()

    # -- accounting -----------------------------------------------------
    @property
    def num_free(self) -> int:
        return sum(len(f) for f in self._shard_free)

    @property
    def _reserved(self) -> int:
        return sum(self._shard_reserved)

    @property
    def available(self) -> int:
        """Pages that can still be *reserved* by a new admission."""
        return self.num_free - self._reserved

    def available_in(self, shard: int) -> int:
        """Reservable pages on one shard (admission checks the shard the
        request's slot lives on)."""
        return len(self._shard_free[shard]) - self._shard_reserved[shard]

    def shard_of(self, page: int) -> int:
        return page // self.pages_per_shard

    def null_page(self, shard: int) -> int:
        return shard * self.pages_per_shard

    def pages_for(self, n_tokens: int) -> int:
        return -(-n_tokens // self.page_size) if n_tokens > 0 else 0

    def reserve(self, n: int, shard: int = 0):
        if n > self.available_in(shard):
            raise PageError(f"cannot reserve {n} pages on shard {shard}: "
                            f"only {self.available_in(shard)} available")
        self._shard_reserved[shard] += n

    def unreserve(self, n: int, shard: int = 0):
        if n > self._shard_reserved[shard]:
            raise PageError(f"unreserve({n}) exceeds shard {shard} "
                            f"reservation {self._shard_reserved[shard]}")
        self._shard_reserved[shard] -= n

    def alloc(self, n: int = 1, reserved: bool = True,
              shard: int = 0) -> List[int]:
        """Bind ``n`` concrete pages on one shard. With ``reserved`` (the
        scheduler path) the pages come out of this request's prior
        reservation."""
        free = self._shard_free[shard]
        if n > len(free):
            raise PageError(f"out of pages: want {n}, free "
                            f"{len(free)} on shard {shard}")
        if reserved:
            self.unreserve(n, shard)
        elif n > self.available_in(shard):
            raise PageError(f"alloc({n}) would eat into reservations: "
                            f"available {self.available_in(shard)} on "
                            f"shard {shard}")
        return [free.pop() for _ in range(n)]

    def free(self, pages: List[int]):
        for p in pages:
            if not (0 <= p < self.n_pages):
                raise PageError(f"freeing unknown page {p}")
            if p % self.pages_per_shard == 0:
                raise PageError("freeing the null page")
            sh = self.shard_of(p)
            if p in self._shard_free[sh]:
                raise PageError(f"double free of page {p}")
            self._shard_free[sh].append(p)

    def stats(self) -> dict:
        return {"n_pages": self.n_pages, "free": self.num_free,
                "reserved": self._reserved, "available": self.available,
                "seized": self._seized, "page_size": self.page_size,
                "n_shards": self.n_shards,
                "free_by_shard": [len(f) for f in self._shard_free]}

    # -- fault injection / recovery -------------------------------------
    def seize(self, n: int = 0) -> List[int]:
        """Remove up to ``n`` free pages (all of them for ``n <= 0``)
        from circulation WITHOUT reservation accounting — the
        fault-injection hook for forced page pressure. Seized pages may
        leave ``available`` negative; the scheduler's preemption path is
        what absorbs that hazard. Return them with :meth:`release`."""
        if n <= 0 or n > self.num_free:
            n = self.num_free
        out: List[int] = []
        h = 0
        while len(out) < n:
            if self._shard_free[h]:
                out.append(self._shard_free[h].pop())
            h = (h + 1) % self.n_shards
        self._seized += len(out)
        return out

    def release(self, pages: List[int]):
        """Return pages taken by :meth:`seize` to the free list."""
        if len(pages) > self._seized:
            raise PageError(f"releasing {len(pages)} pages but only "
                            f"{self._seized} are seized")
        for p in pages:
            sh = self.shard_of(p) if 0 <= p < self.n_pages else -1
            if (sh < 0 or p % self.pages_per_shard == 0
                    or p in self._shard_free[sh]):
                raise PageError(f"releasing bad/free page {p}")
        self._seized -= len(pages)
        for p in pages:
            self._shard_free[self.shard_of(p)].append(p)

    def reset_storage(self):
        """(Re)allocate zeroed page arrays. Used at construction and by
        recompute recovery, where a failed donating step has consumed
        the live arrays and every sequence will be re-prefilled."""
        for li, (hkv, dh) in self._layers.items():
            shape = (self.n_pages, self.page_size, hkv, dh)
            self.k_pages[li] = jnp.zeros(shape, self.dtype)
            self.v_pages[li] = jnp.zeros(shape, self.dtype)

    def shrink(self, n_shards: int):
        """Drop the trailing shards (host loss): capacity reshrinks to
        ``n_shards * pages_per_shard`` pages, surviving shards keep
        their pages and free lists. Every page of a dropped shard must
        already be free — the scheduler preempts the requests living
        there first ("preempt to fit")."""
        if not (1 <= n_shards < self.n_shards):
            raise PageError(f"shrink to {n_shards} shards from "
                            f"{self.n_shards} is not a shrink")
        if self._seized:
            raise PageError(f"cannot shrink with {self._seized} seized "
                            f"pages in flight")
        pps = self.pages_per_shard
        for h in range(n_shards, self.n_shards):
            if len(self._shard_free[h]) != pps - 1 or self._shard_reserved[h]:
                raise PageError(
                    f"shard {h} still has live/reserved pages "
                    f"({pps - 1 - len(self._shard_free[h])} live, "
                    f"{self._shard_reserved[h]} reserved); preempt its "
                    f"requests before shrinking")
        self.n_shards = n_shards
        self.n_pages = n_shards * pps
        self._shard_free = self._shard_free[:n_shards]
        self._shard_reserved = self._shard_reserved[:n_shards]
        for li in self.k_pages:
            self.k_pages[li] = self.k_pages[li][:self.n_pages]
            self.v_pages[li] = self.v_pages[li][:self.n_pages]

    # -- snapshot --------------------------------------------------------
    def snapshot(self) -> dict:
        """Host-side copy of accounting + page storage (numpy-backed)."""
        return {"free": [p for f in self._shard_free for p in f],
                "reserved": self._reserved,
                "reserved_by": list(self._shard_reserved),
                "n_shards": self.n_shards,
                "seized": self._seized,
                "k_pages": {li: np.asarray(a)
                            for li, a in self.k_pages.items()},
                "v_pages": {li: np.asarray(a)
                            for li, a in self.v_pages.items()}}

    def restore(self, snap: dict):
        if set(snap["k_pages"]) != set(self.k_pages):
            raise PageError("snapshot layer set does not match this pool")
        if snap.get("n_shards", 1) != self.n_shards:
            raise PageError(f"snapshot has {snap.get('n_shards', 1)} "
                            f"shards, pool has {self.n_shards}")
        flat = list(snap["free"])
        self._shard_free = [[p for p in flat if self.shard_of(p) == h]
                            for h in range(self.n_shards)]
        rby = snap.get("reserved_by")
        if rby is not None:
            self._shard_reserved = [int(r) for r in rby]
        else:
            self._shard_reserved = [int(snap["reserved"])] + \
                [0] * (self.n_shards - 1)
        self._seized = int(snap.get("seized", 0))
        for li in self.k_pages:
            self.k_pages[li] = jnp.asarray(snap["k_pages"][li], self.dtype)
            self.v_pages[li] = jnp.asarray(snap["v_pages"][li], self.dtype)

    # -- storage --------------------------------------------------------
    def write_prefill(self, li: int, pages: List[int], k, v):
        """Scatter a prefilled (S, Hkv, Dh) K/V slab into ``pages``.
        S is padded up to a whole number of pages (pad rows are past the
        sequence position, hence masked at attention time)."""
        ps = self.page_size
        s = k.shape[0]
        pad = len(pages) * ps - s
        if pad < 0:
            raise PageError(f"{len(pages)} pages cannot hold {s} tokens")
        idx = jnp.asarray(pages, jnp.int32)
        kp = jnp.pad(k, ((0, pad), (0, 0), (0, 0))).reshape(
            len(pages), ps, *k.shape[1:]).astype(self.dtype)
        vp = jnp.pad(v, ((0, pad), (0, 0), (0, 0))).reshape(
            len(pages), ps, *v.shape[1:]).astype(self.dtype)
        self.k_pages[li] = self.k_pages[li].at[idx].set(kp)
        self.v_pages[li] = self.v_pages[li].at[idx].set(vp)
