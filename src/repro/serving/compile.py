"""The compiled serving decode step (ROADMAP: serve-heavy-traffic).

One whole decode step — embed, every layer's attention/SSM + FFN over the
paged KV cache, final norm + logits — is built as a single ``@dc_program``
SDFG and lowered through ``default_pipeline("pallas")``. The attention of
each layer enters the graph as a :class:`~repro.library.PagedAttnDecode`
Library Node whose ``pallas`` expansion is a (b, h) mapped tasklet, so
MapTiling + GridConversion turn it into a batched Pallas grid kernel
inside the compiled step (it shows up in ``Compiled.report``'s
``grid_kernels``). Everything around it — QKV projection + RoPE, the
paged KV write, the page gather, FFN/MoE, RWKV/Mamba state updates — are
jnp tasklets replicating ``models.blocks`` decode math exactly, so the
compiled step matches ``TransformerLM.decode_step`` token for token.

Shape bucketing: the step is specialized on ``(B, ctx)`` — the padded
batch bucket and the context bucket (a multiple of the page size covering
the longest live sequence). Each bucket is one SDFG whose content hash
keys the process-wide ``COMPILATION_CACHE``; re-entering a bucket is a
cache hit, no re-lowering. Padding lanes ride along: their block-table
rows are zero, so their KV writes land on the pool's null page and their
attention reads garbage that the ``j <= pos`` mask never admits.

Why this beats ``jax.jit(model.decode_step)``: the baseline attends over
the full dense ``max_model_len`` cache every step and re-threads the
whole (B, Smax, Hkv, Dh) cache through the jit boundary; the compiled
step attends over the (much smaller) live context bucket, gathers only
the pages the block table names, and donates the page/state buffers.
"""
from __future__ import annotations

from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..core.memlet import Memlet
from ..frontends.api import Program, TensorHandle, dc_program
from ..library import PagedAttnDecode
from ..models import blocks
from ..models.layers import apply_rope, layer_norm, rms_norm
from ..pipeline.cache import COMPILATION_CACHE, CompilationCache
from ..pipeline.passes import (ExpandLibraryNodesPass, GridConversionPass,
                               MapFusionPass, MapTilingPass, PassManager,
                               PipelineFusionPass, SetExpansionPreferencePass,
                               VectorizationPass, default_pipeline)


# ---------------------------------------------------------------------------
# Model introspection: flat layer order, weight/state naming
# ---------------------------------------------------------------------------
def flat_layer_specs(model) -> List:
    """Layer specs in execution order: period scan unrolled, then tail."""
    specs = []
    for _ in range(model.n_periods):
        specs.extend(model.period_specs)
    specs.extend(model.tail_specs)
    return specs


def attention_layer_shapes(model) -> Dict[int, Tuple[int, int]]:
    """flat layer index -> (n_kv_heads, head_dim) for every attn layer."""
    cfg = model.cfg
    return {li: (cfg.n_kv_heads, cfg.head_dim)
            for li, spec in enumerate(flat_layer_specs(model))
            if spec.kind == "attn"}


def flatten_params(model, params) -> Dict[str, jnp.ndarray]:
    """Stacked tree -> flat ``L{li}__{group}__{key}`` arrays (+ head/embed).

    Iteration order is deterministic (periods outer, positions inner,
    matching the scan's execution order), so two flattenings of the same
    model produce identical container orders and the built SDFGs
    content-hash equal.
    """
    out: Dict[str, jnp.ndarray] = {"embed": params["embed"]}
    li = 0
    for pp in range(model.n_periods):
        for pi in range(len(model.period_specs)):
            for gname, gdict in params["body"][pi].items():
                for k, a in gdict.items():
                    out[f"L{li}__{gname}__{k}"] = a[pp]
            li += 1
    for ti in range(len(model.tail_specs)):
        for gname, gdict in params["tail"][ti].items():
            for k, a in gdict.items():
                out[f"L{li}__{gname}__{k}"] = a
        li += 1
    out["final_scale"] = params["final_scale"]
    if "final_bias" in params:
        out["final_bias"] = params["final_bias"]
    if not model.cfg.tie_embeddings:
        out["lm_head"] = params["lm_head"]
    return out


def state_specs(model) -> Dict[str, Tuple[int, Tuple[int, ...], str]]:
    """Per-slot recurrent-state rows for non-attention layers:
    ``st{li}__{key}`` -> (flat layer index, per-row shape, dtype)."""
    cfg = model.cfg
    out: Dict[str, Tuple[int, Tuple[int, ...], str]] = {}
    for li, spec in enumerate(flat_layer_specs(model)):
        if spec.kind == "rwkv":
            one = blocks.rwkv_cache_init(cfg, 1)
        elif spec.kind == "mamba":
            one = blocks.mamba_cache_init(cfg, 1)
        else:
            continue
        for key in sorted(one):
            a = one[key]
            out[f"st{li}__{key}"] = (li, tuple(a.shape[1:]), str(a.dtype))
    return out


# ---------------------------------------------------------------------------
# SDFG builder
# ---------------------------------------------------------------------------
def _tasklet(p: Program, label: str, ins: Dict[str, TensorHandle],
             outs: Dict[str, object], fn) -> Dict[str, TensorHandle]:
    """Wire one tasklet. ``outs`` values are either an existing handle (an
    in/out container — gets a fresh access-node version) or a
    ``(shape, dtype)`` tuple (a new transient)."""
    st = p.state
    t = st.add_tasklet(label, list(ins), list(outs), fn)
    for conn, h in ins.items():
        st.add_edge(h.read_node(), None, t, conn, Memlet.simple(h.name))
    res = {}
    for conn, spec in outs.items():
        if isinstance(spec, tuple):
            h = p.temp(spec[0], spec[1], name=f"{label}_{conn}")
        else:
            h = spec
        st.add_edge(t, conn, h.fresh_write_node(), None,
                    Memlet.simple(h.name))
        res[conn] = h
    return res


@dc_program
def serving_decode_step(p: Program, model=None, wspecs=None, B=None,
                        ctx=None, page_size=None, n_pages=None,
                        cache_dtype="bfloat16"):
    """One full decode step over the paged cache, specialized on (B, ctx).

    Inputs: tokens (B,1) i32, positions (B,) i32, block_table
    (B, ctx/page_size) i32, flat weights, per-attention-layer page arrays
    kp{li}/vp{li}, per-recurrent-layer state rows st{li}__*. Outputs:
    logits (B, V) plus the updated page/state containers (donated by the
    step wrapper).
    """
    cfg = model.cfg
    adt = cfg.activation_dtype
    H, Hkv, dh = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    D = cfg.d_model
    ps = page_size
    n_bt = ctx // ps
    vocab_padded = model.vocab_padded
    specs = flat_layer_specs(model)
    sspecs = state_specs(model)

    tokens = p.input("tokens", (B, 1), "int32")
    positions = p.input("positions", (B,), "int32")
    bt = p.input("block_table", (B, n_bt), "int32")
    wh = {name: p.input(name, shape, dt)
          for name, (shape, dt) in wspecs.items()}
    kph, vph = {}, {}
    for li, spec in enumerate(specs):
        if spec.kind == "attn":
            shape = (n_pages, ps, Hkv, dh)
            kph[li] = p.input(f"kp{li}", shape, cache_dtype)
            vph[li] = p.input(f"vp{li}", shape, cache_dtype)
    sth = {name: p.input(name, (B,) + shape, dt)
           for name, (li, shape, dt) in sspecs.items()}

    def embed_fn(tokens, embed):
        return {"x": jnp.take(embed, tokens[:, 0], axis=0
                              ).astype(jnp.dtype(adt))}

    x = _tasklet(p, "embed", {"tokens": tokens, "embed": wh["embed"]},
                 {"x": ((B, D), adt)}, embed_fn)["x"]

    for li, spec in enumerate(specs):
        w = lambda g, k: wh[f"L{li}__{g}__{k}"]
        if spec.kind == "attn":
            x = _attn_layer(p, cfg, li, spec, x, positions, bt, w,
                            kph[li], vph[li], B, ctx, ps)
            x = _ffn_layer(p, cfg, li, spec, x, w, B, D)
        elif spec.kind == "mamba":
            x = _recurrent_layer(p, cfg, li, "mamba", blocks.mamba_apply,
                                 x, w, sth, sspecs, B, D)
            x = _ffn_layer(p, cfg, li, spec, x, w, B, D)
        elif spec.kind == "rwkv":
            x = _recurrent_layer(p, cfg, li, "rwkv", blocks.rwkv_apply,
                                 x, w, sth, sspecs, B, D)
        else:
            raise ValueError(f"unknown layer kind {spec.kind!r}")

    head_ins = {"x": x, "final_scale": wh["final_scale"]}
    if cfg.norm == "layernorm":
        head_ins["final_bias"] = wh["final_bias"]
    if cfg.tie_embeddings:
        head_ins["embed"] = wh["embed"]
    else:
        head_ins["lm_head"] = wh["lm_head"]

    def head_fn(x, final_scale, final_bias=None, embed=None, lm_head=None):
        xs = x[:, None, :]
        if cfg.norm == "rmsnorm":
            xs = rms_norm(xs, final_scale)
        else:
            xs = layer_norm(xs, final_scale + 1.0, final_bias)
        jadt = jnp.dtype(adt)
        head = embed.T if cfg.tie_embeddings else lm_head
        lg = jnp.einsum("bsd,dv->bsv", xs.astype(jadt), head.astype(jadt))
        if cfg.tie_embeddings:
            lg = lg * np.float32(1.0 / np.sqrt(cfg.d_model)
                                 ).astype(lg.dtype)
        if vocab_padded != cfg.vocab:
            pad = jnp.arange(vocab_padded) >= cfg.vocab
            lg = jnp.where(pad, jnp.asarray(-1e30, lg.dtype), lg)
        return {"logits": lg[:, 0]}

    lg = _tasklet(p, "head", head_ins,
                  {"logits": ((B, vocab_padded), adt)}, head_fn)["logits"]
    p.output("logits", lg)

    # Builder-declared partition hints for ShardMapPass (inert unless the
    # lowering pipeline actually shards): per-slot containers split on the
    # batch/slot dim, page arrays on the page dim (each host owns one
    # contiguous page block and the block table it receives is localized
    # to it), weights replicate. The tasklet closures above are all
    # batch-row-wise (``reshape(-1, ...)``), so they run unchanged on the
    # shard-local row blocks.
    declared = {"tokens": 0, "positions": 0, "block_table": 0, "logits": 0}
    declared.update({name: None for name in wspecs})
    for li in kph:
        declared[f"kp{li}"] = 0
        declared[f"vp{li}"] = 0
    declared.update({name: 0 for name in sspecs})
    p.sdfg.metadata["shard_declared"] = declared


def _attn_layer(p, cfg, li, spec, x, positions, bt, w, kp, vp, B, ctx, ps):
    """QKV -> paged KV write -> page gather -> PagedAttnDecode -> proj.

    The tasklet math mirrors ``blocks.attn_apply``'s decode branch
    exactly (same casts, same op order) so the compiled step reproduces
    ``decode_step`` bit-for-bit on the positions the mask admits.
    """
    adt = cfg.activation_dtype
    H, Hkv, dh = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    D = cfg.d_model
    cache_dtype = p.sdfg.arrays[kp.name].dtype.name

    qkv_ins = {"x": x, "positions": positions, "wq": w("attn", "wq"),
               "wk": w("attn", "wk"), "wv": w("attn", "wv"),
               "ln_scale": w("attn", "ln_scale")}
    if cfg.norm == "layernorm":
        qkv_ins["ln_bias"] = w("attn", "ln_bias")

    def qkv_fn(x, positions, wq, wk, wv, ln_scale, ln_bias=None):
        jadt = jnp.dtype(adt)
        pn = {"ln_scale": ln_scale}
        if ln_bias is not None:
            pn["ln_bias"] = ln_bias
        xs = x[:, None, :]
        h = blocks._norm(cfg, xs, pn, "ln").astype(jadt)
        q = jnp.einsum("bsd,dh->bsh", h, wq.astype(jadt)
                       ).reshape(-1, 1, H, dh)
        k = jnp.einsum("bsd,dh->bsh", h, wk.astype(jadt)
                       ).reshape(-1, 1, Hkv, dh)
        v = jnp.einsum("bsd,dh->bsh", h, wv.astype(jadt)
                       ).reshape(-1, 1, Hkv, dh)
        pos2 = positions[:, None]
        q = apply_rope(q, pos2, cfg.rope_theta)
        k = apply_rope(k, pos2, cfg.rope_theta)
        cdt = jnp.dtype(cache_dtype)
        return {"q": q[:, 0], "k_new": k[:, 0].astype(cdt),
                "v_new": v[:, 0].astype(cdt)}

    qkv = _tasklet(p, f"qkv{li}", qkv_ins,
                   {"q": ((B, H, dh), adt),
                    "k_new": ((B, Hkv, dh), cache_dtype),
                    "v_new": ((B, Hkv, dh), cache_dtype)}, qkv_fn)

    def kvw_fn(kp, vp, k_new, v_new, bt, positions):
        page = jnp.take_along_axis(bt, positions[:, None] // ps,
                                   axis=1)[:, 0]
        off = positions % ps
        return {"kp_out": kp.at[page, off].set(k_new),
                "vp_out": vp.at[page, off].set(v_new)}

    _tasklet(p, f"kvw{li}",
             {"kp": kp, "vp": vp, "k_new": qkv["k_new"],
              "v_new": qkv["v_new"], "bt": bt, "positions": positions},
             {"kp_out": kp, "vp_out": vp}, kvw_fn)

    def gather_fn(kp, vp, bt):
        jadt = jnp.dtype(adt)
        rep = H // Hkv

        def expand(pages):
            c = pages[bt].reshape(-1, ctx, Hkv, dh)
            if rep > 1:
                b = c.shape[0]
                c = jnp.broadcast_to(c[:, :, :, None, :],
                                     (b, ctx, Hkv, rep, dh)
                                     ).reshape(b, ctx, H, dh)
            return c.astype(jadt)

        return {"ck": expand(kp), "cv": expand(vp)}

    g = _tasklet(p, f"gather{li}", {"kp": kp, "vp": vp, "bt": bt},
                 {"ck": ((B, ctx, H, dh), adt),
                  "cv": ((B, ctx, H, dh), adt)}, gather_fn)

    node = PagedAttnDecode(f"attn{li}", window=spec.window)
    attn = p.add_op(node, {"q": qkv["q"], "k": g["ck"], "v": g["cv"],
                           "pos": positions},
                    out_shapes={"out": (B, H, dh)},
                    out_dtypes={"out": adt})

    def proj_fn(x, attn, wo):
        jadt = jnp.dtype(adt)
        out = jnp.einsum("bsh,hd->bsd", attn.reshape(-1, 1, H * dh),
                         wo.astype(jadt))
        return {"x": (x[:, None, :] + out.astype(x.dtype))[:, 0]}

    return _tasklet(p, f"proj{li}",
                    {"x": x, "attn": attn, "wo": w("attn", "wo")},
                    {"x": ((B, D), adt)}, proj_fn)["x"]


def _ffn_layer(p, cfg, li, spec, x, w, B, D):
    adt = cfg.activation_dtype
    is_moe = spec.is_moe
    keys = sorted(k for k in p.sdfg.arrays
                  if k.startswith(f"L{li}__ffn__"))
    short = [k.split("__", 2)[2] for k in keys]

    def ffn_fn(x, **pw):
        y, _ = blocks.ffn_apply(cfg, pw, x[:, None, :], is_moe)
        return {"x": y[:, 0]}

    ins = {"x": x}
    ins.update({s: w("ffn", s) for s in short})
    return _tasklet(p, f"ffn{li}", ins, {"x": ((B, D), adt)}, ffn_fn)["x"]


def _recurrent_layer(p, cfg, li, kind, apply_fn, x, w, sth, sspecs, B, D):
    """RWKV / Mamba layer: one tasklet threading per-slot state rows.

    Rows are independent under both blocks (per-position norms, einsums
    over feature dims only), so padding lanes evolve garbage state in
    their own rows without touching live slots.
    """
    adt = cfg.activation_dtype
    skeys = [name for name, (sli, _, _) in sspecs.items() if sli == li]
    short = {name: name.split("__", 1)[1] for name in skeys}
    pkeys = sorted(k for k in p.sdfg.arrays
                   if k.startswith(f"L{li}__{kind}__"))
    pshort = [k.split("__", 2)[2] for k in pkeys]
    cache_keys = sorted(short.values())

    def rec_fn(x, **kw):
        cache = {ck: kw.pop(ck) for ck in cache_keys}
        y, nc = apply_fn(cfg, kw, x[:, None, :], cache=cache)
        out = {"x": y[:, 0]}
        for ck in cache_keys:
            out[f"{ck}_out"] = nc[ck]
        return out

    ins = {"x": x}
    ins.update({s: w(kind, s) for s in pshort})
    ins.update({short[name]: sth[name] for name in skeys})
    outs = {"x": ((B, D), adt)}
    outs.update({f"{short[name]}_out": sth[name] for name in skeys})
    return _tasklet(p, f"{kind}{li}", ins, outs, rec_fn)["x"]


# ---------------------------------------------------------------------------
# Pipelines + bucketed compile wrapper
# ---------------------------------------------------------------------------
def decode_pipeline(interpret: bool = True,
                    dtype_aware_sublanes: bool = False,
                    n_shards: int = 1, shard_axis: str = "shard",
                    mesh_sig: Optional[str] = None) -> PassManager:
    """The serving lowering pipeline.

    Default: ``default_pipeline("pallas")`` (calibrated CPU-interpret
    tiles). With ``dtype_aware_sublanes`` the second-minor tile falls back
    to MapTiling's per-scope dtype-aware sublane packing (bf16 -> 16-row
    blocks, fp32 -> 8), exercising the per-dtype block shapes instead of
    the calibrated crossover table. ``n_shards > 1`` inserts
    ``ShardMapPass`` (after MapFusion, before tiling) so the step's slot
    and page containers partition across a 1-D mesh — tiles and grids
    then derive from the shard-local shapes.
    """
    if not dtype_aware_sublanes:
        return default_pipeline("pallas", interpret=interpret,
                                n_shards=n_shards, shard_axis=shard_axis,
                                mesh_sig=mesh_sig)
    from ..pipeline.passes import ShardMapPass
    shard = [ShardMapPass(n_shards=n_shards, axis=shard_axis,
                          mesh_sig=mesh_sig)] if n_shards > 1 else []
    tiles = GridConversionPass.default_tiles("pallas", interpret)
    return PassManager([
        SetExpansionPreferencePass(("pallas", "xla", "generic")),
        PipelineFusionPass(interpret=interpret),
        ExpandLibraryNodesPass(),
        MapFusionPass(),
        *shard,
        VectorizationPass(),
        MapTilingPass(tile_size=tiles.get("minor"), second_size=None),
        GridConversionPass(),
    ], name="pallas_serve_dtype" if not shard
        else "pallas_serve_dtype_sharded")


class CompiledDecodeStep:
    """One (B, ctx) bucket: positional jit wrapper with buffer donation.

    ``Compiled.fn`` is kwargs-only; jax donation is positional, so the
    wrapper pins the argument order (``Compiled.argument_names()``) and
    donates the page/state containers — the step consumes last step's
    pages and returns this step's without a copy.

    ``donate=False`` keeps the inputs alive (the fault-tolerant mode: a
    failed step can be re-run from the same inputs), and ``rung`` names
    the degradation-ladder level this step was compiled at (``"grid"``
    for the Pallas pipeline, ``"jit"`` for the jnp fallback).
    """

    def __init__(self, compiled, donate_names, donate: bool = True,
                 rung: str = "grid"):
        from ..codegen.jnp_backend import classify_arguments
        self.compiled = compiled
        self.report = compiled.report
        self.donate = donate
        self.rung = rung
        self.arg_names, self.output_names = classify_arguments(compiled.sdfg)
        names = self.arg_names
        fn = compiled.fn
        donate = tuple(i for i, n in enumerate(names) if n in donate_names) \
            if donate else ()

        def positional(*args):
            return fn(**dict(zip(names, args)))

        self._jit = jax.jit(positional, donate_argnums=donate)

    def __call__(self, kwargs: Dict[str, jnp.ndarray]) -> Dict:
        return self._jit(*(kwargs[n] for n in self.arg_names))


class DecodeStepCompiler:
    """Shape-bucketed compiles of the serving decode step.

    Owns the flattened weights and hands back a :class:`CompiledDecodeStep`
    per (B, ctx) bucket. Lowered SDFGs are served by the (shared, LRU)
    ``CompilationCache``: identical buckets — across scheduler restarts or
    separate compiler instances sharing a cache — hit without re-lowering.

    Graceful degradation: a bucket whose Pallas grid compile raises is
    served by the jnp-jit fallback (same SDFG, ``backend="jnp"`` — token
    for token the same step) instead of killing the server. Every
    degradation is a typed entry in ``events`` (``compile_fallback`` /
    ``compile_retry_failed`` / ``compile_recovered``), and subsequent
    hits on the bucket retry the grid compile with capped exponential
    backoff (1, 2, 4, ... ``max_compile_backoff`` bucket hits between
    attempts). ``compile_fault`` is the injection seam: a callable
    ``(B, ctx) -> None`` invoked before each grid compile (the
    fault-injection harness installs one that raises).
    """

    def __init__(self, model, params, *, page_size: int, n_pages: int,
                 cache_dtype="bfloat16", interpret: bool = True,
                 dtype_aware_sublanes: bool = False,
                 cache: Optional[CompilationCache] = None,
                 donate: bool = True, max_compile_backoff: int = 32,
                 n_shards: int = 1, shard_axis: str = "shard",
                 mesh_sig: Optional[str] = None):
        self.model = model
        self.page_size = page_size
        self.n_pages = n_pages
        self.cache_dtype = str(jnp.dtype(cache_dtype))
        self.interpret = interpret
        self.dtype_aware_sublanes = dtype_aware_sublanes
        self.cache = COMPILATION_CACHE if cache is None else cache
        self.donate = donate
        self.max_compile_backoff = max_compile_backoff
        if n_shards > 1 and n_pages % n_shards:
            raise ValueError(f"n_pages {n_pages} not divisible by "
                             f"n_shards {n_shards}")
        self.n_shards = int(n_shards)
        self.shard_axis = shard_axis
        self.mesh_sig = mesh_sig
        self.compile_fault = None  # optional fn(B, ctx) raising to inject
        self.events: List[dict] = []
        self.flat_weights = flatten_params(model, params)
        self._wspecs = {n: (tuple(int(s) for s in a.shape), str(a.dtype))
                        for n, a in self.flat_weights.items()}
        self._steps: Dict[Tuple[int, int], CompiledDecodeStep] = {}
        self._fallbacks: Dict[Tuple[int, int], CompiledDecodeStep] = {}
        #: per-bucket grid-compile failure state for the backoff retry
        self._fail: Dict[Tuple[int, int], dict] = {}
        self._donate = (
            {f"kp{li}" for li in attention_layer_shapes(model)} |
            {f"vp{li}" for li in attention_layer_shapes(model)} |
            set(state_specs(model)))

    def _lowered(self, B: int, ctx: int):
        low = serving_decode_step.lower(
            model=self.model, wspecs=self._wspecs, B=B, ctx=ctx,
            page_size=self.page_size, n_pages=self.n_pages,
            cache_dtype=self.cache_dtype)
        # record the donation intent on the SDFG so the static verifier
        # (analysis.bounds, DON001/DON002) can prove every donated buffer
        # is genuinely consumed-and-rewritten rather than aliased
        low.sdfg.metadata["donated"] = sorted(self._donate)
        return low

    def _check_sharded(self, compiled, B: int, ctx: int):
        """A sharded compiler must never silently serve an unsharded
        step: a ShardMapPass refusal here is a hard, typed error."""
        if self.n_shards <= 1:
            return compiled
        info = compiled.report.get("shard_map") or {}
        if not info.get("sharded"):
            reasons = [d for d in compiled.report.get("grid_decisions", ())
                       if d.get("decision") in ("unsharded", "shard_refused")]
            raise RuntimeError(
                f"decode step bucket (B={B}, ctx={ctx}) did not shard "
                f"across {self.n_shards} hosts: {reasons}")
        return compiled

    def _compile_grid(self, B: int, ctx: int) -> CompiledDecodeStep:
        if self.compile_fault is not None:
            self.compile_fault(B, ctx)
        compiled = self._check_sharded(self._lowered(B, ctx).compile(
            backend="pallas", interpret=self.interpret,
            pipeline=decode_pipeline(self.interpret,
                                     self.dtype_aware_sublanes,
                                     n_shards=self.n_shards,
                                     shard_axis=self.shard_axis,
                                     mesh_sig=self.mesh_sig),
            cache=self.cache), B, ctx)
        return CompiledDecodeStep(compiled, self._donate,
                                  donate=self.donate, rung="grid")

    def _compile_jit(self, B: int, ctx: int,
                     donate: bool) -> CompiledDecodeStep:
        compiled = self._check_sharded(self._lowered(B, ctx).compile(
            backend="jnp", cache=self.cache,
            pipeline=default_pipeline("jnp", n_shards=self.n_shards,
                                      shard_axis=self.shard_axis,
                                      mesh_sig=self.mesh_sig)), B, ctx)
        return CompiledDecodeStep(compiled, self._donate, donate=donate,
                                  rung="jit")

    def fallback_for(self, B: int, ctx: int) -> CompiledDecodeStep:
        """The jnp-jit rung for a bucket, never donating — a failed grid
        step is re-run through it from the still-live inputs."""
        fb = self._fallbacks.get((B, ctx))
        if fb is None:
            fb = self._compile_jit(B, ctx, donate=False)
            self._fallbacks[(B, ctx)] = fb
        return fb

    def step_for(self, B: int, ctx: int) -> CompiledDecodeStep:
        if ctx % self.page_size:
            raise ValueError(f"ctx bucket {ctx} not a multiple of the "
                             f"page size {self.page_size}")
        key = (B, ctx)
        step = self._steps.get(key)
        fail = self._fail.get(key)
        if step is not None and fail is not None:
            # degraded bucket: retry the grid compile with capped backoff
            fail["hits_since"] += 1
            if fail["hits_since"] >= fail["backoff"]:
                try:
                    step = self._compile_grid(B, ctx)
                    self._steps[key] = step
                    self.events.append({
                        "kind": "compile_recovered", "bucket": key,
                        "after_failures": fail["failures"]})
                    del self._fail[key]
                except Exception as e:  # noqa: BLE001 - stays degraded
                    fail["failures"] += 1
                    fail["hits_since"] = 0
                    fail["backoff"] = min(fail["backoff"] * 2,
                                          self.max_compile_backoff)
                    self.events.append({
                        "kind": "compile_retry_failed", "bucket": key,
                        "error": repr(e),
                        "next_retry_after": fail["backoff"]})
            return self._steps[key]
        if step is None:
            try:
                step = self._compile_grid(B, ctx)
            except Exception as e:  # noqa: BLE001 - degrade, don't die
                self.events.append({"kind": "compile_fallback",
                                    "bucket": key, "error": repr(e),
                                    "rung": "jit"})
                self._fail[key] = {"failures": 1, "hits_since": 0,
                                   "backoff": 1}
                step = self._compile_jit(B, ctx, donate=self.donate)
            self._steps[key] = step
        return step
