"""Continuous-batching scheduler over the paged KV cache.

Requests are admitted/evicted *between* compiled decode steps. Admission
is reservation-based: a request enters only when a free slot exists and
the pool can reserve its worst-case page count (prompt + max_new_tokens),
so a running request can never be starved of pages mid-decode. Prefill is
chunked — the prompt runs through ``model.decode_step`` in fixed-size
chunks against a small dense scratch cache, then the K/V slab is
scattered into freshly bound pages and the scratch is dropped; chunked
and whole-prompt prefill agree bit-for-bit because ``decode_step`` masks
by absolute position, not by chunk boundary.

Each step runs one (B, ctx)-bucketed compiled SDFG step
(:mod:`.compile`): B is the smallest bucket covering the highest occupied
slot, ctx the smallest page-multiple bucket covering the longest live
sequence. Padding lanes carry zeroed block-table rows (-> null page) and
position 0; their logits are never sampled. Eviction frees the request's
pages, returns its unused reservation, zeroes its block-table row, and
the next admission reuses both the slot and the pages — no live batch
array is ever reshaped.
"""
from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Deque, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from .compile import (DecodeStepCompiler, attention_layer_shapes,
                      flat_layer_specs, state_specs)
from .pages import KVPagePool, PageError


@dataclasses.dataclass
class Request:
    rid: int
    prompt: List[int]
    max_new_tokens: int
    eos_id: Optional[int] = None
    # -- scheduler-owned runtime state --
    slot: int = -1
    pos: int = 0                      # next KV write position
    tokens_out: List[int] = dataclasses.field(default_factory=list)
    pages: List[int] = dataclasses.field(default_factory=list)
    reserved_left: int = 0
    submit_time: float = 0.0
    first_token_time: float = 0.0
    token_times: List[float] = dataclasses.field(default_factory=list)
    done: bool = False

    @property
    def ttft(self) -> float:
        return self.first_token_time - self.submit_time


def _pow2_at_least(n: int) -> int:
    return 1 << max(0, (n - 1).bit_length())


class Scheduler:
    """Continuous batching + chunked prefill over compiled decode steps."""

    def __init__(self, model, params, *, max_slots: int = 8,
                 page_size: int = 16, n_pages: int = 64,
                 max_model_len: int = 256, prefill_chunk: int = 8,
                 cache_dtype="bfloat16",
                 compiler: Optional[DecodeStepCompiler] = None,
                 interpret: bool = True,
                 dtype_aware_sublanes: bool = False, compile_cache=None,
                 temperature: float = 0.0, top_k: Optional[int] = None,
                 seed: int = 0):
        if max_model_len % page_size:
            raise ValueError("max_model_len must be a multiple of "
                             f"page_size ({page_size}), got {max_model_len}")
        self.model = model
        self.params = params
        self.cfg = model.cfg
        self.max_slots = max_slots
        self.page_size = page_size
        self.max_model_len = max_model_len
        self.prefill_chunk = prefill_chunk
        self.pool = KVPagePool(attention_layer_shapes(model), n_pages,
                               page_size, dtype=cache_dtype)
        self.compiler = compiler or DecodeStepCompiler(
            model, params, page_size=page_size, n_pages=n_pages,
            cache_dtype=cache_dtype, interpret=interpret,
            dtype_aware_sublanes=dtype_aware_sublanes, cache=compile_cache)
        self.block_table = np.zeros(
            (max_slots, max_model_len // page_size), np.int32)
        self._sspecs = state_specs(model)
        self.states: Dict[str, jnp.ndarray] = {
            name: jnp.zeros((max_slots,) + shape, dt)
            for name, (li, shape, dt) in self._sspecs.items()}
        if temperature < 0.0:
            raise ValueError(f"temperature must be >= 0, got {temperature}")
        if top_k is not None and top_k < 1:
            raise ValueError(f"top_k must be >= 1, got {top_k}")
        self.temperature = float(temperature)
        self.top_k = top_k
        self._rng = np.random.default_rng(seed)
        self.slots: List[Optional[Request]] = [None] * max_slots
        self.queue: Deque[Request] = deque()
        self.finished: List[Request] = []
        self.last_logits = None
        self._next_rid = 0
        self._prefill_step = jax.jit(model.decode_step)
        self.n_steps = 0

    # -- submission / admission -----------------------------------------
    def submit(self, prompt: List[int], max_new_tokens: int,
               eos_id: Optional[int] = None) -> int:
        if not prompt:
            raise ValueError("empty prompt")
        if len(prompt) >= self.max_model_len:
            raise ValueError(f"prompt of {len(prompt)} tokens >= "
                             f"max_model_len {self.max_model_len}")
        rid = self._next_rid
        self._next_rid += 1
        req = Request(rid, list(prompt), max_new_tokens, eos_id,
                      submit_time=time.perf_counter())
        self.queue.append(req)
        return rid

    def _free_slot(self) -> Optional[int]:
        for i, r in enumerate(self.slots):
            if r is None:
                return i
        return None

    def _try_admit(self):
        while self.queue:
            slot = self._free_slot()
            if slot is None:
                return
            req = self.queue[0]
            total_tokens = min(len(req.prompt) + req.max_new_tokens,
                               self.max_model_len)
            total_pages = self.pool.pages_for(total_tokens)
            if total_pages > self.pool.available:
                return
            self.queue.popleft()
            self.pool.reserve(total_pages)
            self._admit(req, slot, total_pages)

    def _admit(self, req: Request, slot: int, total_pages: int):
        """Chunked prefill into a dense scratch cache, then scatter the
        K/V slab into pages and install the request in its slot."""
        model, params = self.model, self.params
        prompt = jnp.asarray(req.prompt, jnp.int32)[None]
        L = len(req.prompt)
        cache = model.init_cache(1, L, dtype=self.pool.dtype)
        logits = None
        i = 0
        while i < L:
            chunk = prompt[:, i:i + self.prefill_chunk]
            logits, cache = self._prefill_step(params, cache, chunk)
            i += chunk.shape[1]

        n_prompt_pages = self.pool.pages_for(L)
        pages = self.pool.alloc(n_prompt_pages)
        req.pages = pages
        req.reserved_left = total_pages - n_prompt_pages
        self.block_table[slot, :len(pages)] = pages

        for li, layer_cache in self._iter_layer_caches(cache):
            if "k" in layer_cache:  # attention
                self.pool.write_prefill(li, pages, layer_cache["k"][0, :L],
                                        layer_cache["v"][0, :L])
            else:  # recurrent state rows
                for key, a in layer_cache.items():
                    name = f"st{li}__{key}"
                    self.states[name] = self.states[name].at[slot].set(a[0])

        req.slot = slot
        req.pos = L
        self.slots[slot] = req
        first = self._sample(logits[0, -1])
        req.tokens_out.append(first)
        req.first_token_time = time.perf_counter()
        req.token_times.append(req.first_token_time - req.submit_time)
        self._maybe_finish(req, first)

    def _iter_layer_caches(self, cache):
        """(flat layer index, per-layer cache dict) in execution order."""
        pi_count = len(self.model.period_specs)
        li = 0
        for pp in range(self.model.n_periods):
            for pi in range(pi_count):
                yield li, jax.tree.map(lambda a: a[pp], cache["body"][pi])
                li += 1
        for c in cache["tail"]:
            yield li, c
            li += 1

    # -- eviction ---------------------------------------------------------
    def _maybe_finish(self, req: Request, last_token: int):
        if (len(req.tokens_out) >= req.max_new_tokens
                or (req.eos_id is not None and last_token == req.eos_id)
                or req.pos >= self.max_model_len - 1):
            self._finish(req)

    def _finish(self, req: Request):
        if req.pages:
            self.pool.free(req.pages)
        self.pool.unreserve(req.reserved_left)
        req.reserved_left = 0
        if req.slot >= 0:
            self.block_table[req.slot, :] = 0
            for name in self.states:
                self.states[name] = self.states[name].at[req.slot].set(0)
            self.slots[req.slot] = None
        req.done = True
        self.finished.append(req)

    # -- decode ----------------------------------------------------------
    def _buckets(self, active: List[Request]) -> tuple:
        top_slot = max(r.slot for r in active)
        B = min(_pow2_at_least(top_slot + 1), self.max_slots)
        longest = max(r.pos + 1 for r in active)
        pages = _pow2_at_least(self.pool.pages_for(longest))
        ctx = min(pages * self.page_size, self.max_model_len)
        return B, ctx

    def step(self) -> List[Request]:
        """Admit waiting requests, run one compiled decode step over all
        active slots, sample, and evict finished requests. Returns the
        requests that finished during this step."""
        self._try_admit()
        n_done = len(self.finished)
        active = [r for r in self.slots if r is not None]
        if not active:
            return self.finished[n_done:]

        for r in active:  # bind a fresh page when crossing a boundary
            while len(r.pages) < self.pool.pages_for(r.pos + 1):
                pg = self.pool.alloc(1)[0]
                r.reserved_left -= 1
                self.block_table[r.slot, len(r.pages)] = pg
                r.pages.append(pg)

        B, ctx = self._buckets(active)
        tokens = np.zeros((B, 1), np.int32)
        positions = np.zeros((B,), np.int32)
        for r in active:
            tokens[r.slot, 0] = r.tokens_out[-1]
            positions[r.slot] = r.pos
        n_bt = ctx // self.page_size

        kwargs = dict(self.compiler.flat_weights)
        kwargs["tokens"] = jnp.asarray(tokens)
        kwargs["positions"] = jnp.asarray(positions)
        kwargs["block_table"] = jnp.asarray(self.block_table[:B, :n_bt])
        for li in attention_layer_shapes(self.model):
            kwargs[f"kp{li}"] = self.pool.k_pages[li]
            kwargs[f"vp{li}"] = self.pool.v_pages[li]
        for name in self._sspecs:
            kwargs[name] = self.states[name][:B]

        step_fn = self.compiler.step_for(B, ctx)
        t0 = time.perf_counter()
        out = step_fn(kwargs)
        logits = out["logits"]
        logits.block_until_ready()
        dt = time.perf_counter() - t0
        self.n_steps += 1
        self.last_logits = logits

        for li in attention_layer_shapes(self.model):
            self.pool.k_pages[li] = out[f"kp{li}"]
            self.pool.v_pages[li] = out[f"vp{li}"]
        for name in self._sspecs:
            if B == self.max_slots:
                # the full slice aliased (and donated) the master buffer
                self.states[name] = out[name]
            else:
                self.states[name] = self.states[name].at[:B].set(out[name])

        rows = np.asarray(logits)
        for r in active:
            t = self._sample(rows[r.slot])
            r.pos += 1
            r.tokens_out.append(t)
            r.token_times.append(dt)
            self._maybe_finish(r, t)
        return self.finished[n_done:]

    def _sample(self, row) -> int:
        """Next token from one request's last-position logits: greedy
        argmax at ``temperature == 0`` (the default, preserving the
        token-exact reference tests), otherwise softmax sampling at the
        given temperature, optionally truncated to the ``top_k`` highest
        logits, drawn from the scheduler's seeded generator."""
        row = np.asarray(row, np.float64)
        row = row.reshape(-1, row.shape[-1])[-1]
        if self.temperature == 0.0:
            return int(row.argmax())
        logits = row / self.temperature
        if self.top_k is not None and self.top_k < logits.shape[-1]:
            kth = np.partition(logits, -self.top_k)[-self.top_k]
            logits = np.where(logits < kth, -np.inf, logits)
        logits -= logits.max()
        p = np.exp(logits)
        p /= p.sum()
        return int(self._rng.choice(p.shape[-1], p=p))

    def run(self, max_steps: int = 100000) -> List[Request]:
        """Drive until every submitted request finishes."""
        for _ in range(max_steps):
            if not self.queue and all(r is None for r in self.slots):
                break
            self.step()
        else:
            raise RuntimeError(f"did not drain within {max_steps} steps")
        return sorted(self.finished, key=lambda r: r.rid)

    # -- invariants -------------------------------------------------------
    def check_invariants(self):
        """Page accounting + block-table consistency; raises PageError."""
        live: List[int] = []
        for r in self.slots:
            if r is None:
                continue
            live.extend(r.pages)
            row = self.block_table[r.slot]
            if list(row[:len(r.pages)]) != r.pages:
                raise PageError(f"block-table row of slot {r.slot} does "
                                f"not match its pages: {row[:len(r.pages)]}"
                                f" vs {r.pages}")
            if any(row[len(r.pages):]):
                raise PageError(f"stale block-table entries in slot "
                                f"{r.slot}: {row}")
        if 0 in live:
            raise PageError("null page bound to a live request")
        if len(set(live)) != len(live):
            raise PageError(f"page bound to two live requests: {live}")
        n_accounted = self.pool.num_free + len(live)
        if n_accounted != self.pool.n_pages - 1:
            raise PageError(f"page leak: {self.pool.num_free} free + "
                            f"{len(live)} live != {self.pool.n_pages - 1}")
        reserved = sum(r.reserved_left for r in self.slots if r is not None)
        if reserved != self.pool._reserved:
            raise PageError(f"reservation drift: pool {self.pool._reserved}"
                            f" vs requests {reserved}")
        for i, r in enumerate(self.slots):
            if r is None and any(self.block_table[i]):
                raise PageError(f"free slot {i} has a non-zero "
                                "block-table row")
