"""Continuous-batching scheduler over the paged KV cache.

Requests are admitted/evicted *between* compiled decode steps. Admission
is reservation-based: a request enters only when a free slot exists and
the pool can reserve its worst-case page count (prompt + max_new_tokens),
so a running request can never be starved of pages mid-decode. Prefill is
chunked — the prompt runs through ``model.decode_step`` in fixed-size
chunks against a small dense scratch cache, then the K/V slab is
scattered into freshly bound pages and the scratch is dropped; chunked
and whole-prompt prefill agree bit-for-bit because ``decode_step`` masks
by absolute position, not by chunk boundary.

Each step runs one (B, ctx)-bucketed compiled SDFG step
(:mod:`.compile`): B is the smallest bucket covering the highest occupied
slot, ctx the smallest page-multiple bucket covering the longest live
sequence. Padding lanes carry zeroed block-table rows (-> null page) and
position 0; their logits are never sampled. Eviction frees the request's
pages, returns its unused reservation, zeroes its block-table row, and
the next admission reuses both the slot and the pages — no live batch
array is ever reshaped.

Fault tolerance (ISSUE 8) is layered around the compiled step, not into
user code:

* **Recompute preemption** — if binding a page at a boundary crossing
  raises :class:`PageError` (pool pressure, injected or real), the
  youngest admitted request is evicted with its generated tokens kept,
  re-queued at the front, and re-prefilled over prompt + generated
  tokens on readmission; the re-prefill does not re-sample, so greedy
  streams are byte-identical to an unpreempted run. A request preempted
  more than ``max_preemptions`` times finishes ``preempted_limit``.
* **Typed finish reasons** — every request ends with
  ``Request.finish_reason`` in :data:`FINISH_REASONS`; per-request
  ``deadline_s`` and the scheduler-wide ``queue_ttl_s`` expire requests
  (queued or active) with ``timeout``.
* **Degradation ladder** — a step that raises or produces non-finite
  logits on an active lane is (1) re-run through the never-donating
  jnp-jit fallback bucket when the inputs are still alive
  (``donate=False``, the default once an injector is armed), else
  (2) recovered by *recompute*: every active request is preempted with
  its tokens, the page/state arrays are re-zeroed (a donating step may
  have consumed them), and readmission re-prefills. Lanes that stay
  non-finite and steps that keep failing increment per-request
  ``n_failures``; at ``max_failures`` the request finishes ``failed``
  instead of retrying forever. Detection and the event log live in the
  :class:`~repro.serving.faults.StepWatchdog` (HeartbeatMonitor-backed).
* **Snapshot/restore** — :meth:`Scheduler.snapshot` serializes the whole
  in-flight state (queue, slots, block tables, KV pages, recurrent
  states, RNG) host-side; :meth:`Scheduler.restore` resumes token-exact
  in a fresh scheduler over the same model/config.
"""
from __future__ import annotations

import dataclasses
import time
from collections import Counter, deque
from typing import Callable, Deque, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from .compile import (DecodeStepCompiler, attention_layer_shapes,
                      state_specs)
from .faults import StepWatchdog
from .pages import KVPagePool, PageError

#: the typed ways a request can end
FINISH_REASONS = ("eos", "max_tokens", "timeout", "preempted_limit",
                  "failed")

SNAPSHOT_VERSION = 1


@dataclasses.dataclass
class Request:
    rid: int
    prompt: List[int]
    max_new_tokens: int
    eos_id: Optional[int] = None
    deadline_s: Optional[float] = None  # wall budget from submit time
    # -- scheduler-owned runtime state --
    slot: int = -1
    pos: int = 0                      # next KV write position
    tokens_out: List[int] = dataclasses.field(default_factory=list)
    pages: List[int] = dataclasses.field(default_factory=list)
    reserved_left: int = 0
    submit_time: float = 0.0
    first_token_time: float = 0.0
    token_times: List[float] = dataclasses.field(default_factory=list)
    done: bool = False
    finish_reason: Optional[str] = None  # one of FINISH_REASONS when done
    n_preemptions: int = 0
    n_failures: int = 0
    admit_seq: int = -1               # admission order; youngest = max

    @property
    def ttft(self) -> float:
        return self.first_token_time - self.submit_time


def _pow2_at_least(n: int) -> int:
    return 1 << max(0, (n - 1).bit_length())


class Scheduler:
    """Continuous batching + chunked prefill over compiled decode steps."""

    def __init__(self, model, params, *, max_slots: int = 8,
                 page_size: int = 16, n_pages: int = 64,
                 max_model_len: int = 256, prefill_chunk: int = 8,
                 cache_dtype="bfloat16",
                 compiler: Optional[DecodeStepCompiler] = None,
                 interpret: bool = True,
                 dtype_aware_sublanes: bool = False, compile_cache=None,
                 temperature: float = 0.0, top_k: Optional[int] = None,
                 seed: int = 0,
                 queue_ttl_s: Optional[float] = None,
                 max_preemptions: int = 3, max_failures: int = 3,
                 injector=None, watchdog: Optional[StepWatchdog] = None,
                 donate: Optional[bool] = None,
                 n_shards: int = 1, shard_axis: str = "shard",
                 clock: Callable[[], float] = time.perf_counter):
        if max_model_len % page_size:
            raise ValueError("max_model_len must be a multiple of "
                             f"page_size ({page_size}), got {max_model_len}")
        if n_shards > 1:
            if max_slots % n_shards:
                raise ValueError(f"max_slots {max_slots} not divisible by "
                                 f"n_shards {n_shards}")
            if n_pages % n_shards:
                raise ValueError(f"n_pages {n_pages} not divisible by "
                                 f"n_shards {n_shards}")
        self.model = model
        self.params = params
        self.cfg = model.cfg
        self.max_slots = max_slots
        self.page_size = page_size
        self.max_model_len = max_model_len
        self.prefill_chunk = prefill_chunk
        self.queue_ttl_s = queue_ttl_s
        self.max_preemptions = max_preemptions
        self.max_failures = max_failures
        self.injector = injector
        self._clock = clock
        self.n_shards = int(n_shards)
        self.shard_axis = shard_axis
        self._spb = max_slots // self.n_shards  # slots per host shard
        self.mesh_sig = self._mesh_sig(self.n_shards)
        self.interpret = interpret
        self.dtype_aware_sublanes = dtype_aware_sublanes
        self.pool = KVPagePool(attention_layer_shapes(model), n_pages,
                               page_size, dtype=cache_dtype,
                               n_shards=self.n_shards)
        if donate is None:
            # donation consumes the step inputs, which forecloses the
            # re-run-from-same-inputs recovery rung; an armed injector
            # implies fault-tolerant mode, so default donation off there
            donate = injector is None
        self.compiler = compiler or DecodeStepCompiler(
            model, params, page_size=page_size, n_pages=n_pages,
            cache_dtype=cache_dtype, interpret=interpret,
            dtype_aware_sublanes=dtype_aware_sublanes, cache=compile_cache,
            donate=donate, n_shards=self.n_shards, shard_axis=shard_axis,
            mesh_sig=self.mesh_sig)
        self.watchdog = watchdog or StepWatchdog()
        self.block_table = np.zeros(
            (max_slots, max_model_len // page_size), np.int32)
        self._sspecs = state_specs(model)
        self.states: Dict[str, jnp.ndarray] = self._zero_states()
        if temperature < 0.0:
            raise ValueError(f"temperature must be >= 0, got {temperature}")
        if top_k is not None and top_k < 1:
            raise ValueError(f"top_k must be >= 1, got {top_k}")
        self.temperature = float(temperature)
        self.top_k = top_k
        self._rng = np.random.default_rng(seed)
        self.slots: List[Optional[Request]] = [None] * max_slots
        self.queue: Deque[Request] = deque()
        self.finished: List[Request] = []
        self.last_logits = None
        self.events: List[dict] = []
        self.n_preemptions = 0
        self.n_fallback_steps = 0
        self.n_recomputes = 0
        self._next_rid = 0
        self._admit_seq = 0
        self._prefill_step = jax.jit(model.decode_step)
        self.n_steps = 0         # scheduler iterations — the fault clock
        self.n_decode_steps = 0  # compiled decode steps actually executed
        if injector is not None:
            injector.attach(self)

    def _zero_states(self) -> Dict[str, jnp.ndarray]:
        return {name: jnp.zeros((self.max_slots,) + shape, dt)
                for name, (li, shape, dt) in self._sspecs.items()}

    def _mesh_sig(self, n_shards: int) -> Optional[str]:
        """Canonical signature of the device mesh this scheduler shards
        over — part of every compiled step's cache key, so a changed
        mesh (shrink, or same count over different devices) can never
        hit a stale compiled step."""
        if n_shards <= 1:
            return None
        from ..codegen.shard import make_shard_mesh
        from ..launch.steps import mesh_signature
        return repr(mesh_signature(make_shard_mesh(n_shards,
                                                   self.shard_axis)))

    def _shard_of(self, slot: int) -> int:
        return slot // self._spb

    # -- submission / admission -----------------------------------------
    def submit(self, prompt: List[int], max_new_tokens: int,
               eos_id: Optional[int] = None,
               deadline_s: Optional[float] = None) -> int:
        if not prompt:
            raise ValueError("empty prompt")
        if len(prompt) >= self.max_model_len:
            raise ValueError(f"prompt of {len(prompt)} tokens >= "
                             f"max_model_len {self.max_model_len}")
        rid = self._next_rid
        self._next_rid += 1
        req = Request(rid, list(prompt), max_new_tokens, eos_id,
                      deadline_s=deadline_s, submit_time=self._clock())
        self.queue.append(req)
        return rid

    def _free_slot(self, total_pages: int = 0) -> Optional[int]:
        """First free slot whose host shard can still reserve
        ``total_pages`` (with one shard this is just first-free)."""
        for i, r in enumerate(self.slots):
            if (r is None and self.pool.available_in(self._shard_of(i))
                    >= total_pages):
                return i
        return None

    def _try_admit(self):
        while self.queue:
            req = self.queue[0]
            total_tokens = min(len(req.prompt) + req.max_new_tokens,
                               self.max_model_len)
            total_pages = self.pool.pages_for(total_tokens)
            if self._free_slot() is None:
                return
            slot = self._free_slot(total_pages)
            if slot is None:
                return
            self.queue.popleft()
            self.pool.reserve(total_pages, self._shard_of(slot))
            self._admit(req, slot, total_pages)

    def _admit(self, req: Request, slot: int, total_pages: int):
        """Chunked prefill into a dense scratch cache, then scatter the
        K/V slab into pages and install the request in its slot.

        A *re*-admission (a preempted request carrying generated tokens)
        prefills prompt + tokens_out[:-1] — everything whose K/V the
        evicted pages held — and does NOT sample: the last generated
        token is still waiting to be fed to the next decode step, so the
        resumed stream is exactly the unpreempted one."""
        model, params = self.model, self.params
        seq = req.prompt + req.tokens_out[:-1]
        prompt = jnp.asarray(seq, jnp.int32)[None]
        L = len(seq)
        cache = model.init_cache(1, L, dtype=self.pool.dtype)
        logits = None
        i = 0
        while i < L:
            chunk = prompt[:, i:i + self.prefill_chunk]
            logits, cache = self._prefill_step(params, cache, chunk)
            i += chunk.shape[1]

        n_prompt_pages = self.pool.pages_for(L)
        pages = self.pool.alloc(n_prompt_pages, shard=self._shard_of(slot))
        req.pages = pages
        req.reserved_left = total_pages - n_prompt_pages
        self.block_table[slot, :len(pages)] = pages

        for li, layer_cache in self._iter_layer_caches(cache):
            if "k" in layer_cache:  # attention
                self.pool.write_prefill(li, pages, layer_cache["k"][0, :L],
                                        layer_cache["v"][0, :L])
            else:  # recurrent state rows
                for key, a in layer_cache.items():
                    name = f"st{li}__{key}"
                    self.states[name] = self.states[name].at[slot].set(a[0])

        req.slot = slot
        req.pos = L
        req.admit_seq = self._admit_seq
        self._admit_seq += 1
        self.slots[slot] = req
        if not req.tokens_out:  # fresh request: sample its first token
            first = self._sample(logits[0, -1])
            req.tokens_out.append(first)
            req.first_token_time = self._clock()
            req.token_times.append(req.first_token_time - req.submit_time)
            self._maybe_finish(req, first)

    def _iter_layer_caches(self, cache):
        """(flat layer index, per-layer cache dict) in execution order."""
        pi_count = len(self.model.period_specs)
        li = 0
        for pp in range(self.model.n_periods):
            for pi in range(pi_count):
                yield li, jax.tree.map(lambda a: a[pp], cache["body"][pi])
                li += 1
        for c in cache["tail"]:
            yield li, c
            li += 1

    # -- finishing / eviction / preemption --------------------------------
    def _maybe_finish(self, req: Request, last_token: int):
        if req.eos_id is not None and last_token == req.eos_id:
            self._finish(req, "eos")
        elif (len(req.tokens_out) >= req.max_new_tokens
              or req.pos >= self.max_model_len - 1):
            self._finish(req, "max_tokens")

    def _strip(self, req: Request, touch_state: bool = True):
        """Return the request's pool/slot resources. ``touch_state=False``
        skips zeroing the jnp state rows (recompute recovery replaces the
        whole arrays — the old ones may be donated-dead)."""
        if req.pages:
            self.pool.free(req.pages)
            req.pages = []
        if req.reserved_left:
            self.pool.unreserve(req.reserved_left,
                                self._shard_of(req.slot)
                                if req.slot >= 0 else 0)
            req.reserved_left = 0
        if req.slot >= 0:
            self.block_table[req.slot, :] = 0
            if touch_state:
                for name in self.states:
                    self.states[name] = self.states[name].at[req.slot].set(0)
            self.slots[req.slot] = None
            req.slot = -1

    def _finish(self, req: Request, reason: str):
        assert reason in FINISH_REASONS, reason
        self._strip(req)
        req.finish_reason = reason
        req.done = True
        self.finished.append(req)

    def _preempt(self, req: Request):
        """Evict keeping generated tokens; re-queue at the front for
        recompute-readmission (or finish ``preempted_limit``)."""
        self.n_preemptions += 1
        req.n_preemptions += 1
        self._strip(req)
        if req.n_preemptions > self.max_preemptions:
            req.finish_reason = "preempted_limit"
            req.done = True
            self.finished.append(req)
            self.events.append({"kind": "preempted_limit", "rid": req.rid,
                                "step": self.n_steps})
        else:
            self.queue.appendleft(req)
            self.events.append({"kind": "preempt", "rid": req.rid,
                                "step": self.n_steps,
                                "kept_tokens": len(req.tokens_out)})

    def _expire(self):
        """Finish queued/active requests past their deadline or TTL."""
        now = self._clock()
        for r in list(self.queue):
            limit = r.deadline_s if r.deadline_s is not None \
                else self.queue_ttl_s
            if limit is not None and now - r.submit_time > limit:
                self.queue.remove(r)
                self._finish(r, "timeout")
                self.events.append({"kind": "timeout", "rid": r.rid,
                                    "where": "queue", "step": self.n_steps})
        for r in list(self.slots):
            if (r is not None and r.deadline_s is not None
                    and now - r.submit_time > r.deadline_s):
                self._finish(r, "timeout")
                self.events.append({"kind": "timeout", "rid": r.rid,
                                    "where": "active", "step": self.n_steps})

    # -- decode ----------------------------------------------------------
    def _buckets(self, active: List[Request]) -> tuple:
        if self.n_shards > 1:
            # sharded steps always run the full slot range: the static
            # slot -> host mapping (slot // slots_per_shard) must line
            # up with shard_map's equal split of the batch dim, which a
            # shrunken B bucket would shift
            B = self.max_slots
        else:
            top_slot = max(r.slot for r in active)
            B = min(_pow2_at_least(top_slot + 1), self.max_slots)
        longest = max(r.pos + 1 for r in active)
        pages = _pow2_at_least(self.pool.pages_for(longest))
        ctx = min(pages * self.page_size, self.max_model_len)
        return B, ctx

    def _bind_pages(self, active: List[Request]):
        """Bind a fresh page to each request crossing a page boundary.
        Pool pressure (PageError) preempts the youngest admitted request
        instead of killing the server — the ISSUE-8 crash-path fix."""
        for r in list(active):
            if r.done or r.slot < 0:
                continue  # evicted while a victim for an earlier request
            while len(r.pages) < self.pool.pages_for(r.pos + 1):
                reserved = r.reserved_left > 0
                sh = self._shard_of(r.slot)
                try:
                    pg = self.pool.alloc(1, reserved=reserved, shard=sh)[0]
                except PageError:
                    # pressure is per host shard: evicting a request on
                    # another shard frees no page this one can use
                    victim = max(
                        (a for a in self.slots if a is not None
                         and self._shard_of(a.slot) == sh),
                        key=lambda a: a.admit_seq)
                    self._preempt(victim)
                    if victim is r:
                        break
                    continue
                if reserved:
                    r.reserved_left -= 1
                self.block_table[r.slot, len(r.pages)] = pg
                r.pages.append(pg)

    def _step_kwargs(self, B: int, ctx: int) -> Dict[str, jnp.ndarray]:
        active = [r for r in self.slots if r is not None]
        tokens = np.zeros((B, 1), np.int32)
        positions = np.zeros((B,), np.int32)
        for r in active:
            tokens[r.slot, 0] = r.tokens_out[-1]
            positions[r.slot] = r.pos
        n_bt = ctx // self.page_size
        kwargs = dict(self.compiler.flat_weights)
        kwargs["tokens"] = jnp.asarray(tokens)
        kwargs["positions"] = jnp.asarray(positions)
        bt = self.block_table[:B, :n_bt]
        if self.n_shards > 1:
            # the compiled step's shard h sees only its own page block:
            # global page ids localize to it (the zero entries of
            # inactive lanes become each shard's own null page, local 0)
            shard = (np.arange(B) // self._spb)[:, None]
            bt = np.where(bt != 0,
                          bt - shard * self.pool.pages_per_shard, 0)
        kwargs["block_table"] = jnp.asarray(bt, jnp.int32)
        for li in attention_layer_shapes(self.model):
            kwargs[f"kp{li}"] = self.pool.k_pages[li]
            kwargs[f"vp{li}"] = self.pool.v_pages[li]
        for name in self._sspecs:
            kwargs[name] = self.states[name][:B]
        return kwargs

    def _execute(self, step_fn, kwargs, active, B, ctx):
        """Run one decode step through the degradation ladder.

        Returns ``(out, rows, dt, bad)`` on success — ``bad`` the active
        requests whose logits stayed non-finite after the ladder — or
        ``None`` when no usable output was produced (recompute recovery
        has already re-queued the active requests)."""

        def attempt(fn, retry):
            if self.injector is not None:
                self.injector.on_execute(self.n_steps, retry=retry)
            t0 = time.perf_counter()
            out = fn(kwargs)
            out["logits"].block_until_ready()
            dt = time.perf_counter() - t0
            rows = np.asarray(out["logits"])
            if self.injector is not None:
                rows = self.injector.corrupt_logits(self.n_steps, rows)
            return out, rows, dt

        def bad_lanes(rows):
            return [r for r in active
                    if not np.isfinite(rows[r.slot]).all()]

        try:
            out, rows, dt = attempt(step_fn, retry=False)
            bad = bad_lanes(rows)
            if not bad:
                return out, rows, dt, []
            self.watchdog.fault(self.n_steps, "nan_logits",
                                f"slots {[r.slot for r in bad]}")
        except Exception as e:  # noqa: BLE001 - every step fault recovers
            self.watchdog.fault(self.n_steps, "step_exception", repr(e))
        # rung 2: re-run from the same inputs — possible only when the
        # primary step did not donate (inputs still alive)
        if not self.compiler.donate:
            try:
                fb = self.compiler.fallback_for(B, ctx)
                out, rows, dt = attempt(fb, retry=True)
                self.n_fallback_steps += 1
                bad = bad_lanes(rows)
                if bad:
                    self.watchdog.fault(self.n_steps,
                                        "nan_logits_persistent",
                                        f"slots {[r.slot for r in bad]}")
                return out, rows, dt, bad
            except Exception as e:  # noqa: BLE001 - drop to rung 3
                self.watchdog.fault(self.n_steps, "fallback_failed",
                                    repr(e))
        # rung 3: recompute — preempt everyone with tokens kept, rebuild
        # the (possibly donated-dead) device arrays, re-prefill on admit
        self._recover_recompute(active)
        return None

    def _recover_recompute(self, active: List[Request]):
        self.n_recomputes += 1
        self.watchdog.fault(self.n_steps, "recompute_recovery",
                            f"rids {[r.rid for r in active]}")
        for r in sorted(active, key=lambda a: a.admit_seq, reverse=True):
            r.n_failures += 1
            self._strip(r, touch_state=False)
            if r.n_failures >= self.max_failures:
                r.finish_reason = "failed"
                r.done = True
                self.finished.append(r)
            else:
                self.queue.appendleft(r)
        self.block_table[:] = 0
        self.pool.reset_storage()
        self.states = self._zero_states()

    def step(self) -> List[Request]:
        """Admit waiting requests, run one compiled decode step over all
        active slots, sample, and evict finished requests. Returns the
        requests that finished during this step.

        ``n_steps`` ticks on every call — including iterations where
        recovery preempted everyone and no decode ran — so it is the
        clock fault plans key on: a stalled scheduler still advances
        toward e.g. a scheduled pressure release. ``n_decode_steps``
        counts compiled steps actually executed."""
        try:
            return self._step_inner()
        finally:
            self.n_steps += 1

    def _step_inner(self) -> List[Request]:
        n_done = len(self.finished)
        self._expire()
        if self.injector is not None:
            self.injector.on_step_begin(self.n_steps, self)
        self._try_admit()
        active = [r for r in self.slots if r is not None]
        if not active:
            return self.finished[n_done:]

        self._bind_pages(active)
        active = [r for r in self.slots if r is not None]
        if not active:
            return self.finished[n_done:]

        B, ctx = self._buckets(active)
        kwargs = self._step_kwargs(B, ctx)
        step_fn = self.compiler.step_for(B, ctx)
        result = self._execute(step_fn, kwargs, active, B, ctx)
        if result is None:  # recompute recovery: no tokens this step
            return self.finished[n_done:]
        out, rows, dt, bad = result
        self.last_logits = out["logits"]

        for li in attention_layer_shapes(self.model):
            self.pool.k_pages[li] = out[f"kp{li}"]
            self.pool.v_pages[li] = out[f"vp{li}"]
        for name in self._sspecs:
            if B == self.max_slots:
                # the full slice aliased (and donated) the master buffer
                self.states[name] = out[name]
            else:
                self.states[name] = self.states[name].at[:B].set(out[name])

        slow = (self.injector.slow_factor_for(self.n_steps)
                if self.injector is not None else 1.0)
        self.watchdog.record(self.n_steps, dt * slow)
        self.n_decode_steps += 1

        skip = set()
        for r in bad:  # lanes still non-finite after the ladder
            skip.add(r.rid)
            r.n_failures += 1
            if r.n_failures >= self.max_failures:
                self._finish(r, "failed")
        for r in active:
            if r.done or r.rid in skip:
                continue  # failed lanes retry (or are done) — no token
            t = self._sample(rows[r.slot])
            r.pos += 1
            r.tokens_out.append(t)
            r.token_times.append(dt)
            self._maybe_finish(r, t)
        return self.finished[n_done:]

    def _sample(self, row) -> int:
        """Next token from one request's last-position logits: greedy
        argmax at ``temperature == 0`` (the default, preserving the
        token-exact reference tests), otherwise softmax sampling at the
        given temperature, optionally truncated to the ``top_k`` highest
        logits, drawn from the scheduler's seeded generator."""
        row = np.asarray(row, np.float64)
        row = row.reshape(-1, row.shape[-1])[-1]
        if self.temperature == 0.0:
            return int(row.argmax())
        logits = row / self.temperature
        if self.top_k is not None and self.top_k < logits.shape[-1]:
            kth = np.partition(logits, -self.top_k)[-self.top_k]
            logits = np.where(logits < kth, -np.inf, logits)
        logits -= logits.max()
        p = np.exp(logits)
        p /= p.sum()
        return int(self._rng.choice(p.shape[-1], p=p))

    def run(self, max_steps: int = 100000) -> List[Request]:
        """Drive until every submitted request finishes."""
        for _ in range(max_steps):
            if not self.queue and all(r is None for r in self.slots):
                break
            self.step()
        else:
            raise RuntimeError(f"did not drain within {max_steps} steps")
        return sorted(self.finished, key=lambda r: r.rid)

    # -- observability ----------------------------------------------------
    def stats(self) -> dict:
        """One typed view of the run: finish reasons, recovery counters,
        watchdog/compiler event logs, pool accounting."""
        reasons = Counter(r.finish_reason for r in self.finished)
        return {"n_shards": self.n_shards,
                "mesh_signature": self.mesh_sig,
                "n_steps": self.n_steps,
                "n_decode_steps": self.n_decode_steps,
                "finished": len(self.finished),
                "queued": len(self.queue),
                "active": sum(r is not None for r in self.slots),
                "finish_reasons": dict(reasons),
                "preemptions": self.n_preemptions,
                "fallback_steps": self.n_fallback_steps,
                "recomputes": self.n_recomputes,
                "watchdog_events": list(self.watchdog.events),
                "compiler_events": list(self.compiler.events),
                "events": list(self.events),
                "pool": self.pool.stats()}

    # -- snapshot / restore -----------------------------------------------
    def _snapshot_config(self) -> dict:
        return {"max_slots": self.max_slots, "page_size": self.page_size,
                "n_pages": self.pool.n_pages,
                "max_model_len": self.max_model_len,
                "cache_dtype": str(self.pool.dtype),
                "n_shards": self.n_shards}

    def snapshot(self) -> dict:
        """Serialize the whole in-flight state host-side (numpy-backed).

        Call between steps (after :meth:`step` returns). The snapshot is
        a deep copy: continuing this scheduler afterwards does not
        disturb it. Restoring into a fresh scheduler over the same
        model/params/config resumes token-exact — the compiled step is a
        pure function of exactly what the snapshot captures (tokens,
        block tables, pages, recurrent states, RNG)."""
        def req(r):
            return None if r is None else dataclasses.asdict(r)

        return {"version": SNAPSHOT_VERSION,
                "config": self._snapshot_config(),
                "now": self._clock(),
                "queue": [req(r) for r in self.queue],
                "slots": [req(r) for r in self.slots],
                "finished": [req(r) for r in self.finished],
                "block_table": self.block_table.copy(),
                "pool": self.pool.snapshot(),
                "states": {name: np.asarray(a)
                           for name, a in self.states.items()},
                "rng": self._rng.bit_generator.state,
                "next_rid": self._next_rid,
                "admit_seq": self._admit_seq,
                "n_steps": self.n_steps,
                "n_decode_steps": self.n_decode_steps}

    def restore(self, snap: dict) -> "Scheduler":
        """Load a :meth:`snapshot` into this (fresh) scheduler.

        The scheduler must be built over the same model geometry
        (slots/pages/model-len/dtype); wall-clock request timestamps are
        rebased onto this scheduler's clock so deadlines keep meaning
        'time since submission'."""
        if snap.get("version") != SNAPSHOT_VERSION:
            raise ValueError(f"unknown snapshot version "
                             f"{snap.get('version')!r}")
        if snap["config"] != self._snapshot_config():
            raise ValueError(f"snapshot config {snap['config']} does not "
                             f"match scheduler {self._snapshot_config()}")
        shift = self._clock() - snap["now"]

        def req(d):
            if d is None:
                return None
            r = Request(**d)
            r.submit_time += shift
            if r.first_token_time:
                r.first_token_time += shift
            return r

        self.queue = deque(req(d) for d in snap["queue"])
        self.slots = [req(d) for d in snap["slots"]]
        self.finished = [req(d) for d in snap["finished"]]
        self.block_table = np.array(snap["block_table"], np.int32)
        self.pool.restore(snap["pool"])
        self.states = {name: jnp.asarray(snap["states"][name],
                                         self.states[name].dtype)
                       for name in self.states}
        self._rng.bit_generator.state = snap["rng"]
        self._next_rid = int(snap["next_rid"])
        self._admit_seq = int(snap["admit_seq"])
        self.n_steps = int(snap["n_steps"])
        self.n_decode_steps = int(snap["n_decode_steps"])
        self.last_logits = None
        return self

    # -- elastic multi-host: shrink + per-host snapshot shards -------------
    def shrink(self, n_shards: int):
        """Live mesh shrink (host loss): drop the trailing host shards.

        Requests on the dropped shards are preempted with their tokens
        kept (re-queued at the front; readmission re-prefills, so greedy
        streams stay byte-identical), the pool reshrinks to the
        surviving page blocks, and the compiled step is rebuilt for the
        smaller mesh — a different pipeline signature and mesh
        signature, hence a compilation-cache miss, never a stale
        kernel. Requests on surviving shards keep running untouched."""
        if not (1 <= n_shards < self.n_shards):
            raise ValueError(f"shrink to {n_shards} shards from "
                             f"{self.n_shards} is not a shrink")
        new_slots = n_shards * self._spb
        victims = [r for r in self.slots[new_slots:] if r is not None]
        for r in sorted(victims, key=lambda a: a.admit_seq, reverse=True):
            self._strip(r)
            self.queue.appendleft(r)
            self.events.append({"kind": "shrink_preempt", "rid": r.rid,
                                "step": self.n_steps,
                                "kept_tokens": len(r.tokens_out)})
        self.pool.shrink(n_shards)
        self.slots = self.slots[:new_slots]
        self.block_table = self.block_table[:new_slots].copy()
        self.states = {name: a[:new_slots]
                       for name, a in self.states.items()}
        old = self.n_shards
        self.n_shards = n_shards
        self.max_slots = new_slots
        self.mesh_sig = self._mesh_sig(n_shards)
        self.compiler = DecodeStepCompiler(
            self.model, self.params, page_size=self.page_size,
            n_pages=self.pool.n_pages, cache_dtype=str(self.pool.dtype),
            interpret=self.interpret,
            dtype_aware_sublanes=self.dtype_aware_sublanes,
            cache=self.compiler.cache, donate=self.compiler.donate,
            n_shards=n_shards, shard_axis=self.shard_axis,
            mesh_sig=self.mesh_sig)
        self.events.append({"kind": "mesh_shrink", "from": old,
                            "to": n_shards, "step": self.n_steps,
                            "preempted": [r.rid for r in victims]})
        return self

    def snapshot_to_dir(self, d):
        """Sharded :meth:`snapshot`: one ``meta.json`` (control state +
        mesh signature) plus one ``host{h}.npz`` per host shard holding
        only that host's slot rows and page block — what each host of a
        real pod can write locally without gathering the cluster. The
        directory commit is atomic (tmp + rename)."""
        import json
        import os
        import shutil

        d = str(d)
        tmp = d + ".tmp"
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp)

        def req(r):
            return None if r is None else dataclasses.asdict(r)

        meta = {"version": SNAPSHOT_VERSION,
                "config": self._snapshot_config(),
                "mesh_signature": self.mesh_sig,
                "now": self._clock(),
                "queue": [req(r) for r in self.queue],
                "slots": [req(r) for r in self.slots],
                "finished": [req(r) for r in self.finished],
                "pool": {"free": [p for f in self.pool._shard_free
                                  for p in f],
                         "reserved_by": list(self.pool._shard_reserved),
                         "seized": self.pool._seized},
                "rng": self._rng.bit_generator.state,
                "next_rid": self._next_rid,
                "admit_seq": self._admit_seq,
                "n_steps": self.n_steps,
                "n_decode_steps": self.n_decode_steps}
        with open(os.path.join(tmp, "meta.json"), "w") as f:
            json.dump(meta, f)
        spb, pps = self._spb, self.pool.pages_per_shard
        for h in range(self.n_shards):
            arrs = {"block_table":
                    self.block_table[h * spb:(h + 1) * spb].copy()}
            for name, a in self.states.items():
                arrs[f"st::{name}"] = np.asarray(
                    a[h * spb:(h + 1) * spb])
            for li in self.pool.k_pages:
                arrs[f"kp{li}"] = np.asarray(
                    self.pool.k_pages[li][h * pps:(h + 1) * pps])
                arrs[f"vp{li}"] = np.asarray(
                    self.pool.v_pages[li][h * pps:(h + 1) * pps])
            np.savez(os.path.join(tmp, f"host{h:03d}.npz"), **arrs)
        from pathlib import Path

        from ..checkpoint.store import _commit
        _commit(Path(d), Path(tmp))
        return d

    def restore_from_dir(self, d) -> "Scheduler":
        """Load a :meth:`snapshot_to_dir` directory into this (fresh)
        scheduler — possibly over a *different* mesh.

        * Same shard count, all host files present: exact restore
          (byte-identical continuation, like :meth:`restore`).
        * Fewer shards here, or a host file missing (that host died
          with its snapshot shard): the surviving hosts restore
          exactly; every request whose slot lived on a lost shard is
          re-queued with its generated tokens kept and a typed
          ``restore_recompute`` event — its KV pages are gone, so
          readmission re-prefills from tokens (PR 8's recompute rung),
          keeping greedy streams byte-identical.
        * More shards here (grow): all snapshot shards restore, the new
          hosts start empty.

        Slot-per-host and pages-per-host geometry must match — the
        snapshot's host shards map 1:1 onto this scheduler's."""
        import json
        import os

        d = str(d)
        with open(os.path.join(d, "meta.json")) as f:
            meta = json.load(f)
        if meta.get("version") != SNAPSHOT_VERSION:
            raise ValueError(f"unknown snapshot version "
                             f"{meta.get('version')!r}")
        cfg_s = dict(meta["config"])
        cfg_m = self._snapshot_config()
        k_snap = int(cfg_s.get("n_shards", 1))
        spb_s = cfg_s["max_slots"] // k_snap
        pps_s = cfg_s["n_pages"] // k_snap
        same = {k: cfg_s[k] for k in ("page_size", "max_model_len",
                                      "cache_dtype")}
        if (same != {k: cfg_m[k] for k in same}
                or spb_s != self._spb
                or pps_s != self.pool.pages_per_shard):
            raise ValueError(f"snapshot geometry {cfg_s} does not map "
                             f"onto scheduler {cfg_m}")
        shift = self._clock() - meta["now"]

        def req(dd):
            if dd is None:
                return None
            r = Request(**dd)
            r.submit_time += shift
            if r.first_token_time:
                r.first_token_time += shift
            return r

        host_file = {h: os.path.join(d, f"host{h:03d}.npz")
                     for h in range(k_snap)}
        dead = [h for h in range(k_snap)
                if h >= self.n_shards or not os.path.exists(host_file[h])]
        alive = [h for h in range(k_snap) if h not in dead]

        self.block_table = np.zeros(
            (self.max_slots, self.max_model_len // self.page_size),
            np.int32)
        self.states = self._zero_states()
        self.pool.reset_storage()
        pps = self.pool.pages_per_shard
        self.pool._shard_free = [
            list(range((h + 1) * pps - 1, h * pps, -1))
            for h in range(self.n_shards)]
        self.pool._shard_reserved = [0] * self.n_shards
        self.pool._seized = 0

        spb = self._spb
        for h in alive:
            with np.load(host_file[h]) as z:
                self.block_table[h * spb:(h + 1) * spb] = z["block_table"]
                for name in self.states:
                    self.states[name] = self.states[name].at[
                        h * spb:(h + 1) * spb].set(
                            jnp.asarray(z[f"st::{name}"],
                                        self.states[name].dtype))
                for li in self.pool.k_pages:
                    self.pool.k_pages[li] = self.pool.k_pages[li].at[
                        h * pps:(h + 1) * pps].set(
                            jnp.asarray(z[f"kp{li}"], self.pool.dtype))
                    self.pool.v_pages[li] = self.pool.v_pages[li].at[
                        h * pps:(h + 1) * pps].set(
                            jnp.asarray(z[f"vp{li}"], self.pool.dtype))
            self.pool._shard_free[h] = [
                p for p in meta["pool"]["free"]
                if self.pool.shard_of(p) == h]
            self.pool._shard_reserved[h] = \
                int(meta["pool"]["reserved_by"][h])

        self.queue = deque(req(dd) for dd in meta["queue"])
        self.finished = [req(dd) for dd in meta["finished"]]
        self.slots = [None] * self.max_slots
        lost: List[Request] = []
        for r in (req(dd) for dd in meta["slots"]):
            if r is None:
                continue
            h = self._shard_of(r.slot)
            if h in dead:
                r.pages = []
                r.reserved_left = 0
                r.slot = -1
                lost.append(r)
            else:
                self.slots[r.slot] = r
        for r in sorted(lost, key=lambda a: a.admit_seq, reverse=True):
            self.queue.appendleft(r)
            self.events.append({"kind": "restore_recompute",
                                "rid": r.rid, "step": self.n_steps,
                                "kept_tokens": len(r.tokens_out)})
        if dead:
            self.n_recomputes += 1
            self.watchdog.fault(self.n_steps, "restore_shard_lost",
                                f"shards {dead}, rids "
                                f"{[r.rid for r in lost]}")
        self._rng.bit_generator.state = meta["rng"]
        self._next_rid = int(meta["next_rid"])
        self._admit_seq = int(meta["admit_seq"])
        self.n_steps = int(meta["n_steps"])
        self.n_decode_steps = int(meta["n_decode_steps"])
        self.last_logits = None
        return self

    # -- invariants -------------------------------------------------------
    def check_invariants(self):
        """Page accounting + block-table consistency; raises PageError."""
        live: List[int] = []
        for r in self.slots:
            if r is None:
                continue
            live.extend(r.pages)
            row = self.block_table[r.slot]
            if list(row[:len(r.pages)]) != r.pages:
                raise PageError(f"block-table row of slot {r.slot} does "
                                f"not match its pages: {row[:len(r.pages)]}"
                                f" vs {r.pages}")
            if any(row[len(r.pages):]):
                raise PageError(f"stale block-table entries in slot "
                                f"{r.slot}: {row}")
        if any(p % self.pool.pages_per_shard == 0 for p in live):
            raise PageError("null page bound to a live request")
        if len(set(live)) != len(live):
            raise PageError(f"page bound to two live requests: {live}")
        for r in self.slots:
            if r is not None and any(
                    self.pool.shard_of(p) != self._shard_of(r.slot)
                    for p in r.pages):
                raise PageError(f"request {r.rid} in slot {r.slot} holds "
                                f"pages off its host shard: {r.pages}")
        n_accounted = self.pool.num_free + len(live) + self.pool._seized
        n_data = self.pool.n_pages - self.pool.n_shards  # one null each
        if n_accounted != n_data:
            raise PageError(f"page leak: {self.pool.num_free} free + "
                            f"{len(live)} live + {self.pool._seized} "
                            f"seized != {n_data}")
        reserved = sum(r.reserved_left for r in self.slots if r is not None)
        if reserved != self.pool._reserved:
            raise PageError(f"reservation drift: pool {self.pool._reserved}"
                            f" vs requests {reserved}")
        for i, r in enumerate(self.slots):
            if r is None and any(self.block_table[i]):
                raise PageError(f"free slot {i} has a non-zero "
                                "block-table row")
        for r in self.finished:
            if not r.done or r.finish_reason not in FINISH_REASONS:
                raise PageError(f"request {r.rid} finished without a "
                                f"typed reason: {r.finish_reason!r}")