"""SDFG-compiled decode serving (ROADMAP: serve-heavy-traffic).

Continuous batching (:class:`Scheduler`), paged KV cache
(:class:`KVPagePool`), and the shape-bucketed compiled decode step
(:class:`DecodeStepCompiler`). See ARCHITECTURE.md, 'Serving path'.
"""
from .compile import (CompiledDecodeStep, DecodeStepCompiler,
                      attention_layer_shapes, decode_pipeline,
                      flat_layer_specs, flatten_params, state_specs)
from .faults import FaultInjector, ServeFaultPlan, StepFault, StepWatchdog
from .pages import NULL_PAGE, KVPagePool, PageError
from .scheduler import FINISH_REASONS, Request, Scheduler

__all__ = [
    "CompiledDecodeStep", "DecodeStepCompiler", "FINISH_REASONS",
    "FaultInjector", "KVPagePool", "NULL_PAGE", "PageError", "Request",
    "Scheduler", "ServeFaultPlan", "StepFault", "StepWatchdog",
    "attention_layer_shapes", "decode_pipeline", "flat_layer_specs",
    "flatten_params", "state_specs",
]
