"""SDFG-compiled decode serving (ROADMAP: serve-heavy-traffic).

Continuous batching (:class:`Scheduler`), paged KV cache
(:class:`KVPagePool`), and the shape-bucketed compiled decode step
(:class:`DecodeStepCompiler`). See ARCHITECTURE.md, 'Serving path'.
"""
from .compile import (CompiledDecodeStep, DecodeStepCompiler,
                      attention_layer_shapes, decode_pipeline,
                      flat_layer_specs, flatten_params, state_specs)
from .pages import NULL_PAGE, KVPagePool, PageError
from .scheduler import Request, Scheduler

__all__ = [
    "CompiledDecodeStep", "DecodeStepCompiler", "KVPagePool", "NULL_PAGE",
    "PageError", "Request", "Scheduler", "attention_layer_shapes",
    "decode_pipeline", "flat_layer_specs", "flatten_params", "state_specs",
]
