"""StreamingMemory and StreamingComposition (paper §3.2.2-§3.2.3).

StreamingMemory extracts reads/writes of off-chip containers into dedicated
streaming accessor components (on FPGA: burst readers; on TPU: the
HBM->VMEM pipeline stage that Pallas double-buffers). It does not change
off-chip volume — it restructures access for bandwidth.

StreamingComposition fuses consecutive computations through a stream when
the producer's write order equals the consumer's read order, removing the
off-chip round-trip entirely: the container becomes a VMEM stream and its
2x HBM volume disappears. This is the transformation behind the paper's
headline Table-1/2/3 gains.

This module also hosts the shared write-order = read-order legality
front-end (:func:`solve_write_read_sigma`, :func:`sigma_covered`) that
both StreamingComposition's access-order matching and MapFusion's
halo-aware grid fusion build on: a producer writing ``t[p + c]`` per
iteration and a consumer reading ``t[f(q) ]`` are order-compatible
exactly when the affine renaming sigma(p) = f(q) - c exists and maps the
consumer's iteration box into the producer's — then the consumer's read
order IS the producer's write order composed with sigma, and the
intermediate can ride through the fused scope as shifted in-VMEM reads
instead of an off-chip round-trip.
"""
from __future__ import annotations

from fractions import Fraction
from typing import Dict, List, Optional, Tuple

import networkx as nx

from ..core.dtypes import StorageType
from ..core.memlet import Memlet, Subset
from ..core.sdfg import (AccessNode, Array, LibraryNode, MapEntry, MapExit,
                         Scalar, SDFG, State, Stream, Tasklet)
from ..core.symbolic import Expr
from .base import Transformation


# ---------------------------------------------------------------------------
# Shared write-order = read-order front-end (consumed by MapFusion's
# halo path; see module docstring)
# ---------------------------------------------------------------------------


def affine_decompose(expr: Expr, params) -> Optional[Tuple[int, Dict[str, int]]]:
    """Decompose ``expr`` as ``const + sum(coeff_p * p)`` over ``params``.
    Returns ``(const, {p: coeff})`` with integer values, or None when the
    expression is non-affine, has fractional coefficients, or references a
    symbol outside ``params``."""
    pset = set(params)
    const = 0
    coeffs: Dict[str, int] = {}
    for mono, c in Expr.wrap(expr).terms.items():
        if isinstance(c, Fraction):
            if c.denominator != 1:
                return None
            c = c.numerator
        c = int(c)
        if mono == ():
            const += c
            continue
        if len(mono) != 1 or mono[0][1] != 1:
            return None
        name = mono[0][0]
        if name not in pset:
            return None
        coeffs[name] = coeffs.get(name, 0) + c
    return const, coeffs


def solve_write_read_sigma(write_subset: Optional[Subset],
                           read_subset: Optional[Subset],
                           prod_params: List[str],
                           prod_ranges: Dict[str, Tuple[int, int]],
                           cons_params: List[str]):
    """Solve the affine renaming sigma that makes the producer's write
    order equal the consumer's read order for one intermediate edge pair.

    The producer must write ``t[..., p_d + c_d, ...]`` — every dimension an
    index addressed by exactly one distinct producer parameter with
    coefficient 1 (plus a constant); producer parameters absent from the
    write subset must have single-iteration ranges (otherwise the write
    revisits elements). The consumer read must be all-index with each
    dimension affine over the consumer parameters; then
    ``sigma(p_d) = read_d - c_d``.

    Returns ``(sigma, None)`` on success — ``sigma`` maps each producer
    parameter to an :class:`Expr` over consumer parameters — or
    ``(None, reason)`` with a typed refusal reason.
    """
    if write_subset is None or read_subset is None:
        return None, "whole-container access to the intermediate"
    if len(write_subset) != len(read_subset):
        return None, "read/write rank mismatch on the intermediate"
    sigma: Dict[str, Expr] = {}
    for d, (wr, rr) in enumerate(zip(write_subset, read_subset)):
        if not wr.is_index():
            return None, "producer writes a slice of the intermediate"
        if not rr.is_index():
            return None, ("consumer reads a windowed slice of the "
                          "intermediate")
        wdec = affine_decompose(wr.start, prod_params)
        if wdec is None:
            return None, f"non-affine write index in dim {d}"
        wconst, wcoeffs = wdec
        live = {p: c for p, c in wcoeffs.items() if c != 0}
        if len(live) != 1 or next(iter(live.values())) != 1:
            return None, (f"write index in dim {d} is not a unit-coefficient "
                          f"single-parameter shift")
        (p,) = live
        if p in sigma:
            return None, f"producer parameter {p} indexes two dimensions"
        rdec = affine_decompose(rr.start, cons_params)
        if rdec is None:
            return None, (f"read index in dim {d} is not affine over the "
                          f"consumer parameters")
        rconst, rcoeffs = rdec
        e = Expr.const(rconst - wconst)
        for q, c in rcoeffs.items():
            e = e + Expr.sym(q) * c
        sigma[p] = e
    for p in prod_params:
        if p in sigma:
            continue
        rng = prod_ranges.get(p)
        if rng is None or rng[1] != 1:
            return None, (f"producer parameter {p} does not address the "
                          f"intermediate (broadcast write revisits elements)")
        sigma[p] = Expr.const(rng[0])
    return sigma, None


def sigma_covered(sigma: Dict[str, Expr],
                  prod_ranges: Dict[str, Tuple[int, int]],
                  cons_ranges: Dict[str, Tuple[int, int]]) -> bool:
    """True when the image of the consumer's iteration box under ``sigma``
    lies inside the producer's iteration box (interval arithmetic over the
    affine shifts) — every shifted read then hits an iteration the
    producer actually executed. Producer iterations outside the image are
    dead once the intermediate has no other reader."""
    for p, expr in sigma.items():
        dec = affine_decompose(expr, list(cons_ranges))
        if dec is None:
            return False
        c0, coeffs = dec
        lo = hi = c0
        for q, (qs, qn) in cons_ranges.items():
            a = coeffs.get(q, 0)
            if a >= 0:
                lo += a * qs
                hi += a * (qs + qn - 1)
            else:
                lo += a * (qs + qn - 1)
                hi += a * qs
        ps, pn = prod_ranges[p]
        if lo < ps or hi > ps + pn - 1:
            return False
    return True


def _access_order_key(state: State, edge, endpoint: str):
    """Canonical access-order key for a producer/consumer edge.

    For edges into/out of map scopes, the key combines the scope's
    iteration ranges with the memlet's index expressions, both canonicalized
    over positional parameters (paper §3.2.3: 'canonicalizing the memlets'
    symbolic expressions by remapping symbol names to indices'). For
    whole-array accesses the key is ('FULL', shape).
    """
    node = edge.src if endpoint == "producer" else edge.dst
    scope_map = None
    if endpoint == "producer" and isinstance(node, MapExit):
        scope_map = node.map
    if endpoint == "consumer" and isinstance(node, MapEntry):
        scope_map = node.map
    memlet = edge.memlet
    if scope_map is None:
        return ("FULL",)
    params = scope_map.params
    env = {p: f"__i{k}" for k, p in enumerate(params)}
    ranges = tuple((r.start.subs(env), r.stop.subs(env), r.step.subs(env))
                   for r in scope_map.ranges)
    # find the inner memlet (through the scope) for the same data
    inner = None
    if endpoint == "producer":
        for e in state.in_edges(node):
            if e.memlet.data == memlet.data:
                inner = e.memlet
                break
    else:
        for e in state.out_edges(node):
            if e.memlet.data == memlet.data:
                inner = e.memlet
                break
    if inner is None or inner.subset is None:
        return ("FULL",)
    order = inner.access_order(params)
    return (ranges, order)


class StreamingComposition(Transformation):
    """array node with in-degree 1 / out-degree 1 and matching access
    orders -> convert the container into a VMEM stream."""

    def find_matches(self, sdfg: SDFG, **kwargs):
        counts: Dict[str, int] = {}
        for st in sdfg.states:
            for node in st.data_nodes():
                counts[node.data] = counts.get(node.data, 0) + 1
        for st in sdfg.states:
            for node in st.data_nodes():
                desc = sdfg.arrays[node.data]
                if (desc.transient and isinstance(desc, Array)
                        and not isinstance(desc, Stream)
                        and not isinstance(desc, Scalar)
                        and st.in_degree(node) == 1
                        and st.out_degree(node) == 1
                        and counts[node.data] == 1):
                    yield {"state": st, "node": node}

    def can_apply(self, sdfg: SDFG, match: Dict) -> bool:
        st, node = match["state"], match["node"]
        if node not in st.graph:
            return False
        if node.data in sdfg.metadata.get("pin_hbm", ()):
            return False  # performance engineer pinned it off-chip
        desc = sdfg.arrays[node.data]
        if isinstance(desc, Stream):
            return False
        in_e = st.in_edges(node)[0]
        out_e = st.out_edges(node)[0]
        prod_key = _access_order_key(st, in_e, "producer")
        cons_key = _access_order_key(st, out_e, "consumer")
        return prod_key == cons_key

    def apply_match(self, sdfg: SDFG, match: Dict):
        st, node = match["state"], match["node"]
        desc: Array = sdfg.arrays[node.data]
        sdfg.arrays[node.data] = Stream(
            dtype=desc.dtype, storage=StorageType.VMEM, transient=True,
            buffer_size=4, shape=(), element_shape=tuple(desc.shape),
            total_volume=desc.num_elements)
        # split into producer-side and consumer-side access nodes: the two
        # PEs hold no dataflow edge, synchronizing only through the stream
        # container (paper §2.5 / Fig. 3)
        out_e = st.out_edges(node)[0]
        consumer_side = st.add_access(node.data)
        st.add_edge(consumer_side, None, out_e.dst, out_e.dst_conn,
                    out_e.memlet)
        st.remove_edge(out_e)


class StreamingMemory(Transformation):
    """Extract off-chip reads/writes into streaming accessor components.

    Reads: for each HBM access node feeding computation, insert a reader
    tasklet (memory -> stream) and redirect the consumer to the stream.
    Multiple consumers with the same access order share one reader with
    multiple output streams (paper: broadcast); dependent accesses get
    separate components (deadlock avoidance via reachability).
    """

    def find_matches(self, sdfg: SDFG, **kwargs):
        for st in sdfg.states:
            for node in st.data_nodes():
                desc = sdfg.arrays[node.data]
                if isinstance(desc, (Stream, Scalar)) or not isinstance(desc, Array):
                    continue
                if not desc.storage.off_chip:
                    continue
                if sdfg.metadata.get("streamed_" + node.data):
                    continue
                reads = [e for e in st.out_edges(node)
                         if not isinstance(e.dst, AccessNode)]
                writes = [e for e in st.in_edges(node)
                          if not isinstance(e.src, AccessNode)]
                if reads:
                    yield {"state": st, "node": node, "edges": reads,
                           "mode": "read"}
                if writes:
                    yield {"state": st, "node": node, "edges": writes,
                           "mode": "write"}

    def can_apply(self, sdfg: SDFG, match: Dict) -> bool:
        return match["node"] in match["state"].graph and not \
            sdfg.metadata.get("streamed_" + match["node"].data + "_" +
                              match["mode"])

    def apply_match(self, sdfg: SDFG, match: Dict):
        st: State = match["state"]
        node: AccessNode = match["node"]
        desc: Array = sdfg.arrays[node.data]
        mode = match["mode"]
        sdfg.metadata["streamed_" + node.data + "_" + mode] = True

        # group consumer/producer edges by access order; dependent groups
        # (reachability between endpoints) are kept separate
        groups: List[List] = []
        for e in match["edges"]:
            key = _access_order_key(
                st, e, "consumer" if mode == "read" else "producer")
            placed = False
            for g in groups:
                if g[0][0] == key and not self._dependent(st, g[0][1], e):
                    g.append((key, e))
                    placed = True
                    break
            if not placed:
                groups.append([(key, e)])

        for gi, group in enumerate(groups):
            stream_names = []
            for si, (_, e) in enumerate(group):
                sname = f"{node.data}_{mode}_stream"
                if gi or si:
                    sname += f"_{gi}_{si}"
                base = sname
                k = 0
                while sname in sdfg.arrays:
                    k += 1
                    sname = f"{base}_{k}"
                sdfg.add_stream(sname, desc.dtype, buffer_size=4,
                                element_shape=tuple(desc.shape),
                                total_volume=desc.num_elements,
                                storage=StorageType.VMEM)
                stream_names.append(sname)
            if mode == "read":
                # reader PE: mem -> stream(s)  (paper red/black boxes, Fig. 3)
                reader = st.add_tasklet(
                    f"read_{node.data}" + (f"_{gi}" if gi else ""),
                    ["mem"], [f"s{k}" for k in range(len(group))],
                    (lambda n_out: (lambda mem: {f"s{k}": mem for k in
                                                 range(n_out)}))(len(group)))
                st.add_edge(node, None, reader, "mem",
                            Memlet.simple(node.data,
                                          volume=desc.num_elements))
                for k, ((key, e), sname) in enumerate(zip(group, stream_names)):
                    s_prod = st.add_access(sname)   # producer-side node
                    s_cons = st.add_access(sname)   # consumer-side node (no
                    #                       edge between PEs, paper Fig. 3)
                    st.add_edge(reader, f"s{k}", s_prod, None,
                                Memlet.simple(sname,
                                              volume=desc.num_elements))
                    st.add_edge(s_cons, None, e.dst, e.dst_conn,
                                self._retarget(e.memlet, sname))
                    self._retarget_scope(st, e.dst, node.data, sname)
                    st.remove_edge(e)
            else:
                # writer PE: stream -> mem (paper blue box)
                writer = st.add_tasklet(
                    f"write_{node.data}" + (f"_{gi}" if gi else ""),
                    [f"s{k}" for k in range(len(group))], ["mem"],
                    (lambda n_in: (lambda **kw: {"mem": kw["s0"]}))(len(group)))
                st.add_edge(writer, "mem", node, None,
                            Memlet.simple(node.data,
                                          volume=desc.num_elements))
                for k, ((key, e), sname) in enumerate(zip(group, stream_names)):
                    s_prod = st.add_access(sname)
                    s_cons = st.add_access(sname)
                    st.add_edge(e.src, e.src_conn, s_prod, None,
                                self._retarget(e.memlet, sname))
                    st.add_edge(s_cons, None, writer, f"s{k}",
                                Memlet.simple(sname,
                                              volume=desc.num_elements))
                    self._retarget_scope(st, e.src, node.data, sname)
                    st.remove_edge(e)

    @staticmethod
    def _retarget(memlet: Memlet, new_data: str) -> Memlet:
        return Memlet(data=new_data, subset=memlet.subset,
                      volume=memlet.volume, wcr=memlet.wcr)

    @staticmethod
    def _retarget_scope(st: State, scope_node, old: str, new: str):
        """Rewrite memlets inside a map scope that reference the old
        container (reads through OUT_<old> connectors)."""
        if not isinstance(scope_node, (MapEntry, MapExit)):
            return
        stack = [scope_node]
        seen = set()
        while stack:
            n = stack.pop()
            if n in seen:
                continue
            seen.add(n)
            for e in st.out_edges(n):
                if e.memlet.data == old:
                    e.memlet.data = new
                if e.src_conn and e.src_conn == f"OUT_{old}":
                    e.src_conn = f"OUT_{new}"
                if e.dst_conn and e.dst_conn == f"IN_{old}":
                    e.dst_conn = f"IN_{new}"
                if not isinstance(e.dst, (AccessNode,)):
                    stack.append(e.dst)
            for e in st.in_edges(n):
                if e.memlet.data == old:
                    e.memlet.data = new
                if e.src_conn and e.src_conn == f"OUT_{old}":
                    e.src_conn = f"OUT_{new}"
                if e.dst_conn and e.dst_conn == f"IN_{old}":
                    e.dst_conn = f"IN_{new}"

    @staticmethod
    def _dependent(st: State, e1, e2) -> bool:
        """Reachability between the two consumers/producers => dependent
        accesses must not share a streaming component (deadlock avoidance,
        paper §3.2.2)."""
        try:
            return (nx.has_path(st.graph, e1.dst, e2.dst)
                    or nx.has_path(st.graph, e2.dst, e1.dst))
        except Exception:
            return True
