"""StreamingMemory and StreamingComposition (paper §3.2.2-§3.2.3).

StreamingMemory extracts reads/writes of off-chip containers into dedicated
streaming accessor components (on FPGA: burst readers; on TPU: the
HBM->VMEM pipeline stage that Pallas double-buffers). It does not change
off-chip volume — it restructures access for bandwidth.

StreamingComposition fuses consecutive computations through a stream when
the producer's write order equals the consumer's read order, removing the
off-chip round-trip entirely: the container becomes a VMEM stream and its
2x HBM volume disappears. This is the transformation behind the paper's
headline Table-1/2/3 gains.
"""
from __future__ import annotations

from typing import Dict, List, Optional, Tuple

import networkx as nx

from ..core.dtypes import StorageType
from ..core.memlet import Memlet
from ..core.sdfg import (AccessNode, Array, LibraryNode, MapEntry, MapExit,
                         Scalar, SDFG, State, Stream, Tasklet)
from .base import Transformation


def _access_order_key(state: State, edge, endpoint: str):
    """Canonical access-order key for a producer/consumer edge.

    For edges into/out of map scopes, the key combines the scope's
    iteration ranges with the memlet's index expressions, both canonicalized
    over positional parameters (paper §3.2.3: 'canonicalizing the memlets'
    symbolic expressions by remapping symbol names to indices'). For
    whole-array accesses the key is ('FULL', shape).
    """
    node = edge.src if endpoint == "producer" else edge.dst
    scope_map = None
    if endpoint == "producer" and isinstance(node, MapExit):
        scope_map = node.map
    if endpoint == "consumer" and isinstance(node, MapEntry):
        scope_map = node.map
    memlet = edge.memlet
    if scope_map is None:
        return ("FULL",)
    params = scope_map.params
    env = {p: f"__i{k}" for k, p in enumerate(params)}
    ranges = tuple((r.start.subs(env), r.stop.subs(env), r.step.subs(env))
                   for r in scope_map.ranges)
    # find the inner memlet (through the scope) for the same data
    inner = None
    if endpoint == "producer":
        for e in state.in_edges(node):
            if e.memlet.data == memlet.data:
                inner = e.memlet
                break
    else:
        for e in state.out_edges(node):
            if e.memlet.data == memlet.data:
                inner = e.memlet
                break
    if inner is None or inner.subset is None:
        return ("FULL",)
    order = inner.access_order(params)
    return (ranges, order)


class StreamingComposition(Transformation):
    """array node with in-degree 1 / out-degree 1 and matching access
    orders -> convert the container into a VMEM stream."""

    def find_matches(self, sdfg: SDFG, **kwargs):
        counts: Dict[str, int] = {}
        for st in sdfg.states:
            for node in st.data_nodes():
                counts[node.data] = counts.get(node.data, 0) + 1
        for st in sdfg.states:
            for node in st.data_nodes():
                desc = sdfg.arrays[node.data]
                if (desc.transient and isinstance(desc, Array)
                        and not isinstance(desc, Stream)
                        and not isinstance(desc, Scalar)
                        and st.in_degree(node) == 1
                        and st.out_degree(node) == 1
                        and counts[node.data] == 1):
                    yield {"state": st, "node": node}

    def can_apply(self, sdfg: SDFG, match: Dict) -> bool:
        st, node = match["state"], match["node"]
        if node not in st.graph:
            return False
        if node.data in sdfg.metadata.get("pin_hbm", ()):
            return False  # performance engineer pinned it off-chip
        desc = sdfg.arrays[node.data]
        if isinstance(desc, Stream):
            return False
        in_e = st.in_edges(node)[0]
        out_e = st.out_edges(node)[0]
        prod_key = _access_order_key(st, in_e, "producer")
        cons_key = _access_order_key(st, out_e, "consumer")
        return prod_key == cons_key

    def apply_match(self, sdfg: SDFG, match: Dict):
        st, node = match["state"], match["node"]
        desc: Array = sdfg.arrays[node.data]
        sdfg.arrays[node.data] = Stream(
            dtype=desc.dtype, storage=StorageType.VMEM, transient=True,
            buffer_size=4, shape=(), element_shape=tuple(desc.shape),
            total_volume=desc.num_elements)
        # split into producer-side and consumer-side access nodes: the two
        # PEs hold no dataflow edge, synchronizing only through the stream
        # container (paper §2.5 / Fig. 3)
        out_e = st.out_edges(node)[0]
        consumer_side = st.add_access(node.data)
        st.add_edge(consumer_side, None, out_e.dst, out_e.dst_conn,
                    out_e.memlet)
        st.remove_edge(out_e)


class StreamingMemory(Transformation):
    """Extract off-chip reads/writes into streaming accessor components.

    Reads: for each HBM access node feeding computation, insert a reader
    tasklet (memory -> stream) and redirect the consumer to the stream.
    Multiple consumers with the same access order share one reader with
    multiple output streams (paper: broadcast); dependent accesses get
    separate components (deadlock avoidance via reachability).
    """

    def find_matches(self, sdfg: SDFG, **kwargs):
        for st in sdfg.states:
            for node in st.data_nodes():
                desc = sdfg.arrays[node.data]
                if isinstance(desc, (Stream, Scalar)) or not isinstance(desc, Array):
                    continue
                if not desc.storage.off_chip:
                    continue
                if sdfg.metadata.get("streamed_" + node.data):
                    continue
                reads = [e for e in st.out_edges(node)
                         if not isinstance(e.dst, AccessNode)]
                writes = [e for e in st.in_edges(node)
                          if not isinstance(e.src, AccessNode)]
                if reads:
                    yield {"state": st, "node": node, "edges": reads,
                           "mode": "read"}
                if writes:
                    yield {"state": st, "node": node, "edges": writes,
                           "mode": "write"}

    def can_apply(self, sdfg: SDFG, match: Dict) -> bool:
        return match["node"] in match["state"].graph and not \
            sdfg.metadata.get("streamed_" + match["node"].data + "_" +
                              match["mode"])

    def apply_match(self, sdfg: SDFG, match: Dict):
        st: State = match["state"]
        node: AccessNode = match["node"]
        desc: Array = sdfg.arrays[node.data]
        mode = match["mode"]
        sdfg.metadata["streamed_" + node.data + "_" + mode] = True

        # group consumer/producer edges by access order; dependent groups
        # (reachability between endpoints) are kept separate
        groups: List[List] = []
        for e in match["edges"]:
            key = _access_order_key(
                st, e, "consumer" if mode == "read" else "producer")
            placed = False
            for g in groups:
                if g[0][0] == key and not self._dependent(st, g[0][1], e):
                    g.append((key, e))
                    placed = True
                    break
            if not placed:
                groups.append([(key, e)])

        for gi, group in enumerate(groups):
            stream_names = []
            for si, (_, e) in enumerate(group):
                sname = f"{node.data}_{mode}_stream"
                if gi or si:
                    sname += f"_{gi}_{si}"
                base = sname
                k = 0
                while sname in sdfg.arrays:
                    k += 1
                    sname = f"{base}_{k}"
                sdfg.add_stream(sname, desc.dtype, buffer_size=4,
                                element_shape=tuple(desc.shape),
                                total_volume=desc.num_elements,
                                storage=StorageType.VMEM)
                stream_names.append(sname)
            if mode == "read":
                # reader PE: mem -> stream(s)  (paper red/black boxes, Fig. 3)
                reader = st.add_tasklet(
                    f"read_{node.data}" + (f"_{gi}" if gi else ""),
                    ["mem"], [f"s{k}" for k in range(len(group))],
                    (lambda n_out: (lambda mem: {f"s{k}": mem for k in
                                                 range(n_out)}))(len(group)))
                st.add_edge(node, None, reader, "mem",
                            Memlet.simple(node.data,
                                          volume=desc.num_elements))
                for k, ((key, e), sname) in enumerate(zip(group, stream_names)):
                    s_prod = st.add_access(sname)   # producer-side node
                    s_cons = st.add_access(sname)   # consumer-side node (no
                    #                       edge between PEs, paper Fig. 3)
                    st.add_edge(reader, f"s{k}", s_prod, None,
                                Memlet.simple(sname,
                                              volume=desc.num_elements))
                    st.add_edge(s_cons, None, e.dst, e.dst_conn,
                                self._retarget(e.memlet, sname))
                    self._retarget_scope(st, e.dst, node.data, sname)
                    st.remove_edge(e)
            else:
                # writer PE: stream -> mem (paper blue box)
                writer = st.add_tasklet(
                    f"write_{node.data}" + (f"_{gi}" if gi else ""),
                    [f"s{k}" for k in range(len(group))], ["mem"],
                    (lambda n_in: (lambda **kw: {"mem": kw["s0"]}))(len(group)))
                st.add_edge(writer, "mem", node, None,
                            Memlet.simple(node.data,
                                          volume=desc.num_elements))
                for k, ((key, e), sname) in enumerate(zip(group, stream_names)):
                    s_prod = st.add_access(sname)
                    s_cons = st.add_access(sname)
                    st.add_edge(e.src, e.src_conn, s_prod, None,
                                self._retarget(e.memlet, sname))
                    st.add_edge(s_cons, None, writer, f"s{k}",
                                Memlet.simple(sname,
                                              volume=desc.num_elements))
                    self._retarget_scope(st, e.src, node.data, sname)
                    st.remove_edge(e)

    @staticmethod
    def _retarget(memlet: Memlet, new_data: str) -> Memlet:
        return Memlet(data=new_data, subset=memlet.subset,
                      volume=memlet.volume, wcr=memlet.wcr)

    @staticmethod
    def _retarget_scope(st: State, scope_node, old: str, new: str):
        """Rewrite memlets inside a map scope that reference the old
        container (reads through OUT_<old> connectors)."""
        if not isinstance(scope_node, (MapEntry, MapExit)):
            return
        stack = [scope_node]
        seen = set()
        while stack:
            n = stack.pop()
            if n in seen:
                continue
            seen.add(n)
            for e in st.out_edges(n):
                if e.memlet.data == old:
                    e.memlet.data = new
                if e.src_conn and e.src_conn == f"OUT_{old}":
                    e.src_conn = f"OUT_{new}"
                if e.dst_conn and e.dst_conn == f"IN_{old}":
                    e.dst_conn = f"IN_{new}"
                if not isinstance(e.dst, (AccessNode,)):
                    stack.append(e.dst)
            for e in st.in_edges(n):
                if e.memlet.data == old:
                    e.memlet.data = new
                if e.src_conn and e.src_conn == f"OUT_{old}":
                    e.src_conn = f"OUT_{new}"
                if e.dst_conn and e.dst_conn == f"IN_{old}":
                    e.dst_conn = f"IN_{new}"

    @staticmethod
    def _dependent(st: State, e1, e2) -> bool:
        """Reachability between the two consumers/producers => dependent
        accesses must not share a streaming component (deadlock avoidance,
        paper §3.2.2)."""
        try:
            return (nx.has_path(st.graph, e1.dst, e2.dst)
                    or nx.has_path(st.graph, e2.dst, e1.dst))
        except Exception:
            return True
