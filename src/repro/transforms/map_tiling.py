"""MapTiling: split map dimensions into (tile-counter, intra-tile) pairs —
the platform-agnostic transformation the paper lists among the DaCe toolbox
(§3.2), used on TPU to align block shapes with VMEM capacity and the
VPU/MXU lane layout.

Tiling is **multi-dimensional and alignment-aware**: every eligible map
parameter is split independently (mixed radix — one tile parameter per
dimension), and the default tile sizes follow the TPU register layout the
way the paper's Vectorization transform (§3.2.4) widens the FPGA data
path: the minor (innermost) parameter tiles to the vector width recorded
by ``Vectorization`` (``sdfg.metadata['vector_width']``, default 128
lanes), the next parameter to 8 sublanes. Non-divisible extents are
remainder-safe: the tile counter ranges over ``ceil(n / tile)`` blocks and
the grid code generator masks the partial final block (the structural
interpreter enumerates only valid lattice points).

Tiled maps are annotated with the tile structure: ``annotations['tiling']``
maps each intra-tile parameter to
``{"tile", "counter", "extent", "blocks"}``. The Pallas grid code
generator (``GridConversionPass`` + ``pallas_backend``) consumes it to
derive BlockSpec block shapes: intra-tile parameters widen memlet index
dimensions into VMEM-resident blocks while tile-counter parameters become
grid dimensions. The annotation — not the ``_tiled`` label suffix, which
is purely cosmetic — is also what makes the transformation idempotent, so
fuse-after-tile and per-dimension re-tiling compose.
"""
from __future__ import annotations

import math
from typing import Dict, Optional

from ..core.dtypes import ScheduleType, TPU_LANES, TPU_SUBLANES
from ..core.memlet import Range
from ..core.sdfg import MapEntry, SDFG
from ..core.symbolic import sym
from .base import Transformation

#: schedules whose maps tile (grid-eligible schedules; UNROLLED / MESH
#: scopes are replicated hardware and keep their per-lane identity).
_TILABLE = (ScheduleType.PIPELINED, ScheduleType.DEVICE)


def normalize_tiling(ann: Dict) -> Dict[str, Dict]:
    """Normalize a ``tiling`` annotation to the rich per-parameter form.
    Legacy entries (``{param: extent_int}``) carry no counter/extent
    information and are treated as exactly-divisible."""
    out = {}
    for q, info in (ann or {}).items():
        if isinstance(info, dict):
            out[q] = info
        else:
            out[q] = {"tile": int(info), "counter": None,
                      "extent": None, "blocks": None}
    return out


def _choose_tile(n: int, preferred: int) -> Optional[int]:
    """Tile size for an extent of ``n`` elements given a preferred
    (alignment) width: the preferred width when it divides ``n``, else the
    largest divisor of ``n`` within [preferred/4, preferred] (aligned
    blocks, no remainder), else the preferred width with a masked partial
    final block. None when ``n`` is too small to be worth splitting."""
    if n <= 1 or preferred <= 1:
        return None
    if n <= preferred:
        return n                      # whole dimension in one block
    if n % preferred == 0:
        return preferred
    for d in range(preferred, max(2, preferred // 4) - 1, -1):
        if n % d == 0:
            return d
    return preferred                  # ceil-division, masked partial block


class MapTiling(Transformation):
    """Split every eligible parameter of PIPELINED/DEVICE maps into a
    (counter, intra) pair. ``tile_size`` overrides the preferred *minor*
    (lane) width of the default policy — like the defaults, it plans each
    map exactly once (an already-annotated map is left alone, so fixpoint
    re-matches cannot whole-tile deliberately-skipped dims). Only
    ``tile_sizes`` — explicit per-parameter tiles — composes with earlier
    tilings, one dimension at a time."""

    def __init__(self, tile_size: int = None, map_label: str = None,
                 tile_sizes: Dict[str, int] = None):
        self.tile_size = tile_size
        self.map_label = map_label
        self.tile_sizes = tile_sizes

    # ------------------------------------------------------------------
    def _shared_dim_params(self, sdfg: SDFG, st, entry: MapEntry) -> set:
        """Parameters that co-index a memlet dimension with another map
        parameter (e.g. ``x[c*K + l]``): splitting one would put two tile
        parameters in a single dimension, which BlockSpec factorization
        cannot express — leave them whole."""
        pset = set(entry.map.params)
        shared = set()
        scopes = st.scope_children()
        nodes = {entry}
        stack = list(scopes.get(entry, []))
        while stack:
            nd = stack.pop()
            if nd in nodes:
                continue
            nodes.add(nd)
            if isinstance(nd, MapEntry):
                stack.extend(scopes.get(nd, []))
        for e in st.edges:
            if e.src not in nodes and e.dst not in nodes:
                continue
            if e.memlet.subset is None:
                continue
            for r in e.memlet.subset:
                used = (r.start.free_symbols | r.stop.free_symbols) & pset
                if len(used) > 1:
                    shared |= used
        return shared

    def _plan(self, sdfg: SDFG, st, entry: MapEntry,
              tile_size: int, tile_sizes: Dict[str, int]
              ) -> Dict[str, int]:
        """Per-parameter tile plan for one map (param -> tile size)."""
        m = entry.map
        tiling = normalize_tiling(m.annotations.get("tiling"))
        counters = {info.get("counter") for info in tiling.values()}
        if tiling and not tile_sizes:
            # the default policy plans a map exactly once: params it left
            # untiled (small second dims, outer/batch dims) were left
            # deliberately — a fixpoint re-match must not whole-tile them
            # as fresh "minor" dims. Explicit tile_sizes still compose.
            return {}
        env = sdfg.symbol_values
        sizes = {}
        for p, r in zip(m.params, m.ranges):
            if p in tiling or p in counters:
                continue              # already tiled: idempotence
            try:
                sizes[p] = int(r.size.evaluate(env))
            except Exception:
                continue              # dynamic extent: cannot tile
        if not sizes:
            return {}
        shared = self._shared_dim_params(sdfg, st, entry)
        candidates = [p for p in m.params if p in sizes and p not in shared]
        if not candidates:
            return {}
        plan: Dict[str, int] = {}
        if tile_sizes:
            for p in candidates:
                if p in tile_sizes and sizes[p] > 1:
                    plan[p] = max(1, min(int(tile_sizes[p]), sizes[p]))
            return plan
        lanes = tile_size or sdfg.metadata.get("vector_width") or TPU_LANES
        minor = candidates[-1]
        if len(m.params) == 1:
            # a 1-D map only tiles when it yields >= 2 blocks (a whole-dim
            # block would collapse the grid to a single step)
            if sizes[minor] > lanes:
                plan[minor] = _choose_tile(sizes[minor], lanes)
        else:
            t = _choose_tile(sizes[minor], lanes)
            if t is not None:
                plan[minor] = t
            if len(candidates) >= 2:
                second = candidates[-2]
                if sizes[second] > TPU_SUBLANES:
                    t2 = _choose_tile(sizes[second], TPU_SUBLANES)
                    if t2 is not None:
                        plan[second] = t2
        return {p: t for p, t in plan.items() if t and t >= 1}

    # ------------------------------------------------------------------
    def find_matches(self, sdfg: SDFG, tile_size: int = None,
                     map_label: str = None, tile_sizes: Dict[str, int] = None,
                     **kwargs):
        ts = tile_size if tile_size is not None else self.tile_size
        label = map_label or self.map_label
        explicit = tile_sizes if tile_sizes is not None else self.tile_sizes
        for st in sdfg.states:
            for node in st.nodes:
                if not isinstance(node, MapEntry):
                    continue
                m = node.map
                if label and not m.label.startswith(label):
                    continue
                if m.schedule not in _TILABLE:
                    continue
                plan = self._plan(sdfg, st, node, ts, explicit)
                if plan:
                    yield {"state": st, "entry": node, "plan": plan}

    def apply_match(self, sdfg: SDFG, match: Dict):
        st, entry, plan = match["state"], match["entry"], match["plan"]
        m = entry.map
        env = sdfg.symbol_values
        ann = m.annotations.setdefault("tiling", {})
        new_params, new_ranges, repl = [], [], {}
        for p, r in zip(m.params, m.ranges):
            if p not in plan:
                new_params.append(p)
                new_ranges.append(r)
                continue
            ts = plan[p]
            n = int(r.size.evaluate(env))
            blocks = math.ceil(n / ts)
            lo = r.start
            pt, pi = f"{p}_tile", f"{p}_in"
            new_params += [pt, pi]
            new_ranges += [Range.make(0, blocks), Range.make(0, ts)]
            ann[pi] = {"tile": ts, "counter": pt, "extent": n,
                       "blocks": blocks}
            # rewrite memlets in the scope: p -> lo + p_tile*ts + p_in
            repl[p] = lo + sym(pt) * ts + sym(pi)
        m.params = new_params
        m.ranges = new_ranges
        if not m.label.endswith("_tiled"):
            m.label += "_tiled"
        scopes = st.scope_children()
        stack = list(scopes.get(entry, []))
        nodes = {entry} | set(stack)
        while stack:
            nd = stack.pop()
            if isinstance(nd, MapEntry):
                for child in scopes.get(nd, []):
                    if child not in nodes:
                        nodes.add(child)
                        stack.append(child)
        for e in st.edges:
            if e.src in nodes or e.dst in nodes:
                if e.memlet.subset is not None:
                    e.memlet.subset = e.memlet.subset.subs(repl)
