"""MapTiling: split map dimensions into (tile-counter, intra-tile) pairs —
the platform-agnostic transformation the paper lists among the DaCe toolbox
(§3.2), used on TPU to align block shapes with VMEM capacity and the
VPU/MXU lane layout.

Tiling is **multi-dimensional and alignment-aware**: every eligible map
parameter is split independently (mixed radix — one tile parameter per
dimension), and the default tile sizes follow the TPU register layout the
way the paper's Vectorization transform (§3.2.4) widens the FPGA data
path: the minor (innermost) parameter tiles to the vector width recorded
by ``Vectorization`` (``sdfg.metadata['vector_width']``, default 128
lanes), the next parameter to the **dtype-aware sublane count** (fp32 ->
8, bf16/fp16 -> 16, int8/fp8 -> 32 — the narrowest container accessed by
the scope wins, falling back to the Vectorization-recorded
``sublane_width``). Non-divisible extents are remainder-safe: the tile
counter ranges over ``ceil(n / tile)`` blocks and the grid code generator
masks the partial final block (the structural interpreter enumerates only
valid lattice points).

Tiled maps are annotated with the tile structure: ``annotations['tiling']``
maps each intra-tile parameter to
``{"tile", "counter", "extent", "blocks", "start"}``. The Pallas grid
code generator (``GridConversionPass`` + ``pallas_backend``) consumes it
to derive BlockSpec block shapes: intra-tile parameters widen memlet index
dimensions into VMEM-resident blocks while tile-counter parameters become
grid dimensions. The annotation — not the ``_tiled`` label suffix, which
is purely cosmetic — is also what makes the transformation idempotent, so
fuse-after-tile and per-dimension re-tiling compose.

``range_equivalence`` is the annotation-aware iteration-space matcher
``MapFusion`` consults so that tiling and fusion commute: a tiled
producer matches an untiled consumer over the same underlying extent
(the consumer parameter renames onto ``start + counter*tile + intra``),
two maps tiled with the same annotation match pair-for-pair, and an
untiled producer facing a tiled consumer is retiled in place with the
consumer's tile structure.
"""
from __future__ import annotations

import math
from typing import Dict, List, Optional, Tuple

from ..core.dtypes import (ScheduleType, TPU_LANES, TPU_SUBLANES,
                           sublanes_for_bytes)
from ..core.memlet import Range
from ..core.sdfg import Array, MapEntry, SDFG
from ..core.symbolic import Expr, sym
from .base import Transformation

#: schedules whose maps tile (grid-eligible schedules; UNROLLED / MESH
#: scopes are replicated hardware and keep their per-lane identity).
_TILABLE = (ScheduleType.PIPELINED, ScheduleType.DEVICE)


def normalize_tiling(ann: Dict) -> Dict[str, Dict]:
    """Normalize a ``tiling`` annotation to the rich per-parameter form.
    Legacy entries (``{param: extent_int}``) carry no counter/extent
    information and are treated as exactly-divisible."""
    out = {}
    for q, info in (ann or {}).items():
        if isinstance(info, dict):
            out[q] = info
        else:
            out[q] = {"tile": int(info), "counter": None,
                      "extent": None, "blocks": None}
    return out


def _choose_tile(n: int, preferred: int) -> Optional[int]:
    """Tile size for an extent of ``n`` elements given a preferred
    (alignment) width: the preferred width when it divides ``n``, else the
    largest divisor of ``n`` within [preferred/4, preferred] (aligned
    blocks, no remainder), else the preferred width with a masked partial
    final block. None when ``n`` is too small to be worth splitting."""
    if n <= 1 or preferred <= 1:
        return None
    if n <= preferred:
        return n                      # whole dimension in one block
    if n % preferred == 0:
        return preferred
    for d in range(preferred, max(2, preferred // 4) - 1, -1):
        if n % d == 0:
            return d
    return preferred                  # ceil-division, masked partial block


# ---------------------------------------------------------------------------
# Annotation-aware iteration-space equivalence (MapFusion support)
# ---------------------------------------------------------------------------


def _logical_dims(m) -> Optional[List[Tuple]]:
    """Group a map's parameters into logical iteration dimensions: a
    MapTiling'd (counter, intra) pair is ONE dimension over its original
    extent; every other parameter is its own dimension. Entries are
    ``("tiled", counter, intra, info)`` / ``("plain", param, range)``.
    Returns None when the parameter order interleaves pairs in a way the
    positional reconstruction cannot express."""
    tiling = normalize_tiling(m.annotations.get("tiling"))
    rich = {q: info for q, info in tiling.items()
            if info.get("counter") in m.params
            and info.get("extent") is not None and q in m.params}
    counters = {info["counter"]: q for q, info in rich.items()}
    dims, order, seen = [], [], set()
    for p, r in zip(m.params, m.ranges):
        if p in seen:
            continue
        if p in rich:
            info = rich[p]
            dims.append(("tiled", info["counter"], p, info))
            seen |= {p, info["counter"]}
            order += [info["counter"], p]
        elif p in counters:
            q = counters[p]
            dims.append(("tiled", p, q, rich[q]))
            seen |= {p, q}
            order += [p, q]
        else:
            dims.append(("plain", p, r))
            order.append(p)
    if order != list(m.params):
        return None   # non-adjacent pair members: positional form ambiguous
    return dims


def range_equivalence(prod, cons, env: Dict[str, int]) -> Optional[Dict]:
    """Match the iteration spaces of a producer and consumer map up to
    MapTiling splits, using ``annotations['tiling']`` as the contract.

    Returns None when the spaces differ, else a plan::

        {"ren":       consumer param -> Expr over final producer params,
         "prod_repl": producer param -> Expr   (retile substitution; only
                      non-empty when an untiled producer dim must adopt
                      the consumer's tiling),
         "params", "ranges": the fused map's final parameter list,
         "sizes":     final param -> int range size (None if symbolic),
         "tiling":    tiling annotation entries the fused map must carry}
    """
    pdims, cdims = _logical_dims(prod), _logical_dims(cons)
    if pdims is None or cdims is None or len(pdims) != len(cdims):
        return None
    ren: Dict[str, Expr] = {}
    prod_repl: Dict[str, Expr] = {}
    params: List[str] = []
    ranges: List[Range] = []
    tiling: Dict[str, Dict] = {}
    plain_pairs = []
    taken = set(prod.params)

    def _static(e) -> Optional[int]:
        try:
            return int(Expr.wrap(e).evaluate(env))
        except Exception:
            return None

    def _info_nums(info) -> Optional[Tuple[int, int, int, int]]:
        start = info.get("start", 0)
        if start is None:
            return None
        try:
            return (int(info["tile"]), int(info["extent"]),
                    int(info["blocks"]), int(start))
        except (KeyError, TypeError, ValueError):
            return None

    def _fresh(name: str) -> str:
        while name in taken:
            name += "_f"
        taken.add(name)
        return name

    for pd, cd in zip(pdims, cdims):
        if pd[0] == "plain" and cd[0] == "plain":
            _, pp, pr = pd
            _, cp, cr = cd
            if cp != pp:
                ren[cp] = Expr.sym(pp)
            plain_pairs.append((pr, cr))
            params.append(pp)
            ranges.append(pr)
        elif pd[0] == "tiled" and cd[0] == "tiled":
            _, pctr, pq, pinfo = pd
            _, cctr, cq, cinfo = cd
            pn, cn = _info_nums(pinfo), _info_nums(cinfo)
            if pn is None or cn is None or pn != cn:
                return None
            if cctr != pctr:
                ren[cctr] = Expr.sym(pctr)
            if cq != pq:
                ren[cq] = Expr.sym(pq)
            params += [pctr, pq]
            ranges += [Range.make(0, pn[2]), Range.make(0, pn[0])]
            tiling[pq] = dict(pinfo)
        elif pd[0] == "tiled":
            # tiled producer, untiled consumer: the consumer parameter is
            # the composed producer index
            _, pctr, pq, pinfo = pd
            _, cp, cr = cd
            pn = _info_nums(pinfo)
            cs, csz, cst = _static(cr.start), _static(cr.size), \
                _static(cr.step)
            if pn is None or None in (cs, csz, cst):
                return None
            if cst != 1 or cs != pn[3] or csz != pn[1]:
                return None
            taken |= {pctr, pq}
            ren[cp] = (Expr.const(pn[3]) + Expr.sym(pctr) * pn[0]
                       + Expr.sym(pq))
            params += [pctr, pq]
            ranges += [Range.make(0, pn[2]), Range.make(0, pn[0])]
            tiling[pq] = dict(pinfo)
        else:
            # untiled producer, tiled consumer: retile the producer in
            # place with the consumer's tile structure
            _, pp, pr = pd
            _, cctr, cq, cinfo = cd
            cn = _info_nums(cinfo)
            ps, psz, pst = _static(pr.start), _static(pr.size), \
                _static(pr.step)
            if cn is None or None in (ps, psz, pst):
                return None
            if pst != 1 or ps != cn[3] or psz != cn[1]:
                return None
            taken.discard(pp)         # pp is being replaced: its name frees up
            nctr, nq = _fresh(cctr), _fresh(cq)
            prod_repl[pp] = (Expr.const(cn[3]) + Expr.sym(nctr) * cn[0]
                             + Expr.sym(nq))
            if cctr != nctr:
                ren[cctr] = Expr.sym(nctr)
            if cq != nq:
                ren[cq] = Expr.sym(nq)
            params += [nctr, nq]
            ranges += [Range.make(0, cn[2]), Range.make(0, cn[0])]
            tiling[nq] = {**cinfo, "counter": nctr}
    for pr, cr in plain_pairs:
        if cr.subs(ren) != pr:
            return None
    sizes = {p: _static(r.size) for p, r in zip(params, ranges)}
    return {"ren": ren, "prod_repl": prod_repl, "params": params,
            "ranges": ranges, "sizes": sizes, "tiling": tiling or None}


class MapTiling(Transformation):
    """Split every eligible parameter of PIPELINED/DEVICE maps into a
    (counter, intra) pair. ``tile_size`` overrides the preferred *minor*
    (lane) width of the default policy and ``second_size`` the preferred
    second-minor (sublane) width — like the defaults, they plan each
    map exactly once (an already-annotated map is left alone, so fixpoint
    re-matches cannot whole-tile deliberately-skipped dims). Only
    ``tile_sizes`` — explicit per-parameter tiles — composes with earlier
    tilings, one dimension at a time."""

    def __init__(self, tile_size: int = None, map_label: str = None,
                 tile_sizes: Dict[str, int] = None, second_size: int = None):
        self.tile_size = tile_size
        self.map_label = map_label
        self.tile_sizes = tile_sizes
        self.second_size = second_size

    # ------------------------------------------------------------------
    def _scope_nodes(self, st, entry: MapEntry) -> set:
        scopes = st.scope_children()
        nodes = {entry}
        stack = list(scopes.get(entry, []))
        while stack:
            nd = stack.pop()
            if nd in nodes:
                continue
            nodes.add(nd)
            if isinstance(nd, MapEntry):
                stack.extend(scopes.get(nd, []))
        return nodes

    def _shared_dim_params(self, sdfg: SDFG, st, entry: MapEntry,
                          nodes: set) -> set:
        """Parameters that co-index a memlet dimension with another map
        parameter (e.g. ``x[c*K + l]``), that index a dimension with a
        non-unit coefficient (strided access like a pooling read
        ``t[2*ph + u]``), or that offset a non-unit *range* (a windowed
        read like a conv's ``x[ow:ow+5]``): splitting any of these would
        need a block index map BlockSpec factorization cannot express —
        leave them whole."""
        from ..core.symbolic import Expr
        pset = set(entry.map.params)
        shared = set()
        for e in st.edges:
            if e.src not in nodes and e.dst not in nodes:
                continue
            if e.memlet.subset is None:
                continue
            for r in e.memlet.subset:
                used = (r.start.free_symbols | r.stop.free_symbols) & pset
                if len(used) > 1:
                    shared |= used
                if used and not r.is_index():
                    shared |= used
                for expr in (r.start, r.stop):
                    for mono, c in Expr.wrap(expr).terms.items():
                        for name, _ in mono:
                            if name in pset and abs(c) != 1:
                                shared.add(name)
        return shared

    def _scope_sublanes(self, sdfg: SDFG, st, entry: MapEntry,
                        nodes: set) -> int:
        """Dtype-aware sublane preference for one scope: the narrowest
        Array element among the containers its memlets touch decides the
        packing (fp32 -> 8, bf16 -> 16, int8 -> 32); scopes touching no
        sized array fall back to the Vectorization-recorded default."""
        min_bytes = None
        for e in st.edges:
            if e.src not in nodes and e.dst not in nodes:
                continue
            desc = sdfg.arrays.get(e.memlet.data) \
                if e.memlet.data is not None else None
            if isinstance(desc, Array) and not desc.is_stream and desc.shape:
                b = desc.dtype.bytes
                min_bytes = b if min_bytes is None else min(min_bytes, b)
        if min_bytes is None:
            return sdfg.metadata.get("sublane_width") or TPU_SUBLANES
        return sublanes_for_bytes(min_bytes)

    def _plan(self, sdfg: SDFG, st, entry: MapEntry,
              tile_size: int, tile_sizes: Dict[str, int],
              second_size: int = None) -> Dict[str, int]:
        """Per-parameter tile plan for one map (param -> tile size)."""
        m = entry.map
        tiling = normalize_tiling(m.annotations.get("tiling"))
        counters = {info.get("counter") for info in tiling.values()}
        if tiling and not tile_sizes:
            # the default policy plans a map exactly once: params it left
            # untiled (small second dims, outer/batch dims) were left
            # deliberately — a fixpoint re-match must not whole-tile them
            # as fresh "minor" dims. Explicit tile_sizes still compose.
            return {}
        env = sdfg.symbol_values
        sizes = {}
        for p, r in zip(m.params, m.ranges):
            if p in tiling or p in counters:
                continue              # already tiled: idempotence
            try:
                sizes[p] = int(r.size.evaluate(env))
            except Exception:
                continue              # dynamic extent: cannot tile
        if not sizes:
            return {}
        nodes = self._scope_nodes(st, entry)
        shared = self._shared_dim_params(sdfg, st, entry, nodes)
        candidates = [p for p in m.params if p in sizes and p not in shared]
        if not candidates:
            return {}
        plan: Dict[str, int] = {}
        if tile_sizes:
            for p in candidates:
                if p in tile_sizes and sizes[p] > 1:
                    plan[p] = max(1, min(int(tile_sizes[p]), sizes[p]))
            return plan
        lanes = tile_size or sdfg.metadata.get("vector_width") or TPU_LANES
        sublanes = second_size or self._scope_sublanes(sdfg, st, entry, nodes)
        minor = candidates[-1]
        if len(m.params) == 1:
            # a 1-D map only tiles when it yields >= 2 blocks (a whole-dim
            # block would collapse the grid to a single step)
            if sizes[minor] > lanes:
                plan[minor] = _choose_tile(sizes[minor], lanes)
        else:
            t = _choose_tile(sizes[minor], lanes)
            if t is not None:
                plan[minor] = t
            if len(candidates) >= 2:
                second = candidates[-2]
                if sizes[second] > sublanes:
                    t2 = _choose_tile(sizes[second], sublanes)
                    if t2 is not None:
                        plan[second] = t2
        return {p: t for p, t in plan.items() if t and t >= 1}

    # ------------------------------------------------------------------
    def find_matches(self, sdfg: SDFG, tile_size: int = None,
                     map_label: str = None, tile_sizes: Dict[str, int] = None,
                     second_size: int = None, **kwargs):
        ts = tile_size if tile_size is not None else self.tile_size
        label = map_label or self.map_label
        explicit = tile_sizes if tile_sizes is not None else self.tile_sizes
        second = second_size if second_size is not None else self.second_size
        for st in sdfg.states:
            for node in st.nodes:
                if not isinstance(node, MapEntry):
                    continue
                m = node.map
                if label and not m.label.startswith(label):
                    continue
                if m.schedule not in _TILABLE:
                    continue
                plan = self._plan(sdfg, st, node, ts, explicit, second)
                if plan:
                    yield {"state": st, "entry": node, "plan": plan}

    def apply_match(self, sdfg: SDFG, match: Dict):
        st, entry, plan = match["state"], match["entry"], match["plan"]
        m = entry.map
        env = sdfg.symbol_values
        ann = m.annotations.setdefault("tiling", {})
        new_params, new_ranges, repl = [], [], {}
        for p, r in zip(m.params, m.ranges):
            if p not in plan:
                new_params.append(p)
                new_ranges.append(r)
                continue
            ts = plan[p]
            n = int(r.size.evaluate(env))
            blocks = math.ceil(n / ts)
            lo = r.start
            try:
                start = int(lo.evaluate(env))
            except Exception:
                start = None          # symbolic start: fusion equivalence
                                      # across this split is refused
            pt, pi = f"{p}_tile", f"{p}_in"
            new_params += [pt, pi]
            new_ranges += [Range.make(0, blocks), Range.make(0, ts)]
            ann[pi] = {"tile": ts, "counter": pt, "extent": n,
                       "blocks": blocks, "start": start}
            # rewrite memlets in the scope: p -> lo + p_tile*ts + p_in
            repl[p] = lo + sym(pt) * ts + sym(pi)
        m.params = new_params
        m.ranges = new_ranges
        if not m.label.endswith("_tiled"):
            m.label += "_tiled"
        nodes = self._scope_nodes(st, entry)
        for e in st.edges:
            if e.src in nodes or e.dst in nodes:
                if e.memlet.subset is not None:
                    e.memlet.subset = e.memlet.subset.subs(repl)
