"""MapTiling: split a map dimension into (tile, intra-tile) — the
platform-agnostic transformation the paper lists among the DaCe toolbox
(§3.2), used on TPU to align block shapes with VMEM capacity.

Tiled maps are annotated with the tile structure (``annotations['tiling']``
maps each intra-tile parameter to its extent); the Pallas grid code
generator (``GridConversionPass`` + ``pallas_backend``) consumes it to
derive BlockSpec block shapes: tile parameters widen memlet index
dimensions into VMEM-resident blocks while tile-counter parameters become
grid dimensions.
"""
from __future__ import annotations

from typing import Dict

from ..core.memlet import Range
from ..core.sdfg import MapEntry, SDFG
from ..core.symbolic import Expr, sym
from .base import Transformation


class MapTiling(Transformation):
    def __init__(self, tile_size: int = 128, map_label: str = None):
        self.tile_size = tile_size
        self.map_label = map_label

    def find_matches(self, sdfg: SDFG, tile_size: int = None,
                     map_label: str = None, **kwargs):
        ts = tile_size or self.tile_size
        label = map_label or self.map_label
        for st in sdfg.states:
            for node in st.nodes:
                if not isinstance(node, MapEntry):
                    continue
                m = node.map
                if label and not m.label.startswith(label):
                    continue
                if len(m.params) != 1 or m.label.endswith("_tiled"):
                    continue
                r = m.ranges[0]
                try:
                    n = r.size.evaluate(sdfg.symbol_values)
                except Exception:
                    continue
                if n % ts == 0 and n > ts:
                    yield {"state": st, "entry": node, "tile": ts}

    def apply_match(self, sdfg: SDFG, match: Dict):
        st, entry, ts = match["state"], match["entry"], match["tile"]
        m = entry.map
        p = m.params[0]
        lo = m.ranges[0].start
        n = m.ranges[0].size
        pt, pi = f"{p}_tile", f"{p}_in"
        m.params = [pt, pi]
        m.ranges = [Range.make(0, n / ts), Range.make(0, ts)]
        m.label += "_tiled"
        # metadata for the grid code generator: intra-tile params span
        # VMEM-resident blocks, tile counters become the grid.
        m.annotations.setdefault("tiling", {})[pi] = ts
        # rewrite memlets in the scope: p -> lo + p_tile*ts + p_in
        repl = {p: lo + sym(pt) * ts + sym(pi)}
        scopes = st.scope_children()
        stack = list(scopes.get(entry, []))
        nodes = {entry} | set(stack)
        while stack:
            nd = stack.pop()
            if isinstance(nd, MapEntry):
                for child in scopes.get(nd, []):
                    if child not in nodes:
                        nodes.add(child)
                        stack.append(child)
        for e in st.edges:
            if e.src in nodes or e.dst in nodes:
                if e.memlet.subset is not None:
                    e.memlet.subset = e.memlet.subset.subs(repl)
