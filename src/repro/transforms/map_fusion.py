"""MapFusion: fuse producer->consumer map scopes over matching ranges.

The paper's streaming composition removes an off-chip round-trip by
turning the intermediate container into a FIFO between two processing
elements. MapFusion is the tighter, whole-dataflow variant (cf. FLOWER's
fusion of adjacent processing stages): when a map writes a transient that
a second map over the *same* iteration space reads back element-for-
element, the two scopes merge into one and the intermediate stops being a
container access altogether — it becomes a per-iteration value carried on
a direct tasklet->tasklet edge inside the fused scope. On TPU the fused
scope lowers to a single Pallas grid kernel whose intermediate lives in
registers/VMEM, where the unfused pair was two kernel launches with an
HBM array between them.

Legality (checked per match, mirrored by tests/test_map_fusion.py):

  * the intermediate is a transient ``Array`` accessed at exactly one
    node in the whole SDFG, written once by the producer's exit and read
    only by the consumer's entry (no other readers/writers);
  * producer and consumer ranges match positionally (after renaming the
    consumer's parameters onto the producer's);
  * every consumer read subset equals the producer write subset under
    that renaming — offset reads (stencil halos) refuse to fuse;
  * no write-conflict resolution on the intermediate's edges (a wcr
    write is not a per-iteration value);
  * both scopes contain only tasklets, and fusing must not reorder
    accesses to any *other* container shared between the two scopes.

After fusion the intermediate's descriptor is retargeted to registers
(``StorageType.REG``): it no longer appears at any access node, so it
contributes nothing to the off-chip volume metric.
"""
from __future__ import annotations

from typing import Dict, Optional

from ..core.dtypes import ScheduleType, StorageType
from ..core.memlet import Memlet
from ..core.sdfg import (AccessNode, Array, MapEntry, MapExit, Scalar, SDFG,
                         State, Stream, Tasklet)
from ..core.symbolic import Expr
from .base import Transformation

#: schedules whose scopes may fuse (grid-eligible schedules; UNROLLED /
#: MESH scopes are replicated hardware and keep their own identity).
_FUSIBLE = (ScheduleType.PIPELINED, ScheduleType.DEVICE)


def _consumer_entry(state: State, node: AccessNode) -> Optional[MapEntry]:
    """The single MapEntry consuming ``node``, or None."""
    dsts = {e.dst for e in state.out_edges(node)}
    if len(dsts) != 1:
        return None
    (dst,) = dsts
    return dst if isinstance(dst, MapEntry) else None


def _scope_tasklets(state: State, scopes, entry: MapEntry):
    """Directly-contained nodes minus the exit; None if any is not a
    Tasklet (nested maps / access nodes keep their scopes separate)."""
    inner = [n for n in scopes.get(entry, []) if not isinstance(n, MapExit)]
    if not inner or not all(isinstance(n, Tasklet) for n in inner):
        return None
    return inner


def _param_renaming(prod, cons) -> Optional[Dict[str, Expr]]:
    """Positional consumer->producer parameter renaming, or None when the
    iteration spaces differ."""
    if len(prod.params) != len(cons.params):
        return None
    ren = {cp: Expr.sym(pp) for cp, pp in zip(cons.params, prod.params)
           if cp != pp}
    for rp, rc in zip(prod.ranges, cons.ranges):
        if rc.subs(ren) != rp:
            return None
    return ren


class MapFusion(Transformation):
    """transient array node between a map exit and a map entry over the
    same iteration space -> merge the scopes; the intermediate becomes a
    direct per-iteration tasklet->tasklet edge."""

    def find_matches(self, sdfg: SDFG, **kwargs):
        for st in sdfg.states:
            for node in st.data_nodes():
                desc = sdfg.arrays.get(node.data)
                if not isinstance(desc, Array) or isinstance(desc, (Stream,)):
                    continue
                if not desc.transient:
                    continue
                if st.in_degree(node) != 1:
                    continue
                if not isinstance(st.in_edges(node)[0].src, MapExit):
                    continue
                if _consumer_entry(st, node) is None:
                    continue
                yield {"state": st, "node": node}

    # ------------------------------------------------------------------
    def can_apply(self, sdfg: SDFG, match: Dict) -> bool:
        st: State = match["state"]
        node: AccessNode = match["node"]
        if node not in st.graph:
            return False
        t = node.data
        desc = sdfg.arrays.get(t)
        if not isinstance(desc, Array) or isinstance(desc, (Stream, Scalar)):
            return False
        if not desc.transient or t in sdfg.metadata.get("pin_hbm", ()):
            return False
        # the one access node in the whole SDFG (no cross-PE aliasing)
        count = sum(1 for s in sdfg.states for n in s.data_nodes()
                    if n.data == t)
        if count != 1 or st.in_degree(node) != 1:
            return False
        in_e = st.in_edges(node)[0]
        if not isinstance(in_e.src, MapExit):
            return False
        px: MapExit = in_e.src
        ce = _consumer_entry(st, node)
        if ce is None or ce is px.entry:
            return False
        prod, cons = px.map, ce.map
        if prod.schedule not in _FUSIBLE or cons.schedule not in _FUSIBLE:
            return False
        ren = _param_renaming(prod, cons)
        if ren is None:
            return False
        scopes = st.scope_children()
        if _scope_tasklets(st, scopes, px.entry) is None:
            return False
        if _scope_tasklets(st, scopes, ce) is None:
            return False
        cx = next((n for n in st.nodes
                   if isinstance(n, MapExit) and n.entry is ce), None)
        if cx is None:
            return False
        # exactly one in-scope writer of t, plain (no wcr), static subset
        w_edges = [e for e in st.in_edges(px) if e.memlet.data == t]
        if len(w_edges) != 1:
            return False
        w = w_edges[0]
        if w.memlet.wcr is not None or w.memlet.dynamic \
                or w.memlet.subset is None:
            return False
        if in_e.memlet.wcr is not None:
            return False
        # the writes must be disjoint across iterations — otherwise the
        # fused consumer reads its iteration's private value where the
        # sequential schedule delivered the LAST write. Sufficient
        # condition for an injective index map: every parameter indexes
        # exactly one size-1 dimension, and no dimension mixes two
        # parameters (t[i+j] collides; t[i:i+2] overlaps neighbors; a
        # subset ignoring a param revisits locations).
        pset = set(prod.params)
        used_params = set()
        for r in w.memlet.subset:
            rsyms = (r.start.free_symbols | r.stop.free_symbols
                     | r.step.free_symbols)
            if (rsyms & pset) and not r.is_index():
                return False
            dim_params = r.start.free_symbols & pset
            if len(dim_params) > 1 or dim_params & used_params:
                return False
            used_params |= dim_params
        if used_params != pset:
            return False
        # every consumer read must be the element the producer just wrote
        r_edges = [e for e in st.out_edges(ce) if e.memlet.data == t]
        if not r_edges:
            return False
        for e in r_edges:
            if e.memlet.wcr is not None or e.memlet.dynamic \
                    or e.memlet.subset is None:
                return False
            if e.memlet.subset.subs(ren) != w.memlet.subset:
                return False
        # renaming must not capture a consumer-scope symbol that already
        # means something else (a free symbol equal to a producer param)
        cons_free = set()
        for e in st.out_edges(ce) + st.in_edges(cx):
            if e.memlet.subset is not None:
                for r in e.memlet.subset:
                    cons_free |= (r.start.free_symbols | r.stop.free_symbols
                                  | r.step.free_symbols)
        cons_free -= set(cons.params)
        if cons_free & set(prod.params):
            return False
        # fusing must not reorder accesses to other shared containers
        prod_writes = {e.memlet.data for e in st.in_edges(px)
                       if e.memlet.data} - {t}
        prod_reads = {e.memlet.data for e in st.out_edges(px.entry)
                      if e.memlet.data}
        cons_reads = {e.memlet.data for e in st.out_edges(ce)
                      if e.memlet.data} - {t}
        cons_writes = {e.memlet.data for e in st.in_edges(cx)
                       if e.memlet.data}
        if prod_writes & (cons_reads | cons_writes):
            return False
        if cons_writes & prod_reads:
            return False
        # no consumer input may depend on the producer through a path
        # OTHER than the fused intermediate (a third scope in between):
        # rerouting those inputs to the fused entry would create a cycle
        import networkx as nx
        for e in st.in_edges(ce):
            if e.src is node:
                continue
            if nx.has_path(st.graph, px, e.src):
                return False
        return True

    # ------------------------------------------------------------------
    def apply_match(self, sdfg: SDFG, match: Dict):
        st: State = match["state"]
        node: AccessNode = match["node"]
        t = node.data
        in_e = st.in_edges(node)[0]
        px: MapExit = in_e.src
        pe: MapEntry = px.entry
        prod = px.map
        ce = _consumer_entry(st, node)
        cons = ce.map
        cx = next(n for n in st.nodes
                  if isinstance(n, MapExit) and n.entry is ce)
        ren = _param_renaming(prod, cons)

        def rn(memlet: Memlet) -> Memlet:
            if ren and memlet.subset is not None:
                return Memlet(data=memlet.data,
                              subset=memlet.subset.subs(ren),
                              volume=memlet.volume, wcr=memlet.wcr,
                              dynamic=memlet.dynamic)
            return memlet

        scopes = st.scope_children()
        cons_inner = set(_scope_tasklets(st, scopes, ce))

        # the producer tasklet that computes t, and its output connector
        w_edge = next(e for e in st.in_edges(px) if e.memlet.data == t)
        writer, writer_conn = w_edge.src, w_edge.src_conn

        # outer sources feeding the consumer entry, and existing producer
        # entry inputs (dedupe key: (source node, entry connector))
        outer_src = {e.memlet.data: e.src for e in st.in_edges(ce)
                     if e.memlet.data not in (None, t)}
        pe_in = {(e.src, e.dst_conn) for e in st.in_edges(pe)}

        # consumer-scope reads: through the fused entry, or — for the
        # intermediate — straight off the producer tasklet
        for e in list(st.out_edges(ce)):
            if e.memlet.data == t:
                st.add_edge(writer, writer_conn, e.dst, e.dst_conn,
                            rn(e.memlet))
                continue
            st.add_edge(pe, e.src_conn, e.dst, e.dst_conn, rn(e.memlet))
            d = e.memlet.data
            if d is not None and d in outer_src:
                key = (outer_src[d], f"IN_{d}")
                if key not in pe_in:
                    st.add_edge(outer_src[d], None, pe, f"IN_{d}",
                                Memlet.simple(d))
                    pe_in.add(key)

        # consumer-internal tasklet->tasklet edges: rename in place
        for e in st.edges:
            if e.src in cons_inner and e.dst in cons_inner:
                e.memlet = rn(e.memlet)

        # consumer-scope writes: through the fused exit
        for e in list(st.in_edges(cx)):
            st.add_edge(e.src, e.src_conn, px, e.dst_conn, rn(e.memlet))
        for e in list(st.out_edges(cx)):
            st.add_edge(px, e.src_conn, e.dst, e.dst_conn, e.memlet)

        # drop the intermediate round-trip and the consumed scope shell
        st.remove_edge(w_edge)
        st.remove_node(node)
        st.remove_node(ce)
        st.remove_node(cx)

        prod.label = f"{prod.label}+{cons.label}"
        # the intermediate now lives on a per-iteration edge only: pure
        # on-chip storage, out of the off-chip volume metric
        sdfg.arrays[t].storage = StorageType.REG
