"""MapFusion: fuse producer->consumer map scopes over matching ranges.

The paper's streaming composition removes an off-chip round-trip by
turning the intermediate container into a FIFO between two processing
elements. MapFusion is the tighter, whole-dataflow variant (cf. FLOWER's
fusion of adjacent processing stages): when a map writes a transient that
a second map reads back, the two scopes merge into one and the
intermediate stops being a container access altogether — it becomes a
per-iteration value carried on a direct tasklet->tasklet edge inside the
fused scope. On TPU the fused scope lowers to a single Pallas grid kernel
whose intermediate lives in registers/VMEM, where the unfused pair was
two kernel launches with an HBM array between them.

Three fusion modes, tried in order per (intermediate, consumer) match:

**exact** — the original whole-dataflow fusion: producer and consumer
iterate equivalent spaces (up to MapTiling splits,
:func:`transforms.map_tiling.range_equivalence`) and every consumer read
is element-for-element the producer's write. Handles producer DAGs,
multi-intermediate groups, scalar intermediates, and fuse-across-tiling
exactly as before.

**wcr** — a producer whose write carries write-conflict resolution
(``wcr="add"``) feeds a consumer that reads the finished reduction
element-exactly. The scopes merge over the *producer's* space (which
carries the reduction parameters); the intermediate becomes an
accumulating tasklet->tasklet edge (``Memlet(wcr="add")``) inside the
fused scope. The sequential interpreter accumulates in place and the
consumer's final re-execution wins; the vectorizing backends lower this
as a two-phase accumulate+consume grid (jnp: reduce between two vmapped
phases; Pallas: scratch accumulation with a ``@pl.when`` phase flip).
Restricted to ``add`` because its identity matches the zero-initialized
intermediate; the consumer must be idempotent under re-execution
(side-effect free, never reading a container it writes).

**halo** — the write-order = read-order rule shared with
StreamingComposition (:func:`transforms.streaming.solve_write_read_sigma`):
a producer writing ``t[p + c]`` per iteration and a consumer reading
``t[f(q)]`` fuse whenever the affine renaming ``sigma(p) = f(q) - c``
maps the consumer's iteration box into the producer's. The producer's
tasklets are *replicated* into the consumer scope once per distinct
``sigma`` (shifted-window reads of a stencil stage become shifted
replicas reading the producer's inputs directly), with content-addressed
deduplication so chained stencils grow linearly (a depth-k radius-1
chain costs 1+3+...+(2k-1) tasklets, not 3^k). Multi-consumer
intermediates fuse the same way: each consumer gets its own replicas and
the producer is kept while other readers remain (``keep``) or deleted
with the intermediate once the last reader fuses.

Legality (checked per match, mirrored by tests/test_map_fusion.py):

  * each intermediate is a transient ``Array``/``Scalar`` accessed at
    exactly one node in the whole SDFG, written once by the producer's
    exit; exact mode additionally requires the consumer's entry to be
    its only reader;
  * exact mode: iteration spaces equivalent under ``range_equivalence``,
    reads element-exact under the renaming, writes disjoint across
    iterations (:func:`_injective_write`), no wcr anywhere on the
    intermediate;
  * halo mode: both scopes untiled with static unit-step ranges, the
    write an injective unit-coefficient parameter shift, every read an
    affine index whose ``sigma`` image is covered by the producer's box,
    and the replication budget (``max_fused_tasklets``,
    ``max_replicated_producer`` for kept producers) not exceeded;
  * wcr mode: ``add`` only, single intermediate and single consumer,
    element-exact reads pairing consumer params to the write's output
    params over equal ranges, with at least one genuine reduction
    parameter left over;
  * both scopes contain only tasklets, and fusing must not reorder
    accesses to any *other* container shared between the two scopes.

Refusals record a typed reason (``MapFusion.explain``) that the pipeline
surfaces in ``report["grid_skipped"]`` / ``grid_decisions``.

After fusion each fully-consumed intermediate's descriptor is retargeted
to registers (``StorageType.REG``): it no longer appears at any access
node, so it contributes nothing to the off-chip volume metric. Fused
labels join the component labels with ``+`` (stripping the cosmetic
``_tiled`` suffix from components, re-appending it when the fused map
carries tiling annotations), so fuse-then-tile and tile-then-fuse name
the same kernel.
"""
from __future__ import annotations

from fractions import Fraction
from typing import Dict, List, Optional, Tuple

import networkx as nx

from ..core.dtypes import ScheduleType, StorageType
from ..core.memlet import Memlet, Subset
from ..core.sdfg import (AccessNode, Array, MapEntry, MapExit, Scalar, SDFG,
                         State, Stream, Tasklet)
from ..core.symbolic import Expr
from .base import Transformation
from .map_tiling import range_equivalence
from .streaming import affine_decompose, sigma_covered, solve_write_read_sigma

#: schedules whose scopes may fuse (grid-eligible schedules; UNROLLED /
#: MESH scopes are replicated hardware and keep their own identity).
_FUSIBLE = (ScheduleType.PIPELINED, ScheduleType.DEVICE)


def _consumer_entry(state: State, node: AccessNode) -> Optional[MapEntry]:
    """The single MapEntry consuming ``node``, or None."""
    dsts = {e.dst for e in state.out_edges(node)}
    if len(dsts) != 1:
        return None
    (dst,) = dsts
    return dst if isinstance(dst, MapEntry) else None


def _scope_tasklets(state: State, scopes, entry: MapEntry):
    """Directly-contained nodes minus the exit; None if any is not a
    Tasklet (nested maps / access nodes keep their scopes separate)."""
    inner = [n for n in scopes.get(entry, []) if not isinstance(n, MapExit)]
    if not inner or not all(isinstance(n, Tasklet) for n in inner):
        return None
    return inner


def _fusible_desc(desc) -> bool:
    return (isinstance(desc, (Array, Scalar)) and not isinstance(desc, Stream)
            and desc.transient)


def _scalar_like(desc) -> bool:
    return not getattr(desc, "shape", ())


def _base_label(lbl: str) -> str:
    return lbl[:-len("_tiled")] if lbl.endswith("_tiled") else lbl


def _group(state: State, px: MapExit, ce: MapEntry) -> Optional[List[AccessNode]]:
    """Every access node carried from ``px`` into ``ce``. All of them
    must fuse together (a leftover container between the pair would put a
    cycle through the fused scope); an access node that also feeds a
    third consumer poisons the whole pair — returns None."""
    members = []
    for e in state.out_edges(px):
        dst = e.dst
        if not isinstance(dst, AccessNode):
            continue
        outs = state.out_edges(dst)
        to_ce = [o for o in outs if o.dst is ce]
        if not to_ce:
            continue
        if len(to_ce) != len(outs):
            return None
        members.append(dst)
    return members or None


def _injective_write(subset: Optional[Subset],
                     sizes: Dict[str, Optional[int]]) -> bool:
    """True when the write subset touches a distinct location on every
    iteration of the (final) parameter space. Parameters whose range has
    a single iteration cannot revisit anything and are exempt; a
    dimension combining several parameters is accepted exactly when its
    coefficients form a positional (mixed-radix) system — the MapTiling
    ``start + counter*tile + intra`` shape — and rejected otherwise
    (``t[i+j]`` collides across iterations)."""
    used = set()
    pset = set(sizes)
    if subset is None or len(subset) == 0:
        return all(sz == 1 for sz in sizes.values())
    for r in subset:
        rsyms = (r.start.free_symbols | r.stop.free_symbols
                 | r.step.free_symbols)
        if (rsyms & pset) and not r.is_index():
            return False
        terms = []
        for mono, c in r.start.terms.items():
            if mono == ():
                continue
            names = [nm for nm, _ in mono]
            if not any(nm in pset for nm in names):
                continue
            if len(mono) != 1 or mono[0][1] != 1:
                return False          # non-affine in a parameter
            name = mono[0][0]
            if isinstance(c, Fraction):
                if c.denominator != 1:
                    return False
                c = c.numerator
            coeff = abs(int(c))
            if coeff == 0:
                continue
            sz = sizes.get(name)
            if sz is None:
                return False          # dynamic extent: cannot prove
            if sz <= 1:
                continue              # single iteration: no collision
            if name in used:
                return False          # same param indexes two dimensions
            terms.append((coeff, sz, name))
        terms.sort()
        span = 0
        for coeff, sz, name in terms:
            if coeff <= span:
                return False          # offsets of smaller terms overlap
            span += coeff * (sz - 1)
        used |= {name for _, _, name in terms}
    covering = {p for p, sz in sizes.items() if sz is None or sz > 1}
    return covering <= used


def _expr_key(e) -> tuple:
    return tuple(sorted(Expr.wrap(e).terms.items()))


def _subset_key(sub: Optional[Subset]):
    if sub is None:
        return None
    return tuple((_expr_key(r.start), _expr_key(r.stop), _expr_key(r.step))
                 for r in sub)


def _sigma_key(sigma: Dict[str, Expr]) -> tuple:
    return tuple(sorted((p, _expr_key(e)) for p, e in sigma.items()))


def _edge_symbols(memlet: Memlet) -> set:
    out = set()
    if memlet.subset is not None:
        for r in memlet.subset:
            out |= (r.start.free_symbols | r.stop.free_symbols
                    | r.step.free_symbols)
    return out


def prune_dead_scopes(sdfg: SDFG) -> List[str]:
    """Remove tasklet-only fusible scopes whose every output is an
    unread single-access transient (side-effect free dead code). Arises
    when halo fusion replicates a kept producer into its last remaining
    consumer: the producer's outputs lose their readers but the scope
    itself survives. Returns the removed map labels."""
    removed: List[str] = []
    for st in sdfg.states:
        changed = True
        while changed:
            changed = False
            scopes = st.scope_children()
            for entry, children in list(scopes.items()):
                if entry is None or entry.map.schedule not in _FUSIBLE:
                    continue
                inner = [n for n in children if not isinstance(n, MapExit)]
                if not inner or not all(
                        isinstance(n, Tasklet)
                        and getattr(n, "side_effect_free", True)
                        for n in inner):
                    continue
                px = next((n for n in children
                           if isinstance(n, MapExit) and n.entry is entry),
                          None)
                if px is None:
                    continue
                outs = st.out_edges(px)
                dead = True
                for e in outs:
                    dst = e.dst
                    if not isinstance(dst, AccessNode):
                        dead = False
                        break
                    desc = sdfg.arrays.get(dst.data)
                    if (desc is None or not getattr(desc, "transient", False)
                            or st.out_degree(dst) != 0
                            or st.in_degree(dst) != 1
                            or dst.data in sdfg.metadata.get("pin_hbm", ())):
                        dead = False
                        break
                    count = sum(1 for s in sdfg.states for n in s.data_nodes()
                                if n.data == dst.data)
                    if count != 1:
                        dead = False
                        break
                if not dead:
                    continue
                removed.append(entry.map.label)
                srcs = [e.src for e in st.in_edges(entry)]
                for n in [entry, px] + inner + [e.dst for e in outs]:
                    if n in st.graph:
                        st.remove_node(n)
                for s in srcs:
                    if (s in st.graph and st.graph.degree(s) == 0
                            and isinstance(s, AccessNode)):
                        desc = sdfg.arrays.get(s.data)
                        if desc is not None and getattr(desc, "transient",
                                                        False):
                            st.remove_node(s)
                changed = True
                break
    return removed


class MapFusion(Transformation):
    """Transient array/scalar node(s) between a map exit and a map entry
    -> merge the scopes (exact), fuse through the reduction (wcr), or
    replicate shifted producer tasklets into the consumer (halo)."""

    def __init__(self, max_fused_tasklets: int = 48,
                 max_replicated_producer: int = 4):
        #: refuse fusions whose fused scope would exceed this many
        #: tasklets after halo replication (content-deduplicated count)
        self.max_fused_tasklets = max_fused_tasklets
        #: refuse halo replication that keeps the producer alive (other
        #: readers remain) when the producer has more tasklets than this
        self.max_replicated_producer = max_replicated_producer
        #: typed reason for the most recent can_apply refusal
        self._reason: Optional[str] = None

    def find_matches(self, sdfg: SDFG, **kwargs):
        for st in sdfg.states:
            for node in st.data_nodes():
                desc = sdfg.arrays.get(node.data)
                if desc is None or not _fusible_desc(desc):
                    continue
                if st.in_degree(node) != 1:
                    continue
                if not isinstance(st.in_edges(node)[0].src, MapExit):
                    continue
                seen = set()
                for e in st.out_edges(node):
                    if isinstance(e.dst, MapEntry) and id(e.dst) not in seen:
                        seen.add(id(e.dst))
                        yield {"state": st, "node": node, "consumer": e.dst}

    # ------------------------------------------------------------------
    def _write_edge(self, st: State, px: MapExit, t: str):
        w_edges = [e for e in st.in_edges(px) if e.memlet.data == t]
        return w_edges[0] if len(w_edges) == 1 else None

    def _static_ranges(self, m, env) -> Optional[Dict[str, Tuple[int, int]]]:
        """param -> (start, size) for unit-step static ranges, else None."""
        out: Dict[str, Tuple[int, int]] = {}
        for p, r in zip(m.params, m.ranges):
            try:
                start = r.start.subs(env).as_int()
                stop = r.stop.subs(env).as_int()
                step = r.step.subs(env).as_int()
            except (ValueError, KeyError, TypeError):
                return None
            if step != 1 or stop - start < 1:
                return None
            out[p] = (start, stop - start)
        return out

    def _member_legal(self, sdfg: SDFG, st: State, member: AccessNode,
                      px: MapExit, ce: MapEntry, plan: Dict) -> bool:
        t = member.data
        desc = sdfg.arrays.get(t)
        if desc is None or not _fusible_desc(desc):
            return False
        if t in sdfg.metadata.get("pin_hbm", ()):
            return False
        # the one access node in the whole SDFG (no cross-PE aliasing)
        count = sum(1 for s in sdfg.states for n in s.data_nodes()
                    if n.data == t)
        if count != 1 or st.in_degree(member) != 1:
            return False
        in_e = st.in_edges(member)[0]
        if in_e.src is not px or in_e.memlet.wcr is not None:
            return False
        w = self._write_edge(st, px, t)
        if w is None or w.memlet.wcr is not None or w.memlet.dynamic:
            return False
        scalar = _scalar_like(desc)
        if w.memlet.subset is None and not scalar:
            return False
        wsub = w.memlet.subset.subs(plan["prod_repl"]) \
            if w.memlet.subset is not None else None
        # writes must be disjoint across iterations — otherwise the fused
        # consumer reads its iteration's private value where the
        # sequential schedule delivered the LAST write
        if not _injective_write(wsub, plan["sizes"]):
            return False
        # every consumer read must be the element the producer just wrote
        r_edges = [e for e in st.out_edges(ce) if e.memlet.data == t]
        if not r_edges:
            return False
        for e in r_edges:
            if e.memlet.wcr is not None or e.memlet.dynamic:
                return False
            rsub = e.memlet.subset
            if rsub is None and wsub is None:
                continue              # whole-scalar write, whole-scalar read
            if rsub is None or wsub is None:
                return False
            if rsub.subs(plan["ren"]) != wsub:
                return False
        return True

    # ------------------------------------------------------------------
    def can_apply(self, sdfg: SDFG, match: Dict) -> bool:
        self._reason = None
        st: State = match["state"]
        node: AccessNode = match["node"]
        if node not in st.graph:
            return False
        desc = sdfg.arrays.get(node.data)
        if desc is None or not _fusible_desc(desc):
            return False
        if st.in_degree(node) != 1:
            return False
        in_e = st.in_edges(node)[0]
        if not isinstance(in_e.src, MapExit):
            return False
        px: MapExit = in_e.src
        ce = match.get("consumer")
        if ce is None:
            ce = _consumer_entry(st, node)
            match["consumer"] = ce
        if (ce is None or ce not in st.graph or not isinstance(ce, MapEntry)
                or ce is px.entry):
            return False
        if not any(e.dst is ce for e in st.out_edges(node)):
            return False
        prod, cons = px.map, ce.map
        if prod.schedule not in _FUSIBLE or cons.schedule not in _FUSIBLE:
            return False
        scopes = st.scope_children()
        if _scope_tasklets(st, scopes, px.entry) is None:
            return False
        if _scope_tasklets(st, scopes, ce) is None:
            return False
        cx = next((n for n in st.nodes
                   if isinstance(n, MapExit) and n.entry is ce), None)
        if cx is None:
            return False

        # exact first — it is free (no replication) and preserves the
        # historical behavior when the consumer is the sole reader
        if _consumer_entry(st, node) is ce:
            if self._can_apply_exact(sdfg, st, node, px, ce, cx):
                match["mode"] = "exact"
                return True
        w = self._write_edge(st, px, node.data)
        if w is not None and w.memlet.wcr is not None:
            if self._can_apply_wcr(sdfg, st, node, px, ce, cx, match):
                match["mode"] = "wcr"
                return True
            return False
        if self._can_apply_halo(sdfg, st, node, px, ce, cx, match):
            match["mode"] = "halo"
            return True
        return False

    # -- exact mode ----------------------------------------------------
    def _can_apply_exact(self, sdfg: SDFG, st: State, node: AccessNode,
                         px: MapExit, ce: MapEntry, cx: MapExit) -> bool:
        prod, cons = px.map, ce.map
        plan = range_equivalence(prod, cons, sdfg.symbol_values)
        if plan is None:
            return False
        members = _group(st, px, ce)
        if members is None or node not in members:
            return False
        for member in members:
            if not self._member_legal(sdfg, st, member, px, ce, plan):
                return False
        tset = {m.data for m in members}
        # renaming must not capture a consumer-scope symbol that already
        # means something else (a free symbol equal to a fused-map param)
        cons_free = set()
        for e in st.out_edges(ce) + st.in_edges(cx):
            cons_free |= _edge_symbols(e.memlet)
        cons_free -= set(cons.params)
        if cons_free & set(plan["params"]):
            return False
        if not self._hazards_ok(st, px, ce, cx, tset):
            return False
        # no consumer input may depend on the producer through a path
        # OTHER than the fused intermediates (a third scope in between):
        # rerouting those inputs to the fused entry would create a cycle
        member_set = set(members)
        for e in st.in_edges(ce):
            if e.src in member_set:
                continue
            if nx.has_path(st.graph, px, e.src):
                return False
        return True

    def _hazards_ok(self, st: State, px: MapExit, ce: MapEntry, cx: MapExit,
                    tset: set) -> bool:
        """Fusing must not reorder accesses to other shared containers."""
        prod_writes = {e.memlet.data for e in st.in_edges(px)
                       if e.memlet.data} - tset
        prod_reads = {e.memlet.data for e in st.out_edges(px.entry)
                      if e.memlet.data}
        cons_reads = {e.memlet.data for e in st.out_edges(ce)
                      if e.memlet.data} - tset
        cons_writes = {e.memlet.data for e in st.in_edges(cx)
                       if e.memlet.data}
        if prod_writes & (cons_reads | cons_writes):
            self._reason = ("fusion would reorder accesses to a container "
                            "both scopes touch")
            return False
        if cons_writes & prod_reads:
            self._reason = ("fusion would reorder accesses to a container "
                            "both scopes touch")
            return False
        return True

    # -- halo mode -----------------------------------------------------
    def _can_apply_halo(self, sdfg: SDFG, st: State, node: AccessNode,
                        px: MapExit, ce: MapEntry, cx: MapExit,
                        match: Dict) -> bool:
        prod, cons = px.map, ce.map
        pe = px.entry
        if prod.annotations.get("tiling") or cons.annotations.get("tiling"):
            self._reason = ("halo fusion requires untiled scopes "
                            "(runs before MapTiling)")
            return False
        env = sdfg.symbol_values
        prod_rngs = self._static_ranges(prod, env)
        cons_rngs = self._static_ranges(cons, env)
        if prod_rngs is None or cons_rngs is None:
            self._reason = ("halo fusion requires static unit-step "
                            "iteration ranges")
            return False
        scopes = st.scope_children()
        prod_tasklets = _scope_tasklets(st, scopes, pe)
        cons_inner = _scope_tasklets(st, scopes, ce)

        # halo group: every access node the producer feeds into this
        # consumer; anything else the producer writes keeps it alive
        members: List[AccessNode] = []
        keep = False
        for e in st.out_edges(px):
            dst = e.dst
            if not isinstance(dst, AccessNode):
                keep = True
                continue
            outs = st.out_edges(dst)
            if not any(o.dst is ce for o in outs):
                keep = True
                continue
            if dst not in members:
                members.append(dst)
            if any(o.dst is not ce for o in outs):
                keep = True
        if node not in members:
            return False
        tset = {m.data for m in members}

        writer_of: Dict[str, Tuple[Tasklet, str]] = {}
        w_subsets: Dict[str, Optional[Subset]] = {}
        for member in members:
            t = member.data
            desc = sdfg.arrays.get(t)
            if desc is None or not _fusible_desc(desc):
                self._reason = f"intermediate {t} is not a fusible transient"
                return False
            if t in sdfg.metadata.get("pin_hbm", ()):
                self._reason = f"intermediate {t} is pinned to HBM"
                return False
            count = sum(1 for s in sdfg.states for n in s.data_nodes()
                        if n.data == t)
            if count != 1 or st.in_degree(member) != 1:
                self._reason = (f"intermediate {t} is accessed at more than "
                                f"one node")
                return False
            in_e = st.in_edges(member)[0]
            if in_e.src is not px:
                return False
            w = self._write_edge(st, px, t)
            if w is None or w.memlet.dynamic:
                self._reason = f"intermediate {t} has no unique static write"
                return False
            if w.memlet.wcr is not None or in_e.memlet.wcr is not None:
                self._reason = "intermediate group mixes wcr and plain writes"
                return False
            writer_of[t] = (w.src, w.src_conn)
            w_subsets[t] = w.memlet.subset

        # producer structure: side-effect-free tasklets with plain edges,
        # every external input traceable to an outer source
        prod_set = set(prod_tasklets)
        for T in prod_tasklets:
            if not getattr(T, "side_effect_free", True):
                self._reason = ("producer tasklet is not side-effect free "
                                "(cannot replicate)")
                return False
            if st.in_degree(T) == 0:
                self._reason = ("producer tasklet without inputs cannot be "
                                "replicated into the consumer scope")
                return False
        prod_src = {e.memlet.data: e.src for e in st.in_edges(pe)
                    if e.memlet.data is not None}
        cparams = set(cons.params)
        pparams = set(prod.params)
        for e in st.edges:
            inside = ((e.src is pe or e.src in prod_set)
                      and (e.dst in prod_set or e.dst is px))
            if not inside:
                continue
            if e.memlet.wcr is not None or e.memlet.dynamic:
                self._reason = "producer carries wcr or dynamic edges"
                return False
            if (_edge_symbols(e.memlet) - pparams) & cparams:
                self._reason = ("producer memlet captures a consumer "
                                "parameter name")
                return False
            if e.src is pe:
                d = e.memlet.data
                if d is None or d not in prod_src or d in tset:
                    self._reason = "producer input without an outer source"
                    return False

        # per-read sigma: the write-order = read-order rule
        read_edges: List[Tuple] = []
        for e in st.out_edges(ce):
            t = e.memlet.data
            if t not in tset:
                continue
            if e.memlet.wcr is not None or e.memlet.dynamic:
                self._reason = "dynamic or wcr read of the intermediate"
                return False
            sigma, reason = solve_write_read_sigma(
                w_subsets[t], e.memlet.subset, prod.params, prod_rngs,
                cons.params)
            if sigma is None:
                self._reason = reason
                return False
            if not sigma_covered(sigma, prod_rngs, cons_rngs):
                self._reason = ("shifted reads fall outside the producer's "
                                "iteration box")
                return False
            read_edges.append((e, sigma))
        if not read_edges:
            return False

        if not self._hazards_ok(st, px, ce, cx, tset):
            return False
        member_set = set(members)
        for e in st.in_edges(ce):
            if e.src in member_set:
                continue
            if nx.has_path(st.graph, px, e.src):
                self._reason = ("consumer depends on the producer through "
                                "another path")
                return False
        for d, s in prod_src.items():
            if s is ce or nx.has_path(st.graph, cx, s):
                self._reason = ("routing a producer input into the consumer "
                                "would create a cycle")
                return False

        n_rep = self._count_replicas(st, pe, writer_of, read_edges)
        if n_rep + len(cons_inner) > self.max_fused_tasklets:
            self._reason = (f"fused scope would exceed "
                            f"{self.max_fused_tasklets} tasklets after "
                            f"producer replication")
            return False
        if keep and len(prod_tasklets) > self.max_replicated_producer:
            self._reason = ("multi-consumer replication of the producer "
                            "exceeds the replication cost threshold")
            return False
        match["halo"] = {
            "members": members, "keep": keep, "read_edges": read_edges,
            "writer_of": writer_of, "pe": pe, "px": px, "cx": cx,
            "prod_src": prod_src, "prod_tasklets": prod_tasklets,
        }
        return True

    def _replica_key_fn(self, st: State, pe: MapEntry):
        """Content-addressed replica identity: a producer tasklet under a
        substitution sigma is the same replica as another exactly when the
        computation (fn), output connectors, and the full substituted
        input structure coincide — so shifted copies of shifted copies
        deduplicate across fusion rounds."""
        memo: Dict[Tuple, Tuple] = {}

        def key_of(T, skey, sigma):
            mk = (id(T), skey)
            if mk in memo:
                return memo[mk]
            sigs = []
            for e in st.in_edges(T):
                sub = (e.memlet.subset.subs(sigma)
                       if e.memlet.subset is not None else None)
                if e.src is pe:
                    sigs.append(("ext", e.dst_conn, e.memlet.data,
                                 _subset_key(sub)))
                else:
                    sigs.append(("int", e.dst_conn, e.src_conn,
                                 key_of(e.src, skey, sigma)))
            k = (id(T.fn), tuple(sorted(T.outputs)),
                 tuple(sorted(sigs, key=repr)))
            memo[mk] = k
            return k

        return key_of

    def _count_replicas(self, st: State, pe: MapEntry, writer_of: Dict,
                        read_edges: List[Tuple]) -> int:
        key_of = self._replica_key_fn(st, pe)
        all_keys = set()

        def collect(T, skey, sigma):
            k = key_of(T, skey, sigma)
            if k in all_keys:
                return
            all_keys.add(k)
            for e in st.in_edges(T):
                if e.src is not pe:
                    collect(e.src, skey, sigma)

        for e, sigma in read_edges:
            T_w, _ = writer_of[e.memlet.data]
            collect(T_w, _sigma_key(sigma), sigma)
        return len(all_keys)

    def _apply_halo(self, sdfg: SDFG, match: Dict):
        st: State = match["state"]
        ce: MapEntry = match["consumer"]
        h = match["halo"]
        members, keep = h["members"], h["keep"]
        read_edges, writer_of = h["read_edges"], h["writer_of"]
        pe, px = h["pe"], h["px"]
        prod_src, prod_tasklets = h["prod_src"], h["prod_tasklets"]
        prod, cons = px.map, ce.map
        tset = {m.data for m in members}

        routed = {e.memlet.data for e in st.in_edges(ce)
                  if e.memlet.data is not None and e.memlet.data not in tset}
        key_of = self._replica_key_fn(st, pe)
        created: Dict[Tuple, Tasklet] = {}
        serial = [0]

        def materialize(T, skey, sigma) -> Tasklet:
            k = key_of(T, skey, sigma)
            if k in created:
                return created[k]
            R = st.add_tasklet(f"{T.label}.{serial[0]}", list(T.inputs),
                               list(T.outputs), T.fn)
            serial[0] += 1
            created[k] = R
            for e in st.in_edges(T):
                sub = (e.memlet.subset.subs(sigma)
                       if e.memlet.subset is not None else None)
                vol = (e.memlet.volume.subs(sigma)
                       if isinstance(e.memlet.volume, Expr)
                       else e.memlet.volume)
                m = Memlet(data=e.memlet.data, subset=sub, volume=vol)
                if e.src is pe:
                    d = e.memlet.data
                    if d not in routed:
                        st.add_edge(prod_src[d], None, ce, f"IN_{d}",
                                    Memlet.simple(d))
                        routed.add(d)
                    st.add_edge(ce, f"OUT_{d}", R, e.dst_conn, m)
                else:
                    U = materialize(e.src, skey, sigma)
                    st.add_edge(U, e.src_conn, R, e.dst_conn, m)
            return R

        # shifted reads become edges from the matching replica, keeping
        # the consumer-space subset (the element this iteration consumes)
        for e, sigma in read_edges:
            T_w, conn_w = writer_of[e.memlet.data]
            R = materialize(T_w, _sigma_key(sigma), sigma)
            st.add_edge(R, conn_w, e.dst, e.dst_conn,
                        Memlet(data=e.memlet.data, subset=e.memlet.subset,
                               volume=e.memlet.volume))
            st.remove_edge(e)
        for member in members:
            for oe in [o for o in st.out_edges(member) if o.dst is ce]:
                st.remove_edge(oe)

        if not keep:
            for n in [pe, px] + list(prod_tasklets) + members:
                if n in st.graph:
                    st.remove_node(n)
            for d, s in prod_src.items():
                if (s in st.graph and st.graph.degree(s) == 0
                        and isinstance(s, AccessNode)):
                    desc = sdfg.arrays.get(s.data)
                    if desc is not None and getattr(desc, "transient", False):
                        st.remove_node(s)
            # the intermediates now live on per-iteration edges only:
            # pure on-chip storage, out of the off-chip volume metric
            for t in tset:
                sdfg.arrays[t].storage = StorageType.REG

        cons.label = f"{_base_label(prod.label)}+{_base_label(cons.label)}"

    # -- wcr mode ------------------------------------------------------
    def _can_apply_wcr(self, sdfg: SDFG, st: State, node: AccessNode,
                       px: MapExit, ce: MapEntry, cx: MapExit,
                       match: Dict) -> bool:
        prod, cons = px.map, ce.map
        pe = px.entry
        t = node.data
        w = self._write_edge(st, px, t)
        if w is None or w.memlet.dynamic:
            self._reason = f"intermediate {t} has no unique static write"
            return False
        mode = w.memlet.wcr
        if mode != "add":
            self._reason = (f"wcr mode {mode!r} unsupported for fused "
                            f"reductions (identity differs from zero init)")
            return False
        if prod.annotations.get("tiling") or cons.annotations.get("tiling"):
            self._reason = ("wcr fusion requires untiled scopes "
                            "(runs before MapTiling)")
            return False
        env = sdfg.symbol_values
        prod_rngs = self._static_ranges(prod, env)
        cons_rngs = self._static_ranges(cons, env)
        if prod_rngs is None or cons_rngs is None:
            self._reason = ("wcr fusion requires static unit-step "
                            "iteration ranges")
            return False
        if _consumer_entry(st, node) is not ce:
            self._reason = (f"reduction intermediate {t} has multiple "
                            f"consumers")
            return False
        count = sum(1 for s in sdfg.states for n in s.data_nodes()
                    if n.data == t)
        if count != 1 or st.in_degree(node) != 1:
            self._reason = (f"intermediate {t} is accessed at more than "
                            f"one node")
            return False
        if t in sdfg.metadata.get("pin_hbm", ()):
            self._reason = f"intermediate {t} is pinned to HBM"
            return False
        # the reduction must be the producer's only product
        for e in st.out_edges(px):
            if not (isinstance(e.dst, AccessNode) and e.dst is node):
                self._reason = ("wcr producer has outputs besides the "
                                "reduction")
                return False
        for e in st.in_edges(px):
            if e.memlet.data != t:
                self._reason = ("wcr producer has outputs besides the "
                                "reduction")
                return False

        # write subset: out params (indexing the reduction) vs reduction
        # params (summed away)
        wsub = w.memlet.subset
        if wsub is None:
            self._reason = "whole-container wcr write"
            return False
        out_of: Dict[int, Tuple[str, int]] = {}
        used = set()
        for d, r in enumerate(wsub):
            if not r.is_index():
                self._reason = "wcr write is not element-indexed"
                return False
            dec = affine_decompose(r.start, prod.params)
            if dec is None:
                self._reason = f"non-affine wcr write index in dim {d}"
                return False
            c0, coeffs = dec
            live = {p: c for p, c in coeffs.items() if c != 0}
            if len(live) != 1 or next(iter(live.values())) != 1:
                self._reason = ("wcr write index is not a unit-coefficient "
                                "single-parameter shift")
                return False
            (p,) = live
            if p in used:
                self._reason = (f"producer parameter {p} indexes two "
                                f"dimensions")
                return False
            used.add(p)
            out_of[d] = (p, c0)
        red_params = [p for p in prod.params
                      if p not in used and prod_rngs[p][1] > 1]
        if not red_params:
            self._reason = ("wcr write with no reduction parameters "
                            "(producer revisits no elements)")
            return False

        # consumer reads: element-exact bijection onto the out params
        ren: Dict[str, str] = {}
        r_edges = [e for e in st.out_edges(ce) if e.memlet.data == t]
        if not r_edges:
            return False
        for e in r_edges:
            if e.memlet.wcr is not None or e.memlet.dynamic:
                self._reason = "dynamic or wcr read of the reduction"
                return False
            rsub = e.memlet.subset
            if rsub is None or len(rsub) != len(wsub):
                self._reason = "reduction read/write rank mismatch"
                return False
            for d, r in enumerate(rsub):
                if not r.is_index():
                    self._reason = ("consumer reads a windowed slice of the "
                                    "reduction")
                    return False
                dec = affine_decompose(r.start, cons.params)
                if dec is None:
                    self._reason = (f"reduction read index in dim {d} is not "
                                    f"affine over the consumer parameters")
                    return False
                c0, coeffs = dec
                live = {q: c for q, c in coeffs.items() if c != 0}
                p, wc = out_of[d]
                if len(live) != 1 or next(iter(live.values())) != 1:
                    self._reason = ("consumer read of the reduction is not "
                                    "element-exact")
                    return False
                (q,) = live
                if c0 != wc:
                    self._reason = ("consumer reads the reduction at a "
                                    "shifted offset")
                    return False
                if ren.get(q, p) != p or any(
                        pp == p for qq, pp in ren.items() if qq != q):
                    self._reason = ("inconsistent parameter pairing on the "
                                    "reduction read")
                    return False
                ren[q] = p
                if cons_rngs[q] != prod_rngs[p]:
                    self._reason = ("consumer range differs from the "
                                    "reduction's output range")
                    return False
        for q in cons.params:
            if q not in ren and cons_rngs[q][1] != 1:
                self._reason = (f"consumer parameter {q} is not bound by the "
                                f"reduction read")
                return False

        # consumer must be idempotent under re-execution: the fused scope
        # runs it once per reduction step, only the final write survives
        scopes = st.scope_children()
        prod_tasklets = _scope_tasklets(st, scopes, pe)
        cons_inner = _scope_tasklets(st, scopes, ce)
        for T in cons_inner:
            if not getattr(T, "side_effect_free", True):
                self._reason = ("consumer tasklet is not side-effect free "
                                "(re-executed per reduction step)")
                return False
        cons_reads = {e.memlet.data for e in st.out_edges(ce)
                      if e.memlet.data} - {t}
        cons_writes = {e.memlet.data for e in st.in_edges(cx)
                       if e.memlet.data}
        if cons_reads & cons_writes:
            self._reason = ("consumer reads a container it writes (not "
                            "idempotent under re-execution)")
            return False
        for e in st.in_edges(cx):
            if e.memlet.wcr is not None:
                self._reason = ("wcr consumer write behind a fused "
                                "reduction")
                return False
        for e in st.edges:
            if e.src in set(prod_tasklets) and e.dst in set(prod_tasklets):
                if e.memlet.wcr is not None:
                    self._reason = "nested wcr inside the wcr producer"
                    return False
        if len(prod_tasklets) + len(cons_inner) > self.max_fused_tasklets:
            self._reason = (f"fused scope would exceed "
                            f"{self.max_fused_tasklets} tasklets")
            return False

        # renaming must not capture symbols; shared containers must not
        # be reordered; no third scope between the pair
        cons_free = set()
        for e in st.out_edges(ce) + st.in_edges(cx):
            cons_free |= _edge_symbols(e.memlet)
        cons_free -= set(cons.params)
        if cons_free & set(prod.params):
            self._reason = ("consumer memlet captures a producer parameter "
                            "name")
            return False
        if not self._hazards_ok(st, px, ce, cx, {t}):
            return False
        for e in st.in_edges(ce):
            if e.src is node:
                continue
            if nx.has_path(st.graph, px, e.src):
                self._reason = ("consumer depends on the producer through "
                                "another path")
                return False

        ren_expr = {q: Expr.sym(p) for q, p in ren.items()}
        for q in cons.params:
            if q not in ren_expr:
                ren_expr[q] = Expr.const(cons_rngs[q][0])
        match["wcr"] = {"ren": ren_expr, "wsub": wsub}
        return True

    def _apply_wcr_fusion(self, sdfg: SDFG, match: Dict):
        st: State = match["state"]
        node: AccessNode = match["node"]
        ce: MapEntry = match["consumer"]
        in_e = st.in_edges(node)[0]
        px: MapExit = in_e.src
        pe = px.entry
        prod, cons = px.map, ce.map
        cx = next(n for n in st.nodes
                  if isinstance(n, MapExit) and n.entry is ce)
        ren = match["wcr"]["ren"]
        wsub = match["wcr"]["wsub"]
        t = node.data
        w = self._write_edge(st, px, t)
        writer, writer_conn = w.src, w.src_conn

        def rn(memlet: Memlet) -> Memlet:
            if ren and memlet.subset is not None:
                return Memlet(data=memlet.data,
                              subset=memlet.subset.subs(ren),
                              volume=memlet.volume, wcr=memlet.wcr,
                              dynamic=memlet.dynamic)
            return memlet

        scopes = st.scope_children()
        cons_inner = set(_scope_tasklets(st, scopes, ce))
        outer_src = {e.memlet.data: e.src for e in st.in_edges(ce)
                     if e.memlet.data is not None and e.memlet.data != t}
        pe_in = {(e.src, e.dst_conn) for e in st.in_edges(pe)}

        # consumer reads of the reduction ride an accumulating edge from
        # the producer's writer; other reads route through the fused entry
        for e in list(st.out_edges(ce)):
            if e.memlet.data == t:
                st.add_edge(writer, writer_conn, e.dst, e.dst_conn,
                            Memlet(data=t, subset=wsub, wcr="add"))
                continue
            st.add_edge(pe, e.src_conn, e.dst, e.dst_conn, rn(e.memlet))
            d = e.memlet.data
            if d is not None and d in outer_src:
                key = (outer_src[d], f"IN_{d}")
                if key not in pe_in:
                    st.add_edge(outer_src[d], None, pe, f"IN_{d}",
                                Memlet.simple(d))
                    pe_in.add(key)
        for e in st.edges:
            if e.src in cons_inner and e.dst in cons_inner:
                e.memlet = rn(e.memlet)
        for e in list(st.in_edges(cx)):
            st.add_edge(e.src, e.src_conn, px, e.dst_conn, rn(e.memlet))
        for e in list(st.out_edges(cx)):
            st.add_edge(px, e.src_conn, e.dst, e.dst_conn, e.memlet)

        st.remove_edge(w)
        st.remove_node(node)
        st.remove_node(ce)
        st.remove_node(cx)
        prod.label = f"{_base_label(prod.label)}+{_base_label(cons.label)}"
        sdfg.arrays[t].storage = StorageType.REG

    # ------------------------------------------------------------------
    def apply_match(self, sdfg: SDFG, match: Dict):
        mode = match.get("mode", "exact")
        if mode == "halo":
            return self._apply_halo(sdfg, match)
        if mode == "wcr":
            return self._apply_wcr_fusion(sdfg, match)
        return self._apply_exact(sdfg, match)

    def _apply_exact(self, sdfg: SDFG, match: Dict):
        st: State = match["state"]
        node: AccessNode = match["node"]
        in_e = st.in_edges(node)[0]
        px: MapExit = in_e.src
        pe: MapEntry = px.entry
        prod = px.map
        ce = _consumer_entry(st, node)
        cons = ce.map
        cx = next(n for n in st.nodes
                  if isinstance(n, MapExit) and n.entry is ce)
        plan = range_equivalence(prod, cons, sdfg.symbol_values)
        ren = plan["ren"]
        members = _group(st, px, ce)
        tset = {m.data for m in members}

        # adopt the consumer's tile structure on retiled producer dims
        if plan["prod_repl"]:
            prod.params = list(plan["params"])
            prod.ranges = list(plan["ranges"])
            if plan["tiling"]:
                prod.annotations.setdefault("tiling", {}).update(
                    {q: info for q, info in plan["tiling"].items()
                     if q in prod.params})
            scopes0 = st.scope_children()
            nodes = {pe, px} | set(scopes0.get(pe, []))
            for e in st.edges:
                if e.src in nodes or e.dst in nodes:
                    if e.memlet.subset is not None:
                        e.memlet.subset = e.memlet.subset.subs(
                            plan["prod_repl"])

        def rn(memlet: Memlet) -> Memlet:
            if ren and memlet.subset is not None:
                return Memlet(data=memlet.data,
                              subset=memlet.subset.subs(ren),
                              volume=memlet.volume, wcr=memlet.wcr,
                              dynamic=memlet.dynamic)
            return memlet

        scopes = st.scope_children()
        cons_inner = set(_scope_tasklets(st, scopes, ce))

        # the producer tasklet and output connector behind each member
        writer_of: Dict[str, Tuple] = {}
        w_edges = []
        for member in members:
            w = self._write_edge(st, px, member.data)
            writer_of[member.data] = (w.src, w.src_conn)
            w_edges.append(w)

        # outer sources feeding the consumer entry, and existing producer
        # entry inputs (dedupe key: (source node, entry connector))
        outer_src = {e.memlet.data: e.src for e in st.in_edges(ce)
                     if e.memlet.data is not None
                     and e.memlet.data not in tset}
        pe_in = {(e.src, e.dst_conn) for e in st.in_edges(pe)}

        # consumer-scope reads: through the fused entry, or — for the
        # intermediates — straight off their producer tasklets
        for e in list(st.out_edges(ce)):
            if e.memlet.data in tset:
                writer, writer_conn = writer_of[e.memlet.data]
                st.add_edge(writer, writer_conn, e.dst, e.dst_conn,
                            rn(e.memlet))
                continue
            st.add_edge(pe, e.src_conn, e.dst, e.dst_conn, rn(e.memlet))
            d = e.memlet.data
            if d is not None and d in outer_src:
                key = (outer_src[d], f"IN_{d}")
                if key not in pe_in:
                    st.add_edge(outer_src[d], None, pe, f"IN_{d}",
                                Memlet.simple(d))
                    pe_in.add(key)

        # consumer-internal tasklet->tasklet edges: rename in place
        for e in st.edges:
            if e.src in cons_inner and e.dst in cons_inner:
                e.memlet = rn(e.memlet)

        # consumer-scope writes: through the fused exit
        for e in list(st.in_edges(cx)):
            st.add_edge(e.src, e.src_conn, px, e.dst_conn, rn(e.memlet))
        for e in list(st.out_edges(cx)):
            st.add_edge(px, e.src_conn, e.dst, e.dst_conn, e.memlet)

        # drop the intermediate round-trips and the consumed scope shell
        for w in w_edges:
            st.remove_edge(w)
        for member in members:
            st.remove_node(member)
        st.remove_node(ce)
        st.remove_node(cx)

        prod.label = f"{_base_label(prod.label)}+{_base_label(cons.label)}"
        if prod.annotations.get("tiling"):
            prod.label += "_tiled"
        # the intermediates now live on per-iteration edges only: pure
        # on-chip storage, out of the off-chip volume metric
        for t in tset:
            sdfg.arrays[t].storage = StorageType.REG

    # ------------------------------------------------------------------
    def explain(self, sdfg: SDFG) -> List[Tuple[str, str]]:
        """Post-fixpoint: (consumer label, typed reason) for every
        remaining producer->consumer pair that refused to fuse."""
        out: List[Tuple[str, str]] = []
        seen = set()
        for m in self.find_matches(sdfg):
            if self.can_apply(sdfg, m):
                continue           # racing fixpoint leftovers; ignore
            if not self._reason:
                continue
            ce = m.get("consumer")
            label = ce.map.label if isinstance(ce, MapEntry) else "?"
            key = (label, self._reason)
            if key not in seen:
                seen.add(key)
                out.append(key)
        return out
