"""MapFusion: fuse producer->consumer map scopes over matching ranges.

The paper's streaming composition removes an off-chip round-trip by
turning the intermediate container into a FIFO between two processing
elements. MapFusion is the tighter, whole-dataflow variant (cf. FLOWER's
fusion of adjacent processing stages): when a map writes a transient that
a second map over the *same* iteration space reads back element-for-
element, the two scopes merge into one and the intermediate stops being a
container access altogether — it becomes a per-iteration value carried on
a direct tasklet->tasklet edge inside the fused scope. On TPU the fused
scope lowers to a single Pallas grid kernel whose intermediate lives in
registers/VMEM, where the unfused pair was two kernel launches with an
HBM array between them.

The transform handles general producer **DAGs**, not just linear chains:

  * a consumer scope fed by several independent producer exits fuses
    with all of them across fixpoint rounds (gemver's ger->ger->gemv
    chain, a dot over two generated operands);
  * ALL intermediates connecting one (producer exit, consumer entry)
    pair fuse in a single application — each becomes its own
    tasklet->tasklet edge (a producer computing sin+cos for one
    consumer). If any of them is ineligible the pair refuses, because
    fusing a subset would leave a container path into the fused scope
    (a cycle);
  * ``Scalar``-descriptor (and 0-d) intermediates fuse the same way
    ``Array`` transients do — their disjoint-writes condition simply has
    no index dimensions to discharge it, so they are legal exactly when
    no parameter revisits them (all range sizes 1);
  * iteration spaces match **up to MapTiling splits**
    (:func:`transforms.map_tiling.range_equivalence`): a tiled producer
    fuses with an untiled consumer over the same extent, two maps tiled
    with the same annotation fuse pair-for-pair, and an untiled producer
    adopting a tiled consumer's structure is retiled in place — so the
    MapFusion / MapTiling pipeline orders commute.

Legality (checked per match, mirrored by tests/test_map_fusion.py):

  * each intermediate is a transient ``Array``/``Scalar`` accessed at
    exactly one node in the whole SDFG, written once by the producer's
    exit and read only by the consumer's entry (no other readers or
    writers);
  * producer and consumer iteration spaces are equivalent under
    ``range_equivalence`` (positional renaming, tiling-aware);
  * every consumer read subset equals the producer write subset under
    that renaming — offset reads (stencil halos) refuse to fuse;
  * the producer's writes are disjoint across iterations: every
    parameter with more than one iteration must index the intermediate
    injectively. Mixed-radix dimensions (``t[c*K + l]`` with ``l < K``,
    the MapTiling form) count as injective; ``t[i+j]`` does not;
  * no write-conflict resolution on the intermediate's edges (a wcr
    write is not a per-iteration value);
  * both scopes contain only tasklets, and fusing must not reorder
    accesses to any *other* container shared between the two scopes.

After fusion each intermediate's descriptor is retargeted to registers
(``StorageType.REG``): it no longer appears at any access node, so it
contributes nothing to the off-chip volume metric. Fused labels join the
component labels with ``+`` (stripping the cosmetic ``_tiled`` suffix
from components, re-appending it when the fused map carries tiling
annotations), so fuse-then-tile and tile-then-fuse name the same kernel.
"""
from __future__ import annotations

from fractions import Fraction
from typing import Dict, List, Optional, Tuple

from ..core.dtypes import ScheduleType, StorageType
from ..core.memlet import Memlet, Subset
from ..core.sdfg import (AccessNode, Array, MapEntry, MapExit, Scalar, SDFG,
                         State, Stream, Tasklet)
from .base import Transformation
from .map_tiling import range_equivalence

#: schedules whose scopes may fuse (grid-eligible schedules; UNROLLED /
#: MESH scopes are replicated hardware and keep their own identity).
_FUSIBLE = (ScheduleType.PIPELINED, ScheduleType.DEVICE)


def _consumer_entry(state: State, node: AccessNode) -> Optional[MapEntry]:
    """The single MapEntry consuming ``node``, or None."""
    dsts = {e.dst for e in state.out_edges(node)}
    if len(dsts) != 1:
        return None
    (dst,) = dsts
    return dst if isinstance(dst, MapEntry) else None


def _scope_tasklets(state: State, scopes, entry: MapEntry):
    """Directly-contained nodes minus the exit; None if any is not a
    Tasklet (nested maps / access nodes keep their scopes separate)."""
    inner = [n for n in scopes.get(entry, []) if not isinstance(n, MapExit)]
    if not inner or not all(isinstance(n, Tasklet) for n in inner):
        return None
    return inner


def _fusible_desc(desc) -> bool:
    return (isinstance(desc, (Array, Scalar)) and not isinstance(desc, Stream)
            and desc.transient)


def _scalar_like(desc) -> bool:
    return not getattr(desc, "shape", ())


def _group(state: State, px: MapExit, ce: MapEntry) -> Optional[List[AccessNode]]:
    """Every access node carried from ``px`` into ``ce``. All of them
    must fuse together (a leftover container between the pair would put a
    cycle through the fused scope); an access node that also feeds a
    third consumer poisons the whole pair — returns None."""
    members = []
    for e in state.out_edges(px):
        dst = e.dst
        if not isinstance(dst, AccessNode):
            continue
        outs = state.out_edges(dst)
        to_ce = [o for o in outs if o.dst is ce]
        if not to_ce:
            continue
        if len(to_ce) != len(outs):
            return None
        members.append(dst)
    return members or None


def _injective_write(subset: Optional[Subset],
                     sizes: Dict[str, Optional[int]]) -> bool:
    """True when the write subset touches a distinct location on every
    iteration of the (final) parameter space. Parameters whose range has
    a single iteration cannot revisit anything and are exempt; a
    dimension combining several parameters is accepted exactly when its
    coefficients form a positional (mixed-radix) system — the MapTiling
    ``start + counter*tile + intra`` shape — and rejected otherwise
    (``t[i+j]`` collides across iterations)."""
    used = set()
    pset = set(sizes)
    if subset is None or len(subset) == 0:
        return all(sz == 1 for sz in sizes.values())
    for r in subset:
        rsyms = (r.start.free_symbols | r.stop.free_symbols
                 | r.step.free_symbols)
        if (rsyms & pset) and not r.is_index():
            return False
        terms = []
        for mono, c in r.start.terms.items():
            if mono == ():
                continue
            names = [nm for nm, _ in mono]
            if not any(nm in pset for nm in names):
                continue
            if len(mono) != 1 or mono[0][1] != 1:
                return False          # non-affine in a parameter
            name = mono[0][0]
            if isinstance(c, Fraction):
                if c.denominator != 1:
                    return False
                c = c.numerator
            coeff = abs(int(c))
            if coeff == 0:
                continue
            sz = sizes.get(name)
            if sz is None:
                return False          # dynamic extent: cannot prove
            if sz <= 1:
                continue              # single iteration: no collision
            if name in used:
                return False          # same param indexes two dimensions
            terms.append((coeff, sz, name))
        terms.sort()
        span = 0
        for coeff, sz, name in terms:
            if coeff <= span:
                return False          # offsets of smaller terms overlap
            span += coeff * (sz - 1)
        used |= {name for _, _, name in terms}
    covering = {p for p, sz in sizes.items() if sz is None or sz > 1}
    return covering <= used


class MapFusion(Transformation):
    """Transient array/scalar node(s) between a map exit and a map entry
    over equivalent iteration spaces -> merge the scopes; each
    intermediate becomes a direct per-iteration tasklet->tasklet edge."""

    def find_matches(self, sdfg: SDFG, **kwargs):
        for st in sdfg.states:
            for node in st.data_nodes():
                desc = sdfg.arrays.get(node.data)
                if desc is None or not _fusible_desc(desc):
                    continue
                if st.in_degree(node) != 1:
                    continue
                if not isinstance(st.in_edges(node)[0].src, MapExit):
                    continue
                if _consumer_entry(st, node) is None:
                    continue
                yield {"state": st, "node": node}

    # ------------------------------------------------------------------
    def _write_edge(self, st: State, px: MapExit, t: str):
        w_edges = [e for e in st.in_edges(px) if e.memlet.data == t]
        return w_edges[0] if len(w_edges) == 1 else None

    def _member_legal(self, sdfg: SDFG, st: State, member: AccessNode,
                      px: MapExit, ce: MapEntry, plan: Dict) -> bool:
        t = member.data
        desc = sdfg.arrays.get(t)
        if desc is None or not _fusible_desc(desc):
            return False
        if t in sdfg.metadata.get("pin_hbm", ()):
            return False
        # the one access node in the whole SDFG (no cross-PE aliasing)
        count = sum(1 for s in sdfg.states for n in s.data_nodes()
                    if n.data == t)
        if count != 1 or st.in_degree(member) != 1:
            return False
        in_e = st.in_edges(member)[0]
        if in_e.src is not px or in_e.memlet.wcr is not None:
            return False
        w = self._write_edge(st, px, t)
        if w is None or w.memlet.wcr is not None or w.memlet.dynamic:
            return False
        scalar = _scalar_like(desc)
        if w.memlet.subset is None and not scalar:
            return False
        wsub = w.memlet.subset.subs(plan["prod_repl"]) \
            if w.memlet.subset is not None else None
        # writes must be disjoint across iterations — otherwise the fused
        # consumer reads its iteration's private value where the
        # sequential schedule delivered the LAST write
        if not _injective_write(wsub, plan["sizes"]):
            return False
        # every consumer read must be the element the producer just wrote
        r_edges = [e for e in st.out_edges(ce) if e.memlet.data == t]
        if not r_edges:
            return False
        for e in r_edges:
            if e.memlet.wcr is not None or e.memlet.dynamic:
                return False
            rsub = e.memlet.subset
            if rsub is None and wsub is None:
                continue              # whole-scalar write, whole-scalar read
            if rsub is None or wsub is None:
                return False
            if rsub.subs(plan["ren"]) != wsub:
                return False
        return True

    # ------------------------------------------------------------------
    def can_apply(self, sdfg: SDFG, match: Dict) -> bool:
        st: State = match["state"]
        node: AccessNode = match["node"]
        if node not in st.graph:
            return False
        desc = sdfg.arrays.get(node.data)
        if desc is None or not _fusible_desc(desc):
            return False
        if st.in_degree(node) != 1:
            return False
        in_e = st.in_edges(node)[0]
        if not isinstance(in_e.src, MapExit):
            return False
        px: MapExit = in_e.src
        ce = _consumer_entry(st, node)
        if ce is None or ce is px.entry:
            return False
        prod, cons = px.map, ce.map
        if prod.schedule not in _FUSIBLE or cons.schedule not in _FUSIBLE:
            return False
        plan = range_equivalence(prod, cons, sdfg.symbol_values)
        if plan is None:
            return False
        scopes = st.scope_children()
        if _scope_tasklets(st, scopes, px.entry) is None:
            return False
        if _scope_tasklets(st, scopes, ce) is None:
            return False
        cx = next((n for n in st.nodes
                   if isinstance(n, MapExit) and n.entry is ce), None)
        if cx is None:
            return False
        members = _group(st, px, ce)
        if members is None or node not in members:
            return False
        for member in members:
            if not self._member_legal(sdfg, st, member, px, ce, plan):
                return False
        tset = {m.data for m in members}
        # renaming must not capture a consumer-scope symbol that already
        # means something else (a free symbol equal to a fused-map param)
        cons_free = set()
        for e in st.out_edges(ce) + st.in_edges(cx):
            if e.memlet.subset is not None:
                for r in e.memlet.subset:
                    cons_free |= (r.start.free_symbols | r.stop.free_symbols
                                  | r.step.free_symbols)
        cons_free -= set(cons.params)
        if cons_free & set(plan["params"]):
            return False
        # fusing must not reorder accesses to other shared containers
        prod_writes = {e.memlet.data for e in st.in_edges(px)
                       if e.memlet.data} - tset
        prod_reads = {e.memlet.data for e in st.out_edges(px.entry)
                      if e.memlet.data}
        cons_reads = {e.memlet.data for e in st.out_edges(ce)
                      if e.memlet.data} - tset
        cons_writes = {e.memlet.data for e in st.in_edges(cx)
                       if e.memlet.data}
        if prod_writes & (cons_reads | cons_writes):
            return False
        if cons_writes & prod_reads:
            return False
        # no consumer input may depend on the producer through a path
        # OTHER than the fused intermediates (a third scope in between):
        # rerouting those inputs to the fused entry would create a cycle
        import networkx as nx
        member_set = set(members)
        for e in st.in_edges(ce):
            if e.src in member_set:
                continue
            if nx.has_path(st.graph, px, e.src):
                return False
        return True

    # ------------------------------------------------------------------
    def apply_match(self, sdfg: SDFG, match: Dict):
        st: State = match["state"]
        node: AccessNode = match["node"]
        in_e = st.in_edges(node)[0]
        px: MapExit = in_e.src
        pe: MapEntry = px.entry
        prod = px.map
        ce = _consumer_entry(st, node)
        cons = ce.map
        cx = next(n for n in st.nodes
                  if isinstance(n, MapExit) and n.entry is ce)
        plan = range_equivalence(prod, cons, sdfg.symbol_values)
        ren = plan["ren"]
        members = _group(st, px, ce)
        tset = {m.data for m in members}

        # adopt the consumer's tile structure on retiled producer dims
        if plan["prod_repl"]:
            prod.params = list(plan["params"])
            prod.ranges = list(plan["ranges"])
            if plan["tiling"]:
                prod.annotations.setdefault("tiling", {}).update(
                    {q: info for q, info in plan["tiling"].items()
                     if q in prod.params})
            scopes0 = st.scope_children()
            nodes = {pe, px} | set(scopes0.get(pe, []))
            for e in st.edges:
                if e.src in nodes or e.dst in nodes:
                    if e.memlet.subset is not None:
                        e.memlet.subset = e.memlet.subset.subs(
                            plan["prod_repl"])

        def rn(memlet: Memlet) -> Memlet:
            if ren and memlet.subset is not None:
                return Memlet(data=memlet.data,
                              subset=memlet.subset.subs(ren),
                              volume=memlet.volume, wcr=memlet.wcr,
                              dynamic=memlet.dynamic)
            return memlet

        scopes = st.scope_children()
        cons_inner = set(_scope_tasklets(st, scopes, ce))

        # the producer tasklet and output connector behind each member
        writer_of: Dict[str, Tuple] = {}
        w_edges = []
        for member in members:
            w = self._write_edge(st, px, member.data)
            writer_of[member.data] = (w.src, w.src_conn)
            w_edges.append(w)

        # outer sources feeding the consumer entry, and existing producer
        # entry inputs (dedupe key: (source node, entry connector))
        outer_src = {e.memlet.data: e.src for e in st.in_edges(ce)
                     if e.memlet.data is not None
                     and e.memlet.data not in tset}
        pe_in = {(e.src, e.dst_conn) for e in st.in_edges(pe)}

        # consumer-scope reads: through the fused entry, or — for the
        # intermediates — straight off their producer tasklets
        for e in list(st.out_edges(ce)):
            if e.memlet.data in tset:
                writer, writer_conn = writer_of[e.memlet.data]
                st.add_edge(writer, writer_conn, e.dst, e.dst_conn,
                            rn(e.memlet))
                continue
            st.add_edge(pe, e.src_conn, e.dst, e.dst_conn, rn(e.memlet))
            d = e.memlet.data
            if d is not None and d in outer_src:
                key = (outer_src[d], f"IN_{d}")
                if key not in pe_in:
                    st.add_edge(outer_src[d], None, pe, f"IN_{d}",
                                Memlet.simple(d))
                    pe_in.add(key)

        # consumer-internal tasklet->tasklet edges: rename in place
        for e in st.edges:
            if e.src in cons_inner and e.dst in cons_inner:
                e.memlet = rn(e.memlet)

        # consumer-scope writes: through the fused exit
        for e in list(st.in_edges(cx)):
            st.add_edge(e.src, e.src_conn, px, e.dst_conn, rn(e.memlet))
        for e in list(st.out_edges(cx)):
            st.add_edge(px, e.src_conn, e.dst, e.dst_conn, e.memlet)

        # drop the intermediate round-trips and the consumed scope shell
        for w in w_edges:
            st.remove_edge(w)
        for member in members:
            st.remove_node(member)
        st.remove_node(ce)
        st.remove_node(cx)

        def base(lbl: str) -> str:
            return lbl[:-len("_tiled")] if lbl.endswith("_tiled") else lbl

        prod.label = f"{base(prod.label)}+{base(cons.label)}"
        if prod.annotations.get("tiling"):
            prod.label += "_tiled"
        # the intermediates now live on per-iteration edges only: pure
        # on-chip storage, out of the off-chip volume metric
        for t in tset:
            sdfg.arrays[t].storage = StorageType.REG
