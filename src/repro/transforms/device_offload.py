"""DeviceOffload — the paper's ``FPGATransformSDFG`` (§3.2.1), for TPU.

Detects all host-memory accesses in the computation states, creates device
(HBM) twins of the containers, inserts a pre-state copying inputs
host->device and a post-state copying outputs device->host, and redirects
every access in the computation states to the device twins.
"""
from __future__ import annotations

from typing import Dict

from ..core.memlet import Memlet
from ..core.sdfg import AccessNode, Array, Scalar, SDFG, State, Stream
from ..core.dtypes import StorageType
from .base import Transformation


class DeviceOffload(Transformation):
    prefix = "dev_"

    def find_matches(self, sdfg: SDFG, **kwargs):
        # one whole-SDFG match if any non-transient host container is
        # accessed in a state (and offload has not run yet)
        if sdfg.metadata.get("device_offloaded"):
            return
        names = set()
        for st in sdfg.states:
            for node in st.data_nodes():
                desc = sdfg.arrays[node.data]
                if (not desc.transient and isinstance(desc, Array)
                        and not isinstance(desc, Stream)
                        and node.data not in sdfg.constants
                        and desc.storage in (StorageType.DEFAULT,
                                             StorageType.HOST)):
                    names.add(node.data)
        if names:
            yield {"names": sorted(names)}

    def apply_match(self, sdfg: SDFG, match: Dict):
        names = match["names"]
        dev_of = {}
        # read-before-write containers need a host->device pre-copy;
        # written containers need a device->host post-copy
        read, written = set(), set()
        for st in (sdfg.state_order() or sdfg.states):
            for node in st.topological_nodes():
                if not isinstance(node, AccessNode) or node.data not in names:
                    continue
                if st.in_degree(node) > 0:
                    written.add(node.data)
                if st.out_degree(node) > 0 and node.data not in written:
                    read.add(node.data)
        for name in names:
            desc = sdfg.arrays[name]
            desc.storage = StorageType.HOST
            dev = self.prefix + name
            sdfg.add_transient(dev, desc.shape, desc.dtype,
                               storage=StorageType.HBM)
            dev_of[name] = dev

        # redirect accesses in computation states
        for st in list(sdfg.states):
            for node in st.data_nodes():
                if node.data in dev_of:
                    new = dev_of[node.data]
                    node.data = new
                    node.label = new
            for e in st.edges:
                if e.memlet.data in dev_of:
                    e.memlet.data = dev_of[e.memlet.data]

        # intermediates point to off-chip memory by default (paper §3.2.3:
        # 'In unoptimized SDFGs, intermediate data is represented as data
        # access nodes, pointing to off-chip memory by default.')
        for name, desc in sdfg.arrays.items():
            if (desc.transient and isinstance(desc, Array)
                    and not isinstance(desc, Stream)
                    and desc.storage is StorageType.DEFAULT):
                desc.storage = StorageType.HBM

        # pre/post copy states (paper Fig. 3 pre_axpy / post_axpy)
        order = sdfg.state_order()
        first, last = order[0], order[-1]
        pre = sdfg.add_state_before(first, "pre_copy_to_device")
        post = sdfg.add_state_after(last, "post_copy_to_host")
        for name in sorted(read):
            h = pre.add_access(name)
            d = pre.add_access(dev_of[name])
            pre.add_edge(h, None, d, None, Memlet.simple(dev_of[name]))
        for name in sorted(written):
            d = post.add_access(dev_of[name])
            h = post.add_access(name)
            post.add_edge(d, None, h, None, Memlet.simple(dev_of[name]))
        sdfg.metadata["device_offloaded"] = True
