"""Vectorization (paper §3.2.4): widen the data path to W elements.

On FPGA, W controls the unroll factor of inner circuits and accumulation
interleaving. On TPU, the natural W is the 128-element VPU lane (x8
sublanes); the transformation records W on the SDFG and on each container
whose minor dimension divides W, and Library-Node expansions consult it to
pick block shapes / partial-sum widths (e.g. Dot's partial-sum buffer).
"""
from __future__ import annotations

from typing import Dict

from ..core.dtypes import TPU_LANES, sublanes_for_bytes
from ..core.sdfg import Array, SDFG, Scalar, Stream
from .base import Transformation


class Vectorization(Transformation):
    def __init__(self, width: int = TPU_LANES):
        self.width = width

    def find_matches(self, sdfg: SDFG, width: int = None, **kwargs):
        w = width or self.width
        if sdfg.metadata.get("vector_width") == w:
            return
        yield {"width": w}

    def apply_match(self, sdfg: SDFG, match: Dict):
        w = match["width"]
        sdfg.metadata["vector_width"] = w
        env = sdfg.symbol_values
        min_bytes = None
        for name, desc in sdfg.arrays.items():
            if isinstance(desc, (Scalar, Stream)) or not isinstance(desc, Array):
                continue
            if not desc.shape:
                continue
            min_bytes = desc.dtype.bytes if min_bytes is None \
                else min(min_bytes, desc.dtype.bytes)
            minor = desc.shape[-1]
            try:
                if minor.evaluate(env) % w == 0:
                    desc.vector_width = w
            except Exception:
                # symbolic minor dim: assume divisible (checked at dry-run)
                desc.vector_width = w
        if min_bytes is not None:
            # the dtype-aware sublane count MapTiling's second-dim default
            # consults when a scope's own containers don't pin one
            # (narrowest container wins: its packing needs the most rows)
            sdfg.metadata["sublane_width"] = sublanes_for_bytes(min_bytes)
