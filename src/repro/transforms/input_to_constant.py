"""InputToConstant (paper §5.1, DaCeML): bake inference parameters into the
program as compile-time constants.

On FPGA the parameters are fixed in hardware; on TPU they become jit-closure
constants folded into the XLA executable. The transformation verifies the
parameter is never written, installs the value in ``sdfg.constants``, and
removes the container from the argument list. Off-chip volume accounting
then excludes reads of constant containers (they are loaded once with the
program, not per execution — DESIGN.md §2).
"""
from __future__ import annotations

from typing import Dict

import numpy as np

from ..core.sdfg import AccessNode, SDFG
from .base import Transformation


class InputToConstant(Transformation):
    def __init__(self, parameters: Dict[str, np.ndarray] = None):
        self.parameters = parameters or {}

    def find_matches(self, sdfg: SDFG, parameters: Dict[str, np.ndarray] = None,
                     **kwargs):
        params = parameters or self.parameters
        for name, value in params.items():
            if name in sdfg.constants or name not in sdfg.arrays:
                continue
            yield {"name": name, "value": value}

    def can_apply(self, sdfg: SDFG, match: Dict) -> bool:
        name = match["name"]
        # verify the parameter array is never written (paper: 'first
        # verifies that the parameter array is never written to')
        for st in sdfg.states:
            for node in st.data_nodes():
                if node.data == name and st.in_degree(node) > 0:
                    return False
        return True

    def apply_match(self, sdfg: SDFG, match: Dict):
        name, value = match["name"], match["value"]
        sdfg.constants[name] = np.asarray(value)
        desc = sdfg.arrays[name]
        desc.transient = False  # stays addressable; excluded from args by
        #                        sdfg.argument_names() via constants check
