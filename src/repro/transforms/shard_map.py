"""ShardMap partition analysis: memlets decide what crosses the mesh.

The same ``factor_subset`` idea that turns affine memlet subsets into
Pallas BlockSpecs extends one level up (ROADMAP scale-out item): the
outermost dimension of an eligible DEVICE/PIPELINED map scope is
partitioned across a 1-D mesh axis, and every data container is
classified from its memlets as

  * **shard-local** — a scope parameter indexes the dimension exactly
    (coefficient 1, offset 0): each shard owns ``extent / n_shards`` of
    it and the per-shard trace sees the local shape;
  * **replicated** — never addressed by a partitioned parameter (weights,
    lookup tables): every shard holds the full array;
  * **collective** — written with ``wcr`` reduced *over* a partitioned
    parameter: each shard produces a partial value and a ``psum`` over
    the mesh axis completes the reduction (data-parallel gradients).

Reads that cross the shard boundary — a partitioned parameter appearing
with an offset (``p0 + 1``: a halo), inside a slice bound, or in a step —
are a **typed refusal**: the partition either replicates the operand (a
read-only halo input) or refuses the whole SDFG with the reason recorded
in ``report["grid_decisions"]`` (PR-7 plumbing), never silently computes
the wrong thing.

Containers that only appear through whole-container memlets (the serving
step's monolithic tasklets wire everything with ``Memlet.simple(name)``)
are statically opaque; two escape hatches cover them:

  * ``sdfg.metadata["shard_declared"]`` — the *builder* declares the
    partition dim (or ``None`` for replicated) per container; the page
    pools' in-shard-ness is a pool-protocol invariant no static analysis
    can see, so the serving builder declares it (decision ``declared``).
  * transients whose leading-dim extent equals a sharded extent inherit
    dim-0 partitioning (the per-layer activations between monolithic
    tasklets); everything else defaults to replicated.

``partition_sdfg`` mutates the SDFG in place — container shapes and map
ranges divide by ``n_shards`` — and stamps ``sdfg.metadata["shard_map"]``
(pure data, content-hash safe) for the backend, which wraps the built
callable in ``jax.experimental.shard_map`` (codegen/shard.py).
"""
from __future__ import annotations

from typing import Dict, List, Optional, Set, Tuple

from ..core.dtypes import ScheduleType
from ..core.memlet import Range
from ..core.sdfg import SDFG, Array, MapEntry, MapExit
from ..core.symbolic import Expr

#: metadata key carrying the partition result to codegen
SHARD_ANNOTATION = "shard_map"
#: metadata key for builder-declared container partitions
DECLARED_KEY = "shard_declared"

#: sentinel: container pinned replicated (vs. "not yet classified")
_REPLICATED = -1


class ShardRefusal(Exception):
    """Typed refusal: the SDFG cannot be partitioned as requested."""

    def __init__(self, reason: str, container: str = None, scope: str = None):
        self.reason = reason
        self.container = container
        self.scope = scope
        super().__init__(reason)


def _scope_memlets(state, entry: MapEntry, scopes) -> List:
    """All distinct memlets incident to a map scope's nodes (entry, exit,
    children): the outer whole-container edges plus the per-iteration
    subset edges the classification reads."""
    nodes = {entry}
    for n in scopes.get(entry, []):
        nodes.add(n)
        if isinstance(n, MapEntry):  # nested scopes contribute their edges
            nodes |= set(scopes.get(n, []))
    nodes |= {n for n in state.nodes
              if isinstance(n, MapExit) and n.entry is entry}
    out = []
    seen = set()
    for e in state.edges:
        if (e.src in nodes or e.dst in nodes) and e.memlet.data is not None:
            if id(e) not in seen:
                seen.add(id(e))
                out.append(e)
    return out


def _exact_index_dim(r: Range, p: str) -> Optional[bool]:
    """True: ``r`` is exactly ``[p]``. False: ``p`` appears some other way
    (offset/slice/step — a shard-boundary crossing). None: ``p`` unused."""
    syms = r.start.free_symbols | r.stop.free_symbols | r.step.free_symbols
    if p not in syms:
        return None
    return bool(r.is_index() and r.start == Expr.sym(p))


class _Analysis:
    """One fixpoint partition analysis over an SDFG."""

    def __init__(self, sdfg: SDFG, n_shards: int, axis: str):
        self.sdfg = sdfg
        self.k = n_shards
        self.axis = axis
        self.env = {k: v for k, v in sdfg.symbol_values.items()
                    if isinstance(v, int)}
        #: container -> shard dim, or _REPLICATED (pinned)
        self.assign: Dict[str, int] = {}
        self.psum: Set[str] = set()
        self.decisions: List[dict] = []
        #: (map label, param) pairs whose range divides by k
        self.divided: Set[Tuple[int, str]] = set()
        self._maps: Dict[int, object] = {}

    # -- helpers --------------------------------------------------------
    def _extent(self, name: str, dim: int) -> Optional[int]:
        desc = self.sdfg.arrays.get(name)
        if not isinstance(desc, Array) or dim >= len(desc.shape):
            return None
        try:
            return int(desc.shape[dim].evaluate(self.env))
        except Exception:  # symbolic extent: not partitionable statically
            return None

    def _assign_shard(self, name: str, dim: int, how: str):
        cur = self.assign.get(name)
        if cur == dim:
            return False
        if cur is not None and cur != dim:
            if cur == _REPLICATED:
                raise ShardRefusal(
                    f"container {name!r} must stay replicated "
                    f"(declared or halo-read) but a scope indexes its "
                    f"dim {dim} with a partitioned parameter",
                    container=name)
            raise ShardRefusal(
                f"container {name!r} partitioned on two different dims "
                f"({cur} and {dim}) by different scopes", container=name)
        ext = self._extent(name, dim)
        if ext is None or ext % self.k:
            raise ShardRefusal(
                f"container {name!r} dim {dim} extent {ext} is not "
                f"divisible by n_shards={self.k}", container=name)
        self.assign[name] = dim
        self.decisions.append({"map": None, "container": name,
                               "decision": "shard", "dim": dim,
                               "how": how, "extent": ext})
        return True

    # -- per-scope classification ---------------------------------------
    def _scope_uses(self, state, entry, scopes):
        """param -> {(container, dim)} exact uses, plus violations
        (param -> [(container, reason)]) and wcr reductions."""
        exact: Dict[str, Set[Tuple[str, int]]] = {}
        bad: Dict[str, List[Tuple[str, str]]] = {}
        wcr_over: List[Tuple[str, Set[str]]] = []  # (container, used params)
        params = set()
        m = entry.map
        params |= set(m.params)
        for n in scopes.get(entry, []):
            if isinstance(n, MapEntry):
                params |= set(n.map.params)
        for e in _scope_memlets(state, entry, scopes):
            ml = e.memlet
            if ml.subset is None:
                if ml.wcr is not None and not self.sdfg.arrays[ml.data].transient:
                    wcr_over.append((ml.data, set()))
                continue
            used = set()
            for d, r in enumerate(ml.subset):
                for p in params:
                    res = _exact_index_dim(r, p)
                    if res is None:
                        continue
                    used.add(p)
                    if res:
                        exact.setdefault(p, set()).add((ml.data, d))
                    else:
                        bad.setdefault(p, []).append(
                            (ml.data,
                             f"parameter {p!r} addresses {ml.data!r} dim "
                             f"{d} as {r!r} (offset/slice crosses the "
                             f"shard boundary)"))
            if ml.wcr is not None:
                wcr_over.append((ml.data, used))
        return exact, bad, wcr_over

    def _run_scope(self, state, entry, scopes, seed: bool) -> bool:
        """Process one scope; returns True if the assignment changed."""
        m = entry.map
        if not m.params:
            return False
        exact, bad, wcr_over = self._scope_uses(state, entry, scopes)

        # which params already touch sharded dims?
        hot = [p for p, uses in exact.items()
               if any(self.assign.get(c) == d for c, d in uses)]
        if not hot and seed:
            # seed from the outermost param of an eligible DEVICE scope
            if m.schedule not in (ScheduleType.DEVICE,
                                  ScheduleType.PIPELINED):
                return False
            p0 = m.params[0]
            r0 = m.ranges[0]
            try:
                ext = int(r0.size.evaluate(self.env))
                start = int(r0.start.evaluate(self.env))
            except Exception:
                return False
            if start != 0 or ext < self.k or ext % self.k:
                self.decisions.append({
                    "map": m.label, "decision": "unsharded",
                    "reason": f"outermost extent {ext} not divisible by "
                              f"n_shards={self.k}"})
                return False
            if p0 in bad:
                self.decisions.append({
                    "map": m.label, "decision": "unsharded",
                    "reason": bad[p0][0][1]})
                return False
            if p0 not in exact:
                return False
            hot = [p0]
        if not hot:
            return False
        if len(hot) > 1:
            raise ShardRefusal(
                f"scope {m.label!r}: parameters {sorted(hot)} both index "
                f"partitioned dims — 2-D sharding is not supported",
                scope=m.label)
        p = hot[0]
        if p in bad:
            # a partitioned parameter also reads across the boundary
            raise ShardRefusal(bad[p][0][1], container=bad[p][0][0],
                               scope=m.label)
        changed = False
        for c, d in exact[p]:
            changed |= self._assign_shard(c, d, how=f"indexed in {m.label}")
        # wcr writes not addressed by p reduce over the partition: the
        # per-shard partial needs a psum to complete
        for c, used in wcr_over:
            if p not in used:
                desc = self.sdfg.arrays[c]
                if not desc.transient:
                    if self.assign.get(c, _REPLICATED) != _REPLICATED:
                        raise ShardRefusal(
                            f"container {c!r} is both partitioned and "
                            f"wcr-reduced over the partition",
                            container=c, scope=m.label)
                    self.assign[c] = _REPLICATED
                    if c not in self.psum:
                        self.psum.add(c)
                        self.decisions.append({
                            "map": m.label, "container": c,
                            "decision": "collective", "op": "psum"})
                        changed = True
        return changed

    # -- driver ----------------------------------------------------------
    def run(self):
        declared = self.sdfg.metadata.get(DECLARED_KEY) or {}
        for name, dim in declared.items():
            if name not in self.sdfg.arrays:
                continue
            if dim is None:
                self.assign[name] = _REPLICATED
                self.decisions.append({"map": None, "container": name,
                                       "decision": "replicated",
                                       "how": "declared"})
            else:
                self._assign_shard(name, int(dim), how="declared")

        scopes_of = {}
        for st in self.sdfg.states:
            scopes_of[st] = st.scope_children()
        seed = not declared
        for _ in range(64):  # fixpoint; scope count bounds real iterations
            changed = False
            for st in self.sdfg.states:
                for node in st.nodes:
                    if isinstance(node, MapEntry):
                        changed |= self._run_scope(st, node, scopes_of[st],
                                                   seed)
            if not changed:
                break

        if not any(d != _REPLICATED for d in self.assign.values()):
            raise ShardRefusal("no eligible scope: nothing to partition")

        # transients touched only by whole-container memlets: inherit dim-0
        # partitioning when the leading extent matches a sharded extent
        shard_extents = {self._extent(c, d)
                         for c, d in self.assign.items() if d != _REPLICATED}
        shard_extents.discard(None)
        for name, desc in self.sdfg.arrays.items():
            if name in self.assign or not isinstance(desc, Array):
                continue
            if not desc.shape:
                continue
            if desc.transient and self._extent(name, 0) in shard_extents:
                self.assign[name] = 0
                self.decisions.append({"map": None, "container": name,
                                       "decision": "shard", "dim": 0,
                                       "how": "transient_extent"})
            elif not desc.transient:
                self.decisions.append({"map": None, "container": name,
                                       "decision": "replicated",
                                       "how": "default"})

    # -- transform --------------------------------------------------------
    def transform(self):
        """Divide sharded container shapes and the map ranges addressing
        them by ``n_shards``; stamp the partition metadata.

        Validation happens before any mutation: a refusal raised here must
        leave the SDFG untouched (the caller then compiles unsharded)."""
        planned = []  # (map, range index, new Range)
        for st in self.sdfg.states:
            scopes = st.scope_children()
            for node in st.nodes:
                if not isinstance(node, MapEntry):
                    continue
                m = node.map
                exact, _, _ = self._scope_uses(st, node, scopes)
                owners = {}  # param -> required divided extent
                for p, uses in exact.items():
                    for c, d in uses:
                        if self.assign.get(c, _REPLICATED) == d:
                            ext = self._extent(c, d)
                            if p in owners and owners[p] != ext:
                                raise ShardRefusal(
                                    f"scope {m.label!r}: parameter {p!r} "
                                    f"indexes partitioned dims of "
                                    f"different extents", scope=m.label)
                            owners[p] = ext
                for me in ([node] + [n for n in scopes.get(node, [])
                                     if isinstance(n, MapEntry)]):
                    mm = me.map
                    for i, p in enumerate(mm.params):
                        if p not in owners:
                            continue
                        r = mm.ranges[i]
                        try:
                            ext = int(r.size.evaluate(self.env))
                            start = int(r.start.evaluate(self.env))
                        except Exception as exc:
                            raise ShardRefusal(
                                f"scope {mm.label!r}: symbolic range for "
                                f"partitioned parameter {p!r}",
                                scope=mm.label) from exc
                        if start != 0 or ext != owners[p]:
                            raise ShardRefusal(
                                f"scope {mm.label!r}: parameter {p!r} "
                                f"iterates [{start}:{start + ext}) but "
                                f"the partitioned dim extent is "
                                f"{owners[p]} — partial iteration cannot "
                                f"shard", scope=mm.label)
                        planned.append((mm, i, Range.make(0, ext // self.k)))
                        self.divided.add((mm.label, p))
        for mm, i, r in planned:
            mm.ranges[i] = r
        # container shapes
        for name, dim in self.assign.items():
            if dim == _REPLICATED:
                continue
            desc = self.sdfg.arrays[name]
            shape = list(desc.shape)
            ext = int(shape[dim].evaluate(self.env))
            shape[dim] = Expr.const(ext // self.k)
            desc.shape = tuple(shape)
        self.sdfg.metadata[SHARD_ANNOTATION] = {
            "axis": self.axis, "n_shards": self.k,
            "specs": {name: (None if dim == _REPLICATED else dim)
                      for name, dim in sorted(self.assign.items())
                      if not self.sdfg.arrays[name].transient},
            "psum": sorted(self.psum),
            # (map label, param) pairs whose range was divided by the
            # shard count — the verifier (analysis.annotations, SHD003)
            # uses this to prove replicated containers are not written
            # per shard.
            "divided": sorted(self.divided),
        }


def partition_sdfg(sdfg: SDFG, n_shards: int, axis: str = "shard") -> dict:
    """Partition ``sdfg`` in place across ``n_shards`` mesh shards.

    Returns ``{"sharded": bool, "decisions": [...], "specs": {...}}``.
    On a typed refusal the SDFG is left untouched and the refusal reason
    is the single decision — the caller compiles unsharded.
    """
    if n_shards <= 1:
        return {"sharded": False, "decisions": [], "specs": {}}
    ana = _Analysis(sdfg, n_shards, axis)
    try:
        ana.run()
        ana.transform()
    except ShardRefusal as e:
        return {"sharded": False,
                "decisions": ana.decisions + [{
                    "map": e.scope, "container": e.container,
                    "decision": "shard_refused", "reason": e.reason}],
                "specs": {}}
    meta = sdfg.metadata[SHARD_ANNOTATION]
    return {"sharded": True, "decisions": ana.decisions,
            "specs": meta["specs"], "psum": meta["psum"]}
