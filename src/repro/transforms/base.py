"""Transformation framework: pattern-match + rewrite on SDFGs (paper §3.2).

DaCe expresses transformations as subgraph pattern matches; we keep the
same contract with a lighter API: ``find_matches`` yields candidate dicts,
``can_apply`` validates, ``apply_match`` mutates the graph.
"""
from __future__ import annotations

from typing import Dict, Iterable, List

from ..core.sdfg import SDFG, State


class Transformation:
    def find_matches(self, sdfg: SDFG) -> Iterable[Dict]:
        raise NotImplementedError

    def can_apply(self, sdfg: SDFG, match: Dict) -> bool:
        return True

    def apply_match(self, sdfg: SDFG, match: Dict) -> None:
        raise NotImplementedError

    def apply_everywhere(self, sdfg: SDFG, **kwargs) -> int:
        count = 0
        # fixpoint: a rewrite can expose new matches, but each pass collects
        # matches first so mutation does not invalidate the iterator.
        for _ in range(100):
            matches = [m for m in self.find_matches(sdfg, **kwargs)
                       if self.can_apply(sdfg, m)]
            if not matches:
                break
            applied_this_pass = 0
            for m in matches:
                if not self.can_apply(sdfg, m):  # may be stale after rewrite
                    continue
                self.apply_match(sdfg, m)
                count += 1
                applied_this_pass += 1
            if applied_this_pass == 0:
                break
        return count
