"""Mid-level transformations (paper §3.2), adapted to TPU."""
from .base import Transformation
from .device_offload import DeviceOffload
from .input_to_constant import InputToConstant
from .map_fusion import MapFusion
from .map_tiling import MapTiling
from .streaming import StreamingComposition, StreamingMemory
from .vectorization import Vectorization

__all__ = [
    "Transformation", "DeviceOffload", "InputToConstant", "MapFusion",
    "MapTiling", "StreamingComposition", "StreamingMemory", "Vectorization",
]
