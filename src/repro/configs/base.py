"""Config system: model architecture + input-shape specs.

One file per assigned architecture in this package; each exports CONFIG.
``reduced()`` returns a same-family miniature for CPU smoke tests; the full
config is exercised only through the dry-run (ShapeDtypeStruct, no
allocation).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    d_ff_expert: int
    moe_every: int = 1          # every n-th layer is MoE
    shared_expert: bool = False
    capacity_factor: float = 1.25


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                  # dense | moe | ssm | hybrid | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    d_head: Optional[int] = None
    moe: Optional[MoEConfig] = None
    # attention pattern: period of (local:global); window size for local
    local_global_ratio: Optional[Tuple[int, int]] = None  # e.g. (5, 1)
    window: Optional[int] = None
    # hybrid (jamba): layers per period that are attention (rest = mamba)
    hybrid_period: Optional[int] = None
    hybrid_attn_index: int = 0
    # ssm / mamba / rwkv
    d_state: int = 16
    d_conv: int = 4
    expand: int = 2
    # enc-dec
    n_encoder_layers: Optional[int] = None
    # vlm / audio stubs
    n_stub_tokens: int = 0       # patch/frame embeddings prepended
    rope_theta: float = 10000.0
    norm: str = "rmsnorm"        # rmsnorm | layernorm
    act: str = "swiglu"          # swiglu | gelu
    tie_embeddings: bool = False
    param_dtype: str = "float32"
    activation_dtype: str = "bfloat16"
    optimizer: str = "adamw"     # adamw | adafactor (low-mem for XXL archs)
    # skip list for shapes inapplicable to this arch (DESIGN.md §4)
    skip_shapes: Tuple[str, ...] = ()
    source: str = ""
    # -- perf variants (EXPERIMENTS §Perf): defaults are the paper-faithful
    # baseline; the hillclimbed configuration sets chunked/sort.
    attention_impl: str = "naive"    # naive | chunked
    moe_dispatch: str = "onehot"     # onehot | sort

    @property
    def head_dim(self) -> int:
        return self.d_head or self.d_model // self.n_heads

    def n_params(self) -> int:
        """Approximate parameter count (embeddings + blocks)."""
        d, L = self.d_model, self.n_layers
        p = self.vocab * d * (1 if self.tie_embeddings else 2)
        hd = self.head_dim
        attn = d * (self.n_heads * hd) * 2 + d * (self.n_kv_heads * hd) * 2
        if self.family == "ssm":
            # rwkv6: 5 square time-mix matrices + 2 channel-mix matrices
            blk = 5 * d * d + 2 * d * self.d_ff
            p += L * (blk + 4 * d)
            return p
        def ffn_dense(dff):
            return 3 * d * dff if self.act == "swiglu" else 2 * d * dff
        n_attn_layers = L
        n_mamba_layers = 0
        if self.hybrid_period:
            n_attn_layers = L // self.hybrid_period
            n_mamba_layers = L - n_attn_layers
        p += n_attn_layers * attn
        d_inner = self.expand * d
        p += n_mamba_layers * (2 * d * d_inner + d_inner * d
                               + d_inner * self.d_state * 2)
        if self.moe:
            n_moe = L // self.moe.moe_every
            n_dense = L - n_moe
            p += n_moe * (self.moe.n_experts * 3 * d * self.moe.d_ff_expert
                          + d * self.moe.n_experts)
            if self.moe.shared_expert:
                p += n_moe * 3 * d * self.moe.d_ff_expert
            p += n_dense * ffn_dense(self.d_ff)
        else:
            p += L * ffn_dense(self.d_ff)
        if self.n_encoder_layers:
            p += self.n_encoder_layers * (attn + ffn_dense(self.d_ff))
            p += L * attn  # cross attention
        return p

    def n_active_params(self) -> int:
        """Active parameters per token (MoE: only top_k experts count)."""
        if not self.moe:
            return self.n_params()
        d, L = self.d_model, self.n_layers
        full = self.n_params()
        n_moe = L // self.moe.moe_every
        all_experts = n_moe * self.moe.n_experts * 3 * d * self.moe.d_ff_expert
        active = n_moe * self.moe.top_k * 3 * d * self.moe.d_ff_expert
        return full - all_experts + active

    def reduced(self) -> "ModelConfig":
        """Miniature same-family config for CPU smoke tests."""
        n_layers = min(self.n_layers, 4)
        if self.hybrid_period:
            n_layers = min(self.n_layers, self.hybrid_period)
        if self.local_global_ratio:
            n_layers = sum(self.local_global_ratio)  # one full l:g period
        changes = dict(
            n_layers=n_layers,
            d_model=128,
            n_heads=4,
            n_kv_heads=min(self.n_kv_heads, 2) if self.n_kv_heads <
            self.n_heads else 4,
            d_head=32,
            d_ff=256,
            vocab=512,
            window=min(self.window, 16) if self.window else None,
            d_state=8,
            n_encoder_layers=2 if self.n_encoder_layers else None,
            n_stub_tokens=min(self.n_stub_tokens, 8),
        )
        if self.moe:
            changes["moe"] = MoEConfig(
                n_experts=4, top_k=min(self.moe.top_k, 2), d_ff_expert=128,
                moe_every=self.moe.moe_every,
                shared_expert=self.moe.shared_expert)
        if self.hybrid_period:
            changes["hybrid_period"] = min(self.hybrid_period, 4)
            changes["n_layers"] = changes["hybrid_period"]
        return dataclasses.replace(self, **changes)


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str                    # train | prefill | decode


SHAPES: Dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524288, 1, "decode"),
}
