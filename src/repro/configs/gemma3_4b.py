"""gemma3-4b [dense]: 34L d=2560 8H (GQA kv=4) d_ff=10240 vocab=262144,
5:1 local:global attention, 1024-token sliding window, 128k context.
long_500k runs: 5/6 of layers are sliding-window; global layers decode with
sequence-sharded KV (DESIGN.md §4). [hf:google/gemma-3-1b-pt; unverified]"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="gemma3-4b",
    family="dense",
    n_layers=34,
    d_model=2560,
    n_heads=8,
    n_kv_heads=4,
    d_head=256,
    d_ff=10240,
    vocab=262144,
    local_global_ratio=(5, 1),
    window=1024,
    rope_theta=1000000.0,
    tie_embeddings=True,
    source="hf:google/gemma-3-4b (unverified)",
)
