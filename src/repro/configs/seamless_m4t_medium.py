"""seamless-m4t-medium [audio]: enc-dec 12L d=1024 16H (kv=16) d_ff=4096
vocab=256206. Transformer BACKBONE only; the audio frontend is a STUB
(input_specs provides precomputed frame embeddings). [arXiv:2308.11596; hf]"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="seamless-m4t-medium",
    family="encdec",
    n_layers=12,             # decoder layers
    n_encoder_layers=12,
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    d_head=64,
    d_ff=4096,
    vocab=256206,
    norm="layernorm",
    act="gelu",
    n_stub_tokens=1024,      # audio frames fed to the encoder (stub)
    skip_shapes=("long_500k",),
    source="arXiv:2308.11596",
)
