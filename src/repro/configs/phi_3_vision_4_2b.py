"""phi-3-vision-4.2b [vlm]: 32L d=3072 32H (MHA kv=32) d_ff=8192 vocab=32064;
phi3-mini backbone + CLIP vision frontend. Backbone only; the modality
frontend is a STUB (input_specs provides precomputed patch embeddings).
[hf:microsoft/Phi-3-vision-128k-instruct; hf]"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="phi-3-vision-4.2b",
    family="vlm",
    n_layers=32,
    d_model=3072,
    n_heads=32,
    n_kv_heads=32,
    d_head=96,
    d_ff=8192,
    vocab=32064,
    n_stub_tokens=576,      # CLIP 24x24 patch embeddings (stub)
    skip_shapes=("long_500k",),
    source="hf:microsoft/Phi-3-vision-128k-instruct",
)
