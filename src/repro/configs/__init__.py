"""Architecture registry: ``--arch <id>`` resolves here."""
from __future__ import annotations

import importlib
from typing import Dict

from .base import SHAPES, ModelConfig, MoEConfig, ShapeSpec

ARCHS = {
    "llama4-scout-17b-a16e": "llama4_scout_17b_a16e",
    "kimi-k2-1t-a32b": "kimi_k2_1t_a32b",
    "granite-3-2b": "granite_3_2b",
    "starcoder2-3b": "starcoder2_3b",
    "gemma3-4b": "gemma3_4b",
    "yi-34b": "yi_34b",
    "rwkv6-7b": "rwkv6_7b",
    "seamless-m4t-medium": "seamless_m4t_medium",
    "jamba-1.5-large-398b": "jamba_1_5_large_398b",
    "phi-3-vision-4.2b": "phi_3_vision_4_2b",
}


def get_config(arch: str) -> ModelConfig:
    if arch not in ARCHS:
        raise KeyError(f"unknown arch {arch!r}; choose from {sorted(ARCHS)}")
    mod = importlib.import_module(f".{ARCHS[arch]}", __package__)
    return mod.CONFIG


def all_configs() -> Dict[str, ModelConfig]:
    return {a: get_config(a) for a in ARCHS}


__all__ = ["ARCHS", "SHAPES", "ModelConfig", "MoEConfig", "ShapeSpec",
           "get_config", "all_configs"]
