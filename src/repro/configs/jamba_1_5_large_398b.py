"""jamba-1.5-large-398b [hybrid]: 72L d=8192 64H (GQA kv=8) d_ff=24576,
Mamba+attention 1:7 interleave (1 attention layer per 8), MoE 16e top-2
every other layer. long_500k runs (hybrid). [arXiv:2403.19887; hf]"""
from .base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="jamba-1.5-large-398b",
    family="hybrid",
    n_layers=72,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_head=128,
    d_ff=24576,
    vocab=65536,
    moe=MoEConfig(n_experts=16, top_k=2, d_ff_expert=24576, moe_every=2),
    hybrid_period=8,
    hybrid_attn_index=0,
    d_state=16,
    expand=2,
    optimizer="adafactor",
    source="arXiv:2403.19887",
)
