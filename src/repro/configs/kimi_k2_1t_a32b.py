"""kimi-k2-1t-a32b [moe]: 61L d=7168 64H (GQA kv=8) d_ff=2048 (expert)
vocab=163840, MoE 384 experts top-8, shared expert — trillion-param MoE.
[arXiv:2501.kimi2; unverified]"""
from .base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="kimi-k2-1t-a32b",
    family="moe",
    n_layers=61,
    d_model=7168,
    n_heads=64,
    n_kv_heads=8,
    d_head=112,
    d_ff=2048,
    vocab=163840,
    moe=MoEConfig(n_experts=384, top_k=8, d_ff_expert=2048, moe_every=1,
                  shared_expert=True, capacity_factor=1.0),
    rope_theta=50000.0,
    optimizer="adafactor",   # fp32 Adam for 1T params needs >4 pods
    skip_shapes=("long_500k",),
    source="arXiv:2501.kimi2 (unverified)",
)
