"""rwkv6-7b [ssm]: 32L d=4096 (attention-free, data-dependent decay, Finch)
d_ff=14336 vocab=65536. long_500k runs (O(1) state). [arXiv:2404.05892; hf]"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="rwkv6-7b",
    family="ssm",
    n_layers=32,
    d_model=4096,
    n_heads=64,           # wkv heads of size 64
    n_kv_heads=64,
    d_head=64,
    d_ff=14336,
    vocab=65536,
    norm="layernorm",
    source="arXiv:2404.05892",
)
