"""Explicit AOT compilation stages: ``Wrapped -> Lowered -> Compiled``.

Mirrors the jax.stages / JaCe idiom on top of the SDFG IR:

  * ``Wrapped``  -- a traceable program builder (what ``@dc_program``
    returns). Calling it builds the raw frontend SDFG; ``.lower()`` builds,
    binds symbols, validates, and enters the IR world.
  * ``Lowered``  -- owns a validated SDFG. ``.optimize(pipeline)`` runs a
    ``PassManager`` of mid-level rewrites in place; ``.compile(backend=..)``
    runs the backend's lowering pipeline on a private copy and emits an
    executable, so one ``Lowered`` can compile to several backends and its
    content hash stays stable for caching.
  * ``Compiled`` -- callable result carrying the expansion/fusion report,
    the pass timings, and its cache key.

``Lowered.compile`` consults the process-wide ``COMPILATION_CACHE`` keyed
by ``(sdfg.content_hash(), backend, pipeline signature, jit)``: a second
compile of an identical program is served without tracing or expansion.
"""
from __future__ import annotations

import copy
import inspect
import os
from typing import Any, Iterable, Optional

import jax

from ..core.sdfg import SDFG
from .cache import COMPILATION_CACHE, CompilationCache
from .passes import PassManager, PassLike, default_pipeline

#: backend names, for introspection; the authoritative name->module
#: registry (and the single "unknown backend" error path) is
#: ``codegen.get_backend``, which ``Lowered.compile`` consults.
BACKENDS = ("jnp", "pallas")


def _env_verify() -> Optional[str]:
    """Verify mode requested by the environment: ``REPRO_VERIFY=1`` (or
    ``full``) records per-pass verifier results, ``REPRO_VERIFY=strict``
    raises on the first pass that introduces a violation."""
    v = os.environ.get("REPRO_VERIFY", "").strip().lower()
    if v in ("", "0", "false", "off"):
        return None
    return "strict" if v == "strict" else "full"


class Stage:
    """Common base so users can isinstance-check any pipeline stage."""


class Wrapped(Stage):
    """A traceable SDFG factory (returned by ``@dc_program``).

    Calling the object builds and returns the raw frontend SDFG (the
    'unoptimized SDFG' of the paper); ``lower`` additionally binds symbol
    values, validates, and returns a :class:`Lowered` stage. Keyword
    arguments not accepted by the builder are treated as symbol bindings,
    e.g. ``wrapped.lower(n=1024)`` for a program over symbolic ``n``.
    """

    def __init__(self, builder, name: str = None):
        self._builder = builder
        self.__name__ = name or getattr(builder, "__name__", "program")
        self.__wrapped__ = builder

    def _split_kwargs(self, kwargs):
        """Builder kwargs vs. leftover symbol bindings."""
        try:
            params = inspect.signature(self._builder).parameters
        except (TypeError, ValueError):
            return kwargs, {}
        if any(p.kind is inspect.Parameter.VAR_KEYWORD
               for p in params.values()):
            return kwargs, {}
        accepted = {k: v for k, v in kwargs.items() if k in params}
        leftover = {k: v for k, v in kwargs.items() if k not in params}
        return accepted, leftover

    def __call__(self, *args, **kwargs) -> SDFG:
        build_kwargs, symbols = self._split_kwargs(kwargs)
        sdfg = self._builder(*args, **build_kwargs)
        if not isinstance(sdfg, SDFG):
            raise TypeError(
                f"builder {self.__name__!r} returned {type(sdfg).__name__}, "
                "expected an SDFG")
        if symbols:
            known = set(sdfg.symbols) | sdfg.free_symbols()
            unknown = sorted(set(symbols) - known)
            if unknown:
                raise TypeError(
                    f"{self.__name__}() got unknown keyword(s) {unknown}: "
                    "neither builder parameters nor symbols of the program "
                    f"(symbols: {sorted(known)})")
            sdfg.specialize(**{k: int(v) for k, v in symbols.items()})
        return sdfg

    def lower(self, *args, **kwargs) -> "Lowered":
        sdfg = self(*args, **kwargs)
        sdfg.validate()
        return Lowered(sdfg)

    def __repr__(self):
        return f"Wrapped({self.__name__})"


class Lowered(Stage):
    """A validated SDFG between tracing and codegen.

    ``optimize`` mutates the owned SDFG (mid-level rewrites are meant to
    be observable: off-chip volume, PE counts); ``compile`` never does —
    backend lowering runs on a deep copy unless ``in_place=True`` (the
    legacy ``compile_sdfg`` contract).
    """

    def __init__(self, sdfg: SDFG):
        self._sdfg = sdfg
        self.reports: list = []

    @property
    def sdfg(self) -> SDFG:
        return self._sdfg

    def compiler_ir(self) -> SDFG:
        return self._sdfg

    def specialize(self, **symbol_values: int) -> "Lowered":
        self._sdfg.specialize(**symbol_values)
        return self

    def optimize(self, pipeline: Optional[Iterable[PassLike]] = None,
                 skip: Iterable[str] = ()) -> "Lowered":
        """Run a PassManager (or any iterable of passes / Transformation
        classes) over the owned SDFG, in place. Returns ``self``."""
        if pipeline is None:
            return self
        pm = pipeline if isinstance(pipeline, PassManager) \
            else PassManager(pipeline)
        report = {"pipeline": pm.name}
        pm.run(self._sdfg, report=report, skip=skip)
        self.reports.append(report)
        return self

    def compile(self, backend: str = "jnp", jit: bool = True,
                interpret: bool = True,
                expansion_level: Optional[str] = None,
                pipeline: Optional[PassManager] = None,
                cache: Optional[CompilationCache] = COMPILATION_CACHE,
                in_place: bool = False,
                verify: Optional[str] = None) -> "Compiled":
        """Lower to an executable with the backend's pass pipeline.

        ``pipeline`` overrides the backend default (it must then include
        expansion). ``cache=None`` disables caching. ``in_place=True``
        expands the owned SDFG itself instead of a private copy — that
        mode never touches the cache: the produced callable aliases the
        caller's live (mutable) graph, and a hit would skip the in-place
        expansion legacy callers rely on.

        ``verify`` (``"full"`` / ``"strict"``, default from the
        ``REPRO_VERIFY`` env var) arms the per-pass verification harness
        — see :class:`~repro.pipeline.passes.PassManager`. Results land
        in ``Compiled.report["verify"]``. A verifying compile keys the
        cache separately so a cached non-verified artifact is never
        served where a verification record was requested.
        """
        from ..codegen import get_backend
        backend_mod = get_backend(backend)  # validates the name early
        pm = pipeline if pipeline is not None else default_pipeline(
            backend, interpret=interpret, expansion_level=expansion_level)
        if verify is None:
            verify = pm.verify if pm.verify is not None else _env_verify()
        if in_place:
            cache = None
        key = None
        if cache is not None:  # content_hash walks the whole graph
            key = (self._sdfg.content_hash(), backend, pm.signature(),
                   bool(jit)) + ((verify,) if verify else ())
            hit = cache.lookup(key)
            if hit is not None:
                return hit

        work = self._sdfg if in_place else copy.deepcopy(self._sdfg)
        work.validate()
        if backend == "pallas":
            # honored by pipeline-fused and generated grid kernels alike;
            # an explicit PipelineFusionPass(interpret=...) overrides.
            work.metadata["pallas_interpret"] = bool(interpret)
        report = {"backend": backend, "fused_regions": [], "expansions": [],
                  "passes": [], "grid_kernels": [], "grid_converted": [],
                  "grid_skipped": [], "grid_fallbacks": [],
                  "pipeline": pm.name}
        pm.run(work, report=report, verify=verify)
        work.validate()

        fn = backend_mod.build_callable(work)
        jitted = jax.jit(fn) if jit else None
        compiled = Compiled(work, fn, jitted, backend, report, cache_key=key)
        if cache is not None:
            cache.store(key, compiled)
        return compiled

    def __repr__(self):
        return f"Lowered({self._sdfg})"


class Compiled(Stage):
    """Executable stage: call with keyword arrays, get a dict of outputs.

    ``report`` carries the structured pipeline record: backend, per-pass
    timings (``report['passes']``), expansion log, and fused regions.
    """

    def __init__(self, sdfg: SDFG, fn, jitted, backend: str, report: dict,
                 cache_key=None):
        self.sdfg = sdfg
        self.fn = fn
        self.jitted = jitted
        self.backend = backend
        self.report = report
        self.cache_key = cache_key

    def __call__(self, **kwargs):
        return self.jitted(**kwargs) if self.jitted is not None \
            else self.fn(**kwargs)

    def lower(self, **kwargs):
        """Lower the compiled callable through jax (HLO inspection)."""
        return jax.jit(self.fn).lower(**kwargs)

    def argument_names(self):
        return self.sdfg.argument_names()

    def __repr__(self):
        return f"Compiled({self.sdfg.name}, backend={self.backend})"


def lower(sdfg: SDFG, validate: bool = True) -> Lowered:
    """Enter the staged pipeline from a hand-built SDFG."""
    if validate:
        sdfg.validate()
    return Lowered(sdfg)
