"""Compilation cache for the staged pipeline (ROADMAP: serve-heavy-traffic).

Keys are structural: for SDFG programs, ``(content_hash, backend,
pipeline_signature, jit)``; for the launch layer, mesh/config signatures.
Values are whatever the builder produced (a ``Compiled`` stage, a jax
``Lowered``, a jitted step function). The cache is a bounded LRU so long
sweeps (dry-runs over every arch x shape cell) cannot grow it without
limit.

A single process-wide instance, ``COMPILATION_CACHE``, is shared by
``Lowered.compile`` and the launch-layer helpers; tests construct private
instances.
"""
from __future__ import annotations

import os
import threading
from collections import OrderedDict
from typing import Any, Callable, Hashable, Optional

_MISSING = object()

#: env var overriding the default LRU capacity. Serving sweeps many
#: (batch, context) shape buckets; a deployment holding more live buckets
#: than the default can raise this without code changes.
CACHE_SIZE_ENV = "REPRO_COMPILE_CACHE_SIZE"
DEFAULT_MAX_ENTRIES = 128


def _default_max_entries() -> int:
    raw = os.environ.get(CACHE_SIZE_ENV)
    if raw is None:
        return DEFAULT_MAX_ENTRIES
    try:
        n = int(raw)
    except ValueError:
        raise ValueError(
            f"{CACHE_SIZE_ENV}={raw!r} is not an integer") from None
    if n < 1:
        raise ValueError(f"{CACHE_SIZE_ENV} must be >= 1, got {n}")
    return n


class CompilationCache:
    """Bounded LRU cache with hit/miss accounting.

    Capacity: explicit ``max_entries`` wins; ``None`` defers to the
    ``REPRO_COMPILE_CACHE_SIZE`` env var (read at construction time), then
    to ``DEFAULT_MAX_ENTRIES``.
    """

    def __init__(self, max_entries: Optional[int] = None):
        self.max_entries = _default_max_entries() if max_entries is None \
            else max_entries
        self._entries: "OrderedDict[Hashable, Any]" = OrderedDict()
        self._lock = threading.Lock()
        self.hits = 0
        self.misses = 0

    def lookup(self, key: Hashable, default=None) -> Optional[Any]:
        with self._lock:
            value = self._entries.get(key, _MISSING)
            if value is _MISSING:
                self.misses += 1
                return default
            self._entries.move_to_end(key)
            self.hits += 1
            return value

    def contains(self, key: Hashable) -> bool:
        """Membership test without touching hit/miss counters."""
        with self._lock:
            return key in self._entries

    def store(self, key: Hashable, value: Any) -> Any:
        with self._lock:
            self._entries[key] = value
            self._entries.move_to_end(key)
            while len(self._entries) > self.max_entries:
                self._entries.popitem(last=False)
        return value

    def get_or_build(self, key: Hashable, builder: Callable[[], Any]) -> Any:
        value = self.lookup(key, _MISSING)
        if value is not _MISSING:
            return value
        return self.store(key, builder())

    def clear(self):
        with self._lock:
            self._entries.clear()
            self.hits = 0
            self.misses = 0

    @property
    def stats(self) -> dict:
        with self._lock:
            return {"entries": len(self._entries), "hits": self.hits,
                    "misses": self.misses}

    def __len__(self):
        return len(self._entries)

    def __repr__(self):
        s = self.stats
        return (f"CompilationCache({s['entries']} entries, "
                f"{s['hits']} hits, {s['misses']} misses)")


#: process-wide cache used by ``Lowered.compile`` and the launch layer.
COMPILATION_CACHE = CompilationCache()
