"""Composable pass infrastructure over SDFGs.

The paper's multi-level flow (frontend SDFG -> domain passes -> platform
passes -> codegen) is expressed as a ``PassManager``: an ordered, named,
skippable list of ``Pass`` objects with per-pass timing and a structured
report. FLOWER structures its HLS flow the same way; JaCe's
``lower()/compile()`` stages drive an equivalent pipeline.

Three kinds of passes exist:

  * ``TransformationPass`` -- adapts any ``transforms.Transformation``
    (the five mid-level rewrites ship pre-wrapped below);
  * graph-lowering passes -- ``ExpandLibraryNodesPass`` (paper §3 multi-
    level expansion) and ``PipelineFusionPass`` (stream-chain fusion for
    the Pallas backend);
  * configuration passes -- ``SetExpansionPreferencePass`` records the
    vendor-specific expansion order on the SDFG.

Every pass has a stable ``signature()`` so a pipeline's configuration can
key the compilation cache. Custom passes register with ``register_pass``
and can then be named in pipelines by string.
"""
from __future__ import annotations

import time
from typing import Any, Dict, Iterable, List, Optional, Sequence, Tuple, Union

from ..core.sdfg import SDFG, _stable_repr
from ..transforms import (DeviceOffload, InputToConstant, MapFusion,
                          MapTiling, StreamingComposition, StreamingMemory,
                          Transformation, Vectorization)

#: name -> Pass subclass, for string lookup in pipelines / custom passes.
PASS_REGISTRY: Dict[str, type] = {}


def register_pass(cls=None, *, name: str = None):
    """Class decorator: make a Pass constructible by name in pipelines."""
    def deco(c):
        PASS_REGISTRY[name or c.__name__] = c
        return c
    return deco(cls) if cls is not None else deco


# canonical, hashable string for pass-option values — the same
# canonicalizer the SDFG content hash uses, so pipeline signatures and
# graph hashes can never drift apart.
_canon = _stable_repr


class Pass:
    """One named rewrite step. Subclasses override ``apply`` (mutates the
    SDFG, returns a summary value recorded in the report) and optionally
    ``should_skip``."""

    #: display/skip name; defaults to the class name.
    name: str = None

    def __init_subclass__(cls, **kw):
        super().__init_subclass__(**kw)
        if cls.__dict__.get("name") is None:
            cls.name = cls.__name__

    def apply(self, sdfg: SDFG, report: dict) -> Any:
        raise NotImplementedError

    def should_skip(self, sdfg: SDFG) -> bool:
        return False

    def options(self) -> Dict[str, Any]:
        """Configuration that affects the pass's behavior (cache key)."""
        return {}

    def signature(self) -> Tuple:
        return (self.name,
                tuple((k, _canon(v)) for k, v in sorted(
                    self.options().items())))

    def __repr__(self):
        opts = ", ".join(f"{k}={v!r}" for k, v in self.options().items())
        return f"{self.name}({opts})"


class TransformationPass(Pass):
    """Adapter: run a ``transforms.Transformation`` everywhere it matches.

    Subclasses set ``transformation``; kwargs are forwarded to
    ``SDFG.apply`` (i.e. to ``find_matches``). The summary is the number
    of applications.
    """

    transformation: type = None

    def __init__(self, transformation: type = None, **kwargs):
        t = transformation or type(self).transformation
        if t is None:
            raise TypeError("TransformationPass needs a transformation")
        if not (isinstance(t, type) and issubclass(t, Transformation)):
            raise TypeError(f"{t!r} is not a Transformation subclass")
        self._transformation = t
        self.kwargs = kwargs
        if type(self).transformation is None:
            self.name = t.__name__

    def apply(self, sdfg: SDFG, report: dict) -> int:
        return sdfg.apply(self._transformation(), **self.kwargs)

    def options(self) -> Dict[str, Any]:
        return {"transformation": self._transformation.__name__,
                **self.kwargs}


# The five mid-level rewrites (paper §3.2), pre-wrapped as passes --------

@register_pass
class DeviceOffloadPass(TransformationPass):
    transformation = DeviceOffload
    name = "DeviceOffload"


@register_pass
class InputToConstantPass(TransformationPass):
    transformation = InputToConstant
    name = "InputToConstant"


@register_pass
class MapFusionPass(TransformationPass):
    """Fuse producer->consumer map scopes (transforms/map_fusion.py): the
    intermediate becomes a per-iteration tasklet->tasklet value (exact
    mode), an in-kernel accumulator (wcr mode), or replicated shifted
    producers (halo mode) instead of an HBM round-trip. Runs after
    expansion (generic subgraphs expose the map pairs) and before
    MapTiling (fused single-parameter maps then tile as one; halo/wcr
    legality needs the untiled iteration boxes).

    Producer scopes left fully dead by multi-consumer halo fusion are
    pruned afterwards, and every refused fusion records its typed reason
    in ``report["grid_skipped"]`` / ``grid_decisions`` so a pipeline
    report explains *why* a pair stayed two kernels."""
    transformation = MapFusion
    name = "MapFusion"

    def apply(self, sdfg: SDFG, report: dict) -> int:
        from ..transforms.map_fusion import prune_dead_scopes
        t = self._transformation()
        count = sdfg.apply(t, **self.kwargs)
        pruned = prune_dead_scopes(sdfg)
        if pruned:
            report.setdefault("pruned_scopes", []).extend(pruned)
        from ..analysis.diagnostics import refusal_code, refusal_diagnostic
        for label, reason in t.explain(sdfg):
            report.setdefault("grid_skipped", []).append(
                (label, f"fusion refused: {reason}"))
            report.setdefault("grid_decisions", []).append(
                {"map": label, "decision": "unfused", "reason": reason,
                 "code": refusal_code("fusion", reason)})
            report.setdefault("refusals", []).append(
                refusal_diagnostic("fusion", label, reason).to_dict())
        return count


@register_pass
class MapTilingPass(TransformationPass):
    transformation = MapTiling
    name = "MapTiling"


@register_pass
class StreamingCompositionPass(TransformationPass):
    transformation = StreamingComposition
    name = "StreamingComposition"


@register_pass
class StreamingMemoryPass(TransformationPass):
    transformation = StreamingMemory
    name = "StreamingMemory"


@register_pass
class VectorizationPass(TransformationPass):
    transformation = Vectorization
    name = "Vectorization"


@register_pass
class SetExpansionPreferencePass(Pass):
    """Record the vendor expansion order consulted by
    ``LibraryNode.pick_expansion`` (paper: Intel vs Xilinx codegen)."""

    name = "SetExpansionPreference"

    def __init__(self, preference: Sequence[str]):
        self.preference = tuple(preference)

    def apply(self, sdfg: SDFG, report: dict):
        sdfg.expansion_preference = self.preference
        return self.preference

    def options(self):
        return {"preference": self.preference}


@register_pass
class PipelineFusionPass(Pass):
    """Fuse stream-connected Library-Node chains into single Pallas
    kernels (codegen/pipeline_fusion.py); Pallas backend only."""

    name = "PipelineFusion"

    def __init__(self, interpret: bool = True):
        self.interpret = interpret

    def apply(self, sdfg: SDFG, report: dict) -> List[str]:
        from ..codegen.pipeline_fusion import fuse_stream_pipelines
        sdfg.metadata["pallas_interpret"] = self.interpret
        fused = fuse_stream_pipelines(sdfg, interpret=self.interpret)
        report.setdefault("fused_regions", []).extend(fused)
        return fused

    def options(self):
        return {"interpret": self.interpret}


@register_pass
class GridConversionPass(Pass):
    """Annotate eligible DEVICE/PIPELINED map scopes with derived Pallas
    grid specs (``codegen.pallas_backend.analyze_map_scope``): grid from
    map ranges, BlockSpecs factored from affine memlet subsets, wcr
    add/max/min as VMEM scratch accumulation. Non-affine / dynamic /
    misaligned scopes are left un-annotated and fall back to the
    structural interpreter — the paper's generic-expansion fallback.

    Conversion is gated by a VMEM-aware cost model: a scope only becomes
    a grid kernel when its per-step blocks (double-buffered, plus
    reduction scratch) fit ``vmem_budget_bytes``, its grid has at least
    ``min_grid_steps`` steps (a one-step grid is a whole-array copy the
    vmap path does without launch overhead), and its fused chain stays
    under ``max_fused_tasklets``. Scopes the model rejects are recorded
    as ``grid_skipped(reason)`` and stay on the vmap path; converted
    scopes are recorded in ``grid_converted`` with their cost estimates.
    Runs after MapTilingPass so tile annotations shape the VMEM blocks;
    Pallas backend only."""

    name = "GridConversion"

    #: VMEM is ~16 MiB/core on current TPUs; the budget bounds the
    #: double-buffered working set a generated kernel may pin there.
    DEFAULT_VMEM_BUDGET = 16 * 2 ** 20

    #: measured tile crossovers per (backend, interpret) — seeded from the
    #: committed ``BENCH_*.json`` ``--calibrate`` sweeps: the gemver
    #: minor-tile sweep bottoms out at 64 (not the lane-aligned 128) and
    #: the star-stencil sublane sweep at 32 (not the fp32-aligned 8) on
    #: CPU interpret mode, where per-step Python dispatch dwarfs register
    #: packing. Real hardware (interpret=False) has no committed
    #: calibration and keeps the static lane/sublane alignment defaults.
    CALIBRATED_TILES = {("pallas", True): {"minor": 64, "second": 32}}

    @classmethod
    def default_tiles(cls, backend: str, interpret: bool = True) -> Dict:
        """Per-backend preferred (minor, second) tile widths: the
        calibrated table when a measured entry exists, else empty — the
        caller falls back to the static alignment defaults."""
        return dict(cls.CALIBRATED_TILES.get((backend, bool(interpret)), {}))

    def __init__(self, vmem_budget_bytes: int = DEFAULT_VMEM_BUDGET,
                 min_grid_steps: int = 2, max_fused_tasklets: int = 16):
        self.vmem_budget_bytes = int(vmem_budget_bytes)
        self.min_grid_steps = int(min_grid_steps)
        self.max_fused_tasklets = int(max_fused_tasklets)

    def options(self) -> Dict[str, Any]:
        return {"vmem_budget_bytes": self.vmem_budget_bytes,
                "min_grid_steps": self.min_grid_steps,
                "max_fused_tasklets": self.max_fused_tasklets}

    # -- cost model -----------------------------------------------------
    def estimate(self, spec, sdfg: SDFG) -> Dict[str, int]:
        """Static cost estimate for a derived grid spec: total grid steps,
        VMEM bytes pinned per step (deduplicated in/out blocks
        double-buffered by the Pallas pipeline + scratch accumulators),
        bytes moved per step, the real block shape, and chain length."""
        from ..codegen.pallas_backend import unique_operands
        steps = 1
        for _, n in spec.grid:
            steps *= n
        def block_bytes(es):
            desc = sdfg.arrays.get(es.data)
            block = desc.dtype.bytes if desc is not None else 4
            for b in es.fact.block_shape:
                block *= b
            return block

        vmem = bytes_per_step = 0
        for es in unique_operands(spec):
            vmem += 2 * block_bytes(es)   # HBM->VMEM double buffering
            bytes_per_step += block_bytes(es)
        for es in spec.outputs:
            vmem += 2 * block_bytes(es)
            bytes_per_step += block_bytes(es)
            if es.wcr and es.reduction:
                vmem += block_bytes(es)   # scratch accumulator
        # fused-DAG in-kernel intermediates: each tasklet->tasklet edge
        # holds one tile-shaped value live in VMEM under the whole-block
        # body (sized with the first output's element width). Halo-fused
        # scopes are charged through the same term — every replicated
        # producer's value is one more tile — plus the windowed operands'
        # full-dimension blocks already counted above.
        in_kernel = int(getattr(spec, "internal_edges", 0))
        if in_kernel:
            tile_elems = 1
            for _, b in spec.block_params:
                tile_elems *= b
            desc = sdfg.arrays.get(spec.outputs[0].data) \
                if spec.outputs else None
            elem = desc.dtype.bytes if desc is not None else 4
            vmem += in_kernel * tile_elems * elem
        # two-phase reduction scratch: one kept-lattice block per
        # in-kernel wcr value, resident across all reduction steps
        import numpy as _np
        bp = dict(spec.block_params)
        for w in getattr(spec, "internal_wcr", ()):
            elems = 1
            for q in w.kept_intra:
                elems *= bp.get(q, 1)
            vmem += elems * _np.dtype(w.dtype).itemsize
        block_shape = (list(spec.outputs[0].fact.effective_shape())
                       if spec.outputs else [])
        return {"grid_steps": steps, "vmem_bytes": vmem,
                "bytes_per_step": bytes_per_step,
                "block_shape": block_shape,
                "in_kernel_values": in_kernel,
                "tasklets": max(1, len(spec.tasklet_labels))}

    def skip_reason(self, est: Dict[str, int]) -> Optional[str]:
        if est["vmem_bytes"] > self.vmem_budget_bytes:
            return (f"blocks pin {est['vmem_bytes']} B of VMEM > budget "
                    f"{self.vmem_budget_bytes} B")
        if est["grid_steps"] < self.min_grid_steps:
            return (f"grid of {est['grid_steps']} step(s) below "
                    f"min_grid_steps={self.min_grid_steps}; vmap path wins")
        if est["tasklets"] > self.max_fused_tasklets:
            return (f"{est['tasklets']} fused tasklets exceed "
                    f"max_fused_tasklets={self.max_fused_tasklets}")
        return None

    def apply(self, sdfg: SDFG, report: dict) -> List[str]:
        from ..analysis.diagnostics import refusal_code, refusal_diagnostic
        from ..codegen.pallas_backend import (GRID_ANNOTATION,
                                              analyze_map_scope)
        from ..core.memlet import BlockFactorError
        from ..core.sdfg import MapEntry

        # symbols mutated by interstate assignments are not compile-time
        # constants; subsets referencing them must fall back.
        mutated = set()
        for _, _, d in sdfg.cfg.edges(data=True):
            e = d.get("edge")
            if e is not None and e.assignments:
                mutated |= set(e.assignments)
        env = {k: v for k, v in sdfg.symbol_values.items()
               if k not in mutated}

        converted, skipped, fallbacks, decisions = [], [], [], []
        for st in sdfg.states:
            scopes = st.scope_children()
            for node in st.nodes:
                if not isinstance(node, MapEntry):
                    continue
                try:
                    spec = analyze_map_scope(sdfg, st, node, scopes, env)
                except BlockFactorError as exc:
                    # drop any annotation from an earlier run: a stale
                    # spec would emit a kernel with outdated BlockSpecs
                    node.map.annotations.pop(GRID_ANNOTATION, None)
                    fallbacks.append((node.map.label, str(exc)))
                    report.setdefault("refusals", []).append(
                        refusal_diagnostic("grid_fallback", node.map.label,
                                           str(exc)).to_dict())
                    continue
                est = self.estimate(spec, sdfg)
                reason = self.skip_reason(est)
                if reason is not None:
                    node.map.annotations.pop(GRID_ANNOTATION, None)
                    skipped.append((node.map.label, reason))
                    decisions.append({"map": node.map.label,
                                      "decision": "vmap", "reason": reason,
                                      "code": refusal_code("grid", reason),
                                      **est})
                    report.setdefault("refusals", []).append(
                        refusal_diagnostic("grid", node.map.label,
                                           reason).to_dict())
                    continue
                node.map.annotations[GRID_ANNOTATION] = spec
                converted.append({"map": spec.kernel_name, **est})
                decisions.append({"map": spec.kernel_name,
                                  "decision": "grid", "reason": None, **est})
        report.setdefault("grid_kernels", []).extend(
            c["map"] for c in converted)
        report.setdefault("grid_converted", []).extend(converted)
        report.setdefault("grid_skipped", []).extend(skipped)
        report.setdefault("grid_fallbacks", []).extend(fallbacks)
        report.setdefault("grid_decisions", []).extend(decisions)
        return [c["map"] for c in converted]


@register_pass
class ShardMapPass(Pass):
    """Partition an eligible DEVICE/PIPELINED map scope's outermost
    dimension across a 1-D mesh axis (transforms/shard_map.py): memlet
    analysis classifies every container as shard-local, replicated, or
    collective (wcr over the partition -> ``psum``); halo reads across
    the shard boundary are a typed refusal recorded in
    ``report["grid_decisions"]``. The SDFG's shapes and ranges divide by
    ``n_shards`` in place and the backend wraps the built callable in
    ``shard_map`` (codegen/shard.py). Runs after MapFusion (fused scopes
    partition as one) and before Vectorization/MapTiling, so tiling and
    grid derivation happen on the shard-local shapes.

    ``n_shards`` and ``mesh_sig`` are part of ``options()`` — a mesh
    shrink (or the same shard count over a different device set) changes
    the pipeline signature, so recompiling onto a changed mesh is a
    compilation-cache miss, never a stale kernel."""

    name = "ShardMap"

    def __init__(self, n_shards: int = 1, axis: str = "shard",
                 mesh_sig: Optional[str] = None):
        self.n_shards = int(n_shards)
        self.axis = axis
        self.mesh_sig = mesh_sig

    def should_skip(self, sdfg: SDFG) -> bool:
        return self.n_shards <= 1

    def options(self) -> Dict[str, Any]:
        return {"n_shards": self.n_shards, "axis": self.axis,
                "mesh_sig": self.mesh_sig}

    def apply(self, sdfg: SDFG, report: dict):
        from ..analysis.diagnostics import refusal_code, refusal_diagnostic
        from ..transforms.shard_map import partition_sdfg
        res = partition_sdfg(sdfg, self.n_shards, self.axis)
        for d in res["decisions"]:
            entry = {"map": d.get("map"), "decision": d["decision"],
                     "reason": d.get("reason")}
            entry.update({k: v for k, v in d.items()
                          if k in ("container", "dim", "how", "op",
                                   "extent")})
            if d["decision"] in ("unsharded", "shard_refused"):
                label = d.get("map") or d.get("container") or "<sdfg>"
                entry["code"] = refusal_code("shard", d.get("reason"))
                report.setdefault("grid_skipped", []).append(
                    (label, f"shard refused: {d.get('reason')}"))
                report.setdefault("refusals", []).append(
                    refusal_diagnostic("shard", label,
                                       d.get("reason")).to_dict())
            report.setdefault("grid_decisions", []).append(entry)
        report["shard_map"] = {"sharded": res["sharded"],
                               "n_shards": self.n_shards,
                               "axis": self.axis,
                               "specs": res.get("specs", {}),
                               "psum": res.get("psum", [])}
        return ("sharded" if res["sharded"] else "refused",
                len(res.get("specs", {})))


@register_pass
class ExpandLibraryNodesPass(Pass):
    """Multi-level Library-Node expansion (paper §3): lower every abstract
    node to its implementation subgraph, honoring the SDFG's expansion
    preference (or a forced ``level``)."""

    name = "ExpandLibraryNodes"

    def __init__(self, level: Optional[str] = None):
        self.level = level

    def apply(self, sdfg: SDFG, report: dict) -> List[str]:
        log = sdfg.expand_library_nodes(level=self.level)
        report.setdefault("expansions", []).extend(log)
        return log

    def should_skip(self, sdfg: SDFG) -> bool:
        return not sdfg.all_library_nodes()

    def options(self):
        return {"level": self.level}


# ---------------------------------------------------------------------------
# PassManager
# ---------------------------------------------------------------------------

PassLike = Union[Pass, Transformation, type, str]


def _as_pass(p: PassLike) -> Pass:
    if isinstance(p, Pass):
        return p
    if isinstance(p, str):
        try:
            return PASS_REGISTRY[p]()
        except KeyError:
            raise KeyError(
                f"unknown pass {p!r}; registered: {sorted(PASS_REGISTRY)}")
    if isinstance(p, type) and issubclass(p, Pass):
        return p()
    if isinstance(p, type) and issubclass(p, Transformation):
        return TransformationPass(p)
    if isinstance(p, Transformation):
        wrapped = TransformationPass(type(p))
        wrapped._transformation_instance = p
        # instance may carry constructor state (e.g. tile_size); apply it
        wrapped.apply = lambda sdfg, report, _t=p: sdfg.apply(_t)
        wrapped.options = lambda _t=p: {
            "transformation": type(_t).__name__,
            **{k: v for k, v in vars(_t).items()}}
        return wrapped
    raise TypeError(f"cannot interpret {p!r} as a Pass")


class PassManager:
    """Ordered, named, skippable pass list with per-pass timing.

    ``run`` executes the passes in order against one SDFG, appending one
    entry per pass to ``report['passes']``:

        {"name", "skipped", "seconds", "summary"}

    Passes named in ``skip`` (constructor or ``run`` argument) are recorded
    but not executed. ``signature()`` canonicalizes the full configuration
    for the compilation-cache key.

    ``verify`` arms the static verification harness (``analysis.verify``):
    ``"full"`` re-runs the verifier after every executed pass, diffs the
    structural snapshot, attributes any *new* violation to the pass that
    introduced it, and records everything under ``report["verify"]``;
    ``"strict"`` additionally raises
    :class:`~repro.analysis.diagnostics.VerificationError` at the first
    offending pass. Violations present *before* the pipeline ran are
    recorded as the baseline, not attributed.
    """

    def __init__(self, passes: Iterable[PassLike] = (), name: str = "custom",
                 skip: Iterable[str] = (), verify: Optional[str] = None):
        self.name = name
        self.passes: List[Pass] = [_as_pass(p) for p in passes]
        self.skip = set(skip)
        if verify not in (None, "full", "strict"):
            raise ValueError(f"verify must be None, 'full' or 'strict', "
                             f"got {verify!r}")
        self.verify = verify

    def append(self, p: PassLike) -> "PassManager":
        self.passes.append(_as_pass(p))
        return self

    def extend(self, ps: Iterable[PassLike]) -> "PassManager":
        for p in ps:
            self.append(p)
        return self

    def run(self, sdfg: SDFG, report: Optional[dict] = None,
            skip: Iterable[str] = (), verify: Optional[str] = None) -> dict:
        report = report if report is not None else {}
        entries = report.setdefault("passes", [])
        skip_names = self.skip | set(skip)
        verify = verify if verify is not None else self.verify
        vrec = snap = known = None
        if verify:
            from ..analysis.verify import (diff_snapshots, snapshot,
                                           verify_sdfg)
            baseline = verify_sdfg(sdfg)
            known = {d.key() for d in baseline}
            vrec = {"mode": verify,
                    "baseline": [d.to_dict() for d in baseline],
                    "passes": [], "violations": 0}
            report["verify"] = vrec
            snap = snapshot(sdfg)
        for p in self.passes:
            entry = {"name": p.name, "skipped": False, "seconds": 0.0,
                     "summary": None}
            entries.append(entry)
            if p.name in skip_names or p.should_skip(sdfg):
                entry["skipped"] = True
                continue
            t0 = time.perf_counter()
            entry["summary"] = _summarize(p.apply(sdfg, report))
            entry["seconds"] = time.perf_counter() - t0
            if verify:
                from ..analysis.diagnostics import VerificationError
                diags = verify_sdfg(sdfg)
                new = [d.attributed(p.name) for d in diags
                       if d.key() not in known]
                known |= {d.key() for d in new}
                new_snap = snapshot(sdfg)
                vrec["passes"].append({
                    "name": p.name,
                    "clean": not new,
                    "violations": [d.to_dict() for d in new],
                    "diff": diff_snapshots(snap, new_snap),
                })
                vrec["violations"] += len(new)
                snap = new_snap
                if new and verify == "strict":
                    raise VerificationError(new)
        return report

    def signature(self) -> Tuple:
        return (tuple(p.signature() for p in self.passes),
                tuple(sorted(self.skip)))

    def __iter__(self):
        return iter(self.passes)

    def __len__(self):
        return len(self.passes)

    def __repr__(self):
        return (f"PassManager({self.name}: "
                f"{[p.name for p in self.passes]})")


def _summarize(result) -> Any:
    """Keep report entries small and printable."""
    if isinstance(result, (list, tuple)) and len(result) > 16:
        return f"{len(result)} items"
    return result


def default_pipeline(backend: str, interpret: bool = True,
                     expansion_level: Optional[str] = None,
                     n_shards: int = 1,
                     shard_axis: str = "shard",
                     mesh_sig: Optional[str] = None) -> PassManager:
    """Backend-specific default lowering pipeline (paper §2.1 vendor split).

    ``jnp``     -- XLA-auto: prefer (xla, generic) expansions; XLA fuses.
    ``pallas``  -- explicit: fuse stream-connected chains into Pallas
                   kernels first, then prefer (pallas, xla, generic);
                   expanded map pairs fuse (MapFusion) before tiling so
                   producer->consumer chains become single grid kernels.
                   Vectorization records the lane width that MapTiling's
                   alignment-aware multi-dimensional defaults consume
                   (minor dim -> 128 lanes, next dim -> dtype-aware
                   sublanes); on CPU-interpret runs the measured
                   crossover table (``GridConversionPass.default_tiles``)
                   overrides both preferred widths.
    """
    shard = [ShardMapPass(n_shards=n_shards, axis=shard_axis,
                          mesh_sig=mesh_sig)] \
        if n_shards > 1 else []
    if backend == "pallas":
        tiles = GridConversionPass.default_tiles("pallas", interpret)
        return PassManager([
            SetExpansionPreferencePass(("pallas", "xla", "generic")),
            PipelineFusionPass(interpret=interpret),
            ExpandLibraryNodesPass(level=expansion_level),
            MapFusionPass(),
            # ShardMap before Vectorization/MapTiling: tiles and grids
            # derive from the shard-local shapes
            *shard,
            VectorizationPass(),
            MapTilingPass(tile_size=tiles.get("minor"),
                          second_size=tiles.get("second")),
            GridConversionPass(),
        ], name="pallas_default" if not shard else "pallas_sharded")
    return PassManager([
        SetExpansionPreferencePass(("xla", "generic")),
        ExpandLibraryNodesPass(level=expansion_level),
        *shard,
    ], name="jnp_default" if not shard else "jnp_sharded")
