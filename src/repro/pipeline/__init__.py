"""Staged AOT compilation: ``Wrapped -> Lowered -> Compiled``.

Entry points:

  * ``@dc_program`` (frontends.api) returns a :class:`Wrapped`;
  * :func:`lower` wraps a hand-built SDFG into a :class:`Lowered`;
  * ``Lowered.optimize(pipeline)`` runs mid-level passes;
  * ``Lowered.compile(backend=...)`` runs the backend pipeline and caches
    the result in :data:`COMPILATION_CACHE`.

See ARCHITECTURE.md for the stage lifecycle and how to register custom
passes.
"""
from .cache import COMPILATION_CACHE, CompilationCache
from .passes import (PASS_REGISTRY, DeviceOffloadPass, ExpandLibraryNodesPass,
                     GridConversionPass, InputToConstantPass, MapFusionPass,
                     MapTilingPass, Pass, PassManager,
                     PipelineFusionPass, SetExpansionPreferencePass,
                     StreamingCompositionPass, StreamingMemoryPass,
                     TransformationPass, VectorizationPass, default_pipeline,
                     register_pass)
from .stages import BACKENDS, Compiled, Lowered, Stage, Wrapped, lower

__all__ = [
    "BACKENDS", "COMPILATION_CACHE", "CompilationCache", "Compiled",
    "DeviceOffloadPass", "ExpandLibraryNodesPass", "GridConversionPass",
    "InputToConstantPass",
    "Lowered", "MapFusionPass", "MapTilingPass",
    "PASS_REGISTRY", "Pass", "PassManager",
    "PipelineFusionPass", "SetExpansionPreferencePass", "Stage",
    "StreamingCompositionPass", "StreamingMemoryPass", "TransformationPass",
    "VectorizationPass", "Wrapped", "default_pipeline", "lower",
    "register_pass",
]
