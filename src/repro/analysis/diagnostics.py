"""Typed diagnostic taxonomy for static SDFG analysis.

Every finding the verifier (``analysis.verify``) or a pass refusal can
produce is a :class:`Diagnostic` carrying a stable code from one
vocabulary, so ``report["grid_decisions"]`` refusals and verifier
violations speak the same language and CI can gate on codes instead of
string-matching prose.

Code families
-------------

``STRUCT``  structural validity (name collisions, connector shadowing)
``RACE``    map-scope and inter-state data races
``BND``     memlet bounds / volume consistency
``ANN``     pass-to-codegen annotation consistency (tiling, grid specs)
``SHD``     shard-map classification consistency
``DON``     buffer-donation aliasing lints
``FUS``     MapFusion refusal reasons (info severity)
``GRD``     GridConversion refusal reasons (info severity)
``SHR``     ShardMap refusal reasons (info severity)
"""
from __future__ import annotations

from dataclasses import dataclass, field, replace
from typing import Dict, List, Optional, Tuple

#: code -> one-line meaning (the ARCHITECTURE.md table is generated from
#: this registry; keep the descriptions self-contained).
CODES: Dict[str, str] = {
    # structural
    "STRUCT000": "core structural validation failed (core.validation)",
    "STRUCT001": "container name collides with a symbol name",
    "STRUCT002": "tasklet connector shadowing (duplicate connector name)",
    # races
    "RACE001": "write-write race: map iterations write overlapping "
               "elements without wcr",
    "RACE002": "read-write conflict: a map scope reads elements another "
               "iteration writes",
    "RACE003": "inter-state ordering hazard: unordered states access a "
               "container and at least one writes",
    # bounds / volume
    "BND001": "memlet subset provably outside its container under the "
              "map ranges",
    "BND002": "transient consumed outside its produced region",
    "BND003": "memlet volume smaller than its subset",
    # annotation consistency
    "ANN001": "tiling annotation out of sync with the map ranges",
    "ANN002": "derived grid spec out of sync with the map scope",
    # shard classification
    "SHD001": "shard spec names an unknown container or dimension",
    "SHD002": "psum-classified container has no wcr('add') write",
    "SHD003": "replicated-classified container is written per shard",
    # donation lints
    "DON001": "donated buffer is never written (output aliasing hazard)",
    "DON002": "donated name is not a program argument",
    # pass-refusal families (info severity; reasons stay verbatim)
    "FUS001": "fusion refused: access reorder hazard",
    "FUS002": "fusion refused: intermediate not fusible",
    "FUS003": "fusion refused: iteration ranges not static/untiled",
    "FUS004": "fusion refused: replication or tasklet budget exceeded",
    "FUS005": "fusion refused: read pattern unsupported (shift/window/"
              "non-affine)",
    "FUS006": "fusion refused: wcr mode unsupported",
    "FUS007": "fusion refused: fusing would create a cycle",
    "FUS000": "fusion refused: other",
    "GRD001": "grid conversion skipped: VMEM budget exceeded",
    "GRD002": "grid conversion skipped: grid too small",
    "GRD003": "grid conversion skipped: fused chain too long",
    "GRD004": "grid fallback: subset not factorable into BlockSpecs",
    "GRD000": "grid conversion skipped: other",
    "SHR001": "shard refused: nothing to partition",
    "SHR002": "shard refused: read crosses the shard boundary",
    "SHR003": "shard refused: extent not divisible / partial iteration",
    "SHR004": "shard refused: declared classification conflict",
    "SHR000": "shard refused: other",
}


@dataclass(frozen=True)
class Diagnostic:
    """One typed finding. ``pass_name`` is attribution filled in by the
    verification harness (the pass after which the finding first
    appeared); it is excluded from :meth:`key` so the same violation is
    one finding regardless of when it was noticed."""

    code: str
    message: str
    state: Optional[str] = None
    scope: Optional[str] = None         # map label
    container: Optional[str] = None
    severity: str = "error"             # "error" | "info"
    pass_name: Optional[str] = None

    def key(self) -> Tuple:
        return (self.code, self.state, self.scope, self.container,
                self.message)

    def attributed(self, pass_name: str) -> "Diagnostic":
        return replace(self, pass_name=pass_name)

    def to_dict(self) -> dict:
        return {"code": self.code, "message": self.message,
                "state": self.state, "scope": self.scope,
                "container": self.container, "severity": self.severity,
                "pass": self.pass_name}

    def __str__(self):
        where = "/".join(x for x in (self.state, self.scope,
                                     self.container) if x)
        at = f" [{where}]" if where else ""
        via = f" (introduced by {self.pass_name})" if self.pass_name else ""
        return f"{self.code}{at}: {self.message}{via}"


class VerificationError(Exception):
    """Raised in strict verify mode when a pass introduces violations."""

    def __init__(self, diagnostics: List[Diagnostic]):
        self.diagnostics = list(diagnostics)
        lines = "\n  ".join(str(d) for d in self.diagnostics)
        super().__init__(f"{len(self.diagnostics)} verifier violation(s):"
                         f"\n  {lines}")


# ---------------------------------------------------------------------------
# Refusal-reason classification (PR-7/PR-9 typed reasons -> codes)
# ---------------------------------------------------------------------------

#: ordered (substring, code) rules per refusal source; first match wins.
#: The verbatim reason strings stay in the report — the code is *added*.
_REFUSAL_RULES = {
    "fusion": (
        ("reorder accesses", "FUS001"),
        ("pinned to HBM", "FUS002"),
        ("not a fusible transient", "FUS002"),
        ("more than one node", "FUS002"),
        ("no unique static write", "FUS002"),
        ("mixes wcr and plain writes", "FUS002"),
        ("untiled scopes", "FUS003"),
        ("static unit-step", "FUS003"),
        ("exceed", "FUS004"),
        ("replication cost threshold", "FUS004"),
        ("cannot be replicated", "FUS004"),
        ("cannot replicate", "FUS004"),
        ("windowed slice", "FUS005"),
        ("shifted", "FUS005"),
        ("outside the producer", "FUS005"),
        ("element-exact", "FUS005"),
        ("rank mismatch", "FUS005"),
        ("affine", "FUS005"),
        ("not bound by the reduction", "FUS005"),
        ("differs from the reduction", "FUS005"),
        ("parameter pairing", "FUS005"),
        ("captures a", "FUS005"),
        ("wcr", "FUS006"),
        ("cycle", "FUS007"),
        ("another path", "FUS007"),
    ),
    "grid": (
        ("VMEM", "GRD001"),
        ("min_grid_steps", "GRD002"),
        ("max_fused_tasklets", "GRD003"),
    ),
    "shard": (
        ("nothing to partition", "SHR001"),
        ("crosses the shard boundary", "SHR002"),
        ("halo", "SHR002"),
        ("offset", "SHR002"),
        ("divisible", "SHR003"),
        ("partial iteration", "SHR003"),
        ("different extents", "SHR003"),
        ("symbolic range", "SHR003"),
        ("declared", "SHR004"),
        ("conflict", "SHR004"),
    ),
}

_REFUSAL_FALLBACK = {"fusion": "FUS000", "grid": "GRD000",
                     "shard": "SHR000", "grid_fallback": "GRD004"}


def refusal_code(source: str, reason: Optional[str]) -> str:
    """Classify a pass-refusal reason string onto the shared taxonomy.

    ``source`` is one of ``fusion`` (MapFusion), ``grid``
    (GridConversion cost model), ``grid_fallback`` (BlockFactorError
    fallbacks), ``shard`` (ShardMapPass). The verbatim reason is never
    rewritten — callers attach the code alongside it."""
    if source == "grid_fallback":
        return "GRD004"
    rules = _REFUSAL_RULES.get(source, ())
    text = reason or ""
    for needle, code in rules:
        if needle in text:
            return code
    return _REFUSAL_FALLBACK.get(source, "GRD000")


def refusal_diagnostic(source: str, scope: Optional[str],
                       reason: Optional[str]) -> Diagnostic:
    """A refusal as an info-severity Diagnostic (shared vocabulary)."""
    return Diagnostic(code=refusal_code(source, reason),
                      message=reason or "", scope=scope, severity="info")
