"""Pass-to-codegen annotation consistency checks.

The transforms communicate with the Pallas code generator through
annotations — ``Map.annotations["tiling"]`` (MapTiling), the derived
``pallas_grid`` GridSpec (GridConversionPass), and the SDFG-level
``shard_map`` metadata (ShardMapPass). A transform that edits a map
after another pass annotated it can silently desynchronize the two
views; these checks re-derive the cheap invariants from scratch.

``ANN001`` — a tiling annotation disagrees with the map's ranges
    (missing intra/counter parameter, wrong tile/block extent, or a
    block count that cannot cover the recorded extent).
``ANN002`` — a ``pallas_grid`` GridSpec names parameters the map no
    longer has, or its grid/block extents disagree with the ranges.
``SHD001`` — a shard spec names an unknown container or a dimension
    outside the container's rank.
``SHD002`` — a psum-classified container has no wcr write anywhere
    (nothing produces the partial values the collective combines).
``SHD003`` — a replicated-classified container receives a plain write
    inside a shard-divided map scope (each shard would write different
    values into a buffer declared identical across shards).
"""
from __future__ import annotations

import math
from typing import Dict, List, Optional

from ..core.sdfg import (MapEntry, MapExit, NestedSDFG, SDFG, Tasklet)
from ..transforms.map_tiling import normalize_tiling
from .affine import edge_scope, scope_map, static_env
from .diagnostics import Diagnostic


def _range_size(m, param, env) -> Optional[int]:
    for p, r in zip(m.params, m.ranges):
        if p == param:
            try:
                return r.size.subs(env).as_int()
            except Exception:
                return None
    return None


def check_tiling(sdfg: SDFG) -> List[Diagnostic]:
    env = static_env(sdfg)
    diags: List[Diagnostic] = []
    for state in sdfg.states:
        for node in state.nodes:
            if not isinstance(node, MapEntry):
                continue
            m = node.map
            tiling = normalize_tiling(m.annotations.get("tiling"))
            for pi, info in tiling.items():
                pt = info.get("counter")
                if pt is None:
                    continue            # legacy exact-divisible entry
                problems = []
                if pi not in m.params:
                    problems.append(f"intra parameter '{pi}' missing")
                if pt not in m.params:
                    problems.append(f"counter parameter '{pt}' missing")
                tile, blocks = info.get("tile"), info.get("blocks")
                extent = info.get("extent")
                sz_pi = _range_size(m, pi, env)
                sz_pt = _range_size(m, pt, env)
                if tile is not None and sz_pi is not None and sz_pi != tile:
                    problems.append(f"'{pi}' iterates {sz_pi} != tile "
                                    f"{tile}")
                if blocks is not None and sz_pt is not None \
                        and sz_pt != blocks:
                    problems.append(f"'{pt}' iterates {sz_pt} != blocks "
                                    f"{blocks}")
                if tile and blocks is not None and extent is not None \
                        and blocks != math.ceil(extent / tile):
                    problems.append(f"{blocks} blocks of {tile} cannot "
                                    f"tile extent {extent}")
                for p in problems:
                    diags.append(Diagnostic(
                        code="ANN001",
                        message=(f"tiling annotation of map '{m.label}' "
                                 f"desynchronized: {p}"),
                        state=state.label, scope=m.label))
    return diags


def check_grid_specs(sdfg: SDFG) -> List[Diagnostic]:
    from ..codegen.pallas_backend import GRID_ANNOTATION
    env = static_env(sdfg)
    diags: List[Diagnostic] = []
    for state in sdfg.states:
        for node in state.nodes:
            if not isinstance(node, MapEntry):
                continue
            m = node.map
            spec = m.annotations.get(GRID_ANNOTATION)
            if spec is None:
                continue
            problems = []
            for p, size in getattr(spec, "grid", ()):
                sz = _range_size(m, p, env)
                if p not in m.params:
                    problems.append(f"grid parameter '{p}' missing from "
                                    "the map")
                elif sz is not None and sz != size:
                    problems.append(f"grid dim '{p}' spans {size} but the "
                                    f"map iterates {sz}")
            for p, extent in getattr(spec, "block_params", ()):
                sz = _range_size(m, p, env)
                if p not in m.params:
                    problems.append(f"block parameter '{p}' missing from "
                                    "the map")
                elif sz is not None and sz != extent:
                    problems.append(f"block dim '{p}' spans {extent} but "
                                    f"the map iterates {sz}")
            for p in problems:
                diags.append(Diagnostic(
                    code="ANN002",
                    message=(f"grid spec of map '{m.label}' "
                             f"desynchronized: {p}"),
                    state=state.label, scope=m.label))
    return diags


# ---------------------------------------------------------------------------
# Shard classification (SHD001-SHD003)
# ---------------------------------------------------------------------------


def check_shard(sdfg: SDFG) -> List[Diagnostic]:
    from ..transforms.shard_map import SHARD_ANNOTATION
    meta = sdfg.metadata.get(SHARD_ANNOTATION)
    if not meta:
        return []
    diags: List[Diagnostic] = []
    specs: Dict[str, Optional[int]] = meta.get("specs", {})
    psum = set(meta.get("psum", ()))
    divided_labels = {lbl for lbl, _ in meta.get("divided", ())}
    for name, dim in specs.items():
        desc = sdfg.arrays.get(name)
        if desc is None:
            diags.append(Diagnostic(
                code="SHD001",
                message=f"shard spec names unknown container '{name}'",
                container=name))
            continue
        rank = len(getattr(desc, "shape", ()) or ())
        if dim is not None and not (0 <= dim < rank):
            diags.append(Diagnostic(
                code="SHD001",
                message=(f"shard spec partitions dim {dim} of '{name}' "
                         f"(rank {rank})"),
                container=name))
    wcr_written = set()
    plain_writes = []   # (state, scope_chain_labels, container)
    for state in sdfg.states:
        scope_of = scope_map(state)
        for e in state.edges:
            m = e.memlet
            if m is None or m.data is None:
                continue
            is_write = (isinstance(e.src, Tasklet)
                        and isinstance(e.dst, (MapExit,))) \
                or (isinstance(e.src, Tasklet)
                    and not isinstance(e.dst, Tasklet))
            if not is_write:
                continue
            if m.wcr is not None:
                wcr_written.add(m.data)
                continue
            scope = edge_scope(e, scope_of)
            chain = []
            seen = set()
            while scope is not None and id(scope) not in seen:
                seen.add(id(scope))
                chain.append(scope.map.label)
                scope = scope_of.get(scope)
            plain_writes.append((state.label, chain, m.data))
    for name in sorted(psum):
        if name not in wcr_written:
            diags.append(Diagnostic(
                code="SHD002",
                message=(f"psum-classified container '{name}' has no "
                         "wcr('add') write producing shard partials"),
                container=name))
    flagged = set()
    for state_label, chain, name in plain_writes:
        if name in flagged or name in psum:
            continue
        if specs.get(name, 0) is not None:   # sharded or not classified
            continue
        if any(lbl in divided_labels for lbl in chain):
            flagged.add(name)
            diags.append(Diagnostic(
                code="SHD003",
                message=(f"replicated-classified container '{name}' is "
                         f"written inside shard-divided scope(s) "
                         f"{[l for l in chain if l in divided_labels]}"),
                state=state_label, container=name))
    return diags


def check_annotations(sdfg: SDFG) -> List[Diagnostic]:
    diags: List[Diagnostic] = []
    diags.extend(check_tiling(sdfg))
    diags.extend(check_grid_specs(sdfg))
    diags.extend(check_shard(sdfg))
    for st in sdfg.states:
        for n in st.nodes:
            if isinstance(n, NestedSDFG):
                diags.extend(check_annotations(n.sdfg))
    return diags
