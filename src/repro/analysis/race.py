"""Map-scope and inter-state data-race detection.

Three checks, all built on the mixed-radix/affine machinery the
transforms already trust:

``RACE001`` — a map scope writes a container without ``wcr`` and the
    write subset is *not* provably injective across iteration points
    (the same :func:`~repro.transforms.map_fusion._injective_write`
    proof MapFusion uses for its write-order = read-order rule).
``RACE002`` — a map scope both reads and writes a container at
    *different* per-iteration subsets: iteration ``i`` may observe
    iteration ``j``'s write. Element-local read-modify-write (equal
    subsets, plain write) is the benign in-place pattern and passes.
``RACE003`` — two states with no control-flow ordering between them
    access the same container and at least one writes it.

Everything is prove-or-stay-silent in the *safe* direction for a
verifier: a race is only reported when the subset is affine in the map
parameters and every relevant extent is static, so a symbolic program
is never flagged on spec alone — but canonical pipeline output (which
is fully static after specialization) gets the exact proof.
"""
from __future__ import annotations

from typing import Dict, List, Optional, Tuple

from ..core.memlet import Subset
from ..core.sdfg import (AccessNode, MapEntry, MapExit, NestedSDFG, SDFG,
                         State, Tasklet)
from ..core.symbolic import Expr
from ..transforms.map_fusion import _injective_write
from .affine import edge_scope, param_box, scope_map, static_env
from .diagnostics import Diagnostic

import networkx as nx


def _subset_key(subset: Optional[Subset]) -> Tuple:
    """Canonical per-iteration identity of a subset (symbolic, exact)."""
    if subset is None:
        return ("*",)
    key = []
    for r in subset:
        key.append((tuple(sorted(Expr.wrap(r.start).terms.items())),
                    tuple(sorted(Expr.wrap(r.stop).terms.items())),
                    tuple(sorted(Expr.wrap(r.step).terms.items()))))
    return tuple(key)


def _params_affine(subset: Optional[Subset], params) -> bool:
    """True when every range bound is affine in the map parameters —
    the precondition under which ``_injective_write``'s rejection is a
    meaningful non-injectivity verdict rather than "could not prove"."""
    if subset is None:
        return True
    pset = set(params)
    for r in subset:
        for e in (r.start, r.stop, r.step):
            for mono, _ in Expr.wrap(e).terms.items():
                names = [nm for nm, p in mono]
                if any(nm in pset for nm in names):
                    if len(mono) != 1 or mono[0][1] != 1:
                        return False
    return True


def _scope_sizes(entry: MapEntry,
                 scope_of: Dict,
                 env: Dict[str, int]) -> Optional[Dict[str, int]]:
    """{param: static iteration count} for ``entry`` and all enclosing
    scopes; None when any extent is unevaluable (stay silent)."""
    sizes: Dict[str, int] = {}
    cur: Optional[MapEntry] = entry
    seen = set()
    while cur is not None and id(cur) not in seen:
        seen.add(id(cur))
        for p, r in zip(cur.map.params, cur.map.ranges):
            try:
                sizes[p] = r.size.subs(env).as_int()
            except Exception:
                return None
        cur = scope_of.get(cur)
    return sizes


def _is_stream(sdfg: SDFG, name: str) -> bool:
    desc = sdfg.arrays.get(name)
    return desc is not None and not hasattr(desc, "shape") \
        and type(desc).__name__ == "Stream"


def _scope_accesses(state: State, scope_of: Dict):
    """Per innermost scope: the tasklet-level read and write edges.

    Reads are ``MapEntry -> Tasklet`` edges (the per-iteration element
    view); writes are ``Tasklet -> MapExit`` edges. Aggregated restated
    memlets on the outside of the scope (``AccessNode -> MapEntry``,
    ``MapExit -> AccessNode``) and fused register edges between tasklets
    are deliberately excluded — they describe the same movement at a
    different granularity.
    """
    accesses: Dict[MapEntry, Dict[str, list]] = {}
    for e in state.edges:
        if e.memlet is None or e.memlet.data is None:
            continue
        if isinstance(e.src, Tasklet) and isinstance(e.dst, Tasklet):
            continue  # fused register traffic, iteration-private
        kind = None
        if isinstance(e.src, MapEntry) and isinstance(e.dst, Tasklet):
            kind = "read"
        elif isinstance(e.src, Tasklet) and isinstance(e.dst, MapExit):
            kind = "write"
        elif isinstance(e.src, Tasklet) and isinstance(e.dst, AccessNode):
            kind = "write"
        if kind is None:
            continue
        scope = edge_scope(e, scope_of)
        if scope is None:
            continue  # top-level tasklet: single execution, no race
        accesses.setdefault(scope, {}).setdefault(
            e.memlet.data, []).append((kind, e))
    return accesses


def check_state_races(sdfg: SDFG, state: State,
                      env: Dict[str, int]) -> List[Diagnostic]:
    diags: List[Diagnostic] = []
    scope_of = scope_map(state)
    accesses = _scope_accesses(state, scope_of)
    for scope, by_container in accesses.items():
        sizes = _scope_sizes(scope, scope_of, env)
        for name, acc in by_container.items():
            if _is_stream(sdfg, name):
                continue  # push/pop semantics, ordered by construction
            writes = [e for k, e in acc if k == "write"]
            reads = [e for k, e in acc if k == "read"]
            # RACE001: non-injective plain write across iterations
            for e in writes:
                m = e.memlet
                if m.wcr is not None or m.dynamic:
                    continue
                if sizes is None:
                    continue  # extent unprovable: stay silent
                if not _params_affine(m.subset, sizes):
                    continue  # cannot reason: stay silent
                if not _injective_write(m.subset, dict(sizes)):
                    diags.append(Diagnostic(
                        code="RACE001",
                        message=(f"map '{scope.map.label}' writes "
                                 f"'{name}' at {m.subset!r} without wcr "
                                 "and distinct iterations overlap"),
                        state=state.label, scope=scope.map.label,
                        container=name))
            # RACE002: read subset differs from every write subset
            if writes and reads:
                wkeys = {_subset_key(e.memlet.subset) for e in writes}
                wcr_write = any(e.memlet.wcr is not None for e in writes)
                for e in reads:
                    rk = _subset_key(e.memlet.subset)
                    if not wcr_write and rk in wkeys:
                        continue  # element-local RMW
                    if sizes is None or any(sz is None
                                            for sz in sizes.values()):
                        continue
                    if all(sz <= 1 for sz in sizes.values()):
                        continue  # single iteration point
                    diags.append(Diagnostic(
                        code="RACE002",
                        message=(f"map '{scope.map.label}' reads "
                                 f"'{name}' at {e.memlet.subset!r} while "
                                 "another iteration writes it"),
                        state=state.label, scope=scope.map.label,
                        container=name))
    return diags


def _state_container_access(state: State):
    """(reads, writes) container-name sets at state granularity."""
    reads, writes = set(), set()
    for n in state.nodes:
        if not isinstance(n, AccessNode):
            continue
        if state.out_edges(n):
            reads.add(n.data)
        if state.in_edges(n):
            writes.add(n.data)
    return reads, writes


def check_interstate_races(sdfg: SDFG) -> List[Diagnostic]:
    """RACE003: unordered state pairs sharing a container with a writer."""
    diags: List[Diagnostic] = []
    states = list(sdfg.states)
    if len(states) < 2:
        return diags
    reach = {s: nx.descendants(sdfg.cfg, s) | {s} for s in states
             if s in sdfg.cfg}
    summary = {s: _state_container_access(s) for s in states}

    def guarded(s):
        # A state entered through a conditional edge may be mutually
        # exclusive with its unordered siblings — stay silent.
        return any(d.get("edge") is not None
                   and getattr(d["edge"], "condition", None) is not None
                   for _, _, d in sdfg.cfg.in_edges(s, data=True))

    for i, a in enumerate(states):
        for b in states[i + 1:]:
            if a not in reach or b not in reach:
                continue
            if b in reach[a] or a in reach[b]:
                continue  # ordered by control flow
            if guarded(a) or guarded(b):
                continue
            ra, wa = summary[a]
            rb, wb = summary[b]
            conflict = (wa & wb) | (wa & rb) | (ra & wb)
            for name in sorted(conflict):
                if _is_stream(sdfg, name):
                    continue
                diags.append(Diagnostic(
                    code="RACE003",
                    message=(f"states '{a.label}' and '{b.label}' are "
                             f"unordered in the CFG but both access "
                             f"'{name}' and at least one writes it"),
                    state=f"{a.label}|{b.label}", container=name))
    return diags


def check_races(sdfg: SDFG) -> List[Diagnostic]:
    """All race diagnostics for an SDFG (recursing into nested SDFGs)."""
    env = static_env(sdfg)
    diags: List[Diagnostic] = []
    for st in sdfg.states:
        diags.extend(check_state_races(sdfg, st, env))
        for n in st.nodes:
            if isinstance(n, NestedSDFG):
                diags.extend(check_races(n.sdfg))
    diags.extend(check_interstate_races(sdfg))
    return diags
