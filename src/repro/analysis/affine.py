"""Shared affine/interval machinery for the static analyses.

The race and bounds checkers reason about memlet subsets *under the map
ranges that bind their parameters*: which scope an edge executes in,
what integer box each parameter iterates over, and the provable
min/max of an affine index expression over that box. Everything here is
conservative — when a value cannot be proven (symbolic extent, mutated
symbol, non-affine index) the caller stays silent rather than guessing.
"""
from __future__ import annotations

from fractions import Fraction
from typing import Dict, Optional, Tuple

from ..core.memlet import Range, Subset
from ..core.sdfg import (AccessNode, MapEntry, MapExit, Node, SDFG, State)
from ..core.symbolic import Expr


def static_env(sdfg: SDFG) -> Dict[str, int]:
    """Symbol bindings that are compile-time constants: ``symbol_values``
    minus symbols mutated by interstate assignments (the same exclusion
    ``GridConversionPass`` applies)."""
    mutated = set()
    for _, _, d in sdfg.cfg.edges(data=True):
        e = d.get("edge")
        if e is not None and getattr(e, "assignments", None):
            mutated |= set(e.assignments)
    return {k: v for k, v in sdfg.symbol_values.items()
            if k not in mutated and isinstance(v, int)}


def scope_map(state: State) -> Dict[Node, Optional[MapEntry]]:
    """node -> innermost enclosing MapEntry (None = top level)."""
    out: Dict[Node, Optional[MapEntry]] = {}
    for scope, children in state.scope_children().items():
        for n in children:
            out[n] = scope
    return out


def edge_scope(e, scope_of: Dict[Node, Optional[MapEntry]]
               ) -> Optional[MapEntry]:
    """The scope an edge's data movement executes in. Edges leaving a
    MapEntry (the ``OUT_*`` side) and entering a MapExit (the ``IN_*``
    side) are *inside* that map; edges entering an entry / leaving an
    exit are outside."""
    if isinstance(e.src, MapEntry):
        return e.src
    if isinstance(e.dst, MapExit):
        return e.dst.entry
    if isinstance(e.src, MapExit):
        return scope_of.get(e.src.entry)
    if isinstance(e.dst, MapEntry):
        return scope_of.get(e.dst)
    return scope_of.get(e.dst, scope_of.get(e.src))


def param_box(entry: Optional[MapEntry],
              scope_of: Dict[Node, Optional[MapEntry]],
              env: Dict[str, int]
              ) -> Tuple[Dict[str, Tuple[int, int]], bool]:
    """Inclusive (lo, hi) iteration box per parameter for ``entry`` and
    every enclosing scope. Returns ``(box, complete)``; ``complete`` is
    False when some enclosing range could not be evaluated (those
    parameters are omitted — expressions using them stay unprovable)."""
    box: Dict[str, Tuple[int, int]] = {}
    complete = True
    seen = set()
    while entry is not None and id(entry) not in seen:
        seen.add(id(entry))
        m = entry.map
        for p, r in zip(m.params, m.ranges):
            try:
                start = r.start.subs(env).as_int()
                size = r.size.subs(env).as_int()
                step = r.step.subs(env).as_int()
            except Exception:
                complete = False
                continue
            if size < 1:
                complete = False
                continue
            box[p] = (start, start + (size - 1) * step) if step >= 0 \
                else (start + (size - 1) * step, start)
        entry = scope_of.get(entry)
    return box, complete


def expr_bounds(e: Expr, box: Dict[str, Tuple[int, int]],
                env: Dict[str, int]) -> Optional[Tuple[int, int]]:
    """Provable inclusive (min, max) of ``e`` with parameters ranging
    over ``box`` and other symbols bound by ``env``; None when the
    expression is non-affine or uses an unbound symbol."""
    e = e.subs(env)
    lo = hi = Fraction(0)
    for mono, c in e.terms.items():
        if mono == ():
            lo += c
            hi += c
            continue
        if len(mono) != 1 or mono[0][1] != 1:
            return None                       # non-affine
        name = mono[0][0]
        if name not in box:
            return None                       # unbound parameter/symbol
        plo, phi = box[name]
        if c >= 0:
            lo += c * plo
            hi += c * phi
        else:
            lo += c * phi
            hi += c * plo
    if lo.denominator != 1 or hi.denominator != 1:
        return None
    return int(lo), int(hi)


def subset_box(subset: Subset, box: Dict[str, Tuple[int, int]],
               env: Dict[str, int]
               ) -> Optional[Tuple[Tuple[int, int], ...]]:
    """Element box touched by a subset over the whole iteration space:
    per dimension the provable inclusive ``(min_start, max_last)`` where
    ``max_last`` is the largest element index the half-open range can
    reach. None when any dimension is unprovable."""
    dims = []
    for r in subset:
        b_start = expr_bounds(r.start, box, env)
        b_stop = expr_bounds(r.stop, box, env)
        if b_start is None or b_stop is None:
            return None
        dims.append((b_start[0], b_stop[1] - 1))
    return tuple(dims)


def container_extents(sdfg: SDFG, name: str,
                      env: Dict[str, int]) -> Optional[Tuple[int, ...]]:
    """Static dimension extents of a container, or None per-unknown."""
    desc = sdfg.arrays.get(name)
    shape = getattr(desc, "shape", None)
    if not shape:
        return ()
    out = []
    for s in shape:
        try:
            out.append(int(Expr.wrap(s).evaluate(env)))
        except Exception:
            return None
    return tuple(out)
