"""Static analysis and verification for the SDFG pipeline.

The paper's premise is that the SDFG captures program characteristics
precisely enough to validate them statically; this package is the
independent oracle for the legality rules the transforms otherwise
enforce ad hoc. See ``diagnostics.CODES`` for the full code table and
ARCHITECTURE.md ("Static analysis and verification") for the flow.

Entry points
------------

``verify_sdfg(sdfg)``           all error-severity findings
``Diagnostic`` / ``CODES``      the typed taxonomy
``VerificationError``           raised by strict verify mode
``refusal_code(source, reason)``  classify pass-refusal prose
``python -m repro.analysis.lint`` compile-and-verify every benchmark
"""
from .diagnostics import (CODES, Diagnostic, VerificationError,
                          refusal_code, refusal_diagnostic)
from .verify import diff_snapshots, snapshot, verify_sdfg

__all__ = [
    "CODES", "Diagnostic", "VerificationError", "refusal_code",
    "refusal_diagnostic", "verify_sdfg", "snapshot", "diff_snapshots",
]
